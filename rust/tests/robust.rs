//! Robust-aggregation property tests (ISSUE 10 acceptance criteria):
//!
//! * **robust-off is free**: with `[fl.robust]` disabled the config
//!   serializes without any robust keys, fingerprints identically, and
//!   a journaled run is **byte-identical** to one that never mentioned
//!   the section — the robust pipeline cannot perturb existing runs;
//! * **sharded == sequential**: clipping and trimming run as
//!   range-sharded stages on the ShardPool, so a robust server at
//!   `fl.shards ∈ {2,4,8, $QAFEL_TEST_SHARDS}` evolves bit-identically
//!   to the sequential `S=1` server across codecs, dimensions, seeds
//!   and staleness weights;
//! * **full-sim shard invariance**: a hostile population (heavy-tailed
//!   noise + sign flips) under clip+trim produces bit-identical
//!   training curves at `S=1` and `S=4`;
//! * **the trivial tree commutes with clipping**: one edge,
//!   forward-every-update buffer, identity partial codec, per-update
//!   clipping at the edge — the whole curve and the per-tier
//!   clipped-update counters match the flat clipped server bit for bit
//!   (the edge clips raw updates; the root never re-clips partials).

use qafel::config::{Algorithm, Config, TierConfig};
use qafel::coordinator::{Server, ServerStep};
use qafel::quant::parse_spec;
use qafel::runtime::QuadraticBackend;
use qafel::sim::SimEngine;
use qafel::util::prng::Prng;

fn shard_counts() -> Vec<usize> {
    let mut counts = vec![2usize, 4, 8];
    if let Some(s) = qafel::config::env_shards_override() {
        if !counts.contains(&s) && s > 1 {
            counts.push(s);
        }
    }
    counts
}

fn robust_cfg(client: &str, server: &str, shards: usize) -> Config {
    let mut c = Config::default();
    c.fl.algorithm = Algorithm::Qafel;
    c.quant.client = client.into();
    c.quant.server = server.into();
    c.fl.buffer_size = 3;
    c.fl.server_lr = 1.0;
    c.fl.server_momentum = 0.3;
    c.fl.shards = shards;
    c.fl.robust.enabled = true;
    c.fl.robust.clip_norm = 0.5; // low enough that large test deltas clip
    c.fl.robust.trim_frac = 0.34; // K=3: drop min and max per coordinate
    c
}

/// Drive a sequential and a sharded robust server with identical upload
/// streams and assert bit-equal evolution.
fn assert_robust_servers_identical(client: &str, server: &str, d: usize, seed: u64, shards: usize) {
    let mut s1 = Server::build(&robust_cfg(client, server, 1), vec![0.0; d], seed).unwrap();
    let mut sn = Server::build(&robust_cfg(client, server, shards), vec![0.0; d], seed).unwrap();
    let qc = parse_spec(client).unwrap();
    let mut rng1 = Prng::new(seed ^ 0xF00D);
    let mut rng2 = Prng::new(seed ^ 0xF00D);
    for round in 0..9u64 {
        // alternate small and large deltas so both the clipped and the
        // unclipped accumulate paths run
        let scale = if round % 2 == 0 { 0.01 } else { 10.0 };
        let delta: Vec<f32> = (0..d)
            .map(|i| ((i as f64 * 0.37 + round as f64).sin() * scale) as f32)
            .collect();
        let m1 = qc.quantize(&delta, &mut rng1);
        let m2 = qc.quantize(&delta, &mut rng2);
        let r1 = s1.ingest(&m1, round % 5).unwrap();
        let r2 = sn.ingest(&m2, round % 5).unwrap();
        assert_eq!(
            s1.last_ingest_clipped(),
            sn.last_ingest_clipped(),
            "{client}/{server} d={d} S={shards} round {round}: clip decision"
        );
        match (r1, r2) {
            (ServerStep::Stepped(b1), ServerStep::Stepped(b2)) => {
                assert_eq!(
                    b1[0].msg.payload, b2[0].msg.payload,
                    "{client}/{server} d={d} S={shards}: broadcast bytes"
                );
                assert_eq!(
                    s1.last_trim_flags(),
                    sn.last_trim_flags(),
                    "{client}/{server} d={d} S={shards}: trim attribution"
                );
            }
            (ServerStep::Buffered, ServerStep::Buffered) => {}
            _ => panic!("{client}/{server} d={d} S={shards}: step/buffer divergence"),
        }
    }
    assert_eq!(s1.model(), sn.model(), "{client}/{server} d={d} S={shards}: model");
    assert_eq!(
        s1.client_snapshot().as_slice(),
        sn.client_snapshot().as_slice(),
        "{client}/{server} d={d} S={shards}: hidden state"
    );
    assert_eq!(s1.clipped_updates, sn.clipped_updates);
    assert_eq!(s1.trimmed_updates, sn.trimmed_updates);
    assert!(s1.clipped_updates > 0, "{client}/{server} d={d}: clip never fired");
}

#[test]
fn robust_sharded_server_bit_identical_across_codecs_dims_seeds() {
    // dims straddle shard-bucket boundaries: below one bucket, exact
    // multiples, ragged tails, and a dimension smaller than shard count
    for &d in &[5usize, 128, 384, 500] {
        for seed in [1u64, 2, 3] {
            for (qc, qs) in [
                ("qsgd:4", "qsgd:4"),
                ("none", "qsgd:4"),
                ("qsgd:8", "top:0.1"),
                // biased *client* codecs exercise the sparse accumulate
                // under the clip weight
                ("top:0.2", "qsgd:4"),
                ("rand:0.2", "qsgd:4"),
            ] {
                for shards in shard_counts() {
                    assert_robust_servers_identical(qc, qs, d, seed, shards);
                }
            }
        }
    }
}

// ---------------------------------------------------------------- sim --

fn sim_cfg() -> Config {
    let mut c = Config::default();
    c.fl.algorithm = Algorithm::Qafel;
    c.fl.buffer_size = 4;
    c.fl.client_lr = 0.15;
    c.fl.server_lr = 1.0;
    c.fl.server_momentum = 0.0;
    c.fl.clip_norm = 0.0;
    c.quant.client = "qsgd:8".into();
    c.quant.server = "qsgd:8".into();
    c.sim.concurrency = 20;
    c.sim.eval_every = 10;
    c.stop.target_accuracy = 2.0; // unreachable: run the full horizon
    c.stop.max_uploads = 100_000;
    c.stop.max_server_steps = 120;
    c
}

fn sim_backend() -> QuadraticBackend {
    QuadraticBackend::new(24, 10, 1.0, 0.3, 0.3, 0.02, 2, 11)
}

fn temp_journal(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("qafel-robust-{tag}-{}.jsonl", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

#[test]
fn robust_off_run_is_byte_identical_to_plain() {
    // the acceptance bar for retrofitting the robust pipeline: a config
    // that never heard of [fl.robust] and one with every knob set but
    // `enabled = false` must fingerprint the same and journal the same
    // bytes — disabled robustness is unobservable
    let b = sim_backend();
    let path = temp_journal("off-vs-plain");
    let mut plain = sim_cfg();
    plain.telemetry.journal = Some(path.clone());
    plain.validate().unwrap();

    // same journal path (the Meta event embeds the resolved config, so
    // the path must be identical for byte comparison): run sequentially
    let mut off = plain.clone();
    off.fl.robust.enabled = false;
    off.fl.robust.clip_norm = 9.0;
    off.fl.robust.normalize = true;
    off.fl.robust.trim_frac = 0.25;
    off.validate().unwrap();

    assert_eq!(
        qafel::telemetry::config_fingerprint(&plain),
        qafel::telemetry::config_fingerprint(&off),
        "disabled robust knobs leaked into the config fingerprint"
    );

    let rp = SimEngine::new(&plain, &b, 33).run().unwrap();
    let text_plain = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    let ro = SimEngine::new(&off, &b, 33).run().unwrap();
    let text_off = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).unwrap();

    assert_eq!(rp.final_accuracy.to_bits(), ro.final_accuracy.to_bits());
    assert_eq!(rp.comm.uploads, ro.comm.uploads);
    assert_eq!(rp.comm.upload_bytes, ro.comm.upload_bytes);
    assert_eq!(rp.comm.broadcast_bytes, ro.comm.broadcast_bytes);
    assert_eq!(rp.scenario.tiers, ro.scenario.tiers);
    for t in &rp.scenario.tiers {
        assert_eq!(t.clipped_updates, 0);
        assert_eq!(t.trimmed_updates, 0);
    }
    assert_eq!(text_plain, text_off, "robust-off journal diverged from plain");
}

/// Hostile two-tier population under clip + trim.
fn hostile_robust_cfg() -> Config {
    let mut c = sim_cfg();
    let mut good = TierConfig::named("good");
    good.weight = 0.6;
    let mut noisy = TierConfig::named("noisy");
    noisy.weight = 0.25;
    noisy.grad_noise = Some("student_t:3:0.1".into());
    let mut flip = TierConfig::named("flip");
    flip.weight = 0.15;
    flip.adversary = Some("sign_flip".into());
    c.scenario.tiers = vec![good, noisy, flip];
    c.fl.robust.enabled = true;
    c.fl.robust.clip_norm = 1.0;
    c.fl.robust.trim_frac = 0.25;
    c
}

#[test]
fn robust_hostile_sim_is_bit_identical_across_shards() {
    let b = sim_backend();
    let mut s1 = hostile_robust_cfg();
    s1.fl.shards = 1;
    s1.validate().unwrap();
    let mut s4 = hostile_robust_cfg();
    s4.fl.shards = 4;
    s4.validate().unwrap();
    let r1 = SimEngine::new(&s1, &b, 61).run().unwrap();
    let r4 = SimEngine::new(&s4, &b, 61).run().unwrap();
    assert_eq!(r1.server_steps, r4.server_steps);
    assert_eq!(r1.comm.uploads, r4.comm.uploads);
    assert_eq!(r1.final_accuracy.to_bits(), r4.final_accuracy.to_bits());
    assert_eq!(r1.curve.len(), r4.curve.len());
    for (p1, p4) in r1.curve.iter().zip(r4.curve.iter()) {
        assert_eq!(p1.val_loss.to_bits(), p4.val_loss.to_bits());
        assert_eq!(p1.val_accuracy.to_bits(), p4.val_accuracy.to_bits());
    }
    // per-tier robust forensics are part of the invariant surface
    assert_eq!(r1.scenario.tiers, r4.scenario.tiers);
    let total_trimmed: u64 = r1.scenario.tiers.iter().map(|t| t.trimmed_updates).sum();
    assert!(total_trimmed > 0, "trim never excluded anything");
}

#[test]
fn trivial_tree_with_clipping_is_bit_identical_to_flat() {
    // one edge, buffer 1, identity partial codec, per-update clipping:
    // the edge clips raw updates with the same scale the flat server
    // would, forwards exact f32s, and the root accumulates them at
    // weight 1 without re-clipping — the curve and the per-tier
    // clipped-update counters must match bit for bit
    let b = sim_backend();
    let mut flat = sim_cfg();
    flat.fl.robust.enabled = true;
    flat.fl.robust.clip_norm = 0.2; // deep enough to fire regularly
    let mut tree = flat.clone();
    tree.scenario.aggregators.edges = 1;
    tree.scenario.aggregators.buffer_size = 1;
    tree.scenario.aggregators.partial_codec = "none".into();
    flat.validate().unwrap();
    tree.validate().unwrap();

    let rf = SimEngine::new(&flat, &b, 31).run().unwrap();
    let rt = SimEngine::new(&tree, &b, 31).run().unwrap();

    assert_eq!(rf.server_steps, rt.server_steps);
    assert_eq!(rf.final_accuracy.to_bits(), rt.final_accuracy.to_bits());
    assert_eq!(rf.comm.uploads, rt.comm.uploads);
    assert_eq!(rf.curve.len(), rt.curve.len());
    for (i, (f, t)) in rf.curve.iter().zip(rt.curve.iter()).enumerate() {
        assert_eq!(f.val_loss.to_bits(), t.val_loss.to_bits(), "curve[{i}].val_loss");
        assert_eq!(f.val_accuracy.to_bits(), t.val_accuracy.to_bits(), "curve[{i}].val_accuracy");
    }
    // clip attribution commutes with the tree: the flat server counted
    // at the root, the tree counted at the edge — same updates clipped
    let flat_clipped: Vec<u64> = rf.scenario.tiers.iter().map(|t| t.clipped_updates).collect();
    let tree_clipped: Vec<u64> = rt.scenario.tiers.iter().map(|t| t.clipped_updates).collect();
    assert_eq!(flat_clipped, tree_clipped);
    assert!(flat_clipped.iter().sum::<u64>() > 0, "clip never fired");
    assert_eq!(rt.scenario.edges.len(), 1);
    assert_eq!(rt.scenario.edges[0].updates, rf.comm.uploads);
}
