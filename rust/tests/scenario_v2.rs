//! Scenario engine v2 integration tests (DESIGN_SCENARIOS.md):
//!
//! * **per-tier quantizer presets** — a 2-tier run with distinct client
//!   codecs completes, per-tier byte accounting matches each tier's own
//!   codec exactly, and the heterogeneous path keeps the sharded
//!   pipeline's bit-identical-across-shards contract;
//! * **mid-round partial-work dropout** — dropped clients salvage their
//!   `m/P` prefix, counted separately from full dropouts, with wasted
//!   downlink bytes attributed only to the latter;
//! * **availability-weighted sampling** — diurnal windows shape who
//!   arrives: counter-phased populations lose no arrivals and still
//!   track the target concurrency.

use qafel::config::{Algorithm, Config, TierConfig};
use qafel::quant::parse_spec;
use qafel::runtime::QuadraticBackend;
use qafel::sim::SimEngine;

fn quad_cfg() -> Config {
    let mut c = Config::default();
    c.fl.algorithm = Algorithm::Qafel;
    c.fl.buffer_size = 4;
    c.fl.client_lr = 0.15;
    c.fl.server_lr = 1.0;
    c.fl.server_momentum = 0.0;
    c.fl.clip_norm = 0.0;
    c.quant.client = "qsgd:8".into();
    c.quant.server = "qsgd:8".into();
    c.sim.concurrency = 20;
    c.sim.eval_every = 10;
    c.stop.target_accuracy = 2.0; // fixed horizon
    c.stop.max_uploads = 6000;
    c.stop.max_server_steps = 150;
    c
}

fn backend(seed: u64) -> QuadraticBackend {
    QuadraticBackend::new(24, 10, 1.0, 0.3, 0.3, 0.02, 2, seed)
}

fn two_codec_cfg() -> Config {
    let mut c = quad_cfg();
    let mut fast = TierConfig::named("fast");
    fast.weight = 0.5;
    fast.duration_sigma = 0.5;
    let mut slow = TierConfig::named("slow");
    slow.weight = 0.5;
    slow.quant_client = Some("top:0.25".into());
    c.scenario.tiers = vec![fast, slow];
    c
}

#[test]
fn two_tier_run_with_distinct_codecs_accounts_bytes_per_tier() {
    let cfg = two_codec_cfg();
    cfg.validate().unwrap();
    let b = backend(11);
    let r = SimEngine::new(&cfg, &b, 7).run().unwrap();
    assert_eq!(r.server_steps, 150, "run did not complete its horizon");
    let sc = &r.scenario;
    assert_eq!(sc.tiers.len(), 2);
    // each tier is tagged with the codec it actually uploaded on
    assert_eq!(sc.tiers[0].codec, "qsgd:8");
    assert_eq!(sc.tiers[1].codec, "top:0.25");
    // per-tier byte accounting is exact: uploads x that codec's wire size
    let d = 24;
    let qsgd_bytes = parse_spec("qsgd:8").unwrap().expected_bytes(d) as u64;
    let top_bytes = parse_spec("top:0.25").unwrap().expected_bytes(d) as u64;
    assert_ne!(qsgd_bytes, top_bytes, "codecs must differ on the wire");
    assert!(sc.tiers[0].uploads > 0 && sc.tiers[1].uploads > 0);
    assert_eq!(sc.tiers[0].upload_bytes, sc.tiers[0].uploads * qsgd_bytes);
    assert_eq!(sc.tiers[1].upload_bytes, sc.tiers[1].uploads * top_bytes);
    // and sums to the server's global accounting
    let uploads: u64 = sc.tiers.iter().map(|t| t.uploads).sum();
    let bytes: u64 = sc.tiers.iter().map(|t| t.upload_bytes).sum();
    assert_eq!(uploads, r.comm.uploads);
    assert_eq!(bytes, r.comm.upload_bytes);
}

#[test]
fn heterogeneous_codecs_keep_the_shard_bit_identity_contract() {
    // the per-tier-codec ingest path runs on the same sharded decode
    // pipeline: S=1 and S=4 must produce byte-identical trajectories
    let cfg0 = two_codec_cfg();
    let b = backend(11);
    let mut curves: Vec<Vec<u64>> = Vec::new();
    for shards in [1usize, 4] {
        let mut cfg = cfg0.clone();
        cfg.fl.shards = shards;
        let r = SimEngine::new(&cfg, &b, 9).run().unwrap();
        assert!(r.comm.uploads > 0);
        curves.push(
            r.curve
                .iter()
                .flat_map(|p| {
                    [
                        p.time.to_bits(),
                        p.server_steps,
                        p.uploads,
                        p.upload_mb.to_bits(),
                        p.val_loss.to_bits(),
                        p.val_accuracy.to_bits(),
                    ]
                })
                .collect(),
        );
    }
    assert_eq!(curves[0], curves[1], "S=1 vs S=4 diverged under per-tier codecs");
}

#[test]
fn preset_equal_to_default_codec_dedups_to_the_single_codec_path() {
    // a preset naming the default spec must change nothing: the codec
    // registry dedups it to id 0, so the trajectory is byte-identical
    // to the same population without the preset
    let mut a = TierConfig::named("a");
    a.weight = 0.3;
    let mut bt = TierConfig::named("b");
    bt.weight = 0.7;
    let mut bt_preset = bt.clone();
    bt_preset.quant_client = Some("qsgd:8".into()); // == quant.client
    let mut with = quad_cfg();
    with.scenario.tiers = vec![a.clone(), bt_preset];
    let mut without = quad_cfg();
    without.scenario.tiers = vec![a, bt];
    let b = backend(3);
    let r1 = SimEngine::new(&with, &b, 5).run().unwrap();
    let r2 = SimEngine::new(&without, &b, 5).run().unwrap();
    assert_eq!(r1.comm.uploads, r2.comm.uploads);
    assert_eq!(r1.comm.upload_bytes, r2.comm.upload_bytes);
    assert_eq!(r1.server_steps, r2.server_steps);
    let bits = |r: &qafel::metrics::RunResult| -> Vec<u64> {
        r.curve.iter().map(|p| p.val_loss.to_bits()).collect()
    };
    assert_eq!(bits(&r1), bits(&r2), "deduped preset changed the trajectory");
}

#[test]
fn partial_work_salvages_dropped_rounds() {
    let mut cfg = quad_cfg();
    cfg.fl.local_steps = 2; // partial prefixes exist
    let mut fast = TierConfig::named("fast");
    fast.weight = 0.5;
    let mut slow = TierConfig::named("slow");
    slow.weight = 0.5;
    slow.dropout = 0.4;
    slow.partial_work = 0.5;
    slow.download_mbps = 8.0;
    cfg.scenario.tiers = vec![fast, slow];
    cfg.validate().unwrap();
    let b = backend(13);
    let r = SimEngine::new(&cfg, &b, 3).run().unwrap();
    let sc = &r.scenario;
    let slow_m = &sc.tiers[1];
    assert_eq!(slow_m.name, "slow");
    // both outcomes occurred: full drops and partial salvages
    assert!(slow_m.dropouts > 0, "expected full dropouts");
    assert!(slow_m.partial_uploads > 0, "expected partial submissions");
    // partial uploads are counted inside uploads, and the global
    // accounting still balances
    assert!(slow_m.partial_uploads <= slow_m.uploads);
    let uploads: u64 = sc.tiers.iter().map(|t| t.uploads).sum();
    assert_eq!(uploads, r.comm.uploads);
    assert_eq!(sc.staleness.n, r.comm.uploads);
    // wasted downlink = full dropouts only (partials contributed)
    let down_per_trip = parse_spec("qsgd:8").unwrap().expected_bytes(24) as u64;
    assert_eq!(slow_m.wasted_download_bytes, slow_m.dropouts * down_per_trip);
    assert_eq!(sc.tiers[0].wasted_download_bytes, 0);
    assert_eq!(sc.tiers[0].partial_uploads, 0);
    // arrivals ~= uploads + dropouts + still-in-flight
    assert!(slow_m.arrivals >= slow_m.uploads + slow_m.dropouts);
    // determinism across repeat runs
    let r2 = SimEngine::new(&cfg, &b, 3).run().unwrap();
    assert_eq!(r.scenario, r2.scenario);
}

#[test]
fn partial_work_needs_two_local_steps() {
    // with P = 1 there is no mid-round prefix: partial_work is inert
    // and every dropout stays a full dropout
    let mut cfg = quad_cfg();
    cfg.fl.local_steps = 1;
    let mut only = TierConfig::named("only");
    only.dropout = 0.4;
    only.partial_work = 1.0;
    cfg.scenario.tiers = vec![only];
    cfg.validate().unwrap();
    let b = backend(13);
    let r = SimEngine::new(&cfg, &b, 3).run().unwrap();
    let t = &r.scenario.tiers[0];
    assert!(t.dropouts > 0);
    assert_eq!(t.partial_uploads, 0);
}

#[test]
fn availability_sampling_loses_no_arrivals_in_counter_phase() {
    let mut cfg = quad_cfg();
    cfg.fl.algorithm = Algorithm::FedBuff;
    cfg.fl.client_lr = 0.05;
    cfg.sim.concurrency = 40;
    cfg.sim.eval_every = 500;
    cfg.stop.max_uploads = 12_000;
    cfg.stop.max_server_steps = 1_000_000;
    cfg.scenario.sampling = "availability".into();
    let mut day = TierConfig::named("day");
    day.weight = 0.5;
    day.day_period = 8.0;
    day.on_fraction = 0.5;
    let mut night = TierConfig::named("night");
    night.weight = 0.5;
    night.day_period = 8.0;
    night.on_fraction = 0.5;
    night.phase = 4.0;
    cfg.scenario.tiers = vec![day, night];
    cfg.validate().unwrap();
    let b = QuadraticBackend::new(16, 8, 1.0, 0.3, 0.2, 0.02, 1, 3);
    let r = SimEngine::new(&cfg, &b, 5).run().unwrap();
    let sc = &r.scenario;
    // someone is always on: no arrival is ever lost, and no per-tier
    // off-window skip is recorded (the drawn tier is on by construction)
    assert_eq!(sc.arrivals_all_off, 0);
    assert!(sc.tiers.iter().all(|t| t.unavailable == 0));
    assert!(sc.tiers.iter().all(|t| t.arrivals > 0));
    // and the calibration still tracks the target concurrency
    let measured = sc.mean_concurrency;
    assert!(
        (measured - 40.0).abs() / 40.0 < 0.15,
        "availability sampling: measured mean concurrency {measured}, target 40"
    );
}

#[test]
fn availability_sampling_counts_all_off_gaps() {
    // both tiers share the same off window: arrivals landing there are
    // lost and counted on the run-level all-off counter
    let mut cfg = quad_cfg();
    cfg.fl.algorithm = Algorithm::FedBuff;
    cfg.fl.client_lr = 0.05;
    cfg.stop.max_server_steps = 300;
    cfg.scenario.sampling = "availability".into();
    let mut a = TierConfig::named("a");
    a.day_period = 8.0;
    a.on_fraction = 0.5;
    let mut bt = TierConfig::named("b");
    bt.day_period = 8.0;
    bt.on_fraction = 0.5;
    cfg.scenario.tiers = vec![a, bt];
    cfg.validate().unwrap();
    let b = QuadraticBackend::new(16, 8, 1.0, 0.3, 0.2, 0.02, 1, 3);
    let r = SimEngine::new(&cfg, &b, 5).run().unwrap();
    let sc = &r.scenario;
    assert!(sc.arrivals_all_off > 0, "expected all-off arrival gaps");
    assert!(sc.tiers.iter().all(|t| t.unavailable == 0));
}
