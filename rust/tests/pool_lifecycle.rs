//! Lifecycle tests for the persistent shard worker pool ([`ShardPool`])
//! as the server uses it: determinism across pool reuse over many
//! steps, panic propagation (an error, not a hang), drop/shutdown
//! joining every worker, and the steady-state regression guard — **zero
//! thread spawns per server step**.
//!
//! The spawn/live counters are process-global, so every test that reads
//! them serializes on a file-local mutex (test binaries run one at a
//! time, tests within this binary in parallel).

use qafel::config::{Algorithm, Config};
use qafel::coordinator::{Server, ServerStep};
use qafel::quant::{parse_spec, Quantizer};
use qafel::util::pool::{self, ShardPool, Task};
use qafel::util::prng::Prng;
use std::panic::AssertUnwindSafe;
use std::sync::{Mutex, MutexGuard};

static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    // a poisoned lock only means another test failed; the counters are
    // still coherent
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn server_cfg(qc: &str, qs: &str, shards: usize) -> Config {
    let mut c = Config::default();
    c.fl.algorithm = Algorithm::Qafel;
    c.quant.client = qc.into();
    c.quant.server = qs.into();
    c.fl.buffer_size = 3;
    c.fl.server_lr = 1.0;
    c.fl.server_momentum = 0.3;
    c.fl.shards = shards;
    c
}

/// Drive `server` for `rounds` uploads, returning every broadcast
/// payload (deterministic upload stream from `seed`).
fn drive(server: &mut Server, qc: &str, seed: u64, rounds: u64) -> Vec<Vec<u8>> {
    let codec = parse_spec(qc).unwrap();
    let mut rng = Prng::new(seed);
    let d = server.d();
    let mut broadcasts = Vec::new();
    for round in 0..rounds {
        let delta: Vec<f32> =
            (0..d).map(|i| ((i as f64 * 0.13 + round as f64).cos() * 0.2) as f32).collect();
        let msg = codec.quantize(&delta, &mut rng);
        if let ServerStep::Stepped(b) = server.ingest(&msg, round % 4).unwrap() {
            broadcasts.extend(b.into_iter().map(|bc| bc.msg.payload));
        }
    }
    broadcasts
}

#[test]
fn pool_reuse_is_deterministic_over_many_steps() {
    let _g = serial();
    // one pool instance reused across 60 steps must equal a fresh
    // same-seed server (and the sequential reference) bit-for-bit
    let d = 3 * 128 + 45;
    for (qc, qs) in [("qsgd:4", "qsgd:4"), ("qsgd:8", "top:0.1"), ("none", "rand:0.25")] {
        let mut a = Server::build(&server_cfg(qc, qs, 4), vec![0.0; d], 9).unwrap();
        let mut b = Server::build(&server_cfg(qc, qs, 4), vec![0.0; d], 9).unwrap();
        let mut seq = Server::build(&server_cfg(qc, qs, 1), vec![0.0; d], 9).unwrap();
        let ba = drive(&mut a, qc, 77, 180);
        let bb = drive(&mut b, qc, 77, 180);
        let bs = drive(&mut seq, qc, 77, 180);
        assert_eq!(ba.len(), 60, "{qc}/{qs}: expected 60 steps");
        assert_eq!(ba, bb, "{qc}/{qs}: pool reuse diverged across servers");
        assert_eq!(ba, bs, "{qc}/{qs}: pooled vs sequential diverged");
        assert_eq!(a.model(), seq.model(), "{qc}/{qs}: model");
    }
}

#[test]
fn worker_panic_propagates_as_unwind_not_hang() {
    let _g = serial();
    let pool = ShardPool::new(4);
    let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
        let tasks: Vec<Task<'_>> = (0..4)
            .map(|i| {
                Box::new(move || {
                    if i == 1 {
                        panic!("worker task failed");
                    }
                }) as Task<'_>
            })
            .collect();
        pool.run(tasks);
    }));
    let payload = result.expect_err("panic must propagate to the caller");
    let msg = payload.downcast_ref::<&'static str>().copied().unwrap_or("");
    assert_eq!(msg, "worker task failed");
    // no worker died: the pool still has its full complement and works
    assert_eq!(pool.workers(), 3);
    let mut out = vec![0u32; 8];
    let tasks: Vec<Task<'_>> =
        out.chunks_mut(2).map(|c| Box::new(move || c.fill(3)) as Task<'_>).collect();
    pool.run(tasks);
    assert!(out.iter().all(|&v| v == 3));
}

#[test]
fn drop_and_server_drop_join_all_workers() {
    let _g = serial();
    let live0 = pool::live_workers_total();
    {
        let pool = ShardPool::new(6);
        assert_eq!(pool.workers(), 5);
        assert_eq!(pool::live_workers_total(), live0 + 5);
    }
    assert_eq!(pool::live_workers_total(), live0, "pool drop leaked workers");
    // a server owns its pool: dropping the server joins the workers too
    {
        let mut s = Server::build(&server_cfg("qsgd:4", "qsgd:4", 4), vec![0.0; 512], 1).unwrap();
        assert_eq!(pool::live_workers_total(), live0 + 3);
        let _ = drive(&mut s, "qsgd:4", 5, 9);
    }
    assert_eq!(pool::live_workers_total(), live0, "server drop leaked workers");
}

#[test]
fn zero_steady_state_thread_spawns_per_server_step() {
    let _g = serial();
    let d = 4 * 128 + 19;
    // codecs covering all three sharded encode shapes: stitch (qsgd),
    // merge (top_k), per-bucket streams (rand_k)
    for (qc, qs) in [("qsgd:4", "qsgd:4"), ("qsgd:4", "top:0.1"), ("rand:0.25", "rand:0.25")] {
        let mut server = Server::build(&server_cfg(qc, qs, 4), vec![0.0; d], 3).unwrap();
        // warm up one full step, then pin the spawn counters
        let warm = drive(&mut server, qc, 1, 3);
        assert_eq!(warm.len(), 1, "{qc}/{qs}: warmup did not step");
        let spawned = pool::threads_spawned_total();
        let live = pool::live_workers_total();
        let t0 = server.t();
        let broadcasts = drive(&mut server, qc, 2, 150);
        assert_eq!(server.t() - t0, 50, "{qc}/{qs}: expected 50 steady-state steps");
        assert_eq!(broadcasts.len(), 50);
        assert_eq!(
            pool::threads_spawned_total(),
            spawned,
            "{qc}/{qs}: server steps spawned threads in steady state"
        );
        assert_eq!(pool::live_workers_total(), live, "{qc}/{qs}: live workers changed");
    }
}
