//! Integration tests over the real AOT artifacts (L1+L2 через PJRT).
//! Each test skips gracefully when `make artifacts` has not run.

use qafel::config::{Config, DataConfig};
use qafel::data::Dataset;
use qafel::quant::qsgd::Qsgd;
use qafel::quant::Quantizer as _;
use qafel::runtime::{artifacts_available, Backend as _, Engine, PjrtBackend};
use qafel::sim::SimEngine;
use qafel::util::prng::Prng;
use qafel::util::vecf;
use std::rc::Rc;

fn engine() -> Option<Rc<Engine>> {
    if !artifacts_available("artifacts") {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Rc::new(Engine::load("artifacts").expect("engine load")))
}

#[test]
fn manifest_matches_model_contract() {
    let Some(engine) = engine() else { return };
    let m = engine.manifest();
    assert_eq!(m.model.d, 29_474, "paper-scale model (117.9 kB updates)");
    assert_eq!(m.model.n_layers, 4);
    assert_eq!((m.model.height, m.model.width, m.model.in_channels), (32, 32, 3));
    for name in ["init_params", "train_step", "client_update",
                 "client_update_quantized", "eval_step", "qsgd_quantize"] {
        assert!(m.artifacts.contains_key(name), "missing artifact {name}");
    }
}

#[test]
fn init_params_deterministic_and_seed_sensitive() {
    let Some(engine) = engine() else { return };
    let a = engine.init_params(1).unwrap();
    let b = engine.init_params(1).unwrap();
    let c = engine.init_params(2).unwrap();
    assert_eq!(a, b);
    assert_ne!(a, c);
    let norm = vecf::norm2(&a);
    assert!(norm > 1.0 && norm < 1000.0, "init norm {norm}");
}

#[test]
fn client_update_descends_and_is_deterministic() {
    let Some(engine) = engine() else { return };
    let m = engine.manifest();
    let (p, b) = (m.local_steps, m.batch);
    let img = engine.img_elems();
    let params = engine.init_params(0).unwrap();
    let ds = Dataset::new(&DataConfig::default());
    let mut rng = Prng::new(3);
    let mut xs = vec![0.0f32; p * b * img];
    let mut ys = vec![0i32; p * b];
    let mut mask = vec![0.0f32; p * b];
    ds.fill_round(1, &mut rng, p, b, &mut xs, &mut ys, &mut mask);

    let r1 = engine.client_update(&params, &xs, &ys, &mask, 1e-2, 7).unwrap();
    let r2 = engine.client_update(&params, &xs, &ys, &mask, 1e-2, 7).unwrap();
    assert_eq!(r1.delta, r2.delta, "PJRT call must be deterministic");
    assert!(r1.loss.is_finite() && vecf::norm2(&r1.delta) > 0.0);

    // two chained updates reduce the loss on the same batch
    let mut pp = params.clone();
    vecf::add_assign(&mut pp, &r1.delta);
    let r3 = engine.client_update(&pp, &xs, &ys, &mask, 1e-2, 7).unwrap();
    assert!(
        r3.loss < r1.loss,
        "loss should decrease on the same batch: {} -> {}",
        r1.loss,
        r3.loss
    );
}

#[test]
fn pallas_qsgd_artifact_matches_rust_codec_exactly() {
    let Some(engine) = engine() else { return };
    let d = engine.d();
    let mut rng = Prng::new(11);
    let x: Vec<f32> = (0..d).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let mut u = vec![0.0f32; d];
    rng.fill_uniform_f32(&mut u);

    for bits in [2u32, 4, 8] {
        let q = Qsgd::new(bits).unwrap();
        let s = q.levels() as f32;
        let g = q.bucket();
        let (levels, norms) = engine.qsgd_quantize(&x, &u, s).unwrap();
        assert_eq!(norms.len(), d.div_ceil(g));
        // replicate the bucketed stochastic rounding with the same uniforms
        let mut mism = 0usize;
        for i in 0..d {
            let lo = (i / g) * g;
            let hi = (lo + g).min(d);
            let norm = vecf::norm2(&x[lo..hi]) as f32;
            let a = x[i].abs() * s / norm;
            let lv = (a + u[i]).floor() as i32;
            let expect = if x[i] < 0.0 { -lv } else { lv };
            if levels[i] != expect {
                mism += 1;
            }
        }
        // float-order differences may flip a coordinate sitting exactly
        // on a rounding boundary; allow a vanishing fraction
        assert!(mism <= 2, "{bits}-bit: {mism} level mismatches");
        // levels respect the codec's range
        assert!(levels.iter().all(|l| l.unsigned_abs() <= q.levels()));
        // wire round-trip of the kernel's own output
        let msg = q.encode_levels(&levels, &norms);
        let (n2, lv2) = q.decode_levels(&msg).unwrap();
        assert_eq!((n2, lv2), (norms.clone(), levels));
    }
}

#[test]
fn client_update_quantized_consistent_with_separate_calls() {
    let Some(engine) = engine() else { return };
    let m = engine.manifest();
    let (p, b) = (m.local_steps, m.batch);
    let img = engine.img_elems();
    let d = engine.d();
    let params = engine.init_params(0).unwrap();
    let ds = Dataset::new(&DataConfig::default());
    let mut rng = Prng::new(5);
    let mut xs = vec![0.0f32; p * b * img];
    let mut ys = vec![0i32; p * b];
    let mut mask = vec![0.0f32; p * b];
    ds.fill_round(2, &mut rng, p, b, &mut xs, &mut ys, &mut mask);
    let mut u = vec![0.0f32; d];
    rng.fill_uniform_f32(&mut u);

    let fused = engine
        .client_update_quantized(&params, &xs, &ys, &mask, 1e-2, 3, &u, 7.0)
        .unwrap();
    let plain = engine.client_update(&params, &xs, &ys, &mask, 1e-2, 3).unwrap();
    let (levels, norms) = engine.qsgd_quantize(&plain.delta, &u, 7.0).unwrap();
    assert_eq!(fused.levels, levels, "fused Pallas path != separate path");
    assert_eq!(fused.norms.len(), norms.len());
    for (a, b) in fused.norms.iter().zip(&norms) {
        assert!((a - b).abs() <= b.abs() * 1e-5 + 1e-12);
    }
    assert!((fused.loss - plain.loss).abs() < 1e-5);
}

#[test]
fn eval_step_counts_and_bounds() {
    let Some(engine) = engine() else { return };
    let m = engine.manifest();
    let eb = m.eval_batch;
    let img = engine.img_elems();
    let params = engine.init_params(0).unwrap();
    let ds = Dataset::new(&DataConfig::default());
    let mut x = vec![0.0f32; eb * img];
    let mut y = vec![0i32; eb];
    let mut mask = vec![0.0f32; eb];
    for slot in 0..eb / 2 {
        y[slot] = ds.sample_into(slot % ds.num_users(), 0,
                                 &mut x[slot * img..(slot + 1) * img]) as i32;
        mask[slot] = 1.0;
    }
    let (loss_sum, correct, count) = engine.eval_step(&params, &x, &y, &mask).unwrap();
    assert_eq!(count as usize, eb / 2);
    assert!(correct >= 0.0 && correct <= count);
    assert!(loss_sum > 0.0);
}

#[test]
fn short_end_to_end_training_run_improves_accuracy() {
    let Some(engine) = engine() else { return };
    let mut cfg = Config::default();
    cfg.fl.client_lr = 1e-2;
    cfg.fl.server_lr = 1.0;
    cfg.sim.eval_every = 5;
    cfg.data.eval_samples = 512;
    cfg.stop.max_uploads = 400;
    cfg.stop.target_accuracy = 0.85;
    let backend = PjrtBackend::new(engine, &cfg.data, 1).unwrap();
    let r = SimEngine::new(&cfg, &backend, 1).run().unwrap();
    let first = r.curve.first().unwrap().val_accuracy;
    assert!(
        r.final_accuracy > first + 0.15 || r.reached.is_some(),
        "no learning: {first:.3} -> {:.3}",
        r.final_accuracy
    );
}
