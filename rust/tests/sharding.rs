//! Property tests for the sharded aggregation pipeline: for every shard
//! count, every supported codec and adversarial vectors, the sharded
//! codec paths and the whole sharded server step must be **bit-identical**
//! to the sequential implementation (broadcast payloads, model, hidden
//! state, and PRNG stream consumption).
//!
//! Since the biased-codec range formats landed, *every* built-in codec
//! has a range view — top_k (per-shard candidate merge) and rand_k
//! (per-bucket index streams) are property-tested here across
//! dimensions (sub-bucket, bucket-ragged, 2^20), k/d ratios, seeds,
//! accumulate weights and shard counts, including scaled vs unscaled
//! rand_k.
//!
//! `QAFEL_TEST_SHARDS=<n>` (the CI shard matrix) additionally runs the
//! whole suite with that default `fl.shards`, and is appended to the
//! shard sweep below.

use qafel::config::{Algorithm, Config};
use qafel::coordinator::{Server, ServerStep};
use qafel::quant::{parse_spec, sharded, Quantizer};
use qafel::testing::prop::{forall_cfg, gens, PropConfig};
use qafel::util::pool::ShardPool;
use qafel::util::prng::Prng;
use std::sync::Arc;

/// Shard counts to sweep: a default spread plus the CI matrix value
/// (the same override `Config::default()` resolves).
fn shard_counts() -> Vec<usize> {
    let mut counts = vec![1usize, 2, 3, 8];
    if let Some(s) = qafel::config::env_shards_override() {
        if !counts.contains(&s) {
            counts.push(s);
        }
    }
    counts
}

fn pools() -> Vec<Arc<ShardPool>> {
    shard_counts().into_iter().map(ShardPool::new).collect()
}

/// Every codec with a range view — all of them, since the biased-codec
/// range formats landed.
fn range_specs() -> Vec<&'static str> {
    vec![
        "none",
        "qsgd:2",
        "qsgd:4",
        "qsgd:8",
        "qsgd:16",
        "qsgd:4:32",
        "top:0.1",
        "top:0.5",
        "rand:0.1",
        "rand:0.5",
        "rand_scaled:0.25",
    ]
}

/// Assert the three sharded codec paths match the sequential trait
/// calls bitwise (payload, accumulate floats, dequantize floats, and
/// the PRNG stream consumed).
fn assert_codec_paths_match(
    q: &dyn Quantizer,
    xs: &[f32],
    pool: &ShardPool,
    weight: f32,
) -> Result<(), String> {
    let shards = pool.shards();
    // quantize: same bytes AND same rng consumption
    let mut rng_seq = Prng::new(7);
    let mut rng_shard = Prng::new(7);
    let a = q.quantize(xs, &mut rng_seq);
    let b = sharded::quantize(q, xs, &mut rng_shard, pool);
    if a.payload != b.payload {
        return Err(format!("S={shards}: payload mismatch"));
    }
    if rng_seq.next_u64() != rng_shard.next_u64() {
        return Err(format!("S={shards}: rng stream diverged"));
    }
    // accumulate
    let mut acc_a = vec![0.25f32; xs.len()];
    let mut acc_b = vec![0.25f32; xs.len()];
    q.accumulate(&a, weight, &mut acc_a).map_err(|e| e.to_string())?;
    sharded::accumulate(q, &a, weight, &mut acc_b, pool).map_err(|e| e.to_string())?;
    if acc_a != acc_b {
        return Err(format!("S={shards}: accumulate mismatch"));
    }
    // dequantize
    let mut out_a = vec![0.0f32; xs.len()];
    let mut out_b = vec![0.0f32; xs.len()];
    q.dequantize_into(&a, &mut out_a).map_err(|e| e.to_string())?;
    sharded::dequantize_into(q, &a, &mut out_b, pool).map_err(|e| e.to_string())?;
    if out_a != out_b {
        return Err(format!("S={shards}: dequantize mismatch"));
    }
    Ok(())
}

#[test]
fn sharded_codec_paths_match_sequential_bitwise() {
    let pools = pools();
    for spec in range_specs() {
        let q = parse_spec(spec).unwrap();
        forall_cfg(
            &format!("sharded == sequential for {spec}"),
            PropConfig { cases: 20, ..Default::default() },
            gens::vec_f32_gnarly(1, 2000),
            |xs| {
                for pool in &pools {
                    assert_codec_paths_match(q.as_ref(), xs, pool, 0.5)
                        .map_err(|e| format!("{spec} {e}"))?;
                }
                Ok(())
            },
        );
    }
}

#[test]
fn biased_codecs_bit_identical_across_dims_ratios_seeds_weights() {
    // satellite property suite for the biased sparsifiers: small dims,
    // bucket-ragged dims, k/d ratios from 1 coordinate to lossless,
    // several seeds and accumulate weights, scaled vs unscaled rand_k
    let pools = pools();
    let specs = [
        "top:0.01",
        "top:0.1",
        "top:0.5",
        "top:1.0",
        "rand:0.01",
        "rand:0.1",
        "rand:0.5",
        "rand:1.0",
        "rand_scaled:0.1",
        "rand_scaled:0.5",
    ];
    let dims = [1usize, 7, 127, 128, 129, 384, 3 * 128 + 57, 1000];
    for spec in specs {
        let q = parse_spec(spec).unwrap();
        for &d in &dims {
            for seed in [1u64, 2, 3] {
                let mut rng = Prng::new(seed * 1000 + d as u64);
                let x: Vec<f32> =
                    (0..d).map(|_| (rng.f32() - 0.5) * if d % 2 == 0 { 2e3 } else { 0.1 }).collect();
                for (pool, &w) in pools.iter().zip([1.0f32, -0.5, 0.125].iter().cycle()) {
                    if let Err(e) = assert_codec_paths_match(q.as_ref(), &x, pool, w) {
                        panic!("{spec} d={d} seed={seed}: {e}");
                    }
                }
            }
        }
    }
}

#[test]
fn biased_codecs_bit_identical_at_2_20() {
    // the million-coordinate regime the pool exists for — one seed per
    // spec keeps the test fast while covering the big-d code paths
    let d = 1 << 20;
    let mut rng = Prng::new(42);
    let x: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
    let pools: Vec<Arc<ShardPool>> = [1usize, 4, 8].into_iter().map(ShardPool::new).collect();
    for spec in ["top:0.1", "rand:0.1", "rand_scaled:0.01"] {
        let q = parse_spec(spec).unwrap();
        for pool in &pools {
            if let Err(e) = assert_codec_paths_match(q.as_ref(), &x, pool, 0.25) {
                panic!("{spec} d=2^20: {e}");
            }
        }
    }
}

fn qafel_cfg(client: &str, server: &str, shards: usize) -> Config {
    let mut c = Config::default();
    c.fl.algorithm = Algorithm::Qafel;
    c.quant.client = client.into();
    c.quant.server = server.into();
    c.fl.buffer_size = 3;
    c.fl.server_lr = 1.0;
    c.fl.server_momentum = 0.3;
    c.fl.shards = shards;
    c
}

/// Drive two servers with identical upload streams and assert bit-equal
/// evolution (broadcast bytes, model, hidden state).
fn assert_servers_identical(client: &str, server: &str, d: usize, seed: u64, shards: usize) {
    let mut s1 = Server::build(&qafel_cfg(client, server, 1), vec![0.0; d], seed).unwrap();
    let mut s2 = Server::build(&qafel_cfg(client, server, shards), vec![0.0; d], seed).unwrap();
    let qc = parse_spec(client).unwrap();
    let mut rng1 = Prng::new(seed ^ 0xFEED);
    let mut rng2 = Prng::new(seed ^ 0xFEED);
    for round in 0..9u64 {
        let delta: Vec<f32> =
            (0..d).map(|i| ((i as f64 * 0.37 + round as f64).sin() * 0.1) as f32).collect();
        let m1 = qc.quantize(&delta, &mut rng1);
        let m2 = qc.quantize(&delta, &mut rng2);
        let r1 = s1.ingest(&m1, round % 5).unwrap();
        let r2 = s2.ingest(&m2, round % 5).unwrap();
        match (r1, r2) {
            (ServerStep::Stepped(b1), ServerStep::Stepped(b2)) => {
                assert_eq!(
                    b1[0].msg.payload, b2[0].msg.payload,
                    "{client}/{server} d={d} S={shards}: broadcast bytes"
                );
                assert_eq!(b1[0].t, b2[0].t);
            }
            (ServerStep::Buffered, ServerStep::Buffered) => {}
            _ => panic!("{client}/{server} d={d} S={shards}: step/buffer divergence"),
        }
    }
    assert_eq!(s1.model(), s2.model(), "{client}/{server} d={d} S={shards}: model");
    assert_eq!(
        s1.client_snapshot().as_slice(),
        s2.client_snapshot().as_slice(),
        "{client}/{server} d={d} S={shards}: hidden state"
    );
}

#[test]
fn sharded_server_bit_identical_across_seeds_and_quantizers() {
    // dims straddle bucket boundaries: below one bucket, exact multiples,
    // ragged tails, and a dimension smaller than the shard count
    for &d in &[5usize, 128, 384, 500, 1000] {
        for seed in [1u64, 2, 3] {
            for (qc, qs) in [
                ("qsgd:4", "qsgd:4"),
                ("qsgd:8", "qsgd:2"),
                ("qsgd:16", "qsgd:16"),
                ("none", "none"),
                ("none", "qsgd:4"),
                // biased server codecs: merge (top_k) and per-bucket
                // index streams (rand_k) through the whole server step
                ("qsgd:4", "top:0.1"),
                ("qsgd:4", "rand:0.25"),
            ] {
                for shards in [2usize, 4, 8] {
                    assert_servers_identical(qc, qs, d, seed, shards);
                }
            }
        }
    }
}

#[test]
fn sharded_server_bit_identical_with_biased_client_codecs() {
    // biased codecs on the *upload* path exercise the sparse sharded
    // accumulate inside Server::ingest
    for (qc, qs) in [
        ("top:0.2", "qsgd:4"),
        ("rand:0.2", "qsgd:4"),
        ("rand_scaled:0.5", "top:0.5"),
        ("top:1.0", "rand_scaled:0.25"),
    ] {
        for &d in &[37usize, 500, 777] {
            assert_servers_identical(qc, qs, d, 11, 4);
        }
    }
}

#[test]
fn sharded_paths_reject_dimension_mismatch() {
    // the per-shard range checks only see prefixes; the sharded entry
    // points must enforce the whole-vector dimension contract just like
    // the sequential decoders
    for spec in ["qsgd:4", "top:0.1", "rand:0.1"] {
        let q = parse_spec(spec).unwrap();
        let mut rng = Prng::new(1);
        let big: Vec<f32> = (0..512).map(|i| i as f32 * 0.01).collect();
        let msg = q.quantize(&big, &mut rng);
        for shards in [1usize, 4] {
            let pool = ShardPool::new(shards);
            let mut small = vec![0.0f32; 256];
            assert!(
                sharded::accumulate(q.as_ref(), &msg, 1.0, &mut small, &pool).is_err(),
                "{spec} S={shards}"
            );
            assert!(
                sharded::dequantize_into(q.as_ref(), &msg, &mut small, &pool).is_err(),
                "{spec} S={shards}"
            );
        }
    }
}

#[test]
fn directquant_sharded_matches_sequential() {
    let mut base = Config::default();
    base.fl.algorithm = Algorithm::DirectQuant;
    base.quant.client = "none".into();
    base.quant.server = "qsgd:4".into();
    base.fl.buffer_size = 2;
    let d = 2 * 128 + 9;
    let mut c1 = base.clone();
    c1.fl.shards = 1;
    let mut c4 = base.clone();
    c4.fl.shards = 4;
    let mut s1 = Server::build(&c1, vec![0.1; d], 5).unwrap();
    let mut s4 = Server::build(&c4, vec![0.1; d], 5).unwrap();
    let qc = parse_spec("none").unwrap();
    let mut rng = Prng::new(8);
    for round in 0..6u64 {
        let delta: Vec<f32> = (0..d).map(|i| (i as f32 * 0.01) - round as f32 * 0.001).collect();
        let msg = qc.quantize(&delta, &mut rng);
        let r1 = s1.ingest(&msg, 0).unwrap();
        let r4 = s4.ingest(&msg, 0).unwrap();
        if let (ServerStep::Stepped(b1), ServerStep::Stepped(b4)) = (r1, r4) {
            assert!(b1[0].absolute && b4[0].absolute);
            assert_eq!(b1[0].msg.payload, b4[0].msg.payload, "round {round}");
        }
    }
    assert_eq!(s1.model(), s4.model());
    assert_eq!(s1.client_snapshot().as_slice(), s4.client_snapshot().as_slice());
}
