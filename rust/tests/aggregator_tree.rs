//! Aggregation-tree integration tests (ISSUE 6 acceptance criteria):
//!
//! * the **trivial tree** — one edge, forward-every-update buffer,
//!   identity partial codec — replays **bit-identical** to the flat
//!   server, both in the virtual-time simulator (full training curves
//!   match field for field) and over real TCP (every broadcast frame a
//!   hand-driven worker reads through an [`EdgeLeader`] relay is
//!   byte-identical to a reference [`Server`] fed the same uploads);
//! * a 2-level simulated tree is deterministic across seeds and
//!   bit-identical across `fl.shards ∈ {1, 4}` (the repo-wide shard
//!   invariance extends to the edge layer);
//! * a real 2-level loopback deployment — root + two edge leaders +
//!   four workers, seven threads in one process — completes, converges,
//!   and the per-edge byte accounting is exact at every hop.
//!
//! `UpdatePartial` frame round-trip and truncation-rejection property
//! tests live with the other wire-format tests in `net::message`.

use qafel::config::{Algorithm, Config};
use qafel::coordinator::{Server, ServerStep};
use qafel::net::{EdgeLeader, Leader, Message, Worker, PROTOCOL_VERSION};
use qafel::quant::parse_spec;
use qafel::runtime::{Backend as _, QuadraticBackend};
use qafel::sim::SimEngine;
use qafel::util::prng::Prng;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};

// ---------------------------------------------------------------- sim --

/// A fast deterministic simulator config on the analytic quadratic
/// backend (grad-norm accuracy proxy, fixed horizon).
fn sim_cfg() -> Config {
    let mut c = Config::default();
    c.fl.algorithm = Algorithm::Qafel;
    c.fl.buffer_size = 4;
    c.fl.client_lr = 0.15;
    c.fl.server_lr = 1.0;
    c.fl.server_momentum = 0.0;
    c.fl.clip_norm = 0.0;
    c.quant.client = "qsgd:8".into();
    c.quant.server = "qsgd:8".into();
    c.sim.concurrency = 20;
    c.sim.eval_every = 10;
    c.stop.target_accuracy = 2.0; // unreachable: run the full horizon
    c.stop.max_uploads = 100_000;
    c.stop.max_server_steps = 120;
    c
}

fn sim_backend() -> QuadraticBackend {
    QuadraticBackend::new(24, 10, 1.0, 0.3, 0.3, 0.02, 2, 11)
}

#[test]
fn trivial_tree_sim_curve_is_bit_identical_to_flat() {
    // One edge, buffer size 1 (forward every update), identity partial
    // codec: the edge applies the same staleness weight the flat server
    // would and forwards the exact f32 values, so the entire training
    // curve must match bit for bit. Only upload bytes differ — partials
    // ride the identity codec, not the client codec.
    let b = sim_backend();
    let flat = sim_cfg();
    let mut tree = flat.clone();
    tree.scenario.aggregators.edges = 1;
    tree.scenario.aggregators.buffer_size = 1;
    tree.scenario.aggregators.partial_codec = "none".into();
    tree.validate().unwrap();

    let rf = SimEngine::new(&flat, &b, 31).run().unwrap();
    let rt = SimEngine::new(&tree, &b, 31).run().unwrap();

    assert_eq!(rf.server_steps, rt.server_steps);
    assert_eq!(rf.final_accuracy.to_bits(), rt.final_accuracy.to_bits());
    assert_eq!(rf.comm.uploads, rt.comm.uploads, "B=1 partials are 1:1 with uploads");
    assert_eq!(rf.comm.broadcasts, rt.comm.broadcasts);
    assert_eq!(rf.comm.broadcast_bytes, rt.comm.broadcast_bytes);
    // ...but the wire format upstream differs: identity partials are
    // wider than qsgd:8 client uploads
    assert!(rt.comm.upload_bytes > rf.comm.upload_bytes);

    assert_eq!(rf.curve.len(), rt.curve.len());
    for (i, (f, t)) in rf.curve.iter().zip(rt.curve.iter()).enumerate() {
        assert_eq!(f.time.to_bits(), t.time.to_bits(), "curve[{i}].time");
        assert_eq!(f.server_steps, t.server_steps, "curve[{i}].server_steps");
        assert_eq!(f.uploads, t.uploads, "curve[{i}].uploads");
        assert_eq!(f.broadcast_mb.to_bits(), t.broadcast_mb.to_bits(), "curve[{i}].broadcast_mb");
        assert_eq!(f.val_loss.to_bits(), t.val_loss.to_bits(), "curve[{i}].val_loss");
        assert_eq!(f.val_accuracy.to_bits(), t.val_accuracy.to_bits(), "curve[{i}].val_accuracy");
        assert_eq!(
            f.grad_norm_sq.map(f64::to_bits),
            t.grad_norm_sq.map(f64::to_bits),
            "curve[{i}].grad_norm_sq"
        );
    }

    // the tree run reported its single edge, and the edge saw everything
    assert_eq!(rt.scenario.edges.len(), 1);
    let e = &rt.scenario.edges[0];
    assert_eq!(e.updates, rf.comm.uploads);
    assert_eq!(e.partials, e.updates, "B=1 forwards every update");
    assert_eq!(e.staleness.n, e.updates);
}

#[test]
fn two_level_sim_tree_is_shard_invariant_and_seed_deterministic() {
    let b = sim_backend();
    let mut c = sim_cfg();
    c.stop.max_server_steps = 60;
    c.scenario.aggregators.edges = 4;
    c.scenario.aggregators.buffer_size = 2;
    c.scenario.aggregators.partial_codec = "qsgd:4".into();
    c.validate().unwrap();

    // shard invariance: S=1 and S=4 produce bit-identical trajectories
    // (the edge layer uses the same pooled block reductions as the root)
    let mut s1 = c.clone();
    s1.fl.shards = 1;
    let mut s4 = c.clone();
    s4.fl.shards = 4;
    let r1 = SimEngine::new(&s1, &b, 41).run().unwrap();
    let r4 = SimEngine::new(&s4, &b, 41).run().unwrap();
    assert_eq!(r1.server_steps, r4.server_steps);
    assert_eq!(r1.comm.uploads, r4.comm.uploads);
    assert_eq!(r1.final_accuracy.to_bits(), r4.final_accuracy.to_bits());
    assert_eq!(r1.curve.len(), r4.curve.len());
    for (p1, p4) in r1.curve.iter().zip(r4.curve.iter()) {
        assert_eq!(p1.val_loss.to_bits(), p4.val_loss.to_bits());
    }
    assert_eq!(r1.scenario.edges, r4.scenario.edges);

    // same seed replays exactly; a different seed moves the trajectory
    let r1b = SimEngine::new(&s1, &b, 41).run().unwrap();
    assert_eq!(r1.final_accuracy.to_bits(), r1b.final_accuracy.to_bits());
    assert_eq!(r1.scenario.edges, r1b.scenario.edges);
    let r_other = SimEngine::new(&s1, &b, 42).run().unwrap();
    assert!(
        r_other.final_accuracy != r1.final_accuracy
            || r_other.comm.uploads != r1.comm.uploads,
        "seed change left the tree run unchanged"
    );
}

// ---------------------------------------------------------------- tcp --

/// Read one raw frame (length prefix + body), returning the body bytes.
fn read_frame(s: &mut TcpStream) -> Vec<u8> {
    let mut len = [0u8; 4];
    s.read_exact(&mut len).unwrap();
    let n = u32::from_le_bytes(len) as usize;
    let mut body = vec![0u8; n];
    s.read_exact(&mut body).unwrap();
    body
}

/// Write one raw frame around the given body bytes.
fn write_frame(s: &mut TcpStream, body: &[u8]) {
    s.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
    s.write_all(body).unwrap();
    s.flush().unwrap();
}

fn net_cfg() -> Config {
    let mut c = Config::default();
    c.fl.algorithm = Algorithm::Qafel;
    c.quant.client = "qsgd:8".into();
    c.quant.server = "qsgd:4".into();
    c.fl.client_lr = 0.05;
    c.fl.server_lr = 1.0;
    c.fl.server_momentum = 0.0;
    c.fl.staleness_scaling = true;
    c.fl.clip_norm = 0.0;
    c.stop.max_uploads = 100_000;
    c.net.v1_grace_ms = 300;
    c
}

#[test]
fn tcp_trivial_tree_broadcasts_bit_identical_to_flat_server() {
    // Root leader + edge leader + one hand-driven worker in lockstep:
    // every upload travels worker -> edge (UpdateV2) -> root
    // (UpdatePartial, count 1, identity codec) -> server step, and the
    // broadcast is relayed back down through the edge. Each frame the
    // worker reads must be byte-identical to the frame a *flat*
    // reference Server produces from the same payload at the same
    // staleness — the TCP half of the trivial-tree acceptance
    // criterion. Lockstep driving (send, then read the broadcast before
    // sending again) makes the whole exchange deterministic.
    let mut cfg = net_cfg();
    cfg.fl.buffer_size = 1; // K=1: every partial steps the server
    cfg.stop.max_server_steps = 4;
    cfg.net.edge_buffer = 1;
    cfg.net.partial_codec = "none".into();
    let d = 32usize;
    let x0: Vec<f32> = (0..d).map(|i| (i as f32 * 0.01).sin()).collect();

    let root_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let root_addr = root_listener.local_addr().unwrap().to_string();
    let edge_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let edge_addr = edge_listener.local_addr().unwrap().to_string();

    let root_cfg = cfg.clone();
    let root_x0 = x0.clone();
    let root = std::thread::spawn(move || {
        Leader::new(root_cfg, root_x0, 7).run_on(root_listener, 1).unwrap()
    });
    let edge_cfg = cfg.clone();
    let edge = std::thread::spawn(move || {
        EdgeLeader::new(edge_cfg, 99).run_on(edge_listener, &root_addr, 1).unwrap()
    });

    // --- hand-driven v2 worker against the edge ---------------------
    let mut sock = TcpStream::connect(&edge_addr).unwrap();
    sock.set_nodelay(true).unwrap();
    write_frame(
        &mut sock,
        &Message::Hello {
            version: PROTOCOL_VERSION,
            tier: None,
            quant_client: None,
            bandwidth_hint: None,
        }
        .encode(),
    );
    let (client_quant, join_x0) = match Message::decode(&read_frame(&mut sock)).unwrap() {
        Message::JoinV2 { version, codec_id, d: jd, x0, client_quant, .. } => {
            assert_eq!(version, PROTOCOL_VERSION);
            assert_eq!(codec_id, 0);
            assert_eq!(jd as usize, d);
            (client_quant, x0)
        }
        other => panic!("expected JoinV2 via the edge, got {other:?}"),
    };
    assert_eq!(join_x0, x0, "edge must relay the root's x^0 untouched");

    // the flat reference: same config, same x^0, same server seed
    let mut reference = Server::build(&cfg, x0.clone(), 7).unwrap();
    let qc = parse_spec(&client_quant).unwrap();
    let mut rng = Prng::new(4242);
    for round in 0..4u64 {
        let delta: Vec<f32> =
            (0..d).map(|i| ((i as f32) * 0.02 + round as f32).cos() * 0.1).collect();
        let msg = qc.quantize(&delta, &mut rng);
        // t_start pinned at 0: staleness grows 0,1,2,3 — the w(tau)
        // weighting path is exercised, not just the trivial w=1 case
        write_frame(
            &mut sock,
            &Message::UpdateV2 {
                worker_id: 0,
                t_start: 0,
                trip: round,
                train_loss: 0.0,
                codec_id: 0,
                payload: msg.payload.clone(),
            }
            .encode(),
        );
        let staleness = reference.t(); // == round; t_start was 0
        let b = match reference.ingest_from(&msg, staleness, 0).unwrap() {
            ServerStep::Stepped(mut b) => b.remove(0),
            other => panic!("K=1 must step, got {other:?}"),
        };
        let bcast = read_frame(&mut sock);
        let expect =
            Message::Broadcast { t: b.t, absolute: b.absolute, payload: b.msg.payload }.encode();
        assert_eq!(bcast, expect, "round {round}: broadcast through the edge diverged");
    }
    // step cap reached: the shutdown is relayed down the tree
    assert_eq!(read_frame(&mut sock), vec![4u8], "expected relayed Shutdown");
    write_frame(&mut sock, &Message::Bye { worker_id: 0, uploads: 4 }.encode());
    drop(sock);

    let edge_report = edge.join().unwrap();
    let root_report = root.join().unwrap();

    // the root's final model is the flat reference's, bit for bit
    assert_eq!(root_report.server_steps, 4);
    assert_eq!(&root_report.model[..], reference.model(), "tree model != flat reference");

    // exact accounting at both hops of the trivial tree
    assert_eq!(edge_report.updates, 4);
    assert_eq!(edge_report.partials, 4, "edge buffer 1 forwards every update");
    assert_eq!(edge_report.pending_at_shutdown, 0);
    assert_eq!(edge_report.replica_t, 4);
    assert_eq!(edge_report.partial_codec, "none");
    assert_eq!(
        edge_report.update_bytes,
        4 * qc.expected_bytes(d) as u64,
        "edge downstream bytes follow the client codec"
    );
    assert_eq!(
        edge_report.partial_bytes,
        4 * parse_spec("none").unwrap().expected_bytes(d) as u64,
        "edge upstream bytes follow the partial codec"
    );
    let ws = &root_report.worker_stats[0];
    assert_eq!(ws.uploads, 4);
    assert_eq!(ws.partials, 4, "every root ingest was an UpdatePartial frame");
    assert_eq!(ws.codec, "none");
    assert_eq!(root_report.comm.uploads, 4);
    assert_eq!(root_report.comm.upload_bytes, edge_report.partial_bytes);
}

#[test]
fn two_level_loopback_converges_with_exact_per_edge_accounting() {
    // The real deployment shape: one root, two edge leaders, four
    // workers — seven threads, six TCP connections, all in-process.
    let mut cfg = net_cfg();
    cfg.fl.buffer_size = 2; // root K
    cfg.stop.max_server_steps = 20;
    cfg.net.edge_buffer = 2;
    cfg.net.partial_codec = "qsgd:8".into();
    const D: usize = 64;
    let backend = |seed: u64| QuadraticBackend::new(D, 8, 1.0, 0.3, 0.2, 0.02, 1, seed);
    let x0 = backend(17).init_params(0).unwrap();
    let g0 = backend(17).grad_norm_sq(&x0);

    let root_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let root_addr = root_listener.local_addr().unwrap().to_string();
    let root_cfg = cfg.clone();
    let root_x0 = x0.clone();
    let root = std::thread::spawn(move || {
        Leader::new(root_cfg, root_x0, 7).run_on(root_listener, 2).unwrap()
    });

    let mut edges = Vec::new();
    let mut workers = Vec::new();
    for e in 0..2u64 {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let edge_addr = listener.local_addr().unwrap().to_string();
        let edge_cfg = cfg.clone();
        let up = root_addr.clone();
        edges.push(std::thread::spawn(move || {
            EdgeLeader::new(edge_cfg, 0xE0 + e).run_on(listener, &up, 2).unwrap()
        }));
        for w in 0..2u64 {
            let addr = edge_addr.clone();
            workers.push(std::thread::spawn(move || {
                let mut worker = Worker::new(backend(17 + 10 * e + w));
                worker.round_delay = std::time::Duration::from_millis(1);
                worker.run(&addr).unwrap()
            }));
        }
    }
    let root_report = root.join().unwrap();
    let edge_reports: Vec<_> = edges.into_iter().map(|e| e.join().unwrap()).collect();
    let worker_reports: Vec<_> = workers.into_iter().map(|w| w.join().unwrap()).collect();

    // the run completed and actually descended
    assert_eq!(root_report.server_steps, 20);
    assert_eq!(root_report.comm.broadcasts, 20);
    let g1 = backend(17).grad_norm_sq(&root_report.model);
    assert!(g1 < g0, "no descent through the tree: {g0} -> {g1}");

    // every worker negotiated v2 through its edge
    assert_eq!(worker_reports.len(), 4);
    for r in &worker_reports {
        assert_eq!(r.protocol, 2);
        assert_eq!(r.codec, "qsgd:8");
    }

    // root-side accounting: two "workers", both edges, all uploads
    // UpdatePartial frames on the partial codec
    let qp = parse_spec(&cfg.net.partial_codec).unwrap();
    assert_eq!(root_report.worker_stats.len(), 2);
    for ws in &root_report.worker_stats {
        assert!(ws.uploads > 0, "edge {} never forwarded", ws.worker_id);
        assert_eq!(ws.partials, ws.uploads);
        assert_eq!(ws.codec, qp.name());
        assert_eq!(ws.upload_bytes, ws.uploads * qp.expected_bytes(D) as u64);
        assert_eq!(ws.staleness.n, 2 * ws.uploads, "B=2 partials carry 2 staleness samples");
        // every live edge's writer delivered all broadcasts + Shutdown
        assert_eq!(ws.broadcast_frames, 21);
    }
    let root_uploads: u64 = root_report.worker_stats.iter().map(|w| w.uploads).sum();
    assert_eq!(root_uploads, root_report.comm.uploads);

    // per-edge accounting, exact at every hop
    let qc = parse_spec(&cfg.quant.client).unwrap();
    assert_eq!(edge_reports.len(), 2);
    let mut ids: Vec<u32> = edge_reports.iter().map(|e| e.edge_worker_id).collect();
    ids.sort();
    assert_eq!(ids, vec![0, 1]);
    for er in &edge_reports {
        // a partial forwarded while the root's Shutdown is in flight is
        // dropped at the root (same as a flat worker's late upload), so
        // the edge may have forwarded a little more than the root took
        let ws = &root_report.worker_stats[er.edge_worker_id as usize];
        assert!(er.partials >= ws.uploads, "edge {} vs root row", er.edge_worker_id);
        assert_eq!(er.partial_bytes, er.partials * qp.expected_bytes(D) as u64);
        // downstream: two workers, client-codec bytes, B=2 buffering
        let down: u64 = er.worker_stats.iter().map(|w| w.uploads).sum();
        assert_eq!(er.updates, down);
        assert_eq!(er.update_bytes, er.updates * qc.expected_bytes(D) as u64);
        assert_eq!(er.updates, 2 * er.partials + er.pending_at_shutdown as u64);
        assert!(er.pending_at_shutdown < 2, "B=2 never holds 2+ pending");
        assert_eq!(er.staleness.n, er.updates);
        assert_eq!(er.replica_t, 20, "edge replica followed every broadcast");
        for dws in &er.worker_stats {
            assert!(dws.uploads > 0, "downstream worker {} starved", dws.worker_id);
            assert_eq!(dws.partials, 0, "leaf workers never send partials");
            assert_eq!(dws.broadcast_frames, 21);
        }
    }
    // workers count uploads at send time; an upload racing the relayed
    // Shutdown is dropped by its edge, so sent >= ingested
    let tree_updates: u64 = edge_reports.iter().map(|e| e.updates).sum();
    let worker_uploads: u64 = worker_reports.iter().map(|r| r.uploads).sum();
    assert!(tree_updates <= worker_uploads, "edges ingested more than workers sent");
}
