//! Wire-protocol v2 integration tests (ISSUE 5 acceptance criteria):
//!
//! * a loopback run with three workers on three *different* client
//!   codecs completes, with per-worker byte accounting matching each
//!   codec's wire size exactly, and the leader's recorded event stream
//!   is **bit-identical** under [`qafel::telemetry::replay_events`]
//!   (the journal replayer drives the simulator's
//!   [`Server::ingest_from`] path);
//! * a v1 worker (no version field, silent join) is still served
//!   byte-identically to the legacy protocol — the Join/Broadcast/
//!   Shutdown frames it sees are pinned against a hand-built golden;
//! * v1 and v2 workers coexist on one leader;
//! * decode/codec errors surface which worker they came from
//!   (worker id + peer address in the error context).
//!
//! Everything runs under the `QAFEL_TEST_SHARDS` matrix: broadcast
//! payloads are bit-identical for every shard count, so the goldens and
//! replays hold at S=1 and S=4 alike.
//!
//! The adaptive-quantization control loop (ISSUE 9) adds the `Rekey`
//! renegotiation state machine on top: a scripted raw-socket worker
//! pins the Broadcast-then-Rekey frame order, the in-flight-old-codec
//! transition window, per-epoch byte accounting across a switch, and
//! the cutover after which a stale tag is a hard error; a loopback run
//! under an unmeetable byte budget drives every worker down the ladder
//! and still replays bit-identically.

use qafel::config::{Algorithm, Config, TierConfig};
use qafel::coordinator::{Server, ServerStep};
use qafel::net::{Leader, Message, Worker, PROTOCOL_VERSION};
use qafel::quant::parse_spec;
use qafel::runtime::{Backend as _, QuadraticBackend};
use qafel::telemetry::{replay_events, Event};
use qafel::util::prng::Prng;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};

/// Read one raw frame (length prefix + body), returning the body bytes.
fn read_frame(s: &mut TcpStream) -> Vec<u8> {
    let mut len = [0u8; 4];
    s.read_exact(&mut len).unwrap();
    let n = u32::from_le_bytes(len) as usize;
    let mut body = vec![0u8; n];
    s.read_exact(&mut body).unwrap();
    body
}

/// Write one raw frame around the given body bytes.
fn write_frame(s: &mut TcpStream, body: &[u8]) {
    s.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
    s.write_all(body).unwrap();
    s.flush().unwrap();
}

/// A config for fast deterministic loopback runs: mixed codecs via one
/// tier preset, a short v1 grace so back-compat tests stay quick.
fn mixed_cfg() -> Config {
    let mut c = Config::default();
    c.fl.algorithm = Algorithm::Qafel;
    c.quant.client = "qsgd:8".into();
    c.quant.server = "qsgd:4".into();
    c.fl.buffer_size = 3;
    c.fl.client_lr = 0.05;
    c.fl.server_lr = 1.0;
    c.fl.server_momentum = 0.0;
    c.fl.staleness_scaling = true;
    c.fl.clip_norm = 0.0;
    c.stop.max_server_steps = 30;
    c.stop.max_uploads = 100_000;
    c.net.v1_grace_ms = 200;
    let mut phone = TierConfig::named("phone");
    phone.quant_client = Some("top:0.1".into());
    c.scenario.tiers = vec![phone];
    c
}

const D: usize = 64;

fn backend(seed: u64) -> QuadraticBackend {
    QuadraticBackend::new(D, 8, 1.0, 0.3, 0.2, 0.02, 1, seed)
}

#[test]
fn mixed_codec_loopback_replays_bit_identical_to_ingest_from() {
    let cfg = mixed_cfg();
    let x0 = backend(21).init_params(0).unwrap();
    let g0 = backend(21).grad_norm_sq(&x0);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let leader_cfg = cfg.clone();
    let leader_x0 = x0.clone();
    let leader = std::thread::spawn(move || {
        let mut l = Leader::new(leader_cfg, leader_x0, 7);
        l.record_events = true;
        l.run_on(listener, 3).unwrap()
    });

    // three workers, three different upload codecs: an explicit
    // override, a tier preset, and the config default
    let mut workers = Vec::new();
    for req in [Some(("quant", "qsgd:4")), Some(("tier", "phone")), None] {
        let addr = addr.clone();
        workers.push(std::thread::spawn(move || {
            let mut w = Worker::new(backend(21));
            w.round_delay = std::time::Duration::from_millis(1);
            match req {
                Some(("quant", spec)) => w.quant_client = Some(spec.into()),
                Some(("tier", name)) => w.tier = Some(name.into()),
                _ => {}
            }
            w.run(&addr).unwrap()
        }));
    }
    let report = leader.join().unwrap();
    let worker_reports: Vec<_> = workers.into_iter().map(|w| w.join().unwrap()).collect();

    // the run completed and actually descended
    assert_eq!(report.server_steps, 30);
    assert_eq!(report.comm.broadcasts, 30);
    let g1 = backend(21).grad_norm_sq(&report.model);
    assert!(g1 < g0 * 0.9, "{g0} -> {g1}");

    // every worker negotiated v2 and got its requested codec
    let mut worker_codecs: Vec<String> =
        worker_reports.iter().map(|r| r.codec.clone()).collect();
    worker_codecs.sort();
    assert_eq!(worker_codecs, vec!["qsgd:4", "qsgd:8", "top:0.1"]);
    for r in &worker_reports {
        assert_eq!(r.protocol, 2);
    }

    // per-worker byte accounting matches each codec's wire size exactly
    assert_eq!(report.worker_stats.len(), 3);
    let mut stats_codecs: Vec<String> =
        report.worker_stats.iter().map(|w| w.codec.clone()).collect();
    stats_codecs.sort();
    assert_eq!(stats_codecs, vec!["qsgd:4", "qsgd:8", "top:0.1"]);
    for ws in &report.worker_stats {
        assert!(ws.uploads > 0, "worker {} never uploaded", ws.worker_id);
        let per_upload = parse_spec(&ws.codec).unwrap().expected_bytes(D) as u64;
        assert_eq!(
            ws.upload_bytes,
            ws.uploads * per_upload,
            "worker {} ({}) byte accounting",
            ws.worker_id,
            ws.codec
        );
        assert_eq!(ws.staleness.n, ws.uploads);
    }
    let total_uploads: u64 = report.worker_stats.iter().map(|w| w.uploads).sum();
    let total_bytes: u64 = report.worker_stats.iter().map(|w| w.upload_bytes).sum();
    assert_eq!(total_uploads, report.comm.uploads);
    assert_eq!(total_bytes, report.comm.upload_bytes);

    // === the acceptance criterion: the recorded event stream replays
    // bit-identically through the shared journal replayer — the same
    // machinery `qafel journal replay` runs on a journal file. Replay
    // rebuilds the config from the Meta event, re-registers the codec
    // registry in recorded order, feeds every ingest, and checks every
    // broadcast payload and the final model byte-for-byte.
    let events = report.events.expect("record_events was set");
    let Some(Event::Meta { runtime, algorithm, fingerprint, .. }) = events.first() else {
        panic!("event stream does not start with meta");
    };
    assert_eq!(runtime, "tcp");
    assert_eq!(algorithm, "qafel");
    assert_eq!(*fingerprint, report.fingerprint);
    // the registry events cover the dynamically negotiated codecs (the
    // explicit qsgd:4 override and the phone tier's top:0.1 preset)
    let mut codec_specs: Vec<String> = events
        .iter()
        .filter_map(|ev| match ev {
            Event::Codec { reg, spec, .. } if reg == "client" => Some(spec.clone()),
            _ => None,
        })
        .collect();
    codec_specs.sort();
    assert_eq!(codec_specs, vec!["qsgd:4", "top:0.1"]);
    let replay = replay_events(&events).unwrap();
    assert_eq!(replay.steps, 30);
    assert_eq!(replay.broadcasts_checked, 30);
    assert_eq!(replay.uploads, report.comm.uploads);
    assert!(replay.finalized, "event stream must end in a verified final event");
}

#[test]
fn v1_worker_served_bit_identically_golden() {
    // A silent (v1) client must receive, byte for byte, the frames the
    // legacy protocol defined: the Join built from the raw config
    // specs, one Broadcast per server step, then Shutdown.
    let mut cfg = Config::default();
    cfg.fl.algorithm = Algorithm::Qafel;
    cfg.quant.client = "qsgd:8".into();
    cfg.quant.server = "qsgd:8".into();
    cfg.fl.buffer_size = 1;
    cfg.fl.server_lr = 1.0;
    cfg.fl.server_momentum = 0.0;
    cfg.fl.clip_norm = 0.0;
    cfg.stop.max_server_steps = 2;
    cfg.net.v1_grace_ms = 150;
    let d = 256usize;
    let x0: Vec<f32> = (0..d).map(|i| (i as f32 * 0.01).sin()).collect();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let leader_cfg = cfg.clone();
    let leader_x0 = x0.clone();
    let leader = std::thread::spawn(move || {
        Leader::new(leader_cfg, leader_x0, 5).run_on(listener, 1).unwrap()
    });

    let mut sock = TcpStream::connect(&addr).unwrap();
    sock.set_nodelay(true).unwrap();
    // say nothing: the leader must classify us as v1 by our silence

    // --- golden Join frame, built by hand from the v1 wire layout ----
    let join = read_frame(&mut sock);
    let mut expect = vec![1u8]; // TAG_JOIN
    expect.extend_from_slice(&0u32.to_le_bytes()); // worker_id
    expect.extend_from_slice(&(d as u32).to_le_bytes()); // d
    expect.extend_from_slice(&(d as u32).to_le_bytes()); // x0 length
    for v in &x0 {
        expect.extend_from_slice(&v.to_le_bytes());
    }
    expect.extend_from_slice(&6u32.to_le_bytes());
    expect.extend_from_slice(b"qsgd:8"); // client_quant: the raw config spec
    expect.extend_from_slice(&6u32.to_le_bytes());
    expect.extend_from_slice(b"qsgd:8"); // server_quant
    expect.extend_from_slice(&cfg.fl.client_lr.to_le_bytes());
    assert_eq!(join, expect, "v1 Join frame changed");

    // --- the run itself: reference server == what the leader must do -
    let qc = parse_spec("qsgd:8").unwrap();
    let mut rng = Prng::new(77);
    let mut reference = Server::build(&cfg, x0.clone(), 5).unwrap();
    for round in 0..2u64 {
        let delta: Vec<f32> =
            (0..d).map(|i| ((i as f32) * 0.02 + round as f32).cos() * 0.1).collect();
        let msg = qc.quantize(&delta, &mut rng);
        write_frame(
            &mut sock,
            &Message::Update {
                worker_id: 0,
                t_start: round,
                trip: round,
                train_loss: 0.0,
                payload: msg.payload.clone(),
            }
            .encode(),
        );
        let staleness = reference.t().saturating_sub(round);
        let b = match reference.ingest(&msg, staleness).unwrap() {
            ServerStep::Stepped(mut b) => b.remove(0),
            other => panic!("K=1 must step, got {other:?}"),
        };
        let bcast = read_frame(&mut sock);
        let expect =
            Message::Broadcast { t: b.t, absolute: b.absolute, payload: b.msg.payload }.encode();
        assert_eq!(bcast, expect, "round {round}: v1 Broadcast frame diverged");
    }
    // step cap reached: the v1 worker gets a bare Shutdown frame
    assert_eq!(read_frame(&mut sock), vec![4u8], "v1 Shutdown frame changed");
    write_frame(&mut sock, &Message::Bye { worker_id: 0, uploads: 2 }.encode());
    drop(sock);

    let report = leader.join().unwrap();
    assert_eq!(report.server_steps, 2);
    assert_eq!(&report.model[..], reference.model(), "leader model != reference");
    let ws = &report.worker_stats[0];
    assert_eq!(ws.protocol, 1, "silent worker must be served as v1");
    assert_eq!(ws.codec_id, 0);
    assert_eq!(ws.codec, "qsgd:8");
    assert_eq!(ws.uploads, 2);
    assert_eq!(ws.upload_bytes, 2 * qc.expected_bytes(d) as u64);
}

#[test]
fn v1_and_v2_workers_coexist_on_one_leader() {
    let mut cfg = mixed_cfg();
    cfg.stop.max_server_steps = 20;
    cfg.net.v1_grace_ms = 150;
    let x0 = backend(9).init_params(0).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let leader_cfg = cfg.clone();
    let leader_x0 = x0.clone();
    let leader = std::thread::spawn(move || {
        Leader::new(leader_cfg, leader_x0, 3).run_on(listener, 3).unwrap()
    });

    let mut workers = Vec::new();
    for kind in ["v1", "preset", "default"] {
        let addr = addr.clone();
        workers.push(std::thread::spawn(move || {
            let mut w = Worker::new(backend(9));
            w.round_delay = std::time::Duration::from_millis(1);
            match kind {
                "v1" => w.force_v1 = true,
                "preset" => w.quant_client = Some("qsgd:4".into()),
                _ => {}
            }
            (kind, w.run(&addr).unwrap())
        }));
    }
    let report = leader.join().unwrap();
    let worker_reports: Vec<_> = workers.into_iter().map(|w| w.join().unwrap()).collect();

    assert_eq!(report.server_steps, 20);
    for (kind, r) in &worker_reports {
        match *kind {
            "v1" => {
                assert_eq!(r.protocol, 1);
                assert_eq!(r.codec_id, 0);
                assert_eq!(r.codec, "qsgd:8");
            }
            "preset" => {
                assert_eq!(r.protocol, 2);
                assert_eq!(r.codec, "qsgd:4");
            }
            _ => {
                assert_eq!(r.protocol, 2);
                assert_eq!(r.codec, "qsgd:8");
                assert_eq!(r.codec_id, 0);
            }
        }
    }
    // leader-side stats agree with what each worker negotiated, and the
    // byte accounting is exact for every protocol generation
    let mut protocols: Vec<u8> = report.worker_stats.iter().map(|w| w.protocol).collect();
    protocols.sort();
    assert_eq!(protocols, vec![1, 2, 2]);
    for ws in &report.worker_stats {
        assert!(ws.uploads > 0);
        let per_upload = parse_spec(&ws.codec).unwrap().expected_bytes(D) as u64;
        assert_eq!(ws.upload_bytes, ws.uploads * per_upload);
        // every live worker's writer delivered all broadcasts + Shutdown
        assert_eq!(ws.broadcast_frames, 21, "worker {}", ws.worker_id);
    }
}

#[test]
fn future_version_hello_negotiates_down_to_v2() {
    let mut cfg = mixed_cfg();
    cfg.net.v1_grace_ms = 500;
    let x0 = vec![0.0f32; 8];
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let leader_x0 = x0.clone();
    let leader = std::thread::spawn(move || {
        Leader::new(cfg, leader_x0, 1).run_on(listener, 1).unwrap()
    });

    let mut sock = TcpStream::connect(&addr).unwrap();
    write_frame(
        &mut sock,
        &Message::Hello { version: 9, tier: None, quant_client: None, bandwidth_hint: None }
            .encode(),
    );
    let join = Message::decode(&read_frame(&mut sock)).unwrap();
    match join {
        Message::JoinV2 { version, codec_id, d, .. } => {
            assert_eq!(version, PROTOCOL_VERSION, "leader must cap at its own version");
            assert_eq!(codec_id, 0);
            assert_eq!(d, 8);
        }
        other => panic!("expected JoinV2, got {other:?}"),
    }
    drop(sock); // clean disconnect: the leader reports an idle run
    let report = leader.join().unwrap();
    assert_eq!(report.server_steps, 0);
    assert_eq!(report.worker_stats[0].protocol, 2);
}

#[test]
fn mismatched_codec_id_error_names_worker_and_peer() {
    // An upload must be tagged with the codec its connection negotiated
    // (two registered codecs can share a wire size, so a wrong-but-
    // registered id could silently mis-decode; an unregistered id is
    // the same violation). The error names the worker, peer and ids.
    let mut cfg = mixed_cfg();
    cfg.scenario.tiers.clear(); // only the default codec is registered
    let x0 = vec![0.0f32; 8];
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let leader = std::thread::spawn(move || Leader::new(cfg, x0, 1).run_on(listener, 1));

    let client = std::thread::spawn(move || {
        let mut sock = TcpStream::connect(&addr).unwrap();
        write_frame(
            &mut sock,
            &Message::Hello { version: 2, tier: None, quant_client: None, bandwidth_hint: None }
                .encode(),
        );
        let _join = read_frame(&mut sock);
        write_frame(
            &mut sock,
            &Message::UpdateV2 {
                worker_id: 0,
                t_start: 0,
                trip: 0,
                train_loss: 0.0,
                codec_id: 9,
                payload: vec![0; 16],
            }
            .encode(),
        );
        // the leader aborts; drain until EOF so the write cannot race it
        let mut rest = Vec::new();
        let _ = sock.read_to_end(&mut rest);
    });

    let err = leader.join().unwrap().unwrap_err().to_string();
    assert!(err.contains("worker 0"), "missing worker id: {err}");
    assert!(err.contains("127.0.0.1"), "missing peer addr: {err}");
    assert!(err.contains("codec id 9"), "missing tagged codec id: {err}");
    assert!(err.contains("negotiated codec id 0"), "missing negotiated id: {err}");
    client.join().unwrap();
}

#[test]
fn wrong_sized_upload_error_names_worker_and_codec() {
    let mut cfg = mixed_cfg();
    cfg.scenario.tiers.clear();
    let x0 = vec![0.0f32; 8];
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let leader = std::thread::spawn(move || Leader::new(cfg, x0, 1).run_on(listener, 1));

    let client = std::thread::spawn(move || {
        let mut sock = TcpStream::connect(&addr).unwrap();
        write_frame(
            &mut sock,
            &Message::Hello { version: 2, tier: None, quant_client: None, bandwidth_hint: None }
                .encode(),
        );
        let _join = read_frame(&mut sock);
        // a 3-byte payload is no valid qsgd:8 encoding at d=8
        write_frame(
            &mut sock,
            &Message::UpdateV2 {
                worker_id: 0,
                t_start: 0,
                trip: 0,
                train_loss: 0.0,
                codec_id: 0,
                payload: vec![1, 2, 3],
            }
            .encode(),
        );
        let mut rest = Vec::new();
        let _ = sock.read_to_end(&mut rest);
    });

    let err = format!("{:#}", leader.join().unwrap().unwrap_err());
    assert!(err.contains("worker 0"), "missing worker id: {err}");
    assert!(err.contains("127.0.0.1"), "missing peer addr: {err}");
    assert!(err.contains("qsgd:8"), "missing codec name: {err}");
    client.join().unwrap();
}

#[test]
fn garbage_frame_is_fatal_with_worker_context_but_disconnect_is_not() {
    // A worker dying mid-run (abrupt close) is tolerated exactly as in
    // v1; a worker sending a corrupt frame aborts the run naming the
    // worker. Two workers: one disconnects, one sends garbage.
    let mut cfg = mixed_cfg();
    cfg.scenario.tiers.clear();
    let x0 = vec![0.0f32; 8];
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let leader = std::thread::spawn(move || Leader::new(cfg, x0, 1).run_on(listener, 2));

    // worker 0: joins, then vanishes — must NOT fail the run
    let addr0 = addr.clone();
    let quitter = std::thread::spawn(move || {
        let mut sock = TcpStream::connect(&addr0).unwrap();
        write_frame(
            &mut sock,
            &Message::Hello { version: 2, tier: None, quant_client: None, bandwidth_hint: None }
                .encode(),
        );
        let _join = read_frame(&mut sock);
        drop(sock);
    });
    quitter.join().unwrap();

    // worker 1: joins, then sends a well-framed body with an unknown tag
    let client = std::thread::spawn(move || {
        let mut sock = TcpStream::connect(&addr).unwrap();
        write_frame(
            &mut sock,
            &Message::Hello { version: 2, tier: None, quant_client: None, bandwidth_hint: None }
                .encode(),
        );
        let _join = read_frame(&mut sock);
        write_frame(&mut sock, &[99u8]); // unknown message tag
        let mut rest = Vec::new();
        let _ = sock.read_to_end(&mut rest);
    });

    let err = leader.join().unwrap().unwrap_err().to_string();
    assert!(err.contains("worker 1"), "wrong or missing worker id: {err}");
    assert!(err.contains("127.0.0.1"), "missing peer addr: {err}");
    client.join().unwrap();
}

/// Config for the scripted renegotiation tests: d=8, K=1 (every upload
/// steps), the controller scores every step, and the byte budget equals
/// qsgd:4's wire size — so a lone worker uploading qsgd:8 overshoots
/// and is walked exactly one ladder level down.
fn adaptive_cfg(budget_bytes_per_step: u64, steps: u64) -> Config {
    let mut c = mixed_cfg();
    c.scenario.tiers.clear();
    c.fl.buffer_size = 1;
    c.stop.max_server_steps = steps;
    c.net.adaptive.enabled = true;
    c.net.adaptive.interval = 1;
    c.net.adaptive.min_uploads = 1;
    c.net.adaptive.budget_bytes_per_step = budget_bytes_per_step;
    c.net.adaptive.levels = vec!["qsgd:8".into(), "qsgd:4".into(), "qsgd:2".into()];
    c
}

#[test]
fn rekey_transition_accepts_in_flight_uploads_and_accounts_per_epoch() {
    // One scripted worker, four uploads: the first overshoots the
    // budget and triggers a Rekey qsgd:8 -> qsgd:4; the second is still
    // tagged with the old codec (in flight across the switch) and must
    // be accepted and attributed to the *old* epoch; the third carries
    // the new tag and cuts the transition window over; the fourth shows
    // the downshifted worker now fits the budget (no further Rekey).
    let d = 8usize;
    let q8 = parse_spec("qsgd:8").unwrap();
    let q4 = parse_spec("qsgd:4").unwrap();
    let b8 = q8.expected_bytes(d) as u64;
    let b4 = q4.expected_bytes(d) as u64;
    assert!(b8 > b4, "ladder must be strictly ordered at d={d}");
    let cfg = adaptive_cfg(b4, 4);
    let x0 = vec![0.0f32; d];

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let leader = std::thread::spawn(move || {
        let mut l = Leader::new(cfg, x0, 5);
        l.record_events = true;
        l.run_on(listener, 1).unwrap()
    });

    let mut sock = TcpStream::connect(&addr).unwrap();
    sock.set_nodelay(true).unwrap();
    write_frame(
        &mut sock,
        &Message::Hello { version: 2, tier: None, quant_client: None, bandwidth_hint: None }
            .encode(),
    );
    match Message::decode(&read_frame(&mut sock)).unwrap() {
        Message::JoinV2 { codec_id, d: jd, .. } => {
            assert_eq!(codec_id, 0);
            assert_eq!(jd as usize, d);
        }
        other => panic!("expected JoinV2, got {other:?}"),
    }

    let mut rng = Prng::new(3);
    let delta: Vec<f32> = (0..d).map(|i| (i as f32 * 0.3).sin() * 0.1).collect();
    let mut upload = |sock: &mut TcpStream, tag: u32, t_start: u64, q: &dyn qafel::quant::Quantizer, rng: &mut Prng| {
        let msg = q.quantize(&delta, rng);
        write_frame(
            sock,
            &Message::UpdateV2 {
                worker_id: 0,
                t_start,
                trip: t_start,
                train_loss: 0.0,
                codec_id: tag,
                payload: msg.payload,
            }
            .encode(),
        );
    };

    // upload 1 (qsgd:8): steps to t=1, overshoots the budget. The wire
    // order is pinned: Broadcast for the step FIRST, then the Rekey —
    // the writer queue is FIFO across step and control frames.
    upload(&mut sock, 0, 0, q8.as_ref(), &mut rng);
    match Message::decode(&read_frame(&mut sock)).unwrap() {
        Message::Broadcast { t, .. } => assert_eq!(t, 1),
        other => panic!("expected Broadcast before Rekey, got {other:?}"),
    }
    let new_id = match Message::decode(&read_frame(&mut sock)).unwrap() {
        Message::Rekey { worker_id, codec_id, spec, t } => {
            assert_eq!(worker_id, 0);
            assert_eq!(spec, "qsgd:4");
            assert_eq!(t, 1, "Rekey must carry the step it was decided at");
            codec_id
        }
        other => panic!("expected Rekey after Broadcast, got {other:?}"),
    };

    // upload 2: still tagged 0 — in flight from before the worker saw
    // the Rekey. Accepted, and no second Rekey while the transition
    // window is open (the controller skips workers mid-switch).
    upload(&mut sock, 0, 1, q8.as_ref(), &mut rng);
    match Message::decode(&read_frame(&mut sock)).unwrap() {
        Message::Broadcast { t, .. } => assert_eq!(t, 2),
        other => panic!("expected Broadcast, got {other:?}"),
    }

    // uploads 3+4: the new tag cuts the window over; at qsgd:4 the
    // projection fits the budget, so no further Rekey arrives.
    upload(&mut sock, new_id, 2, q4.as_ref(), &mut rng);
    match Message::decode(&read_frame(&mut sock)).unwrap() {
        Message::Broadcast { t, .. } => assert_eq!(t, 3),
        other => panic!("expected Broadcast, got {other:?}"),
    }
    upload(&mut sock, new_id, 3, q4.as_ref(), &mut rng);
    match Message::decode(&read_frame(&mut sock)).unwrap() {
        Message::Broadcast { t, .. } => assert_eq!(t, 4),
        other => panic!("expected Broadcast, got {other:?}"),
    }
    assert!(matches!(Message::decode(&read_frame(&mut sock)).unwrap(), Message::Shutdown));
    write_frame(&mut sock, &Message::Bye { worker_id: 0, uploads: 4 }.encode());
    drop(sock);

    let report = leader.join().unwrap();
    assert_eq!(report.server_steps, 4);
    assert_eq!(report.comm.uploads, 4);

    // exact per-epoch byte accounting across the switch: two uploads on
    // each codec, in-flight old-tag uploads attributed to their epoch
    let ws = &report.worker_stats[0];
    assert_eq!(ws.rekeys, 1);
    assert_eq!(ws.codec, "qsgd:4");
    assert_eq!(ws.codec_id, new_id as usize);
    assert_eq!(ws.epochs.len(), 2);
    assert_eq!(ws.epochs[0].codec, "qsgd:8");
    assert_eq!(ws.epochs[0].codec_id, 0);
    assert_eq!(ws.epochs[0].uploads, 2);
    assert_eq!(ws.epochs[0].upload_bytes, 2 * b8);
    assert_eq!(ws.epochs[1].codec, "qsgd:4");
    assert_eq!(ws.epochs[1].codec_id, new_id as usize);
    assert_eq!(ws.epochs[1].uploads, 2);
    assert_eq!(ws.epochs[1].upload_bytes, 2 * b4);
    assert_eq!(ws.upload_bytes, 2 * b8 + 2 * b4);
    assert_eq!(report.comm.upload_bytes, ws.upload_bytes);

    // registry dedup pinned: "qsgd:8" is the config default (id 0), so
    // the ladder registers exactly qsgd:4 and qsgd:2 — once each
    let events = report.events.expect("record_events was set");
    let mut client_codecs: Vec<String> = events
        .iter()
        .filter_map(|ev| match ev {
            Event::Codec { reg, spec, .. } if reg == "client" => Some(spec.clone()),
            _ => None,
        })
        .collect();
    client_codecs.sort();
    assert_eq!(client_codecs, vec!["qsgd:2", "qsgd:4"]);
    let rekeys: Vec<_> = events
        .iter()
        .filter_map(|ev| match ev {
            Event::Rekey { step, worker, old, new, spec, .. } => {
                Some((*step, *worker, *old, *new, spec.clone()))
            }
            _ => None,
        })
        .collect();
    assert_eq!(rekeys, vec![(1, 0, 0, new_id as u64, "qsgd:4".to_string())]);

    // the recorded stream — ingests under both codec ids straddling the
    // Rekey — replays bit-identically through the journal machinery
    let replay = replay_events(&events).unwrap();
    assert_eq!(replay.steps, 4);
    assert_eq!(replay.uploads, 4);
    assert!(replay.finalized);
}

#[test]
fn stale_codec_tag_after_cutover_is_rejected_with_context() {
    // Once a worker has uploaded under its post-Rekey codec, the
    // transition window is closed: per-connection frame order means no
    // older-tagged frame can legitimately follow, so one arriving is
    // the same hard error as any other mismatched tag.
    let d = 8usize;
    let q8 = parse_spec("qsgd:8").unwrap();
    let q4 = parse_spec("qsgd:4").unwrap();
    let budget = q4.expected_bytes(d) as u64;
    let cfg = adaptive_cfg(budget, 10);
    let x0 = vec![0.0f32; d];

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let leader = std::thread::spawn(move || Leader::new(cfg, x0, 5).run_on(listener, 1));

    let client = std::thread::spawn(move || -> u32 {
        let mut sock = TcpStream::connect(&addr).unwrap();
        sock.set_nodelay(true).unwrap();
        write_frame(
            &mut sock,
            &Message::Hello { version: 2, tier: None, quant_client: None, bandwidth_hint: None }
                .encode(),
        );
        let _join = read_frame(&mut sock);
        let mut rng = Prng::new(4);
        let delta: Vec<f32> = (0..d).map(|i| (i as f32 * 0.2).cos() * 0.1).collect();
        let m8 = q8.quantize(&delta, &mut rng);
        write_frame(
            &mut sock,
            &Message::UpdateV2 {
                worker_id: 0,
                t_start: 0,
                trip: 0,
                train_loss: 0.0,
                codec_id: 0,
                payload: m8.payload,
            }
            .encode(),
        );
        let _bcast = read_frame(&mut sock);
        let new_id = match Message::decode(&read_frame(&mut sock)).unwrap() {
            Message::Rekey { codec_id, .. } => codec_id,
            other => panic!("expected Rekey, got {other:?}"),
        };
        // cutover: first upload under the new tag closes the window
        let m4 = q4.quantize(&delta, &mut rng);
        write_frame(
            &mut sock,
            &Message::UpdateV2 {
                worker_id: 0,
                t_start: 1,
                trip: 1,
                train_loss: 0.0,
                codec_id: new_id,
                payload: m4.payload,
            }
            .encode(),
        );
        let _bcast = read_frame(&mut sock);
        // a frame with the superseded tag after the cutover is fatal
        let m8b = q8.quantize(&delta, &mut rng);
        write_frame(
            &mut sock,
            &Message::UpdateV2 {
                worker_id: 0,
                t_start: 2,
                trip: 2,
                train_loss: 0.0,
                codec_id: 0,
                payload: m8b.payload,
            }
            .encode(),
        );
        let mut rest = Vec::new();
        let _ = sock.read_to_end(&mut rest);
        new_id
    });

    let err = leader.join().unwrap().unwrap_err().to_string();
    let new_id = client.join().unwrap();
    assert!(err.contains("worker 0"), "missing worker id: {err}");
    assert!(err.contains("upload tagged codec id 0"), "missing stale tag: {err}");
    assert!(
        err.contains(&format!("negotiated codec id {new_id}")),
        "missing negotiated id: {err}"
    );
    assert!(err.contains("qsgd:4"), "missing negotiated codec name: {err}");
}

#[test]
fn adaptive_loopback_downshifts_every_worker_and_replays() {
    // Full control loop against real Workers: a byte budget nobody can
    // meet walks every scoreable worker straight down to the ladder
    // bottom (one Rekey each — the greedy projection moves a worker
    // repeatedly within one decision, emitting a single frame). One
    // worker announces a bandwidth hint, exercising the hinted scoring
    // path; the run still converges and replays bit-identically.
    let mut cfg = mixed_cfg();
    cfg.scenario.tiers.clear();
    cfg.net.adaptive.enabled = true;
    cfg.net.adaptive.interval = 2;
    cfg.net.adaptive.min_uploads = 1;
    cfg.net.adaptive.budget_bytes_per_step = 1; // unmeetable by design
    cfg.net.adaptive.levels = vec!["qsgd:8".into(), "qsgd:4".into(), "qsgd:2".into()];
    let x0 = backend(33).init_params(0).unwrap();
    let g0 = backend(33).grad_norm_sq(&x0);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let leader_cfg = cfg.clone();
    let leader_x0 = x0.clone();
    let leader = std::thread::spawn(move || {
        let mut l = Leader::new(leader_cfg, leader_x0, 11);
        l.record_events = true;
        l.run_on(listener, 3).unwrap()
    });

    let mut workers = Vec::new();
    for hint in [Some(0.25f32), None, None] {
        let addr = addr.clone();
        workers.push(std::thread::spawn(move || {
            let mut w = Worker::new(backend(33));
            w.round_delay = std::time::Duration::from_millis(1);
            w.bandwidth_hint = hint;
            w.run(&addr).unwrap()
        }));
    }
    let report = leader.join().unwrap();
    let worker_reports: Vec<_> = workers.into_iter().map(|w| w.join().unwrap()).collect();

    assert_eq!(report.server_steps, 30);
    let g1 = backend(33).grad_norm_sq(&report.model);
    assert!(g1 < g0 * 0.9, "run must still converge under rekeying: {g0} -> {g1}");

    // every worker was downshifted to the ladder bottom in one Rekey,
    // and both sides agree on the final codec
    for r in &worker_reports {
        assert_eq!(r.rekeys, 1, "worker {}", r.worker_id);
        assert_eq!(r.codec, "qsgd:2", "worker {}", r.worker_id);
    }
    let hinted = report
        .worker_stats
        .iter()
        .find(|w| w.bandwidth_hint == Some(0.25))
        .expect("the announced bandwidth hint must reach the leader's stats");
    assert_eq!(hinted.rekeys, 1);

    // per-epoch accounting stays exact across the switch, including
    // whatever old-codec uploads were in flight when the Rekey landed
    for ws in &report.worker_stats {
        assert_eq!(ws.rekeys, 1, "worker {}", ws.worker_id);
        assert_eq!(ws.codec, "qsgd:2");
        assert_eq!(ws.epochs.len(), 2);
        assert_eq!(ws.epochs[0].codec, "qsgd:8");
        assert_eq!(ws.epochs[1].codec, "qsgd:2");
        let mut ep_uploads = 0u64;
        let mut ep_bytes = 0u64;
        for ep in &ws.epochs {
            let per = parse_spec(&ep.codec).unwrap().expected_bytes(D) as u64;
            assert_eq!(
                ep.upload_bytes,
                ep.uploads * per,
                "worker {} epoch '{}' byte accounting",
                ws.worker_id,
                ep.codec
            );
            ep_uploads += ep.uploads;
            ep_bytes += ep.upload_bytes;
        }
        assert_eq!(ep_uploads, ws.uploads, "worker {}", ws.worker_id);
        assert_eq!(ep_bytes, ws.upload_bytes, "worker {}", ws.worker_id);
    }
    let total_bytes: u64 = report.worker_stats.iter().map(|w| w.upload_bytes).sum();
    assert_eq!(total_bytes, report.comm.upload_bytes);

    // the journal records one Rekey per worker and replays bit-exactly
    let events = report.events.expect("record_events was set");
    let rekey_events =
        events.iter().filter(|ev| matches!(ev, Event::Rekey { .. })).count() as u64;
    assert_eq!(rekey_events, report.worker_stats.iter().map(|w| w.rekeys).sum::<u64>());
    let replay = replay_events(&events).unwrap();
    assert_eq!(replay.steps, 30);
    assert_eq!(replay.uploads, report.comm.uploads);
    assert!(replay.finalized);
}

#[test]
fn unknown_tier_is_rejected_loudly() {
    let cfg = mixed_cfg(); // knows only tier "phone"
    let x0 = vec![0.0f32; 8];
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let leader = std::thread::spawn(move || Leader::new(cfg, x0, 1).run_on(listener, 1));

    let client = std::thread::spawn(move || {
        let mut sock = TcpStream::connect(&addr).unwrap();
        write_frame(
            &mut sock,
            &Message::Hello {
                version: 2,
                tier: Some("nosuch".into()),
                quant_client: None,
                bandwidth_hint: None,
            }
            .encode(),
        );
        let mut rest = Vec::new();
        let _ = sock.read_to_end(&mut rest);
    });

    let err = leader.join().unwrap().unwrap_err().to_string();
    assert!(err.contains("unknown tier 'nosuch'"), "{err}");
    assert!(err.contains("phone"), "should list known tiers: {err}");
    client.join().unwrap();
}
