//! Scenario-engine integration tests:
//!
//! * **golden back-compat** — the desugared default scenario reproduces
//!   the pre-scenario simulator bit-for-bit. The reference implementation
//!   below is a line-by-line replay of the old `SimEngine::run_traced`
//!   (constant/Poisson arrivals from the half-normal-calibrated rate,
//!   per-arrival Arc snapshots, shared duration stream);
//! * **determinism** — two runs with the same (cfg, seed) produce
//!   byte-identical `RunResult` curves, across `fl.shards ∈ {1, 4}` and
//!   both a default and a heterogeneous scenario (extends the
//!   `tests/sharding.rs` pattern to whole simulations);
//! * **rate calibration** — measured mean concurrency tracks
//!   `sim.concurrency` for all three duration distributions (regression
//!   for the old engine deriving the rate from a hard-coded half-normal
//!   even under lognormal/fixed durations).

use qafel::config::{Algorithm, Config, TierConfig};
use qafel::coordinator::{ClientLogic, Server, ServerStep};
use qafel::metrics::{CommMetrics, CurvePoint};
use qafel::quant::parse_spec;
use qafel::runtime::{Backend, QuadraticBackend};
use qafel::scenario::build_arrival;
use qafel::sim::SimEngine;
use qafel::util::dist::{DurationDist, Exponential, HalfNormal, LogNormal};
use qafel::util::prng::Prng;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::Arc;

/// Exact byte serialization of a curve (f64 bit patterns, not display
/// rounding) — "byte-for-byte" comparisons go through this.
fn curve_bytes(curve: &[CurvePoint]) -> Vec<u8> {
    let mut out = Vec::new();
    for p in curve {
        out.extend(p.time.to_bits().to_le_bytes());
        out.extend(p.server_steps.to_le_bytes());
        out.extend(p.uploads.to_le_bytes());
        out.extend(p.upload_mb.to_bits().to_le_bytes());
        out.extend(p.broadcast_mb.to_bits().to_le_bytes());
        out.extend(p.val_loss.to_bits().to_le_bytes());
        out.extend(p.val_accuracy.to_bits().to_le_bytes());
        match p.grad_norm_sq {
            Some(g) => {
                out.push(1);
                out.extend(g.to_bits().to_le_bytes());
            }
            None => out.push(0),
        }
    }
    out
}

fn assert_comm_eq(a: &CommMetrics, b: &CommMetrics, what: &str) {
    assert_eq!(a.uploads, b.uploads, "{what}: uploads");
    assert_eq!(a.upload_bytes, b.upload_bytes, "{what}: upload bytes");
    assert_eq!(a.broadcasts, b.broadcasts, "{what}: broadcasts");
    assert_eq!(a.broadcast_bytes, b.broadcast_bytes, "{what}: broadcast bytes");
}

// ---------------------------------------------------------------------------
// Reference: the pre-scenario engine, replayed verbatim
// ---------------------------------------------------------------------------

enum RefKind {
    Arrival,
    Finish { user: usize, snapshot: Arc<Vec<f32>>, t_start: u64, trip: u64 },
}

struct RefEvent {
    time: f64,
    seq: u64,
    kind: RefKind,
}

impl PartialEq for RefEvent {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for RefEvent {}
impl PartialOrd for RefEvent {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for RefEvent {
    fn cmp(&self, other: &Self) -> Ordering {
        other.time.total_cmp(&self.time).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The pre-scenario `SimEngine::run_traced` with default `SimOptions`,
/// including its rate derivation from `HalfNormal::rate_for_concurrency`
/// (correct only for the half-normal default — which is exactly the
/// regime the golden test pins down).
fn prerefactor_run(
    cfg: &Config,
    backend: &dyn Backend,
    seed: u64,
) -> (Vec<CurvePoint>, CommMetrics, u64) {
    let root = Prng::new(seed);
    let mut arrival_rng = root.stream("arrivals");
    let mut duration_rng = root.stream("durations");
    let mut sampling_rng = root.stream("client-sampling");
    let mut duration_dist = match cfg.sim.duration.as_str() {
        "halfnormal" => DurationDist::HalfNormal(HalfNormal::new(cfg.sim.duration_sigma)),
        "lognormal" => DurationDist::LogNormal(LogNormal::new(0.0, cfg.sim.duration_sigma)),
        "fixed" => DurationDist::Fixed(cfg.sim.duration_sigma),
        other => panic!("unknown duration dist '{other}'"),
    };

    let rate = HalfNormal::new(cfg.sim.duration_sigma)
        .rate_for_concurrency(cfg.sim.concurrency as f64)
        .max(cfg.sim.concurrency as f64 / duration_dist.mean().max(1e-9) * 1e-6);
    let constant_gap = 1.0 / rate;
    let poisson = Exponential::new(rate);
    let use_poisson = cfg.sim.arrival == "poisson";

    let x0 = backend.init_params(seed as i32 & 0x7FFF_FFFF).unwrap();
    let mut server = {
        let mut s = root.stream("server");
        Server::build(cfg, x0, s.next_u64()).unwrap()
    };
    let logic = {
        let mut s = root.stream("client");
        ClientLogic::new(cfg, s.next_u64()).unwrap()
    };

    let mut events: BinaryHeap<RefEvent> = BinaryHeap::new();
    let mut seq = 0u64;
    let mut push = |events: &mut BinaryHeap<RefEvent>, time: f64, kind: RefKind| {
        let s = seq;
        seq += 1;
        events.push(RefEvent { time, seq: s, kind });
    };
    push(&mut events, 0.0, RefKind::Arrival);

    let mut trips = 0u64;
    let mut curve: Vec<CurvePoint> = Vec::new();
    let mut last_eval_t = 0u64;
    let n_users = backend.num_train_users();

    let ev0 = backend.evaluate(server.model()).unwrap();
    curve.push(CurvePoint {
        time: 0.0,
        server_steps: 0,
        uploads: 0,
        upload_mb: 0.0,
        broadcast_mb: 0.0,
        val_loss: ev0.loss,
        val_accuracy: ev0.accuracy,
        grad_norm_sq: ev0.grad_norm_sq,
    });

    let mut clock = 0.0f64;
    while let Some(ev) = events.pop() {
        clock = ev.time;
        match ev.kind {
            RefKind::Arrival => {
                let user = sampling_rng.range(0, n_users);
                let dur = duration_dist.sample(&mut duration_rng).max(1e-9);
                let trip = trips;
                trips += 1;
                push(
                    &mut events,
                    clock + dur,
                    RefKind::Finish {
                        user,
                        snapshot: server.client_snapshot(),
                        t_start: server.t(),
                        trip,
                    },
                );
                let gap =
                    if use_poisson { poisson.sample(&mut arrival_rng) } else { constant_gap };
                push(&mut events, clock + gap, RefKind::Arrival);
            }
            RefKind::Finish { user, snapshot, t_start, trip } => {
                let upload = logic.run_round(backend, &snapshot, user, trip).unwrap();
                drop(snapshot);
                let staleness = server.t() - t_start;
                let stepped = matches!(
                    server.ingest(&upload.msg, staleness).unwrap(),
                    ServerStep::Stepped(_)
                );
                if stepped && server.t() - last_eval_t >= cfg.sim.eval_every as u64 {
                    last_eval_t = server.t();
                    let e = backend.evaluate(server.model()).unwrap();
                    let point = CurvePoint {
                        time: clock,
                        server_steps: server.t(),
                        uploads: server.comm.uploads,
                        upload_mb: server.comm.upload_mb(),
                        broadcast_mb: server.comm.broadcast_mb(),
                        val_loss: e.loss,
                        val_accuracy: e.accuracy,
                        grad_norm_sq: e.grad_norm_sq,
                    };
                    curve.push(point);
                    if point.val_accuracy >= cfg.stop.target_accuracy {
                        break; // default SimOptions: stop at target
                    }
                }
                if server.comm.uploads >= cfg.stop.max_uploads
                    || server.t() >= cfg.stop.max_server_steps
                {
                    break;
                }
            }
        }
    }
    (curve, server.comm.clone(), server.t())
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

fn quad_cfg(algorithm: Algorithm) -> Config {
    let mut c = Config::default();
    c.fl.algorithm = algorithm;
    c.fl.buffer_size = 4;
    c.fl.client_lr = 0.15;
    c.fl.server_lr = 1.0;
    c.fl.server_momentum = 0.0;
    c.fl.clip_norm = 0.0;
    c.quant.client = "qsgd:8".into();
    c.quant.server = "qsgd:8".into();
    c.sim.concurrency = 20;
    c.sim.eval_every = 10;
    c.stop.target_accuracy = 0.99;
    c.stop.max_uploads = 6000;
    c.stop.max_server_steps = 400;
    c
}

fn backend(seed: u64) -> QuadraticBackend {
    QuadraticBackend::new(24, 10, 1.0, 0.3, 0.3, 0.02, 2, seed)
}

#[test]
fn golden_default_scenario_is_bit_identical_to_prerefactor_engine() {
    // (algorithm, arrival, achievable target?) — poisson exercises the
    // arrivals stream, the 2.0 target exercises the fixed-horizon path.
    let cases = [
        (Algorithm::Qafel, "constant", 0.99, 7u64),
        (Algorithm::FedBuff, "poisson", 2.0, 3u64),
        (Algorithm::DirectQuant, "constant", 2.0, 5u64),
    ];
    for (algo, arrival, target, seed) in cases {
        let mut cfg = quad_cfg(algo);
        cfg.sim.arrival = arrival.into();
        cfg.stop.target_accuracy = target;
        let b = backend(11);
        let (ref_curve, ref_comm, ref_steps) = prerefactor_run(&cfg, &b, seed);
        let new = SimEngine::new(&cfg, &b, seed).run().unwrap();
        let what = format!("{algo:?}/{arrival}");
        assert_eq!(ref_curve.len(), new.curve.len(), "{what}: curve length");
        assert_eq!(
            curve_bytes(&ref_curve),
            curve_bytes(&new.curve),
            "{what}: curve bytes diverged"
        );
        assert_comm_eq(&ref_comm, &new.comm, &what);
        assert_eq!(ref_steps, new.server_steps, "{what}: server steps");
        assert!(ref_curve.len() > 2, "{what}: trivial run proves nothing");
    }
}

fn hetero_cfg() -> Config {
    let mut c = quad_cfg(Algorithm::Qafel);
    c.stop.target_accuracy = 2.0;
    c.stop.max_server_steps = 120;
    c.scenario.arrival = Some("bursty".into());
    c.scenario.burst_factor = 5.0;
    c.scenario.burst_on = 1.0;
    c.scenario.burst_off = 3.0;
    let mut fast = TierConfig::named("fast");
    fast.weight = 0.4;
    fast.duration_sigma = 0.5;
    fast.upload_mbps = 10.0;
    fast.download_mbps = 40.0;
    let mut slow = TierConfig::named("slow");
    slow.weight = 0.6;
    slow.duration = "lognormal".into();
    slow.dropout = 0.2;
    slow.day_period = 6.0;
    slow.on_fraction = 0.7;
    slow.upload_mbps = 2.0;
    slow.download_mbps = 8.0;
    c.scenario.tiers = vec![fast, slow];
    c
}

#[test]
fn same_seed_same_curve_across_shards_and_scenarios() {
    for (name, cfg0) in [
        ("default", {
            let mut c = quad_cfg(Algorithm::Qafel);
            c.stop.target_accuracy = 2.0;
            c.stop.max_server_steps = 120;
            c
        }),
        ("heterogeneous", hetero_cfg()),
    ] {
        cfg0.validate().unwrap();
        let b = backend(17);
        let mut per_shard: Vec<Vec<u8>> = Vec::new();
        for shards in [1usize, 4] {
            let mut cfg = cfg0.clone();
            cfg.fl.shards = shards;
            let r1 = SimEngine::new(&cfg, &b, 21).run().unwrap();
            let r2 = SimEngine::new(&cfg, &b, 21).run().unwrap();
            let what = format!("{name} S={shards}");
            assert_eq!(
                curve_bytes(&r1.curve),
                curve_bytes(&r2.curve),
                "{what}: repeat run diverged"
            );
            assert_comm_eq(&r1.comm, &r2.comm, &what);
            assert_eq!(r1.scenario, r2.scenario, "{what}: scenario metrics diverged");
            assert!(r1.comm.uploads > 0, "{what}: empty run");
            per_shard.push(curve_bytes(&r1.curve));
        }
        // the sharded pipeline's bit-identical contract extends to whole
        // simulated trajectories
        assert_eq!(per_shard[0], per_shard[1], "{name}: S=1 vs S=4 diverged");
    }
}

#[test]
fn mean_concurrency_tracks_target_for_every_duration_dist() {
    // regression: the old engine derived the arrival rate from a
    // half-normal regardless of sim.duration, overshooting lognormal
    // concurrency by ~2x (E[lognormal(0,1)] = 1.65 vs E[|N(0,1)|] = 0.80).
    for dist in ["halfnormal", "lognormal", "fixed"] {
        let mut c = Config::default();
        c.fl.algorithm = Algorithm::FedBuff;
        c.fl.buffer_size = 4;
        c.fl.client_lr = 0.05;
        c.fl.clip_norm = 0.0;
        c.sim.concurrency = 40;
        c.sim.duration = dist.into();
        c.sim.duration_sigma = 1.0;
        c.sim.eval_every = 500;
        c.stop.target_accuracy = 2.0;
        c.stop.max_uploads = 12_000;
        c.stop.max_server_steps = 1_000_000;
        let b = QuadraticBackend::new(16, 8, 1.0, 0.3, 0.2, 0.02, 1, 3);
        let r = SimEngine::new(&c, &b, 4).run().unwrap();
        let measured = r.scenario.mean_concurrency;
        assert!(
            (measured - 40.0).abs() / 40.0 < 0.15,
            "{dist}: measured mean concurrency {measured}, target 40"
        );
    }
}

#[test]
fn diurnal_windows_keep_calibrated_concurrency() {
    // Two counter-phased half-populations, each available half the
    // time. The arrival rate compensates for window-gated arrivals
    // (availability-weighted Little's law), so the achieved mean
    // concurrency still tracks sim.concurrency — a window-blind rate
    // would land at ~50% of target.
    let mut c = Config::default();
    c.fl.algorithm = Algorithm::FedBuff;
    c.fl.buffer_size = 4;
    c.fl.client_lr = 0.05;
    c.fl.clip_norm = 0.0;
    c.sim.concurrency = 40;
    c.sim.eval_every = 500;
    c.stop.target_accuracy = 2.0;
    c.stop.max_uploads = 12_000;
    c.stop.max_server_steps = 1_000_000;
    let mut day = TierConfig::named("day");
    day.weight = 0.5;
    day.day_period = 8.0;
    day.on_fraction = 0.5;
    let mut night = TierConfig::named("night");
    night.weight = 0.5;
    night.day_period = 8.0;
    night.on_fraction = 0.5;
    night.phase = 4.0;
    c.scenario.tiers = vec![day, night];
    c.validate().unwrap();
    let b = QuadraticBackend::new(16, 8, 1.0, 0.3, 0.2, 0.02, 1, 3);
    let r = SimEngine::new(&c, &b, 5).run().unwrap();
    let measured = r.scenario.mean_concurrency;
    assert!(
        (measured - 40.0).abs() / 40.0 < 0.15,
        "diurnal: measured mean concurrency {measured}, target 40"
    );
    // both tiers saw gated arrivals
    assert!(r.scenario.tiers.iter().all(|t| t.unavailable > 0));
}

// ---------------------------------------------------------------------------
// Reference: the PR 3 (pre-v2) heterogeneous engine, replayed verbatim
// ---------------------------------------------------------------------------

/// The scenario-v1 `SimEngine::run_traced` for tiered populations,
/// replayed line by line with the tier model reimplemented locally
/// (weighted tier draw, persistent per-tier duration samplers,
/// deterministic diurnal windows, exact-wire-size transfer delays,
/// availability-weighted Little's-law calibration, single client codec,
/// all-or-nothing dropout). Pins the v2 engine's no-preset path:
/// without `quant_client` / `partial_work` / `sampling=availability`
/// the refactor must be byte-identical.
fn pr3_hetero_run(
    cfg: &Config,
    backend: &dyn Backend,
    seed: u64,
) -> (Vec<CurvePoint>, CommMetrics, u64) {
    struct RefTier {
        cfg: TierConfig,
        dist: DurationDist,
    }
    let bytes_delay = |bytes: usize, mbps: f64| -> f64 {
        if mbps > 0.0 {
            bytes as f64 * 8.0 / (mbps * 1e6)
        } else {
            0.0
        }
    };

    let root = Prng::new(seed);
    let mut arrival_rng = root.stream("arrivals");
    let mut duration_rng = root.stream("durations");
    let mut sampling_rng = root.stream("client-sampling");
    let mut tier_rng = root.stream("scenario-tier");
    let mut dropout_rng = root.stream("scenario-dropout");

    let mut tiers: Vec<RefTier> = cfg
        .resolved_tiers()
        .into_iter()
        .map(|tc| {
            let dist = match tc.duration.as_str() {
                "halfnormal" => DurationDist::HalfNormal(HalfNormal::new(tc.duration_sigma)),
                "lognormal" => DurationDist::LogNormal(LogNormal::new(0.0, tc.duration_sigma)),
                "fixed" => DurationDist::Fixed(tc.duration_sigma),
                other => panic!("unknown duration dist '{other}'"),
            };
            RefTier { cfg: tc, dist }
        })
        .collect();
    let mut cum = Vec::new();
    let mut total_weight = 0.0;
    for t in &tiers {
        total_weight += t.cfg.weight;
        cum.push(total_weight);
    }

    let x0 = backend.init_params(seed as i32 & 0x7FFF_FFFF).unwrap();
    let mut server = {
        let mut s = root.stream("server");
        Server::build(cfg, x0, s.next_u64()).unwrap()
    };
    let logic = {
        let mut s = root.stream("client");
        ClientLogic::new(cfg, s.next_u64()).unwrap()
    };
    let d = server.d();
    let eval_pool = server.pool().clone();

    let upload_bytes = logic.upload_bytes(d);
    let download_spec = match cfg.fl.algorithm {
        Algorithm::Qafel | Algorithm::DirectQuant => cfg.quant.server.as_str(),
        Algorithm::FedBuff | Algorithm::FedAsync => "none",
    };
    let download_bytes = parse_spec(download_spec).unwrap().expected_bytes(d);

    // PR 3 rate calibration: availability-weighted expected residency
    let weighted: f64 = tiers
        .iter()
        .map(|t| {
            let c = &t.cfg;
            let avail = if c.day_period > 0.0 { c.on_fraction } else { 1.0 };
            let residency = t.dist.mean()
                + bytes_delay(download_bytes, c.download_mbps)
                + (1.0 - c.dropout) * bytes_delay(upload_bytes, c.upload_mbps);
            c.weight * avail * residency
        })
        .sum();
    let rate = cfg.sim.concurrency as f64 / (weighted / total_weight);
    let mut arrival = build_arrival(
        cfg.resolved_arrival(),
        rate,
        cfg.scenario.burst_factor,
        cfg.scenario.burst_on,
        cfg.scenario.burst_off,
    )
    .unwrap();

    let available = |t: &TierConfig, clock: f64| -> bool {
        if t.day_period <= 0.0 {
            return true;
        }
        ((clock + t.phase) % t.day_period) / t.day_period < t.on_fraction
    };

    enum K {
        Arrival,
        Finish {
            user: usize,
            tier: usize,
            snapshot: Arc<Vec<f32>>,
            t_start: u64,
            trip: u64,
            dropped: bool,
        },
    }
    struct Ev {
        time: f64,
        seq: u64,
        kind: K,
    }
    impl PartialEq for Ev {
        fn eq(&self, other: &Self) -> bool {
            self.cmp(other) == Ordering::Equal
        }
    }
    impl Eq for Ev {}
    impl PartialOrd for Ev {
        fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for Ev {
        fn cmp(&self, other: &Self) -> Ordering {
            other.time.total_cmp(&self.time).then_with(|| other.seq.cmp(&self.seq))
        }
    }

    let mut events: BinaryHeap<Ev> = BinaryHeap::new();
    let mut seq = 0u64;
    let mut push = |events: &mut BinaryHeap<Ev>, time: f64, kind: K| {
        let s = seq;
        seq += 1;
        events.push(Ev { time, seq: s, kind });
    };
    push(&mut events, 0.0, K::Arrival);

    let mut trips = 0u64;
    let mut curve: Vec<CurvePoint> = Vec::new();
    let mut last_eval_t = 0u64;
    let n_users = backend.num_train_users();

    let ev0 = backend.evaluate_pooled(server.model(), &eval_pool).unwrap();
    curve.push(CurvePoint {
        time: 0.0,
        server_steps: 0,
        uploads: 0,
        upload_mb: 0.0,
        broadcast_mb: 0.0,
        val_loss: ev0.loss,
        val_accuracy: ev0.accuracy,
        grad_norm_sq: ev0.grad_norm_sq,
    });

    let mut clock = 0.0f64;
    while let Some(ev) = events.pop() {
        clock = ev.time;
        match ev.kind {
            K::Arrival => {
                let tier = if tiers.len() == 1 {
                    0
                } else {
                    let x = tier_rng.f64() * total_weight;
                    cum.iter().position(|&c| x < c).unwrap_or(tiers.len() - 1)
                };
                if available(&tiers[tier].cfg, clock) {
                    let user = sampling_rng.range(0, n_users);
                    let dur = tiers[tier].dist.sample(&mut duration_rng).max(1e-9);
                    let p = tiers[tier].cfg.dropout;
                    let dropped = p > 0.0 && dropout_rng.bool(p);
                    let trip = trips;
                    trips += 1;
                    let c = &tiers[tier].cfg;
                    let mut delay = bytes_delay(download_bytes, c.download_mbps);
                    if !dropped {
                        delay += bytes_delay(upload_bytes, c.upload_mbps);
                    }
                    push(
                        &mut events,
                        clock + dur + delay,
                        K::Finish {
                            user,
                            tier,
                            snapshot: server.client_snapshot(),
                            t_start: server.t(),
                            trip,
                            dropped,
                        },
                    );
                }
                let gap = arrival.next_gap(&mut arrival_rng);
                push(&mut events, clock + gap, K::Arrival);
            }
            K::Finish { user, tier: _, snapshot, t_start, trip, dropped } => {
                if dropped {
                    continue;
                }
                let upload = logic.run_round(backend, &snapshot, user, trip).unwrap();
                drop(snapshot);
                let staleness = server.t() - t_start;
                let stepped = matches!(
                    server.ingest(&upload.msg, staleness).unwrap(),
                    ServerStep::Stepped(_)
                );
                if stepped && server.t() - last_eval_t >= cfg.sim.eval_every as u64 {
                    last_eval_t = server.t();
                    let e = backend.evaluate_pooled(server.model(), &eval_pool).unwrap();
                    let point = CurvePoint {
                        time: clock,
                        server_steps: server.t(),
                        uploads: server.comm.uploads,
                        upload_mb: server.comm.upload_mb(),
                        broadcast_mb: server.comm.broadcast_mb(),
                        val_loss: e.loss,
                        val_accuracy: e.accuracy,
                        grad_norm_sq: e.grad_norm_sq,
                    };
                    curve.push(point);
                    if point.val_accuracy >= cfg.stop.target_accuracy {
                        break;
                    }
                }
                if server.comm.uploads >= cfg.stop.max_uploads
                    || server.t() >= cfg.stop.max_server_steps
                {
                    break;
                }
            }
        }
    }
    (curve, server.comm.clone(), server.t())
}

#[test]
fn golden_nopreset_tiers_bit_identical_to_pr3_engine() {
    // The v2 acceptance bar: with no per-tier presets configured
    // (no quant_client, partial_work = 0, sampling = weighted) the
    // refactored engine's curves and comm bytes are byte-identical to
    // the PR 3 engine — for a genuinely heterogeneous population
    // (bandwidth limits, dropout, a diurnal window, bursty arrivals).
    let cfg = hetero_cfg();
    cfg.validate().unwrap();
    assert!(cfg.scenario.tiers.iter().all(|t| t.quant_client.is_none()));
    // also pins the per-tier-downlink refactor: without quant_server
    // presets there is exactly one downlink family and the engine must
    // stay bit-identical to the single-broadcast reference below
    assert!(cfg.scenario.tiers.iter().all(|t| t.quant_server.is_none()));
    assert!(cfg.scenario.tiers.iter().all(|t| t.partial_work == 0.0));
    for seed in [21u64, 4] {
        let b = backend(17);
        let (ref_curve, ref_comm, ref_steps) = pr3_hetero_run(&cfg, &b, seed);
        let new = SimEngine::new(&cfg, &b, seed).run().unwrap();
        assert_eq!(ref_curve.len(), new.curve.len(), "seed {seed}: curve length");
        assert_eq!(
            curve_bytes(&ref_curve),
            curve_bytes(&new.curve),
            "seed {seed}: curve bytes diverged from the PR 3 engine"
        );
        assert_comm_eq(&ref_comm, &new.comm, &format!("seed {seed}"));
        assert_eq!(ref_steps, new.server_steps, "seed {seed}: server steps");
        assert!(ref_curve.len() > 2, "seed {seed}: trivial run proves nothing");
        // the population actually exercised the heterogeneous paths
        let sc = &new.scenario;
        assert!(sc.tiers[1].dropouts > 0, "no dropouts — weak golden");
        assert!(sc.tiers[1].unavailable > 0, "no off-window arrivals — weak golden");
        assert!(sc.tiers.iter().all(|t| t.partial_uploads == 0));
    }
}

#[test]
fn bursty_arrivals_sustain_target_concurrency_on_average() {
    let mut c = Config::default();
    c.fl.algorithm = Algorithm::FedBuff;
    c.fl.buffer_size = 4;
    c.fl.client_lr = 0.05;
    c.fl.clip_norm = 0.0;
    c.sim.concurrency = 40;
    c.sim.eval_every = 500;
    c.scenario.arrival = Some("bursty".into());
    c.stop.target_accuracy = 2.0;
    c.stop.max_uploads = 20_000;
    c.stop.max_server_steps = 1_000_000;
    let b = QuadraticBackend::new(16, 8, 1.0, 0.3, 0.2, 0.02, 1, 3);
    let r = SimEngine::new(&c, &b, 6).run().unwrap();
    let measured = r.scenario.mean_concurrency;
    assert!(
        (measured - 40.0).abs() / 40.0 < 0.30,
        "bursty: measured mean concurrency {measured}, target 40"
    );
}
