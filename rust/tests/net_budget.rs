//! Budgeted broadcast fan-out regression tests (ISSUE 8):
//!
//! * a consumer that stops reading its socket cannot make the leader
//!   buffer frames unboundedly: with `net.broadcast_budget_bytes` set,
//!   its writer queue evicts superseded frames (bounded memory), the
//!   step loop never stalls behind it (the live worker keeps receiving
//!   every broadcast promptly — the test would hang otherwise), and
//!   once the consumer drains again it reconverges **bit-exactly**
//!   through the per-family `UpdateLog` catch-up: replayed increments
//!   or a full-state `Sync` frame;
//! * a peer that connects and stalls mid-handshake fails alone: other
//!   workers' joins complete while it burns its own grace deadline
//!   (handshakes run on per-connection threads, not a serial accept
//!   loop).

use qafel::config::{Algorithm, Config};
use qafel::coordinator::client::HiddenReplica;
use qafel::coordinator::Broadcast;
use qafel::net::{Leader, Message};
use qafel::quant::{parse_spec, QuantizedMsg};
use qafel::util::pool::ShardPool;
use qafel::util::prng::Prng;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

/// Read one raw frame (length prefix + body), returning the body bytes.
fn read_frame(s: &mut TcpStream) -> Vec<u8> {
    let mut len = [0u8; 4];
    s.read_exact(&mut len).unwrap();
    let n = u32::from_le_bytes(len) as usize;
    let mut body = vec![0u8; n];
    s.read_exact(&mut body).unwrap();
    body
}

/// Write one raw frame around the given body bytes.
fn write_frame(s: &mut TcpStream, body: &[u8]) {
    s.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
    s.write_all(body).unwrap();
    s.flush().unwrap();
}

/// Big enough that the run's broadcast volume (~26 MiB of identity
/// frames) overflows loopback socket buffering: the stalled worker's
/// writer genuinely blocks and its queue must evict.
const D: usize = 16384;
const STEPS: u64 = 400;

fn budget_cfg() -> Config {
    let mut c = Config::default();
    c.fl.algorithm = Algorithm::Qafel;
    c.quant.client = "none".into();
    c.quant.server = "none".into();
    c.fl.buffer_size = 1;
    c.fl.client_lr = 0.05;
    c.fl.server_lr = 1.0;
    c.fl.server_momentum = 0.0;
    c.fl.clip_norm = 0.0;
    c.stop.max_server_steps = STEPS;
    c.stop.max_uploads = 1_000_000;
    c.net.v1_grace_ms = 2000;
    // room for ~3 of the ~64 KiB identity frames per writer queue
    c.net.broadcast_budget_bytes = 200_000;
    c
}

/// v2 handshake over a raw socket; returns (worker_id, x0, server_quant).
fn hello(sock: &mut TcpStream) -> (u32, Vec<f32>, String) {
    let h =
        Message::Hello { version: 2, tier: None, quant_client: None, bandwidth_hint: None };
    write_frame(sock, &h.encode());
    match Message::decode(&read_frame(sock)).unwrap() {
        Message::JoinV2 { worker_id, x0, server_quant, server_codec_id, .. } => {
            assert_eq!(server_codec_id, 0, "no tier: default downlink family");
            (worker_id, x0, server_quant)
        }
        other => panic!("expected JoinV2, got {other:?}"),
    }
}

/// Apply one wire broadcast to a client-side hidden replica.
fn apply_broadcast(rep: &mut HiddenReplica, t: u64, absolute: bool, payload: Vec<u8>) {
    let b = Broadcast {
        t,
        bytes: payload.len(),
        msg: QuantizedMsg { payload, d: D },
        absolute,
        codec: 0,
    };
    rep.apply(&b).unwrap();
}

#[test]
fn slow_consumer_bounded_queue_reconverges_via_catch_up() {
    let cfg = budget_cfg();
    let x0: Vec<f32> = (0..D).map(|i| (i as f32 * 0.001).sin()).collect();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let leader_cfg = cfg.clone();
    let leader_x0 = x0.clone();
    let leader = std::thread::spawn(move || {
        Leader::new(leader_cfg, leader_x0, 11).run_on(listener, 2).unwrap()
    });

    // worker 0 drives every step and drains promptly; worker 1 joins,
    // then never reads its socket until the run is over
    let mut fast = TcpStream::connect(&addr).unwrap();
    fast.set_nodelay(true).unwrap();
    let (fast_id, fast_x0, fast_sq) = hello(&mut fast);
    let mut slow = TcpStream::connect(&addr).unwrap();
    let (slow_id, slow_x0, slow_sq) = hello(&mut slow);
    assert_eq!(fast_sq, "none");
    assert_eq!(slow_sq, "none");

    let pool = ShardPool::new(1);
    let mut fast_rep = HiddenReplica::with_spec("none", fast_x0, pool.clone()).unwrap();
    let mut slow_rep = HiddenReplica::with_spec("none", slow_x0, pool).unwrap();

    let codec = parse_spec("none").unwrap();
    let mut rng = Prng::new(3);
    for round in 0..STEPS {
        let delta: Vec<f32> =
            (0..D).map(|i| ((i as f64 * 0.37 + round as f64).cos() * 0.1) as f32).collect();
        let msg = codec.quantize(&delta, &mut rng);
        let up = Message::update_v2_from(fast_id, fast_rep.t, round, 0.0, 0, &msg);
        write_frame(&mut fast, &up.encode());
        match Message::decode(&read_frame(&mut fast)).unwrap() {
            Message::Broadcast { t, absolute, payload } => {
                apply_broadcast(&mut fast_rep, t, absolute, payload);
            }
            other => panic!("round {round}: expected Broadcast, got {other:?}"),
        }
    }
    assert_eq!(fast_rep.t, STEPS);
    assert!(matches!(Message::decode(&read_frame(&mut fast)).unwrap(), Message::Shutdown));
    write_frame(&mut fast, &Message::Bye { worker_id: fast_id, uploads: STEPS }.encode());
    drop(fast);

    // the stalled worker wakes up after the run and drains everything:
    // whatever the budget kept, folded gaps arriving as catch-up
    // increments and/or full-state Sync frames
    let mut syncs = 0u64;
    loop {
        match Message::decode(&read_frame(&mut slow)).unwrap() {
            Message::Broadcast { t, absolute, payload } => {
                apply_broadcast(&mut slow_rep, t, absolute, payload);
            }
            Message::Sync { t, x } => {
                slow_rep.resync(t, x).unwrap();
                syncs += 1;
            }
            Message::Shutdown => break,
            other => panic!("unexpected frame for the stalled worker: {other:?}"),
        }
    }
    write_frame(&mut slow, &Message::Bye { worker_id: slow_id, uploads: 0 }.encode());
    drop(slow);

    let report = leader.join().unwrap();
    assert_eq!(report.server_steps, STEPS);

    // the stalled replica caught all the way up, bit-identical to the
    // replica that followed the live stream frame by frame
    assert_eq!(slow_rep.t, STEPS);
    assert_eq!(slow_rep.state(), fast_rep.state(), "catch-up diverged from the live stream");

    let fast_ws = &report.worker_stats[fast_id as usize];
    let slow_ws = &report.worker_stats[slow_id as usize];
    // the live worker saw every broadcast + Shutdown, nothing skipped
    assert_eq!(fast_ws.broadcast_frames, STEPS + 1);
    assert_eq!(fast_ws.skipped_broadcasts, 0);
    assert_eq!(fast_ws.catch_up_frames, 0);
    assert_eq!(fast_ws.full_syncs, 0);
    // the stalled worker's queue stayed within budget by evicting, and
    // the gap was folded instead of being replayed frame by frame. An
    // identity downlink retains a single increment (C_max = 1), so the
    // folds here must ship full-state Syncs.
    assert!(slow_ws.skipped_broadcasts > 0, "budget never evicted: {slow_ws:?}");
    assert!(slow_ws.catch_up_frames > 0, "no catch-up frames: {slow_ws:?}");
    assert!(slow_ws.full_syncs > 0, "expected at least one full-state Sync: {slow_ws:?}");
    assert_eq!(slow_ws.full_syncs, syncs, "leader accounting vs frames actually received");
    assert!(
        slow_ws.broadcast_frames < fast_ws.broadcast_frames,
        "the stalled worker should receive fewer frames: {} vs {}",
        slow_ws.broadcast_frames,
        fast_ws.broadcast_frames
    );
}

#[test]
fn stalled_handshake_does_not_block_other_joins() {
    let mut cfg = Config::default();
    cfg.fl.algorithm = Algorithm::Qafel;
    cfg.quant.client = "qsgd:8".into();
    cfg.quant.server = "qsgd:8".into();
    cfg.stop.max_server_steps = 1;
    cfg.net.v1_grace_ms = 3000;
    let x0 = vec![0.0f32; 8];
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let leader = std::thread::spawn(move || Leader::new(cfg, x0, 1).run_on(listener, 2));

    // peer A connects first and sends a partial frame, then stalls.
    // Under a serial accept loop every later join would wait out A's
    // 3 s grace; with per-connection handshake threads only A pays it.
    let mut stalled = TcpStream::connect(&addr).unwrap();
    stalled.write_all(&[7, 0]).unwrap(); // 2 bytes of a 4-byte length prefix
    stalled.flush().unwrap();
    std::thread::sleep(Duration::from_millis(100)); // A is accepted first

    // peer B is a well-behaved v2 worker; its JoinV2 must arrive while
    // A is still wedged (well inside A's grace window)
    let mut ok = TcpStream::connect(&addr).unwrap();
    let h =
        Message::Hello { version: 2, tier: None, quant_client: None, bandwidth_hint: None };
    write_frame(&mut ok, &h.encode());
    ok.set_read_timeout(Some(Duration::from_millis(1500))).unwrap();
    match Message::decode(&read_frame(&mut ok)).unwrap() {
        Message::JoinV2 { worker_id, .. } => assert_eq!(worker_id, 1),
        other => panic!("expected JoinV2, got {other:?}"),
    }

    // A's own deadline still fires: the leader aborts naming worker 0
    let err = leader.join().unwrap().unwrap_err().to_string();
    assert!(err.contains("worker 0"), "error should name the stalled peer: {err}");
    assert!(err.contains("handshake deadline"), "{err}");
    drop(stalled);
    drop(ok);
}
