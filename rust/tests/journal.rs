//! Flight-recorder acceptance tests (ISSUE 7):
//!
//! * journaling is a pure observer — the recorded run's curve is
//!   bit-identical to an unjournaled run — and the journal replays
//!   bit-identically through [`qafel::telemetry::replay_file`], for
//!   qafel *and* fedbuff, at shard counts 1 and 4;
//! * a sim run killed at step k (journal cut after an interior
//!   checkpoint, with a torn tail line) resumes to the same curve,
//!   model bits and event stream as the uninterrupted golden;
//! * a TCP leader killed the same way resumes with rejoining workers,
//!   and the stitched journal (true prefix + post-resume history)
//!   replays end-to-end.

use qafel::config::{Algorithm, Config};
use qafel::net::{Leader, Worker};
use qafel::runtime::{Backend as _, QuadraticBackend};
use qafel::sim::{SimEngine, SimOptions};
use qafel::telemetry::{replay_file, Event, JournalReader};
use std::net::TcpListener;

fn temp_journal(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("qafel_it_{tag}_{}.jsonl", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn sim_cfg(algo: Algorithm, shards: usize) -> Config {
    let mut c = Config::default();
    c.fl.algorithm = algo;
    let (qc, qs) = match algo {
        Algorithm::FedBuff => ("none", "none"),
        _ => ("qsgd:4", "qsgd:4"),
    };
    c.quant.client = qc.into();
    c.quant.server = qs.into();
    c.fl.buffer_size = 4;
    c.fl.client_lr = 0.15;
    c.fl.server_lr = 1.0;
    c.fl.server_momentum = 0.0;
    c.fl.clip_norm = 0.0;
    c.fl.shards = shards;
    c.sim.concurrency = 10;
    c.sim.eval_every = 5;
    c.seeds = vec![1];
    c.stop.target_accuracy = 2.0; // fixed horizon
    c.stop.max_server_steps = 40;
    c.stop.max_uploads = 100_000;
    c
}

fn sim_backend(seed: u64) -> QuadraticBackend {
    QuadraticBackend::new(64, 16, 1.0, 0.3, 0.2, 0.02, 2, seed)
}

/// Drop wall-clock noise before comparing event streams: checkpoints
/// carry nondeterministic state blobs (and the TCP "wall" base), and
/// `Step.stages` are span timings.
fn normalized(events: &[Event]) -> Vec<Event> {
    events
        .iter()
        .filter(|e| !matches!(e, Event::Checkpoint { .. }))
        .cloned()
        .map(|mut e| {
            if let Event::Step { stages, .. } = &mut e {
                *stages = None;
            }
            e
        })
        .collect()
}

/// Rewrite `path` to the event prefix `events[..keep]` plus a torn
/// half-line, simulating a kill mid-write at that point of the run.
fn kill_journal_at(path: &str, events: &[Event], keep: usize) {
    let mut text = String::new();
    for ev in &events[..keep] {
        text.push_str(&ev.to_line());
        text.push('\n');
    }
    text.push_str("{\"ev\":\"step\",\"time\":12.");
    std::fs::write(path, text).unwrap();
}

#[test]
fn journal_is_a_pure_observer_and_replays_across_algorithms_and_shards() {
    for algo in [Algorithm::Qafel, Algorithm::FedBuff] {
        for shards in [1usize, 4] {
            let c = sim_cfg(algo, shards);
            let b = sim_backend(5);
            let plain = SimEngine::new(&c, &b, 5).run().unwrap();

            let path = temp_journal(&format!("replay_{}_{shards}", algo.name()));
            let mut cj = c.clone();
            cj.telemetry.journal = Some(path.clone());
            let journaled = SimEngine::new(&cj, &b, 5).run().unwrap();

            // observer: identical curve bits with and without the recorder
            assert_eq!(plain.curve.len(), journaled.curve.len());
            for (p, q) in plain.curve.iter().zip(&journaled.curve) {
                assert_eq!(p.time.to_bits(), q.time.to_bits());
                assert_eq!(p.val_loss.to_bits(), q.val_loss.to_bits());
                assert_eq!(p.val_accuracy.to_bits(), q.val_accuracy.to_bits());
                assert_eq!(p.uploads, q.uploads);
            }
            assert_eq!(plain.fingerprint, journaled.fingerprint);

            // the journal replays bit-identically (every broadcast payload
            // and the final model are verified inside replay_file)
            let report = replay_file(&path).unwrap();
            assert!(report.finalized, "{algo:?} S={shards}");
            assert_eq!(report.steps, journaled.server_steps);
            assert_eq!(report.uploads, journaled.comm.uploads);
            assert_eq!(report.broadcasts_checked, journaled.comm.broadcasts);
            std::fs::remove_file(&path).unwrap();
        }
    }
}

#[test]
fn killed_sim_run_resumes_to_the_uninterrupted_golden() {
    let mut c = sim_cfg(Algorithm::Qafel, 1);
    let path = temp_journal("sim_resume");
    c.telemetry.journal = Some(path.clone());
    c.telemetry.checkpoint_every = 10;
    let b = sim_backend(9);
    let golden = SimEngine::new(&c, &b, 9).run().unwrap();
    let golden_events = JournalReader::read(&path).unwrap();

    // kill at step ~20: cut after the step-20 checkpoint, keep a couple
    // of doomed post-checkpoint events and a torn tail
    let cut = golden_events
        .iter()
        .position(|e| matches!(e, Event::Checkpoint { step, .. } if *step == 20))
        .expect("no checkpoint at step 20");
    kill_journal_at(&path, &golden_events, (cut + 3).min(golden_events.len()));

    let opts = SimOptions { resume: true, ..Default::default() };
    let resumed = SimEngine::new(&c, &b, 9).run_with(&opts).unwrap();

    // same curve, bit for bit
    assert_eq!(golden.curve.len(), resumed.curve.len());
    for (p, q) in golden.curve.iter().zip(&resumed.curve) {
        assert_eq!(p.time.to_bits(), q.time.to_bits());
        assert_eq!(p.val_loss.to_bits(), q.val_loss.to_bits());
        assert_eq!(p.val_accuracy.to_bits(), q.val_accuracy.to_bits());
        assert_eq!(p.uploads, q.uploads);
    }
    assert_eq!(golden.server_steps, resumed.server_steps);
    assert_eq!(golden.comm.uploads, resumed.comm.uploads);
    assert_eq!(golden.comm.upload_bytes, resumed.comm.upload_bytes);
    assert_eq!(golden.comm.broadcast_bytes, resumed.comm.broadcast_bytes);

    // same journal modulo checkpoints and span timings — including the
    // Final event, i.e. the resumed model is bit-identical
    let resumed_events = JournalReader::read(&path).unwrap();
    assert_eq!(normalized(&golden_events), normalized(&resumed_events));

    // and the stitched journal still replays end to end
    let report = replay_file(&path).unwrap();
    assert!(report.finalized);
    assert_eq!(report.steps, golden.server_steps);
    std::fs::remove_file(&path).unwrap();
}

const D: usize = 64;

fn tcp_backend(seed: u64) -> QuadraticBackend {
    QuadraticBackend::new(D, 8, 1.0, 0.3, 0.2, 0.02, 1, seed)
}

fn tcp_cfg() -> Config {
    let mut c = Config::default();
    c.fl.algorithm = Algorithm::Qafel;
    c.quant.client = "qsgd:8".into();
    c.quant.server = "qsgd:4".into();
    c.fl.buffer_size = 3;
    c.fl.client_lr = 0.05;
    c.fl.server_lr = 1.0;
    c.fl.server_momentum = 0.0;
    c.fl.clip_norm = 0.0;
    c.stop.max_server_steps = 24;
    c.stop.max_uploads = 100_000;
    c
}

/// One leader run over loopback with two workers; returns the report.
fn tcp_run(cfg: Config, resume: bool) -> qafel::net::LeaderReport {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let x0 = tcp_backend(21).init_params(0).unwrap();
    let leader = std::thread::spawn(move || {
        let mut l = Leader::new(cfg, x0, 7);
        l.resume = resume;
        l.run_on(listener, 2).unwrap()
    });
    let workers: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut w = Worker::new(tcp_backend(21));
                w.round_delay = std::time::Duration::from_millis(1);
                w.run(&addr).unwrap()
            })
        })
        .collect();
    let report = leader.join().unwrap();
    for w in workers {
        w.join().unwrap();
    }
    report
}

#[test]
fn killed_tcp_leader_resumes_and_the_stitched_journal_replays() {
    let mut cfg = tcp_cfg();
    let path = temp_journal("tcp_resume");
    cfg.telemetry.journal = Some(path.clone());
    cfg.telemetry.checkpoint_every = 6;

    let golden = tcp_run(cfg.clone(), false);
    assert_eq!(golden.server_steps, 24);
    let golden_events = JournalReader::read(&path).unwrap();

    // kill after the step-12 checkpoint (plus a torn tail); the second
    // leader restores t=12 and fresh workers rejoin mid-run
    let cut = golden_events
        .iter()
        .position(|e| matches!(e, Event::Checkpoint { step, .. } if *step == 12))
        .expect("no checkpoint at step 12");
    kill_journal_at(&path, &golden_events, (cut + 3).min(golden_events.len()));

    let resumed = tcp_run(cfg.clone(), true);
    assert_eq!(resumed.server_steps, 24);
    assert_eq!(resumed.fingerprint, golden.fingerprint);

    // the stitched journal is true history: the 12-step prefix plus the
    // re-run — every broadcast of both halves verifies bit-for-bit, and
    // the Final model matches what the resumed leader reports
    let report = replay_file(&path).unwrap();
    assert!(report.finalized);
    assert_eq!(report.steps, 24);
    assert_eq!(report.broadcasts_checked, 24);
    let events = JournalReader::read(&path).unwrap();
    match events.last().unwrap() {
        Event::Final { model, .. } => {
            assert_eq!(model.len(), D);
            for (a, b) in model.iter().zip(&resumed.model) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        other => panic!("journal does not end in Final: {other:?}"),
    }
    std::fs::remove_file(&path).unwrap();
}
