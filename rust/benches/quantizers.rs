//! L3 hot-path microbenchmarks: quantizer encode/decode at the paper's
//! model dimension (d = 29,474). The coordinator executes one `quantize`
//! per upload (client side), one `accumulate` per upload (server buffer),
//! and one `quantize` per broadcast — these ops must stay far below the
//! PJRT client_update cost (~tens of ms) to keep L3 off the critical
//! path.

mod common;

use common::{bench, bench_throughput};
use qafel::quant::parse_spec;
use qafel::util::prng::Prng;
use std::hint::black_box;

fn main() {
    let d = 29_474;
    let mut rng = Prng::new(1);
    let x: Vec<f32> = (0..d).map(|_| rng.f32() * 2.0 - 1.0).collect();
    println!("== quantizer codecs at d = {d} (paper model) ==");

    for spec in ["none", "qsgd:8", "qsgd:4", "qsgd:2", "top:0.1", "rand:0.1"] {
        let q = parse_spec(spec).unwrap();
        let bytes = q.expected_bytes(d);

        let mut qrng = Prng::new(2);
        bench_throughput(&format!("quantize   {spec} ({bytes} B)"), 300, d * 4, || {
            black_box(q.quantize(black_box(&x), &mut qrng));
        });

        let msg = q.quantize(&x, &mut qrng);
        let mut acc = vec![0.0f32; d];
        bench_throughput(&format!("accumulate {spec}"), 300, d * 4, || {
            q.accumulate(black_box(&msg), 0.1, black_box(&mut acc)).unwrap();
        });
    }

    println!("\n== supporting vector kernels ==");
    let y: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
    let mut acc = vec![0.0f32; d];
    bench_throughput("vecf::axpy", 2000, d * 4, || {
        qafel::util::vecf::axpy(black_box(&mut acc), 0.5, black_box(&y));
    });
    bench_throughput("vecf::norm2", 2000, d * 4, || {
        black_box(qafel::util::vecf::norm2(black_box(&y)));
    });
    let mut u = vec![0.0f32; d];
    bench_throughput("prng fill_uniform_f32", 1000, d * 4, || {
        let mut r = Prng::new(3);
        r.fill_uniform_f32(black_box(&mut u));
    });

    println!("\n== server ingest path (dequantize+axpy, qsgd:4) ==");
    let q = parse_spec("qsgd:4").unwrap();
    let msg = q.quantize(&x, &mut rng);
    let mut buffer = vec![0.0f32; d];
    bench("server ingest (1 upload)", 1000, || {
        q.accumulate(black_box(&msg), 0.316, black_box(&mut buffer)).unwrap();
    });
}
