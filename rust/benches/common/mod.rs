//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `bench(name, iters, f)` warms up, measures `iters` timed runs, and
//! prints mean / p50 / p95 per-iteration times plus derived throughput.
//! Set `QAFEL_BENCH_FAST=1` to cut iteration counts (used by CI smoke).

// Each bench target compiles its own copy of this module and uses a
// different subset of the helpers.
#![allow(dead_code)]

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

pub fn fast_mode() -> bool {
    std::env::var("QAFEL_BENCH_FAST").is_ok()
}

pub fn scaled(iters: usize) -> usize {
    if fast_mode() {
        (iters / 10).max(3)
    } else {
        iters
    }
}

/// Run and report one benchmark. `f` is called once per iteration; use
/// `std::hint::black_box` inside to defeat DCE.
pub fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> BenchResult {
    let iters = scaled(iters);
    // warmup ~10%
    for _ in 0..(iters / 10).max(1) {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p50 = samples[samples.len() / 2];
    let p95 = samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)];
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: p50,
        p95_ns: p95,
    };
    print_result(&r, None);
    r
}

/// Like [`bench`] but also reports bytes/second given per-iter bytes.
pub fn bench_throughput<F: FnMut()>(name: &str, iters: usize, bytes_per_iter: usize, f: F) {
    let r = bench_quiet(name, iters, f);
    print_result(&r, Some(bytes_per_iter));
}

pub fn bench_quiet<F: FnMut()>(name: &str, iters: usize, mut f: F) -> BenchResult {
    let iters = scaled(iters);
    for _ in 0..(iters / 10).max(1) {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
        p50_ns: samples[samples.len() / 2],
        p95_ns: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
    }
}

fn print_result(r: &BenchResult, bytes: Option<usize>) {
    let human = |ns: f64| -> String {
        if ns < 1e3 {
            format!("{ns:.0} ns")
        } else if ns < 1e6 {
            format!("{:.2} us", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.2} s", ns / 1e9)
        }
    };
    match bytes {
        Some(b) => {
            let gbs = b as f64 / r.mean_ns; // bytes/ns == GB/s
            println!(
                "{:<44} {:>10}/iter  p50 {:>10}  p95 {:>10}  {:>7.2} GB/s",
                r.name,
                human(r.mean_ns),
                human(r.p50_ns),
                human(r.p95_ns),
                gbs
            );
        }
        None => println!(
            "{:<44} {:>10}/iter  p50 {:>10}  p95 {:>10}   ({} iters)",
            r.name,
            human(r.mean_ns),
            human(r.p50_ns),
            human(r.p95_ns),
            r.iters
        ),
    }
}
