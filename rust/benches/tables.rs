//! End-to-end benches, one per paper table/figure (reduced-scale): runs
//! the same harness as `qafel exp ...` on the analytic backend and prints
//! the regenerated rows plus wall time. These validate that each
//! table/figure pipeline executes end-to-end inside `cargo bench`;
//! full-scale PJRT numbers are produced by `qafel exp --backend pjrt`
//! and recorded in EXPERIMENTS.md.

mod common;

use anyhow::Result;
use qafel::config::{Algorithm, Config};
use qafel::experiments::{self, runner::BackendFactory};
use qafel::runtime::QuadraticBackend;
use qafel::sim::SimOptions;
use std::time::Instant;

fn base_cfg() -> Config {
    let mut c = Config::default();
    c.fl.buffer_size = 4;
    c.fl.client_lr = 0.15;
    c.fl.server_lr = 1.0;
    c.fl.server_momentum = 0.0;
    c.fl.clip_norm = 0.0;
    c.sim.concurrency = 10;
    c.sim.eval_every = 5;
    c.seeds = if common::fast_mode() { vec![1] } else { vec![1, 2, 3] };
    c.stop.target_accuracy = 0.95;
    c.stop.max_uploads = 30_000;
    c.stop.max_server_steps = 8000;
    c
}

fn factory(seed: u64) -> Result<Box<dyn qafel::runtime::Backend>> {
    Ok(Box::new(QuadraticBackend::new(128, 32, 1.0, 0.3, 0.2, 0.02, 2, seed)))
}

fn timed<F: FnOnce() -> Result<()>>(name: &str, f: F) {
    let t0 = Instant::now();
    f().unwrap();
    println!(">>> {name}: {:.2}s\n", t0.elapsed().as_secs_f64());
}

fn main() {
    let out = std::env::temp_dir().join(format!("qafel-bench-tables-{}", std::process::id()));
    let out = out.to_str().unwrap().to_string();
    let opts = SimOptions::default();
    let f: &BackendFactory = &factory;

    timed("fig3 (concurrency sweep, reduced)", || {
        let mut cfg = base_cfg();
        cfg.sim.concurrency = 10; // reduced from 100/500/1000
        let mut rows = Vec::new();
        for conc in [10usize, 40] {
            for (algo, qc, qs) in [
                (Algorithm::Qafel, "qsgd:4", "qsgd:4"),
                (Algorithm::FedBuff, "none", "none"),
            ] {
                let mut c = cfg.clone();
                c.fl.algorithm = algo;
                c.quant.client = qc.into();
                c.quant.server = qs.into();
                c.sim.concurrency = conc;
                c.fl.staleness_scaling = true;
                let set = experiments::runner::run_seeds(
                    &c, f, &opts, &format!("{} c={conc}", algo.name()))?;
                rows.push(experiments::runner::aggregate(&set));
            }
        }
        let md = experiments::runner::report("bench_fig3", &out, &rows)?;
        println!("{md}");
        Ok(())
    });

    timed("table1 (qsgd grid)", || {
        experiments::table1::run(&base_cfg(), f, &out, &opts).map(|_| ())
    });

    timed("table2 (biased top_k server)", || {
        experiments::table2::run(&base_cfg(), f, &out, &opts).map(|_| ())
    });

    timed("convergence (Prop 3.5)", || {
        let horizons: &[u64] = if common::fast_mode() { &[40, 160] } else { &[40, 160, 640] };
        experiments::convergence::run(&base_cfg(), f, &out, horizons).map(|_| ())
    });

    timed("ablations", || {
        experiments::ablations::hidden_state(&base_cfg(), f, &out, &opts)?;
        experiments::ablations::k_sweep(&base_cfg(), f, &out, &opts)?;
        Ok(())
    });

    let _ = std::fs::remove_dir_all(&out);
}
