//! End-to-end L3 coordinator throughput with compute stubbed out: how
//! many uploads/second can the server state machine ingest (dequantize,
//! buffer, momentum step, hidden-state quantize + broadcast)?
//!
//! DESIGN.md perf target: >= 10^4 uploads/s at the paper's model size so
//! L3 is never the bottleneck (one PJRT client_update is ~10-70 ms).
//!
//! The **shard sweep** section measures the sharded aggregation
//! pipeline (DESIGN_SHARDING.md): full server steps (K ingests + the
//! momentum/quantize/broadcast step) at S in {1, 2, 4, 8} from the
//! paper's d = 29,474 up to ~8M coordinates, and records the results in
//! `BENCH_sharded_step.json` for the perf log.
//!
//! The **tree sweep** section measures hierarchical aggregation (ISSUE
//! 6): the same update stream pushed through K edge aggregators running
//! on their own threads (modelling the distributed tree's critical
//! path) vs the flat server ingesting every client upload itself.
//! Records `BENCH_tree_step.json`.

mod common;

use common::{bench, scaled};
use qafel::config::{Algorithm, Config, TierConfig};
use qafel::coordinator::{AggOutcome, EdgeAggregator, Server, ServerStep};
use qafel::quant::parse_spec;
use qafel::runtime::QuadraticBackend;
use qafel::sim::SimEngine;
use qafel::util::json::Json;
use qafel::util::pool::ShardPool;
use qafel::util::prng::Prng;
use std::hint::black_box;
use std::time::Instant;

fn cfg(algo: Algorithm, qc: &str, qs: &str, k: usize) -> Config {
    let mut c = Config::default();
    c.fl.algorithm = algo;
    c.quant.client = qc.into();
    c.quant.server = qs.into();
    c.fl.buffer_size = k;
    c.fl.server_lr = 1.0;
    c.fl.server_momentum = 0.3;
    c
}

fn main() {
    let d = 29_474;
    let mut rng = Prng::new(1);
    let delta: Vec<f32> = (0..d).map(|_| (rng.f32() - 0.5) * 1e-3).collect();

    println!("== coordinator ingest throughput (d = {d}, K = 10) ==");
    for (name, algo, qc, qs) in [
        ("qafel 4/4", Algorithm::Qafel, "qsgd:4", "qsgd:4"),
        ("qafel 8/8", Algorithm::Qafel, "qsgd:8", "qsgd:8"),
        ("fedbuff", Algorithm::FedBuff, "none", "none"),
        ("directquant 4/4", Algorithm::DirectQuant, "qsgd:4", "qsgd:4"),
    ] {
        let c = cfg(algo, qc, qs, 10);
        let mut server = Server::build(&c, vec![0.0; d], 1).unwrap();
        let codec = parse_spec(if matches!(algo, Algorithm::FedBuff) { "none" } else { qc }).unwrap();
        let mut qrng = Prng::new(2);
        let msg = codec.quantize(&delta, &mut qrng);

        let iters = scaled(20_000);
        let t0 = Instant::now();
        for i in 0..iters {
            let _ = black_box(server.ingest(black_box(&msg), (i % 7) as u64).unwrap());
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{name:<18} {:>9.0} uploads/s  ({:.2} us/upload, {} server steps)",
            iters as f64 / dt,
            dt / iters as f64 * 1e6,
            server.t()
        );
    }

    println!("\n== full client trip without compute (quantize + ingest) ==");
    let c = cfg(Algorithm::Qafel, "qsgd:4", "qsgd:4", 10);
    let mut server = Server::build(&c, vec![0.0; d], 1).unwrap();
    let codec = parse_spec("qsgd:4").unwrap();
    let mut qrng = Prng::new(3);
    bench("quantize+ingest (qsgd:4)", 5000, || {
        let msg = codec.quantize(black_box(&delta), &mut qrng);
        let _ = black_box(server.ingest(&msg, 3).unwrap());
    });

    println!("\n== snapshot cost (Arc clone of hidden state) ==");
    bench("client_snapshot", 100_000, || {
        black_box(server.client_snapshot());
    });

    // guard against silent regression: assert the DESIGN.md target when
    // not in fast mode
    if !common::fast_mode() {
        let c = cfg(Algorithm::Qafel, "qsgd:4", "qsgd:4", 10);
        let mut server = Server::build(&c, vec![0.0; d], 1).unwrap();
        let msg = codec.quantize(&delta, &mut qrng);
        let t0 = Instant::now();
        let n = 20_000;
        for i in 0..n {
            match server.ingest(&msg, (i % 5) as u64).unwrap() {
                ServerStep::Buffered | ServerStep::Stepped(_) => {}
            }
        }
        let rate = n as f64 / t0.elapsed().as_secs_f64();
        println!("\nperf target check: {rate:.0} uploads/s (target >= 10000)");
    }

    shard_sweep();
    tree_sweep();
    scenario_stream();
}

/// Sharded-pipeline sweep: wall time of one full server step (K = 10
/// ingests + momentum/diff/Q_s/broadcast) on the persistent shard pool,
/// vs shard count, model dimension and codec. `qsgd:4` sweeps the full
/// dimension range; the biased codecs (`top:0.1`'s candidate merge,
/// `rand:0.1`'s per-bucket index streams) ride the smaller dims so the
/// smoke stays fast. Writes BENCH_sharded_step.json.
fn shard_sweep() {
    const K: usize = 10;
    let full_dims: &[usize] = if common::fast_mode() {
        &[29_474, 1 << 20]
    } else {
        &[29_474, 1 << 20, 1 << 23] // paper scale .. ~8.4M coordinates
    };
    let biased_dims: &[usize] = &[29_474, 1 << 20];
    let shard_counts = [1usize, 2, 4, 8];
    println!("\n== sharded server step on the persistent pool (K = {K}) ==");
    println!(
        "{:>10} {:>10} {:>4} {:>14} {:>12} {:>9}",
        "codec", "d", "S", "ns/step", "steps/s", "speedup"
    );

    let mut results: Vec<Json> = Vec::new();
    for spec in ["qsgd:4", "top:0.1", "rand:0.1"] {
        let dims = if spec == "qsgd:4" { full_dims } else { biased_dims };
        for &dim in dims {
            let codec = parse_spec(spec).unwrap();
            let mut qrng = Prng::new(3);
            let delta: Vec<f32> = {
                let mut r = Prng::new(4);
                (0..dim).map(|_| (r.f32() - 0.5) * 1e-3).collect()
            };
            let msg = codec.quantize(&delta, &mut qrng);
            // enough steps for a stable mean, scaled down as d grows
            let steps = (scaled(40_000_000) / dim.max(1)).clamp(3, 2_000);
            let mut baseline_ns = 0.0f64;
            for &shards in &shard_counts {
                let mut c = cfg(Algorithm::Qafel, spec, spec, K);
                c.fl.shards = shards;
                let mut server = Server::build(&c, vec![0.0; dim], 1).unwrap();
                // warmup one full step
                for i in 0..K {
                    let _ = black_box(server.ingest(&msg, (i % 3) as u64).unwrap());
                }
                let t0 = Instant::now();
                for step in 0..steps {
                    for i in 0..K {
                        let _ = black_box(server.ingest(&msg, ((step + i) % 5) as u64).unwrap());
                    }
                }
                let ns_per_step = t0.elapsed().as_nanos() as f64 / steps as f64;
                if shards == 1 {
                    baseline_ns = ns_per_step;
                }
                let speedup = baseline_ns / ns_per_step;
                println!(
                    "{:>10} {:>10} {:>4} {:>14.0} {:>12.1} {:>8.2}x",
                    spec,
                    dim,
                    shards,
                    ns_per_step,
                    1e9 / ns_per_step,
                    speedup
                );
                results.push(Json::obj(vec![
                    ("codec", Json::str(spec)),
                    ("d", Json::num(dim as f64)),
                    ("shards", Json::num(shards as f64)),
                    ("k_buffer", Json::num(K as f64)),
                    ("steps_timed", Json::num(steps as f64)),
                    ("ns_per_step", Json::num(ns_per_step)),
                    ("steps_per_sec", Json::num(1e9 / ns_per_step)),
                    ("speedup_vs_s1", Json::num(speedup)),
                ]));
            }
        }
    }
    let doc = Json::obj(vec![
        ("bench", Json::str("sharded_step")),
        ("quantizers", Json::str("client == server codec per row")),
        ("threads_available", Json::num(
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as f64,
        )),
        ("fast_mode", Json::Bool(common::fast_mode())),
        ("results", Json::arr(results)),
    ]);
    let out = std::env::var("QAFEL_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_sharded_step.json".to_string());
    match std::fs::write(&out, doc.pretty()) {
        Ok(()) => println!("\nshard sweep recorded in {out}"),
        Err(e) => println!("\ncould not write {out}: {e}"),
    }
}

/// Aggregation-tree sweep: wall time to absorb one fixed stream of
/// client updates, flat (the root decodes every upload itself) vs
/// through E in {1, 8, 32} edge aggregators, each on its own thread
/// with its buffer and partial codec — the distributed tree's critical
/// path with the network stubbed out (partials travel over an in-proc
/// channel). The per-update O(d) decode + staleness weighting moves to
/// the edges, so the root only folds count-weighted partials: at large
/// d the 32-edge row should meet or beat the flat row (the fast-mode
/// smoke runs a small d where thread overhead can dominate — the JSON
/// records `fast_mode` so the checker only enforces the comparison on
/// full runs). Writes BENCH_tree_step.json (QAFEL_BENCH_TREE_OUT
/// overrides the path).
fn tree_sweep() {
    const K_ROOT: usize = 32; // root buffer: steps once per 32 updates
    const B_EDGE: usize = 8; // edge buffer: one partial per 8 updates
    let d: usize = if common::fast_mode() { 29_474 } else { 1 << 20 };
    let spec = "qsgd:4";
    let codec = parse_spec(spec).unwrap();
    let delta: Vec<f32> = {
        let mut r = Prng::new(4);
        (0..d).map(|_| (r.f32() - 0.5) * 1e-3).collect()
    };
    let msg = codec.quantize(&delta, &mut Prng::new(3));
    // one stream for every row, sized in multiples of 256 = lcm of
    // K_ROOT and every E * B_EDGE, so each edge drains exactly and
    // every row performs the same whole number of root steps
    let updates = (scaled(4_000_000) / d).clamp(1, 500) * 256;

    println!("\n== aggregation tree: flat root vs E edge threads (d = {d}, K = {K_ROOT}, B = {B_EDGE}) ==");
    println!("{:>6} {:>10} {:>14} {:>12} {:>9}", "edges", "updates", "ns/update", "updates/s", "speedup");

    let mut results: Vec<Json> = Vec::new();
    let mut flat_ns = 0.0f64;
    for edges in [0usize, 1, 8, 32] {
        let mut c = cfg(Algorithm::Qafel, spec, spec, K_ROOT);
        c.fl.shards = 1; // isolate the tree effect from shard parallelism
        let mut server = Server::build(&c, vec![0.0; d], 1).unwrap();
        let steps_expected = (updates / K_ROOT) as u64;

        let wall = if edges == 0 {
            // flat baseline: the root ingests every client upload
            let t0 = Instant::now();
            for i in 0..updates {
                let _ = black_box(server.ingest_from(black_box(&msg), (i % 5) as u64, 0).unwrap());
            }
            t0.elapsed()
        } else {
            assert!(server.register_partial_codec(spec).unwrap() == 0);
            let per_edge = updates / edges;
            let (ptx, prx) = std::sync::mpsc::channel();
            let t0 = Instant::now();
            std::thread::scope(|s| {
                for e in 0..edges {
                    let ptx = ptx.clone();
                    let msg = &msg;
                    s.spawn(move || {
                        let mut edge = EdgeAggregator::new(
                            d,
                            B_EDGE,
                            spec,
                            spec,
                            Algorithm::Qafel,
                            true,
                            ShardPool::new(1),
                            100 + e as u64,
                        )
                        .unwrap();
                        for i in 0..per_edge {
                            match edge.ingest_from(msg, (i % 5) as u64, 0).unwrap() {
                                AggOutcome::Forward(p) => {
                                    let _ = ptx.send(p);
                                }
                                AggOutcome::Buffered => {}
                                AggOutcome::Stepped(_) => unreachable!("edges never step"),
                            }
                        }
                    });
                }
                drop(ptx);
                // the root thread folds partials as they arrive
                for p in prx {
                    let _ = black_box(
                        server.ingest_partial(&p.msg, p.count, &p.staleness, 0).unwrap(),
                    );
                }
            });
            t0.elapsed()
        };
        assert_eq!(server.t(), steps_expected, "E={edges}: wrong step count");

        let ns_per_update = wall.as_nanos() as f64 / updates as f64;
        if edges == 0 {
            flat_ns = ns_per_update;
        }
        let speedup = flat_ns / ns_per_update;
        println!(
            "{:>6} {:>10} {:>14.0} {:>12.1} {:>8.2}x",
            if edges == 0 { "flat".to_string() } else { edges.to_string() },
            updates,
            ns_per_update,
            1e9 / ns_per_update,
            speedup
        );
        results.push(Json::obj(vec![
            ("edges", Json::num(edges as f64)),
            ("d", Json::num(d as f64)),
            ("k_buffer", Json::num(K_ROOT as f64)),
            ("edge_buffer", Json::num(if edges == 0 { 0.0 } else { B_EDGE as f64 })),
            ("updates", Json::num(updates as f64)),
            ("server_steps", Json::num(steps_expected as f64)),
            ("ns_per_update", Json::num(ns_per_update)),
            ("updates_per_sec", Json::num(1e9 / ns_per_update)),
            ("speedup_vs_flat", Json::num(speedup)),
        ]));
    }
    let doc = Json::obj(vec![
        ("bench", Json::str("tree_step")),
        ("codec", Json::str(spec)),
        ("partial_codec", Json::str(spec)),
        ("threads_available", Json::num(
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as f64,
        )),
        ("fast_mode", Json::Bool(common::fast_mode())),
        ("results", Json::arr(results)),
    ]);
    let out = std::env::var("QAFEL_BENCH_TREE_OUT")
        .unwrap_or_else(|_| "BENCH_tree_step.json".to_string());
    match std::fs::write(&out, doc.pretty()) {
        Ok(()) => println!("tree sweep recorded in {out}"),
        Err(e) => println!("could not write {out}: {e}"),
    }
}

/// Scenario-engine throughput: a ~1M-virtual-client arrival stream from
/// a heterogeneous 2-tier population (bandwidth delays, dropouts) pushed
/// through the event loop + versioned snapshot store. The model is tiny
/// (d = 64) so the measurement isolates the event machinery rather than
/// the gradient compute. Writes BENCH_scenario_step.json
/// (QAFEL_BENCH_SCENARIO_OUT overrides the path).
fn scenario_stream() {
    let fast_mode = common::fast_mode();
    let concurrency = if fast_mode { 25_000 } else { 250_000 };
    let max_uploads: u64 = if fast_mode { 75_000 } else { 750_000 };

    let mut cfg = Config::default();
    cfg.fl.algorithm = Algorithm::Qafel;
    cfg.quant.client = "qsgd:4".into();
    cfg.quant.server = "qsgd:4".into();
    cfg.fl.buffer_size = 50;
    cfg.fl.client_lr = 0.05;
    cfg.fl.clip_norm = 0.0;
    cfg.sim.concurrency = concurrency;
    cfg.sim.eval_every = 1_000_000_000; // eval only at t = 0
    cfg.stop.target_accuracy = 2.0;
    cfg.stop.max_uploads = max_uploads;
    cfg.stop.max_server_steps = u64::MAX;
    let mut fast_tier = TierConfig::named("fast");
    fast_tier.weight = 0.3;
    fast_tier.duration_sigma = 0.4;
    fast_tier.upload_mbps = 20.0;
    fast_tier.download_mbps = 80.0;
    let mut slow_tier = TierConfig::named("slow");
    slow_tier.weight = 0.7;
    slow_tier.duration = "lognormal".into();
    slow_tier.duration_sigma = 1.0;
    slow_tier.upload_mbps = 1.5;
    slow_tier.download_mbps = 6.0;
    slow_tier.dropout = 0.05;
    cfg.scenario.tiers = vec![fast_tier, slow_tier];
    cfg.validate().unwrap();

    let backend = QuadraticBackend::new(64, 1000, 1.0, 0.3, 0.2, 0.02, 1, 1);
    let t0 = Instant::now();
    let result = SimEngine::new(&cfg, &backend, 1).run().unwrap();
    let wall = t0.elapsed().as_secs_f64();

    let sc = &result.scenario;
    let arrivals: u64 = sc.tiers.iter().map(|t| t.arrivals + t.unavailable).sum();
    let dropouts: u64 = sc.tiers.iter().map(|t| t.dropouts).sum();
    // every arrival is one event; every started client finishes once
    let events = arrivals + result.comm.uploads + dropouts;
    println!("\n== scenario engine: heterogeneous arrival stream ==");
    println!(
        "virtual clients     : {arrivals} arrivals ({} uploads, {dropouts} dropouts)",
        result.comm.uploads
    );
    println!("server steps        : {}", result.server_steps);
    println!(
        "wall                : {wall:.2}s  ({:.0} events/s, {:.0} uploads/s)",
        events as f64 / wall,
        result.comm.uploads as f64 / wall
    );
    println!(
        "concurrency         : target {concurrency}, measured mean {:.0}, peak in-flight {}",
        sc.mean_concurrency, sc.max_in_flight
    );
    println!(
        "snapshot store      : peak {} live model versions (vs {} in-flight clients)",
        sc.max_live_snapshots, sc.max_in_flight
    );

    let doc = Json::obj(vec![
        ("bench", Json::str("scenario_step")),
        ("tiers", Json::num(sc.tiers.len() as f64)),
        ("target_concurrency", Json::num(concurrency as f64)),
        ("arrivals", Json::num(arrivals as f64)),
        ("uploads", Json::num(result.comm.uploads as f64)),
        ("dropouts", Json::num(dropouts as f64)),
        ("server_steps", Json::num(result.server_steps as f64)),
        ("wall_seconds", Json::num(wall)),
        ("events_per_sec", Json::num(events as f64 / wall)),
        ("uploads_per_sec", Json::num(result.comm.uploads as f64 / wall)),
        ("mean_concurrency", Json::num(sc.mean_concurrency)),
        ("max_in_flight", Json::num(sc.max_in_flight as f64)),
        ("max_live_snapshots", Json::num(sc.max_live_snapshots as f64)),
        ("fast_mode", Json::Bool(fast_mode)),
    ]);
    let out = std::env::var("QAFEL_BENCH_SCENARIO_OUT")
        .unwrap_or_else(|_| "BENCH_scenario_step.json".to_string());
    match std::fs::write(&out, doc.pretty()) {
        Ok(()) => println!("scenario stream recorded in {out}"),
        Err(e) => println!("could not write {out}: {e}"),
    }
}
