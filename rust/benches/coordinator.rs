//! End-to-end L3 coordinator throughput with compute stubbed out: how
//! many uploads/second can the server state machine ingest (dequantize,
//! buffer, momentum step, hidden-state quantize + broadcast)?
//!
//! DESIGN.md perf target: >= 10^4 uploads/s at the paper's model size so
//! L3 is never the bottleneck (one PJRT client_update is ~10-70 ms).

mod common;

use common::{bench, scaled};
use qafel::config::{Algorithm, Config};
use qafel::coordinator::{Server, ServerStep};
use qafel::quant::parse_spec;
use qafel::util::prng::Prng;
use std::hint::black_box;
use std::time::Instant;

fn cfg(algo: Algorithm, qc: &str, qs: &str, k: usize) -> Config {
    let mut c = Config::default();
    c.fl.algorithm = algo;
    c.quant.client = qc.into();
    c.quant.server = qs.into();
    c.fl.buffer_size = k;
    c.fl.server_lr = 1.0;
    c.fl.server_momentum = 0.3;
    c
}

fn main() {
    let d = 29_474;
    let mut rng = Prng::new(1);
    let delta: Vec<f32> = (0..d).map(|_| (rng.f32() - 0.5) * 1e-3).collect();

    println!("== coordinator ingest throughput (d = {d}, K = 10) ==");
    for (name, algo, qc, qs) in [
        ("qafel 4/4", Algorithm::Qafel, "qsgd:4", "qsgd:4"),
        ("qafel 8/8", Algorithm::Qafel, "qsgd:8", "qsgd:8"),
        ("fedbuff", Algorithm::FedBuff, "none", "none"),
        ("directquant 4/4", Algorithm::DirectQuant, "qsgd:4", "qsgd:4"),
    ] {
        let c = cfg(algo, qc, qs, 10);
        let mut server = Server::build(&c, vec![0.0; d], 1).unwrap();
        let codec = parse_spec(if matches!(algo, Algorithm::FedBuff) { "none" } else { qc }).unwrap();
        let mut qrng = Prng::new(2);
        let msg = codec.quantize(&delta, &mut qrng);

        let iters = scaled(20_000);
        let t0 = Instant::now();
        for i in 0..iters {
            let _ = black_box(server.ingest(black_box(&msg), (i % 7) as u64).unwrap());
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{name:<18} {:>9.0} uploads/s  ({:.2} us/upload, {} server steps)",
            iters as f64 / dt,
            dt / iters as f64 * 1e6,
            server.t()
        );
    }

    println!("\n== full client trip without compute (quantize + ingest) ==");
    let c = cfg(Algorithm::Qafel, "qsgd:4", "qsgd:4", 10);
    let mut server = Server::build(&c, vec![0.0; d], 1).unwrap();
    let codec = parse_spec("qsgd:4").unwrap();
    let mut qrng = Prng::new(3);
    bench("quantize+ingest (qsgd:4)", 5000, || {
        let msg = codec.quantize(black_box(&delta), &mut qrng);
        let _ = black_box(server.ingest(&msg, 3).unwrap());
    });

    println!("\n== snapshot cost (Arc clone of hidden state) ==");
    bench("client_snapshot", 100_000, || {
        black_box(server.client_snapshot());
    });

    // guard against silent regression: assert the DESIGN.md target when
    // not in fast mode
    if !common::fast_mode() {
        let c = cfg(Algorithm::Qafel, "qsgd:4", "qsgd:4", 10);
        let mut server = Server::build(&c, vec![0.0; d], 1).unwrap();
        let msg = codec.quantize(&delta, &mut qrng);
        let t0 = Instant::now();
        let n = 20_000;
        for i in 0..n {
            match server.ingest(&msg, (i % 5) as u64).unwrap() {
                ServerStep::Buffered | ServerStep::Stepped(_) => {}
            }
        }
        let rate = n as f64 / t0.elapsed().as_secs_f64();
        println!("\nperf target check: {rate:.0} uploads/s (target >= 10000)");
    }
}
