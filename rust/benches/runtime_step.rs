//! L2/L1 runtime benchmarks: latency of the AOT executables through PJRT
//! (the real per-upload compute cost). Skipped when artifacts are absent.
//!
//! This is the dominant cost of a simulated upload; EXPERIMENTS.md §Perf
//! tracks client_update before/after the im2col conv rewrite.

mod common;

use common::bench;
use qafel::data::Dataset;
use qafel::runtime::{artifacts_available, Engine};
use qafel::util::prng::Prng;
use std::hint::black_box;

fn main() {
    let dir = std::env::var("QAFEL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !artifacts_available(&dir) {
        println!("runtime_step: artifacts not found in '{dir}' — run `make artifacts`; skipping");
        return;
    }
    let engine = Engine::load(&dir).expect("engine");
    let m = engine.manifest();
    let d = engine.d();
    let (p, b, eb) = (m.local_steps, m.batch, m.eval_batch);
    let img = engine.img_elems();
    println!("== PJRT executables (d={d}, B={b}, P={p}) ==");

    let params = engine.init_params(0).unwrap();
    let ds = Dataset::new(&qafel::config::DataConfig::default());
    let mut rng = Prng::new(7);
    let mut xs = vec![0.0f32; p * b * img];
    let mut ys = vec![0i32; p * b];
    let mut mask = vec![0.0f32; p * b];
    ds.fill_round(3, &mut rng, p, b, &mut xs, &mut ys, &mut mask);

    bench("client_update (P local steps, 1 PJRT call)", 30, || {
        black_box(
            engine
                .client_update(black_box(&params), &xs, &ys, &mask, 4.7e-6, 1)
                .unwrap(),
        );
    });

    let mut u = vec![0.0f32; d];
    rng.fill_uniform_f32(&mut u);
    bench("client_update_quantized (incl. Pallas qsgd)", 30, || {
        black_box(
            engine
                .client_update_quantized(black_box(&params), &xs, &ys, &mask, 4.7e-6, 1, &u, 7.0)
                .unwrap(),
        );
    });

    bench("qsgd_quantize artifact (Pallas kernel alone)", 100, || {
        black_box(engine.qsgd_quantize(black_box(&params), &u, 7.0).unwrap());
    });

    // eval batch
    let mut ex = vec![0.0f32; eb * img];
    let mut ey = vec![0i32; eb];
    let emask = vec![1.0f32; eb];
    let mut slot = 0;
    'outer: for uidx in 0..ds.num_users() {
        for j in 0..ds.user(uidx).n_samples {
            if slot == eb {
                break 'outer;
            }
            ey[slot] = ds.sample_into(uidx, j, &mut ex[slot * img..(slot + 1) * img]) as i32;
            slot += 1;
        }
    }
    bench(&format!("eval_step (batch {eb})"), 30, || {
        black_box(engine.eval_step(black_box(&params), &ex, &ey, &emask).unwrap());
    });

    bench("init_params", 30, || {
        black_box(engine.init_params(black_box(0)).unwrap());
    });

    println!("\n== host-side data path ==");
    bench("dataset fill_round (P batches of B images)", 100, || {
        ds.fill_round(5, &mut rng, p, b, black_box(&mut xs), &mut ys, &mut mask);
    });
}
