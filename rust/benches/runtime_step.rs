//! L2/L1 runtime benchmarks: latency of the AOT executables through PJRT
//! (the real per-upload compute cost). Skipped when artifacts are absent.
//!
//! This is the dominant cost of a simulated upload; EXPERIMENTS.md §Perf
//! tracks client_update before/after the im2col conv rewrite.

mod common;

use common::bench;
use qafel::data::Dataset;
use qafel::runtime::{artifacts_available, Engine};
use qafel::util::prng::Prng;
use std::hint::black_box;

/// Host-side (L3) runtime step at scale: the per-step server work the
/// sharded pipeline parallelizes, swept over shard counts and model
/// dimensions. Runs with no artifacts — this is the pure-rust path.
fn sharded_runtime_step_sweep() {
    use qafel::config::{Algorithm, Config};
    use qafel::coordinator::Server;

    let dims: &[usize] = if common::fast_mode() { &[29_474] } else { &[29_474, 1 << 20] };
    println!("== L3 runtime step vs shards (qsgd:4 both ways, K = 10) ==");
    for &d in dims {
        let codec = qafel::quant::parse_spec("qsgd:4").unwrap();
        let mut qrng = Prng::new(2);
        let delta: Vec<f32> = {
            let mut r = Prng::new(5);
            (0..d).map(|_| (r.f32() - 0.5) * 1e-3).collect()
        };
        let msg = codec.quantize(&delta, &mut qrng);
        for shards in [1usize, 2, 4, 8] {
            let mut cfg = Config::default();
            cfg.fl.algorithm = Algorithm::Qafel;
            cfg.quant.client = "qsgd:4".into();
            cfg.quant.server = "qsgd:4".into();
            cfg.fl.buffer_size = 10;
            cfg.fl.shards = shards;
            let mut server = Server::build(&cfg, vec![0.0; d], 1).unwrap();
            let iters = (common::scaled(8_000_000) / d.max(1)).clamp(3, 500);
            bench(&format!("server step d={d} S={shards}"), iters, || {
                for i in 0..10 {
                    let _ = black_box(server.ingest(black_box(&msg), i % 4).unwrap());
                }
            });
        }
    }
    println!();
}

fn main() {
    sharded_runtime_step_sweep();

    let dir = std::env::var("QAFEL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !artifacts_available(&dir) {
        println!("runtime_step: artifacts not found in '{dir}' — run `make artifacts`; skipping");
        return;
    }
    let engine = Engine::load(&dir).expect("engine");
    let m = engine.manifest();
    let d = engine.d();
    let (p, b, eb) = (m.local_steps, m.batch, m.eval_batch);
    let img = engine.img_elems();
    println!("== PJRT executables (d={d}, B={b}, P={p}) ==");

    let params = engine.init_params(0).unwrap();
    let ds = Dataset::new(&qafel::config::DataConfig::default());
    let mut rng = Prng::new(7);
    let mut xs = vec![0.0f32; p * b * img];
    let mut ys = vec![0i32; p * b];
    let mut mask = vec![0.0f32; p * b];
    ds.fill_round(3, &mut rng, p, b, &mut xs, &mut ys, &mut mask);

    bench("client_update (P local steps, 1 PJRT call)", 30, || {
        black_box(
            engine
                .client_update(black_box(&params), &xs, &ys, &mask, 4.7e-6, 1)
                .unwrap(),
        );
    });

    let mut u = vec![0.0f32; d];
    rng.fill_uniform_f32(&mut u);
    bench("client_update_quantized (incl. Pallas qsgd)", 30, || {
        black_box(
            engine
                .client_update_quantized(black_box(&params), &xs, &ys, &mask, 4.7e-6, 1, &u, 7.0)
                .unwrap(),
        );
    });

    bench("qsgd_quantize artifact (Pallas kernel alone)", 100, || {
        black_box(engine.qsgd_quantize(black_box(&params), &u, 7.0).unwrap());
    });

    // eval batch
    let mut ex = vec![0.0f32; eb * img];
    let mut ey = vec![0i32; eb];
    let emask = vec![1.0f32; eb];
    let mut slot = 0;
    'outer: for uidx in 0..ds.num_users() {
        for j in 0..ds.user(uidx).n_samples {
            if slot == eb {
                break 'outer;
            }
            ey[slot] = ds.sample_into(uidx, j, &mut ex[slot * img..(slot + 1) * img]) as i32;
            slot += 1;
        }
    }
    bench(&format!("eval_step (batch {eb})"), 30, || {
        black_box(engine.eval_step(black_box(&params), &ex, &ey, &emask).unwrap());
    });

    bench("init_params", 30, || {
        black_box(engine.init_params(black_box(0)).unwrap());
    });

    println!("\n== host-side data path ==");
    bench("dataset fill_round (P batches of B images)", 100, || {
        ds.fill_round(5, &mut rng, p, b, black_box(&mut xs), &mut ys, &mut mask);
    });
}
