//! Synthetic CelebA-LEAF substitute (DESIGN.md §4 substitution S9).
//!
//! The paper evaluates on LEAF's CelebA smile-detection task: 32x32x3
//! images, non-iid partition over ~9.3k users with 1–32 samples each,
//! 80/10/10 user split under seed 1549775860. CelebA images are not
//! available offline, so we generate a *learnable, non-iid* synthetic task
//! with the same shape: class-template images plus a per-user style
//! offset and observation noise, with per-user label skew.
//!
//! The reproduced metrics (communication to reach a target validation
//! accuracy) depend on optimization dynamics — gradient noise, client
//! heterogeneity, staleness, quantization error — not on face semantics,
//! so this substitution preserves the comparisons the paper makes.
//!
//! Images are generated **lazily and deterministically**: sample `j` of
//! user `u` is a pure function of (dataset seed, u, j), so the dataset
//! occupies O(users) memory, any client can be replayed bit-exactly, and
//! the virtual-time simulator can evaluate clients in any order.

pub mod partition;
pub mod synth;

pub use partition::{Partition, Split};
pub use synth::{Dataset, IMG_C, IMG_ELEMS, IMG_H, IMG_W};
