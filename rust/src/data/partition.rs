//! LEAF-style user partition: shuffle users under the benchmark seed and
//! split 80% / 10% / 10% into train / validation / test **by user** (the
//! paper: 7474 / 1869 / 1869 users from seed 1549775860).

use crate::util::prng::Prng;

/// Which split a user belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
}

/// User-level split of the dataset.
#[derive(Clone, Debug)]
pub struct Partition {
    pub train: Vec<usize>,
    pub val: Vec<usize>,
    pub test: Vec<usize>,
}

impl Partition {
    /// 80/10/10 split of `num_users` users under `seed`.
    pub fn leaf(num_users: usize, seed: u64) -> Partition {
        Partition::with_fractions(num_users, seed, 0.8, 0.1)
    }

    /// Split with explicit train/val fractions (test gets the rest).
    pub fn with_fractions(
        num_users: usize,
        seed: u64,
        train_frac: f64,
        val_frac: f64,
    ) -> Partition {
        assert!(train_frac >= 0.0 && val_frac >= 0.0 && train_frac + val_frac <= 1.0);
        let mut ids: Vec<usize> = (0..num_users).collect();
        let mut rng = Prng::new(seed).stream("leaf-partition");
        rng.shuffle(&mut ids);
        let n_train = (num_users as f64 * train_frac).round() as usize;
        let n_val = (num_users as f64 * val_frac).round() as usize;
        let n_val_end = (n_train + n_val).min(num_users);
        Partition {
            train: ids[..n_train].to_vec(),
            val: ids[n_train..n_val_end].to_vec(),
            test: ids[n_val_end..].to_vec(),
        }
    }

    pub fn split_of(&self, user: usize) -> Option<Split> {
        if self.train.contains(&user) {
            Some(Split::Train)
        } else if self.val.contains(&user) {
            Some(Split::Val)
        } else if self.test.contains(&user) {
            Some(Split::Test)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_match_leaf() {
        let p = Partition::leaf(1000, 1_549_775_860);
        assert_eq!(p.train.len(), 800);
        assert_eq!(p.val.len(), 100);
        assert_eq!(p.test.len(), 100);
    }

    #[test]
    fn covers_all_users_disjointly() {
        let p = Partition::leaf(503, 7);
        let mut all: Vec<usize> =
            p.train.iter().chain(&p.val).chain(&p.test).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..503).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_and_seed_dependent() {
        let a = Partition::leaf(100, 1);
        let b = Partition::leaf(100, 1);
        let c = Partition::leaf(100, 2);
        assert_eq!(a.train, b.train);
        assert_ne!(a.train, c.train);
    }

    #[test]
    fn split_of_lookup() {
        let p = Partition::leaf(50, 3);
        let u = p.val[0];
        assert_eq!(p.split_of(u), Some(Split::Val));
        assert_eq!(p.split_of(usize::MAX), None);
    }

    #[test]
    fn paper_user_counts_shape() {
        // paper: "7474, 1869, and 1869 train, validation, and test users".
        // 7474 is exactly 80% of 9343 but 1869 is 20% — the paper's val
        // and test counts cannot both be 10% of the same population; we
        // keep a disjoint 80/10/10 and check train matches exactly.
        let p = Partition::leaf(9343, 1_549_775_860);
        assert_eq!(p.train.len(), 7474);
        assert_eq!(p.val.len() + p.test.len(), 1869);
    }
}
