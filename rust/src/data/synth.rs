//! Deterministic synthetic image generator.
//!
//! Construction (per DESIGN.md):
//! * two **class templates** `T_0, T_1` — smooth low-frequency patterns
//!   built from a small sum of random 2-D sinusoids, normalized to unit
//!   RMS. "Smile vs no smile" becomes "which template is present";
//! * each user has a **style image** `S_u` (another smooth pattern), a
//!   signal amplitude `alpha_u in [0.7, 1.3]`, a label skew
//!   `p_u in [0.2, 0.8]` (non-iid label distribution), and a sample count
//!   `n_u ~ U{min..=max}` (LEAF CelebA: 1..=32);
//! * sample `j` of user `u`:
//!   `x = signal * alpha_u * T_y + style * S_u + noise * eps`,
//!   `y ~ Bernoulli(p_u)`, `eps ~ N(0,1)` iid per pixel.
//!
//! Everything derives from `DataConfig::seed` through named PRNG streams.

use crate::config::DataConfig;
use crate::util::dist::Normal;
use crate::util::prng::Prng;

pub const IMG_H: usize = 32;
pub const IMG_W: usize = 32;
pub const IMG_C: usize = 3;
pub const IMG_ELEMS: usize = IMG_H * IMG_W * IMG_C;

/// Per-user metadata (images themselves are generated on demand).
#[derive(Clone, Debug)]
pub struct UserMeta {
    /// Number of local samples (1..=32 for LEAF CelebA).
    pub n_samples: usize,
    /// P(y = 1) for this user (label skew — non-iid).
    pub p_positive: f64,
    /// Signal amplitude multiplier.
    pub alpha: f32,
    /// Seed of the user's style pattern.
    style_seed: u64,
}

/// The synthetic dataset.
pub struct Dataset {
    cfg: DataConfig,
    seed: u64,
    templates: [Vec<f32>; 2],
    users: Vec<UserMeta>,
}

/// Smooth unit-RMS pattern: sum of `n_waves` random 2-D sinusoids per
/// channel with small integer frequencies.
fn smooth_pattern(seed: u64, n_waves: usize) -> Vec<f32> {
    let mut rng = Prng::new(seed);
    let mut img = vec![0.0f32; IMG_ELEMS];
    for c in 0..IMG_C {
        for _ in 0..n_waves {
            let fx = rng.range(1, 5) as f32;
            let fy = rng.range(1, 5) as f32;
            let phase = rng.f32() * std::f32::consts::TAU;
            let amp = 0.5 + rng.f32();
            let sign = if rng.bool(0.5) { 1.0 } else { -1.0 };
            for i in 0..IMG_H {
                for j in 0..IMG_W {
                    let v = amp
                        * sign
                        * ((fx * i as f32 / IMG_H as f32
                            + fy * j as f32 / IMG_W as f32)
                            * std::f32::consts::TAU
                            + phase)
                            .sin();
                    img[(i * IMG_W + j) * IMG_C + c] += v;
                }
            }
        }
    }
    // normalize to unit RMS
    let rms =
        (img.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / IMG_ELEMS as f64).sqrt();
    let inv = if rms > 0.0 { (1.0 / rms) as f32 } else { 0.0 };
    for v in &mut img {
        *v *= inv;
    }
    img
}

impl Dataset {
    pub fn new(cfg: &DataConfig) -> Dataset {
        let root = Prng::new(cfg.seed);
        let t0 = smooth_pattern(root.stream("template-0").next_u64_clone(), 4);
        let t1 = smooth_pattern(root.stream("template-1").next_u64_clone(), 4);
        let mut urng = root.stream("users");
        let users = (0..cfg.num_users)
            .map(|_| UserMeta {
                n_samples: urng.range(cfg.min_samples, cfg.max_samples + 1),
                p_positive: 0.2 + 0.6 * urng.f64(),
                alpha: 0.7 + 0.6 * urng.f32(),
                style_seed: urng.next_u64(),
            })
            .collect();
        Dataset { cfg: cfg.clone(), seed: cfg.seed, templates: [t0, t1], users }
    }

    pub fn num_users(&self) -> usize {
        self.users.len()
    }

    pub fn user(&self, u: usize) -> &UserMeta {
        &self.users[u]
    }

    /// Deterministic label of sample `j` of user `u`.
    pub fn label(&self, u: usize, j: usize) -> u32 {
        let meta = &self.users[u];
        let mut rng = Prng::new(self.seed ^ 0xA5A5_5A5A)
            .stream_u64(u as u64)
            .stream_u64(j as u64);
        rng.bool(meta.p_positive) as u32
    }

    /// Write sample `j` of user `u` into `out` (len IMG_ELEMS); returns
    /// the label. Pure function of (seed, u, j).
    pub fn sample_into(&self, u: usize, j: usize, out: &mut [f32]) -> u32 {
        assert_eq!(out.len(), IMG_ELEMS);
        let meta = &self.users[u];
        let y = self.label(u, j);
        let template = &self.templates[y as usize];
        let style = smooth_pattern(meta.style_seed, 3);
        let mut nrng = Prng::new(self.seed ^ 0x3C3C_C3C3)
            .stream_u64(u as u64)
            .stream_u64(j as u64);
        let mut normal = Normal::new();
        let a = self.cfg.signal * meta.alpha;
        let st = self.cfg.style;
        let no = self.cfg.noise;
        for i in 0..IMG_ELEMS {
            out[i] =
                a * template[i] + st * style[i] + no * normal.sample(&mut nrng) as f32;
        }
        y
    }

    /// Fill a training round for a user: `p_steps` batches of `batch`
    /// samples. LEAF semantics: one epoch over the user's samples in a
    /// random order; if n_u < batch the remainder is mask-padded; if the
    /// epoch is exhausted (P > 1), further batches resample with
    /// replacement. Layouts match the AOT artifact: xs[P,B,H,W,C] (NHWC),
    /// ys[P,B], mask[P,B].
    pub fn fill_round(
        &self,
        u: usize,
        rng: &mut Prng,
        p_steps: usize,
        batch: usize,
        xs: &mut [f32],
        ys: &mut [i32],
        mask: &mut [f32],
    ) {
        assert_eq!(xs.len(), p_steps * batch * IMG_ELEMS);
        assert_eq!(ys.len(), p_steps * batch);
        assert_eq!(mask.len(), p_steps * batch);
        let n = self.users[u].n_samples;
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut cursor = 0usize;
        for p in 0..p_steps {
            for b in 0..batch {
                let slot = p * batch + b;
                let img = &mut xs[slot * IMG_ELEMS..(slot + 1) * IMG_ELEMS];
                if cursor < order.len() {
                    let j = order[cursor];
                    cursor += 1;
                    ys[slot] = self.sample_into(u, j, img) as i32;
                    mask[slot] = 1.0;
                } else if p == 0 {
                    // first batch under-full: mask-pad (LEAF one-epoch case)
                    img.fill(0.0);
                    ys[slot] = 0;
                    mask[slot] = 0.0;
                } else {
                    // later local steps: resample with replacement
                    let j = rng.range(0, n);
                    ys[slot] = self.sample_into(u, j, img) as i32;
                    mask[slot] = 1.0;
                }
            }
        }
    }

    /// Total samples across a set of users.
    pub fn total_samples(&self, users: &[usize]) -> usize {
        users.iter().map(|&u| self.users[u].n_samples).sum()
    }

    /// Enumerate up to `limit` (user, sample) pairs across `users`,
    /// deterministically subsampled with `rng` when the full set is
    /// larger — used to build the fixed validation set.
    pub fn eval_index(
        &self,
        users: &[usize],
        limit: usize,
        rng: &mut Prng,
    ) -> Vec<(usize, usize)> {
        let mut all: Vec<(usize, usize)> = users
            .iter()
            .flat_map(|&u| (0..self.users[u].n_samples).map(move |j| (u, j)))
            .collect();
        if all.len() > limit {
            rng.shuffle(&mut all);
            all.truncate(limit);
            all.sort_unstable();
        }
        all
    }
}

/// Small helper so template construction can consume one u64 from a
/// derived stream without threading a mutable borrow around.
trait NextU64Clone {
    fn next_u64_clone(&self) -> u64;
}

impl NextU64Clone for Prng {
    fn next_u64_clone(&self) -> u64 {
        let mut c = self.clone();
        c.next_u64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DataConfig;

    fn small_cfg() -> DataConfig {
        DataConfig { num_users: 50, ..DataConfig::default() }
    }

    #[test]
    fn deterministic_samples() {
        let ds1 = Dataset::new(&small_cfg());
        let ds2 = Dataset::new(&small_cfg());
        let mut a = vec![0.0f32; IMG_ELEMS];
        let mut b = vec![0.0f32; IMG_ELEMS];
        for (u, j) in [(0, 0), (7, 3), (49, 0)] {
            let ya = ds1.sample_into(u, j, &mut a);
            let yb = ds2.sample_into(u, j, &mut b);
            assert_eq!(ya, yb);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut cfg2 = small_cfg();
        cfg2.seed = 99;
        let ds1 = Dataset::new(&small_cfg());
        let ds2 = Dataset::new(&cfg2);
        let mut a = vec![0.0f32; IMG_ELEMS];
        let mut b = vec![0.0f32; IMG_ELEMS];
        ds1.sample_into(0, 0, &mut a);
        ds2.sample_into(0, 0, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn user_sample_counts_in_leaf_range() {
        let ds = Dataset::new(&small_cfg());
        for u in 0..ds.num_users() {
            let n = ds.user(u).n_samples;
            assert!((1..=32).contains(&n));
        }
        // heterogeneous: not all equal
        let first = ds.user(0).n_samples;
        assert!((0..ds.num_users()).any(|u| ds.user(u).n_samples != first));
    }

    #[test]
    fn labels_match_sample_into() {
        let ds = Dataset::new(&small_cfg());
        let mut img = vec![0.0f32; IMG_ELEMS];
        for u in 0..5 {
            for j in 0..ds.user(u).n_samples.min(4) {
                assert_eq!(ds.label(u, j), ds.sample_into(u, j, &mut img));
            }
        }
    }

    #[test]
    fn label_skew_is_per_user() {
        let cfg = DataConfig { num_users: 30, min_samples: 32, max_samples: 32, ..DataConfig::default() };
        let ds = Dataset::new(&cfg);
        let mut rates: Vec<f64> = Vec::new();
        for u in 0..30 {
            let pos: usize = (0..32).map(|j| ds.label(u, j) as usize).sum();
            rates.push(pos as f64 / 32.0);
        }
        let spread = rates.iter().cloned().fold(f64::MIN, f64::max)
            - rates.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 0.3, "labels look iid across users: {rates:?}");
    }

    #[test]
    fn classes_are_separable_by_template_correlation() {
        // sanity: the Bayes-ish classifier "corr with T1 - corr with T0"
        // must beat chance comfortably, or no model could learn this task.
        let ds = Dataset::new(&small_cfg());
        let mut img = vec![0.0f32; IMG_ELEMS];
        let (mut correct, mut total) = (0, 0);
        for u in 0..ds.num_users() {
            for j in 0..ds.user(u).n_samples.min(4) {
                let y = ds.sample_into(u, j, &mut img);
                let c0 = crate::util::vecf::dot(&img, &ds.templates[0]);
                let c1 = crate::util::vecf::dot(&img, &ds.templates[1]);
                let pred = (c1 > c0) as u32;
                correct += (pred == y) as usize;
                total += 1;
            }
        }
        let acc = correct as f64 / total as f64;
        assert!(acc > 0.85, "template classifier accuracy {acc}");
    }

    #[test]
    fn fill_round_epoch_then_replacement() {
        let ds = Dataset::new(&small_cfg());
        // find a small user
        let u = (0..ds.num_users()).min_by_key(|&u| ds.user(u).n_samples).unwrap();
        let n = ds.user(u).n_samples;
        let (p, b) = (2usize, 8usize);
        let mut xs = vec![0.0f32; p * b * IMG_ELEMS];
        let mut ys = vec![0i32; p * b];
        let mut mask = vec![0.0f32; p * b];
        let mut rng = Prng::new(1);
        ds.fill_round(u, &mut rng, p, b, &mut xs, &mut ys, &mut mask);
        let real_in_first: usize = mask[..b].iter().map(|&m| m as usize).sum();
        assert_eq!(real_in_first, n.min(b));
        // second step has no padding (resampled with replacement)
        let real_in_second: usize = mask[b..].iter().map(|&m| m as usize).sum();
        assert_eq!(real_in_second, b);
    }

    #[test]
    fn eval_index_subsamples_deterministically() {
        let ds = Dataset::new(&small_cfg());
        let users: Vec<usize> = (0..20).collect();
        let mut r1 = Prng::new(5);
        let mut r2 = Prng::new(5);
        let e1 = ds.eval_index(&users, 50, &mut r1);
        let e2 = ds.eval_index(&users, 50, &mut r2);
        assert_eq!(e1, e2);
        assert_eq!(e1.len(), 50.min(ds.total_samples(&users)));
        assert!(e1.iter().all(|&(u, j)| j < ds.user(u).n_samples));
    }
}
