//! Small command-line parser (no `clap` offline).
//!
//! Grammar: `qafel <subcommand> [positional...] [--key value | --key=value
//! | --flag]...`. Repeated options accumulate (used for `--set a.b=c`
//! config overrides). Unknown options are rejected by the caller via
//! [`Args::finish`].

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, Vec<String>>,
    consumed: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // "--" ends option parsing
                    args.positional.extend(it);
                    break;
                }
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let value = match inline {
                    Some(v) => v,
                    None => {
                        // consume the next token as a value unless it looks
                        // like another option
                        match it.peek() {
                            Some(nxt) if !nxt.starts_with("--") => it.next().unwrap(),
                            _ => "true".to_string(),
                        }
                    }
                };
                args.options.entry(key).or_default().push(value);
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// From the process environment.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// First positional argument (the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// Last value of a `--key` option.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.consumed.borrow_mut().insert(key.to_string());
        self.options.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All values of a repeated `--key` option.
    pub fn opts(&self, key: &str) -> Vec<&str> {
        self.consumed.borrow_mut().insert(key.to_string());
        self.options.get(key).map(|v| v.iter().map(|s| s.as_str()).collect()).unwrap_or_default()
    }

    /// Boolean flag (`--flag` or `--flag true/false`).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.opt(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Typed option parse.
    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow!("--{key} {s}: {e}")),
        }
    }

    /// Typed option with default.
    pub fn opt_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.opt_parse(key)?.unwrap_or(default))
    }

    /// Error on any option that was never queried (catches typos).
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> =
            self.options.keys().filter(|k| !consumed.contains(*k)).collect();
        if !unknown.is_empty() {
            bail!("unknown option(s): {}", unknown.iter().map(|s| format!("--{s}")).collect::<Vec<_>>().join(", "));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("exp table1 --config cfg.toml --set fl.buffer_size=5 --set sim.concurrency=500 --verbose");
        assert_eq!(a.subcommand(), Some("exp"));
        assert_eq!(a.positional[1], "table1");
        assert_eq!(a.opt("config"), Some("cfg.toml"));
        assert_eq!(a.opts("set"), vec!["fl.buffer_size=5", "sim.concurrency=500"]);
        assert!(a.flag("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn equals_form_and_typed() {
        let a = parse("run --seeds=7 --lr=0.5");
        assert_eq!(a.opt_or::<u64>("seeds", 0).unwrap(), 7);
        assert_eq!(a.opt_or::<f64>("lr", 0.0).unwrap(), 0.5);
        assert_eq!(a.opt_or::<u64>("missing", 42).unwrap(), 42);
        assert!(a.opt_or::<u64>("lr", 0).is_err());
        a.finish().unwrap();
    }

    #[test]
    fn finish_rejects_unconsumed() {
        let a = parse("run --typo-flag 3");
        assert!(a.finish().is_err());
        let b = parse("run --ok 3");
        let _ = b.opt("ok");
        b.finish().unwrap();
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse("run -- --not-an-option");
        assert_eq!(a.positional, vec!["run", "--not-an-option"]);
    }

    #[test]
    fn flag_without_value_before_flag() {
        let a = parse("run --fast --config x.toml");
        assert!(a.flag("fast"));
        assert_eq!(a.opt("config"), Some("x.toml"));
    }
}
