//! Tiny CSV writer for experiment outputs (reports/*.csv).

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// Build a CSV document in memory, then persist it.
#[derive(Clone, Debug, Default)]
pub struct CsvWriter {
    comments: Vec<String>,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new(columns: &[&str]) -> CsvWriter {
        CsvWriter {
            comments: Vec::new(),
            header: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Add a `# line` comment emitted before the header — provenance
    /// metadata (config fingerprint, git describe) that spreadsheet
    /// tools and pandas (`comment='#'`) skip.
    pub fn comment(&mut self, line: &str) {
        self.comments.push(line.to_string());
    }

    /// Append a row; must match the header width.
    pub fn row(&mut self, values: &[String]) {
        assert_eq!(values.len(), self.header.len(), "csv row width mismatch");
        self.rows.push(values.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        for c in &self.comments {
            let _ = writeln!(out, "# {c}");
        }
        let _ = writeln!(out, "{}", self.header.iter().map(|c| escape(c)).collect::<Vec<_>>().join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|c| escape(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    pub fn save(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

fn escape(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Format helper: fixed-precision float cell.
pub fn f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_escapes() {
        let mut w = CsvWriter::new(&["a", "b,c"]);
        w.row(&["1".into(), "x\"y".into()]);
        w.row(&[f(1.23456, 2), "plain".into()]);
        let text = w.to_string();
        assert_eq!(text, "a,\"b,c\"\n1,\"x\"\"y\"\n1.23,plain\n");
        assert_eq!(w.len(), 2);
    }

    #[test]
    fn comments_precede_header() {
        let mut w = CsvWriter::new(&["a"]);
        w.comment("config deadbeef");
        w.row(&["1".into()]);
        assert_eq!(w.to_string(), "# config deadbeef\na\n1\n");
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut w = CsvWriter::new(&["a"]);
        w.row(&["1".into(), "2".into()]);
    }
}
