//! Communication accounting and training curves — the quantities the
//! paper's evaluation reports (Figures 3–4, Tables 1–2).
//!
//! Conventions (matching the paper's broadcast mode, §2):
//! * **uploads** — one per client trip; `upload_bytes` is the sum of the
//!   actual wire payloads produced by the client quantizer codec.
//! * **broadcasts** — one message per server step (a network broadcast is
//!   counted once, not per recipient): "the MB broadcasted are simply the
//!   MB uploaded divided by the buffer size" (Fig. 3 caption).

pub mod csv;

use crate::scenario::ScenarioMetrics;
use crate::telemetry::StageTimings;

/// Running communication totals for one run.
#[derive(Clone, Debug, Default)]
pub struct CommMetrics {
    /// Client -> server messages (client trips).
    pub uploads: u64,
    /// Total bytes uploaded by clients.
    pub upload_bytes: u64,
    /// Server -> clients broadcast messages (= server steps).
    pub broadcasts: u64,
    /// Total broadcast bytes (one copy per broadcast).
    pub broadcast_bytes: u64,
}

impl CommMetrics {
    pub fn record_upload(&mut self, bytes: usize) {
        self.uploads += 1;
        self.upload_bytes += bytes as u64;
    }

    pub fn record_broadcast(&mut self, bytes: usize) {
        self.broadcasts += 1;
        self.broadcast_bytes += bytes as u64;
    }

    /// Mean kB per upload (the paper's kB/upload column).
    pub fn kb_per_upload(&self) -> f64 {
        if self.uploads == 0 {
            0.0
        } else {
            self.upload_bytes as f64 / self.uploads as f64 / 1000.0
        }
    }

    /// Mean kB per broadcast (the paper's kB/download column).
    pub fn kb_per_download(&self) -> f64 {
        if self.broadcasts == 0 {
            0.0
        } else {
            self.broadcast_bytes as f64 / self.broadcasts as f64 / 1000.0
        }
    }

    pub fn upload_mb(&self) -> f64 {
        self.upload_bytes as f64 / 1e6
    }

    pub fn broadcast_mb(&self) -> f64 {
        self.broadcast_bytes as f64 / 1e6
    }
}

/// One point on the training curve (recorded at each evaluation).
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    /// Virtual time (simulator clock) or wall seconds (net mode).
    pub time: f64,
    pub server_steps: u64,
    pub uploads: u64,
    pub upload_mb: f64,
    pub broadcast_mb: f64,
    pub val_loss: f64,
    pub val_accuracy: f64,
    /// ||grad f||^2 when the backend can compute it (analytic backends).
    pub grad_norm_sq: Option<f64>,
}

/// Result of one simulated/real run.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Evaluation history.
    pub curve: Vec<CurvePoint>,
    /// Snapshot at the first eval where val_accuracy >= target (None if
    /// the run hit a cap first).
    pub reached: Option<CurvePoint>,
    /// Final communication totals.
    pub comm: CommMetrics,
    /// Totals at the end of the run.
    pub final_accuracy: f64,
    pub server_steps: u64,
    /// Wall-clock seconds the run took to execute (not virtual time).
    pub wall_seconds: f64,
    /// Per-tier population metrics (staleness histograms, dropouts,
    /// bytes by tier, concurrency/snapshot tracking). A single "default"
    /// tier for runs without a `[scenario]` table.
    pub scenario: ScenarioMetrics,
    /// Cumulative per-stage server-step timings. `steps` always counts;
    /// the `_ns` fields are populated only while telemetry spans are on
    /// ([`crate::telemetry::set_enabled`]) — zero otherwise.
    pub stage_timings: StageTimings,
    /// Stable fingerprint of (resolved config, seed) — see
    /// [`crate::telemetry::run_fingerprint`]. Ties every result row back
    /// to the exact configuration that produced it.
    pub fingerprint: String,
}

impl RunResult {
    /// The paper's headline metrics, taken at target-reach when available
    /// (otherwise at the end of the run).
    pub fn at_target(&self) -> CurvePoint {
        self.reached.or_else(|| self.curve.last().copied()).unwrap_or(CurvePoint {
            time: 0.0,
            server_steps: 0,
            uploads: 0,
            upload_mb: 0.0,
            broadcast_mb: 0.0,
            val_loss: f64::NAN,
            val_accuracy: 0.0,
            grad_norm_sq: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comm_accounting() {
        let mut m = CommMetrics::default();
        for _ in 0..10 {
            m.record_upload(15_000);
        }
        m.record_broadcast(15_000);
        assert_eq!(m.uploads, 10);
        assert_eq!(m.broadcasts, 1);
        assert!((m.kb_per_upload() - 15.0).abs() < 1e-9);
        assert!((m.kb_per_download() - 15.0).abs() < 1e-9);
        // Fig. 3 caption identity: broadcast MB = upload MB / K when the
        // same quantizer is used in both directions and K uploads per step
        assert!((m.broadcast_mb() - m.upload_mb() / 10.0).abs() < 1e-12);
    }

    #[test]
    fn at_target_prefers_reach_point() {
        let p1 = CurvePoint {
            time: 1.0, server_steps: 5, uploads: 50, upload_mb: 1.0,
            broadcast_mb: 0.1, val_loss: 0.5, val_accuracy: 0.91,
            grad_norm_sq: None,
        };
        let p2 = CurvePoint { time: 2.0, val_accuracy: 0.95, ..p1 };
        let r = RunResult {
            curve: vec![p1, p2],
            reached: Some(p1),
            comm: CommMetrics::default(),
            final_accuracy: 0.95,
            server_steps: 10,
            wall_seconds: 0.0,
            scenario: Default::default(),
            stage_timings: Default::default(),
            fingerprint: String::new(),
        };
        assert_eq!(r.at_target().uploads, 50);
        let r2 = RunResult { reached: None, ..r };
        assert_eq!(r2.at_target().time, 2.0);
    }
}
