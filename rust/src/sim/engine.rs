//! The virtual-time engine driving [`Server`] + [`ClientLogic`] over a
//! [`Backend`], with the client population owned by the scenario engine
//! ([`crate::scenario`], DESIGN_SCENARIOS.md).

use crate::config::{Config, TierConfig};
use crate::coordinator::{AggOutcome, Broadcast, ClientLogic, EdgeAggregator, Server, ServerStep};
use crate::metrics::{CurvePoint, RunResult};
use crate::scenario::metrics::EdgeMetrics;
use crate::runtime::Backend;
use crate::scenario::{
    Adversary, ArrivalProcess, Sampling, Scenario, ScenarioMetrics, SnapshotStore,
};
use crate::telemetry::event::{hex_f32s, hex_u64, parse_hex_f32s, parse_hex_u64};
use crate::telemetry::{
    self, progress_line, truncate_after_last_checkpoint, Event as JEvent, JournalWriter,
};
use crate::util::json::Json;
use crate::util::pool::ShardPool;
use crate::util::prng::Prng;
use anyhow::{anyhow, bail, Result};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A scheduled simulator event.
enum EventKind {
    /// A new client becomes available and starts training.
    Arrival,
    /// A client finishes (training + transfers) and uploads — or drops.
    Finish {
        user: usize,
        /// Index into the scenario's tier list.
        tier: usize,
        /// Server step at start time: the key of the hidden-state
        /// snapshot in the [`SnapshotStore`] (Algorithm 2 line 1) and
        /// the baseline for staleness. In-flight clients carry this u64
        /// instead of an `Arc` snapshot each — memory stays O(distinct
        /// model versions) no matter the concurrency.
        t_start: u64,
        /// Unique per-trip id (drives batch sampling + quantizer noise).
        trip: u64,
        /// Client drops before uploading (decided at arrival from the
        /// tier's dropout probability; the lazy compute is skipped).
        dropped: bool,
        /// A dropped client salvaging partial work: the completed
        /// fraction `m/P` of its local steps. The client stops training
        /// at `fraction * duration`, scales its delta by the fraction
        /// (FedBuff partial-work semantics) and still uploads.
        partial: Option<f32>,
    },
}

struct Event {
    time: f64,
    /// Tie-breaker making heap order fully deterministic.
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap via reversed comparison on (time, seq). `total_cmp`
        // (IEEE totalOrder) keeps this a strict weak ordering even for
        // NaN/-0.0 times — `partial_cmp(..).unwrap_or(Equal)` would
        // report NaN as "equal" to everything, which is intransitive and
        // silently corrupts BinaryHeap order (and with it determinism).
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Extra knobs not in the experiment config.
#[derive(Clone, Debug, Default)]
pub struct SimOptions {
    /// Print progress lines.
    pub verbose: bool,
    /// Stop once target accuracy is reached (default true). The
    /// convergence experiment turns this off to run a fixed horizon.
    pub run_past_target: bool,
    /// Record ‖x−x̂‖² at each eval (hidden-state error trace, Lemma F.9).
    pub trace_hidden_error: bool,
    /// Resume from the journal at `cfg.telemetry.journal`: truncate it
    /// to its last checkpoint, restore the engine state saved there and
    /// continue the run, appending to the same journal. The finished
    /// journal is bit-identical to an uninterrupted run's.
    pub resume: bool,
}

/// The pending-event min-heap plus the monotone sequence counter that
/// makes its order fully deterministic — and checkpointable: a resume
/// restores both the heap entries (with their original `seq`s) and the
/// counter, so post-resume pushes continue the same total order.
struct EventQueue {
    heap: BinaryHeap<Event>,
    seq: u64,
}

impl EventQueue {
    fn new() -> EventQueue {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    fn push(&mut self, time: f64, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Event { time, seq, kind });
    }

    fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }
}

/// The simulator.
pub struct SimEngine<'a> {
    cfg: &'a Config,
    backend: &'a dyn Backend,
    seed: u64,
}

impl<'a> SimEngine<'a> {
    pub fn new(cfg: &'a Config, backend: &'a dyn Backend, seed: u64) -> SimEngine<'a> {
        SimEngine { cfg, backend, seed }
    }

    /// Run one simulation; deterministic in (cfg, backend, seed).
    pub fn run(&self) -> Result<RunResult> {
        self.run_with(&SimOptions::default())
    }

    /// Run, also receiving the hidden-error trace when requested
    /// (returned as the second element).
    pub fn run_with(&self, opts: &SimOptions) -> Result<RunResult> {
        Ok(self.run_traced(opts)?.0)
    }

    pub fn run_traced(&self, opts: &SimOptions) -> Result<(RunResult, Vec<f64>)> {
        let wall_start = std::time::Instant::now();
        let tel = &self.cfg.telemetry;
        if tel.checkpoint_every > 0 && self.cfg.scenario.aggregators.edges > 0 {
            bail!(
                "telemetry.checkpoint_every is not supported with \
                 scenario.aggregators.edges > 0 (edge buffers are not checkpointed)"
            );
        }
        if tel.checkpoint_every > 0 && self.cfg.scenario.adaptive.enabled {
            bail!(
                "telemetry.checkpoint_every is not supported with \
                 [scenario.adaptive] (controller window state is not checkpointed)"
            );
        }
        if opts.resume && tel.journal.is_none() {
            bail!("resume needs telemetry.journal (the journal to resume from)");
        }
        // Spans cost one clock read per stage — turn them on whenever
        // the run is being observed. Unobserved runs (benches) keep the
        // disabled fast path.
        if tel.journal.is_some() || tel.progress > 0 {
            telemetry::set_enabled(true);
        }

        // Resume: cut the journal back to its last checkpoint (dropping
        // whatever the kill tore off) and pick up the state saved there.
        // The dropped suffix is re-executed bit-identically, because
        // every mutable piece of the run is restored below.
        let resume_state: Option<Json> = if opts.resume {
            let path = tel.journal.as_deref().unwrap();
            let prefix = truncate_after_last_checkpoint(path)?;
            let Some(JEvent::Meta { runtime, fingerprint, .. }) = prefix.first() else {
                bail!("journal '{path}' does not start with a meta event");
            };
            if runtime != "sim" {
                bail!("journal '{path}' was recorded by runtime '{runtime}', not the simulator");
            }
            let want = telemetry::run_fingerprint(self.cfg, self.seed);
            if *fingerprint != want {
                bail!(
                    "journal '{path}' was recorded under fingerprint {fingerprint}, but \
                     this config/seed fingerprints as {want} — resume with the original config"
                );
            }
            let Some(JEvent::Checkpoint { state, .. }) = prefix.last() else {
                bail!("journal '{path}' has no checkpoint to resume from");
            };
            Some(state.clone())
        } else {
            None
        };
        let mut journal: Option<JournalWriter> = match (tel.journal.as_deref(), opts.resume) {
            (Some(path), true) => Some(JournalWriter::append(path)?),
            (Some(path), false) => Some(JournalWriter::create(path)?),
            (None, _) => None,
        };

        let root = Prng::new(self.seed);
        let mut arrival_rng = root.stream("arrivals");
        let mut duration_rng = root.stream("durations");
        let mut sampling_rng = root.stream("client-sampling");
        // Scenario-only randomness lives on its own named streams (and
        // single-tier / zero-dropout / zero-partial-work populations
        // draw nothing from them), so the desugared default consumes
        // exactly the same randomness as the pre-scenario engine —
        // bit-identical trajectories.
        let mut tier_rng = root.stream("scenario-tier");
        let mut dropout_rng = root.stream("scenario-dropout");
        let mut partial_rng = root.stream("scenario-partial");
        // Hostile-population streams: heavy-tailed gradient noise and
        // adversarial rewrites draw here and only here, so populations
        // without grad_noise/adversary specs consume exactly the same
        // randomness as before and replay bit-identically.
        let mut noise_rng = root.stream("scenario-noise");
        let mut adversary_rng = root.stream("scenario-adversary");

        let mut scenario = Scenario::build(self.cfg)?;

        // Stale-replay caches, one per tier: the adversarial cohort acts
        // in concert, replaying the tier's first honest delta forever.
        let mut replay_caches: Vec<Option<Vec<f32>>> = vec![None; scenario.num_tiers()];
        let has_replay = (0..scenario.num_tiers())
            .any(|t| scenario.tier_adversary(t) == Some(Adversary::StaleReplay));
        // Ingest-order tiers of the rows in the server's current trim
        // buffer: maps the per-row trim flags back to tiers at each step.
        // Checkpoints land right after a step, where this is empty.
        let mut buffer_tiers: Vec<usize> = Vec::new();
        let trim_on = self.cfg.fl.robust.trim_enabled();

        // initial model: shared x^0 (Algorithm 1 line 1 / Algorithm 3)
        let x0 = self.backend.init_params(self.seed as i32 & 0x7FFF_FFFF)?;
        let server_seed = root.stream("server").next_u64_here();
        // the journal's init event needs x^0 after it moves into the server
        let mut x0_journal =
            if journal.is_some() && !opts.resume { Some(x0.clone()) } else { None };
        let mut server = Server::build(self.cfg, x0, server_seed)?;
        let mut logic = ClientLogic::new(self.cfg, root.stream("client").next_u64_here())?;
        let d = server.d();

        // Per-tier quantizer presets: register each tier's upload codec
        // on both ends (same order => same ids; identical resolved
        // codecs dedup, so a no-preset run keeps exactly one codec and
        // the single-codec ingest path). Each registration is journaled
        // in order — replay re-registers and asserts the same ids.
        let mut codec_events: Vec<JEvent> = Vec::new();
        let mut tier_codec = vec![0usize; scenario.num_tiers()];
        for tier in 0..scenario.num_tiers() {
            if let Some(spec) = scenario.tier_quant_client(tier) {
                let sid = server.register_client_codec(spec)?;
                let cid = logic.register_codec(spec)?;
                if sid != cid {
                    bail!(
                        "internal: codec id mismatch for tier {tier} preset '{spec}' \
                         (server {sid}, client {cid})"
                    );
                }
                tier_codec[tier] = sid;
                codec_events.push(JEvent::Codec {
                    reg: "client".into(),
                    id: sid as u64,
                    spec: spec.to_string(),
                });
            }
        }
        // Adaptive codec ladder (`[scenario.adaptive]`): registered up
        // front — right after the tier presets, mirroring the TCP
        // leader's ordering — so every level's registry entry is in the
        // journal header and a mid-run Rekey never races a Codec event.
        // The registry dedups by resolved name, so levels shared with
        // tier presets (or resolving identically, e.g. under fedbuff)
        // cost nothing. Sorted by encoded size ascending: "one level
        // down" = the next cheaper entry.
        let adaptive = self.cfg.scenario.adaptive.clone();
        let mut ladder: Vec<(usize, String, u64)> = Vec::new(); // (id, name, bytes/upload)
        if adaptive.enabled {
            for spec in &adaptive.levels {
                let sid = server.register_client_codec(spec)?;
                let cid = logic.register_codec(spec)?;
                if sid != cid {
                    bail!(
                        "internal: codec id mismatch for adaptive level '{spec}' \
                         (server {sid}, client {cid})"
                    );
                }
                if !ladder.iter().any(|&(lid, ..)| lid == sid) {
                    let name = logic.codec_name(sid);
                    let bytes = logic.upload_bytes_for(sid, d) as u64;
                    ladder.push((sid, name, bytes));
                    codec_events.push(JEvent::Codec {
                        reg: "client".into(),
                        id: sid as u64,
                        spec: spec.to_string(),
                    });
                }
            }
            ladder.sort_by_key(|&(_, _, b)| b);
        }
        // Tier score for the controller: the configured uplink bandwidth
        // (the sim analog of a TCP worker's Hello hint; 0 = unlimited).
        let tier_mbps: Vec<f64> =
            self.cfg.resolved_tiers().iter().map(|t| t.upload_mbps).collect();

        for tier in 0..scenario.num_tiers() {
            scenario.metrics.tiers[tier].codec = logic.codec_name(tier_codec[tier]);
            if let Some(n) = scenario.tier_grad_noise(tier) {
                scenario.metrics.tiers[tier].grad_noise = n.name();
            }
            if let Some(a) = scenario.tier_adversary(tier) {
                scenario.metrics.tiers[tier].adversary = a.name();
            }
        }

        // Per-tier downlink (broadcast) codecs: each `quant_server`
        // preset resolves to a downlink family in the server — its own
        // Q_s plus its own hidden-state replica x̂_f, deduped by resolved
        // spec so a no-preset run keeps exactly one family and the
        // single-broadcast step path (bit-identical to the pre-family
        // engine). Registrations are journaled in tier order; replay
        // re-registers and asserts the same family ids.
        let mut tier_family = vec![0usize; scenario.num_tiers()];
        for tier in 0..scenario.num_tiers() {
            if let Some(spec) = scenario.tier_quant_server(tier) {
                let fid = server.register_server_codec(spec)?;
                tier_family[tier] = fid;
                codec_events.push(JEvent::Codec {
                    reg: "server".into(),
                    id: fid as u64,
                    spec: spec.to_string(),
                });
                if fid != 0 {
                    scenario.metrics.tiers[tier].download_codec = server.server_codec_name(fid);
                }
            }
        }

        // Hierarchical aggregation (tree-of-leaders): K edge aggregators
        // each own a contiguous slice of the user population; uploads
        // route through the owning edge, which forwards a count-weighted
        // quantized partial to the root on buffer-full. edges == 0 keeps
        // the flat path and draws nothing from the new "edge-agg" stream,
        // so existing runs replay bit-identical.
        let agg_cfg = &self.cfg.scenario.aggregators;
        let mut edges: Vec<EdgeAggregator> = Vec::with_capacity(agg_cfg.edges);
        if agg_cfg.edges > 0 {
            let pid = server.register_partial_codec(&agg_cfg.partial_codec)?;
            if pid != 0 {
                bail!("internal: partial codec '{}' registered at id {pid}", agg_cfg.partial_codec);
            }
            codec_events.push(JEvent::Codec {
                reg: "partial".into(),
                id: 0,
                spec: agg_cfg.partial_codec.clone(),
            });
            let edge_seeds = root.stream("edge-agg");
            for e in 0..agg_cfg.edges {
                let mut edge = EdgeAggregator::new(
                    d,
                    agg_cfg.buffer_size,
                    &agg_cfg.partial_codec,
                    &self.cfg.quant.client,
                    self.cfg.fl.algorithm,
                    self.cfg.fl.staleness_scaling,
                    server.pool().clone(),
                    edge_seeds.stream_u64(e as u64).next_u64_here(),
                )?
                // robust clipping commutes with count-weighted partial
                // forwarding when applied at the tree's ingest points:
                // edges clip raw updates, the root ingests the pre-
                // clipped partials untouched (trim is rejected upstream)
                .with_robust(&self.cfg.fl.robust);
                // same registration order as the server/client pair above
                // => same codec ids on every node of the tree
                let ids = edge.register_tier_presets(self.cfg)?;
                if ids != tier_codec {
                    bail!("internal: edge {e} codec ids {ids:?} != server ids {tier_codec:?}");
                }
                edges.push(edge);
            }
        }

        // Per-tier user pools (opt-in): correlate tier membership with
        // data distribution by giving each tier a contiguous user slice.
        // Off (default) keeps the shared full-population draw and is
        // bit-identical to the pre-pool engine (same single Lemire draw).
        let n_users = self.backend.num_train_users();
        let user_pools: Option<Vec<(usize, usize)>> = if self.cfg.scenario.tier_user_pools {
            Some(tier_user_ranges(&self.cfg.resolved_tiers(), n_users)?)
        } else {
            None
        };

        // Per-trip wire sizes for tier bandwidth delays + byte metrics.
        // Every codec emits fixed-size payloads, so these are exact; the
        // download is one hidden-state increment (broadcast mode). The
        // arrival rate is recalibrated with them so bandwidth-limited
        // tiers don't overshoot the target concurrency (algorithms with
        // bigger payloads would otherwise run at different effective
        // concurrency from the same config) — per tier, since preset
        // codecs change a tier's upload size.
        // (`mut`: a mid-run adaptive rekey re-prices the tier's uplink;
        // the arrival-rate calibration below is start-of-run only.)
        let mut tier_upload_bytes: Vec<usize> = tier_codec
            .iter()
            .map(|&codec| logic.upload_bytes_for(codec, d))
            .collect();
        let tier_download_bytes: Vec<usize> = tier_family
            .iter()
            .map(|&f| server.server_codec_bytes(f))
            .collect();
        scenario.recalibrate_per_tier(&tier_upload_bytes, &tier_download_bytes);
        let mut arrival = scenario.arrival_process()?;

        // Eval reductions run on the server's persistent shard pool
        // (fl.eval_shards sizes a dedicated pool instead when set);
        // results are bit-identical for every pool size.
        let eval_pool = match self.cfg.fl.eval_shards {
            0 => server.pool().clone(),
            s if s == server.pool().shards() => server.pool().clone(),
            s => ShardPool::new(s),
        };

        // Versioned snapshot stores, one per downlink family: all
        // clients of a family arriving between two server steps share
        // one Arc (O(versions * families) memory, not O(clients)). A
        // tier's clients copy *their family's* hidden state x̂_f at
        // round start, mirroring what a real worker on that tier's
        // downlink codec would hold.
        let mut stores: Vec<SnapshotStore> = (0..server.num_server_codecs())
            .map(|f| SnapshotStore::new(server.t(), server.family_snapshot(f)))
            .collect();

        // Adaptive-controller observation window: per-tier uploads and
        // wire bytes since the last controller pass. Plain counting —
        // never serialized, never drawn from — so recording it cannot
        // perturb an adaptive-off run.
        let mut win_uploads: Vec<u64> = vec![0; scenario.num_tiers()];
        let mut win_bytes: Vec<u64> = vec![0; scenario.num_tiers()];

        let mut queue = EventQueue::new();
        let mut trips = 0u64;
        let mut curve: Vec<CurvePoint> = Vec::new();
        let mut reached: Option<CurvePoint> = None;
        let mut hidden_trace: Vec<f64> = Vec::new();
        let mut last_eval_t = 0u64;

        // concurrency tracking (Little's-law calibration check):
        // time-integral of the in-flight count
        let mut in_flight = 0usize;
        let mut max_in_flight = 0usize;
        let mut in_flight_area = 0.0f64;
        let mut clock = 0.0f64;
        // update slots consumed since the last server step — the journal
        // Step event's k, mirroring replay's accounting. Checkpoints are
        // written immediately after a step, so this is 0 at every
        // checkpoint and needs no restoring.
        let mut slots_since_step = 0u64;
        // wall seconds the run accumulated before this process (resume)
        let mut wall_offset = 0.0f64;
        // the previous progress Step event, for --progress deltas
        let mut prev_progress: Option<JEvent> = None;

        if let Some(state) = &resume_state {
            // Restore the killed run, piece by piece. Everything mutable
            // is covered: server (model, hidden state, buffer, momentum,
            // quantizer rng, comm/staleness totals), client quantizer
            // rng, the six scenario streams, arrival-process state, the
            // pending event heap + seq counter, the snapshot store, tier
            // metrics, and the curve recorded so far.
            server.restore_state(field(state, "server")?)?;
            let r = field(state, "rng")?;
            logic.restore_rng(rng_from_json(r, "client")?);
            arrival_rng = Prng::from_state(rng_from_json(r, "arrivals")?);
            duration_rng = Prng::from_state(rng_from_json(r, "durations")?);
            sampling_rng = Prng::from_state(rng_from_json(r, "sampling")?);
            tier_rng = Prng::from_state(rng_from_json(r, "tier")?);
            dropout_rng = Prng::from_state(rng_from_json(r, "dropout")?);
            partial_rng = Prng::from_state(rng_from_json(r, "partial")?);
            if scenario.any_hostile() {
                noise_rng = Prng::from_state(rng_from_json(r, "noise")?);
                adversary_rng = Prng::from_state(rng_from_json(r, "adversary")?);
            }
            arrival.restore(&f64s_from_json(state, "arrival")?)?;
            clock = jf64(state, "clock")?;
            trips = ju64(state, "trips")?;
            in_flight_area = jf64(state, "in_flight_area")?;
            max_in_flight = ju64(state, "max_in_flight")? as usize;
            last_eval_t = ju64(state, "last_eval_t")?;
            wall_offset = jf64(state, "wall")?;
            queue.seq = ju64(state, "seq")?;
            heap_from_json(field(state, "heap")?, &mut queue)?;
            in_flight = queue
                .heap
                .iter()
                .filter(|e| matches!(e.kind, EventKind::Finish { .. }))
                .count();
            stores[0] = store_from_json(field(state, "store")?)?;
            // extra downlink-family stores ride in a conditional field
            // (absent on single-family checkpoints, byte-identity)
            match state.get("store_extra") {
                Some(extra) => {
                    let parts = extra
                        .as_arr()
                        .ok_or_else(|| anyhow!("checkpoint: store_extra is not an array"))?;
                    if parts.len() != stores.len().saturating_sub(1) {
                        bail!(
                            "checkpoint has {} extra snapshot stores but this config \
                             resolves {} downlink families — resume with the original config",
                            parts.len(),
                            stores.len()
                        );
                    }
                    for (i, p) in parts.iter().enumerate() {
                        stores[i + 1] = store_from_json(p)?;
                    }
                }
                None if stores.len() > 1 => bail!(
                    "checkpoint has a single snapshot store but this config resolves \
                     {} downlink families — resume with the original config",
                    stores.len()
                ),
                None => {}
            }
            if has_replay {
                let parts = field(state, "replay")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("checkpoint: replay is not an array"))?;
                if parts.len() != replay_caches.len() {
                    bail!(
                        "checkpoint has {} stale-replay caches but the scenario has {} tiers",
                        parts.len(),
                        replay_caches.len()
                    );
                }
                for (c, p) in replay_caches.iter_mut().zip(parts) {
                    *c = match p {
                        Json::Null => None,
                        s => Some(parse_hex_f32s(s.as_str().ok_or_else(|| {
                            anyhow!("checkpoint: stale-replay cache is not a string")
                        })?)?),
                    };
                }
            }
            let metrics = ScenarioMetrics::from_json(field(state, "metrics")?)?;
            if metrics.tiers.len() != scenario.metrics.tiers.len() {
                bail!(
                    "checkpoint has {} tiers but the scenario has {}",
                    metrics.tiers.len(),
                    scenario.metrics.tiers.len()
                );
            }
            scenario.metrics = metrics;
            curve = curve_from_json(field(state, "curve")?)?;
            reached = match field(state, "reached")? {
                Json::Null => None,
                p => Some(point_from_json(p)?),
            };
            hidden_trace = f64s_from_json(state, "hidden_trace")?;
        } else {
            if let Some(j) = journal.as_mut() {
                j.write(&JEvent::Meta {
                    runtime: "sim".into(),
                    algorithm: self.cfg.fl.algorithm.name().into(),
                    d: d as u64,
                    seed: self.seed,
                    fingerprint: telemetry::run_fingerprint(self.cfg, self.seed),
                    git: telemetry::git_describe(),
                    config: self.cfg.to_json(),
                })?;
                j.write(&JEvent::Init {
                    x0: x0_journal.take().unwrap_or_default(),
                    server_seed,
                })?;
                for ev in &codec_events {
                    j.write(ev)?;
                }
            }
            queue.push(0.0, EventKind::Arrival);

            // evaluate x^0 so curves start at t=0
            let ev0 = self.backend.evaluate_pooled(server.model(), &eval_pool)?;
            curve.push(CurvePoint {
                time: 0.0,
                server_steps: 0,
                uploads: 0,
                upload_mb: 0.0,
                broadcast_mb: 0.0,
                val_loss: ev0.loss,
                val_accuracy: ev0.accuracy,
                grad_norm_sq: ev0.grad_norm_sq,
            });
            if let Some(j) = journal.as_mut() {
                j.write(&JEvent::Eval {
                    time: 0.0,
                    step: 0,
                    uploads: 0,
                    val_loss: ev0.loss,
                    val_accuracy: ev0.accuracy,
                })?;
            }
        }

        while let Some(ev) = queue.pop() {
            in_flight_area += in_flight as f64 * (ev.time - clock);
            clock = ev.time;
            match ev.kind {
                EventKind::Arrival => {
                    // Weighted sampling draws by weight alone and
                    // discards off-window arrivals (the pre-v2 path,
                    // bit-identical); availability sampling draws among
                    // the tiers that are on right now.
                    let tier = match scenario.sampling() {
                        Sampling::Weighted => {
                            let tier = scenario.sample_tier(&mut tier_rng);
                            if scenario.available(tier, clock) {
                                Some(tier)
                            } else {
                                scenario.metrics.record_unavailable(tier);
                                None
                            }
                        }
                        Sampling::Availability => {
                            let picked = scenario.sample_available_tier(clock, &mut tier_rng);
                            if picked.is_none() {
                                scenario.metrics.record_all_off();
                            }
                            picked
                        }
                    };
                    if let Some(tier) = tier {
                        // this client starts training now
                        scenario.metrics.record_arrival(tier);
                        let user = match &user_pools {
                            Some(ranges) => {
                                let (lo, hi) = ranges[tier];
                                sampling_rng.range(lo, hi)
                            }
                            None => sampling_rng.range(0, n_users),
                        };
                        let dur = scenario.sample_duration(tier, &mut duration_rng).max(1e-9);
                        let dropped = scenario.sample_dropout(tier, &mut dropout_rng);
                        // a dropped client may salvage partial work:
                        // train an m/P prefix, then upload it anyway
                        let partial = if dropped {
                            scenario.sample_partial(tier, &mut partial_rng)
                        } else {
                            None
                        };
                        let t_start = stores[tier_family[tier]].acquire();
                        let trip = trips;
                        trips += 1;
                        in_flight += 1;
                        max_in_flight = max_in_flight.max(in_flight);
                        // residency = download + training (+ upload,
                        // unless the client drops without submitting)
                        let trained = match partial {
                            Some(f) => dur * f as f64,
                            None => dur,
                        };
                        let mut delay =
                            scenario.download_delay(tier, tier_download_bytes[tier]);
                        if !dropped || partial.is_some() {
                            delay += scenario.upload_delay(tier, tier_upload_bytes[tier]);
                        }
                        queue.push(
                            clock + trained + delay,
                            EventKind::Finish { user, tier, t_start, trip, dropped, partial },
                        );
                        if let Some(j) = journal.as_mut() {
                            j.write(&JEvent::Arrival {
                                time: clock,
                                tier: scenario.metrics.tiers[tier].name.clone(),
                                user: user as u64,
                                trip,
                                t_start,
                                dropped,
                                partial: partial.map(f64::from),
                            })?;
                        }
                    }
                    // schedule the next arrival
                    let gap = arrival.next_gap(&mut arrival_rng);
                    queue.push(clock + gap, EventKind::Arrival);
                }
                EventKind::Finish { user, tier, t_start, trip, dropped, partial } => {
                    in_flight -= 1;
                    if dropped && partial.is_none() {
                        // trained, downloaded, never uploaded — skip the
                        // lazy compute entirely and release the version
                        stores[tier_family[tier]].release(t_start);
                        scenario.metrics.record_dropout(tier, tier_download_bytes[tier]);
                        continue;
                    }
                    // lazy compute against the start-time snapshot of
                    // the tier's downlink family; a partial dropper
                    // submits scale * delta on the tier's own upload
                    // codec
                    let snapshot = stores[tier_family[tier]]
                        .get(t_start)
                        .map_err(|e| anyhow!("{e} (trip {trip})"))?
                        .clone();
                    let codec = tier_codec[tier];
                    let scale = partial.unwrap_or(1.0);
                    let noise = scenario.tier_grad_noise(tier);
                    let adversary = scenario.tier_adversary(tier);
                    let upload = if noise.is_some() || adversary.is_some() {
                        // hostile tier: rewrite the honest delta on the
                        // scenario streams — noise first (a "bad data"
                        // client), then the adversary (which controls
                        // whatever bytes it ships)
                        let cache = &mut replay_caches[tier];
                        let mut hostile = |delta: &mut [f32]| {
                            if let Some(n) = &noise {
                                n.apply(delta, &mut noise_rng);
                            }
                            if let Some(a) = &adversary {
                                a.apply(delta, cache, &mut adversary_rng);
                            }
                        };
                        logic.run_round_transformed(
                            self.backend,
                            &snapshot,
                            user,
                            trip,
                            codec,
                            scale,
                            Some(&mut hostile),
                        )?
                    } else {
                        logic.run_round_with(self.backend, &snapshot, user, trip, codec, scale)?
                    };
                    drop(snapshot);
                    stores[tier_family[tier]].release(t_start);
                    let staleness = server.t() - t_start;
                    if partial.is_some() {
                        scenario.metrics.record_partial_upload(
                            tier,
                            staleness,
                            upload.msg.wire_bytes(),
                            tier_download_bytes[tier],
                        );
                    } else {
                        scenario.metrics.record_upload(
                            tier,
                            staleness,
                            upload.msg.wire_bytes(),
                            tier_download_bytes[tier],
                        );
                    }
                    win_uploads[tier] += 1;
                    win_bytes[tier] += upload.msg.wire_bytes() as u64;
                    let produced: Option<Vec<Broadcast>> = if edges.is_empty() {
                        if let Some(j) = journal.as_mut() {
                            j.write(&JEvent::Ingest {
                                time: clock,
                                step: server.t(),
                                worker: user as u64,
                                codec: codec as u64,
                                staleness,
                                payload: upload.msg.payload.clone(),
                            })?;
                        }
                        slots_since_step += 1;
                        if trim_on {
                            buffer_tiers.push(tier);
                        }
                        let outcome = server.ingest_from(&upload.msg, staleness, codec)?;
                        if server.last_ingest_clipped() {
                            scenario.metrics.record_clipped(tier);
                        }
                        match outcome {
                            ServerStep::Buffered => None,
                            ServerStep::Stepped(b) => {
                                // per-row trim flags are in ingest order,
                                // the same order buffer_tiers recorded
                                for (&t, &flagged) in
                                    buffer_tiers.iter().zip(server.last_trim_flags())
                                {
                                    if flagged {
                                        scenario.metrics.record_trimmed(t);
                                    }
                                }
                                buffer_tiers.clear();
                                Some(b)
                            }
                        }
                    } else {
                        // contiguous ownership: edge e owns users
                        // [e*n/K, (e+1)*n/K)
                        let e = user * edges.len() / n_users;
                        let clipped_before = edges[e].clipped_updates;
                        let edge_outcome = edges[e].ingest_from(&upload.msg, staleness, codec)?;
                        if edges[e].clipped_updates > clipped_before {
                            scenario.metrics.record_clipped(tier);
                        }
                        match edge_outcome {
                            AggOutcome::Buffered => None,
                            AggOutcome::Forward(p) => {
                                if let Some(j) = journal.as_mut() {
                                    j.write(&JEvent::IngestPartial {
                                        time: clock,
                                        step: server.t(),
                                        worker: e as u64,
                                        codec: 0,
                                        count: u64::from(p.count),
                                        stale_counts: p.staleness.counts.clone(),
                                        stale_sum: p.staleness.sum,
                                        stale_max: p.staleness.max,
                                        stale_n: p.staleness.n,
                                        payload: p.msg.payload.clone(),
                                    })?;
                                }
                                slots_since_step += u64::from(p.count);
                                match server.ingest_partial(&p.msg, p.count, &p.staleness, 0)? {
                                    ServerStep::Buffered => None,
                                    ServerStep::Stepped(b) => Some(b),
                                }
                            }
                            AggOutcome::Stepped(_) => {
                                bail!("internal: edge {e} stepped (edges never step)")
                            }
                        }
                    };
                    let stepped = produced.is_some();
                    if let Some(bs) = produced {
                        for (f, st) in stores.iter_mut().enumerate() {
                            st.publish(server.t(), server.family_snapshot(f));
                        }
                        let step_ev = JEvent::Step {
                            time: clock,
                            step: server.t(),
                            k: slots_since_step,
                            uploads: server.comm.uploads,
                            upload_bytes: server.comm.upload_bytes,
                            broadcast_bytes: server.comm.broadcast_bytes,
                            stale_mean: server.staleness_mean(),
                            stale_max: server.staleness_max,
                            stages: telemetry::enabled()
                                .then(|| server.stage_timings().clone()),
                        };
                        slots_since_step = 0;
                        if let Some(j) = journal.as_mut() {
                            j.write(&step_ev)?;
                            // one broadcast event per downlink family,
                            // family 0 first — replay checks each
                            // payload bit-for-bit against its family
                            for b in bs {
                                j.write(&JEvent::Broadcast {
                                    time: clock,
                                    step: b.t,
                                    absolute: b.absolute,
                                    codec: b.codec as u64,
                                    payload: b.msg.payload,
                                })?;
                            }
                        }
                        // Adaptive-quantization controller mirror
                        // (`[scenario.adaptive]`): every `interval`
                        // steps, project the next window's uplink
                        // traffic from the window just observed and
                        // walk the slowest tiers down the ladder until
                        // it fits the budget — the same greedy pass the
                        // TCP leader runs per worker (`net.adaptive`),
                        // keyed by tier. Switches land exactly at this
                        // step boundary: every later ingest (including
                        // trips already in flight, whose compute is
                        // lazy) encodes with the new codec, and the
                        // journal's Rekey event pins the cutover so
                        // replay stays bit-exact.
                        if adaptive.enabled
                            && !ladder.is_empty()
                            && server.t() % adaptive.interval == 0
                        {
                            let interval = adaptive.interval as f64;
                            let n_tiers = scenario.num_tiers();
                            // Eligible for a switch: tiers with enough
                            // window uploads to score. Score: the
                            // configured uplink bandwidth when bounded,
                            // else the observed window upload rate —
                            // lower score = first to downshift.
                            let mut eligible: Vec<(usize, f64)> = Vec::new();
                            for t in 0..n_tiers {
                                if win_uploads[t] < adaptive.min_uploads.max(1) {
                                    continue;
                                }
                                let score = if tier_mbps[t] > 0.0 {
                                    tier_mbps[t]
                                } else {
                                    win_uploads[t] as f64 / interval
                                };
                                eligible.push((t, score));
                            }
                            // Projected bytes/step if nothing changes:
                            // what each tier actually shipped over the
                            // window. Every tier counts toward the
                            // projection (the budget is global).
                            let mut rate: Vec<f64> = vec![0.0; n_tiers];
                            let mut bytes_now: Vec<u64> = vec![0; n_tiers];
                            let mut projected = 0.0f64;
                            for t in 0..n_tiers {
                                rate[t] = win_uploads[t] as f64 / interval;
                                bytes_now[t] = if win_uploads[t] > 0 {
                                    win_bytes[t] / win_uploads[t]
                                } else {
                                    0
                                };
                                projected += win_bytes[t] as f64 / interval;
                            }
                            // Greedy: move the lowest-scored movable
                            // tier one ladder level down (the largest
                            // entry strictly cheaper than its current
                            // codec), cycling until the projection fits
                            // or everyone is at the bottom.
                            let mut switches: Vec<(usize, usize)> = Vec::new();
                            let budget = adaptive.budget_bytes_per_step as f64;
                            while projected > budget {
                                let mut pick: Option<(usize, f64, usize)> = None;
                                for &(t, score) in &eligible {
                                    let cur = switches
                                        .iter()
                                        .rev()
                                        .find(|&&(st, _)| st == t)
                                        .map(|&(_, idx)| ladder[idx].2)
                                        .unwrap_or(bytes_now[t]);
                                    let Some(down) =
                                        ladder.iter().rposition(|&(_, _, b)| b < cur)
                                    else {
                                        continue; // already at the bottom
                                    };
                                    if pick.map_or(true, |(_, best, _)| score < best) {
                                        pick = Some((t, score, down));
                                    }
                                }
                                let Some((t, _, idx)) = pick else { break };
                                let cur = switches
                                    .iter()
                                    .rev()
                                    .find(|&&(st, _)| st == t)
                                    .map(|&(_, i)| ladder[i].2)
                                    .unwrap_or(bytes_now[t]);
                                projected -= rate[t] * (cur - ladder[idx].2) as f64;
                                switches.retain(|&(st, _)| st != t);
                                switches.push((t, idx));
                            }
                            for (t, idx) in switches {
                                let (new_id, ref name, bytes) = ladder[idx];
                                let old_id = tier_codec[t];
                                if new_id == old_id {
                                    continue;
                                }
                                if let Some(j) = journal.as_mut() {
                                    j.write(&JEvent::Rekey {
                                        time: clock,
                                        step: server.t(),
                                        worker: t as u64,
                                        old: old_id as u64,
                                        new: new_id as u64,
                                        spec: name.clone(),
                                    })?;
                                }
                                tier_codec[t] = new_id;
                                tier_upload_bytes[t] = bytes as usize;
                                scenario.metrics.tiers[t].codec = name.clone();
                                scenario.metrics.tiers[t].codec_switches += 1;
                            }
                            // fresh observation window
                            win_uploads.iter_mut().for_each(|v| *v = 0);
                            win_bytes.iter_mut().for_each(|v| *v = 0);
                        }
                        if tel.progress > 0 && server.t() % tel.progress == 0 {
                            if let Some(line) = progress_line(
                                &step_ev,
                                prev_progress.as_ref(),
                                &scenario.metrics.staleness,
                            ) {
                                eprintln!("[qafel] {line}");
                            }
                            prev_progress = Some(step_ev);
                        }
                    }

                    if stepped && server.t() - last_eval_t >= self.cfg.sim.eval_every as u64 {
                        last_eval_t = server.t();
                        let ev = self.backend.evaluate_pooled(server.model(), &eval_pool)?;
                        let point = CurvePoint {
                            time: clock,
                            server_steps: server.t(),
                            uploads: server.comm.uploads,
                            upload_mb: server.comm.upload_mb(),
                            broadcast_mb: server.comm.broadcast_mb(),
                            val_loss: ev.loss,
                            val_accuracy: ev.accuracy,
                            grad_norm_sq: ev.grad_norm_sq,
                        };
                        if opts.trace_hidden_error {
                            hidden_trace.push(server.hidden_state_error_sq());
                        }
                        if opts.verbose {
                            eprintln!(
                                "[sim] t={:>6} uploads={:>7} upMB={:>9.2} acc={:.4} loss={:.4}",
                                point.server_steps,
                                point.uploads,
                                point.upload_mb,
                                point.val_accuracy,
                                point.val_loss
                            );
                        }
                        curve.push(point);
                        if let Some(j) = journal.as_mut() {
                            j.write(&JEvent::Eval {
                                time: clock,
                                step: server.t(),
                                uploads: server.comm.uploads,
                                val_loss: point.val_loss,
                                val_accuracy: point.val_accuracy,
                            })?;
                        }
                        if reached.is_none()
                            && point.val_accuracy >= self.cfg.stop.target_accuracy
                        {
                            reached = Some(point);
                            if !opts.run_past_target {
                                break;
                            }
                        }
                    }
                    if stepped && tel.checkpoint_every > 0 && server.t() % tel.checkpoint_every == 0
                    {
                        if let Some(j) = journal.as_mut() {
                            let mut rng_fields = vec![
                                ("arrivals", rng_json(arrival_rng.state())),
                                ("durations", rng_json(duration_rng.state())),
                                ("sampling", rng_json(sampling_rng.state())),
                                ("tier", rng_json(tier_rng.state())),
                                ("dropout", rng_json(dropout_rng.state())),
                                ("partial", rng_json(partial_rng.state())),
                                ("client", rng_json(logic.rng_state())),
                            ];
                            if scenario.any_hostile() {
                                // conditional: honest checkpoints keep
                                // the pre-robustness byte layout
                                rng_fields.push(("noise", rng_json(noise_rng.state())));
                                rng_fields
                                    .push(("adversary", rng_json(adversary_rng.state())));
                            }
                            let rng = Json::obj(rng_fields);
                            let mut state_fields = vec![
                                ("clock", f64_json(clock)),
                                ("seq", u64_json(queue.seq)),
                                ("trips", u64_json(trips)),
                                ("in_flight_area", f64_json(in_flight_area)),
                                ("max_in_flight", u64_json(max_in_flight as u64)),
                                ("last_eval_t", u64_json(last_eval_t)),
                                (
                                    "wall",
                                    f64_json(
                                        wall_offset + wall_start.elapsed().as_secs_f64(),
                                    ),
                                ),
                                ("server", server.state_json()),
                                ("rng", rng),
                                ("arrival", f64s_json(&arrival.state())),
                                ("heap", heap_json(&queue)),
                                ("store", store_json(&stores[0])),
                            ];
                            if stores.len() > 1 {
                                // extra family stores: conditional so
                                // single-family checkpoints keep the
                                // pre-family byte layout
                                state_fields.push((
                                    "store_extra",
                                    Json::arr(stores[1..].iter().map(store_json).collect()),
                                ));
                            }
                            if has_replay {
                                state_fields.push((
                                    "replay",
                                    Json::arr(
                                        replay_caches
                                            .iter()
                                            .map(|c| match c {
                                                Some(v) => Json::str(hex_f32s(v)),
                                                None => Json::Null,
                                            })
                                            .collect(),
                                    ),
                                ));
                            }
                            state_fields.extend([
                                ("metrics", scenario.metrics.to_json()),
                                ("curve", curve_json(&curve)),
                                ("reached", reached.map_or(Json::Null, |p| point_json(&p))),
                                ("hidden_trace", f64s_json(&hidden_trace)),
                            ]);
                            let state = Json::obj(state_fields);
                            j.write(&JEvent::Checkpoint {
                                time: clock,
                                step: server.t(),
                                state,
                            })?;
                        }
                    }
                    if server.comm.uploads >= self.cfg.stop.max_uploads
                        || server.t() >= self.cfg.stop.max_server_steps
                    {
                        break;
                    }
                }
            }
        }

        if let Some(j) = journal.as_mut() {
            j.write(&JEvent::Final {
                step: server.t(),
                uploads: server.comm.uploads,
                upload_bytes: server.comm.upload_bytes,
                broadcasts: server.comm.broadcasts,
                broadcast_bytes: server.comm.broadcast_bytes,
                model: server.model().to_vec(),
            })?;
        }

        let final_accuracy = curve.last().map(|p| p.val_accuracy).unwrap_or(0.0);
        let mut scenario_metrics = scenario.metrics;
        // per-edge accounting merged up the tree (empty for flat runs);
        // updates still sitting in an edge buffer at the break are
        // counted in `updates` but not in any forwarded partial.
        scenario_metrics.edges = edges
            .iter()
            .enumerate()
            .map(|(edge_id, e)| EdgeMetrics {
                edge_id,
                updates: e.updates,
                update_bytes: e.update_bytes,
                partials: e.forwarded,
                partial_bytes: e.forwarded_bytes,
                staleness: e.staleness.clone(),
            })
            .collect();
        scenario_metrics.mean_concurrency =
            if clock > 0.0 { in_flight_area / clock } else { 0.0 };
        scenario_metrics.max_in_flight = max_in_flight;
        // total model vectors resident across all family stores
        scenario_metrics.max_live_snapshots = stores.iter().map(|s| s.max_live()).sum();
        Ok((
            RunResult {
                curve,
                reached,
                comm: server.comm.clone(),
                final_accuracy,
                server_steps: server.t(),
                wall_seconds: wall_offset + wall_start.elapsed().as_secs_f64(),
                scenario: scenario_metrics,
                stage_timings: server.stage_timings().clone(),
                fingerprint: telemetry::run_fingerprint(self.cfg, self.seed),
            },
            hidden_trace,
        ))
    }
}

/// Contiguous per-tier user slices proportional to tier weight (the
/// `scenario.tier_user_pools` opt-in): tier i owns `[lo_i, hi_i)` with
/// `hi_i - lo_i ≈ weight_i / Σw · n_users`. The last tier absorbs the
/// rounding remainder; every tier must end up with at least one user.
fn tier_user_ranges(tiers: &[TierConfig], n_users: usize) -> Result<Vec<(usize, usize)>> {
    let total: f64 = tiers.iter().map(|t| t.weight).sum();
    let mut ranges = Vec::with_capacity(tiers.len());
    let mut cum = 0.0f64;
    let mut lo = 0usize;
    for (i, t) in tiers.iter().enumerate() {
        cum += t.weight;
        let hi = if i + 1 == tiers.len() {
            n_users
        } else {
            ((cum / total) * n_users as f64).floor() as usize
        };
        if hi <= lo {
            bail!(
                "scenario.tier_user_pools: tier '{}' gets an empty user slice \
                 ({n_users} train users across {} tiers)",
                t.name,
                tiers.len()
            );
        }
        ranges.push((lo, hi));
        lo = hi;
    }
    Ok(ranges)
}

// ---- checkpoint state (de)serialization ---------------------------------
//
// Every f64 in checkpoint state is the hex of its IEEE-754 bits: the
// Json number printer goes through a decimal round-trip that drops the
// sign of -0.0 and cannot carry NaN, and a resume must restore the
// exact bits (the virtual clock feeds `total_cmp` heap ordering).
// Likewise u64s that may exceed 2^53 (seq, trips, rng words).

fn f64_json(x: f64) -> Json {
    Json::str(hex_u64(x.to_bits()))
}

fn u64_json(v: u64) -> Json {
    Json::str(hex_u64(v))
}

fn f64s_json(xs: &[f64]) -> Json {
    Json::arr(xs.iter().map(|&x| f64_json(x)).collect())
}

fn rng_json(state: [u64; 4]) -> Json {
    Json::arr(state.iter().map(|&w| u64_json(w)).collect())
}

fn field<'a>(j: &'a Json, k: &str) -> Result<&'a Json> {
    j.get(k).ok_or_else(|| anyhow!("checkpoint: missing field '{k}'"))
}

fn hex_val(j: &Json) -> Result<u64> {
    parse_hex_u64(j.as_str().ok_or_else(|| anyhow!("checkpoint: expected a hex string"))?)
}

fn ju64(j: &Json, k: &str) -> Result<u64> {
    hex_val(field(j, k)?)
}

fn jf64(j: &Json, k: &str) -> Result<f64> {
    Ok(f64::from_bits(ju64(j, k)?))
}

fn jusize(j: &Json, k: &str) -> Result<usize> {
    field(j, k)?
        .as_usize()
        .ok_or_else(|| anyhow!("checkpoint: field '{k}' is not an integer"))
}

fn f64s_from_json(j: &Json, k: &str) -> Result<Vec<f64>> {
    field(j, k)?
        .as_arr()
        .ok_or_else(|| anyhow!("checkpoint: field '{k}' is not an array"))?
        .iter()
        .map(|v| Ok(f64::from_bits(hex_val(v)?)))
        .collect()
}

fn rng_from_json(j: &Json, k: &str) -> Result<[u64; 4]> {
    let words = field(j, k)?
        .as_arr()
        .ok_or_else(|| anyhow!("checkpoint: rng '{k}' is not an array"))?;
    if words.len() != 4 {
        bail!("checkpoint: rng '{k}' has {} words, expected 4", words.len());
    }
    let mut out = [0u64; 4];
    for (o, w) in out.iter_mut().zip(words) {
        *o = hex_val(w)?;
    }
    Ok(out)
}

/// The pending event heap, sorted by its pop key so checkpoint bytes do
/// not depend on `BinaryHeap`'s internal layout.
fn heap_json(queue: &EventQueue) -> Json {
    let mut entries: Vec<&Event> = queue.heap.iter().collect();
    entries.sort_by(|a, b| a.time.total_cmp(&b.time).then_with(|| a.seq.cmp(&b.seq)));
    Json::arr(
        entries
            .iter()
            .map(|e| {
                let mut pairs =
                    vec![("time", f64_json(e.time)), ("seq", u64_json(e.seq))];
                match &e.kind {
                    EventKind::Arrival => pairs.push(("kind", Json::str("arrival"))),
                    EventKind::Finish { user, tier, t_start, trip, dropped, partial } => {
                        pairs.push(("kind", Json::str("finish")));
                        pairs.push(("user", Json::num(*user as f64)));
                        pairs.push(("tier", Json::num(*tier as f64)));
                        pairs.push(("t_start", u64_json(*t_start)));
                        pairs.push(("trip", u64_json(*trip)));
                        pairs.push(("dropped", Json::Bool(*dropped)));
                        if let Some(f) = partial {
                            pairs.push(("partial", u64_json(u64::from(f.to_bits()))));
                        }
                    }
                }
                Json::obj(pairs)
            })
            .collect(),
    )
}

fn heap_from_json(j: &Json, queue: &mut EventQueue) -> Result<()> {
    let entries =
        j.as_arr().ok_or_else(|| anyhow!("checkpoint: heap is not an array"))?;
    for e in entries {
        let time = jf64(e, "time")?;
        let seq = ju64(e, "seq")?;
        if seq >= queue.seq {
            bail!("checkpoint: heap entry seq {seq} >= next seq {}", queue.seq);
        }
        let kind = match field(e, "kind")?.as_str() {
            Some("arrival") => EventKind::Arrival,
            Some("finish") => EventKind::Finish {
                user: jusize(e, "user")?,
                tier: jusize(e, "tier")?,
                t_start: ju64(e, "t_start")?,
                trip: ju64(e, "trip")?,
                dropped: field(e, "dropped")?
                    .as_bool()
                    .ok_or_else(|| anyhow!("checkpoint: 'dropped' is not a bool"))?,
                partial: match e.get("partial") {
                    Some(v) => Some(f32::from_bits(u32::try_from(hex_val(v)?)?)),
                    None => None,
                },
            },
            other => bail!("checkpoint: unknown heap event kind {other:?}"),
        };
        queue.heap.push(Event { time, seq, kind });
    }
    Ok(())
}

fn store_json(store: &SnapshotStore) -> Json {
    let (current, max_live, versions) = store.parts();
    Json::obj(vec![
        ("current", u64_json(current)),
        ("max_live", Json::num(max_live as f64)),
        (
            "versions",
            Json::arr(
                versions
                    .iter()
                    .map(|(t, refs, snap)| {
                        Json::obj(vec![
                            ("t", u64_json(*t)),
                            ("refs", Json::num(*refs as f64)),
                            ("snap", Json::str(hex_f32s(snap))),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn store_from_json(j: &Json) -> Result<SnapshotStore> {
    let versions = field(j, "versions")?
        .as_arr()
        .ok_or_else(|| anyhow!("checkpoint: store versions is not an array"))?
        .iter()
        .map(|v| {
            Ok((
                ju64(v, "t")?,
                jusize(v, "refs")?,
                parse_hex_f32s(
                    field(v, "snap")?
                        .as_str()
                        .ok_or_else(|| anyhow!("checkpoint: snapshot is not a string"))?,
                )?,
            ))
        })
        .collect::<Result<Vec<_>>>()?;
    SnapshotStore::from_parts(ju64(j, "current")?, jusize(j, "max_live")?, versions)
}

fn point_json(p: &CurvePoint) -> Json {
    let mut pairs = vec![
        ("time", f64_json(p.time)),
        ("server_steps", u64_json(p.server_steps)),
        ("uploads", u64_json(p.uploads)),
        ("upload_mb", f64_json(p.upload_mb)),
        ("broadcast_mb", f64_json(p.broadcast_mb)),
        ("val_loss", f64_json(p.val_loss)),
        ("val_accuracy", f64_json(p.val_accuracy)),
    ];
    if let Some(g) = p.grad_norm_sq {
        pairs.push(("grad_norm_sq", f64_json(g)));
    }
    Json::obj(pairs)
}

fn point_from_json(j: &Json) -> Result<CurvePoint> {
    Ok(CurvePoint {
        time: jf64(j, "time")?,
        server_steps: ju64(j, "server_steps")?,
        uploads: ju64(j, "uploads")?,
        upload_mb: jf64(j, "upload_mb")?,
        broadcast_mb: jf64(j, "broadcast_mb")?,
        val_loss: jf64(j, "val_loss")?,
        val_accuracy: jf64(j, "val_accuracy")?,
        grad_norm_sq: match j.get("grad_norm_sq") {
            Some(v) => Some(f64::from_bits(hex_val(v)?)),
            None => None,
        },
    })
}

fn curve_json(curve: &[CurvePoint]) -> Json {
    Json::arr(curve.iter().map(point_json).collect())
}

fn curve_from_json(j: &Json) -> Result<Vec<CurvePoint>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("checkpoint: curve is not an array"))?
        .iter()
        .map(point_from_json)
        .collect()
}

/// Helper so a derived stream can yield one u64 inline.
trait NextHere {
    fn next_u64_here(self) -> u64;
}

impl NextHere for Prng {
    fn next_u64_here(mut self) -> u64 {
        self.next_u64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, Config, TierConfig};
    use crate::runtime::QuadraticBackend;

    fn quad_cfg(algorithm: Algorithm) -> Config {
        let mut c = Config::default();
        c.fl.algorithm = algorithm;
        c.fl.buffer_size = 4;
        c.fl.client_lr = 0.15;
        c.fl.server_lr = 1.0;
        c.fl.server_momentum = 0.0;
        c.fl.clip_norm = 0.0; // analytic deltas are O(10)
        c.quant.client = "qsgd:8".into();
        c.quant.server = "qsgd:8".into();
        c.sim.concurrency = 20;
        c.sim.eval_every = 10;
        c.stop.target_accuracy = 0.99; // grad_norm proxy: 1/(1+g2)
        c.stop.max_uploads = 6000;
        c.stop.max_server_steps = 1500;
        c
    }

    fn backend() -> QuadraticBackend {
        QuadraticBackend::new(24, 10, 1.0, 0.3, 0.3, 0.02, 2, 11)
    }

    #[test]
    fn event_heap_pops_in_deterministic_time_seq_order() {
        // regression: Event::cmp used partial_cmp(..).unwrap_or(Equal),
        // which makes NaN "equal" to every time — an intransitive
        // comparison that silently corrupts BinaryHeap order. total_cmp
        // gives a true total order (NaN sorts last) with the seq
        // tie-breaker keeping equal times deterministic.
        let mk = |time: f64, seq: u64| Event { time, seq, kind: EventKind::Arrival };
        let mut heap: BinaryHeap<Event> = BinaryHeap::new();
        let times = [3.0, 1.0, f64::NAN, 2.0, 1.0, -0.0, 0.0, 2.0, f64::NAN];
        for (i, &t) in times.iter().enumerate() {
            heap.push(mk(t, i as u64));
        }
        let mut popped: Vec<(f64, u64)> = Vec::new();
        while let Some(e) = heap.pop() {
            popped.push((e.time, e.seq));
        }
        assert_eq!(popped.len(), times.len());
        // min-heap key (time under totalOrder, then seq) is sorted
        for w in popped.windows(2) {
            let ord = w[0].0.total_cmp(&w[1].0).then(w[0].1.cmp(&w[1].1));
            assert_ne!(ord, Ordering::Greater, "heap order violated: {popped:?}");
        }
        // equal times pop in insertion (seq) order
        let ones: Vec<u64> =
            popped.iter().filter(|(t, _)| *t == 1.0).map(|(_, s)| *s).collect();
        assert_eq!(ones, vec![1, 4]);
        // NaN times sort after every finite time instead of interleaving
        assert!(popped.iter().rev().take(2).all(|(t, _)| t.is_nan()));
    }

    #[test]
    fn qafel_converges_on_quadratic() {
        let cfg = quad_cfg(Algorithm::Qafel);
        let b = backend();
        let result = SimEngine::new(&cfg, &b, 1).run().unwrap();
        assert!(
            result.reached.is_some(),
            "did not converge: final acc {} after {} uploads",
            result.final_accuracy,
            result.comm.uploads
        );
        let r = result.reached.unwrap();
        assert!(r.uploads > 0 && r.upload_mb > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = quad_cfg(Algorithm::Qafel);
        let b = backend();
        let r1 = SimEngine::new(&cfg, &b, 7).run().unwrap();
        let r2 = SimEngine::new(&cfg, &b, 7).run().unwrap();
        assert_eq!(r1.comm.uploads, r2.comm.uploads);
        assert_eq!(r1.server_steps, r2.server_steps);
        assert_eq!(r1.final_accuracy, r2.final_accuracy);
        let r3 = SimEngine::new(&cfg, &b, 8).run().unwrap();
        // different seed -> different trajectory (virtually certain)
        assert!(
            r1.comm.uploads != r3.comm.uploads || r1.final_accuracy != r3.final_accuracy
        );
    }

    #[test]
    fn staleness_grows_with_concurrency() {
        let b = backend();
        let mut lo = quad_cfg(Algorithm::FedBuff);
        lo.sim.concurrency = 5;
        lo.stop.max_server_steps = 200;
        lo.stop.target_accuracy = 2.0; // never reached: fixed horizon
        let mut hi = lo.clone();
        hi.sim.concurrency = 200;
        let e_lo = SimEngine::new(&lo, &b, 3);
        let e_hi = SimEngine::new(&hi, &b, 3);
        let r_lo = e_lo.run().unwrap();
        let r_hi = e_hi.run().unwrap();
        assert_eq!(r_lo.server_steps, 200);
        assert_eq!(r_hi.server_steps, 200);
        // sanity: both made progress and hi processed >= lo uploads
        assert!(r_hi.comm.uploads >= r_lo.comm.uploads);
        // the scenario staleness histogram sees the same effect
        assert!(r_hi.scenario.staleness.mean() > r_lo.scenario.staleness.mean());
    }

    #[test]
    fn quantized_uploads_are_smaller_than_fedbuff() {
        let b = backend();
        let mut q = quad_cfg(Algorithm::Qafel);
        q.quant.client = "qsgd:4".into();
        q.stop.max_server_steps = 50;
        q.stop.target_accuracy = 2.0;
        let mut f = q.clone();
        f.fl.algorithm = Algorithm::FedBuff;
        let rq = SimEngine::new(&q, &b, 5).run().unwrap();
        let rf = SimEngine::new(&f, &b, 5).run().unwrap();
        let kbq = rq.comm.kb_per_upload();
        let kbf = rf.comm.kb_per_upload();
        // 4-bit qsgd ~ 8x smaller than f32 (at d=24 the 4-byte norm
        // header costs a quarter of the message; ratio 6x here, ~7.9x at
        // the paper's d=29474)
        assert!(kbf / kbq >= 5.5, "kb/upload {kbq} vs fedbuff {kbf}");
    }

    #[test]
    fn poisson_and_lognormal_ablations_run() {
        let b = backend();
        let mut c = quad_cfg(Algorithm::Qafel);
        c.sim.arrival = "poisson".into();
        c.sim.duration = "lognormal".into();
        c.stop.max_server_steps = 30;
        c.stop.target_accuracy = 2.0;
        let r = SimEngine::new(&c, &b, 2).run().unwrap();
        assert_eq!(r.server_steps, 30);
    }

    #[test]
    fn curve_is_monotone_in_time_and_uploads() {
        let b = backend();
        let mut c = quad_cfg(Algorithm::Qafel);
        c.stop.max_server_steps = 100;
        c.stop.target_accuracy = 2.0;
        let r = SimEngine::new(&c, &b, 4).run().unwrap();
        for w in r.curve.windows(2) {
            assert!(w[1].time >= w[0].time);
            assert!(w[1].uploads >= w[0].uploads);
            assert!(w[1].upload_mb >= w[0].upload_mb);
        }
        // broadcast MB ~= upload MB / K with identical 8-bit codecs (both
        // directions quantized, Fig. 3 caption identity)
        let last = r.curve.last().unwrap();
        let ratio = last.upload_mb / last.broadcast_mb;
        assert!((ratio - 4.0).abs() < 0.6, "up/down ratio {ratio}");
    }

    #[test]
    fn hidden_error_trace_is_bounded(){
        let b = backend();
        let mut c = quad_cfg(Algorithm::Qafel);
        c.stop.max_server_steps = 120;
        c.stop.target_accuracy = 2.0;
        let opts = SimOptions { trace_hidden_error: true, ..Default::default() };
        let (r, trace) = SimEngine::new(&c, &b, 6).run_traced(&opts).unwrap();
        assert_eq!(trace.len(), r.curve.len() - 1);
        // Lemma F.9: hidden error stays bounded (no blow-up)
        let max0 = trace.iter().take(3).cloned().fold(0.0, f64::max);
        let max1 = trace.iter().rev().take(3).cloned().fold(0.0, f64::max);
        assert!(max1 <= (max0 + 1.0) * 50.0, "hidden error exploding: {max0} -> {max1}");
    }

    #[test]
    fn default_scenario_reports_single_tier_metrics() {
        let b = backend();
        let mut c = quad_cfg(Algorithm::Qafel);
        c.stop.max_server_steps = 80;
        c.stop.target_accuracy = 2.0;
        let r = SimEngine::new(&c, &b, 9).run().unwrap();
        let sc = &r.scenario;
        assert_eq!(sc.tiers.len(), 1);
        assert_eq!(sc.tiers[0].name, "default");
        assert_eq!(sc.tiers[0].uploads, r.comm.uploads);
        assert_eq!(sc.tiers[0].upload_bytes, r.comm.upload_bytes);
        assert_eq!(sc.tiers[0].dropouts, 0);
        assert_eq!(sc.tiers[0].unavailable, 0);
        assert_eq!(sc.staleness.n, r.comm.uploads);
        assert!(sc.mean_concurrency > 0.0);
        assert!(sc.max_in_flight > 0);
        assert!(sc.max_live_snapshots >= 1);
    }

    #[test]
    fn heterogeneous_population_records_tier_metrics() {
        let b = backend();
        let mut c = quad_cfg(Algorithm::Qafel);
        c.stop.target_accuracy = 2.0; // fixed horizon
        let mut fast = TierConfig::named("fast");
        fast.weight = 0.4;
        fast.duration_sigma = 0.5;
        fast.upload_mbps = 10.0;
        fast.download_mbps = 40.0;
        let mut slow = TierConfig::named("slow");
        slow.weight = 0.6;
        slow.duration = "lognormal".into();
        slow.dropout = 0.3;
        slow.day_period = 5.0;
        slow.on_fraction = 0.5;
        c.scenario.tiers = vec![fast, slow];
        c.validate().unwrap();
        let r = SimEngine::new(&c, &b, 12).run().unwrap();
        let sc = &r.scenario;
        assert_eq!(sc.tiers.len(), 2);
        // tier metrics are consistent with the server's accounting
        let uploads: u64 = sc.tiers.iter().map(|t| t.uploads).sum();
        let upload_bytes: u64 = sc.tiers.iter().map(|t| t.upload_bytes).sum();
        assert_eq!(uploads, r.comm.uploads);
        assert_eq!(upload_bytes, r.comm.upload_bytes);
        assert_eq!(sc.staleness.n, r.comm.uploads);
        // the hostile tier actually dropped work and went dark at night
        let slow_m = &sc.tiers[1];
        assert_eq!(slow_m.name, "slow");
        assert!(slow_m.dropouts > 0, "expected slow-tier dropouts");
        assert!(slow_m.unavailable > 0, "expected off-window arrivals");
        assert!(sc.tiers[0].dropouts == 0 && sc.tiers[0].unavailable == 0);
        // arrivals = uploads + dropouts + still-in-flight at the break
        let slow_accounted = slow_m.uploads + slow_m.dropouts;
        assert!(slow_m.arrivals >= slow_accounted);
        // both tiers carried traffic and recorded transfer bytes
        assert!(sc.tiers[0].uploads > 0 && slow_m.uploads > 0);
        assert!(sc.tiers[0].download_bytes > 0);
    }

    #[test]
    fn per_tier_downlink_codecs_split_broadcast_accounting() {
        let b = backend();
        let mut c = quad_cfg(Algorithm::Qafel);
        c.stop.target_accuracy = 2.0; // fixed horizon
        c.stop.max_server_steps = 60;
        let mut fast = TierConfig::named("fast");
        fast.weight = 0.5;
        let mut slow = TierConfig::named("slow");
        slow.weight = 0.5;
        slow.quant_server = Some("qsgd:2".into());
        c.scenario.tiers = vec![fast, slow];
        c.validate().unwrap();
        let r = SimEngine::new(&c, &b, 17).run().unwrap();
        let sc = &r.scenario;
        assert_eq!(sc.tiers.len(), 2);
        // the default tier reports no downlink preset; the slow tier
        // reports its resolved family codec
        assert_eq!(sc.tiers[0].download_codec, "");
        assert!(
            sc.tiers[0].uploads > 0 && sc.tiers[1].uploads > 0,
            "both tiers must carry traffic"
        );
        assert!(
            sc.tiers[1].download_codec.starts_with("qsgd"),
            "slow downlink codec: {:?}",
            sc.tiers[1].download_codec
        );
        // every step broadcast once per family — comm totals double up
        assert_eq!(r.comm.broadcasts, 2 * r.server_steps);
        // distinct per-tier kB/download: each tier's downloads are
        // billed at its own family's wire size (no dropouts/partials
        // here, so downloads == uploads)
        let per_dl =
            |t: &crate::scenario::TierMetrics| t.download_bytes as f64 / t.uploads as f64;
        assert!(
            per_dl(&sc.tiers[1]) < per_dl(&sc.tiers[0]),
            "2-bit downlink should be cheaper: {} vs {}",
            per_dl(&sc.tiers[1]),
            per_dl(&sc.tiers[0])
        );
    }

    #[test]
    fn duplicate_downlink_preset_keeps_single_family() {
        // a quant_server preset equal to the resolved default dedups to
        // family 0: same accounting and trajectory as no preset at all
        let b = backend();
        let mut c = quad_cfg(Algorithm::Qafel);
        c.stop.target_accuracy = 2.0;
        c.stop.max_server_steps = 60;
        let mut fast = TierConfig::named("fast");
        fast.weight = 0.5;
        let mut slow = TierConfig::named("slow");
        slow.weight = 0.5;
        c.scenario.tiers = vec![fast, slow];
        c.validate().unwrap();
        let plain = SimEngine::new(&c, &b, 18).run().unwrap();
        let mut cp = c.clone();
        cp.scenario.tiers[1].quant_server = Some(c.quant.server.clone());
        cp.validate().unwrap();
        let preset = SimEngine::new(&cp, &b, 18).run().unwrap();
        assert_eq!(preset.comm.broadcasts, plain.comm.broadcasts);
        assert_eq!(preset.final_accuracy, plain.final_accuracy);
        assert_eq!(preset.scenario.tiers[1].download_codec, "");
        assert_eq!(
            preset.scenario.tiers[1].download_bytes,
            plain.scenario.tiers[1].download_bytes
        );
    }

    #[test]
    fn tier_user_ranges_partition_the_population() {
        let mk = |name: &str, w: f64| {
            let mut t = TierConfig::named(name);
            t.weight = w;
            t
        };
        let tiers = vec![mk("a", 1.0), mk("b", 3.0)];
        let r = tier_user_ranges(&tiers, 100).unwrap();
        assert_eq!(r, vec![(0, 25), (25, 100)]);
        // rounding remainder goes to the last tier; slices stay disjoint
        // and exhaustive
        let tiers = vec![mk("a", 1.0), mk("b", 1.0), mk("c", 1.0)];
        let r = tier_user_ranges(&tiers, 10).unwrap();
        assert_eq!(r.first().unwrap().0, 0);
        assert_eq!(r.last().unwrap().1, 10);
        for w in r.windows(2) {
            assert_eq!(w[0].1, w[1].0);
            assert!(w[0].0 < w[0].1);
        }
        // too few users for the tier count fails loudly
        let tiers = vec![mk("a", 1.0), mk("b", 1e-9)];
        assert!(tier_user_ranges(&tiers, 2).is_err());
    }

    #[test]
    fn single_tier_user_pools_replay_bit_identical() {
        // with one tier the pool slice is the whole population, so the
        // single Lemire draw is unchanged — the opt-in is free for the
        // desugared default scenario
        let b = backend();
        let mut on = quad_cfg(Algorithm::Qafel);
        on.stop.max_server_steps = 60;
        on.stop.target_accuracy = 2.0;
        let off = on.clone();
        on.scenario.tier_user_pools = true;
        let r_on = SimEngine::new(&on, &b, 21).run().unwrap();
        let r_off = SimEngine::new(&off, &b, 21).run().unwrap();
        assert_eq!(r_on.comm.uploads, r_off.comm.uploads);
        assert_eq!(r_on.final_accuracy, r_off.final_accuracy);
        assert_eq!(r_on.curve.len(), r_off.curve.len());
    }

    #[test]
    fn tier_user_pools_shift_the_sampled_population() {
        let b = backend();
        let mut c = quad_cfg(Algorithm::Qafel);
        c.stop.max_server_steps = 60;
        c.stop.target_accuracy = 2.0;
        let mut fast = TierConfig::named("fast");
        fast.weight = 0.5;
        let mut slow = TierConfig::named("slow");
        slow.weight = 0.5;
        c.scenario.tiers = vec![fast, slow];
        let r_off = SimEngine::new(&c, &b, 22).run().unwrap();
        c.scenario.tier_user_pools = true;
        c.validate().unwrap();
        let r_on = SimEngine::new(&c, &b, 22).run().unwrap();
        // correlating membership with data changes which users train,
        // hence the trajectory (virtually certain on any real backend)
        assert_eq!(r_on.server_steps, r_off.server_steps);
        assert!(
            r_on.final_accuracy != r_off.final_accuracy
                || r_on.curve.last().unwrap().val_loss != r_off.curve.last().unwrap().val_loss,
            "pooled draw unexpectedly identical to shared draw"
        );
    }

    #[test]
    fn edge_tree_reports_per_edge_metrics() {
        let b = backend();
        let mut c = quad_cfg(Algorithm::Qafel);
        c.stop.max_server_steps = 40;
        c.stop.target_accuracy = 2.0;
        c.scenario.aggregators.edges = 4;
        c.scenario.aggregators.buffer_size = 2;
        c.scenario.aggregators.partial_codec = "qsgd:8".into();
        c.validate().unwrap();
        let r = SimEngine::new(&c, &b, 23).run().unwrap();
        assert_eq!(r.server_steps, 40);
        let sc = &r.scenario;
        assert_eq!(sc.edges.len(), 4);
        let updates: u64 = sc.edges.iter().map(|e| e.updates).sum();
        let partials: u64 = sc.edges.iter().map(|e| e.partials).sum();
        // every tier-level upload reached exactly one edge; the root saw
        // one ingest per forwarded partial
        let tier_uploads: u64 = sc.tiers.iter().map(|t| t.uploads).sum();
        assert_eq!(updates, tier_uploads);
        assert_eq!(partials, r.comm.uploads);
        assert!(partials > 0 && partials <= updates);
        // per-edge staleness histograms merge to the tier-level count
        // minus whatever is still buffered at the break
        let hist_n: u64 = sc.edges.iter().map(|e| e.staleness.n).sum();
        assert_eq!(hist_n, updates);
        for e in &sc.edges {
            assert!(e.updates > 0, "edge {} starved", e.edge_id);
            assert_eq!(e.partial_bytes % e.partials.max(1), 0);
        }
    }

    #[test]
    fn edge_tree_is_deterministic_given_seed() {
        let b = backend();
        let mut c = quad_cfg(Algorithm::Qafel);
        c.stop.max_server_steps = 30;
        c.stop.target_accuracy = 2.0;
        c.scenario.aggregators.edges = 3;
        c.scenario.aggregators.buffer_size = 2;
        c.scenario.aggregators.partial_codec = "qsgd:4".into();
        let r1 = SimEngine::new(&c, &b, 24).run().unwrap();
        let r2 = SimEngine::new(&c, &b, 24).run().unwrap();
        assert_eq!(r1.final_accuracy, r2.final_accuracy);
        assert_eq!(r1.comm.uploads, r2.comm.uploads);
        assert_eq!(r1.scenario.edges, r2.scenario.edges);
    }

    #[test]
    fn snapshot_memory_is_versions_not_concurrency() {
        // acceptance: <= 1 live snapshot Arc per server step regardless
        // of concurrency — 2000 in-flight clients share a handful of
        // model versions.
        let b = backend();
        let mut c = quad_cfg(Algorithm::Qafel);
        c.sim.concurrency = 2000;
        c.stop.target_accuracy = 2.0;
        c.stop.max_server_steps = 25;
        c.stop.max_uploads = 1_000_000;
        let r = SimEngine::new(&c, &b, 13).run().unwrap();
        assert_eq!(r.server_steps, 25);
        let sc = &r.scenario;
        assert!(
            sc.max_live_snapshots <= 26,
            "live versions {} > server steps + 1",
            sc.max_live_snapshots
        );
        assert!(sc.max_in_flight > 100, "in-flight {}", sc.max_in_flight);
        assert!(
            sc.max_live_snapshots * 4 < sc.max_in_flight,
            "snapshots {} vs in-flight {}",
            sc.max_live_snapshots,
            sc.max_in_flight
        );
    }

    fn temp_journal(tag: &str) -> String {
        std::env::temp_dir()
            .join(format!("qafel_engine_{tag}_{}.jsonl", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    #[test]
    fn journaled_run_is_a_pure_observer_and_replays() {
        let b = backend();
        let mut c = quad_cfg(Algorithm::Qafel);
        c.stop.max_server_steps = 60;
        c.stop.target_accuracy = 2.0;
        let plain = SimEngine::new(&c, &b, 31).run().unwrap();
        let path = temp_journal("observer");
        let mut cj = c.clone();
        cj.telemetry.journal = Some(path.clone());
        cj.telemetry.checkpoint_every = 20;
        let journaled = SimEngine::new(&cj, &b, 31).run().unwrap();
        // recording must not perturb the trajectory, bit for bit
        assert_eq!(plain.curve.len(), journaled.curve.len());
        for (p, q) in plain.curve.iter().zip(&journaled.curve) {
            assert_eq!(p.time.to_bits(), q.time.to_bits());
            assert_eq!(p.val_loss.to_bits(), q.val_loss.to_bits());
            assert_eq!(p.uploads, q.uploads);
        }
        // telemetry is observer config: same fingerprint either way
        assert_eq!(plain.fingerprint, journaled.fingerprint);
        // the journal replays bit-identically and carries checkpoints
        let report = crate::telemetry::replay_file(&path).unwrap();
        assert!(report.finalized);
        assert_eq!(report.steps, journaled.server_steps);
        assert_eq!(report.uploads, journaled.comm.uploads);
        assert!(report.checkpoints >= 2, "checkpoints {}", report.checkpoints);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn checkpointed_edge_tree_is_rejected() {
        let b = backend();
        let mut c = quad_cfg(Algorithm::Qafel);
        c.scenario.aggregators.edges = 2;
        c.telemetry.journal = Some(temp_journal("edges_reject"));
        c.telemetry.checkpoint_every = 5;
        let err = SimEngine::new(&c, &b, 1).run().unwrap_err().to_string();
        assert!(err.contains("edge buffers are not checkpointed"), "{err}");
    }

    /// Two-tier population with the slow tier on a thin uplink, plus an
    /// adaptive controller whose budget of 1 byte/step can never be met
    /// — every pass walks every eligible tier to the bottom of the
    /// ladder, so downshifts are guaranteed without hand-computing
    /// codec wire sizes.
    fn adaptive_cfg() -> Config {
        let mut c = quad_cfg(Algorithm::Qafel);
        c.stop.max_server_steps = 60;
        c.stop.target_accuracy = 2.0; // fixed horizon
        let mut fast = TierConfig::named("fast");
        fast.weight = 0.5;
        fast.upload_mbps = 100.0;
        let mut slow = TierConfig::named("slow");
        slow.weight = 0.5;
        slow.upload_mbps = 0.5;
        c.scenario.tiers = vec![fast, slow];
        c.scenario.adaptive.enabled = true;
        c.scenario.adaptive.interval = 5;
        c.scenario.adaptive.budget_bytes_per_step = 1;
        c.scenario.adaptive.levels =
            vec!["qsgd:8".into(), "qsgd:4".into(), "qsgd:2".into()];
        c.scenario.adaptive.min_uploads = 1;
        c.validate().unwrap();
        c
    }

    #[test]
    fn adaptive_disabled_knobs_are_inert() {
        // a fully-populated but disabled [scenario.adaptive] table draws
        // nothing, registers nothing and fingerprints identically to a
        // config that never mentions it (PR 8 byte-identity)
        let b = backend();
        let mut c = adaptive_cfg();
        c.scenario.adaptive.enabled = false;
        let mut plain = c.clone();
        plain.scenario.adaptive = Default::default();
        plain.validate().unwrap();
        let r_off = SimEngine::new(&c, &b, 41).run().unwrap();
        let r_plain = SimEngine::new(&plain, &b, 41).run().unwrap();
        assert_eq!(r_off.fingerprint, r_plain.fingerprint);
        assert_eq!(r_off.comm.uploads, r_plain.comm.uploads);
        assert_eq!(r_off.curve.len(), r_plain.curve.len());
        for (p, q) in r_off.curve.iter().zip(&r_plain.curve) {
            assert_eq!(p.time.to_bits(), q.time.to_bits());
            assert_eq!(p.val_loss.to_bits(), q.val_loss.to_bits());
        }
        assert!(r_off.scenario.tiers.iter().all(|t| t.codec_switches == 0));
    }

    #[test]
    fn adaptive_controller_downshifts_and_is_deterministic() {
        // acceptance: same-seed determinism under mid-run rekeys for
        // S in {1, 4}, tiers end on the cheapest ladder level, and the
        // adaptive run ships strictly fewer bytes per upload than the
        // same population pinned to the static default codec
        let b = backend();
        for buffer in [1usize, 4] {
            let mut c = adaptive_cfg();
            c.fl.buffer_size = buffer;
            let r1 = SimEngine::new(&c, &b, 42).run().unwrap();
            let r2 = SimEngine::new(&c, &b, 42).run().unwrap();
            assert_eq!(r1.comm.uploads, r2.comm.uploads);
            assert_eq!(r1.comm.upload_bytes, r2.comm.upload_bytes);
            assert_eq!(
                r1.final_accuracy.to_bits(),
                r2.final_accuracy.to_bits(),
                "S={buffer}: rekeyed run not deterministic"
            );
            assert_eq!(r1.scenario.tiers, r2.scenario.tiers);
            let switches: u64 =
                r1.scenario.tiers.iter().map(|t| t.codec_switches).sum();
            assert!(switches >= 1, "S={buffer}: controller never switched");
            // the 1-byte budget walks every scored tier to the bottom in
            // one Rekey (qsgd:8 -> qsgd:2 directly, skipping qsgd:4)
            for t in &r1.scenario.tiers {
                if t.codec_switches > 0 {
                    assert!(
                        t.codec.starts_with("qsgd:2"),
                        "tier {} ended on {:?}",
                        t.name,
                        t.codec
                    );
                    assert_eq!(t.codec_switches, 1, "tier {}", t.name);
                }
            }
            // per-tier byte accounting still sums to the server's totals
            let bytes: u64 =
                r1.scenario.tiers.iter().map(|t| t.upload_bytes).sum();
            assert_eq!(bytes, r1.comm.upload_bytes);
            let mut s = c.clone();
            s.scenario.adaptive = Default::default();
            s.validate().unwrap();
            let r_static = SimEngine::new(&s, &b, 42).run().unwrap();
            assert!(
                r1.comm.kb_per_upload() < r_static.comm.kb_per_upload(),
                "S={buffer}: adaptive {} kb/up >= static {}",
                r1.comm.kb_per_upload(),
                r_static.comm.kb_per_upload()
            );
        }
    }

    #[test]
    fn adaptive_journal_records_rekeys_and_replays() {
        // the journal carries the ladder registrations in its header and
        // a Rekey event at each switch; replay re-executes the run —
        // mixed-codec ingests on both sides of the cutover — bit-exactly
        let b = backend();
        let mut c = adaptive_cfg();
        let path = temp_journal("adaptive_replay");
        c.telemetry.journal = Some(path.clone());
        let r = SimEngine::new(&c, &b, 43).run().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let count = |kind: &str| {
            let tag = format!("\"ev\":\"{kind}\"");
            text.lines().filter(|l| l.contains(&tag)).count() as u64
        };
        let switches: u64 = r.scenario.tiers.iter().map(|t| t.codec_switches).sum();
        assert!(switches >= 1);
        assert_eq!(count("rekey"), switches, "one journal event per applied switch");
        // ladder levels are registered in the header: codec events for
        // qsgd:4 and qsgd:2 (qsgd:8 dedups into the default id 0)
        assert_eq!(count("codec"), 2, "ladder registrations missing");
        let report = crate::telemetry::replay_file(&path).unwrap();
        assert!(report.finalized);
        assert_eq!(report.steps, r.server_steps);
        assert_eq!(report.uploads, r.comm.uploads);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn checkpointed_adaptive_run_is_rejected() {
        let b = backend();
        let mut c = adaptive_cfg();
        c.telemetry.journal = Some(temp_journal("adaptive_reject"));
        c.telemetry.checkpoint_every = 5;
        let err = SimEngine::new(&c, &b, 1).run().unwrap_err().to_string();
        assert!(err.contains("scenario.adaptive"), "{err}");
    }

    /// Two-tier population with a hostile minority: `weight` of arrivals
    /// run `adversary`, the rest are honest.
    fn two_tier_attack_cfg(adversary: &str, weight: f64) -> Config {
        let mut c = quad_cfg(Algorithm::Qafel);
        c.fl.buffer_size = 5;
        c.stop.target_accuracy = 2.0; // fixed horizon
        c.stop.max_server_steps = 120;
        c.stop.max_uploads = 100_000;
        let mut good = TierConfig::named("good");
        good.weight = 1.0 - weight;
        let mut bad = TierConfig::named("bad");
        bad.weight = weight;
        bad.adversary = Some(adversary.into());
        c.scenario.tiers = vec![good, bad];
        c
    }

    #[test]
    fn hostile_population_is_deterministic_and_tagged() {
        let b = backend();
        let mut c = quad_cfg(Algorithm::Qafel);
        c.stop.target_accuracy = 2.0;
        c.stop.max_server_steps = 80;
        let mut good = TierConfig::named("good");
        good.weight = 0.5;
        let mut noisy = TierConfig::named("noisy");
        noisy.weight = 0.3;
        noisy.grad_noise = Some("student_t:3:0.05".into());
        let mut stale = TierConfig::named("stale");
        stale.weight = 0.2;
        stale.adversary = Some("stale_replay".into());
        c.scenario.tiers = vec![good, noisy, stale];
        c.fl.robust.enabled = true;
        c.fl.robust.clip_norm = 5.0;
        c.fl.robust.trim_frac = 0.25;
        c.validate().unwrap();
        let r1 = SimEngine::new(&c, &b, 53).run().unwrap();
        let r2 = SimEngine::new(&c, &b, 53).run().unwrap();
        assert_eq!(r1.final_accuracy.to_bits(), r2.final_accuracy.to_bits());
        assert_eq!(r1.comm.uploads, r2.comm.uploads);
        assert_eq!(r1.scenario.tiers, r2.scenario.tiers);
        let sc = &r1.scenario;
        assert_eq!(sc.tiers[1].grad_noise, "student_t:3:0.05");
        assert_eq!(sc.tiers[2].adversary, "stale_replay");
        assert_eq!(sc.tiers[0].grad_noise, "");
        assert_eq!(sc.tiers[0].adversary, "");
        assert!(sc.tiers.iter().all(|t| t.uploads > 0), "a tier starved");
    }

    #[test]
    fn norm_clipping_defends_scaled_garbage() {
        // the classic Gaussian Byzantine attack: huge-norm garbage wrecks
        // the plain mean; per-update norm bounding contains it
        let b = backend();
        let plain = two_tier_attack_cfg("scale:50", 0.3);
        plain.validate().unwrap();
        let r_mean = SimEngine::new(&plain, &b, 51).run().unwrap();
        let mut clip = plain.clone();
        clip.fl.robust.enabled = true;
        clip.fl.robust.clip_norm = 1.0;
        clip.validate().unwrap();
        let r_clip = SimEngine::new(&clip, &b, 51).run().unwrap();
        let lm = r_mean.curve.last().unwrap().val_loss;
        let lc = r_clip.curve.last().unwrap().val_loss;
        assert!(lc < lm * 0.5, "clip loss {lc} not clearly below mean loss {lm}");
        // clipped uploads are attributed per tier; the garbage tier's
        // norm-245 updates are always bounded
        assert!(r_clip.scenario.tiers[1].clipped_updates > 0);
        assert_eq!(r_mean.scenario.tiers[1].clipped_updates, 0);
    }

    #[test]
    fn trimmed_mean_recovers_sign_flip() {
        let b = backend();
        let mean = two_tier_attack_cfg("sign_flip", 0.3);
        mean.validate().unwrap();
        let honest = {
            let mut h = mean.clone();
            h.scenario.tiers[1].adversary = None;
            h.validate().unwrap();
            SimEngine::new(&h, &b, 52).run().unwrap()
        };
        let r_mean = SimEngine::new(&mean, &b, 52).run().unwrap();
        let mut trim = mean.clone();
        trim.fl.robust.enabled = true;
        trim.fl.robust.trim_frac = 0.4; // K=5: keep the per-coordinate median
        trim.validate().unwrap();
        let r_trim = SimEngine::new(&trim, &b, 52).run().unwrap();
        let lh = honest.curve.last().unwrap().val_loss;
        let lm = r_mean.curve.last().unwrap().val_loss;
        let lt = r_trim.curve.last().unwrap().val_loss;
        assert!(lm > lh, "sign flip did not degrade the mean: {lm} vs honest {lh}");
        assert!(lt < lm, "trimmed mean did not recover: {lt} vs mean {lm}");
        assert!(r_trim.scenario.tiers[1].trimmed_updates > 0);
        assert_eq!(r_mean.scenario.tiers[1].trimmed_updates, 0);
    }

    #[test]
    fn hostile_checkpoint_resumes_bit_identical() {
        // kill-and-resume across the full robustness surface: robust
        // server state, the scenario noise/adversary streams and the
        // stale-replay caches all restore; the finished journal is
        // bit-identical to the uninterrupted run's
        let b = backend();
        let mut c = quad_cfg(Algorithm::Qafel);
        c.stop.target_accuracy = 2.0;
        c.stop.max_server_steps = 60;
        let mut good = TierConfig::named("good");
        good.weight = 0.6;
        let mut bad = TierConfig::named("bad");
        bad.weight = 0.4;
        bad.adversary = Some("stale_replay".into());
        bad.grad_noise = Some("pareto:2:0.02".into());
        c.scenario.tiers = vec![good, bad];
        c.fl.robust.enabled = true;
        c.fl.robust.clip_norm = 8.0;
        c.fl.robust.trim_frac = 0.25;
        let path = temp_journal("hostile_resume");
        c.telemetry.journal = Some(path.clone());
        c.telemetry.checkpoint_every = 25;
        c.validate().unwrap();
        let full = SimEngine::new(&c, &b, 54).run().unwrap();
        let text_full = std::fs::read_to_string(&path).unwrap();
        // resume truncates to the last checkpoint (step 50) and
        // re-executes the tail
        let opts = SimOptions { resume: true, ..Default::default() };
        let resumed = SimEngine::new(&c, &b, 54).run_with(&opts).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), text_full);
        assert_eq!(resumed.final_accuracy.to_bits(), full.final_accuracy.to_bits());
        assert_eq!(resumed.scenario.tiers, full.scenario.tiers);
        std::fs::remove_file(&path).unwrap();
    }
}
