//! Event-driven virtual-time simulator of the asynchronous FL system
//! (paper §4 / Appendix D timing model).
//!
//! The client population is owned by the scenario engine
//! ([`crate::scenario`], DESIGN_SCENARIOS.md):
//!
//! * clients **arrive** via a pluggable process — constant rate (paper),
//!   Poisson, or bursty MMPP — calibrated to
//!   `rate = concurrency / E[duration]` under the configured tier mix
//!   (reproducing the paper's 125 / 627 / 1253 clients-per-unit-time for
//!   100 / 500 / 1000 at the default half-normal);
//! * each arrival is assigned a **device tier**: its own duration
//!   distribution (half-normal default — the Meta production model —
//!   log-normal and fixed for ablations), upload/download bandwidth
//!   (adding per-trip transfer delays and byte accounting), dropout
//!   probability, and diurnal availability window;
//! * a client's model snapshot is the hidden state at its **start** time,
//!   held as a `u64` version key into a shared
//!   [`crate::scenario::SnapshotStore`] — all clients arriving between
//!   two server steps share one `Arc`, so memory is O(distinct model
//!   versions), not O(in-flight clients). Its update is ingested at its
//!   **finish** time. Staleness = server steps between the two, exactly
//!   the paper's `tau_n(t)`. The gradient computation happens lazily at
//!   the finish event, against the start-time snapshot — virtual time is
//!   completely decoupled from compute time.
//!
//! Concurrency 10⁶ therefore needs no threads: the engine is a binary
//! heap of (time, event) pairs processed in deterministic order, and an
//! in-flight client costs a few dozen bytes of event record.

pub mod engine;

pub use engine::{SimEngine, SimOptions};
