//! Event-driven virtual-time simulator of the asynchronous FL system
//! (paper §4 / Appendix D timing model).
//!
//! * clients **arrive at a constant rate** (or Poisson, for ablations);
//!   the rate is derived from the target concurrency via
//!   `rate = concurrency / E[duration]`, reproducing the paper's
//!   125 / 627 / 1253 clients-per-unit-time for 100 / 500 / 1000;
//! * each client trains for a **half-normal** duration |N(0, sigma^2)|
//!   (Meta production model) — log-normal and fixed for ablations;
//! * a client's model snapshot is the hidden state at its **start** time
//!   (a cheap `Arc` clone); its update is ingested at its **finish**
//!   time. Staleness = server steps between the two, exactly the paper's
//!   `tau_n(t)`. The gradient computation itself happens lazily at the
//!   finish event, against the start-time snapshot — virtual time is
//!   completely decoupled from compute time.
//!
//! Concurrency 1000 therefore needs no threads: the engine is a binary
//! heap of (time, event) pairs processed in deterministic order.

pub mod engine;

pub use engine::{SimEngine, SimOptions};
