//! L3 ↔ L2 bridge: load the AOT-compiled HLO artifacts and execute them
//! via the PJRT C API, plus the [`Backend`] abstraction the coordinator
//! and simulator are written against.
//!
//! Python is involved only at build time (`make artifacts`); everything
//! here is pure rust + the `xla` crate.

pub mod backend;
pub mod engine;
pub mod manifest;

pub use backend::{Backend, EvalOutput, PjrtBackend, QuadraticBackend};
pub use engine::{artifacts_available, artifacts_dir, Engine, QuantizedRoundOutput, RoundOutput};
pub use manifest::{ArtifactSig, DType, LayerInfo, Manifest, ModelInfo, TensorSig};
