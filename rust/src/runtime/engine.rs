//! PJRT execution engine: loads the HLO-text artifacts and exposes typed
//! entry points for the computations exported by `python/compile/aot.py`.
//!
//! Pattern (see /opt/xla-example/): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format —
//! the bundled xla_extension 0.5.1 rejects jax≥0.5 serialized protos.
//!
//! `PjRtClient` is `Rc`-based (not `Send`); an [`Engine`] therefore lives
//! on one thread. XLA's CPU backend parallelizes *inside* an execution
//! with its own intra-op thread pool, so a single engine thread saturates
//! the machine for our batch sizes.
//!
//! **Feature gating:** the `xla` crate (and its xla_extension shared
//! library) is unavailable in the offline build image, so the real engine
//! is compiled only under `--features pjrt`; the default build ships a
//! stub whose `load` fails with a clear message. Everything that can run
//! without PJRT (the coordinator, simulator, quantizer codecs, TCP
//! runtime, quadratic-backend experiments) is unaffected.

use super::manifest::Manifest;
use anyhow::Result;

#[cfg(feature = "pjrt")]
use super::manifest::DType;
#[cfg(feature = "pjrt")]
use anyhow::{anyhow, bail};
#[cfg(feature = "pjrt")]
use std::collections::BTreeMap;

/// A loaded, compiled artifact set.
pub struct Engine {
    manifest: Manifest,
    #[cfg(feature = "pjrt")]
    exes: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

/// Output of one client round (Algorithm 2 executed in a single PJRT
/// call: P local SGD steps via lax.scan).
#[derive(Clone, Debug)]
pub struct RoundOutput {
    /// Model delta y_P - y_0 (the descent direction the client uploads).
    pub delta: Vec<f32>,
    /// Mean training loss over the P steps.
    pub loss: f32,
    /// Mean training accuracy over the P steps.
    pub acc: f32,
}

/// Output of `client_update_quantized` — the full client request path
/// including the L1 Pallas qsgd kernel, in one executable.
#[derive(Clone, Debug)]
pub struct QuantizedRoundOutput {
    /// Signed qsgd levels from the Pallas kernel.
    pub levels: Vec<i32>,
    /// Per-bucket l2 norms (bucket = 128, matching quant::qsgd).
    pub norms: Vec<f32>,
    pub loss: f32,
    pub acc: f32,
}

impl Engine {
    /// Load every artifact listed in `<dir>/manifest.json` and compile it
    /// on the PJRT CPU client.
    pub fn load(dir: &str) -> Result<Engine> {
        Self::load_subset(dir, &[])
    }

    /// Load only `names` (empty = all). Compiling fewer artifacts speeds
    /// up tools that need just one entry point.
    #[cfg(feature = "pjrt")]
    pub fn load_subset(dir: &str, names: &[&str]) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let mut exes = BTreeMap::new();
        for name in manifest.artifacts.keys() {
            if !names.is_empty() && !names.contains(&name.as_str()) {
                continue;
            }
            let path = manifest.artifact_path(name)?;
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            exes.insert(name.clone(), exe);
        }
        Ok(Engine { manifest, exes })
    }

    /// Stub (built without `--features pjrt`): always errors.
    #[cfg(not(feature = "pjrt"))]
    pub fn load_subset(dir: &str, names: &[&str]) -> Result<Engine> {
        let _ = names;
        anyhow::bail!(
            "qafel was built without the `pjrt` feature (the xla crate is \
             unavailable offline), so artifacts in '{dir}' cannot be \
             executed. Use `--backend quadratic`, or add a local `xla` \
             dependency and rebuild with `--features pjrt`."
        )
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Flat parameter dimension d.
    pub fn d(&self) -> usize {
        self.manifest.model.d
    }

    /// Elements per input image.
    pub fn img_elems(&self) -> usize {
        let m = &self.manifest.model;
        m.height * m.width * m.in_channels
    }
}

// ---------------------------------------------------------------------------
// Real PJRT execution (only with --features pjrt)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
impl Engine {
    // ---- generic execute ---------------------------------------------------

    /// Execute artifact `name` with validated inputs; returns the output
    /// tuple as literals.
    fn exec(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let sig = self.manifest.artifact(name)?;
        if inputs.len() != sig.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", sig.inputs.len(), inputs.len());
        }
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded"))?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        if parts.len() != sig.outputs.len() {
            bail!("{name}: expected {} outputs, got {}", sig.outputs.len(), parts.len());
        }
        Ok(parts)
    }

    fn lit_f32(&self, name: &str, arg: usize, data: &[f32]) -> Result<xla::Literal> {
        let sig = &self.manifest.artifact(name)?.inputs[arg];
        if sig.dtype != DType::F32 || sig.elems() != data.len() {
            bail!("{name} arg {arg}: want {:?} f32 ({}), got {} values",
                  sig.shape, sig.elems(), data.len());
        }
        let dims: Vec<i64> = sig.shape.iter().map(|&s| s as i64).collect();
        xla::Literal::vec1(data)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape {name} arg {arg}: {e:?}"))
    }

    fn lit_i32(&self, name: &str, arg: usize, data: &[i32]) -> Result<xla::Literal> {
        let sig = &self.manifest.artifact(name)?.inputs[arg];
        if sig.dtype != DType::I32 || sig.elems() != data.len() {
            bail!("{name} arg {arg}: want {:?} i32, got {} values", sig.shape, data.len());
        }
        let dims: Vec<i64> = sig.shape.iter().map(|&s| s as i64).collect();
        xla::Literal::vec1(data)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape {name} arg {arg}: {e:?}"))
    }

    fn out_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(|e| anyhow!("output to f32: {e:?}"))
    }

    fn out_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
        lit.to_vec::<i32>().map_err(|e| anyhow!("output to i32: {e:?}"))
    }

    fn out_scalar_f32(lit: &xla::Literal) -> Result<f32> {
        Ok(Self::out_f32(lit)?[0])
    }

    // ---- typed entry points --------------------------------------------------

    /// `init_params(seed) -> params[d]` (He-normal init, Appendix D model).
    pub fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let out = self.exec("init_params", &[xla::Literal::scalar(seed)])?;
        let params = Self::out_f32(&out[0])?;
        debug_assert_eq!(params.len(), self.d());
        Ok(params)
    }

    /// `client_update(params, xs, ys, mask, lr, seed)` — Algorithm 2.
    #[allow(clippy::too_many_arguments)]
    pub fn client_update(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        mask: &[f32],
        lr: f32,
        seed: i32,
    ) -> Result<RoundOutput> {
        let n = "client_update";
        let out = self.exec(
            n,
            &[
                self.lit_f32(n, 0, params)?,
                self.lit_f32(n, 1, xs)?,
                self.lit_i32(n, 2, ys)?,
                self.lit_f32(n, 3, mask)?,
                xla::Literal::scalar(lr),
                xla::Literal::scalar(seed),
            ],
        )?;
        Ok(RoundOutput {
            delta: Self::out_f32(&out[0])?,
            loss: Self::out_scalar_f32(&out[1])?,
            acc: Self::out_scalar_f32(&out[2])?,
        })
    }

    /// `client_update_quantized(...)` — Algorithm 2 + in-graph Pallas qsgd.
    #[allow(clippy::too_many_arguments)]
    pub fn client_update_quantized(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        mask: &[f32],
        lr: f32,
        seed: i32,
        u: &[f32],
        s_levels: f32,
    ) -> Result<QuantizedRoundOutput> {
        let n = "client_update_quantized";
        let out = self.exec(
            n,
            &[
                self.lit_f32(n, 0, params)?,
                self.lit_f32(n, 1, xs)?,
                self.lit_i32(n, 2, ys)?,
                self.lit_f32(n, 3, mask)?,
                xla::Literal::scalar(lr),
                xla::Literal::scalar(seed),
                self.lit_f32(n, 6, u)?,
                xla::Literal::scalar(s_levels),
            ],
        )?;
        Ok(QuantizedRoundOutput {
            levels: Self::out_i32(&out[0])?,
            norms: Self::out_f32(&out[1])?,
            loss: Self::out_scalar_f32(&out[2])?,
            acc: Self::out_scalar_f32(&out[3])?,
        })
    }

    /// One plain SGD step (`train_step` artifact).
    pub fn train_step(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        lr: f32,
        seed: i32,
    ) -> Result<(Vec<f32>, f32, f32)> {
        let n = "train_step";
        let out = self.exec(
            n,
            &[
                self.lit_f32(n, 0, params)?,
                self.lit_f32(n, 1, x)?,
                self.lit_i32(n, 2, y)?,
                self.lit_f32(n, 3, mask)?,
                xla::Literal::scalar(lr),
                xla::Literal::scalar(seed),
            ],
        )?;
        Ok((
            Self::out_f32(&out[0])?,
            Self::out_scalar_f32(&out[1])?,
            Self::out_scalar_f32(&out[2])?,
        ))
    }

    /// `eval_step(params, x, y, mask) -> (loss_sum, correct, count)`.
    pub fn eval_step(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<(f32, f32, f32)> {
        let n = "eval_step";
        let out = self.exec(
            n,
            &[
                self.lit_f32(n, 0, params)?,
                self.lit_f32(n, 1, x)?,
                self.lit_i32(n, 2, y)?,
                self.lit_f32(n, 3, mask)?,
            ],
        )?;
        Ok((
            Self::out_scalar_f32(&out[0])?,
            Self::out_scalar_f32(&out[1])?,
            Self::out_scalar_f32(&out[2])?,
        ))
    }

    /// `qsgd_quantize(x, u, s) -> (levels, bucket norms)` — the
    /// standalone L1 Pallas kernel artifact (cross-validates the codec).
    pub fn qsgd_quantize(&self, x: &[f32], u: &[f32], s_levels: f32) -> Result<(Vec<i32>, Vec<f32>)> {
        let n = "qsgd_quantize";
        let out = self.exec(
            n,
            &[
                self.lit_f32(n, 0, x)?,
                self.lit_f32(n, 1, u)?,
                xla::Literal::scalar(s_levels),
            ],
        )?;
        Ok((Self::out_i32(&out[0])?, Self::out_f32(&out[1])?))
    }
}

// ---------------------------------------------------------------------------
// Stub entry points (default offline build) — same signatures, always Err.
// An Engine cannot actually be constructed in this mode (load_subset
// errors), so these are unreachable at runtime; they exist so callers
// type-check identically with and without the feature.
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
impl Engine {
    fn unavailable<T>(&self, what: &str) -> Result<T> {
        anyhow::bail!("PJRT engine unavailable (built without `pjrt` feature): {what}")
    }

    pub fn init_params(&self, _seed: i32) -> Result<Vec<f32>> {
        self.unavailable("init_params")
    }

    #[allow(clippy::too_many_arguments)]
    pub fn client_update(
        &self,
        _params: &[f32],
        _xs: &[f32],
        _ys: &[i32],
        _mask: &[f32],
        _lr: f32,
        _seed: i32,
    ) -> Result<RoundOutput> {
        self.unavailable("client_update")
    }

    #[allow(clippy::too_many_arguments)]
    pub fn client_update_quantized(
        &self,
        _params: &[f32],
        _xs: &[f32],
        _ys: &[i32],
        _mask: &[f32],
        _lr: f32,
        _seed: i32,
        _u: &[f32],
        _s_levels: f32,
    ) -> Result<QuantizedRoundOutput> {
        self.unavailable("client_update_quantized")
    }

    pub fn train_step(
        &self,
        _params: &[f32],
        _x: &[f32],
        _y: &[i32],
        _mask: &[f32],
        _lr: f32,
        _seed: i32,
    ) -> Result<(Vec<f32>, f32, f32)> {
        self.unavailable("train_step")
    }

    pub fn eval_step(
        &self,
        _params: &[f32],
        _x: &[f32],
        _y: &[i32],
        _mask: &[f32],
    ) -> Result<(f32, f32, f32)> {
        self.unavailable("eval_step")
    }

    pub fn qsgd_quantize(
        &self,
        _x: &[f32],
        _u: &[f32],
        _s_levels: f32,
    ) -> Result<(Vec<i32>, Vec<f32>)> {
        self.unavailable("qsgd_quantize")
    }
}

/// Resolve the artifacts directory: explicit arg, `QAFEL_ARTIFACTS` env
/// var, or `artifacts` relative to the working directory.
pub fn artifacts_dir(explicit: &str) -> String {
    if !explicit.is_empty() {
        return explicit.to_string();
    }
    std::env::var("QAFEL_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

/// Quick availability check used by tests to skip when `make artifacts`
/// hasn't been run — always false in a build without the `pjrt` feature,
/// so PJRT-dependent tests and tools skip gracefully even when the
/// artifact files are present.
pub fn artifacts_available(dir: &str) -> bool {
    cfg!(feature = "pjrt") && std::path::Path::new(dir).join("manifest.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine tests against real artifacts live in rust/tests/ (they need
    // `make artifacts`); here we only cover pure helpers.

    #[test]
    fn artifacts_dir_resolution() {
        assert_eq!(artifacts_dir("x"), "x");
        std::env::remove_var("QAFEL_ARTIFACTS");
        assert_eq!(artifacts_dir(""), "artifacts");
    }

    #[test]
    fn availability_check() {
        assert!(!artifacts_available("/nonexistent/path"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_load_fails_with_clear_message() {
        let err = Engine::load("artifacts").unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }
}
