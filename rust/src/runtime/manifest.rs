//! Parser for `artifacts/manifest.json` (emitted by `python -m
//! compile.aot`): model architecture, flat-parameter layout, and the
//! input/output signature of every AOT-compiled computation.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Tensor dtype in an artifact signature.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype '{other}' in manifest"),
        }
    }
}

/// One tensor in an artifact signature.
#[derive(Clone, Debug)]
pub struct TensorSig {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled computation.
#[derive(Clone, Debug)]
pub struct ArtifactSig {
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// One named slice of the flat parameter vector.
#[derive(Clone, Debug)]
pub struct LayerInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// Model metadata.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    /// Total flat parameter count.
    pub d: usize,
    pub height: usize,
    pub width: usize,
    pub in_channels: usize,
    pub channels: usize,
    pub n_layers: usize,
    pub layers: Vec<LayerInfo>,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelInfo,
    /// Training batch size B baked into the artifacts.
    pub batch: usize,
    /// Local steps P baked into the client_update artifact.
    pub local_steps: usize,
    /// Eval batch size baked into eval_step.
    pub eval_batch: usize,
    pub artifacts: BTreeMap<String, ArtifactSig>,
}

fn sig_list(v: &Json) -> Result<Vec<TensorSig>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("signature must be an array"))?
        .iter()
        .map(|t| {
            Ok(TensorSig {
                dtype: DType::parse(
                    t.get("dtype").and_then(|d| d.as_str()).ok_or_else(|| anyhow!("no dtype"))?,
                )?,
                shape: t
                    .get("shape")
                    .and_then(|s| s.as_arr())
                    .ok_or_else(|| anyhow!("no shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<Vec<_>>>()?,
            })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let doc = Json::parse(text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let format = doc.get("format").and_then(|f| f.as_str()).unwrap_or("");
        if format != "qafel-artifacts-v1" {
            bail!("unknown manifest format '{format}'");
        }
        let model = doc.get("model").ok_or_else(|| anyhow!("manifest: no model"))?;
        let geti = |k: &str| -> Result<usize> {
            model
                .get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("manifest model.{k} missing"))
        };
        let layers = model
            .get("layers")
            .and_then(|l| l.as_arr())
            .ok_or_else(|| anyhow!("manifest: no layers"))?
            .iter()
            .map(|l| {
                Ok(LayerInfo {
                    name: l
                        .get("name")
                        .and_then(|n| n.as_str())
                        .ok_or_else(|| anyhow!("layer name"))?
                        .to_string(),
                    shape: l
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .ok_or_else(|| anyhow!("layer shape"))?
                        .iter()
                        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                        .collect::<Result<Vec<_>>>()?,
                    offset: l.get("offset").and_then(|o| o.as_usize()).ok_or_else(|| anyhow!("layer offset"))?,
                    size: l.get("size").and_then(|s| s.as_usize()).ok_or_else(|| anyhow!("layer size"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let d = geti("d")?;
        // verify the layout tiles [0, d) exactly
        let mut end = 0usize;
        for l in &layers {
            if l.offset != end {
                bail!("manifest layer {} offset {} != expected {end}", l.name, l.offset);
            }
            end += l.size;
        }
        if end != d {
            bail!("manifest layers cover {end} of d={d}");
        }

        let mut artifacts = BTreeMap::new();
        for (name, a) in doc
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| anyhow!("manifest: no artifacts"))?
        {
            artifacts.insert(
                name.clone(),
                ArtifactSig {
                    file: a
                        .get("file")
                        .and_then(|f| f.as_str())
                        .ok_or_else(|| anyhow!("artifact file"))?
                        .to_string(),
                    inputs: sig_list(a.get("inputs").ok_or_else(|| anyhow!("inputs"))?)?,
                    outputs: sig_list(a.get("outputs").ok_or_else(|| anyhow!("outputs"))?)?,
                },
            );
        }

        Ok(Manifest {
            dir,
            model: ModelInfo {
                d,
                height: geti("height")?,
                width: geti("width")?,
                in_channels: geti("in_channels")?,
                channels: geti("channels")?,
                n_layers: geti("n_layers")?,
                layers,
            },
            batch: doc
                .at(&["train", "batch"])
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("manifest train.batch"))?,
            local_steps: doc
                .at(&["train", "local_steps"])
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("manifest train.local_steps"))?,
            eval_batch: doc
                .get("eval_batch")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("manifest eval_batch"))?,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSig> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()))
    }

    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "qafel-artifacts-v1",
      "model": {"d": 10, "height": 32, "width": 32, "in_channels": 3,
                "channels": 2, "n_layers": 1, "kernel": 3, "padding": 2,
                "stride": 1, "groups": 1, "dropout": 0.1, "classes": 2,
                "layers": [
                  {"name": "a", "shape": [2, 3], "offset": 0, "size": 6},
                  {"name": "b", "shape": [4], "offset": 6, "size": 4}]},
      "train": {"batch": 4, "local_steps": 2},
      "eval_batch": 8,
      "artifacts": {
        "client_update": {"file": "client_update.hlo.txt",
          "inputs": [{"dtype": "float32", "shape": [10]},
                     {"dtype": "int32", "shape": [2, 4]}],
          "outputs": [{"dtype": "float32", "shape": [10]}]}}
    }"#;

    #[test]
    fn parses_valid_manifest() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.model.d, 10);
        assert_eq!(m.batch, 4);
        assert_eq!(m.local_steps, 2);
        assert_eq!(m.eval_batch, 8);
        let a = m.artifact("client_update").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].dtype, DType::F32);
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.inputs[1].elems(), 8);
        assert_eq!(m.artifact_path("client_update").unwrap(),
                   PathBuf::from("/tmp/client_update.hlo.txt"));
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn rejects_gapped_layout() {
        let bad = SAMPLE.replace("\"offset\": 6", "\"offset\": 7");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn rejects_wrong_format() {
        let bad = SAMPLE.replace("qafel-artifacts-v1", "v0");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        // integration-ish: when `make artifacts` has run, validate it.
        if let Ok(m) = Manifest::load("artifacts") {
            assert_eq!(m.model.d, 29474);
            assert!(m.artifacts.contains_key("client_update"));
            assert!(m.artifacts.contains_key("qsgd_quantize"));
        }
    }
}
