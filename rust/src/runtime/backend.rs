//! Compute backends: the interface between the L3 coordinator and "what a
//! client actually computes".
//!
//! * [`PjrtBackend`] — the real stack: synthetic CelebA batches + the AOT
//!   `client_update` / `eval_step` executables via PJRT (L2/L1 inside).
//! * [`QuadraticBackend`] — an analytic heterogeneous least-squares
//!   objective with controllable smoothness L, gradient noise sigma_l and
//!   client drift; used by the Proposition 3.5 convergence experiment
//!   (where ||grad f||^2 must be measurable exactly) and by fast unit
//!   tests of the coordinator/simulator, with no PJRT dependency.
//!
//! Backends take `&self` (the simulator is single-threaded per run);
//! internal scratch buffers use `RefCell`.

use super::engine::{Engine, RoundOutput};
use crate::config::DataConfig;
use crate::data::{Dataset, Partition, IMG_ELEMS};
use crate::util::dist::Normal;
use crate::util::pool::{ShardPool, Task};
use crate::util::prng::Prng;
use crate::util::vecf;
use anyhow::Result;
use std::cell::RefCell;

/// Validation metrics.
#[derive(Clone, Copy, Debug)]
pub struct EvalOutput {
    pub loss: f64,
    pub accuracy: f64,
    /// ||grad f(x)||^2 where available (analytic backends only) — the
    /// quantity bounded by Theorem F.1.
    pub grad_norm_sq: Option<f64>,
}

/// What a client computes in one round, plus how the server evaluates.
pub trait Backend {
    /// Flat parameter dimension d.
    fn d(&self) -> usize;

    /// Initial model x^0 (shared by server and all clients).
    fn init_params(&self, seed: i32) -> Result<Vec<f32>>;

    /// Algorithm 2: run P local SGD steps for `user` starting from
    /// `params` (the client's copy of the hidden state) and return the
    /// model delta. `round_seed` makes batch sampling + dropout
    /// deterministic per upload.
    fn client_round(
        &self,
        params: &[f32],
        user: usize,
        round_seed: u64,
        lr: f32,
    ) -> Result<RoundOutput>;

    /// Evaluate on the validation split.
    fn evaluate(&self, params: &[f32]) -> Result<EvalOutput>;

    /// Evaluate with the reduction sharded on a worker pool (the sim
    /// passes the server's persistent [`ShardPool`], ROADMAP's
    /// heavy-traffic eval path). Implementations must be **bit-identical**
    /// to [`Backend::evaluate`] for every pool size; the default simply
    /// delegates.
    fn evaluate_pooled(&self, params: &[f32], _pool: &ShardPool) -> Result<EvalOutput> {
        self.evaluate(params)
    }

    /// Number of train-split users the server may sample.
    fn num_train_users(&self) -> usize;
}

// ---------------------------------------------------------------------------
// PJRT backend (the real three-layer stack)
// ---------------------------------------------------------------------------

struct Scratch {
    xs: Vec<f32>,
    ys: Vec<i32>,
    mask: Vec<f32>,
}

/// One pre-materialized eval batch.
struct EvalBatch {
    x: Vec<f32>,
    y: Vec<i32>,
    mask: Vec<f32>,
}

/// Real backend: synthetic CelebA data + AOT artifacts via PJRT.
///
/// Holds the engine behind an `Rc` so several backends (one per seed in a
/// sweep) share one compiled artifact set.
pub struct PjrtBackend {
    engine: std::rc::Rc<Engine>,
    dataset: Dataset,
    partition: Partition,
    master_seed: u64,
    client_lr_scale: f32,
    eval_batches: Vec<EvalBatch>,
    scratch: RefCell<Scratch>,
}

/// Fixed reduction block (in eval batches) shared by the PJRT
/// `evaluate` and `evaluate_pooled`: per-batch partials are summed per
/// block and the block sums are reduced in block order, so the pooled
/// and sequential evals are bit-identical for every pool size (same
/// contract as [`EVAL_BLOCK`] for the quadratic backend).
const EVAL_BATCH_BLOCK: usize = 8;

impl PjrtBackend {
    /// Build from a loaded engine + data config. `master_seed` drives all
    /// batch sampling (use the experiment seed).
    pub fn new(
        engine: std::rc::Rc<Engine>,
        data_cfg: &DataConfig,
        master_seed: u64,
    ) -> Result<PjrtBackend> {
        let dataset = Dataset::new(data_cfg);
        let partition = Partition::leaf(dataset.num_users(), data_cfg.seed);
        let m = engine.manifest();
        let (p, b, eb) = (m.local_steps, m.batch, m.eval_batch);
        let img = engine.img_elems();
        debug_assert_eq!(img, IMG_ELEMS);

        // Materialize the fixed validation set once (paper evaluates a
        // fixed val split; re-generating synthetic images per eval would
        // dominate runtime).
        let mut erng = Prng::new(data_cfg.seed).stream("eval-subsample");
        let index = dataset.eval_index(&partition.val, data_cfg.eval_samples, &mut erng);
        let mut eval_batches = Vec::new();
        for chunk in index.chunks(eb) {
            let mut batch = EvalBatch {
                x: vec![0.0; eb * img],
                y: vec![0i32; eb],
                mask: vec![0.0; eb],
            };
            for (slot, &(u, j)) in chunk.iter().enumerate() {
                let dst = &mut batch.x[slot * img..(slot + 1) * img];
                batch.y[slot] = dataset.sample_into(u, j, dst) as i32;
                batch.mask[slot] = 1.0;
            }
            eval_batches.push(batch);
        }

        Ok(PjrtBackend {
            engine,
            dataset,
            partition,
            master_seed,
            client_lr_scale: 1.0,
            eval_batches,
            scratch: RefCell::new(Scratch {
                xs: vec![0.0; p * b * img],
                ys: vec![0i32; p * b],
                mask: vec![0.0; p * b],
            }),
        })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn dataset(&self) -> &Dataset {
        &self.dataset
    }

    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// One `(loss, correct, count)` partial per eval batch. The PJRT
    /// executions stay on the caller's thread: the engine lives behind
    /// an `Rc` (its execution context is not `Send`), and each
    /// executable already saturates cores internally.
    fn eval_partials(&self, params: &[f32]) -> Result<Vec<(f64, f64, f64)>> {
        let mut partials = Vec::with_capacity(self.eval_batches.len());
        for b in &self.eval_batches {
            let (l, c, n) = self.engine.eval_step(params, &b.x, &b.y, &b.mask)?;
            partials.push((l as f64, c as f64, n as f64));
        }
        Ok(partials)
    }

    /// Sum one block of per-batch partials sequentially.
    fn eval_batch_block(partials: &[(f64, f64, f64)]) -> (f64, f64, f64) {
        let (mut l, mut c, mut n) = (0.0f64, 0.0f64, 0.0f64);
        for &(pl, pc, pn) in partials {
            l += pl;
            c += pc;
            n += pn;
        }
        (l, c, n)
    }

    /// The bit-identity reference reduction: block sums in block order.
    fn eval_blocked_reduce(partials: &[(f64, f64, f64)]) -> (f64, f64, f64) {
        let (mut l, mut c, mut n) = (0.0f64, 0.0f64, 0.0f64);
        for block in partials.chunks(EVAL_BATCH_BLOCK) {
            let (bl, bc, bn) = Self::eval_batch_block(block);
            l += bl;
            c += bc;
            n += bn;
        }
        (l, c, n)
    }

    fn finalize_eval(loss_sum: f64, correct: f64, count: f64) -> EvalOutput {
        EvalOutput {
            loss: loss_sum / count.max(1.0),
            accuracy: correct / count.max(1.0),
            grad_norm_sq: None,
        }
    }
}

impl Backend for PjrtBackend {
    fn d(&self) -> usize {
        self.engine.d()
    }

    fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        self.engine.init_params(seed)
    }

    fn client_round(
        &self,
        params: &[f32],
        user: usize,
        round_seed: u64,
        lr: f32,
    ) -> Result<RoundOutput> {
        let m = self.engine.manifest();
        let (p, b) = (m.local_steps, m.batch);
        let train_user = self.partition.train[user % self.partition.train.len()];
        let mut scratch = self.scratch.borrow_mut();
        let Scratch { xs, ys, mask } = &mut *scratch;
        let mut rng = Prng::new(self.master_seed)
            .stream("client-batches")
            .stream_u64(train_user as u64)
            .stream_u64(round_seed);
        self.dataset.fill_round(train_user, &mut rng, p, b, xs, ys, mask);
        let dropout_seed = (rng.next_u32() & 0x7FFF_FFFF) as i32;
        self.engine
            .client_update(params, xs, ys, mask, lr * self.client_lr_scale, dropout_seed)
    }

    fn evaluate(&self, params: &[f32]) -> Result<EvalOutput> {
        let partials = self.eval_partials(params)?;
        let (l, c, n) = Self::eval_blocked_reduce(&partials);
        Ok(Self::finalize_eval(l, c, n))
    }

    fn evaluate_pooled(&self, params: &[f32], pool: &ShardPool) -> Result<EvalOutput> {
        // the per-batch partials cannot move off-thread (see
        // `eval_partials`); the pool takes the blocked f64 reduction,
        // reduced in block order — bitwise equal to `evaluate`
        let partials = self.eval_partials(params)?;
        let (l, c, n) = pooled_batch_reduce(&partials, pool);
        Ok(Self::finalize_eval(l, c, n))
    }

    fn num_train_users(&self) -> usize {
        self.partition.train.len()
    }
}

/// Pool-sharded version of [`PjrtBackend::eval_blocked_reduce`]: block
/// sums computed in parallel, reduced in block order — bitwise equal to
/// the sequential reference for every pool size.
fn pooled_batch_reduce(partials: &[(f64, f64, f64)], pool: &ShardPool) -> (f64, f64, f64) {
    let n_blocks = partials.len().div_ceil(EVAL_BATCH_BLOCK);
    if pool.shards() <= 1 || n_blocks < 2 {
        return PjrtBackend::eval_blocked_reduce(partials);
    }
    let per_task = n_blocks.div_ceil(pool.shards());
    let mut sums = vec![(0.0f64, 0.0f64, 0.0f64); n_blocks];
    let tasks: Vec<Task<'_>> = sums
        .chunks_mut(per_task)
        .enumerate()
        .map(|(t, chunk)| {
            Box::new(move || {
                for (j, slot) in chunk.iter_mut().enumerate() {
                    let lo = (t * per_task + j) * EVAL_BATCH_BLOCK;
                    let hi = (lo + EVAL_BATCH_BLOCK).min(partials.len());
                    *slot = PjrtBackend::eval_batch_block(&partials[lo..hi]);
                }
            }) as Task<'_>
        })
        .collect();
    pool.run(tasks);
    let (mut l, mut c, mut n) = (0.0f64, 0.0f64, 0.0f64);
    for &(bl, bc, bn) in &sums {
        l += bl;
        c += bc;
        n += bn;
    }
    (l, c, n)
}

// ---------------------------------------------------------------------------
// Analytic quadratic backend (convergence analysis + fast tests)
// ---------------------------------------------------------------------------

/// Heterogeneous quadratic: client n minimizes
/// `F_n(x) = 0.5 (x - c_n)' A (x - c_n)` with diagonal A in [mu, L] and
/// per-client optimum `c_n = c* + drift_n`. Stochastic gradients add
/// `sigma_l` iid noise. The global optimum is x* = mean(c_n);
/// `||grad f(x)||^2 = ||A (x - c̄)||^2` is computed in closed form, which
/// is exactly the quantity in Proposition 3.5.
pub struct QuadraticBackend {
    d: usize,
    n_clients: usize,
    /// Diagonal of A.
    a: Vec<f32>,
    /// Per-client optima c_n (n_clients x d, flattened).
    centers: Vec<f32>,
    /// Mean center c̄ (global optimum).
    center_mean: Vec<f32>,
    /// Local gradient noise sigma_l.
    pub sigma_l: f32,
    /// Local steps P per round.
    pub local_steps: usize,
    seed: u64,
}

/// Fixed reduction block for the quadratic eval: partial sums are
/// accumulated per block and reduced in block order, so the pooled and
/// sequential evals are bit-identical for every pool size (f64 addition
/// is not associative; a pool-size-dependent split would break the
/// "same curve for every `fl.shards`" contract).
const EVAL_BLOCK: usize = 4096;

impl QuadraticBackend {
    pub fn new(
        d: usize,
        n_clients: usize,
        l_smooth: f32,
        mu: f32,
        heterogeneity: f32,
        sigma_l: f32,
        local_steps: usize,
        seed: u64,
    ) -> QuadraticBackend {
        let mut rng = Prng::new(seed).stream("quadratic");
        let mut normal = Normal::new();
        let a: Vec<f32> = (0..d).map(|_| mu + (l_smooth - mu) * rng.f32()).collect();
        let mut centers = vec![0.0f32; n_clients * d];
        let mut center_mean = vec![0.0f32; d];
        let base: Vec<f32> = (0..d).map(|_| normal.sample(&mut rng) as f32).collect();
        for n in 0..n_clients {
            for i in 0..d {
                let c = base[i] + heterogeneity * normal.sample(&mut rng) as f32;
                centers[n * d + i] = c;
                center_mean[i] += c / n_clients as f32;
            }
        }
        QuadraticBackend { d, n_clients, a, centers, center_mean, sigma_l, local_steps, seed }
    }

    /// One eval block: `(||A (x - c̄)||^2, f(x) - f*)` partials over
    /// `[lo, hi)`.
    fn eval_block(&self, x: &[f32], lo: usize, hi: usize) -> (f64, f64) {
        let (mut g2, mut sub) = (0.0f64, 0.0f64);
        for i in lo..hi {
            let dx = (x[i] - self.center_mean[i]) as f64;
            let g = self.a[i] as f64 * dx;
            g2 += g * g;
            sub += 0.5 * self.a[i] as f64 * dx * dx;
        }
        (g2, sub)
    }

    /// Sequential blocked reduction (the bit-identity reference for the
    /// pooled eval).
    fn eval_reduce(&self, x: &[f32]) -> (f64, f64) {
        let (mut g2, mut sub) = (0.0f64, 0.0f64);
        let mut lo = 0usize;
        while lo < self.d {
            let hi = (lo + EVAL_BLOCK).min(self.d);
            let (g, s) = self.eval_block(x, lo, hi);
            g2 += g;
            sub += s;
            lo = hi;
        }
        (g2, sub)
    }

    /// Exact ||grad f(x)||^2 = || A (x - c̄) ||^2.
    pub fn grad_norm_sq(&self, x: &[f32]) -> f64 {
        self.eval_reduce(x).0
    }

    /// f(x) - f* (suboptimality).
    ///
    /// f(x) = mean_n 0.5 (x-c_n)'A(x-c_n); f* at x* = c̄ leaves the
    /// variance term, which cancels in f(x) - f(x*).
    pub fn suboptimality(&self, x: &[f32]) -> f64 {
        self.eval_reduce(x).1
    }
}

impl Backend for QuadraticBackend {
    fn d(&self) -> usize {
        self.d
    }

    fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let mut rng = Prng::new(self.seed ^ seed as u64).stream("init");
        let mut normal = Normal::new();
        Ok((0..self.d).map(|_| 2.0 * normal.sample(&mut rng) as f32).collect())
    }

    fn client_round(
        &self,
        params: &[f32],
        user: usize,
        round_seed: u64,
        lr: f32,
    ) -> Result<RoundOutput> {
        let n = user % self.n_clients;
        let c = &self.centers[n * self.d..(n + 1) * self.d];
        let mut rng = Prng::new(self.seed)
            .stream("round-noise")
            .stream_u64(n as u64)
            .stream_u64(round_seed);
        let mut normal = Normal::new();
        let mut y: Vec<f32> = params.to_vec();
        let mut loss_acc = 0.0f64;
        for _ in 0..self.local_steps {
            let mut fval = 0.0f64;
            for i in 0..self.d {
                let g = self.a[i] * (y[i] - c[i])
                    + self.sigma_l * normal.sample(&mut rng) as f32;
                fval += 0.5 * (self.a[i] * (y[i] - c[i]) * (y[i] - c[i])) as f64;
                y[i] -= lr * g;
            }
            loss_acc += fval;
        }
        let mut delta = vec![0.0f32; self.d];
        vecf::sub(&mut delta, &y, params);
        Ok(RoundOutput {
            delta,
            loss: (loss_acc / self.local_steps as f64) as f32,
            acc: 0.0,
        })
    }

    fn evaluate(&self, params: &[f32]) -> Result<EvalOutput> {
        let (g2, loss) = self.eval_reduce(params);
        Ok(EvalOutput {
            loss,
            // monotone proxy so accuracy-based stop rules remain usable
            accuracy: 1.0 / (1.0 + g2),
            grad_norm_sq: Some(g2),
        })
    }

    fn evaluate_pooled(&self, params: &[f32], pool: &ShardPool) -> Result<EvalOutput> {
        let n_blocks = self.d.div_ceil(EVAL_BLOCK);
        if pool.shards() <= 1 || n_blocks < 2 {
            return self.evaluate(params);
        }
        // per-block partials computed in parallel, reduced in block
        // order — bitwise equal to the sequential `eval_reduce`
        let per_task = n_blocks.div_ceil(pool.shards());
        let mut partials = vec![(0.0f64, 0.0f64); n_blocks];
        let tasks: Vec<Task<'_>> = partials
            .chunks_mut(per_task)
            .enumerate()
            .map(|(t, chunk)| {
                Box::new(move || {
                    for (j, slot) in chunk.iter_mut().enumerate() {
                        let lo = (t * per_task + j) * EVAL_BLOCK;
                        let hi = (lo + EVAL_BLOCK).min(self.d);
                        *slot = self.eval_block(params, lo, hi);
                    }
                }) as Task<'_>
            })
            .collect();
        pool.run(tasks);
        let (mut g2, mut loss) = (0.0f64, 0.0f64);
        for &(g, s) in &partials {
            g2 += g;
            loss += s;
        }
        Ok(EvalOutput { loss, accuracy: 1.0 / (1.0 + g2), grad_norm_sq: Some(g2) })
    }

    fn num_train_users(&self) -> usize {
        self.n_clients
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> QuadraticBackend {
        QuadraticBackend::new(16, 8, 1.0, 0.1, 0.5, 0.01, 2, 7)
    }

    #[test]
    fn quadratic_gradient_descent_converges() {
        let b = backend();
        let mut x = b.init_params(0).unwrap();
        let g0 = b.grad_norm_sq(&x);
        // emulate centralized training: average rounds over all clients
        for round in 0..2000 {
            let mut mean_delta = vec![0.0f32; b.d()];
            for u in 0..b.num_train_users() {
                let out = b.client_round(&x, u, round, 0.2).unwrap();
                vecf::axpy(&mut mean_delta, 1.0 / b.num_train_users() as f32, &out.delta);
            }
            vecf::add_assign(&mut x, &mean_delta);
        }
        let g1 = b.grad_norm_sq(&x);
        assert!(g1 < g0 * 1e-2, "grad^2 {g0} -> {g1}");
    }

    #[test]
    fn rounds_are_deterministic_given_seed() {
        let b = backend();
        let x = b.init_params(1).unwrap();
        let r1 = b.client_round(&x, 3, 42, 0.1).unwrap();
        let r2 = b.client_round(&x, 3, 42, 0.1).unwrap();
        let r3 = b.client_round(&x, 3, 43, 0.1).unwrap();
        assert_eq!(r1.delta, r2.delta);
        assert_ne!(r1.delta, r3.delta);
    }

    #[test]
    fn evaluate_reports_exact_grad_norm() {
        let b = backend();
        let x = vec![0.0f32; 16];
        let e = b.evaluate(&x).unwrap();
        assert!((e.grad_norm_sq.unwrap() - b.grad_norm_sq(&x)).abs() < 1e-12);
        assert!(e.loss >= 0.0);
    }

    #[test]
    fn pooled_eval_is_bit_identical_to_sequential_for_every_pool_size() {
        // d spans several EVAL_BLOCKs with a ragged tail; f64 sums are
        // order-sensitive, so this pins the fixed-block reduction
        let b = QuadraticBackend::new(3 * EVAL_BLOCK + 1234, 6, 1.0, 0.2, 0.4, 0.01, 1, 5);
        let x = b.init_params(2).unwrap();
        let seq = b.evaluate(&x).unwrap();
        for shards in [1usize, 2, 3, 8] {
            let pool = ShardPool::new(shards);
            let pooled = b.evaluate_pooled(&x, &pool).unwrap();
            assert_eq!(seq.loss.to_bits(), pooled.loss.to_bits(), "S={shards} loss");
            assert_eq!(
                seq.accuracy.to_bits(),
                pooled.accuracy.to_bits(),
                "S={shards} accuracy"
            );
            assert_eq!(
                seq.grad_norm_sq.unwrap().to_bits(),
                pooled.grad_norm_sq.unwrap().to_bits(),
                "S={shards} grad"
            );
        }
        // the public reducers share the same blocked reduction
        assert_eq!(seq.grad_norm_sq.unwrap().to_bits(), b.grad_norm_sq(&x).to_bits());
        assert_eq!(seq.loss.to_bits(), b.suboptimality(&x).to_bits());
    }

    #[test]
    fn pjrt_batch_reduce_is_bit_identical_for_every_pool_size() {
        // the PJRT eval's reduction (no engine needed: it operates on
        // plain per-batch partials) must match the sequential blocked
        // reference bitwise, including ragged tails and per_task splits
        let mut rng = Prng::new(9).stream("reduce-test");
        for len in [1usize, 7, 8, 9, 37, 3 * EVAL_BATCH_BLOCK] {
            let partials: Vec<(f64, f64, f64)> = (0..len)
                .map(|_| (rng.f32() as f64, rng.f32() as f64, (rng.f32() * 64.0 + 1.0) as f64))
                .collect();
            let seq = PjrtBackend::eval_blocked_reduce(&partials);
            for shards in [1usize, 2, 3, 8] {
                let pool = ShardPool::new(shards);
                let pooled = pooled_batch_reduce(&partials, &pool);
                assert_eq!(seq.0.to_bits(), pooled.0.to_bits(), "len={len} S={shards} loss");
                assert_eq!(seq.1.to_bits(), pooled.1.to_bits(), "len={len} S={shards} correct");
                assert_eq!(seq.2.to_bits(), pooled.2.to_bits(), "len={len} S={shards} count");
            }
        }
    }

    #[test]
    fn heterogeneity_shifts_client_optima() {
        let b = QuadraticBackend::new(8, 4, 1.0, 1.0, 2.0, 0.0, 1, 3);
        // with sigma_l = 0 and full-batch gradients, different clients
        // produce different deltas from the same point
        let x = vec![0.0f32; 8];
        let d0 = b.client_round(&x, 0, 0, 0.1).unwrap().delta;
        let d1 = b.client_round(&x, 1, 0, 0.1).unwrap().delta;
        assert_ne!(d0, d1);
    }
}
