//! n-bit **bucketed** qsgd quantizer (Example B.1; Alistarh et al. 2017).
//!
//! `qsgd_s(x)` transmits `||x||`, `sign(x)` and stochastically rounded
//! integer levels `xi(x, s)`. Following the original QSGD design (and
//! explaining the paper's per-message overhead beyond d*n/8 bytes), the
//! vector is quantized in **buckets** of `g` coordinates with one f32
//! norm per bucket: the variance constant is then
//! `min(2g/s^2, sqrt(2g)/s)` instead of the dimension-dependent
//! `sqrt(2d)/s` — at g = 128 and 4 bits that is 2.3 rather than 35 for
//! the paper's d = 29,474, which is what makes coarse quantizers usable
//! at realistic model sizes.
//!
//! An *n-bit* qsgd spends n bits per coordinate: 1 sign bit + (n-1)
//! magnitude bits, so s = 2^(n-1) - 1 levels (4-bit => s = 7,
//! 8-bit => s = 127, 2-bit => s = 1, i.e. ternary). Payload:
//!
//! ```text
//!   [ norm_0 .. norm_{B-1} : f32 each ] [ coord_0 : n bits ] ...
//! ```
//!
//! densely bit-packed; total = 4*ceil(d/g) + ceil(d*n/8) bytes. For the
//! paper's model at 4 bits: 15.66 kB vs the paper's reported 15.38 kB.
//!
//! Stochastic rounding `xi_i = floor(|x_i| s / ||bucket|| + u_i)` is the
//! same math as the L1 Pallas kernel (`python/compile/kernels/qsgd.py`);
//! `encode_levels` lets the PJRT path feed kernel-produced levels into
//! this codec.

use super::{QuantizedMsg, Quantizer};
use crate::util::bitio::{BitReader, BitWriter};
use crate::util::prng::Prng;
use anyhow::{bail, Result};

/// Default bucket size (QSGD paper's recommendation).
pub const DEFAULT_BUCKET: usize = 128;

/// n-bit bucketed qsgd.
#[derive(Clone, Copy, Debug)]
pub struct Qsgd {
    bits: u32,
    /// Number of levels s = 2^(bits-1) - 1.
    s: u32,
    /// Bucket size g.
    bucket: usize,
}

impl Qsgd {
    pub fn new(bits: u32) -> Result<Self> {
        Self::with_bucket(bits, DEFAULT_BUCKET)
    }

    pub fn with_bucket(bits: u32, bucket: usize) -> Result<Self> {
        if !(2..=16).contains(&bits) {
            bail!("qsgd bits must be in 2..=16 (got {bits})");
        }
        if bucket == 0 {
            bail!("qsgd bucket must be >= 1");
        }
        Ok(Qsgd { bits, s: (1u32 << (bits - 1)) - 1, bucket })
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Levels s (quantization granularity).
    pub fn levels(&self) -> u32 {
        self.s
    }

    pub fn bucket(&self) -> usize {
        self.bucket
    }

    fn n_buckets(&self, d: usize) -> usize {
        d.div_ceil(self.bucket)
    }

    /// Pack precomputed signed levels + per-bucket norms into the wire
    /// format (levels from the Pallas kernel artifact take this path).
    pub fn encode_levels(&self, levels: &[i32], norms: &[f32]) -> QuantizedMsg {
        let d = levels.len();
        assert_eq!(norms.len(), self.n_buckets(d), "norms/bucket mismatch");
        let mut w = BitWriter::with_capacity(norms.len() * 32 + d * self.bits as usize);
        for &n in norms {
            w.write_f32(n);
        }
        for &lv in levels {
            debug_assert!(lv.unsigned_abs() <= self.s, "level {lv} > s={}", self.s);
            let sign = (lv < 0) as u64;
            let mag = lv.unsigned_abs().min(self.s) as u64;
            w.write(sign | (mag << 1), self.bits);
        }
        QuantizedMsg { payload: w.into_bytes(), d }
    }

    /// Decode payload into (per-bucket norms, signed levels).
    pub fn decode_levels(&self, msg: &QuantizedMsg) -> Result<(Vec<f32>, Vec<i32>)> {
        let nb = self.n_buckets(msg.d);
        let mut r = BitReader::new(&msg.payload);
        let mut norms = Vec::with_capacity(nb);
        for _ in 0..nb {
            match r.read_f32() {
                Some(n) => norms.push(n),
                None => bail!("qsgd: truncated payload (norms)"),
            }
        }
        let mut levels = Vec::with_capacity(msg.d);
        for i in 0..msg.d {
            let raw = match r.read(self.bits) {
                Some(v) => v,
                None => bail!("qsgd: truncated payload at coord {i}"),
            };
            let sign = raw & 1;
            let mag = (raw >> 1) as i32;
            levels.push(if sign == 1 { -mag } else { mag });
        }
        Ok((norms, levels))
    }
}

impl Quantizer for Qsgd {
    fn name(&self) -> String {
        if self.bucket == DEFAULT_BUCKET {
            format!("qsgd:{}", self.bits)
        } else {
            format!("qsgd:{}:{}", self.bits, self.bucket)
        }
    }

    fn quantize(&self, x: &[f32], rng: &mut Prng) -> QuantizedMsg {
        let d = x.len();
        let nb = self.n_buckets(d);
        let mut w = BitWriter::with_capacity(nb * 32 + d * self.bits as usize);
        // per-bucket norms first (header), then all levels
        let mut scales = Vec::with_capacity(nb);
        for b in 0..nb {
            let lo = b * self.bucket;
            let hi = (lo + self.bucket).min(d);
            let norm = crate::util::vecf::norm2(&x[lo..hi]) as f32;
            w.write_f32(norm);
            scales.push(if norm > 0.0 { self.s as f32 / norm } else { 0.0 });
        }
        for (i, &v) in x.iter().enumerate() {
            let a = v.abs() * scales[i / self.bucket];
            // floor(a + u): ceil with prob frac(a), floor otherwise
            let level = ((a + rng.f32()).floor() as u64).min(self.s as u64);
            let sign = (v < 0.0) as u64;
            w.write(sign | (level << 1), self.bits);
        }
        QuantizedMsg { payload: w.into_bytes(), d }
    }

    fn dequantize_into(&self, msg: &QuantizedMsg, out: &mut [f32]) -> Result<()> {
        if msg.d != out.len() {
            bail!("qsgd: dimension mismatch (msg {}, out {})", msg.d, out.len());
        }
        if msg.payload.len() != self.expected_bytes(msg.d) {
            bail!("qsgd: payload size mismatch");
        }
        let nb = self.n_buckets(msg.d);
        let mut r = BitReader::new(&msg.payload);
        let mut units = Vec::with_capacity(nb);
        for _ in 0..nb {
            units.push(r.read_f32().unwrap() / self.s as f32);
        }
        for (i, o) in out.iter_mut().enumerate() {
            let raw = r.read(self.bits).unwrap();
            let mag = (raw >> 1) as f32;
            let signed = if raw & 1 == 1 { -mag } else { mag };
            *o = units[i / self.bucket] * signed;
        }
        Ok(())
    }

    fn accumulate(&self, msg: &QuantizedMsg, weight: f32, acc: &mut [f32]) -> Result<()> {
        if msg.d != acc.len() {
            bail!("qsgd: dimension mismatch");
        }
        if msg.payload.len() != self.expected_bytes(msg.d) {
            bail!("qsgd: payload size mismatch");
        }
        let nb = self.n_buckets(msg.d);
        let mut units = Vec::with_capacity(nb);
        for b in 0..nb {
            let off = 4 * b;
            let norm = f32::from_le_bytes(msg.payload[off..off + 4].try_into().unwrap());
            units.push(weight * norm / self.s as f32);
        }
        let body = &msg.payload[4 * nb..];
        // §Perf: byte-aligned fast paths — the generic BitReader loop
        // costs ~350 us at d = 29,474; these run in ~30 us (see
        // EXPERIMENTS.md §Perf L3 iteration log).
        match self.bits {
            8 => {
                // chunk by bucket: hoists the unit lookup out of the
                // inner loop and keeps it branch-free
                for (b, chunk) in acc.chunks_mut(self.bucket).enumerate() {
                    let unit = units[b];
                    let base = b * self.bucket;
                    for (j, a) in chunk.iter_mut().enumerate() {
                        let raw = body[base + j];
                        let mag = (raw >> 1) as f32;
                        let signed = if raw & 1 == 1 { -mag } else { mag };
                        *a += unit * signed;
                    }
                }
            }
            4 => {
                for (b, chunk) in acc.chunks_mut(self.bucket).enumerate() {
                    let unit = units[b];
                    let base = b * self.bucket;
                    for (j, a) in chunk.iter_mut().enumerate() {
                        let i = base + j;
                        let byte = body[i >> 1];
                        let raw = (byte >> ((i & 1) * 4)) & 0xF;
                        let mag = (raw >> 1) as f32;
                        let signed = if raw & 1 == 1 { -mag } else { mag };
                        *a += unit * signed;
                    }
                }
            }
            2 => {
                for (b, chunk) in acc.chunks_mut(self.bucket).enumerate() {
                    let unit = units[b];
                    let base = b * self.bucket;
                    for (j, a) in chunk.iter_mut().enumerate() {
                        let i = base + j;
                        let byte = body[i >> 2];
                        let raw = (byte >> ((i & 3) * 2)) & 0b11;
                        let mag = (raw >> 1) as f32;
                        let signed = if raw & 1 == 1 { -mag } else { mag };
                        *a += unit * signed;
                    }
                }
            }
            _ => {
                let mut r = BitReader::new(body);
                for (i, a) in acc.iter_mut().enumerate() {
                    let raw = r.read(self.bits).unwrap();
                    let mag = (raw >> 1) as f32;
                    let signed = if raw & 1 == 1 { -mag } else { mag };
                    *a += units[i / self.bucket] * signed;
                }
            }
        }
        Ok(())
    }

    fn is_unbiased(&self) -> bool {
        true
    }

    fn expected_bytes(&self, d: usize) -> usize {
        4 * self.n_buckets(d) + (d * self.bits as usize).div_ceil(8)
    }

    /// Lemma 3.1 (Alistarh et al. 2017) applied per bucket of size g:
    /// E||Q(x)-x||^2 <= min(2g/s^2, sqrt(2g)/s) ||x||^2.
    fn delta(&self, d: usize) -> f64 {
        let s = self.s as f64;
        let g = self.bucket.min(d) as f64;
        1.0 - (2.0 * g / (s * s)).min((2.0 * g).sqrt() / s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::vecf;

    #[test]
    fn wire_sizes_match_paper_shape() {
        #[allow(clippy::unnecessary_cast)]
        // paper reports 29.924 / 15.380 / 8.108 kB for 8/4/2-bit at
        // d = 29,282; our bucketed codec at d = 29,474:
        let d = 29_474;
        let nb = (d as usize).div_ceil(128);
        assert_eq!(Qsgd::new(8).unwrap().expected_bytes(d), 4 * nb + d);
        let kb4 = Qsgd::new(4).unwrap().expected_bytes(d) as f64 / 1000.0;
        assert!((kb4 - 15.38).abs() < 0.5, "4-bit size {kb4} kB vs paper 15.38");
        let kb2 = Qsgd::new(2).unwrap().expected_bytes(d) as f64 / 1000.0;
        assert!((kb2 - 8.108).abs() < 0.5, "2-bit size {kb2} kB vs paper 8.108");
    }

    #[test]
    fn bucketing_improves_contraction() {
        let d = 29_474;
        let whole = Qsgd::with_bucket(4, d).unwrap();
        let bucketed = Qsgd::new(4).unwrap();
        assert!(bucketed.delta(d) > whole.delta(d));
        // 8-bit bucketed is a true contraction (delta > 0)
        assert!(Qsgd::new(8).unwrap().delta(d) > 0.0);
    }

    #[test]
    fn levels_bounded_by_s() {
        let mut rng = Prng::new(3);
        for bits in [2u32, 4, 8] {
            let q = Qsgd::new(bits).unwrap();
            let x: Vec<f32> = (0..2000).map(|_| rng.f32() * 10.0 - 5.0).collect();
            let msg = q.quantize(&x, &mut rng);
            let (_, levels) = q.decode_levels(&msg).unwrap();
            assert!(levels.iter().all(|l| l.unsigned_abs() <= q.levels()));
        }
    }

    #[test]
    fn encode_decode_levels_roundtrip() {
        let q = Qsgd::with_bucket(4, 4).unwrap();
        let levels: Vec<i32> = vec![0, 1, -1, 7, -7, 3, -2, 0, 5];
        let norms = vec![12.5f32, 3.25, 0.5];
        let msg = q.encode_levels(&levels, &norms);
        let (n2, back) = q.decode_levels(&msg).unwrap();
        assert_eq!(norms, n2);
        assert_eq!(levels, back);
    }

    #[test]
    fn dequantize_matches_formula_per_bucket() {
        let mut rng = Prng::new(4);
        let q = Qsgd::with_bucket(4, 64).unwrap();
        let x: Vec<f32> = (0..257).map(|i| (i as f32 - 128.0) / 37.0).collect();
        let msg = q.quantize(&x, &mut rng);
        let (norms, levels) = q.decode_levels(&msg).unwrap();
        let deq = q.dequantize(&msg).unwrap();
        for i in 0..x.len() {
            let expect = norms[i / 64] / q.levels() as f32 * levels[i] as f32;
            assert!((deq[i] - expect).abs() < 1e-6);
        }
        // per-bucket norms are the actual bucket l2 norms
        for (b, n) in norms.iter().enumerate() {
            let lo = b * 64;
            let hi = (lo + 64).min(x.len());
            assert!((n - vecf::norm2(&x[lo..hi]) as f32).abs() < 1e-4);
        }
    }

    #[test]
    fn preserves_signs_of_large_coords() {
        let mut rng = Prng::new(5);
        let q = Qsgd::new(8).unwrap();
        let x = vec![10.0, -10.0, 10.0, -10.0];
        let deq = q.dequantize(&q.quantize(&x, &mut rng)).unwrap();
        for (a, b) in x.iter().zip(&deq) {
            assert!(a * b > 0.0, "{a} vs {b}");
            assert!((a - b).abs() / a.abs() < 0.02);
        }
    }

    #[test]
    fn zero_vector_is_fixed_point() {
        let mut rng = Prng::new(6);
        let q = Qsgd::new(4).unwrap();
        let x = vec![0.0f32; 300];
        let deq = q.dequantize(&q.quantize(&x, &mut rng)).unwrap();
        assert_eq!(deq, x);
    }

    #[test]
    fn error_decreases_with_bits() {
        let mut rng = Prng::new(7);
        let x: Vec<f32> = (0..4096).map(|_| rng.f32() - 0.5).collect();
        let mut errs = Vec::new();
        for bits in [2u32, 4, 8] {
            let q = Qsgd::new(bits).unwrap();
            let mut e = 0.0;
            for _ in 0..10 {
                let deq = q.dequantize(&q.quantize(&x, &mut rng)).unwrap();
                e += vecf::dist2_sq(&deq, &x);
            }
            errs.push(e);
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }

    #[test]
    fn empirical_error_within_bucketed_bound() {
        let mut rng = Prng::new(8);
        let d = 8192;
        let x: Vec<f32> = (0..d).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let xn = vecf::norm2(&x).powi(2);
        for bits in [4u32, 8] {
            let q = Qsgd::new(bits).unwrap();
            let mut err = 0.0;
            let reps = 20;
            for _ in 0..reps {
                let deq = q.dequantize(&q.quantize(&x, &mut rng)).unwrap();
                err += vecf::dist2_sq(&deq, &x);
            }
            let bound = (1.0 - q.delta(d)) * xn;
            assert!(err / reps as f64 <= bound * 1.1, "{bits}-bit: {err} vs {bound}");
        }
    }

    #[test]
    fn matches_pallas_kernel_math() {
        // identical stochastic-rounding formula as the L1 kernel: replay
        // the PRNG stream and verify each level (single bucket).
        let q = Qsgd::with_bucket(4, 8).unwrap();
        let x = vec![0.5f32, -1.5, 2.0, 0.0, -0.25];
        let norm = vecf::norm2(&x) as f32;
        let mut rng_a = Prng::new(99);
        let msg = q.quantize(&x, &mut rng_a);
        let (norms, levels) = q.decode_levels(&msg).unwrap();
        assert_eq!(norms.len(), 1);
        let mut rng_b = Prng::new(99);
        let _ = rng_b; // norms are written before levels; same draw order
        let mut rng_b = Prng::new(99);
        let s = q.levels() as f32;
        for (i, &v) in x.iter().enumerate() {
            let a = v.abs() * s / norm;
            let lv = (a + rng_b.f32()).floor() as i32;
            let expect = if v < 0.0 { -lv } else { lv };
            assert_eq!(levels[i], expect, "coord {i}");
        }
    }

    #[test]
    fn bits_out_of_range_rejected() {
        assert!(Qsgd::new(1).is_err());
        assert!(Qsgd::new(17).is_err());
        assert!(Qsgd::with_bucket(4, 0).is_err());
        assert!(Qsgd::new(2).is_ok());
    }
}
