//! n-bit **bucketed** qsgd quantizer (Example B.1; Alistarh et al. 2017).
//!
//! `qsgd_s(x)` transmits `||x||`, `sign(x)` and stochastically rounded
//! integer levels `xi(x, s)`. Following the original QSGD design (and
//! explaining the paper's per-message overhead beyond d*n/8 bytes), the
//! vector is quantized in **buckets** of `g` coordinates with one f32
//! norm per bucket: the variance constant is then
//! `min(2g/s^2, sqrt(2g)/s)` instead of the dimension-dependent
//! `sqrt(2d)/s` — at g = 128 and 4 bits that is 2.3 rather than 35 for
//! the paper's d = 29,474, which is what makes coarse quantizers usable
//! at realistic model sizes.
//!
//! An *n-bit* qsgd spends n bits per coordinate: 1 sign bit + (n-1)
//! magnitude bits, so s = 2^(n-1) - 1 levels (4-bit => s = 7,
//! 8-bit => s = 127, 2-bit => s = 1, i.e. ternary). Payload:
//!
//! ```text
//!   [ norm_0 .. norm_{B-1} : f32 each ] [ coord_0 : n bits ] ...
//! ```
//!
//! densely bit-packed; total = 4*ceil(d/g) + ceil(d*n/8) bytes. For the
//! paper's model at 4 bits: 15.66 kB vs the paper's reported 15.38 kB.
//!
//! Stochastic rounding `xi_i = floor(|x_i| s / ||bucket|| + u_i)` is the
//! same math as the L1 Pallas kernel (`python/compile/kernels/qsgd.py`);
//! `encode_levels` lets the PJRT path feed kernel-produced levels into
//! this codec.
//!
//! **Sharding:** the bucket structure makes this codec a [`RangeCodec`]:
//! any bucket-aligned contiguous range of coordinates can be encoded or
//! decoded independently (per-bucket norms are range-local, and the
//! bit-packed body is byte-aligned at every bucket-aligned seam whose
//! `offset * bits` is a whole number of bytes — see
//! [`RangeCodec::alignment`]). The full-vector [`Quantizer`] entry
//! points are thin wrappers over the range primitives, so the sharded
//! and sequential paths share one implementation and are bit-identical.

use super::{EncodeNoise, QuantizedMsg, Quantizer, RangeCodec};
use crate::util::bitio::{BitReader, BitWriter};
use crate::util::prng::Prng;
use anyhow::{bail, Result};

/// Default bucket size (QSGD paper's recommendation).
pub const DEFAULT_BUCKET: usize = 128;

/// n-bit bucketed qsgd.
#[derive(Clone, Copy, Debug)]
pub struct Qsgd {
    bits: u32,
    /// Number of levels s = 2^(bits-1) - 1.
    s: u32,
    /// Bucket size g.
    bucket: usize,
}

impl Qsgd {
    pub fn new(bits: u32) -> Result<Self> {
        Self::with_bucket(bits, DEFAULT_BUCKET)
    }

    pub fn with_bucket(bits: u32, bucket: usize) -> Result<Self> {
        if !(2..=16).contains(&bits) {
            bail!("qsgd bits must be in 2..=16 (got {bits})");
        }
        if bucket == 0 {
            bail!("qsgd bucket must be >= 1");
        }
        Ok(Qsgd { bits, s: (1u32 << (bits - 1)) - 1, bucket })
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Levels s (quantization granularity).
    pub fn levels(&self) -> u32 {
        self.s
    }

    pub fn bucket(&self) -> usize {
        self.bucket
    }

    fn n_buckets(&self, d: usize) -> usize {
        d.div_ceil(self.bucket)
    }

    /// Pack precomputed signed levels + per-bucket norms into the wire
    /// format (levels from the Pallas kernel artifact take this path).
    pub fn encode_levels(&self, levels: &[i32], norms: &[f32]) -> QuantizedMsg {
        let d = levels.len();
        assert_eq!(norms.len(), self.n_buckets(d), "norms/bucket mismatch");
        let mut w = BitWriter::with_capacity(norms.len() * 32 + d * self.bits as usize);
        for &n in norms {
            w.write_f32(n);
        }
        for &lv in levels {
            debug_assert!(lv.unsigned_abs() <= self.s, "level {lv} > s={}", self.s);
            let sign = (lv < 0) as u64;
            let mag = lv.unsigned_abs().min(self.s) as u64;
            w.write(sign | (mag << 1), self.bits);
        }
        QuantizedMsg { payload: w.into_bytes(), d }
    }

    /// Decode payload into (per-bucket norms, signed levels).
    pub fn decode_levels(&self, msg: &QuantizedMsg) -> Result<(Vec<f32>, Vec<i32>)> {
        let nb = self.n_buckets(msg.d);
        let mut r = BitReader::new(&msg.payload);
        let mut norms = Vec::with_capacity(nb);
        for _ in 0..nb {
            match r.read_f32() {
                Some(n) => norms.push(n),
                None => bail!("qsgd: truncated payload (norms)"),
            }
        }
        let mut levels = Vec::with_capacity(msg.d);
        for i in 0..msg.d {
            let raw = match r.read(self.bits) {
                Some(v) => v,
                None => bail!("qsgd: truncated payload at coord {i}"),
            };
            let sign = raw & 1;
            let mag = (raw >> 1) as i32;
            levels.push(if sign == 1 { -mag } else { mag });
        }
        Ok((norms, levels))
    }

    /// Validate a payload/range pair for the range decode paths.
    fn check_range(&self, msg: &QuantizedMsg, len: usize, offset: usize) -> Result<()> {
        if offset % self.bucket != 0 || (offset * self.bits as usize) % 8 != 0 {
            bail!(
                "qsgd: shard offset {offset} not aligned (bucket {}, {} bits)",
                self.bucket,
                self.bits
            );
        }
        if offset + len > msg.d {
            bail!("qsgd: range {offset}..{} exceeds d={}", offset + len, msg.d);
        }
        if msg.payload.len() != self.expected_bytes(msg.d) {
            bail!(
                "qsgd: payload size mismatch (got {} bytes, want {} for d={})",
                msg.payload.len(),
                self.expected_bytes(msg.d),
                msg.d
            );
        }
        Ok(())
    }

    /// Per-bucket `scale * norm` factors for the local buckets of a
    /// range (`offset` bucket-aligned, `len` coordinates).
    fn range_units(&self, msg: &QuantizedMsg, scale: f32, len: usize, offset: usize) -> Vec<f32> {
        let first_bucket = offset / self.bucket;
        let local_nb = len.div_ceil(self.bucket);
        let mut units = Vec::with_capacity(local_nb);
        for b in 0..local_nb {
            let off = 4 * (first_bucket + b);
            let norm = f32::from_le_bytes(msg.payload[off..off + 4].try_into().unwrap());
            units.push(scale * norm / self.s as f32);
        }
        units
    }

    /// Shared decode-and-apply over a range. `APPLY_ADD` selects
    /// accumulate (`acc += unit * level`) vs overwrite (`out = ...`).
    ///
    /// §Perf: byte-aligned fast paths — the generic BitReader loop
    /// costs ~350 us at d = 29,474; these run in ~30 us (see
    /// EXPERIMENTS.md §Perf L3 iteration log).
    fn apply_range<const APPLY_ADD: bool>(
        &self,
        msg: &QuantizedMsg,
        scale: f32,
        dst: &mut [f32],
        offset: usize,
    ) -> Result<()> {
        self.check_range(msg, dst.len(), offset)?;
        let units = self.range_units(msg, scale, dst.len(), offset);
        let nb = self.n_buckets(msg.d);
        let body = &msg.payload[4 * nb..];
        let g = self.bucket;
        macro_rules! emit {
            ($a:expr, $signed:expr, $unit:expr) => {
                if APPLY_ADD {
                    *$a += $unit * $signed;
                } else {
                    *$a = $unit * $signed;
                }
            };
        }
        match self.bits {
            8 => {
                // chunk by bucket: hoists the unit lookup out of the
                // inner loop and keeps it branch-free
                for (b, chunk) in dst.chunks_mut(g).enumerate() {
                    let unit = units[b];
                    let base = offset + b * g;
                    for (j, a) in chunk.iter_mut().enumerate() {
                        let raw = body[base + j];
                        let mag = (raw >> 1) as f32;
                        let signed = if raw & 1 == 1 { -mag } else { mag };
                        emit!(a, signed, unit);
                    }
                }
            }
            4 => {
                for (b, chunk) in dst.chunks_mut(g).enumerate() {
                    let unit = units[b];
                    let base = offset + b * g;
                    for (j, a) in chunk.iter_mut().enumerate() {
                        let i = base + j;
                        let byte = body[i >> 1];
                        let raw = (byte >> ((i & 1) * 4)) & 0xF;
                        let mag = (raw >> 1) as f32;
                        let signed = if raw & 1 == 1 { -mag } else { mag };
                        emit!(a, signed, unit);
                    }
                }
            }
            2 => {
                for (b, chunk) in dst.chunks_mut(g).enumerate() {
                    let unit = units[b];
                    let base = offset + b * g;
                    for (j, a) in chunk.iter_mut().enumerate() {
                        let i = base + j;
                        let byte = body[i >> 2];
                        let raw = (byte >> ((i & 3) * 2)) & 0b11;
                        let mag = (raw >> 1) as f32;
                        let signed = if raw & 1 == 1 { -mag } else { mag };
                        emit!(a, signed, unit);
                    }
                }
            }
            _ => {
                let mut r = BitReader::new(&body[offset * self.bits as usize / 8..]);
                for (j, a) in dst.iter_mut().enumerate() {
                    let raw = match r.read(self.bits) {
                        Some(v) => v,
                        None => bail!("qsgd: truncated payload at coord {}", offset + j),
                    };
                    let mag = (raw >> 1) as f32;
                    let signed = if raw & 1 == 1 { -mag } else { mag };
                    let unit = units[j / g];
                    emit!(a, signed, unit);
                }
            }
        }
        Ok(())
    }
}

impl RangeCodec for Qsgd {
    fn alignment(&self) -> usize {
        // Smallest multiple of the bucket whose bit-packed body is a
        // whole number of bytes (k <= 8 always terminates).
        let mut k = 1usize;
        while (k * self.bucket * self.bits as usize) % 8 != 0 {
            k += 1;
        }
        k * self.bucket
    }

    fn noise_dims(&self, d: usize) -> (usize, usize) {
        (0, d)
    }

    fn encode_range(
        &self,
        x: &[f32],
        offset: usize,
        d: usize,
        noise: &EncodeNoise,
    ) -> (Vec<u8>, Vec<u8>) {
        let noise = &noise.uniforms[..];
        let g = self.bucket;
        assert_eq!(offset % g, 0, "qsgd shard must start on a bucket boundary");
        assert_eq!((offset * self.bits as usize) % 8, 0, "qsgd shard body must be byte-aligned");
        assert!(offset + x.len() <= d && noise.len() == d, "qsgd range out of bounds");
        let nb = x.len().div_ceil(g);
        // per-bucket norms (header) — identical math to the sequential
        // encoder: norm in f64, scale = s / norm computed once per bucket
        let mut header = Vec::with_capacity(nb * 4);
        let mut scales = Vec::with_capacity(nb);
        for b in 0..nb {
            let lo = b * g;
            let hi = (lo + g).min(x.len());
            let norm = crate::util::vecf::norm2(&x[lo..hi]) as f32;
            header.extend_from_slice(&norm.to_le_bytes());
            scales.push(if norm > 0.0 { self.s as f32 / norm } else { 0.0 });
        }
        let mut w = BitWriter::with_capacity(x.len() * self.bits as usize);
        for (j, &v) in x.iter().enumerate() {
            let a = v.abs() * scales[j / g];
            // floor(a + u): ceil with prob frac(a), floor otherwise
            let level = ((a + noise[offset + j]).floor() as u64).min(self.s as u64);
            let sign = (v < 0.0) as u64;
            w.write(sign | (level << 1), self.bits);
        }
        (header, w.into_bytes())
    }

    fn accumulate_range(
        &self,
        msg: &QuantizedMsg,
        weight: f32,
        acc: &mut [f32],
        offset: usize,
    ) -> Result<()> {
        self.apply_range::<true>(msg, weight, acc, offset)
    }

    fn dequantize_range(&self, msg: &QuantizedMsg, out: &mut [f32], offset: usize) -> Result<()> {
        self.apply_range::<false>(msg, 1.0, out, offset)
    }
}

impl Quantizer for Qsgd {
    fn name(&self) -> String {
        if self.bucket == DEFAULT_BUCKET {
            format!("qsgd:{}", self.bits)
        } else {
            format!("qsgd:{}:{}", self.bits, self.bucket)
        }
    }

    fn quantize(&self, x: &[f32], rng: &mut Prng) -> QuantizedMsg {
        // Sequential encoder: draws one uniform per coordinate inline, in
        // coordinate order — no noise-vector allocation on the client /
        // S=1 hot path. The draw order and arithmetic are the wire
        // contract shared with `encode_range` (which takes the same
        // draws pre-materialized); the range-stitch property tests pin
        // the two paths to byte equality.
        let d = x.len();
        let nb = self.n_buckets(d);
        let mut w = BitWriter::with_capacity(nb * 32 + d * self.bits as usize);
        let mut scales = Vec::with_capacity(nb);
        for b in 0..nb {
            let lo = b * self.bucket;
            let hi = (lo + self.bucket).min(d);
            let norm = crate::util::vecf::norm2(&x[lo..hi]) as f32;
            w.write_f32(norm);
            scales.push(if norm > 0.0 { self.s as f32 / norm } else { 0.0 });
        }
        for (i, &v) in x.iter().enumerate() {
            let a = v.abs() * scales[i / self.bucket];
            // floor(a + u): ceil with prob frac(a), floor otherwise
            let level = ((a + rng.f32()).floor() as u64).min(self.s as u64);
            let sign = (v < 0.0) as u64;
            w.write(sign | (level << 1), self.bits);
        }
        QuantizedMsg { payload: w.into_bytes(), d }
    }

    fn dequantize_into(&self, msg: &QuantizedMsg, out: &mut [f32]) -> Result<()> {
        if msg.d != out.len() {
            bail!("qsgd: dimension mismatch (msg {}, out {})", msg.d, out.len());
        }
        self.dequantize_range(msg, out, 0)
    }

    fn accumulate(&self, msg: &QuantizedMsg, weight: f32, acc: &mut [f32]) -> Result<()> {
        if msg.d != acc.len() {
            bail!("qsgd: dimension mismatch");
        }
        self.accumulate_range(msg, weight, acc, 0)
    }

    fn is_unbiased(&self) -> bool {
        true
    }

    fn expected_bytes(&self, d: usize) -> usize {
        4 * self.n_buckets(d) + (d * self.bits as usize).div_ceil(8)
    }

    /// Lemma 3.1 (Alistarh et al. 2017) applied per bucket of size g:
    /// E||Q(x)-x||^2 <= min(2g/s^2, sqrt(2g)/s) ||x||^2.
    fn delta(&self, d: usize) -> f64 {
        let s = self.s as f64;
        let g = self.bucket.min(d) as f64;
        1.0 - (2.0 * g / (s * s)).min((2.0 * g).sqrt() / s)
    }

    fn range_codec(&self) -> Option<&dyn RangeCodec> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::vecf;

    #[test]
    fn wire_sizes_match_paper_shape() {
        #[allow(clippy::unnecessary_cast)]
        // paper reports 29.924 / 15.380 / 8.108 kB for 8/4/2-bit at
        // d = 29,282; our bucketed codec at d = 29,474:
        let d = 29_474;
        let nb = (d as usize).div_ceil(128);
        assert_eq!(Qsgd::new(8).unwrap().expected_bytes(d), 4 * nb + d);
        let kb4 = Qsgd::new(4).unwrap().expected_bytes(d) as f64 / 1000.0;
        assert!((kb4 - 15.38).abs() < 0.5, "4-bit size {kb4} kB vs paper 15.38");
        let kb2 = Qsgd::new(2).unwrap().expected_bytes(d) as f64 / 1000.0;
        assert!((kb2 - 8.108).abs() < 0.5, "2-bit size {kb2} kB vs paper 8.108");
    }

    #[test]
    fn bucketing_improves_contraction() {
        let d = 29_474;
        let whole = Qsgd::with_bucket(4, d).unwrap();
        let bucketed = Qsgd::new(4).unwrap();
        assert!(bucketed.delta(d) > whole.delta(d));
        // 8-bit bucketed is a true contraction (delta > 0)
        assert!(Qsgd::new(8).unwrap().delta(d) > 0.0);
    }

    #[test]
    fn levels_bounded_by_s() {
        let mut rng = Prng::new(3);
        for bits in [2u32, 4, 8] {
            let q = Qsgd::new(bits).unwrap();
            let x: Vec<f32> = (0..2000).map(|_| rng.f32() * 10.0 - 5.0).collect();
            let msg = q.quantize(&x, &mut rng);
            let (_, levels) = q.decode_levels(&msg).unwrap();
            assert!(levels.iter().all(|l| l.unsigned_abs() <= q.levels()));
        }
    }

    #[test]
    fn encode_decode_levels_roundtrip() {
        let q = Qsgd::with_bucket(4, 4).unwrap();
        let levels: Vec<i32> = vec![0, 1, -1, 7, -7, 3, -2, 0, 5];
        let norms = vec![12.5f32, 3.25, 0.5];
        let msg = q.encode_levels(&levels, &norms);
        let (n2, back) = q.decode_levels(&msg).unwrap();
        assert_eq!(norms, n2);
        assert_eq!(levels, back);
    }

    #[test]
    fn dequantize_matches_formula_per_bucket() {
        let mut rng = Prng::new(4);
        let q = Qsgd::with_bucket(4, 64).unwrap();
        let x: Vec<f32> = (0..257).map(|i| (i as f32 - 128.0) / 37.0).collect();
        let msg = q.quantize(&x, &mut rng);
        let (norms, levels) = q.decode_levels(&msg).unwrap();
        let deq = q.dequantize(&msg).unwrap();
        for i in 0..x.len() {
            let expect = norms[i / 64] / q.levels() as f32 * levels[i] as f32;
            assert!((deq[i] - expect).abs() < 1e-6);
        }
        // per-bucket norms are the actual bucket l2 norms
        for (b, n) in norms.iter().enumerate() {
            let lo = b * 64;
            let hi = (lo + 64).min(x.len());
            assert!((n - vecf::norm2(&x[lo..hi]) as f32).abs() < 1e-4);
        }
    }

    #[test]
    fn preserves_signs_of_large_coords() {
        let mut rng = Prng::new(5);
        let q = Qsgd::new(8).unwrap();
        let x = vec![10.0, -10.0, 10.0, -10.0];
        let deq = q.dequantize(&q.quantize(&x, &mut rng)).unwrap();
        for (a, b) in x.iter().zip(&deq) {
            assert!(a * b > 0.0, "{a} vs {b}");
            assert!((a - b).abs() / a.abs() < 0.02);
        }
    }

    #[test]
    fn zero_vector_is_fixed_point() {
        let mut rng = Prng::new(6);
        let q = Qsgd::new(4).unwrap();
        let x = vec![0.0f32; 300];
        let deq = q.dequantize(&q.quantize(&x, &mut rng)).unwrap();
        assert_eq!(deq, x);
    }

    #[test]
    fn error_decreases_with_bits() {
        let mut rng = Prng::new(7);
        let x: Vec<f32> = (0..4096).map(|_| rng.f32() - 0.5).collect();
        let mut errs = Vec::new();
        for bits in [2u32, 4, 8] {
            let q = Qsgd::new(bits).unwrap();
            let mut e = 0.0;
            for _ in 0..10 {
                let deq = q.dequantize(&q.quantize(&x, &mut rng)).unwrap();
                e += vecf::dist2_sq(&deq, &x);
            }
            errs.push(e);
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }

    #[test]
    fn empirical_error_within_bucketed_bound() {
        let mut rng = Prng::new(8);
        let d = 8192;
        let x: Vec<f32> = (0..d).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let xn = vecf::norm2(&x).powi(2);
        for bits in [4u32, 8] {
            let q = Qsgd::new(bits).unwrap();
            let mut err = 0.0;
            let reps = 20;
            for _ in 0..reps {
                let deq = q.dequantize(&q.quantize(&x, &mut rng)).unwrap();
                err += vecf::dist2_sq(&deq, &x);
            }
            let bound = (1.0 - q.delta(d)) * xn;
            assert!(err / reps as f64 <= bound * 1.1, "{bits}-bit: {err} vs {bound}");
        }
    }

    #[test]
    fn matches_pallas_kernel_math() {
        // identical stochastic-rounding formula as the L1 kernel: replay
        // the PRNG stream and verify each level (single bucket).
        let q = Qsgd::with_bucket(4, 8).unwrap();
        let x = vec![0.5f32, -1.5, 2.0, 0.0, -0.25];
        let norm = vecf::norm2(&x) as f32;
        let mut rng_a = Prng::new(99);
        let msg = q.quantize(&x, &mut rng_a);
        let (norms, levels) = q.decode_levels(&msg).unwrap();
        assert_eq!(norms.len(), 1);
        // norms are written before levels; same draw order
        let mut rng_b = Prng::new(99);
        let s = q.levels() as f32;
        for (i, &v) in x.iter().enumerate() {
            let a = v.abs() * s / norm;
            let lv = (a + rng_b.f32()).floor() as i32;
            let expect = if v < 0.0 { -lv } else { lv };
            assert_eq!(levels[i], expect, "coord {i}");
        }
    }

    #[test]
    fn bits_out_of_range_rejected() {
        assert!(Qsgd::new(1).is_err());
        assert!(Qsgd::new(17).is_err());
        assert!(Qsgd::with_bucket(4, 0).is_err());
        assert!(Qsgd::new(2).is_ok());
    }

    #[test]
    fn sixteen_bit_symbols_roundtrip() {
        // 16-bit qsgd: s = 32767 levels, symbols span exactly 2 bytes —
        // exercises the generic BitReader/Writer path at its widest
        // symbol and the range decode at a byte-aligned offset.
        let mut rng = Prng::new(21);
        let q = Qsgd::new(16).unwrap();
        assert_eq!(q.levels(), 32_767);
        let d = 300;
        let x: Vec<f32> = (0..d).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let msg = q.quantize(&x, &mut rng);
        assert_eq!(msg.wire_bytes(), q.expected_bytes(d));
        let deq = q.dequantize(&msg).unwrap();
        // 16-bit is near-lossless on unit-scale data
        let rel = vecf::dist2_sq(&deq, &x) / vecf::norm2(&x).powi(2);
        assert!(rel < 1e-6, "relative err {rel}");
        let (_, levels) = q.decode_levels(&msg).unwrap();
        assert!(levels.iter().all(|l| l.unsigned_abs() <= q.levels()));
        // ranged decode agrees with the full decode
        let mut tail = vec![0.0f32; d - 128];
        q.dequantize_range(&msg, &mut tail, 128).unwrap();
        assert_eq!(&deq[128..], &tail[..]);
    }

    #[test]
    fn truncated_payloads_error_loudly() {
        let mut rng = Prng::new(22);
        for bits in [2u32, 4, 8, 13, 16] {
            let q = Qsgd::new(bits).unwrap();
            let x: Vec<f32> = (0..200).map(|_| rng.f32() - 0.5).collect();
            let mut msg = q.quantize(&x, &mut rng);
            msg.payload.truncate(msg.payload.len() - 1);
            let mut out = vec![0.0f32; 200];
            assert!(q.dequantize_into(&msg, &mut out).is_err(), "{bits}-bit dequantize");
            assert!(q.accumulate(&msg, 1.0, &mut out).is_err(), "{bits}-bit accumulate");
            assert!(q.decode_levels(&msg).is_err(), "{bits}-bit decode_levels");
            // oversized payloads are rejected too
            msg.payload.extend_from_slice(&[0, 0]);
            assert!(q.dequantize_into(&msg, &mut out).is_err(), "{bits}-bit oversized");
        }
    }

    #[test]
    fn range_encode_stitches_to_full_payload() {
        // concat(headers) ++ concat(bodies) over aligned ranges must be
        // byte-identical to the sequential quantize for every bits
        // setting, including ragged tails.
        let mut rng = Prng::new(23);
        for bits in [2u32, 3, 4, 8, 12, 16] {
            let q = Qsgd::new(bits).unwrap();
            let d = 5 * 128 + 77; // ragged tail
            let x: Vec<f32> = (0..d).map(|_| rng.f32() * 4.0 - 2.0).collect();
            let mut noise_rng = Prng::new(1000 + bits as u64);
            let full = {
                let mut r = noise_rng.clone();
                q.quantize(&x, &mut r)
            };
            let mut noise = EncodeNoise { seeds: Vec::new(), uniforms: vec![0.0f32; d] };
            for v in &mut noise.uniforms {
                *v = noise_rng.f32();
            }
            let align = q.alignment();
            assert_eq!(align % q.bucket(), 0);
            let span = 2 * align; // 2 ranges of 2 buckets + tail
            let mut headers = Vec::new();
            let mut bodies = Vec::new();
            for (i, chunk) in x.chunks(span).enumerate() {
                let (h, b) = q.encode_range(chunk, i * span, d, &noise);
                headers.extend_from_slice(&h);
                bodies.extend_from_slice(&b);
            }
            headers.extend_from_slice(&bodies);
            assert_eq!(headers, full.payload, "{bits}-bit stitch mismatch");
        }
    }

    #[test]
    fn range_accumulate_matches_full_accumulate() {
        let mut rng = Prng::new(24);
        for bits in [2u32, 4, 8, 11] {
            let q = Qsgd::new(bits).unwrap();
            let d = 4 * 128 + 19;
            let x: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
            let msg = q.quantize(&x, &mut rng);
            let mut full = vec![0.5f32; d];
            q.accumulate(&msg, 0.25, &mut full).unwrap();
            let mut ranged = vec![0.5f32; d];
            let span = q.alignment();
            for (i, chunk) in ranged.chunks_mut(span).enumerate() {
                q.accumulate_range(&msg, 0.25, chunk, i * span).unwrap();
            }
            assert_eq!(full, ranged, "{bits}-bit ranged accumulate");
        }
    }
}
