//! rand_k quantizer (Example B.1): transmit k coordinates chosen at
//! random.
//!
//! Two variants:
//! * **unscaled** (the paper's Example B.1): `Q(x)_i = x_i` on the sampled
//!   set, 0 elsewhere. Biased contraction with delta = k/d (Lemma A.1 of
//!   Stich et al. 2018).
//! * **scaled**: multiplies each kept coordinate by the inverse of its
//!   inclusion probability, making `E[Q(x)] = x` exactly on every bucket
//!   that receives samples, at the price of variance
//!   ~`(d/k - 1)||x||^2`.
//!
//! **Stratified per-bucket index streams.** The index set is derived
//! from an 8-byte seed included in the message — indices are never
//! transmitted. Coordinates are partitioned into fixed buckets of
//! [`BUCKET`] and the message budget k is split across buckets with a
//! Bresenham prefix rule (`k_pre(c) = floor(k·c/d)` at every bucket
//! boundary `c`), so bucket `b` samples exactly
//! `k_pre(end) - k_pre(start)` of its coordinates from its own
//! decorrelated sub-stream `Prng::new(seed).stream_u64(b)`. Values ride
//! the wire in (bucket, ascending index) order = global ascending index
//! order. Wire: `[ seed : u64 ][ k values : f32 ]`.
//!
//! This is what makes rand_k a [`RangeCodec`]: any bucket-aligned range
//! regenerates its own indices and locates its values at
//! `8 + 4·k_pre(range start)` without touching the rest of the message
//! — encode, accumulate and dequantize all shard, and the full-message
//! [`Quantizer::accumulate`] is a direct sparse scatter (O(k), no O(d)
//! temporary). Within a bucket, inclusion probability is exactly
//! `k_b / g_b` per coordinate (uniform sampling without replacement),
//! which the Bresenham split keeps within 1/g_b of k/d globally.

use super::{EncodeNoise, QuantizedMsg, Quantizer, RangeCodec};
use crate::util::prng::Prng;
use anyhow::{bail, Result};

/// Fixed stratification bucket (coordinates per index sub-stream).
pub const BUCKET: usize = 128;

/// Keep a random `frac` fraction of coordinates.
#[derive(Clone, Copy, Debug)]
pub struct RandK {
    frac: f64,
    scaled: bool,
}

impl RandK {
    pub fn new(frac: f64, scaled: bool) -> Result<Self> {
        if !(frac > 0.0 && frac <= 1.0) {
            bail!("rand_k fraction must be in (0, 1] (got {frac})");
        }
        Ok(RandK { frac, scaled })
    }

    pub fn k_for(&self, d: usize) -> usize {
        ((self.frac * d as f64).ceil() as usize).clamp(1, d)
    }

    /// Bresenham prefix: how many of the k samples land strictly before
    /// global coordinate `c` (exact at bucket boundaries; monotone, ends
    /// at k for c = d).
    fn k_prefix(k: usize, d: usize, c: usize) -> usize {
        ((k as u128 * c as u128) / d as u128) as usize
    }

    /// Sorted in-bucket indices for bucket `b` of size `g_b` holding
    /// `k_b` samples (sub-stream of the message seed): partial
    /// Fisher–Yates over a caller-provided stack buffer — the decode hot
    /// path runs one bucket per 128 coordinates, so this must not heap
    /// allocate. Returns the sorted prefix `&scratch[..k_b]`.
    fn bucket_indices<'a>(
        seed: u64,
        b: usize,
        g_b: usize,
        k_b: usize,
        scratch: &'a mut [u32; BUCKET],
    ) -> &'a [u32] {
        debug_assert!(k_b <= g_b && g_b <= BUCKET);
        for (i, v) in scratch[..g_b].iter_mut().enumerate() {
            *v = i as u32;
        }
        let mut rng = Prng::new(seed).stream_u64(b as u64);
        for i in 0..k_b {
            let j = i + rng.below((g_b - i) as u64) as usize;
            scratch.swap(i, j);
        }
        scratch[..k_b].sort_unstable();
        &scratch[..k_b]
    }

    /// Visit the sampled coordinates of `[offset, offset + len)` as
    /// `(local index, payload value offset in bytes, gain)`. `offset`
    /// must be bucket-aligned; the range may end ragged.
    fn for_range_samples(
        &self,
        seed: u64,
        d: usize,
        offset: usize,
        len: usize,
        mut visit: impl FnMut(usize, usize, f32),
    ) {
        let k = self.k_for(d);
        debug_assert_eq!(offset % BUCKET, 0);
        let mut scratch = [0u32; BUCKET];
        let mut lo = 0usize;
        while lo < len {
            let c = offset + lo; // global bucket start (multiple of BUCKET)
            let g_b = BUCKET.min(d - c);
            let k_pre = Self::k_prefix(k, d, c);
            let k_b = Self::k_prefix(k, d, c + g_b) - k_pre;
            if k_b > 0 {
                let gain = if self.scaled { g_b as f32 / k_b as f32 } else { 1.0 };
                for (j, &i) in
                    Self::bucket_indices(seed, c / BUCKET, g_b, k_b, &mut scratch)
                        .iter()
                        .enumerate()
                {
                    let i = i as usize;
                    if lo + i >= len {
                        break; // ragged range end mid-bucket (indices sorted)
                    }
                    visit(lo + i, 8 + 4 * (k_pre + j), gain);
                }
            }
            lo += g_b;
        }
    }

    /// Shared validation for the decode paths.
    fn check(&self, msg: &QuantizedMsg, offset: usize, len: usize) -> Result<u64> {
        if msg.payload.len() != self.expected_bytes(msg.d) {
            bail!(
                "rand_k: payload size mismatch (got {} bytes, want {} for d={})",
                msg.payload.len(),
                self.expected_bytes(msg.d),
                msg.d
            );
        }
        if offset % BUCKET != 0 {
            bail!("rand_k: shard offset {offset} not aligned (bucket {BUCKET})");
        }
        if offset + len > msg.d {
            bail!("rand_k: range {offset}..{} exceeds d={}", offset + len, msg.d);
        }
        Ok(u64::from_le_bytes(msg.payload[..8].try_into().unwrap()))
    }
}

impl RangeCodec for RandK {
    fn alignment(&self) -> usize {
        BUCKET // shard seams on bucket boundaries; values are whole bytes
    }

    fn noise_dims(&self, _d: usize) -> (usize, usize) {
        (1, 0) // one u64: the index seed
    }

    fn encode_range(
        &self,
        x: &[f32],
        offset: usize,
        d: usize,
        noise: &EncodeNoise,
    ) -> (Vec<u8>, Vec<u8>) {
        assert_eq!(offset % BUCKET, 0, "rand_k shard must start on a bucket boundary");
        assert!(offset + x.len() <= d, "rand_k range out of bounds");
        let seed = noise.seeds[0];
        // the 8-byte seed header belongs to the first range only
        let header = if offset == 0 { seed.to_le_bytes().to_vec() } else { Vec::new() };
        let mut body = Vec::new();
        self.for_range_samples(seed, d, offset, x.len(), |i, _, gain| {
            body.extend_from_slice(&(x[i] * gain).to_le_bytes());
        });
        (header, body)
    }

    fn accumulate_range(
        &self,
        msg: &QuantizedMsg,
        weight: f32,
        acc: &mut [f32],
        offset: usize,
    ) -> Result<()> {
        let seed = self.check(msg, offset, acc.len())?;
        self.for_range_samples(seed, msg.d, offset, acc.len(), |i, off, _| {
            let v = f32::from_le_bytes(msg.payload[off..off + 4].try_into().unwrap());
            acc[i] += weight * v;
        });
        Ok(())
    }

    fn dequantize_range(&self, msg: &QuantizedMsg, out: &mut [f32], offset: usize) -> Result<()> {
        let seed = self.check(msg, offset, out.len())?;
        out.fill(0.0);
        self.for_range_samples(seed, msg.d, offset, out.len(), |i, off, _| {
            out[i] = f32::from_le_bytes(msg.payload[off..off + 4].try_into().unwrap());
        });
        Ok(())
    }
}

impl Quantizer for RandK {
    fn name(&self) -> String {
        format!("{}:{}", if self.scaled { "rand_scaled" } else { "rand" }, self.frac)
    }

    fn quantize(&self, x: &[f32], rng: &mut Prng) -> QuantizedMsg {
        // one code path with the sharded encoder: the whole vector is a
        // single range; the seed is the only randomness consumed
        let d = x.len();
        let noise = EncodeNoise { seeds: vec![rng.next_u64()], uniforms: Vec::new() };
        let (mut payload, body) = self.encode_range(x, 0, d, &noise);
        payload.extend_from_slice(&body);
        QuantizedMsg { payload, d }
    }

    fn dequantize_into(&self, msg: &QuantizedMsg, out: &mut [f32]) -> Result<()> {
        if msg.d != out.len() {
            bail!("rand_k: dimension mismatch (msg {}, out {})", msg.d, out.len());
        }
        self.dequantize_range(msg, out, 0)
    }

    /// Direct sparse accumulate: regenerates the k indices and scatters,
    /// instead of dequantizing into an O(d) temporary.
    fn accumulate(&self, msg: &QuantizedMsg, weight: f32, acc: &mut [f32]) -> Result<()> {
        if msg.d != acc.len() {
            bail!("rand_k: dimension mismatch (msg {}, acc {})", msg.d, acc.len());
        }
        self.accumulate_range(msg, weight, acc, 0)
    }

    fn is_unbiased(&self) -> bool {
        self.scaled
    }

    fn expected_bytes(&self, d: usize) -> usize {
        8 + 4 * self.k_for(d)
    }

    /// Unscaled: delta = k/d (contraction). Scaled: unbiased with
    /// E||Q(x)-x||^2 ~= (d/k - 1)||x||^2, i.e. delta = 1 - (d/k - 1)
    /// (can be <= 0 when k < d/2 — Definition 2.1's constant exceeds 1;
    /// stratification only tightens the per-bucket constants).
    fn delta(&self, d: usize) -> f64 {
        let k = self.k_for(d) as f64;
        let d = d as f64;
        if self.scaled {
            1.0 - (d / k - 1.0)
        } else {
            k / d
        }
    }

    fn range_codec(&self) -> Option<&dyn RangeCodec> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn receiver_recovers_same_indices() {
        let mut rng = Prng::new(1);
        let x: Vec<f32> = (0..500).map(|i| i as f32).collect();
        let q = RandK::new(0.2, false).unwrap();
        let msg = q.quantize(&x, &mut rng);
        let y = q.dequantize(&msg).unwrap();
        let kept: Vec<usize> = (0..500).filter(|&i| y[i] != 0.0).collect();
        // +1 for possible x[0]=0 kept; k=100 sampled
        assert!(kept.len() <= 100 && kept.len() >= 99);
        for &i in &kept {
            assert_eq!(y[i], x[i]);
        }
    }

    #[test]
    fn budget_split_is_exact_and_within_bucket_capacity() {
        let q = RandK::new(0.37, false).unwrap();
        for d in [1usize, 5, 127, 128, 129, 500, 1000, 29_474, (1 << 20) + 77] {
            let k = q.k_for(d);
            let mut total = 0usize;
            let mut c = 0usize;
            while c < d {
                let g_b = BUCKET.min(d - c);
                let k_b = RandK::k_prefix(k, d, c + g_b) - RandK::k_prefix(k, d, c);
                assert!(k_b <= g_b, "d={d}: bucket at {c} got {k_b} > {g_b}");
                total += k_b;
                c += g_b;
            }
            assert_eq!(total, k, "d={d}: split does not sum to k");
        }
    }

    #[test]
    fn scaled_variant_is_unbiased() {
        let mut rng = Prng::new(2);
        let d = 256;
        let x: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
        let q = RandK::new(0.25, true).unwrap();
        let reps = 2000;
        let mut acc = vec![0.0f64; d];
        for _ in 0..reps {
            let y = q.dequantize(&q.quantize(&x, &mut rng)).unwrap();
            for i in 0..d {
                acc[i] += y[i] as f64;
            }
        }
        let mut bias2 = 0.0;
        let mut xn2 = 0.0;
        for i in 0..d {
            let m = acc[i] / reps as f64;
            bias2 += (m - x[i] as f64).powi(2);
            xn2 += (x[i] as f64).powi(2);
        }
        // E error per rep is (d/k-1)|x|^2 = 3|x|^2; mean over reps shrinks
        assert!(bias2 < 3.0 * xn2 / reps as f64 * 9.0, "bias2 {bias2}");
    }

    #[test]
    fn unscaled_error_is_dropped_mass_on_average() {
        let mut rng = Prng::new(3);
        let d = 400;
        let x: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
        let q = RandK::new(0.5, false).unwrap();
        let xn2 = crate::util::vecf::norm2(&x).powi(2);
        let reps = 500;
        let mut err = 0.0;
        for _ in 0..reps {
            let y = q.dequantize(&q.quantize(&x, &mut rng)).unwrap();
            err += crate::util::vecf::dist2_sq(&y, &x);
        }
        let mean = err / reps as f64;
        // E err = (1 - k/d)|x|^2 = 0.5 |x|^2 (inclusion is exactly 1/2
        // in every bucket here: k_b = g_b / 2 for g_b in {128, 16})
        assert!((mean - 0.5 * xn2).abs() / xn2 < 0.05, "mean {mean} xn2 {xn2}");
    }

    #[test]
    fn sparse_accumulate_matches_dense_dequantize_axpy() {
        let mut rng = Prng::new(4);
        for (frac, scaled) in [(0.1, false), (0.33, true), (1.0, false)] {
            let d = 3 * BUCKET + 57;
            let x: Vec<f32> = (0..d).map(|_| rng.f32() * 2.0 - 1.0).collect();
            let q = RandK::new(frac, scaled).unwrap();
            let msg = q.quantize(&x, &mut rng);
            for w in [1.0f32, -0.5, 0.125] {
                let mut a = vec![0.75f32; d];
                let mut b = vec![0.75f32; d];
                q.accumulate(&msg, w, &mut a).unwrap();
                let xq = q.dequantize(&msg).unwrap();
                crate::util::vecf::axpy(&mut b, w, &xq);
                assert_eq!(a, b, "frac={frac} scaled={scaled} w={w}");
            }
        }
    }

    #[test]
    fn range_decode_matches_full_decode_on_bucket_aligned_spans() {
        let mut rng = Prng::new(5);
        let d = 5 * BUCKET + 33; // ragged tail
        let x: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
        for scaled in [false, true] {
            let q = RandK::new(0.2, scaled).unwrap();
            let msg = q.quantize(&x, &mut rng);
            let full = q.dequantize(&msg).unwrap();
            for span in [BUCKET, 2 * BUCKET, 4 * BUCKET] {
                let mut out = vec![7.0f32; d];
                let mut acc = vec![0.5f32; d];
                for (i, chunk) in out.chunks_mut(span).enumerate() {
                    q.dequantize_range(&msg, chunk, i * span).unwrap();
                }
                for (i, chunk) in acc.chunks_mut(span).enumerate() {
                    q.accumulate_range(&msg, 3.0, chunk, i * span).unwrap();
                }
                assert_eq!(out, full, "scaled={scaled} span={span}");
                let mut want = vec![0.5f32; d];
                crate::util::vecf::axpy(&mut want, 3.0, &full);
                assert_eq!(acc, want, "scaled={scaled} span={span} accumulate");
            }
            // misaligned offsets are rejected loudly
            let mut chunk = vec![0.0f32; 64];
            assert!(q.dequantize_range(&msg, &mut chunk, 64).is_err());
        }
    }

    #[test]
    fn malformed_payloads_error_loudly() {
        let mut rng = Prng::new(6);
        let d = 300;
        let x: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
        let q = RandK::new(0.1, false).unwrap();
        let good = q.quantize(&x, &mut rng);
        let mut out = vec![0.0f32; d];
        let mut msg = good.clone();
        msg.payload.pop();
        assert!(q.dequantize_into(&msg, &mut out).is_err());
        assert!(q.accumulate(&msg, 1.0, &mut out).is_err());
        let mut msg = good.clone();
        msg.payload.push(0);
        assert!(q.dequantize_into(&msg, &mut out).is_err());
        let mut small = vec![0.0f32; d / 2];
        assert!(q.dequantize_into(&good, &mut small).is_err());
    }

    #[test]
    fn wire_size() {
        let q = RandK::new(0.1, false).unwrap();
        assert_eq!(q.expected_bytes(1000), 8 + 4 * 100);
    }
}
