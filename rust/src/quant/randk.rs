//! rand_k quantizer (Example B.1): transmit k coordinates chosen
//! uniformly at random.
//!
//! Two variants:
//! * **unscaled** (the paper's Example B.1): `Q(x)_i = x_i` on the sampled
//!   set, 0 elsewhere. Biased contraction with delta = k/d (Lemma A.1 of
//!   Stich et al. 2018).
//! * **scaled**: multiplies kept coordinates by d/k, making E[Q(x)] = x
//!   (unbiased), at the price of variance (d/k - 1)||x||^2.
//!
//! The chosen index set is derived from an 8-byte seed included in the
//! message — the receiver regenerates the same k indices, so indices are
//! never transmitted. Wire: `[ seed : u64 ][ k values : f32 ]`.

use super::{QuantizedMsg, Quantizer};
use crate::util::prng::Prng;
use anyhow::{bail, Result};

/// Keep a random `frac` fraction of coordinates.
#[derive(Clone, Copy, Debug)]
pub struct RandK {
    frac: f64,
    scaled: bool,
}

impl RandK {
    pub fn new(frac: f64, scaled: bool) -> Result<Self> {
        if !(frac > 0.0 && frac <= 1.0) {
            bail!("rand_k fraction must be in (0, 1] (got {frac})");
        }
        Ok(RandK { frac, scaled })
    }

    pub fn k_for(&self, d: usize) -> usize {
        ((self.frac * d as f64).ceil() as usize).clamp(1, d)
    }

    fn indices(seed: u64, d: usize, k: usize) -> Vec<usize> {
        let mut rng = Prng::new(seed);
        let mut idx = rng.sample_indices(d, k);
        idx.sort_unstable();
        idx
    }
}

impl Quantizer for RandK {
    fn name(&self) -> String {
        format!("{}:{}", if self.scaled { "rand_scaled" } else { "rand" }, self.frac)
    }

    fn quantize(&self, x: &[f32], rng: &mut Prng) -> QuantizedMsg {
        let d = x.len();
        let k = self.k_for(d);
        let seed = rng.next_u64();
        let idx = Self::indices(seed, d, k);
        let mut payload = Vec::with_capacity(8 + 4 * k);
        payload.extend_from_slice(&seed.to_le_bytes());
        let gain = if self.scaled { d as f32 / k as f32 } else { 1.0 };
        for &i in &idx {
            payload.extend_from_slice(&(x[i] * gain).to_le_bytes());
        }
        QuantizedMsg { payload, d }
    }

    fn dequantize_into(&self, msg: &QuantizedMsg, out: &mut [f32]) -> Result<()> {
        if msg.d != out.len() {
            bail!("rand_k: dimension mismatch (msg {}, out {})", msg.d, out.len());
        }
        let k = self.k_for(msg.d);
        if msg.payload.len() != 8 + 4 * k {
            bail!("rand_k: payload size mismatch");
        }
        out.fill(0.0);
        let seed = u64::from_le_bytes(msg.payload[..8].try_into().unwrap());
        let idx = Self::indices(seed, msg.d, k);
        for (j, &i) in idx.iter().enumerate() {
            let off = 8 + 4 * j;
            out[i] = f32::from_le_bytes(msg.payload[off..off + 4].try_into().unwrap());
        }
        Ok(())
    }

    fn is_unbiased(&self) -> bool {
        self.scaled
    }

    fn expected_bytes(&self, d: usize) -> usize {
        8 + 4 * self.k_for(d)
    }

    /// Unscaled: delta = k/d (contraction). Scaled: unbiased with
    /// E||Q(x)-x||^2 = (d/k - 1)||x||^2, i.e. delta = 1 - (d/k - 1)
    /// (can be <= 0 when k < d/2 — Definition 2.1's constant exceeds 1).
    fn delta(&self, d: usize) -> f64 {
        let k = self.k_for(d) as f64;
        let d = d as f64;
        if self.scaled {
            1.0 - (d / k - 1.0)
        } else {
            k / d
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn receiver_recovers_same_indices() {
        let mut rng = Prng::new(1);
        let x: Vec<f32> = (0..500).map(|i| i as f32).collect();
        let q = RandK::new(0.2, false).unwrap();
        let msg = q.quantize(&x, &mut rng);
        let y = q.dequantize(&msg).unwrap();
        let kept: Vec<usize> = (0..500).filter(|&i| y[i] != 0.0).collect();
        // +1 for possible x[0]=0 kept; k=100 sampled
        assert!(kept.len() <= 100 && kept.len() >= 99);
        for &i in &kept {
            assert_eq!(y[i], x[i]);
        }
    }

    #[test]
    fn scaled_variant_is_unbiased() {
        let mut rng = Prng::new(2);
        let d = 256;
        let x: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
        let q = RandK::new(0.25, true).unwrap();
        let reps = 2000;
        let mut acc = vec![0.0f64; d];
        for _ in 0..reps {
            let y = q.dequantize(&q.quantize(&x, &mut rng)).unwrap();
            for i in 0..d {
                acc[i] += y[i] as f64;
            }
        }
        let mut bias2 = 0.0;
        let mut xn2 = 0.0;
        for i in 0..d {
            let m = acc[i] / reps as f64;
            bias2 += (m - x[i] as f64).powi(2);
            xn2 += (x[i] as f64).powi(2);
        }
        // E error per rep is (d/k-1)|x|^2 = 3|x|^2; mean over reps shrinks
        assert!(bias2 < 3.0 * xn2 / reps as f64 * 9.0, "bias2 {bias2}");
    }

    #[test]
    fn unscaled_error_is_dropped_mass_on_average() {
        let mut rng = Prng::new(3);
        let d = 400;
        let x: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
        let q = RandK::new(0.5, false).unwrap();
        let xn2 = crate::util::vecf::norm2(&x).powi(2);
        let reps = 500;
        let mut err = 0.0;
        for _ in 0..reps {
            let y = q.dequantize(&q.quantize(&x, &mut rng)).unwrap();
            err += crate::util::vecf::dist2_sq(&y, &x);
        }
        let mean = err / reps as f64;
        // E err = (1 - k/d)|x|^2 = 0.5 |x|^2
        assert!((mean - 0.5 * xn2).abs() / xn2 < 0.05, "mean {mean} xn2 {xn2}");
    }

    #[test]
    fn wire_size() {
        let q = RandK::new(0.1, false).unwrap();
        assert_eq!(q.expected_bytes(1000), 8 + 4 * 100);
    }
}
