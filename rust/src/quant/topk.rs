//! top_k quantizer (Example B.1): transmit the k largest-magnitude
//! coordinates. Biased; contraction delta = k/d (Lemma A.1, Stich et al.
//! 2018). The paper's Table 2 uses top 10% at the *server* side.
//!
//! Wire format: `[ k : u32 ]` then k entries of
//! `[ index : ceil(log2 d) bits ][ value : f32 ]`, densely bit-packed.

use super::{QuantizedMsg, Quantizer};
use crate::util::bitio::{BitReader, BitWriter};
use crate::util::prng::Prng;
use anyhow::{bail, Result};

/// Keep the top `frac` fraction of coordinates (at least 1).
#[derive(Clone, Copy, Debug)]
pub struct TopK {
    frac: f64,
}

impl TopK {
    pub fn new(frac: f64) -> Result<Self> {
        if !(frac > 0.0 && frac <= 1.0) {
            bail!("top_k fraction must be in (0, 1] (got {frac})");
        }
        Ok(TopK { frac })
    }

    pub fn k_for(&self, d: usize) -> usize {
        ((self.frac * d as f64).ceil() as usize).clamp(1, d)
    }

    fn index_bits(d: usize) -> u32 {
        usize::BITS - (d.max(2) - 1).leading_zeros()
    }
}

impl Quantizer for TopK {
    fn name(&self) -> String {
        format!("top:{}", self.frac)
    }

    fn quantize(&self, x: &[f32], _rng: &mut Prng) -> QuantizedMsg {
        let d = x.len();
        let k = self.k_for(d);
        // indices of the k largest |x_i| via partial selection
        let mut idx: Vec<u32> = (0..d as u32).collect();
        let nth = d - k;
        idx.select_nth_unstable_by(nth, |&a, &b| {
            x[a as usize]
                .abs()
                .partial_cmp(&x[b as usize].abs())
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut top: Vec<u32> = idx[nth..].to_vec();
        // canonical order on the wire: ascending index
        top.sort_unstable();

        let ib = Self::index_bits(d);
        let mut w = BitWriter::with_capacity(32 + k * (ib as usize + 32));
        w.write_u32(k as u32);
        for &i in &top {
            w.write(i as u64, ib);
            w.write_f32(x[i as usize]);
        }
        QuantizedMsg { payload: w.into_bytes(), d }
    }

    fn dequantize_into(&self, msg: &QuantizedMsg, out: &mut [f32]) -> Result<()> {
        if msg.d != out.len() {
            bail!("top_k: dimension mismatch (msg {}, out {})", msg.d, out.len());
        }
        out.fill(0.0);
        let ib = Self::index_bits(msg.d);
        let mut r = BitReader::new(&msg.payload);
        let k = match r.read_u32() {
            Some(k) => k as usize,
            None => bail!("top_k: truncated payload"),
        };
        if k > msg.d {
            bail!("top_k: k {k} > d {}", msg.d);
        }
        for _ in 0..k {
            let (i, v) = match (r.read(ib), r.read_f32()) {
                (Some(i), Some(v)) => (i as usize, v),
                _ => bail!("top_k: truncated payload"),
            };
            if i >= msg.d {
                bail!("top_k: index {i} out of range");
            }
            out[i] = v;
        }
        Ok(())
    }

    fn is_unbiased(&self) -> bool {
        false
    }

    fn expected_bytes(&self, d: usize) -> usize {
        let k = self.k_for(d);
        let ib = Self::index_bits(d) as usize;
        4 + (k * (ib + 32)).div_ceil(8)
    }

    /// Lemma A.1 of Stich et al. 2018: delta = k/d.
    fn delta(&self, d: usize) -> f64 {
        self.k_for(d) as f64 / d as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_exactly_the_largest_coordinates() {
        let mut rng = Prng::new(1);
        let x = vec![0.1, -5.0, 0.2, 3.0, -0.05, 1.0, 0.0, -2.0];
        let q = TopK::new(0.5).unwrap(); // k = 4
        let msg = q.quantize(&x, &mut rng);
        let y = q.dequantize(&msg).unwrap();
        // top-4 by |.|: -5.0, 3.0, -2.0, 1.0
        assert_eq!(y, vec![0.0, -5.0, 0.0, 3.0, 0.0, 1.0, 0.0, -2.0]);
    }

    #[test]
    fn kept_values_are_bit_exact() {
        let mut rng = Prng::new(2);
        let x: Vec<f32> = (0..1000).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let q = TopK::new(0.1).unwrap();
        let y = q.dequantize(&q.quantize(&x, &mut rng)).unwrap();
        let kept = y.iter().filter(|v| **v != 0.0).count();
        assert_eq!(kept, 100);
        for i in 0..1000 {
            assert!(y[i] == 0.0 || y[i] == x[i]);
        }
    }

    #[test]
    fn error_equals_dropped_mass() {
        // ||Q(x)-x||^2 = sum of squares of dropped coords (deterministic)
        let mut rng = Prng::new(3);
        let x: Vec<f32> = (0..64).map(|i| (i as f32 + 1.0) / 64.0).collect();
        let q = TopK::new(0.25).unwrap(); // keeps 16 largest = last 16
        let y = q.dequantize(&q.quantize(&x, &mut rng)).unwrap();
        let err: f64 = crate::util::vecf::dist2_sq(&y, &x);
        let dropped: f64 = x[..48].iter().map(|&v| (v as f64) * (v as f64)).sum();
        assert!((err - dropped).abs() < 1e-9);
    }

    #[test]
    fn k_at_least_one_and_full_fraction_is_lossless() {
        let mut rng = Prng::new(4);
        let q = TopK::new(1e-9).unwrap();
        assert_eq!(q.k_for(10), 1);
        let q1 = TopK::new(1.0).unwrap();
        let x: Vec<f32> = (0..37).map(|_| rng.f32()).collect();
        let y = q1.dequantize(&q1.quantize(&x, &mut rng)).unwrap();
        assert_eq!(x, y);
    }

    #[test]
    fn paper_table2_server_size() {
        // server top 10% of d=29,474: 2948 entries * (15 idx bits + 32) + 4B
        let q = TopK::new(0.1).unwrap();
        let b = q.expected_bytes(29_474);
        assert_eq!(b, 4 + (2948usize * (15 + 32)).div_ceil(8));
        // paper reports 15.404 kB/download; ours is within ~13%
        assert!((b as f64 - 15_404.0).abs() / 15_404.0 < 0.15, "{b}");
    }

    #[test]
    fn invalid_fraction_rejected() {
        assert!(TopK::new(0.0).is_err());
        assert!(TopK::new(1.5).is_err());
        assert!(TopK::new(-0.1).is_err());
    }
}
