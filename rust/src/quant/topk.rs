//! top_k quantizer (Example B.1): transmit the k largest-magnitude
//! coordinates. Biased; contraction delta = k/d (Lemma A.1, Stich et al.
//! 2018). The paper's Table 2 uses top 10% at the *server* side.
//!
//! Wire format: `[ k : u32 ]` then k entries of
//! `[ index : ceil(log2 d) bits ][ value : f32 ]`, densely bit-packed,
//! in ascending index order.
//!
//! **Selection order.** "The k largest" is made a *wire contract* by a
//! strict total order: coordinates compare by `|x_i|` under IEEE
//! `total_cmp` (so NaN/-0.0 behave deterministically), with ties broken
//! by the higher index. With no ties the selected set is uniquely
//! determined, which is what lets the sharded encoder reproduce the
//! sequential payload bit-for-bit.
//!
//! **Sharding** ([`RangeCodec`], [`Assembly::Merge`]): the O(d) scan is
//! the expensive part, so each shard selects its *local* top-k as a
//! candidate list (the header; every global winner inside a shard is by
//! definition inside that shard's local top-k), and a cheap sequential
//! merge (≤ S·k candidates) picks the global selection under the same
//! total order and bit-packs the canonical payload. Decode is random
//! access: entries are fixed-width, so a range decoder binary-searches
//! the first in-range index and scans from there — which also gives the
//! server a direct *sparse* accumulate (O(k) instead of an O(d)
//! dequantize into a temp).

use super::{Assembly, EncodeNoise, QuantizedMsg, Quantizer, RangeCodec};
use crate::util::bitio::{BitReader, BitWriter};
use crate::util::prng::Prng;
use anyhow::{bail, Result};
use std::cmp::Ordering;

/// Keep the top `frac` fraction of coordinates (at least 1).
#[derive(Clone, Copy, Debug)]
pub struct TopK {
    frac: f64,
}

impl TopK {
    pub fn new(frac: f64) -> Result<Self> {
        if !(frac > 0.0 && frac <= 1.0) {
            bail!("top_k fraction must be in (0, 1] (got {frac})");
        }
        Ok(TopK { frac })
    }

    pub fn k_for(&self, d: usize) -> usize {
        ((self.frac * d as f64).ceil() as usize).clamp(1, d)
    }

    fn index_bits(d: usize) -> u32 {
        usize::BITS - (d.max(2) - 1).leading_zeros()
    }

    /// The selection total order on `(global index, value)` candidates,
    /// descending: larger `|value|` first, ties to the higher index.
    fn sel_desc(a: &(u32, f32), b: &(u32, f32)) -> Ordering {
        b.1.abs().total_cmp(&a.1.abs()).then_with(|| b.0.cmp(&a.0))
    }

    /// Local top-min(k, len) candidates of a chunk starting at global
    /// coordinate `offset`, returned in ascending index order.
    fn local_top(&self, x: &[f32], offset: usize, k: usize) -> Vec<(u32, f32)> {
        let m = k.min(x.len());
        let mut idx: Vec<u32> = (0..x.len() as u32).collect();
        let nth = x.len() - m;
        // ascending comparator consistent with `sel_desc` (local index
        // order equals global index order within a chunk)
        idx.select_nth_unstable_by(nth, |&a, &b| {
            x[a as usize]
                .abs()
                .total_cmp(&x[b as usize].abs())
                .then_with(|| a.cmp(&b))
        });
        let mut top: Vec<(u32, f32)> =
            idx[nth..].iter().map(|&i| (offset as u32 + i, x[i as usize])).collect();
        top.sort_unstable_by_key(|e| e.0);
        top
    }

    /// Global selection + canonical bit-packing from a candidate
    /// superset (must contain the true top-k; indices distinct).
    fn pack(&self, mut cands: Vec<(u32, f32)>, d: usize) -> Vec<u8> {
        let k = self.k_for(d);
        cands.sort_unstable_by(Self::sel_desc);
        cands.truncate(k);
        cands.sort_unstable_by_key(|e| e.0);
        let ib = Self::index_bits(d);
        let mut w = BitWriter::with_capacity(32 + k * (ib as usize + 32));
        w.write_u32(k as u32);
        for &(i, v) in &cands {
            w.write(i as u64, ib);
            w.write_f32(v);
        }
        w.into_bytes()
    }

    /// Validate the payload and visit every entry whose index falls in
    /// `[offset, offset + len)`, as `(local index, value)`. Entries are
    /// fixed-width records, so the first in-range entry is found by
    /// binary search over the index field.
    fn for_range_entries(
        &self,
        msg: &QuantizedMsg,
        offset: usize,
        len: usize,
        mut visit: impl FnMut(usize, f32),
    ) -> Result<()> {
        let d = msg.d;
        if offset + len > d {
            bail!("top_k: range {offset}..{} exceeds d={d}", offset + len);
        }
        let ib = Self::index_bits(d);
        let ew = ib as usize + 32;
        let mut r = BitReader::new(&msg.payload);
        let k = match r.read_u32() {
            Some(k) => k as usize,
            None => bail!("top_k: truncated payload"),
        };
        if k > d {
            bail!("top_k: k {k} > d {d}");
        }
        if msg.payload.len() != 4 + (k * ew).div_ceil(8) {
            bail!(
                "top_k: payload size mismatch (got {} bytes, want {} for k={k}, d={d})",
                msg.payload.len(),
                4 + (k * ew).div_ceil(8)
            );
        }
        // first entry with index >= offset (entries are index-ascending)
        let (mut lo, mut hi) = (0usize, k);
        while lo < hi {
            let mid = (lo + hi) / 2;
            r.seek(32 + mid * ew);
            let i = match r.read(ib) {
                Some(i) => i as usize,
                None => bail!("top_k: truncated payload"),
            };
            if i < offset {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        r.seek(32 + lo * ew);
        let mut prev: Option<usize> = None;
        for _ in lo..k {
            let (i, v) = match (r.read(ib), r.read_f32()) {
                (Some(i), Some(v)) => (i as usize, v),
                _ => bail!("top_k: truncated payload"),
            };
            if i >= d {
                bail!("top_k: index {i} out of range");
            }
            if prev.is_some_and(|p| i <= p) {
                bail!("top_k: unsorted index stream");
            }
            prev = Some(i);
            if i >= offset + len {
                break;
            }
            visit(i - offset, v);
        }
        Ok(())
    }
}

impl RangeCodec for TopK {
    fn alignment(&self) -> usize {
        1 // selection splits at any seam; assembly is a merge, not a stitch
    }

    fn noise_dims(&self, _d: usize) -> (usize, usize) {
        (0, 0) // deterministic codec
    }

    fn assembly(&self) -> Assembly {
        Assembly::Merge
    }

    fn encode_range(
        &self,
        x: &[f32],
        offset: usize,
        d: usize,
        _noise: &EncodeNoise,
    ) -> (Vec<u8>, Vec<u8>) {
        assert!(offset + x.len() <= d, "top_k range out of bounds");
        // header: the local candidate list `[n : u32][(idx : u32, value
        // bits : u32)...]` — merged by `merge_parts`, never on the wire
        let cands = self.local_top(x, offset, self.k_for(d));
        let mut header = Vec::with_capacity(4 + cands.len() * 8);
        header.extend_from_slice(&(cands.len() as u32).to_le_bytes());
        for &(i, v) in &cands {
            header.extend_from_slice(&i.to_le_bytes());
            header.extend_from_slice(&v.to_le_bytes());
        }
        (header, Vec::new())
    }

    fn merge_parts(&self, parts: Vec<(Vec<u8>, Vec<u8>)>, d: usize) -> Vec<u8> {
        let mut cands: Vec<(u32, f32)> = Vec::new();
        for (header, _) in &parts {
            let n = u32::from_le_bytes(header[..4].try_into().unwrap()) as usize;
            cands.reserve(n);
            for j in 0..n {
                let off = 4 + j * 8;
                let i = u32::from_le_bytes(header[off..off + 4].try_into().unwrap());
                let v = f32::from_le_bytes(header[off + 4..off + 8].try_into().unwrap());
                cands.push((i, v));
            }
        }
        self.pack(cands, d)
    }

    fn accumulate_range(
        &self,
        msg: &QuantizedMsg,
        weight: f32,
        acc: &mut [f32],
        offset: usize,
    ) -> Result<()> {
        self.for_range_entries(msg, offset, acc.len(), |i, v| acc[i] += weight * v)
    }

    fn dequantize_range(&self, msg: &QuantizedMsg, out: &mut [f32], offset: usize) -> Result<()> {
        out.fill(0.0);
        self.for_range_entries(msg, offset, out.len(), |i, v| out[i] = v)
    }
}

impl Quantizer for TopK {
    fn name(&self) -> String {
        format!("top:{}", self.frac)
    }

    fn quantize(&self, x: &[f32], _rng: &mut Prng) -> QuantizedMsg {
        // one code path with the sharded encoder: the whole vector is a
        // single candidate range, packed by the same selection/merge
        let d = x.len();
        let cands = self.local_top(x, 0, self.k_for(d));
        QuantizedMsg { payload: self.pack(cands, d), d }
    }

    fn dequantize_into(&self, msg: &QuantizedMsg, out: &mut [f32]) -> Result<()> {
        if msg.d != out.len() {
            bail!("top_k: dimension mismatch (msg {}, out {})", msg.d, out.len());
        }
        self.dequantize_range(msg, out, 0)
    }

    /// Direct sparse accumulate: scatters the k kept entries instead of
    /// dequantizing into an O(d) temporary.
    fn accumulate(&self, msg: &QuantizedMsg, weight: f32, acc: &mut [f32]) -> Result<()> {
        if msg.d != acc.len() {
            bail!("top_k: dimension mismatch (msg {}, acc {})", msg.d, acc.len());
        }
        self.accumulate_range(msg, weight, acc, 0)
    }

    fn is_unbiased(&self) -> bool {
        false
    }

    fn expected_bytes(&self, d: usize) -> usize {
        let k = self.k_for(d);
        let ib = Self::index_bits(d) as usize;
        4 + (k * (ib + 32)).div_ceil(8)
    }

    /// Lemma A.1 of Stich et al. 2018: delta = k/d.
    fn delta(&self, d: usize) -> f64 {
        self.k_for(d) as f64 / d as f64
    }

    fn range_codec(&self) -> Option<&dyn RangeCodec> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_exactly_the_largest_coordinates() {
        let mut rng = Prng::new(1);
        let x = vec![0.1, -5.0, 0.2, 3.0, -0.05, 1.0, 0.0, -2.0];
        let q = TopK::new(0.5).unwrap(); // k = 4
        let msg = q.quantize(&x, &mut rng);
        let y = q.dequantize(&msg).unwrap();
        // top-4 by |.|: -5.0, 3.0, -2.0, 1.0
        assert_eq!(y, vec![0.0, -5.0, 0.0, 3.0, 0.0, 1.0, 0.0, -2.0]);
    }

    #[test]
    fn kept_values_are_bit_exact() {
        let mut rng = Prng::new(2);
        let x: Vec<f32> = (0..1000).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let q = TopK::new(0.1).unwrap();
        let y = q.dequantize(&q.quantize(&x, &mut rng)).unwrap();
        let kept = y.iter().filter(|v| **v != 0.0).count();
        assert_eq!(kept, 100);
        for i in 0..1000 {
            assert!(y[i] == 0.0 || y[i] == x[i]);
        }
    }

    #[test]
    fn error_equals_dropped_mass() {
        // ||Q(x)-x||^2 = sum of squares of dropped coords (deterministic)
        let mut rng = Prng::new(3);
        let x: Vec<f32> = (0..64).map(|i| (i as f32 + 1.0) / 64.0).collect();
        let q = TopK::new(0.25).unwrap(); // keeps 16 largest = last 16
        let y = q.dequantize(&q.quantize(&x, &mut rng)).unwrap();
        let err: f64 = crate::util::vecf::dist2_sq(&y, &x);
        let dropped: f64 = x[..48].iter().map(|&v| (v as f64) * (v as f64)).sum();
        assert!((err - dropped).abs() < 1e-9);
    }

    #[test]
    fn ties_break_deterministically_to_the_higher_index() {
        // equal magnitudes are a wire contract now, not select_nth
        // internals: the higher index wins
        let mut rng = Prng::new(9);
        let x = vec![1.0f32, -1.0, 1.0, -1.0, 1.0, 0.5];
        let q = TopK::new(0.5).unwrap(); // k = 3 of 6
        let y = q.dequantize(&q.quantize(&x, &mut rng)).unwrap();
        assert_eq!(y, vec![0.0, 0.0, 1.0, -1.0, 1.0, 0.0]);
    }

    #[test]
    fn k_at_least_one_and_full_fraction_is_lossless() {
        let mut rng = Prng::new(4);
        let q = TopK::new(1e-9).unwrap();
        assert_eq!(q.k_for(10), 1);
        let q1 = TopK::new(1.0).unwrap();
        let x: Vec<f32> = (0..37).map(|_| rng.f32()).collect();
        let y = q1.dequantize(&q1.quantize(&x, &mut rng)).unwrap();
        assert_eq!(x, y);
    }

    #[test]
    fn paper_table2_server_size() {
        // server top 10% of d=29,474: 2948 entries * (15 idx bits + 32) + 4B
        let q = TopK::new(0.1).unwrap();
        let b = q.expected_bytes(29_474);
        assert_eq!(b, 4 + (2948usize * (15 + 32)).div_ceil(8));
        // paper reports 15.404 kB/download; ours is within ~13%
        assert!((b as f64 - 15_404.0).abs() / 15_404.0 < 0.15, "{b}");
    }

    #[test]
    fn sparse_accumulate_matches_dense_dequantize_axpy() {
        let mut rng = Prng::new(5);
        for d in [9usize, 100, 1000, 4097] {
            let x: Vec<f32> = (0..d).map(|_| rng.f32() * 4.0 - 2.0).collect();
            let q = TopK::new(0.17).unwrap();
            let msg = q.quantize(&x, &mut rng);
            for w in [1.0f32, 0.25, -0.75] {
                let mut a = vec![0.5f32; d];
                let mut b = vec![0.5f32; d];
                q.accumulate(&msg, w, &mut a).unwrap();
                let xq = q.dequantize(&msg).unwrap();
                crate::util::vecf::axpy(&mut b, w, &xq);
                assert_eq!(a, b, "d={d} w={w}");
            }
        }
    }

    #[test]
    fn range_decode_matches_full_decode_at_every_offset() {
        let mut rng = Prng::new(6);
        let d = 777;
        let x: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
        let q = TopK::new(0.1).unwrap();
        let msg = q.quantize(&x, &mut rng);
        let full = q.dequantize(&msg).unwrap();
        for span in [1usize, 7, 128, 500, 777] {
            let mut out = vec![9.0f32; d];
            let mut acc = vec![0.25f32; d];
            for (i, chunk) in out.chunks_mut(span).enumerate() {
                q.dequantize_range(&msg, chunk, i * span).unwrap();
            }
            for (i, chunk) in acc.chunks_mut(span).enumerate() {
                q.accumulate_range(&msg, 2.0, chunk, i * span).unwrap();
            }
            assert_eq!(out, full, "span {span}");
            let mut want = vec![0.25f32; d];
            crate::util::vecf::axpy(&mut want, 2.0, &full);
            assert_eq!(acc, want, "span {span} accumulate");
        }
    }

    #[test]
    fn malformed_payloads_error_loudly() {
        let mut rng = Prng::new(7);
        let d = 200;
        let x: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
        let q = TopK::new(0.1).unwrap();
        let good = q.quantize(&x, &mut rng);
        let mut out = vec![0.0f32; d];
        // truncated
        let mut msg = good.clone();
        msg.payload.pop();
        assert!(q.dequantize_into(&msg, &mut out).is_err());
        assert!(q.accumulate(&msg, 1.0, &mut out).is_err());
        // oversized
        let mut msg = good.clone();
        msg.payload.push(0);
        assert!(q.dequantize_into(&msg, &mut out).is_err());
        // k > d
        let mut w = BitWriter::new();
        w.write_u32(d as u32 + 1);
        let msg = QuantizedMsg { payload: w.into_bytes(), d };
        assert!(q.dequantize_into(&msg, &mut out).is_err());
        // wrong dimension rejected before decode
        let mut small = vec![0.0f32; d / 2];
        assert!(q.dequantize_into(&good, &mut small).is_err());
        assert!(q.accumulate(&good, 1.0, &mut small).is_err());
    }

    #[test]
    fn invalid_fraction_rejected() {
        assert!(TopK::new(0.0).is_err());
        assert!(TopK::new(1.5).is_err());
        assert!(TopK::new(-0.1).is_err());
    }
}
