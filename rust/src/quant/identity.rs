//! Full-precision "quantizer" — the FedBuff baseline's wire format.
//!
//! 4 bytes per coordinate, little-endian f32. For the paper's d = 29,282
//! this is the 117.128 kB/update FedBuff row in Tables 1–2 (ours:
//! 4 * 29,474 = 117.896 kB).

use super::{EncodeNoise, QuantizedMsg, Quantizer, RangeCodec};
use crate::util::prng::Prng;
use anyhow::{bail, Result};

/// Identity quantizer (no compression).
#[derive(Clone, Copy, Debug, Default)]
pub struct Identity;

impl Quantizer for Identity {
    fn name(&self) -> String {
        "none".into()
    }

    fn quantize(&self, x: &[f32], _rng: &mut Prng) -> QuantizedMsg {
        let mut payload = Vec::with_capacity(x.len() * 4);
        for v in x {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        QuantizedMsg { payload, d: x.len() }
    }

    fn dequantize_into(&self, msg: &QuantizedMsg, out: &mut [f32]) -> Result<()> {
        if msg.d != out.len() || msg.payload.len() != out.len() * 4 {
            bail!(
                "identity: dimension mismatch (msg d={}, out {}, payload {}B)",
                msg.d,
                out.len(),
                msg.payload.len()
            );
        }
        for (i, chunk) in msg.payload.chunks_exact(4).enumerate() {
            out[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        Ok(())
    }

    fn accumulate(&self, msg: &QuantizedMsg, weight: f32, acc: &mut [f32]) -> Result<()> {
        if msg.d != acc.len() || msg.payload.len() != acc.len() * 4 {
            bail!("identity: dimension mismatch");
        }
        for (i, chunk) in msg.payload.chunks_exact(4).enumerate() {
            acc[i] += weight * f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        Ok(())
    }

    fn is_unbiased(&self) -> bool {
        true
    }

    fn expected_bytes(&self, d: usize) -> usize {
        d * 4
    }

    fn delta(&self, _d: usize) -> f64 {
        1.0 // exact: E||Q(x)-x||^2 = 0
    }

    fn range_codec(&self) -> Option<&dyn RangeCodec> {
        Some(self)
    }
}

impl RangeCodec for Identity {
    fn alignment(&self) -> usize {
        1 // 4 whole bytes per coordinate: every seam is byte-aligned
    }

    fn noise_dims(&self, _d: usize) -> (usize, usize) {
        (0, 0) // deterministic codec
    }

    fn encode_range(
        &self,
        x: &[f32],
        offset: usize,
        d: usize,
        _noise: &EncodeNoise,
    ) -> (Vec<u8>, Vec<u8>) {
        assert!(offset + x.len() <= d, "identity range out of bounds");
        let mut body = Vec::with_capacity(x.len() * 4);
        for v in x {
            body.extend_from_slice(&v.to_le_bytes());
        }
        (Vec::new(), body)
    }

    fn accumulate_range(
        &self,
        msg: &QuantizedMsg,
        weight: f32,
        acc: &mut [f32],
        offset: usize,
    ) -> Result<()> {
        if offset + acc.len() > msg.d || msg.payload.len() != msg.d * 4 {
            bail!(
                "identity: bad range {offset}..{} for d={} ({} payload bytes)",
                offset + acc.len(),
                msg.d,
                msg.payload.len()
            );
        }
        let raw = &msg.payload[offset * 4..(offset + acc.len()) * 4];
        for (i, chunk) in raw.chunks_exact(4).enumerate() {
            acc[i] += weight * f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        Ok(())
    }

    fn dequantize_range(&self, msg: &QuantizedMsg, out: &mut [f32], offset: usize) -> Result<()> {
        if offset + out.len() > msg.d || msg.payload.len() != msg.d * 4 {
            bail!("identity: bad range {offset}..{} for d={}", offset + out.len(), msg.d);
        }
        let raw = &msg.payload[offset * 4..(offset + out.len()) * 4];
        for (i, chunk) in raw.chunks_exact(4).enumerate() {
            out[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_roundtrip() {
        let mut rng = Prng::new(1);
        let x: Vec<f32> = (0..1000).map(|_| rng.f32() * 1e6 - 5e5).collect();
        let q = Identity;
        let msg = q.quantize(&x, &mut rng);
        assert_eq!(msg.wire_bytes(), 4000);
        let y = q.dequantize(&msg).unwrap();
        assert_eq!(x, y); // bit-exact
    }

    #[test]
    fn paper_scale_full_precision_size() {
        // d=29,474 -> 117.896 kB (paper's d=29,282 -> 117.128 kB)
        assert_eq!(Identity.expected_bytes(29_474), 117_896);
    }
}
