//! Quantizers and their wire codecs (paper Definition 2.1, Example B.1).
//!
//! A quantizer `Q` satisfies `E[|Q(x) - x|^2] <= (1 - delta) |x|^2`. The
//! paper's system quantizes **both directions**: the client's P-step model
//! delta with `Q_c` and the server's hidden-state increment with `Q_s`.
//!
//! Every quantizer here produces a *real packed byte buffer* — the
//! communication metrics in the reproduced tables are the lengths of these
//! buffers, not closed-form estimates. All quantizer randomness is drawn
//! from an explicit [`Prng`], keeping every experiment deterministic.
//!
//! Implementations:
//! * [`identity::Identity`] — full precision (FedBuff baseline), 4d bytes.
//! * [`qsgd::Qsgd`] — n-bit qsgd (Alistarh et al. 2017): 1 sign bit +
//!   (n-1) magnitude bits per coordinate + one f32 norm. Unbiased.
//! * [`topk::TopK`] — largest-k coordinates (biased), delta = k/d, with
//!   a deterministic total selection order (ties to the higher index).
//! * [`randk::RandK`] — random-k coordinates via stratified per-bucket
//!   index streams; unscaled (biased, delta = k/d) or scaled by the
//!   inverse inclusion probability (unbiased).
//!
//! Every codec exposes a [`RangeCodec`] view, so all of them run on the
//! sharded aggregation pipeline (`sharded`, DESIGN_SHARDING.md) with
//! payloads bit-identical to the sequential encoders at every shard
//! count.

pub mod identity;
pub mod qsgd;
pub mod randk;
pub mod topk;

use crate::util::prng::Prng;
use anyhow::{anyhow, bail, Result};

/// A quantized message as it would travel on the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedMsg {
    /// Packed payload bytes (exactly what the codec emits).
    pub payload: Vec<u8>,
    /// Dimension of the encoded vector (part of the connection handshake,
    /// not repeated per message).
    pub d: usize,
}

impl QuantizedMsg {
    pub fn wire_bytes(&self) -> usize {
        self.payload.len()
    }
}

/// Common interface for all quantizers.
pub trait Quantizer: Send + Sync {
    /// Human-readable spec (e.g. "qsgd:4").
    fn name(&self) -> String;

    /// Quantize + encode `x` into a wire message.
    fn quantize(&self, x: &[f32], rng: &mut Prng) -> QuantizedMsg;

    /// Decode + dequantize into `out` (overwrites).
    fn dequantize_into(&self, msg: &QuantizedMsg, out: &mut [f32]) -> Result<()>;

    /// Decode and accumulate `weight * Q(x)` into `acc` — the server's
    /// buffer-aggregation hot path (no intermediate allocation).
    fn accumulate(&self, msg: &QuantizedMsg, weight: f32, acc: &mut [f32]) -> Result<()> {
        let mut tmp = vec![0.0f32; acc.len()];
        self.dequantize_into(msg, &mut tmp)?;
        crate::util::vecf::axpy(acc, weight, &tmp);
        Ok(())
    }

    /// Convenience: decode to a fresh vector.
    fn dequantize(&self, msg: &QuantizedMsg) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; msg.d];
        self.dequantize_into(msg, &mut out)?;
        Ok(out)
    }

    /// Whether E[Q(x)] = x (Definition 2.1 discussion; Algorithm 2
    /// requires an unbiased client quantizer).
    fn is_unbiased(&self) -> bool;

    /// Expected payload size in bytes for dimension `d`.
    fn expected_bytes(&self, d: usize) -> usize;

    /// The contraction parameter delta in Definition 2.1 for dimension
    /// `d` (may be <= 0 for coarse qsgd, where the bound constant
    /// exceeds 1; see Lemma 3.1 of Alistarh et al. 2017).
    fn delta(&self, d: usize) -> f64;

    /// Range-oriented view of this codec, if it supports one (see
    /// [`RangeCodec`]). Every built-in codec has one: coordinate-local
    /// codecs (qsgd, identity) stitch per-range parts directly, rand_k
    /// derives per-bucket index streams from one shared seed draw, and
    /// top_k merges per-shard candidate lists into the global selection
    /// ([`Assembly::Merge`]). `None` means the sharded paths fall back
    /// to the sequential trait calls.
    fn range_codec(&self) -> Option<&dyn RangeCodec> {
        None
    }
}

/// Externalized randomness for a sharded encode: everything the
/// full-vector [`Quantizer::quantize`] would draw from its `Prng`, drawn
/// once and sequentially by the caller so the PRNG stream (and therefore
/// every later message) is identical for every shard count.
#[derive(Clone, Debug, Default)]
pub struct EncodeNoise {
    /// Raw `u64` draws consumed before any uniforms (rand_k's index
    /// seed).
    pub seeds: Vec<u64>,
    /// Uniform f32 draws in coordinate order (qsgd's stochastic
    /// rounding); indexed at absolute coordinates by `encode_range`.
    pub uniforms: Vec<f32>,
}

impl EncodeNoise {
    /// Draw exactly the randomness `rc`'s quantize consumes for
    /// dimension `d`, in the same order.
    pub fn draw(rc: &dyn RangeCodec, d: usize, rng: &mut Prng) -> EncodeNoise {
        let (n_seeds, n_uniforms) = rc.noise_dims(d);
        let seeds = (0..n_seeds).map(|_| rng.next_u64()).collect();
        let mut uniforms = vec![0.0f32; n_uniforms];
        for v in &mut uniforms {
            *v = rng.f32();
        }
        EncodeNoise { seeds, uniforms }
    }
}

/// How `sharded::quantize` assembles per-range `(header, body)` parts
/// into the final payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Assembly {
    /// `concat(headers) ++ concat(bodies)` in range order (qsgd,
    /// identity, rand_k) — byte-identical to the sequential payload by
    /// construction.
    Stitch,
    /// Headers are opaque per-range summaries (top_k's local candidate
    /// lists); [`RangeCodec::merge_parts`] combines them into the
    /// payload in one sequential pass.
    Merge,
}

/// Contiguous-range encode/decode for shard-parallel aggregation
/// (DESIGN_SHARDING.md).
///
/// A range codec splits the wire format of a `d`-dimensional message
/// into a per-range `(header, body)` pair such that
///
/// ```text
/// payload(x[0..d]) == concat(headers in range order)
///                  ++ concat(bodies  in range order)
/// ```
///
/// **byte-for-byte** for [`Assembly::Stitch`] codecs, provided every
/// range starts at a multiple of [`RangeCodec::alignment`] (the last
/// range may end ragged at `d`). For qsgd this is the bucket structure:
/// the header holds the per-bucket f32 norms and the body the
/// bit-packed levels, so bucket-aligned ranges make per-bucket norms
/// shard-local and keep the packed body byte-aligned at every shard
/// seam. For rand_k the header is the 8-byte index seed (range 0 only)
/// and the body the per-bucket sampled values. Codecs with global
/// structure (top_k's selection) instead return per-range candidate
/// summaries and assemble via [`RangeCodec::merge_parts`]
/// ([`Assembly::Merge`]).
///
/// Randomness is externalized: [`RangeCodec::noise_dims`] says what the
/// full-vector [`Quantizer::quantize`] draws, and the caller passes the
/// *same* draws ([`EncodeNoise`]) to every `encode_range` call — this
/// is what makes the sharded encoding bit-identical to the sequential
/// one for every shard count, including the PRNG state afterwards.
pub trait RangeCodec: Send + Sync {
    /// Shard boundaries must be multiples of this many coordinates.
    fn alignment(&self) -> usize;

    /// Randomness `quantize` consumes for dimension `d`, as
    /// `(u64 seed draws, per-coordinate uniform f32 draws)` — drawn in
    /// that order. `(0, 0)` for deterministic codecs.
    fn noise_dims(&self, d: usize) -> (usize, usize);

    /// How `sharded::quantize` assembles per-range parts.
    fn assembly(&self) -> Assembly {
        Assembly::Stitch
    }

    /// Combine per-range `(header, body)` parts (in range order) into
    /// the final payload. Only called for [`Assembly::Merge`] codecs.
    fn merge_parts(&self, _parts: Vec<(Vec<u8>, Vec<u8>)>, _d: usize) -> Vec<u8> {
        unreachable!("merge_parts called on an Assembly::Stitch codec")
    }

    /// Encode coordinates `[offset, offset + x.len())` of a `d`-dim
    /// vector into `(header, body)`. `noise` is the full draw set;
    /// implementations index uniforms at absolute coordinates.
    fn encode_range(&self, x: &[f32], offset: usize, d: usize, noise: &EncodeNoise)
        -> (Vec<u8>, Vec<u8>);

    /// Decode coordinates `[offset, offset + acc.len())` of `msg` and
    /// accumulate `weight * Q(x)[i]` into `acc`.
    fn accumulate_range(
        &self,
        msg: &QuantizedMsg,
        weight: f32,
        acc: &mut [f32],
        offset: usize,
    ) -> Result<()>;

    /// Decode coordinates `[offset, offset + out.len())` of `msg` into
    /// `out` (overwrite).
    fn dequantize_range(&self, msg: &QuantizedMsg, out: &mut [f32], offset: usize) -> Result<()>;
}

/// Shard-parallel executions of the codec hot paths, used by the
/// coordinator's sharded aggregation pipeline. Work runs on a
/// persistent [`ShardPool`] (no per-call thread spawns). Every function
/// is bit-identical to its sequential counterpart for **every** pool
/// size (including the PRNG stream consumed), and falls back to the
/// sequential trait call when the codec has no range view or the work
/// doesn't split.
pub mod sharded {
    use super::{Assembly, EncodeNoise, QuantizedMsg, Quantizer};
    use crate::util::pool::{ShardPool, Task};
    use crate::util::prng::Prng;
    use crate::util::shard::span_for;
    use anyhow::Result;

    /// Quantize `x`, splitting encode work across the pool's lanes.
    /// Consumes exactly the same `rng` draws as `q.quantize(x, rng)` and
    /// produces the same bytes.
    pub fn quantize(q: &dyn Quantizer, x: &[f32], rng: &mut Prng, pool: &ShardPool) -> QuantizedMsg {
        let d = x.len();
        let shards = pool.shards();
        let rc = match q.range_codec() {
            Some(rc) if shards > 1 && d > 0 => rc,
            _ => return q.quantize(x, rng),
        };
        let span = span_for(d, shards, rc.alignment());
        if span >= d {
            return q.quantize(x, rng);
        }
        // Replicate quantize's sequential draw order exactly, then hand
        // each shard a read-only view of the draws.
        let noise = EncodeNoise::draw(rc, d, rng);
        let noise_ref = &noise;
        let mut parts: Vec<(Vec<u8>, Vec<u8>)> = vec![(Vec::new(), Vec::new()); d.div_ceil(span)];
        let tasks: Vec<Task<'_>> = parts
            .iter_mut()
            .zip(x.chunks(span))
            .enumerate()
            .map(|(i, (slot, chunk))| {
                Box::new(move || *slot = rc.encode_range(chunk, i * span, d, noise_ref))
                    as Task<'_>
            })
            .collect();
        pool.run(tasks);
        let payload = match rc.assembly() {
            Assembly::Stitch => {
                let mut payload = Vec::with_capacity(q.expected_bytes(d));
                for (header, _) in &parts {
                    payload.extend_from_slice(header);
                }
                for (_, body) in &parts {
                    payload.extend_from_slice(body);
                }
                payload
            }
            Assembly::Merge => rc.merge_parts(parts, d),
        };
        QuantizedMsg { payload, d }
    }

    /// Decode `msg` and accumulate `weight * Q(x)` into `acc` across the
    /// pool's lanes.
    pub fn accumulate(
        q: &dyn Quantizer,
        msg: &QuantizedMsg,
        weight: f32,
        acc: &mut [f32],
        pool: &ShardPool,
    ) -> Result<()> {
        let d = acc.len();
        if msg.d != d {
            // per-shard range checks only see prefixes; enforce the whole-
            // vector contract here, like the sequential decoders do
            anyhow::bail!("sharded: dimension mismatch (msg {}, acc {d})", msg.d);
        }
        let shards = pool.shards();
        let rc = match q.range_codec() {
            Some(rc) if shards > 1 && d > 0 => rc,
            _ => return q.accumulate(msg, weight, acc),
        };
        let span = span_for(d, shards, rc.alignment());
        if span >= d {
            return q.accumulate(msg, weight, acc);
        }
        let mut results: Vec<Result<()>> = (0..d.div_ceil(span)).map(|_| Ok(())).collect();
        let tasks: Vec<Task<'_>> = results
            .iter_mut()
            .zip(acc.chunks_mut(span))
            .enumerate()
            .map(|(i, (slot, chunk))| {
                Box::new(move || *slot = rc.accumulate_range(msg, weight, chunk, i * span))
                    as Task<'_>
            })
            .collect();
        pool.run(tasks);
        for r in results {
            r?;
        }
        Ok(())
    }

    /// Decode `msg` into `out` (overwrite) across the pool's lanes.
    pub fn dequantize_into(
        q: &dyn Quantizer,
        msg: &QuantizedMsg,
        out: &mut [f32],
        pool: &ShardPool,
    ) -> Result<()> {
        let d = out.len();
        if msg.d != d {
            anyhow::bail!("sharded: dimension mismatch (msg {}, out {d})", msg.d);
        }
        let shards = pool.shards();
        let rc = match q.range_codec() {
            Some(rc) if shards > 1 && d > 0 => rc,
            _ => return q.dequantize_into(msg, out),
        };
        let span = span_for(d, shards, rc.alignment());
        if span >= d {
            return q.dequantize_into(msg, out);
        }
        let mut results: Vec<Result<()>> = (0..d.div_ceil(span)).map(|_| Ok(())).collect();
        let tasks: Vec<Task<'_>> = results
            .iter_mut()
            .zip(out.chunks_mut(span))
            .enumerate()
            .map(|(i, (slot, chunk))| {
                Box::new(move || *slot = rc.dequantize_range(msg, chunk, i * span)) as Task<'_>
            })
            .collect();
        pool.run(tasks);
        for r in results {
            r?;
        }
        Ok(())
    }
}

/// Parse a quantizer spec string:
/// `"none"` | `"qsgd:<bits>"` | `"top:<frac>"` | `"rand:<frac>"` |
/// `"rand_scaled:<frac>"`.
pub fn parse_spec(spec: &str) -> Result<Box<dyn Quantizer>> {
    let spec = spec.trim();
    if spec.eq_ignore_ascii_case("none") || spec.eq_ignore_ascii_case("identity") {
        return Ok(Box::new(identity::Identity));
    }
    let (kind, arg) = spec
        .split_once(':')
        .ok_or_else(|| anyhow!("bad quantizer spec '{spec}' (want kind:arg)"))?;
    match kind.to_ascii_lowercase().as_str() {
        "qsgd" => {
            // "qsgd:<bits>" or "qsgd:<bits>:<bucket>"
            let (bits_s, bucket_s) = match arg.split_once(':') {
                Some((b, g)) => (b, Some(g)),
                None => (arg, None),
            };
            let bits: u32 = bits_s.parse().map_err(|_| anyhow!("bad qsgd bits '{arg}'"))?;
            match bucket_s {
                Some(g) => {
                    let bucket: usize =
                        g.parse().map_err(|_| anyhow!("bad qsgd bucket '{arg}'"))?;
                    Ok(Box::new(qsgd::Qsgd::with_bucket(bits, bucket)?))
                }
                None => Ok(Box::new(qsgd::Qsgd::new(bits)?)),
            }
        }
        "top" => {
            let frac: f64 = arg.parse().map_err(|_| anyhow!("bad top fraction '{arg}'"))?;
            Ok(Box::new(topk::TopK::new(frac)?))
        }
        "rand" => {
            let frac: f64 = arg.parse().map_err(|_| anyhow!("bad rand fraction '{arg}'"))?;
            Ok(Box::new(randk::RandK::new(frac, false)?))
        }
        "rand_scaled" => {
            let frac: f64 = arg.parse().map_err(|_| anyhow!("bad rand fraction '{arg}'"))?;
            Ok(Box::new(randk::RandK::new(frac, true)?))
        }
        other => bail!("unknown quantizer kind '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{forall, gens};
    use crate::util::vecf;

    fn specs() -> Vec<&'static str> {
        vec!["none", "qsgd:2", "qsgd:4", "qsgd:8", "top:0.1", "rand:0.1", "rand_scaled:0.25"]
    }

    #[test]
    fn parse_all_specs() {
        for s in specs() {
            let q = parse_spec(s).unwrap();
            assert!(!q.name().is_empty());
        }
        assert!(parse_spec("qsgd").is_err());
        assert!(parse_spec("huff:3").is_err());
        assert!(parse_spec("qsgd:x").is_err());
    }

    #[test]
    fn expected_bytes_matches_actual_payload() {
        let mut rng = Prng::new(5);
        for s in specs() {
            let q = parse_spec(s).unwrap();
            for d in [1usize, 7, 128, 1000, 29474] {
                let x: Vec<f32> = (0..d).map(|i| ((i as f32) * 0.37).sin()).collect();
                let msg = q.quantize(&x, &mut rng);
                assert_eq!(
                    msg.wire_bytes(),
                    q.expected_bytes(d),
                    "{s} at d={d}"
                );
            }
        }
    }

    #[test]
    fn contraction_bound_empirical() {
        // E||Q(x)-x||^2 <= (1-delta)||x||^2 with the implementation's own
        // delta (for qsgd the constant may exceed 1; still must hold).
        let mut rng = Prng::new(6);
        let d = 4096;
        let x: Vec<f32> = (0..d).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let xn = vecf::norm2(&x).powi(2);
        for s in specs() {
            let q = parse_spec(s).unwrap();
            let reps = 30;
            let mut err_sum = 0.0;
            for _ in 0..reps {
                let msg = q.quantize(&x, &mut rng);
                let xq = q.dequantize(&msg).unwrap();
                err_sum += vecf::dist2_sq(&xq, &x);
            }
            let mean_err = err_sum / reps as f64;
            let bound = (1.0 - q.delta(d)) * xn;
            assert!(
                mean_err <= bound * 1.10 + 1e-9,
                "{s}: E err {mean_err} > (1-delta)|x|^2 = {bound}"
            );
        }
    }

    #[test]
    fn unbiased_quantizers_have_zero_mean_error() {
        let mut rng = Prng::new(7);
        let d = 512;
        let x: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
        for s in specs() {
            let q = parse_spec(s).unwrap();
            if !q.is_unbiased() {
                continue;
            }
            let reps = 400;
            let mut acc = vec![0.0f64; d];
            for _ in 0..reps {
                let xq = q.dequantize(&q.quantize(&x, &mut rng)).unwrap();
                for i in 0..d {
                    acc[i] += xq[i] as f64;
                }
            }
            let mean: Vec<f64> = acc.iter().map(|a| a / reps as f64).collect();
            let bias2: f64 = mean
                .iter()
                .zip(&x)
                .map(|(m, &v)| (m - v as f64) * (m - v as f64))
                .sum();
            let xn2: f64 = x.iter().map(|&v| (v as f64) * (v as f64)).sum();
            // sampling tolerance: var/reps scaled generously
            let tol = (1.0 - q.delta(d).min(0.99)) * xn2 / reps as f64 * 9.0 + 1e-9;
            assert!(bias2 <= tol, "{s}: bias^2 {bias2} > tol {tol}");
        }
    }

    #[test]
    fn accumulate_equals_dequantize_axpy() {
        let mut rng = Prng::new(8);
        let d = 777;
        let x: Vec<f32> = (0..d).map(|_| rng.f32() * 4.0 - 2.0).collect();
        for s in specs() {
            let q = parse_spec(s).unwrap();
            let msg = q.quantize(&x, &mut rng);
            let mut a = vec![1.0f32; d];
            let mut b = vec![1.0f32; d];
            q.accumulate(&msg, 0.5, &mut a).unwrap();
            let xq = q.dequantize(&msg).unwrap();
            vecf::axpy(&mut b, 0.5, &xq);
            assert_eq!(a, b, "{s}");
        }
    }

    #[test]
    fn prop_roundtrip_never_panics_and_output_is_finite() {
        for s in specs() {
            let q = parse_spec(s).unwrap();
            forall(
                &format!("finite output {s}"),
                gens::vec_f32_gnarly(1, 3000),
                |xs| {
                    let mut rng = Prng::new(11);
                    let msg = q.quantize(xs, &mut rng);
                    let xq = q.dequantize(&msg).map_err(|e| e.to_string())?;
                    if xq.len() != xs.len() {
                        return Err("len mismatch".into());
                    }
                    if xq.iter().any(|v| !v.is_finite()) {
                        return Err("non-finite output".into());
                    }
                    Ok(())
                },
            );
        }
    }

    #[test]
    fn wrong_dimension_rejected() {
        let mut rng = Prng::new(9);
        let q = parse_spec("qsgd:4").unwrap();
        let msg = q.quantize(&[1.0, 2.0, 3.0], &mut rng);
        let mut out = vec![0.0f32; 5];
        assert!(q.dequantize_into(&msg, &mut out).is_err());
    }
}
