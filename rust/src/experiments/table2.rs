//! E3 — Table 2: QAFeL with a **biased** server quantizer (top_k keeping
//! the top 10% of coordinates) against client qsgd in {8, 4, 2} bits.
//!
//! Corollary F.2 covers this case (condition (11)); empirically the
//! paper's footnote warns that 2-bit client + biased server is fragile
//! (one seed failed to reach 90%) — expect lower `reached_frac` there.

use super::runner::{aggregate, report, run_seeds, BackendFactory, Row};
use crate::config::{Algorithm, Config};
use crate::sim::SimOptions;
use anyhow::Result;

pub fn run(
    base: &Config,
    make_backend: &BackendFactory,
    out_dir: &str,
    opts: &SimOptions,
) -> Result<Vec<Row>> {
    let mut rows = Vec::new();

    let mut cfg = base.clone();
    cfg.fl.algorithm = Algorithm::FedBuff;
    let set = run_seeds(&cfg, make_backend, opts, "fedbuff")?;
    rows.push(aggregate(&set));

    for cb in [8u32, 4, 2] {
        let mut cfg = base.clone();
        cfg.fl.algorithm = Algorithm::Qafel;
        cfg.quant.client = format!("qsgd:{cb}");
        cfg.quant.server = "top:0.1".into();
        let label = format!("qafel c{cb}-bit s=top10%");
        let set = run_seeds(&cfg, make_backend, opts, &label)?;
        rows.push(aggregate(&set));
    }
    let md = report("table2", out_dir, base, &rows)?;
    println!("{md}");
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::QuadraticBackend;

    #[test]
    fn table2_biased_server_still_converges() {
        let mut base = Config::default();
        base.fl.buffer_size = 4;
        base.fl.client_lr = 0.15;
        base.fl.server_lr = 1.0;
        base.fl.server_momentum = 0.0;
        base.fl.clip_norm = 0.0;
        base.sim.concurrency = 10;
        base.sim.eval_every = 5;
        base.seeds = vec![1, 2];
        base.stop.target_accuracy = 0.95;
        base.stop.max_uploads = 30_000;
        base.stop.max_server_steps = 8000;

        let factory = |seed: u64| -> Result<Box<dyn crate::runtime::Backend>> {
            // top:0.1 needs enough coordinates for 10% to carry signal
            Ok(Box::new(QuadraticBackend::new(100, 10, 1.0, 0.3, 0.2, 0.02, 2, seed)))
        };
        let dir = std::env::temp_dir().join(format!("qafel-t2-{}", std::process::id()));
        let rows = run(&base, &factory, dir.to_str().unwrap(), &Default::default()).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        assert_eq!(rows.len(), 4);
        // 8-bit client with biased server reaches target
        assert!(rows[1].reached_frac > 0.4, "c8/top10 reached {}", rows[1].reached_frac);
        // download size is constant across client bits (same server codec)
        assert!((rows[1].kb_per_download - rows[3].kb_per_download).abs() < 1e-9);
        // and much smaller than fedbuff's
        assert!(rows[1].kb_per_download < rows[0].kb_per_download / 2.0);
    }
}
