//! E4 — empirical validation of Proposition 3.5 / Corollary F.3 on the
//! analytic quadratic objective, where `R(T) = (1/T) sum_t E||grad
//! f(x^t)||^2` is measurable exactly.
//!
//! Checks performed (the paper's three claims about the error orders):
//! 1. **Client-quantizer dominance**: the excess error
//!    `R_QAFeL - R_FedBuff` grows as the client quantizer coarsens
//!    (2-bit > 4-bit > 8-bit), and a coarse *client* hurts more than an
//!    equally coarse *server* — because the client term decays as
//!    1/sqrt(T) while the server term decays as 1/T.
//! 2. **Infinite-precision limit**: with very fine quantizers
//!    (qsgd:12 both sides), R_QAFeL -> R_FedBuff.
//! 3. **Order-of-decay**: the log-log slope of R(T) is negative and the
//!    QAFeL-vs-FedBuff gap shrinks with T.

use super::runner::BackendFactory;
use crate::config::{Algorithm, Config};
use crate::metrics::csv::CsvWriter;
use crate::sim::{SimEngine, SimOptions};
use crate::util::stats::{mean, ols_slope};
use anyhow::Result;

/// R(T) for one configuration: mean of ||grad f||^2 over the curve.
fn rate_for(cfg: &Config, make_backend: &BackendFactory, seed: u64) -> Result<f64> {
    let backend = make_backend(seed)?;
    let opts = SimOptions { run_past_target: true, ..Default::default() };
    let result = SimEngine::new(cfg, backend.as_ref(), seed).run_with(&opts)?;
    let g2: Vec<f64> = result
        .curve
        .iter()
        .filter_map(|p| p.grad_norm_sq)
        .collect();
    if g2.is_empty() {
        anyhow::bail!("backend does not expose grad_norm_sq (use the quadratic backend)");
    }
    Ok(mean(&g2))
}

/// One labelled convergence measurement.
#[derive(Clone, Debug)]
pub struct RatePoint {
    pub label: String,
    pub horizon: u64,
    pub rate: f64,
}

/// Full report of the convergence experiment.
#[derive(Clone, Debug)]
pub struct ConvergenceReport {
    pub points: Vec<RatePoint>,
    /// R(T=max) per quantizer config.
    pub findings: Vec<String>,
    /// log-log slope of R(T) for QAFeL 4/4.
    pub decay_slope: f64,
}

fn cfg_for(base: &Config, algo: Algorithm, qc: &str, qs: &str, horizon: u64) -> Config {
    let mut cfg = base.clone();
    cfg.fl.algorithm = algo;
    cfg.quant.client = qc.into();
    cfg.quant.server = qs.into();
    cfg.stop.target_accuracy = 2.0; // never stop early: fixed horizon
    cfg.stop.max_server_steps = horizon;
    cfg.stop.max_uploads = u64::MAX;
    cfg
}

pub fn run(
    base: &Config,
    make_backend: &BackendFactory,
    out_dir: &str,
    horizons: &[u64],
) -> Result<ConvergenceReport> {
    let seeds = base.seeds.clone();
    let mut points = Vec::new();
    let configs: Vec<(String, Algorithm, String, String)> = vec![
        ("fedbuff".into(), Algorithm::FedBuff, "none".into(), "none".into()),
        ("qafel c8 s8".into(), Algorithm::Qafel, "qsgd:8".into(), "qsgd:8".into()),
        ("qafel c4 s4".into(), Algorithm::Qafel, "qsgd:4".into(), "qsgd:4".into()),
        ("qafel c2 s8".into(), Algorithm::Qafel, "qsgd:2".into(), "qsgd:8".into()),
        // NOTE: the mirrored "c8 s2" config can violate the paper's own
        // convergence condition: Definition 2.1 needs delta_s > 0, but
        // 2-bit qsgd at dimension d has (1-delta) = sqrt(2d)/s > 1, so
        // Lemma F.9's geometric sum may diverge on gaussian-like diffs
        // (the quadratic backend is exactly that worst case). We report
        // it, plus a contraction-safe coarse server (qsgd:4) for the
        // client-vs-server dominance comparison.
        ("qafel c8 s2".into(), Algorithm::Qafel, "qsgd:8".into(), "qsgd:2".into()),
        ("qafel c8 s4".into(), Algorithm::Qafel, "qsgd:8".into(), "qsgd:4".into()),
        ("qafel c12 s12".into(), Algorithm::Qafel, "qsgd:12".into(), "qsgd:12".into()),
    ];
    for (label, algo, qc, qs) in &configs {
        for &t in horizons {
            let cfg = cfg_for(base, *algo, qc, qs, t);
            let rates: Result<Vec<f64>> =
                seeds.iter().map(|&s| rate_for(&cfg, make_backend, s)).collect();
            let rate = mean(&rates?);
            points.push(RatePoint { label: label.clone(), horizon: t, rate });
        }
    }

    // csv
    let mut csv = CsvWriter::new(&["label", "horizon", "rate"]);
    super::runner::stamp(&mut csv, base);
    for p in &points {
        csv.row(&[p.label.clone(), p.horizon.to_string(), format!("{:.6e}", p.rate)]);
    }
    std::fs::create_dir_all(out_dir)?;
    csv.save(format!("{out_dir}/convergence.csv"))?;

    // findings at the largest horizon
    let t_max = *horizons.last().unwrap();
    let rate_at = |label: &str| -> f64 {
        points
            .iter()
            .find(|p| p.label == label && p.horizon == t_max)
            .map(|p| p.rate)
            .unwrap_or(f64::NAN)
    };
    let r_fb = rate_at("fedbuff");
    let mut findings = vec![
        format!("R(T={t_max}) fedbuff           = {:.4e}", r_fb),
        format!("R(T={t_max}) qafel c8 s8       = {:.4e}", rate_at("qafel c8 s8")),
        format!("R(T={t_max}) qafel c4 s4       = {:.4e}", rate_at("qafel c4 s4")),
        format!("R(T={t_max}) qafel c2 s8       = {:.4e} (coarse CLIENT)", rate_at("qafel c2 s8")),
        format!("R(T={t_max}) qafel c8 s2       = {:.4e} (coarse SERVER, outside delta_s>0)", rate_at("qafel c8 s2")),
        format!("R(T={t_max}) qafel c8 s4       = {:.4e} (coarse SERVER)", rate_at("qafel c8 s4")),
        format!("R(T={t_max}) qafel c12 s12     = {:.4e} (-> fedbuff limit)", rate_at("qafel c12 s12")),
    ];
    findings.push(format!(
        "client-dominance check: excess(c2 s8) = {:.3e} vs excess(c8 s4) = {:.3e}",
        rate_at("qafel c2 s8") - r_fb,
        rate_at("qafel c8 s4") - r_fb,
    ));

    // decay slope for qafel c4 s4
    let xs: Vec<f64> = horizons.iter().map(|&t| (t as f64).ln()).collect();
    let ys: Vec<f64> = horizons
        .iter()
        .map(|&t| {
            points
                .iter()
                .find(|p| p.label == "qafel c4 s4" && p.horizon == t)
                .unwrap()
                .rate
                .ln()
        })
        .collect();
    let decay_slope = ols_slope(&xs, &ys);
    findings.push(format!("log-log decay slope of R(T), qafel c4 s4: {decay_slope:.3}"));

    let md = format!(
        "# convergence (Prop. 3.5 validation)\n\n{}\n",
        findings.join("\n")
    );
    std::fs::write(format!("{out_dir}/convergence.md"), &md)?;
    println!("{md}");
    Ok(ConvergenceReport { points, findings, decay_slope })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::QuadraticBackend;

    #[test]
    fn proposition_3_5_shape() {
        let mut base = Config::default();
        base.fl.buffer_size = 4;
        base.fl.client_lr = 0.1;
        base.fl.server_lr = 1.0;
        base.fl.server_momentum = 0.0;
        base.fl.clip_norm = 0.0;
        base.sim.concurrency = 10;
        base.sim.eval_every = 2;
        base.seeds = vec![1, 2, 3];

        let factory = |seed: u64| -> Result<Box<dyn crate::runtime::Backend>> {
            Ok(Box::new(QuadraticBackend::new(64, 10, 1.0, 0.3, 0.2, 0.05, 2, seed)))
        };
        let dir = std::env::temp_dir().join(format!("qafel-conv-{}", std::process::id()));
        let rep = run(&base, &factory, dir.to_str().unwrap(), &[40, 160, 640]).unwrap();
        let _ = std::fs::remove_dir_all(&dir);

        let at = |label: &str, t: u64| {
            rep.points.iter().find(|p| p.label == label && p.horizon == t).unwrap().rate
        };
        // 1. R decreases with T for every config
        for label in ["fedbuff", "qafel c4 s4", "qafel c2 s8"] {
            assert!(at(label, 640) < at(label, 40), "{label} not decaying");
        }
        // 2. coarse client hurts more than coarse server at the largest T
        // (server side compared at qsgd:4, the coarsest contraction-safe
        // setting on this backend; see the note in `run`)
        let excess_client = at("qafel c2 s8", 640) - at("fedbuff", 640);
        let excess_server = at("qafel c8 s4", 640) - at("fedbuff", 640);
        assert!(
            excess_client > excess_server,
            "client excess {excess_client:.3e} <= server excess {excess_server:.3e}"
        );
        // 3. infinite-precision limit: within 20% of fedbuff
        let lim = at("qafel c12 s12", 640);
        let fb = at("fedbuff", 640);
        assert!((lim - fb).abs() / fb < 0.25, "limit {lim:.3e} vs fedbuff {fb:.3e}");
        // 4. decay slope is negative (R(T) shrinking)
        assert!(rep.decay_slope < -0.1, "slope {}", rep.decay_slope);
    }
}
