//! Shared machinery: run a config over seeds, aggregate mean ± std the
//! way the paper reports, and emit csv/markdown.

use crate::config::Config;
use crate::metrics::csv::CsvWriter;
use crate::metrics::RunResult;
use crate::runtime::Backend;
use crate::sim::{SimEngine, SimOptions};
use anyhow::Result;

/// Builds a fresh backend for a given run seed. PJRT backends share one
/// compiled engine behind `Rc`; quadratic backends are rebuilt per seed.
pub type BackendFactory<'a> = dyn Fn(u64) -> Result<Box<dyn Backend>> + 'a;

/// All runs for one experimental condition (one table row).
#[derive(Clone, Debug)]
pub struct RunSet {
    pub label: String,
    pub results: Vec<RunResult>,
}

/// One aggregated table row (mean ± std over seeds, like the paper).
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    /// Client trips to target, in thousands (paper: "Uploads (in
    /// thousands)").
    pub uploads_k_mean: f64,
    pub uploads_k_std: f64,
    /// Exact codec payload sizes.
    pub kb_per_upload: f64,
    pub kb_per_download: f64,
    pub upload_mb_mean: f64,
    pub upload_mb_std: f64,
    pub broadcast_mb_mean: f64,
    pub broadcast_mb_std: f64,
    /// Virtual time to target.
    pub time_mean: f64,
    /// Fraction of seeds that reached the target accuracy.
    pub reached_frac: f64,
    pub final_acc_mean: f64,
}

/// Run `cfg` once per seed.
pub fn run_seeds(
    cfg: &Config,
    make_backend: &BackendFactory,
    opts: &SimOptions,
    label: &str,
) -> Result<RunSet> {
    let mut results = Vec::new();
    for &seed in &cfg.seeds {
        let backend = make_backend(seed)?;
        let result = SimEngine::new(cfg, backend.as_ref(), seed).run_with(opts)?;
        if opts.verbose {
            eprintln!(
                "[{label}] seed {seed}: uploads={} reached={} final_acc={:.4} ({:.1}s wall)",
                result.comm.uploads,
                result.reached.is_some(),
                result.final_accuracy,
                result.wall_seconds
            );
        }
        results.push(result);
    }
    Ok(RunSet { label: label.to_string(), results })
}

/// Aggregate a [`RunSet`] into one table row.
pub fn aggregate(set: &RunSet) -> Row {
    let at: Vec<_> = set.results.iter().map(|r| r.at_target()).collect();
    let uploads_k: Vec<f64> = at.iter().map(|p| p.uploads as f64 / 1000.0).collect();
    let up_mb: Vec<f64> = at.iter().map(|p| p.upload_mb).collect();
    let down_mb: Vec<f64> = at.iter().map(|p| p.broadcast_mb).collect();
    let times: Vec<f64> = at.iter().map(|p| p.time).collect();
    let kb_up: Vec<f64> = set.results.iter().map(|r| r.comm.kb_per_upload()).collect();
    let kb_down: Vec<f64> = set.results.iter().map(|r| r.comm.kb_per_download()).collect();
    let finals: Vec<f64> = set.results.iter().map(|r| r.final_accuracy).collect();
    let reached = set.results.iter().filter(|r| r.reached.is_some()).count();
    use crate::util::stats::{mean, std};
    Row {
        label: set.label.clone(),
        uploads_k_mean: mean(&uploads_k),
        uploads_k_std: std(&uploads_k),
        kb_per_upload: mean(&kb_up),
        kb_per_download: mean(&kb_down),
        upload_mb_mean: mean(&up_mb),
        upload_mb_std: std(&up_mb),
        broadcast_mb_mean: mean(&down_mb),
        broadcast_mb_std: std(&down_mb),
        time_mean: mean(&times),
        reached_frac: reached as f64 / set.results.len().max(1) as f64,
        final_acc_mean: mean(&finals),
    }
}

/// Stamp provenance comments (`# config <fingerprint>`, `# git <rev>`)
/// onto an experiment CSV so any result file names the exact resolved
/// config that produced it (ARCHITECTURE.md §Telemetry).
pub fn stamp(csv: &mut CsvWriter, base: &Config) {
    csv.comment(&format!("config {}", crate::telemetry::config_fingerprint(base)));
    if let Some(git) = crate::telemetry::git_describe() {
        csv.comment(&format!("git {git}"));
    }
}

/// Write rows as csv + a paper-style markdown table; returns the markdown.
pub fn report(name: &str, out_dir: &str, base: &Config, rows: &[Row]) -> Result<String> {
    let mut csv = CsvWriter::new(&[
        "label",
        "uploads_k_mean",
        "uploads_k_std",
        "kb_per_upload",
        "kb_per_download",
        "upload_mb_mean",
        "upload_mb_std",
        "broadcast_mb_mean",
        "broadcast_mb_std",
        "time_mean",
        "reached_frac",
        "final_acc_mean",
    ]);
    stamp(&mut csv, base);
    for r in rows {
        csv.row(&[
            r.label.clone(),
            format!("{:.3}", r.uploads_k_mean),
            format!("{:.3}", r.uploads_k_std),
            format!("{:.3}", r.kb_per_upload),
            format!("{:.3}", r.kb_per_download),
            format!("{:.3}", r.upload_mb_mean),
            format!("{:.3}", r.upload_mb_std),
            format!("{:.3}", r.broadcast_mb_mean),
            format!("{:.3}", r.broadcast_mb_std),
            format!("{:.3}", r.time_mean),
            format!("{:.2}", r.reached_frac),
            format!("{:.4}", r.final_acc_mean),
        ]);
    }
    csv.save(format!("{out_dir}/{name}.csv"))?;

    let mut md = String::new();
    md.push_str(&format!("# {name}\n\n"));
    md.push_str("| Algorithm | Uploads (thousands) | kB/upload | kB/download | MB uploaded | MB broadcast | reached |\n");
    md.push_str("|---|---|---|---|---|---|---|\n");
    for r in rows {
        md.push_str(&format!(
            "| {} | {:.1} ± {:.1} | {:.3} | {:.3} | {:.1} ± {:.1} | {:.2} ± {:.2} | {:.0}% |\n",
            r.label,
            r.uploads_k_mean,
            r.uploads_k_std,
            r.kb_per_upload,
            r.kb_per_download,
            r.upload_mb_mean,
            r.upload_mb_std,
            r.broadcast_mb_mean,
            r.broadcast_mb_std,
            r.reached_frac * 100.0
        ));
    }
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(format!("{out_dir}/{name}.md"), &md)?;
    Ok(md)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, Config};
    use crate::runtime::QuadraticBackend;

    pub(crate) fn quick_cfg() -> Config {
        let mut c = Config::default();
        c.fl.algorithm = Algorithm::Qafel;
        c.quant.client = "qsgd:4".into();
        c.quant.server = "qsgd:4".into();
        c.fl.buffer_size = 4;
        c.fl.client_lr = 0.15;
        c.fl.server_lr = 1.0;
        c.fl.server_momentum = 0.0;
        c.fl.clip_norm = 0.0;
        c.sim.concurrency = 10;
        c.sim.eval_every = 5;
        c.seeds = vec![1, 2];
        c.stop.target_accuracy = 0.97;
        c.stop.max_uploads = 5000;
        c.stop.max_server_steps = 1000;
        c
    }

    #[test]
    fn run_and_aggregate() {
        let cfg = quick_cfg();
        let factory = |seed: u64| -> Result<Box<dyn crate::runtime::Backend>> {
            Ok(Box::new(QuadraticBackend::new(16, 8, 1.0, 0.3, 0.2, 0.02, 2, seed)))
        };
        let set = run_seeds(&cfg, &factory, &Default::default(), "qafel 4/4").unwrap();
        assert_eq!(set.results.len(), 2);
        let row = aggregate(&set);
        assert_eq!(row.label, "qafel 4/4");
        assert!(row.uploads_k_mean > 0.0);
        assert!(row.kb_per_upload > 0.0);
        // qsgd:4 at d=16: 4 + 8 = 12 bytes
        assert!((row.kb_per_upload - 0.012).abs() < 1e-9);
    }

    #[test]
    fn report_writes_files() {
        let dir = std::env::temp_dir().join(format!("qafel-report-{}", std::process::id()));
        let dir = dir.to_str().unwrap().to_string();
        let row = Row {
            label: "x".into(),
            uploads_k_mean: 1.0,
            uploads_k_std: 0.1,
            kb_per_upload: 15.0,
            kb_per_download: 15.0,
            upload_mb_mean: 15.0,
            upload_mb_std: 1.0,
            broadcast_mb_mean: 1.5,
            broadcast_mb_std: 0.1,
            time_mean: 3.0,
            reached_frac: 1.0,
            final_acc_mean: 0.92,
        };
        let md = report("unit", &dir, &quick_cfg(), &[row]).unwrap();
        assert!(md.contains("| x |"));
        let csv = std::fs::read_to_string(std::path::Path::new(&dir).join("unit.csv")).unwrap();
        assert!(csv.starts_with("# config "), "missing provenance header: {csv}");
        assert!(std::path::Path::new(&dir).join("unit.csv").exists());
        assert!(std::path::Path::new(&dir).join("unit.md").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
