//! Experiment harness: regenerates every table and figure in the paper's
//! evaluation (DESIGN.md §6 experiment index).
//!
//! | id | paper artifact | module |
//! |----|----------------|--------|
//! | E1 | Figure 3 (concurrency sweep)            | [`fig3`] |
//! | E2 | Figure 4 + Table 1 (qsgd grid)          | [`table1`] |
//! | E3 | Table 2 (biased top_k server)           | [`table2`] |
//! | E4 | Prop. 3.5 order validation              | [`convergence`] |
//! | E5–E7 | hidden-state / K / staleness ablations | [`ablations`] |
//! | E8 | heterogeneous-population ablation       | [`heterogeneity`] |
//! | E9 | robust-aggregation ablation             | [`robustness`] |
//!
//! Each experiment writes `reports/<name>.csv` (raw rows) and
//! `reports/<name>.md` (a paper-style table) and prints the table.

pub mod ablations;
pub mod convergence;
pub mod fig3;
pub mod heterogeneity;
pub mod robustness;
pub mod runner;
pub mod table1;
pub mod table2;

pub use runner::{aggregate, BackendFactory, Row, RunSet};
