//! E8 — heterogeneity ablation: QAFeL vs FedBuff vs DirectQuant under a
//! **slow-tier-dominated population** (scenario engine,
//! DESIGN_SCENARIOS.md).
//!
//! FedBuff (Nguyen et al. 2021) and QuAFL-style analyses agree that
//! async-FL algorithms differentiate under client heterogeneity —
//! slow/fast device tiers, dropouts, constrained links — rather than
//! under the uniform population of the headline figures. This experiment
//! runs the three algorithms over a population where 80% of clients are
//! slow devices with heavy-tailed (log-normal) durations, 2/8 Mbps
//! links and a 10% dropout rate, and reports both the paper-style
//! aggregate table (`heterogeneity.csv/.md`) and the per-tier scenario
//! metrics (`heterogeneity_tiers.csv`: staleness histograms, dropouts,
//! bytes by tier).
//!
//! A fourth **per-tier-codec arm** (scenario engine v2) reruns QAFeL
//! over the same population with the slow tier compressing 10x harder
//! (`quant_client = "top:0.05"`) and salvaging half its dropped work as
//! partial updates (`partial_work = 0.5`); its per-tier rows — codec,
//! partial-upload counts, wasted downlink bytes — land in
//! `heterogeneity_presets.csv`.
//!
//! A fifth **adaptive arm** (ISSUE 9) replaces the hand-picked static
//! presets with the `[scenario.adaptive]` control loop: the same
//! population starts uniform on `quant.client` and the controller walks
//! tiers down a codec ladder to meet a hard uplink budget, discovering
//! the per-tier codecs mid-run. Its per-tier rows (including the
//! `codec_switches` count) land in `heterogeneity_adaptive.csv`.

use super::runner::{aggregate, report, run_seeds, BackendFactory, Row};
use crate::config::{Algorithm, Config, TierConfig};
use crate::metrics::csv::CsvWriter;
use crate::scenario::ScenarioMetrics;
use crate::sim::SimOptions;
use anyhow::Result;

/// The hostile population: 20% fast devices (tight half-normal
/// durations, fat links), 80% slow devices (log-normal durations, thin
/// links, 10% dropout). Staleness and dropped work dominate — exactly
/// the regime where buffered aggregation + bidirectional quantization
/// must not fall over.
pub fn slow_dominated(base: &Config) -> Config {
    let mut cfg = base.clone();
    let mut fast = TierConfig::named("fast");
    fast.weight = 0.2;
    fast.duration_sigma = 0.4;
    fast.upload_mbps = 50.0;
    fast.download_mbps = 200.0;
    let mut slow = TierConfig::named("slow");
    slow.weight = 0.8;
    slow.duration = "lognormal".into();
    slow.duration_sigma = 1.0;
    slow.upload_mbps = 2.0;
    slow.download_mbps = 8.0;
    slow.dropout = 0.10;
    cfg.scenario.tiers = vec![fast, slow];
    cfg
}

/// The per-tier-codec variant of [`slow_dominated`]: the slow tier
/// uploads `top:0.05` (10x smaller than the fast tier's `quant.client`)
/// and submits partial work for half of its dropouts.
///
/// Partial prefixes only exist when `base.fl.local_steps >= 2`, and the
/// backends the caller built must run that same round length —
/// `local_steps` is deliberately **not** bumped here, because the
/// backend factory was already constructed from `base` (a config-only
/// bump would make the scenario engine sample `m/P` fractions of rounds
/// the backend never runs). The quadratic `exp heterogeneity` path
/// raises `local_steps` to 2 *before* building its backends.
pub fn slow_dominated_presets(base: &Config) -> Config {
    let mut cfg = slow_dominated(base);
    let slow = cfg
        .scenario
        .tiers
        .iter_mut()
        .find(|t| t.name == "slow")
        .expect("slow_dominated defines a slow tier");
    slow.quant_client = Some("top:0.05".into());
    slow.partial_work = 0.5;
    cfg
}

/// The adaptive variant of [`slow_dominated`]: no static presets —
/// instead a three-level codec ladder (the base `quant.client`, then
/// `qsgd:2`, then `top:0.05`) under a deliberately unmeetable 1-byte
/// uplink budget, so the controller walks every tier that carries
/// traffic down to the cheapest level at its first scored window. The
/// slow tier (thin 2 Mbps uplink, hence the lowest score) downshifts
/// first — the control loop discovers mid-run what
/// [`slow_dominated_presets`] hard-codes.
pub fn slow_dominated_adaptive(base: &Config) -> Config {
    let mut cfg = slow_dominated(base);
    cfg.scenario.adaptive.enabled = true;
    cfg.scenario.adaptive.interval = 10;
    cfg.scenario.adaptive.budget_bytes_per_step = 1;
    cfg.scenario.adaptive.levels =
        vec![base.quant.client.clone(), "qsgd:2".into(), "top:0.05".into()];
    cfg.scenario.adaptive.min_uploads = 1;
    cfg
}

const TIER_COLUMNS: [&str; 19] = [
    "algorithm",
    "seed",
    "tier",
    "codec",
    "codec_switches",
    "arrivals",
    "unavailable",
    "dropouts",
    "uploads",
    "partial_uploads",
    "upload_mb",
    "download_mb",
    "wasted_download_mb",
    "staleness_mean",
    "staleness_max",
    "staleness_hist",
    "mean_concurrency",
    "max_live_snapshots",
    "arrivals_all_off",
];

/// Run the ablation. Returns the aggregate rows (qafel, fedbuff,
/// directquant, qafel+presets, qafel+adaptive) and writes
/// `heterogeneity.{csv,md}` plus the per-tier `heterogeneity_tiers.csv`
/// and — for the per-tier-codec and adaptive arms —
/// `heterogeneity_presets.csv` / `heterogeneity_adaptive.csv` under
/// `out_dir`.
pub fn run(
    base: &Config,
    make_backend: &BackendFactory,
    out_dir: &str,
    opts: &SimOptions,
) -> Result<Vec<Row>> {
    let cfg0 = slow_dominated(base);
    let mut rows = Vec::new();
    let mut tiers_csv = CsvWriter::new(&TIER_COLUMNS);
    for (label, algo) in [
        ("qafel", Algorithm::Qafel),
        ("fedbuff", Algorithm::FedBuff),
        ("directquant", Algorithm::DirectQuant),
    ] {
        let mut cfg = cfg0.clone();
        cfg.fl.algorithm = algo;
        let set = run_seeds(&cfg, make_backend, opts, label)?;
        for (result, &seed) in set.results.iter().zip(&cfg.seeds) {
            tier_rows(&mut tiers_csv, label, seed, &result.scenario);
        }
        rows.push(aggregate(&set));
    }

    // per-tier-codec arm: same population, slow tier on its own codec
    // with partial-work salvage. Pin the algorithm like the arms above:
    // the label says qafel, so the run must be qafel no matter what the
    // base config carries (presets resolve to identity under fedbuff).
    let mut cfg_presets = slow_dominated_presets(base);
    cfg_presets.fl.algorithm = Algorithm::Qafel;
    let mut presets_csv = CsvWriter::new(&TIER_COLUMNS);
    let set = run_seeds(&cfg_presets, make_backend, opts, "qafel+presets")?;
    for (result, &seed) in set.results.iter().zip(&cfg_presets.seeds) {
        tier_rows(&mut presets_csv, "qafel+presets", seed, &result.scenario);
    }
    rows.push(aggregate(&set));

    // adaptive-controller arm (ISSUE 9): the same population under a
    // codec ladder and a hard uplink budget instead of static presets —
    // the control loop discovers the per-tier codecs mid-run, and the
    // codec_switches column records how often it re-keyed each tier.
    let mut cfg_adaptive = slow_dominated_adaptive(base);
    cfg_adaptive.fl.algorithm = Algorithm::Qafel;
    let mut adaptive_csv = CsvWriter::new(&TIER_COLUMNS);
    let set = run_seeds(&cfg_adaptive, make_backend, opts, "qafel+adaptive")?;
    for (result, &seed) in set.results.iter().zip(&cfg_adaptive.seeds) {
        tier_rows(&mut adaptive_csv, "qafel+adaptive", seed, &result.scenario);
    }
    rows.push(aggregate(&set));

    let md = report("heterogeneity", out_dir, base, &rows)?;
    println!("{md}");
    super::runner::stamp(&mut tiers_csv, base);
    super::runner::stamp(&mut presets_csv, base);
    super::runner::stamp(&mut adaptive_csv, base);
    tiers_csv.save(format!("{out_dir}/heterogeneity_tiers.csv"))?;
    presets_csv.save(format!("{out_dir}/heterogeneity_presets.csv"))?;
    adaptive_csv.save(format!("{out_dir}/heterogeneity_adaptive.csv"))?;
    Ok(rows)
}

/// Flatten one run's per-tier metrics into CSV rows.
fn tier_rows(csv: &mut CsvWriter, label: &str, seed: u64, m: &ScenarioMetrics) {
    for t in &m.tiers {
        csv.row(&[
            label.to_string(),
            seed.to_string(),
            t.name.clone(),
            t.codec.clone(),
            t.codec_switches.to_string(),
            t.arrivals.to_string(),
            t.unavailable.to_string(),
            t.dropouts.to_string(),
            t.uploads.to_string(),
            t.partial_uploads.to_string(),
            format!("{:.4}", t.upload_bytes as f64 / 1e6),
            format!("{:.4}", t.download_bytes as f64 / 1e6),
            format!("{:.4}", t.wasted_download_bytes as f64 / 1e6),
            format!("{:.3}", t.staleness.mean()),
            t.staleness.max.to_string(),
            t.staleness.spec_string(),
            format!("{:.2}", m.mean_concurrency),
            m.max_live_snapshots.to_string(),
            m.arrivals_all_off.to_string(),
        ]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::QuadraticBackend;

    fn base() -> Config {
        let mut c = Config::default();
        c.fl.algorithm = Algorithm::Qafel;
        c.quant.client = "qsgd:4".into();
        c.quant.server = "qsgd:4".into();
        c.fl.buffer_size = 4;
        c.fl.client_lr = 0.15;
        c.fl.server_lr = 1.0;
        c.fl.server_momentum = 0.0;
        c.fl.clip_norm = 0.0;
        // matches the factory's QuadraticBackend round length — the
        // presets arm samples m-of-P partial prefixes against it
        c.fl.local_steps = 2;
        c.sim.concurrency = 10;
        c.sim.eval_every = 10;
        c.seeds = vec![1];
        c.stop.target_accuracy = 2.0; // fixed horizon
        c.stop.max_uploads = 3000;
        c.stop.max_server_steps = 150;
        c
    }

    fn factory(seed: u64) -> Result<Box<dyn crate::runtime::Backend>> {
        Ok(Box::new(QuadraticBackend::new(64, 10, 1.0, 0.3, 0.2, 0.02, 2, seed)))
    }

    #[test]
    fn heterogeneity_runs_and_writes_tier_metrics() {
        let dir = std::env::temp_dir().join(format!("qafel-het-{}", std::process::id()));
        let dir_s = dir.to_str().unwrap().to_string();
        let cfg = base();
        cfg.validate().unwrap();
        let rows = run(&cfg, &factory, &dir_s, &Default::default()).unwrap();
        assert_eq!(rows.len(), 5);
        for r in &rows {
            assert!(r.uploads_k_mean > 0.0, "{} ran no uploads", r.label);
        }
        // quantized uploads are smaller than fedbuff's full precision
        let (qafel, fedbuff) = (&rows[0], &rows[1]);
        assert!(
            qafel.kb_per_upload < fedbuff.kb_per_upload / 4.0,
            "qafel {} vs fedbuff {}",
            qafel.kb_per_upload,
            fedbuff.kb_per_upload
        );
        // the per-tier-codec arm compresses the (dominant) slow tier a
        // further 10x, so its mean upload shrinks again
        let presets = &rows[3];
        assert_eq!(presets.label, "qafel+presets");
        assert!(
            presets.kb_per_upload < qafel.kb_per_upload,
            "presets {} vs uniform {}",
            presets.kb_per_upload,
            qafel.kb_per_upload
        );
        // per-tier csv: header + 3 algorithms x 1 seed x 2 tiers
        // (provenance '# config'/'# git' comments filtered out)
        let text =
            std::fs::read_to_string(dir.join("heterogeneity_tiers.csv")).unwrap();
        let lines: Vec<&str> = text.lines().filter(|l| !l.starts_with('#')).collect();
        assert_eq!(lines.len(), 1 + 3 * 2, "{text}");
        assert!(lines[0].starts_with("algorithm,seed,tier,codec"));
        assert!(text.contains("fast") && text.contains("slow"));
        // presets csv: header + 1 arm x 1 seed x 2 tiers, tiers tagged
        // with their own codecs and the slow tier salvaging partials
        let text =
            std::fs::read_to_string(dir.join("heterogeneity_presets.csv")).unwrap();
        let lines: Vec<&str> = text.lines().filter(|l| !l.starts_with('#')).collect();
        assert_eq!(lines.len(), 1 + 2, "{text}");
        assert!(text.contains("top:0.05") && text.contains("qsgd:4"), "{text}");
        let slow_line = lines.iter().find(|l| l.contains(",slow,")).unwrap();
        let fields: Vec<&str> = slow_line.split(',').collect();
        let partials: u64 = fields[9].parse().unwrap();
        assert!(partials > 0, "no partial uploads recorded: {slow_line}");
        // adaptive arm: the controller discovers codecs mid-run, and on
        // the bytes axis it strictly beats the uniform static arm
        // (accuracy-vs-uplink Pareto under the 80%-slow population)
        let adaptive = &rows[4];
        assert_eq!(adaptive.label, "qafel+adaptive");
        assert!(
            adaptive.kb_per_upload < qafel.kb_per_upload,
            "adaptive {} vs uniform {}",
            adaptive.kb_per_upload,
            qafel.kb_per_upload
        );
        // adaptive csv: header + 1 arm x 1 seed x 2 tiers; the slow tier
        // was rekeyed onto the bottom ladder level
        let text =
            std::fs::read_to_string(dir.join("heterogeneity_adaptive.csv")).unwrap();
        let lines: Vec<&str> = text.lines().filter(|l| !l.starts_with('#')).collect();
        assert_eq!(lines.len(), 1 + 2, "{text}");
        assert!(lines[0].starts_with("algorithm,seed,tier,codec,codec_switches"));
        let slow_line = lines.iter().find(|l| l.contains(",slow,")).unwrap();
        let fields: Vec<&str> = slow_line.split(',').collect();
        let switches: u64 = fields[4].parse().unwrap();
        assert!(switches >= 1, "slow tier never rekeyed: {slow_line}");
        // the cheapest ladder level by wire size is qsgd:2 (top:0.05
        // pays 8 bytes per kept coordinate), so that's the bottom
        assert!(fields[3].starts_with("qsgd:2"), "slow codec: {slow_line}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn slow_dominated_population_is_valid_and_slower() {
        let cfg = slow_dominated(&base());
        cfg.validate().unwrap();
        assert_eq!(cfg.scenario.tiers.len(), 2);
        assert!(cfg.scenario.tiers[1].dropout > 0.0);
        // the mix must be slow-dominated by weight
        assert!(cfg.scenario.tiers[1].weight > 2.0 * cfg.scenario.tiers[0].weight);
    }

    #[test]
    fn presets_population_is_valid_and_heterogeneous() {
        let cfg = slow_dominated_presets(&base());
        cfg.validate().unwrap();
        assert!(cfg.fl.local_steps >= 2, "partial work needs P >= 2");
        let slow = cfg.scenario.tiers.iter().find(|t| t.name == "slow").unwrap();
        assert_eq!(slow.quant_client.as_deref(), Some("top:0.05"));
        assert_eq!(slow.partial_work, 0.5);
        let fast = cfg.scenario.tiers.iter().find(|t| t.name == "fast").unwrap();
        assert_eq!(fast.quant_client, None, "fast tier inherits quant.client");
    }

    #[test]
    fn adaptive_population_is_valid_and_budgeted() {
        let cfg = slow_dominated_adaptive(&base());
        cfg.validate().unwrap();
        let a = &cfg.scenario.adaptive;
        assert!(a.enabled);
        assert_eq!(a.levels.len(), 3);
        assert_eq!(a.levels[0], cfg.quant.client, "ladder starts at the default");
        assert_eq!(a.budget_bytes_per_step, 1, "unmeetable: every tier downshifts");
        // no static presets: the controller, not the config, picks codecs
        assert!(cfg.scenario.tiers.iter().all(|t| t.quant_client.is_none()));
    }
}
