//! E8 — heterogeneity ablation: QAFeL vs FedBuff vs DirectQuant under a
//! **slow-tier-dominated population** (scenario engine,
//! DESIGN_SCENARIOS.md).
//!
//! FedBuff (Nguyen et al. 2021) and QuAFL-style analyses agree that
//! async-FL algorithms differentiate under client heterogeneity —
//! slow/fast device tiers, dropouts, constrained links — rather than
//! under the uniform population of the headline figures. This experiment
//! runs the three algorithms over a population where 80% of clients are
//! slow devices with heavy-tailed (log-normal) durations, 2/8 Mbps
//! links and a 10% dropout rate, and reports both the paper-style
//! aggregate table (`heterogeneity.csv/.md`) and the per-tier scenario
//! metrics (`heterogeneity_tiers.csv`: staleness histograms, dropouts,
//! bytes by tier).

use super::runner::{aggregate, report, run_seeds, BackendFactory, Row};
use crate::config::{Algorithm, Config, TierConfig};
use crate::metrics::csv::CsvWriter;
use crate::scenario::ScenarioMetrics;
use crate::sim::SimOptions;
use anyhow::Result;

/// The hostile population: 20% fast devices (tight half-normal
/// durations, fat links), 80% slow devices (log-normal durations, thin
/// links, 10% dropout). Staleness and dropped work dominate — exactly
/// the regime where buffered aggregation + bidirectional quantization
/// must not fall over.
pub fn slow_dominated(base: &Config) -> Config {
    let mut cfg = base.clone();
    let mut fast = TierConfig::named("fast");
    fast.weight = 0.2;
    fast.duration_sigma = 0.4;
    fast.upload_mbps = 50.0;
    fast.download_mbps = 200.0;
    let mut slow = TierConfig::named("slow");
    slow.weight = 0.8;
    slow.duration = "lognormal".into();
    slow.duration_sigma = 1.0;
    slow.upload_mbps = 2.0;
    slow.download_mbps = 8.0;
    slow.dropout = 0.10;
    cfg.scenario.tiers = vec![fast, slow];
    cfg
}

/// Run the ablation. Returns the aggregate rows (qafel, fedbuff,
/// directquant) and writes `heterogeneity.{csv,md}` plus
/// `heterogeneity_tiers.csv` under `out_dir`.
pub fn run(
    base: &Config,
    make_backend: &BackendFactory,
    out_dir: &str,
    opts: &SimOptions,
) -> Result<Vec<Row>> {
    let cfg0 = slow_dominated(base);
    let mut rows = Vec::new();
    let mut tiers_csv = CsvWriter::new(&[
        "algorithm",
        "seed",
        "tier",
        "arrivals",
        "unavailable",
        "dropouts",
        "uploads",
        "upload_mb",
        "download_mb",
        "staleness_mean",
        "staleness_max",
        "staleness_hist",
        "mean_concurrency",
        "max_live_snapshots",
    ]);
    for (label, algo) in [
        ("qafel", Algorithm::Qafel),
        ("fedbuff", Algorithm::FedBuff),
        ("directquant", Algorithm::DirectQuant),
    ] {
        let mut cfg = cfg0.clone();
        cfg.fl.algorithm = algo;
        let set = run_seeds(&cfg, make_backend, opts, label)?;
        for (result, &seed) in set.results.iter().zip(&cfg.seeds) {
            tier_rows(&mut tiers_csv, label, seed, &result.scenario);
        }
        rows.push(aggregate(&set));
    }
    let md = report("heterogeneity", out_dir, &rows)?;
    println!("{md}");
    tiers_csv.save(format!("{out_dir}/heterogeneity_tiers.csv"))?;
    Ok(rows)
}

/// Flatten one run's per-tier metrics into CSV rows.
fn tier_rows(csv: &mut CsvWriter, label: &str, seed: u64, m: &ScenarioMetrics) {
    for t in &m.tiers {
        csv.row(&[
            label.to_string(),
            seed.to_string(),
            t.name.clone(),
            t.arrivals.to_string(),
            t.unavailable.to_string(),
            t.dropouts.to_string(),
            t.uploads.to_string(),
            format!("{:.4}", t.upload_bytes as f64 / 1e6),
            format!("{:.4}", t.download_bytes as f64 / 1e6),
            format!("{:.3}", t.staleness.mean()),
            t.staleness.max.to_string(),
            t.staleness.spec_string(),
            format!("{:.2}", m.mean_concurrency),
            m.max_live_snapshots.to_string(),
        ]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::QuadraticBackend;

    fn base() -> Config {
        let mut c = Config::default();
        c.fl.algorithm = Algorithm::Qafel;
        c.quant.client = "qsgd:4".into();
        c.quant.server = "qsgd:4".into();
        c.fl.buffer_size = 4;
        c.fl.client_lr = 0.15;
        c.fl.server_lr = 1.0;
        c.fl.server_momentum = 0.0;
        c.fl.clip_norm = 0.0;
        c.sim.concurrency = 10;
        c.sim.eval_every = 10;
        c.seeds = vec![1];
        c.stop.target_accuracy = 2.0; // fixed horizon
        c.stop.max_uploads = 3000;
        c.stop.max_server_steps = 150;
        c
    }

    fn factory(seed: u64) -> Result<Box<dyn crate::runtime::Backend>> {
        Ok(Box::new(QuadraticBackend::new(64, 10, 1.0, 0.3, 0.2, 0.02, 2, seed)))
    }

    #[test]
    fn heterogeneity_runs_and_writes_tier_metrics() {
        let dir = std::env::temp_dir().join(format!("qafel-het-{}", std::process::id()));
        let dir_s = dir.to_str().unwrap().to_string();
        let cfg = base();
        cfg.validate().unwrap();
        let rows = run(&cfg, &factory, &dir_s, &Default::default()).unwrap();
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert!(r.uploads_k_mean > 0.0, "{} ran no uploads", r.label);
        }
        // quantized uploads are smaller than fedbuff's full precision
        let (qafel, fedbuff) = (&rows[0], &rows[1]);
        assert!(
            qafel.kb_per_upload < fedbuff.kb_per_upload / 4.0,
            "qafel {} vs fedbuff {}",
            qafel.kb_per_upload,
            fedbuff.kb_per_upload
        );
        // per-tier csv: header + 3 algorithms x 1 seed x 2 tiers
        let text =
            std::fs::read_to_string(dir.join("heterogeneity_tiers.csv")).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 1 + 3 * 2, "{text}");
        assert!(lines[0].starts_with("algorithm,seed,tier"));
        assert!(text.contains("fast") && text.contains("slow"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn slow_dominated_population_is_valid_and_slower() {
        let cfg = slow_dominated(&base());
        cfg.validate().unwrap();
        assert_eq!(cfg.scenario.tiers.len(), 2);
        assert!(cfg.scenario.tiers[1].dropout > 0.0);
        // the mix must be slow-dominated by weight
        assert!(cfg.scenario.tiers[1].weight > 2.0 * cfg.scenario.tiers[0].weight);
    }
}
