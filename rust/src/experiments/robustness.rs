//! E9 — robustness ablation: QAFeL under Byzantine and heavy-tailed
//! client populations, with and without robust aggregation (ISSUE 10,
//! DESIGN_SCENARIOS.md §Adversaries).
//!
//! The grid crosses three aggregation rules with four populations:
//!
//! * aggregation — `mean` (plain QAFeL buffer average), `clip`
//!   (per-update norm bounding, `[fl.robust] clip_norm`), `trim`
//!   (coordinate-wise trimmed mean over the buffer, `trim_frac`);
//! * population — `honest` (every tier clean), `heavy_tail` (30% of
//!   arrivals add Student-t(2) gradient noise), `sign_flip` (30% upload
//!   negated deltas), `scaled_garbage` (30% upload 50x-scaled deltas —
//!   the classic large-norm Byzantine attack).
//!
//! The headline table (`robustness.csv/.md`) reports the usual
//! uploads/bytes/accuracy aggregates per arm; `robustness_tiers.csv`
//! adds the per-tier forensics — which tier was hostile, how many of its
//! updates the server clipped, and how many the trimmed mean excluded.
//! The expected shape: the plain mean degrades under every attack
//! (catastrophically under `scaled_garbage`), clipping restores the
//! norm-bounded attacks, and the trimmed mean restores `sign_flip`,
//! which clipping cannot touch (flipping preserves the norm).

use super::runner::{aggregate, report, run_seeds, BackendFactory, Row};
use crate::config::{Algorithm, Config, TierConfig};
use crate::metrics::csv::CsvWriter;
use crate::scenario::ScenarioMetrics;
use crate::sim::SimOptions;
use anyhow::Result;

/// The aggregation rules under ablation.
const RULES: [&str; 3] = ["mean", "clip", "trim"];

/// The attack populations.
const ATTACKS: [&str; 4] = ["honest", "heavy_tail", "sign_flip", "scaled_garbage"];

/// Fraction of arrivals owned by the hostile tier.
const HOSTILE_WEIGHT: f64 = 0.3;

/// Two-tier population for one attack: a 70% honest `good` tier and a
/// 30% `bad` tier running the named attack (`honest` leaves the bad
/// tier clean, so the split itself is identical across arms and only
/// the hostile knob varies).
pub fn attack_population(base: &Config, attack: &str) -> Config {
    let mut cfg = base.clone();
    cfg.fl.algorithm = Algorithm::Qafel;
    let mut good = TierConfig::named("good");
    good.weight = 1.0 - HOSTILE_WEIGHT;
    let mut bad = TierConfig::named("bad");
    bad.weight = HOSTILE_WEIGHT;
    match attack {
        "honest" => {}
        "heavy_tail" => bad.grad_noise = Some("student_t:2:0.5".into()),
        "sign_flip" => bad.adversary = Some("sign_flip".into()),
        "scaled_garbage" => bad.adversary = Some("scale:50".into()),
        other => panic!("unknown attack '{other}'"),
    }
    cfg.scenario.tiers = vec![good, bad];
    cfg
}

/// Apply one aggregation rule to a population config. `clip_norm = 1.0`
/// bounds every update to unit norm (a uniform shrink on honest
/// updates, a 50-245x shrink on the garbage); `trim_frac = 0.4` over
/// the K=5 buffer keeps the per-coordinate median.
pub fn with_rule(cfg: &Config, rule: &str) -> Config {
    let mut c = cfg.clone();
    match rule {
        "mean" => c.fl.robust.enabled = false,
        "clip" => {
            c.fl.robust.enabled = true;
            c.fl.robust.clip_norm = 1.0;
        }
        "trim" => {
            c.fl.robust.enabled = true;
            c.fl.robust.trim_frac = 0.4;
        }
        other => panic!("unknown rule '{other}'"),
    }
    c
}

const TIER_COLUMNS: [&str; 15] = [
    "rule",
    "attack",
    "seed",
    "tier",
    "grad_noise",
    "adversary",
    "arrivals",
    "uploads",
    "clipped_updates",
    "trimmed_updates",
    "upload_mb",
    "download_mb",
    "staleness_mean",
    "staleness_max",
    "staleness_hist",
];

/// Run the full rule x attack grid. Returns the 12 aggregate rows (in
/// RULES-major order) and writes `robustness.{csv,md}` plus the
/// per-tier `robustness_tiers.csv` under `out_dir`.
pub fn run(
    base: &Config,
    make_backend: &BackendFactory,
    out_dir: &str,
    opts: &SimOptions,
) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    let mut tiers_csv = CsvWriter::new(&TIER_COLUMNS);
    for rule in RULES {
        for attack in ATTACKS {
            let cfg = with_rule(&attack_population(base, attack), rule);
            cfg.validate()?;
            let label = format!("qafel {rule} {attack}");
            let set = run_seeds(&cfg, make_backend, opts, &label)?;
            for (result, &seed) in set.results.iter().zip(&cfg.seeds) {
                tier_rows(&mut tiers_csv, rule, attack, seed, &result.scenario);
            }
            rows.push(aggregate(&set));
        }
    }
    let md = report("robustness", out_dir, base, &rows)?;
    println!("{md}");
    for f in findings(&rows) {
        println!("{f}");
    }
    super::runner::stamp(&mut tiers_csv, base);
    tiers_csv.save(format!("{out_dir}/robustness_tiers.csv"))?;
    Ok(rows)
}

/// Look up one grid cell by rule and attack.
fn cell<'a>(rows: &'a [Row], rule: &str, attack: &str) -> &'a Row {
    let label = format!("qafel {rule} {attack}");
    rows.iter().find(|r| r.label == label).unwrap_or_else(|| panic!("missing arm {label}"))
}

/// Human-readable takeaways printed after the table.
pub fn findings(rows: &[Row]) -> Vec<String> {
    let acc = |rule: &str, attack: &str| cell(rows, rule, attack).final_acc_mean;
    vec![
        format!(
            "scaled_garbage: plain mean acc {:.4} vs clip {:.4} (norm bounding contains \
             large-norm Byzantine updates)",
            acc("mean", "scaled_garbage"),
            acc("clip", "scaled_garbage"),
        ),
        format!(
            "sign_flip: plain mean acc {:.4} vs trimmed mean {:.4} (coordinate-wise \
             trimming excludes norm-preserving flips that clipping cannot touch)",
            acc("mean", "sign_flip"),
            acc("trim", "sign_flip"),
        ),
        format!(
            "heavy_tail: plain mean acc {:.4} vs clip {:.4} vs trim {:.4}",
            acc("mean", "heavy_tail"),
            acc("clip", "heavy_tail"),
            acc("trim", "heavy_tail"),
        ),
        format!(
            "honest baseline: mean {:.4}, clip {:.4}, trim {:.4} (robustness is \
             near-free when nobody attacks)",
            acc("mean", "honest"),
            acc("clip", "honest"),
            acc("trim", "honest"),
        ),
    ]
}

/// Flatten one run's per-tier metrics into CSV rows.
fn tier_rows(csv: &mut CsvWriter, rule: &str, attack: &str, seed: u64, m: &ScenarioMetrics) {
    for t in &m.tiers {
        csv.row(&[
            rule.to_string(),
            attack.to_string(),
            seed.to_string(),
            t.name.clone(),
            t.grad_noise.clone(),
            t.adversary.clone(),
            t.arrivals.to_string(),
            t.uploads.to_string(),
            t.clipped_updates.to_string(),
            t.trimmed_updates.to_string(),
            format!("{:.4}", t.upload_bytes as f64 / 1e6),
            format!("{:.4}", t.download_bytes as f64 / 1e6),
            format!("{:.3}", t.staleness.mean()),
            t.staleness.max.to_string(),
            t.staleness.spec_string(),
        ]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::QuadraticBackend;

    fn base() -> Config {
        let mut c = Config::default();
        c.fl.algorithm = Algorithm::Qafel;
        c.quant.client = "qsgd:4".into();
        c.quant.server = "qsgd:4".into();
        c.fl.buffer_size = 5; // trim_frac 0.4 -> per-coordinate median
        c.fl.client_lr = 0.15;
        c.fl.server_lr = 1.0;
        c.fl.server_momentum = 0.0;
        c.fl.clip_norm = 0.0;
        c.sim.concurrency = 10;
        c.sim.eval_every = 10;
        c.seeds = vec![52];
        c.stop.target_accuracy = 2.0; // fixed horizon
        c.stop.max_uploads = 100_000;
        c.stop.max_server_steps = 120;
        c
    }

    fn factory(seed: u64) -> Result<Box<dyn crate::runtime::Backend>> {
        Ok(Box::new(QuadraticBackend::new(64, 10, 1.0, 0.3, 0.2, 0.02, 2, seed)))
    }

    #[test]
    fn robustness_grid_runs_and_defends() {
        let dir = std::env::temp_dir().join(format!("qafel-robust-{}", std::process::id()));
        let dir_s = dir.to_str().unwrap().to_string();
        let cfg = base();
        cfg.validate().unwrap();
        let rows = run(&cfg, &factory, &dir_s, &Default::default()).unwrap();
        assert_eq!(rows.len(), RULES.len() * ATTACKS.len());
        for r in &rows {
            assert!(r.uploads_k_mean > 0.0, "{} ran no uploads", r.label);
        }
        let acc = |rule: &str, attack: &str| cell(&rows, rule, attack).final_acc_mean;
        // the large-norm attack wrecks the plain mean; clipping contains it
        assert!(
            acc("mean", "scaled_garbage") < acc("mean", "honest"),
            "scaled garbage did not degrade the mean"
        );
        assert!(
            acc("clip", "scaled_garbage") > acc("mean", "scaled_garbage"),
            "clip {:.4} did not beat mean {:.4} under scaled_garbage",
            acc("clip", "scaled_garbage"),
            acc("mean", "scaled_garbage"),
        );
        // sign flips preserve the norm, so only trimming excludes them
        assert!(
            acc("trim", "sign_flip") > acc("mean", "sign_flip"),
            "trim {:.4} did not beat mean {:.4} under sign_flip",
            acc("trim", "sign_flip"),
            acc("mean", "sign_flip"),
        );
        // per-tier forensics: the bad tier shows up in the robust counters
        let text = std::fs::read_to_string(dir.join("robustness_tiers.csv")).unwrap();
        let lines: Vec<&str> = text.lines().filter(|l| !l.starts_with('#')).collect();
        // header + 12 arms x 1 seed x 2 tiers
        assert_eq!(lines.len(), 1 + 12 * 2, "{text}");
        assert!(lines[0].starts_with("rule,attack,seed,tier,grad_noise,adversary"));
        let field = |line: &str, i: usize| line.split(',').nth(i).unwrap().to_string();
        let bad = |rule: &str, attack: &str| {
            lines
                .iter()
                .find(|l| l.starts_with(&format!("{rule},{attack},")) && l.contains(",bad,"))
                .copied()
                .unwrap_or_else(|| panic!("missing bad-tier row for {rule}/{attack}"))
                .to_string()
        };
        let clip_garbage = bad("clip", "scaled_garbage");
        assert_eq!(field(&clip_garbage, 5), "scale:50");
        assert!(field(&clip_garbage, 8).parse::<u64>().unwrap() > 0, "{clip_garbage}");
        let trim_flip = bad("trim", "sign_flip");
        assert_eq!(field(&trim_flip, 5), "sign_flip");
        assert!(field(&trim_flip, 9).parse::<u64>().unwrap() > 0, "{trim_flip}");
        let mean_flip = bad("mean", "sign_flip");
        assert_eq!(field(&mean_flip, 8), "0", "{mean_flip}");
        assert_eq!(field(&mean_flip, 9), "0", "{mean_flip}");
        let heavy = bad("mean", "heavy_tail");
        assert_eq!(field(&heavy, 4), "student_t:2:0.5");
        // headline files landed
        assert!(dir.join("robustness.csv").exists());
        assert!(dir.join("robustness.md").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn populations_and_rules_are_valid() {
        for attack in ATTACKS {
            for rule in RULES {
                let cfg = with_rule(&attack_population(&base(), attack), rule);
                cfg.validate().unwrap_or_else(|e| panic!("{rule}/{attack}: {e}"));
            }
        }
        let flip = attack_population(&base(), "sign_flip");
        assert_eq!(flip.scenario.tiers[1].adversary.as_deref(), Some("sign_flip"));
        assert_eq!(flip.scenario.tiers[0].adversary, None);
        let trim = with_rule(&flip, "trim");
        assert!(trim.fl.robust.enabled && trim.fl.robust.trim_frac == 0.4);
        let mean = with_rule(&flip, "mean");
        assert!(!mean.fl.robust.enabled);
    }
}
