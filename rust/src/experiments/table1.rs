//! E2 — Table 1 / Figure 4: QAFeL with every combination of client and
//! server n-bit qsgd in {8, 4, 2}, plus the FedBuff reference row.
//!
//! Paper's qualitative findings this regenerates:
//! * fewer server bits => always fewer total download bytes;
//! * fewer client bits => sometimes MORE uploads (2-bit client needs up
//!   to ~3x the trips) — the compression/convergence-speed trade-off;
//! * the client quantizer affects convergence much more than the server
//!   quantizer (consistent with the 1/sqrt(T) vs 1/T error orders).

use super::runner::{aggregate, report, run_seeds, BackendFactory, Row};
use crate::config::{Algorithm, Config};
use crate::sim::SimOptions;
use anyhow::Result;

pub const BITS: [u32; 3] = [8, 4, 2];

pub fn run(
    base: &Config,
    make_backend: &BackendFactory,
    out_dir: &str,
    opts: &SimOptions,
) -> Result<Vec<Row>> {
    let mut rows = Vec::new();

    // FedBuff reference row
    let mut cfg = base.clone();
    cfg.fl.algorithm = Algorithm::FedBuff;
    let set = run_seeds(&cfg, make_backend, opts, "fedbuff")?;
    rows.push(aggregate(&set));

    for &cb in &BITS {
        for &sb in &BITS {
            let mut cfg = base.clone();
            cfg.fl.algorithm = Algorithm::Qafel;
            cfg.quant.client = format!("qsgd:{cb}");
            cfg.quant.server = format!("qsgd:{sb}");
            let label = format!("qafel c{cb}-bit s{sb}-bit");
            let set = run_seeds(&cfg, make_backend, opts, &label)?;
            rows.push(aggregate(&set));
        }
    }
    let md = report("table1", out_dir, base, &rows)?;
    println!("{md}");
    Ok(rows)
}

/// Index helper for the 1 + 3x3 row layout produced by [`run`].
pub fn row_for<'a>(rows: &'a [Row], client_bits: u32, server_bits: u32) -> &'a Row {
    let ci = BITS.iter().position(|&b| b == client_bits).unwrap();
    let si = BITS.iter().position(|&b| b == server_bits).unwrap();
    &rows[1 + ci * BITS.len() + si]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::QuadraticBackend;

    #[test]
    fn table1_grid_shape_on_quadratic_backend() {
        let mut base = Config::default();
        base.fl.buffer_size = 4;
        base.fl.client_lr = 0.15;
        base.fl.server_lr = 1.0;
        base.fl.server_momentum = 0.0;
        base.fl.clip_norm = 0.0;
        base.sim.concurrency = 10;
        base.sim.eval_every = 5;
        base.seeds = vec![1, 2, 3];
        base.stop.target_accuracy = 0.95;
        base.stop.max_uploads = 20_000;
        base.stop.max_server_steps = 5000;

        let factory = |seed: u64| -> Result<Box<dyn crate::runtime::Backend>> {
            Ok(Box::new(QuadraticBackend::new(128, 10, 1.0, 0.3, 0.2, 0.02, 2, seed)))
        };
        let dir = std::env::temp_dir().join(format!("qafel-t1-{}", std::process::id()));
        let rows = run(&base, &factory, dir.to_str().unwrap(), &Default::default()).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        assert_eq!(rows.len(), 10);

        // per-message sizes ordered: 8-bit > 4-bit > 2-bit, fedbuff largest
        assert!(rows[0].kb_per_upload > row_for(&rows, 8, 8).kb_per_upload);
        assert!(row_for(&rows, 8, 8).kb_per_upload > row_for(&rows, 4, 8).kb_per_upload);
        assert!(row_for(&rows, 4, 8).kb_per_upload > row_for(&rows, 2, 8).kb_per_upload);
        // server bits only affect download size
        assert!(row_for(&rows, 4, 8).kb_per_download > row_for(&rows, 4, 2).kb_per_download);
        assert_eq!(row_for(&rows, 4, 8).kb_per_upload, row_for(&rows, 4, 2).kb_per_upload);
        // paper finding: 2-bit client needs more trips / converges slower.
        // On the quadratic worst case the 2-bit client's quantization
        // noise floor can sit above the target at fixed lr (the lr
        // condition (8) scales with (1-delta_c)), so assert the ordering
        // (at_target falls back to the end-of-run point when unreached):
        let trips_2 = row_for(&rows, 2, 4).uploads_k_mean;
        let trips_8 = row_for(&rows, 8, 4).uploads_k_mean;
        assert!(trips_2 >= trips_8 * 0.9, "2-bit {trips_2} vs 8-bit {trips_8}");
        assert!(
            row_for(&rows, 2, 8).final_acc_mean
                <= row_for(&rows, 8, 8).final_acc_mean + 0.02,
            "2-bit client unexpectedly beat 8-bit"
        );
        // configs inside the paper's convergence condition reach target.
        // (2-bit qsgd at this dimension has delta <= 0 — sqrt(2d)/s > 1 —
        // outside Definition 2.1's contraction; on the gaussian-diff
        // quadratic backend those rows may legitimately miss the target.
        // Theorem F.1 itself requires delta_s > 0.)
        for &cb in &[8u32, 4] {
            for &sb in &[8u32, 4] {
                let r = row_for(&rows, cb, sb);
                assert!(r.reached_frac >= 0.5, "{} reached {}", r.label, r.reached_frac);
            }
        }
    }
}
