//! E1 — Figure 3: communication metrics to reach the target validation
//! accuracy for concurrency 100 / 500 / 1000, QAFeL (4-bit qsgd both
//! directions) vs FedBuff.
//!
//! Paper setup (Appendix D): K = 10, staleness-scaled server learning
//! rate (weight 1/sqrt(1+tau)), arrival rates 125/627/1253 derived from
//! the half-normal duration's mean. Expected shape: QAFeL uploads count
//! 1–1.5x FedBuff's, MB uploaded 5.2–8x *lower*, MB broadcast lower by a
//! further factor K.

use super::runner::{aggregate, report, run_seeds, BackendFactory, Row};
use crate::config::{Algorithm, Config};
use crate::sim::SimOptions;
use anyhow::Result;

/// Concurrency values from the paper.
pub const CONCURRENCIES: [usize; 3] = [100, 500, 1000];

pub fn run(
    base: &Config,
    make_backend: &BackendFactory,
    out_dir: &str,
    opts: &SimOptions,
) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for &conc in &CONCURRENCIES {
        for (algo, qc, qs) in [
            (Algorithm::Qafel, "qsgd:4", "qsgd:4"),
            (Algorithm::FedBuff, "none", "none"),
        ] {
            let mut cfg = base.clone();
            cfg.fl.algorithm = algo;
            cfg.quant.client = qc.into();
            cfg.quant.server = qs.into();
            cfg.sim.concurrency = conc;
            // Fig. 3 runs use staleness-scaled weights (Appendix D)
            cfg.fl.staleness_scaling = true;
            let label = format!("{} c={conc}", algo.name());
            let set = run_seeds(&cfg, make_backend, opts, &label)?;
            rows.push(aggregate(&set));
        }
    }
    let md = report("fig3", out_dir, base, &rows)?;
    println!("{md}");
    Ok(rows)
}

/// The comparisons the paper draws from Figure 3, as checks over rows.
/// Returns human-readable findings (used by tests and EXPERIMENTS.md).
pub fn findings(rows: &[Row]) -> Vec<String> {
    let mut out = Vec::new();
    for chunk in rows.chunks(2) {
        if chunk.len() < 2 {
            continue;
        }
        let (q, f) = (&chunk[0], &chunk[1]);
        out.push(format!(
            "{}: upload-MB ratio fedbuff/qafel = {:.2} (paper: 5.2-8x); \
             uploads ratio qafel/fedbuff = {:.2} (paper: 1-1.5x); \
             broadcast-MB ratio = {:.2}",
            q.label,
            f.upload_mb_mean / q.upload_mb_mean.max(1e-12),
            q.uploads_k_mean / f.uploads_k_mean.max(1e-12),
            f.broadcast_mb_mean / q.broadcast_mb_mean.max(1e-12),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::QuadraticBackend;

    #[test]
    fn fig3_shape_on_quadratic_backend() {
        // Small-scale shape check: QAFeL must upload far fewer MB while
        // needing a similar number of trips.
        let mut base = Config::default();
        base.fl.buffer_size = 4;
        base.fl.client_lr = 0.15;
        base.fl.server_lr = 1.0;
        base.fl.server_momentum = 0.0;
        base.fl.clip_norm = 0.0;
        base.sim.eval_every = 5;
        base.seeds = vec![1, 2];
        base.stop.target_accuracy = 0.95;
        base.stop.max_uploads = 8000;
        base.stop.max_server_steps = 2000;

        let factory = |seed: u64| -> Result<Box<dyn crate::runtime::Backend>> {
            Ok(Box::new(QuadraticBackend::new(64, 10, 1.0, 0.3, 0.2, 0.02, 2, seed)))
        };
        let dir = std::env::temp_dir().join(format!("qafel-fig3-{}", std::process::id()));
        let mut rows = Vec::new();
        for &conc in &[10usize, 40] {
            for (algo, qc, qs) in [
                (Algorithm::Qafel, "qsgd:4", "qsgd:4"),
                (Algorithm::FedBuff, "none", "none"),
            ] {
                let mut cfg = base.clone();
                cfg.fl.algorithm = algo;
                cfg.quant.client = qc.into();
                cfg.quant.server = qs.into();
                cfg.sim.concurrency = conc;
                cfg.fl.staleness_scaling = true;
                let set = run_seeds(&cfg, &factory, &Default::default(),
                                    &format!("{} c={conc}", algo.name())).unwrap();
                rows.push(aggregate(&set));
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
        for pair in rows.chunks(2) {
            let (q, f) = (&pair[0], &pair[1]);
            assert!(q.reached_frac > 0.4, "{} rarely converged", q.label);
            assert!(f.reached_frac > 0.4, "{} rarely converged", f.label);
            // who wins on bytes: QAFeL by a wide margin
            let mb_ratio = f.upload_mb_mean / q.upload_mb_mean;
            assert!(mb_ratio > 2.0, "{}: MB ratio only {mb_ratio:.2}", q.label);
            // trips: same order (not 5x worse)
            let trip_ratio = q.uploads_k_mean / f.uploads_k_mean;
            assert!(trip_ratio < 3.0, "{}: trip ratio {trip_ratio:.2}", q.label);
        }
        let f = findings(&rows);
        assert_eq!(f.len(), 2);
    }
}
