//! E5–E7 — design ablations called out in DESIGN.md:
//!
//! * **hidden-state** (E5): QAFeL's hidden state vs the DirectQuant
//!   baseline that broadcasts `Q_s(x^{t+1})` — §2's motivation: direct
//!   quantization injects error proportional to ‖x‖ every step, while the
//!   hidden state only quantizes the small increment.
//! * **k-sweep** (E7a): buffer size K ∈ {1, 5, 10, 20} — staleness drops
//!   as ~1/K (Assumption 3.4 discussion) while per-step progress grows.
//! * **staleness** (E7b): weight scaling 1/sqrt(1+tau) on vs off at high
//!   concurrency.
//! * **non-broadcast** (E6): Appendix B.1 cost model — catch-up bytes for
//!   the unicast variant with a C_max-deep update log, evaluated against
//!   the staleness distribution produced by a real run.

use super::runner::{aggregate, report, run_seeds, BackendFactory, Row};
use crate::config::{Algorithm, Config};
use crate::quant::parse_spec;
use crate::sim::SimOptions;
use anyhow::Result;

/// E5: hidden state vs direct quantization, same quantizers everywhere.
pub fn hidden_state(
    base: &Config,
    make_backend: &BackendFactory,
    out_dir: &str,
    opts: &SimOptions,
) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for (label, algo) in [
        ("qafel (hidden state)", Algorithm::Qafel),
        ("direct quantization", Algorithm::DirectQuant),
    ] {
        let mut cfg = base.clone();
        cfg.fl.algorithm = algo;
        let set = run_seeds(&cfg, make_backend, opts, label)?;
        rows.push(aggregate(&set));
    }
    let md = report("ablation_hidden_state", out_dir, base, &rows)?;
    println!("{md}");
    Ok(rows)
}

/// E7a: buffer size sweep.
pub fn k_sweep(
    base: &Config,
    make_backend: &BackendFactory,
    out_dir: &str,
    opts: &SimOptions,
) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for k in [1usize, 5, 10, 20] {
        let mut cfg = base.clone();
        cfg.fl.buffer_size = k;
        let set = run_seeds(&cfg, make_backend, opts, &format!("K={k}"))?;
        rows.push(aggregate(&set));
    }
    let md = report("ablation_k_sweep", out_dir, base, &rows)?;
    println!("{md}");
    Ok(rows)
}

/// E7b: staleness scaling on/off.
pub fn staleness(
    base: &Config,
    make_backend: &BackendFactory,
    out_dir: &str,
    opts: &SimOptions,
) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for scaling in [true, false] {
        let mut cfg = base.clone();
        cfg.fl.staleness_scaling = scaling;
        let label = if scaling { "scale 1/sqrt(1+tau)" } else { "no scaling" };
        let set = run_seeds(&cfg, make_backend, opts, label)?;
        rows.push(aggregate(&set));
    }
    let md = report("ablation_staleness", out_dir, base, &rows)?;
    println!("{md}");
    Ok(rows)
}

/// E6: Appendix B.1 non-broadcast variant, exercised with the REAL
/// [`UpdateLog`] data structure.
///
/// The server keeps the last `C_max = (model bytes)/(increment bytes)`
/// hidden-state increments. We replay an event-driven unicast protocol:
/// per-user replica ages advance only when the user is sampled; each
/// sampling requests `catch_up(last_t)` from the log. Returns
/// (mean catch-up kB per download, FedBuff full-download kB) — B.1's
/// claim is the former never exceeds the latter.
pub fn non_broadcast_cost(
    base: &Config,
    make_backend: &BackendFactory,
) -> Result<(f64, f64)> {
    use crate::coordinator::{Broadcast, UpdateLog};
    use crate::quant::QuantizedMsg;
    use crate::util::prng::Prng;

    let backend = make_backend(base.seeds[0])?;
    let d = backend.d();
    let qs = parse_spec(&base.quant.server)?;
    let pool = crate::util::pool::ShardPool::new(base.fl.shards.max(1));
    let inc_bytes = qs.expected_bytes(d);
    let full_bytes = 4.0 * d as f64;

    // drive the log with a sampling process shaped like the simulator's:
    // uniform user sampling, K uploads per server step.
    let n_users = backend.num_train_users();
    let k = base.fl.buffer_size as u64;
    let steps = 400u64;
    let mut log = UpdateLog::new(vec![0.0f32; d], inc_bytes);
    let mut last_t = vec![0u64; n_users];
    let mut rng = Prng::new(base.seeds[0]).stream("non-broadcast");
    let mut downloads = 0u64;
    for t in 1..=steps {
        // K client samplings per server step, each catching up first
        for _ in 0..k {
            let u = rng.range(0, n_users);
            let _resp = log.catch_up(last_t[u])?;
            last_t[u] = log.t();
            downloads += 1;
        }
        let b = Broadcast {
            t,
            bytes: inc_bytes,
            msg: QuantizedMsg { payload: vec![0; inc_bytes], d },
            absolute: false,
            codec: 0,
        };
        // advance the reference hidden state through the real (sharded)
        // decode path — a zero payload decodes to a zero increment
        log.push_quantized(b, qs.as_ref(), &pool)?;
    }
    let mean_catch_up = log.bytes_sent as f64 / downloads.max(1) as f64;
    Ok((mean_catch_up, full_bytes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::QuadraticBackend;

    fn base() -> Config {
        let mut c = Config::default();
        c.fl.algorithm = Algorithm::Qafel;
        c.quant.client = "qsgd:4".into();
        c.quant.server = "qsgd:4".into();
        c.fl.buffer_size = 4;
        c.fl.client_lr = 0.15;
        c.fl.server_lr = 1.0;
        c.fl.server_momentum = 0.0;
        c.fl.clip_norm = 0.0;
        c.sim.concurrency = 10;
        c.sim.eval_every = 5;
        c.seeds = vec![1, 2];
        c.stop.target_accuracy = 0.95;
        c.stop.max_uploads = 20_000;
        c.stop.max_server_steps = 4000;
        c
    }

    fn factory(seed: u64) -> Result<Box<dyn crate::runtime::Backend>> {
        Ok(Box::new(QuadraticBackend::new(64, 10, 1.0, 0.3, 0.2, 0.02, 2, seed)))
    }

    #[test]
    fn hidden_state_beats_direct_quantization() {
        let dir = std::env::temp_dir().join(format!("qafel-ab1-{}", std::process::id()));
        let rows = hidden_state(&base(), &factory, dir.to_str().unwrap(),
                                &Default::default()).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        let (qafel, direct) = (&rows[0], &rows[1]);
        assert!(qafel.reached_frac > 0.4, "qafel reached {}", qafel.reached_frac);
        // DirectQuant either fails to reach the target or needs far more
        // uploads — the error-propagation motivation of §2.
        let direct_worse = direct.reached_frac < qafel.reached_frac
            || direct.uploads_k_mean > 1.5 * qafel.uploads_k_mean
            || direct.final_acc_mean < qafel.final_acc_mean - 0.005;
        assert!(direct_worse, "direct quantization unexpectedly matched QAFeL: {direct:?}");
    }

    #[test]
    fn k_sweep_runs_all_buffer_sizes() {
        let mut cfg = base();
        cfg.stop.max_server_steps = 500;
        cfg.stop.max_uploads = 4000;
        cfg.stop.target_accuracy = 2.0; // fixed horizon comparison
        let dir = std::env::temp_dir().join(format!("qafel-ab2-{}", std::process::id()));
        let rows = k_sweep(&cfg, &factory, dir.to_str().unwrap(), &Default::default()).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        assert_eq!(rows.len(), 4);
        // broadcast count scales with 1/K for a fixed upload budget:
        // K=1 broadcasts every upload, K=20 every 20th
        let (k1, k20) = (&rows[0], &rows[3]);
        let per_upload_1 = k1.broadcast_mb_mean / k1.upload_mb_mean;
        let per_upload_20 = k20.broadcast_mb_mean / k20.upload_mb_mean;
        assert!(per_upload_1 > 5.0 * per_upload_20,
                "broadcast scaling wrong: {per_upload_1} vs {per_upload_20}");
    }

    #[test]
    fn non_broadcast_cost_is_bounded_by_full_model() {
        let (catch_up, full) = non_broadcast_cost(&base(), &factory).unwrap();
        assert!(catch_up > 0.0);
        // Appendix B.1: "the communication cost of QAFeL is less than or
        // equal to that of FedBuff"
        assert!(catch_up <= full, "catch-up {catch_up} > full model {full}");
    }
}
