//! Append-only JSONL journal: one [`Event`] per line.
//!
//! The durability contract is the one the event format was designed
//! for (see [`super::event`]): every line is a single top-level JSON
//! object written with one `write_all` call, so a kill can only tear
//! the *final* line, and a torn line never parses. [`JournalReader`]
//! therefore tolerates exactly one unparsable tail line and fails
//! loudly on anything malformed before it.

use super::event::Event;
use crate::scenario::metrics::StalenessHist;
use crate::telemetry::StageTimings;
use anyhow::{anyhow, bail, Result};
use std::fs::{File, OpenOptions};
use std::io::Write;

/// Streams events to a journal file, one line per event, each line a
/// single unbuffered write (the torn-tail guarantee).
pub struct JournalWriter {
    file: File,
    path: String,
}

impl JournalWriter {
    /// Start a fresh journal, truncating any existing file.
    pub fn create(path: &str) -> Result<JournalWriter> {
        let file = File::create(path)
            .map_err(|e| anyhow!("journal: cannot create '{path}': {e}"))?;
        Ok(JournalWriter { file, path: path.to_string() })
    }

    /// Continue an existing journal (the resume path — the caller has
    /// already truncated it to the last checkpoint).
    pub fn append(path: &str) -> Result<JournalWriter> {
        let file = OpenOptions::new()
            .append(true)
            .open(path)
            .map_err(|e| anyhow!("journal: cannot append to '{path}': {e}"))?;
        Ok(JournalWriter { file, path: path.to_string() })
    }

    pub fn path(&self) -> &str {
        &self.path
    }

    /// Append one event.
    pub fn write(&mut self, ev: &Event) -> Result<()> {
        let mut line = ev.to_line();
        line.push('\n');
        self.file
            .write_all(line.as_bytes())
            .map_err(|e| anyhow!("journal: write to '{}' failed: {e}", self.path))
    }
}

/// Reads a journal back into typed events.
pub struct JournalReader;

impl JournalReader {
    /// Read every event in `path`. A single unparsable *final* line (a
    /// kill tore it mid-write) is dropped; an unparsable line anywhere
    /// else is corruption and errors with its line number.
    pub fn read(path: &str) -> Result<Vec<Event>> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("journal: cannot read '{path}': {e}"))?;
        let lines: Vec<&str> = text.split('\n').collect();
        let last_content = lines.iter().rposition(|l| !l.is_empty());
        let mut events = Vec::new();
        for (i, line) in lines.iter().enumerate() {
            if line.is_empty() {
                continue;
            }
            match Event::from_line(line) {
                Ok(ev) => events.push(ev),
                // torn tail: the only line a kill can damage
                Err(_) if Some(i) == last_content => break,
                Err(e) => bail!("journal '{path}' line {}: {e}", i + 1),
            }
        }
        if events.is_empty() {
            bail!("journal '{path}' contains no events");
        }
        Ok(events)
    }
}

/// Prepare `path` for resume: find its last `Checkpoint` event, cut the
/// file immediately after that line (dropping post-checkpoint events
/// and any torn tail, so appended events keep step/time monotonic), and
/// return the surviving prefix — `Meta` through that `Checkpoint`.
pub fn truncate_after_last_checkpoint(path: &str) -> Result<Vec<Event>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("journal: cannot read '{path}': {e}"))?;
    let mut events = Vec::new();
    let mut kept = 0usize; // events up to + including the last checkpoint
    let mut cut = 0usize; // byte offset just past that checkpoint's line
    let mut pos = 0usize;
    let lines: Vec<&str> = text.split('\n').collect();
    let last_content = lines.iter().rposition(|l| !l.is_empty());
    for (i, line) in lines.iter().enumerate() {
        let line_end = pos + line.len() + 1; // + the '\n' (or EOF)
        if !line.is_empty() {
            match Event::from_line(line) {
                Ok(ev) => {
                    let is_ckpt = matches!(ev, Event::Checkpoint { .. });
                    events.push(ev);
                    if is_ckpt {
                        kept = events.len();
                        cut = line_end.min(text.len());
                    }
                }
                // torn tail — the cut drops it anyway
                Err(_) if Some(i) == last_content => break,
                Err(e) => bail!("journal '{path}' line {}: {e}", i + 1),
            }
        }
        pos = line_end;
    }
    if kept == 0 {
        bail!(
            "journal '{path}' has no checkpoint to resume from — \
             record with telemetry.checkpoint_every > 0"
        );
    }
    events.truncate(kept);
    let file = OpenOptions::new()
        .write(true)
        .open(path)
        .map_err(|e| anyhow!("journal: cannot open '{path}' for truncation: {e}"))?;
    file.set_len(cut as u64)
        .map_err(|e| anyhow!("journal: truncating '{path}' failed: {e}"))?;
    Ok(events)
}

/// The per-step one-liner shared by `qafel journal tail` and the live
/// `--progress` output: step, buffer fill, staleness quantiles, wire
/// bytes since the previous step, and the stage-time breakdown (when
/// spans were on). `prev` is the preceding `Step` event; `hist` is the
/// staleness histogram over every ingest so far. Returns `None` when
/// `cur` is not a `Step`.
pub fn progress_line(cur: &Event, prev: Option<&Event>, hist: &StalenessHist) -> Option<String> {
    let Event::Step { time, step, k, upload_bytes, broadcast_bytes, stages, .. } = cur else {
        return None;
    };
    let (prev_up, prev_down, prev_stages) = match prev {
        Some(Event::Step {
            upload_bytes: u,
            broadcast_bytes: b,
            stages: s,
            ..
        }) => (*u, *b, s.clone()),
        _ => (0, 0, None),
    };
    let up = upload_bytes.saturating_sub(prev_up);
    let down = broadcast_bytes.saturating_sub(prev_down);
    let mut line = format!(
        "step {step:>6} | t={time:<9.3} | k {k} | stale p50 {} p99 {} | up {} | down {}",
        hist.quantile(0.5),
        hist.quantile(0.99),
        human_bytes(up),
        human_bytes(down),
    );
    if let Some(cum) = stages {
        let base = prev_stages.unwrap_or_default();
        let d = |a: u64, b: u64| a.saturating_sub(b);
        let delta = StageTimings {
            steps: d(cum.steps, base.steps),
            accumulate_ns: d(cum.accumulate_ns, base.accumulate_ns),
            momentum_ns: d(cum.momentum_ns, base.momentum_ns),
            diff_ns: d(cum.diff_ns, base.diff_ns),
            encode_ns: d(cum.encode_ns, base.encode_ns),
            advance_ns: d(cum.advance_ns, base.advance_ns),
        };
        line.push_str(&format!(
            " | acc {} mom {} diff {} enc {} adv {}",
            human_ns(delta.accumulate_ns),
            human_ns(delta.momentum_ns),
            human_ns(delta.diff_ns),
            human_ns(delta.encode_ns),
            human_ns(delta.advance_ns),
        ));
    }
    Some(line)
}

/// `1.5KB`-style byte counts for the progress line.
fn human_bytes(n: u64) -> String {
    if n < 1024 {
        format!("{n}B")
    } else if n < 1024 * 1024 {
        format!("{:.1}KB", n as f64 / 1024.0)
    } else {
        format!("{:.1}MB", n as f64 / (1024.0 * 1024.0))
    }
}

/// `12.3µs`-style durations for the stage breakdown.
fn human_ns(ns: u64) -> String {
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.1}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.1}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    static TEMP_SEQ: AtomicUsize = AtomicUsize::new(0);

    fn temp_path(tag: &str) -> String {
        let n = TEMP_SEQ.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir()
            .join(format!("qafel_journal_{tag}_{}_{n}.jsonl", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    fn step_ev(step: u64, time: f64) -> Event {
        Event::Step {
            time,
            step,
            k: 3,
            uploads: step * 3,
            upload_bytes: step * 300,
            broadcast_bytes: step * 100,
            stale_mean: 1.0,
            stale_max: 4,
            stages: None,
        }
    }

    fn checkpoint_ev(step: u64) -> Event {
        Event::Checkpoint {
            time: step as f64,
            step,
            state: crate::util::json::Json::obj(vec![]),
        }
    }

    #[test]
    fn write_then_read_roundtrips() {
        let path = temp_path("rt");
        let evs = vec![
            Event::Codec { reg: "client".into(), id: 0, spec: "qsgd:4".into() },
            step_ev(1, 0.5),
            checkpoint_ev(1),
            step_ev(2, 1.0),
        ];
        let mut w = JournalWriter::create(&path).unwrap();
        for ev in &evs {
            w.write(ev).unwrap();
        }
        drop(w);
        assert_eq!(JournalReader::read(&path).unwrap(), evs);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn torn_tail_is_dropped_but_mid_file_corruption_errors() {
        let path = temp_path("torn");
        let mut w = JournalWriter::create(&path).unwrap();
        w.write(&step_ev(1, 0.5)).unwrap();
        w.write(&step_ev(2, 1.0)).unwrap();
        drop(w);
        // tear the last line the way a kill mid-write would
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() - 10]).unwrap();
        let evs = JournalReader::read(&path).unwrap();
        assert_eq!(evs, vec![step_ev(1, 0.5)]);
        // corruption *before* the tail is never silently skipped
        let garbled = text.replacen("\"ev\":\"step\"", "\"ev\":\"serp\"", 1);
        std::fs::write(&path, garbled).unwrap();
        let err = JournalReader::read(&path).unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncate_cuts_after_last_checkpoint() {
        let path = temp_path("cut");
        let mut w = JournalWriter::create(&path).unwrap();
        w.write(&checkpoint_ev(1)).unwrap();
        w.write(&step_ev(2, 1.0)).unwrap();
        w.write(&checkpoint_ev(2)).unwrap();
        w.write(&step_ev(3, 1.5)).unwrap();
        w.write(&step_ev(4, 2.0)).unwrap();
        drop(w);
        let prefix = truncate_after_last_checkpoint(&path).unwrap();
        assert_eq!(
            prefix,
            vec![checkpoint_ev(1), step_ev(2, 1.0), checkpoint_ev(2)]
        );
        // the file itself was cut at the same point — and an appended
        // event lands right after the checkpoint line
        let mut w = JournalWriter::append(&path).unwrap();
        w.write(&step_ev(3, 1.5)).unwrap();
        drop(w);
        let evs = JournalReader::read(&path).unwrap();
        assert_eq!(evs.len(), 4);
        assert_eq!(evs[3], step_ev(3, 1.5));
        // a journal with no checkpoint refuses to resume
        let bare = temp_path("bare");
        let mut w = JournalWriter::create(&bare).unwrap();
        w.write(&step_ev(1, 0.5)).unwrap();
        drop(w);
        let err = truncate_after_last_checkpoint(&bare).unwrap_err().to_string();
        assert!(err.contains("no checkpoint"), "{err}");
        std::fs::remove_file(&path).unwrap();
        std::fs::remove_file(&bare).unwrap();
    }

    #[test]
    fn progress_line_shows_deltas_and_stages() {
        let mut hist = StalenessHist::default();
        for s in [0, 0, 1, 2, 8] {
            hist.record(s);
        }
        let mut prev = step_ev(1, 0.5);
        let mut cur = step_ev(2, 1.0);
        let line = progress_line(&cur, Some(&prev), &hist).unwrap();
        assert!(line.starts_with("step ") && line.contains(" 2 |"), "{line}");
        // deltas, not totals: 600-300=300B up, 200-100=100B down
        assert!(line.contains("up 300B") && line.contains("down 100B"), "{line}");
        // [0,0,1,2,8]: median 1, p99 clamped to the observed max 8
        assert!(line.contains("p50 1") && line.contains("p99 8"), "{line}");
        assert!(!line.contains("acc"), "no stage block without spans: {line}");
        // with spans on, the stage breakdown appears as deltas
        let stamp = |ev: &mut Event, ns: u64| {
            if let Event::Step { stages, .. } = ev {
                *stages = Some(StageTimings {
                    steps: ns / 1000,
                    accumulate_ns: ns,
                    momentum_ns: ns,
                    diff_ns: ns,
                    encode_ns: ns,
                    advance_ns: ns,
                });
            }
        };
        stamp(&mut prev, 1_000);
        stamp(&mut cur, 3_500);
        let line = progress_line(&cur, Some(&prev), &hist).unwrap();
        assert!(line.contains("acc 2.5µs"), "{line}");
        // non-step events produce no line
        assert!(progress_line(&checkpoint_ev(1), None, &hist).is_none());
    }
}
