//! Typed journal events — the vocabulary shared by the simulator and
//! the TCP runtime (ARCHITECTURE.md §Telemetry has the taxonomy table).
//!
//! One event serializes to one compact JSON object (one JSONL line)
//! with a discriminant field `"ev"`. Binary payloads (quantized wire
//! messages, model vectors) are lowercase hex of their little-endian
//! bytes so a journal is exact — replay decodes the same bits the run
//! produced. 64-bit integers that may exceed 2^53 (seeds, RNG state
//! words) are hex *strings*; counters that cannot (steps, bytes,
//! staleness) are plain JSON numbers.
//!
//! Because every line is a single top-level object, its last character
//! is the closing `}` — so every strict prefix of a line is unbalanced
//! and fails [`Json::parse`]. A torn tail write (kill mid-line) is
//! therefore always detected, the same guarantee the `net::message`
//! framing gives a torn TCP frame.

use super::StageTimings;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

/// One journal event. `time` is seconds since the run started (sim
/// clock in the simulator, wall clock on the TCP leader); `step` is the
/// server step count t at the moment the event was recorded.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// First line of every journal: what produced it.
    Meta {
        /// `"sim"` or `"tcp"`.
        runtime: String,
        algorithm: String,
        /// Model dimension d.
        d: u64,
        /// Master seed of the run.
        seed: u64,
        /// [`super::run_fingerprint`] of the resolved config + seed.
        fingerprint: String,
        /// `git describe` of the producing tree, when available.
        git: Option<String>,
        /// The resolved config ([`crate::config::Config::to_json`]) —
        /// replay rebuilds the exact run from this, not from CLI flags.
        config: Json,
    },
    /// Codec registry entry, in registration order (the wire contract:
    /// ids are positional). `reg` is `"client"`, `"server"` (downlink
    /// family) or `"partial"`.
    Codec { reg: String, id: u64, spec: String },
    /// Initial model x^0 and the server's quantizer seed.
    Init { x0: Vec<f32>, server_seed: u64 },
    /// A simulated client was sampled and started training (sim only;
    /// informational — replay reconstructs the server from ingests).
    Arrival {
        time: f64,
        tier: String,
        user: u64,
        trip: u64,
        /// Server step when the client snapshotted the model.
        t_start: u64,
        dropped: bool,
        /// Fraction of local work completed before a mid-round drop.
        partial: Option<f64>,
    },
    /// One client upload reached the root server
    /// ([`crate::coordinator::Server::ingest_from`]). `worker` is the
    /// sim user id or the TCP worker id.
    Ingest {
        time: f64,
        step: u64,
        worker: u64,
        codec: u64,
        staleness: u64,
        payload: Vec<u8>,
    },
    /// An edge aggregator's partial reached the root server
    /// ([`crate::coordinator::Server::ingest_partial`]). The staleness
    /// histogram rides along so replay merges the same accounting.
    IngestPartial {
        time: f64,
        step: u64,
        worker: u64,
        codec: u64,
        count: u64,
        stale_counts: Vec<u64>,
        stale_sum: u64,
        stale_max: u64,
        stale_n: u64,
        payload: Vec<u8>,
    },
    /// A server step committed (buffer filled). Totals are cumulative;
    /// `k` is the number of update slots that filled this buffer.
    Step {
        time: f64,
        step: u64,
        k: u64,
        uploads: u64,
        upload_bytes: u64,
        broadcast_bytes: u64,
        stale_mean: f64,
        stale_max: u64,
        /// Cumulative stage timings at this step, when spans are on.
        stages: Option<StageTimings>,
    },
    /// One broadcast emitted by a step — one event per downlink family,
    /// family 0 first. `absolute` marks DirectQuant payloads (the model
    /// itself, not a hidden-state increment); `codec` is the downlink
    /// family id, serialized only when non-zero so single-family
    /// journals stay byte-identical to the pre-family format (and old
    /// journals parse as family 0).
    Broadcast {
        time: f64,
        step: u64,
        absolute: bool,
        codec: u64,
        payload: Vec<u8>,
    },
    /// The adaptive controller switched a client's upload codec
    /// mid-run (`net.adaptive` on the TCP leader, `[scenario.adaptive]`
    /// in the simulator). `worker` is the TCP worker id or the sim tier
    /// index; `old`/`new` are client-registry codec ids and `spec` the
    /// resolved spec of the new codec. Informational for replay — the
    /// ingest events carry their own codec ids — but it pins the switch
    /// point so a journal is a complete record of the control loop.
    Rekey { time: f64, step: u64, worker: u64, old: u64, new: u64, spec: String },
    /// An evaluation point (sim only — the curve).
    Eval {
        time: f64,
        step: u64,
        uploads: u64,
        val_loss: f64,
        val_accuracy: f64,
    },
    /// Full run state for resume. `state` is runtime-specific (the sim
    /// engine and TCP leader each write what they need to continue).
    Checkpoint { time: f64, step: u64, state: Json },
    /// Last line of a completed run: final totals + model.
    Final {
        step: u64,
        uploads: u64,
        upload_bytes: u64,
        broadcasts: u64,
        broadcast_bytes: u64,
        model: Vec<f32>,
    },
}

impl Event {
    /// The `"ev"` discriminant this variant serializes under.
    pub fn kind(&self) -> &'static str {
        match self {
            Event::Meta { .. } => "meta",
            Event::Codec { .. } => "codec",
            Event::Init { .. } => "init",
            Event::Arrival { .. } => "arrival",
            Event::Ingest { .. } => "ingest",
            Event::IngestPartial { .. } => "ingest_partial",
            Event::Step { .. } => "step",
            Event::Broadcast { .. } => "broadcast",
            Event::Rekey { .. } => "rekey",
            Event::Eval { .. } => "eval",
            Event::Checkpoint { .. } => "checkpoint",
            Event::Final { .. } => "final",
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![("ev", Json::str(self.kind()))];
        match self {
            Event::Meta { runtime, algorithm, d, seed, fingerprint, git, config } => {
                pairs.push(("runtime", Json::str(runtime.clone())));
                pairs.push(("algorithm", Json::str(algorithm.clone())));
                pairs.push(("d", Json::num(*d as f64)));
                pairs.push(("seed", Json::str(hex_u64(*seed))));
                pairs.push(("fingerprint", Json::str(fingerprint.clone())));
                if let Some(g) = git {
                    pairs.push(("git", Json::str(g.clone())));
                }
                pairs.push(("config", config.clone()));
            }
            Event::Codec { reg, id, spec } => {
                pairs.push(("reg", Json::str(reg.clone())));
                pairs.push(("id", Json::num(*id as f64)));
                pairs.push(("spec", Json::str(spec.clone())));
            }
            Event::Init { x0, server_seed } => {
                pairs.push(("x0", Json::str(hex_f32s(x0))));
                pairs.push(("server_seed", Json::str(hex_u64(*server_seed))));
            }
            Event::Arrival { time, tier, user, trip, t_start, dropped, partial } => {
                pairs.push(("time", Json::num(*time)));
                pairs.push(("tier", Json::str(tier.clone())));
                pairs.push(("user", Json::num(*user as f64)));
                pairs.push(("trip", Json::num(*trip as f64)));
                pairs.push(("t_start", Json::num(*t_start as f64)));
                pairs.push(("dropped", Json::Bool(*dropped)));
                if let Some(p) = partial {
                    pairs.push(("partial", Json::num(*p)));
                }
            }
            Event::Ingest { time, step, worker, codec, staleness, payload } => {
                pairs.push(("time", Json::num(*time)));
                pairs.push(("step", Json::num(*step as f64)));
                pairs.push(("worker", Json::num(*worker as f64)));
                pairs.push(("codec", Json::num(*codec as f64)));
                pairs.push(("staleness", Json::num(*staleness as f64)));
                pairs.push(("payload", Json::str(hex_bytes(payload))));
            }
            Event::IngestPartial {
                time,
                step,
                worker,
                codec,
                count,
                stale_counts,
                stale_sum,
                stale_max,
                stale_n,
                payload,
            } => {
                pairs.push(("time", Json::num(*time)));
                pairs.push(("step", Json::num(*step as f64)));
                pairs.push(("worker", Json::num(*worker as f64)));
                pairs.push(("codec", Json::num(*codec as f64)));
                pairs.push(("count", Json::num(*count as f64)));
                pairs.push((
                    "stale_counts",
                    Json::arr(stale_counts.iter().map(|&c| Json::num(c as f64)).collect()),
                ));
                pairs.push(("stale_sum", Json::num(*stale_sum as f64)));
                pairs.push(("stale_max", Json::num(*stale_max as f64)));
                pairs.push(("stale_n", Json::num(*stale_n as f64)));
                pairs.push(("payload", Json::str(hex_bytes(payload))));
            }
            Event::Step {
                time,
                step,
                k,
                uploads,
                upload_bytes,
                broadcast_bytes,
                stale_mean,
                stale_max,
                stages,
            } => {
                pairs.push(("time", Json::num(*time)));
                pairs.push(("step", Json::num(*step as f64)));
                pairs.push(("k", Json::num(*k as f64)));
                pairs.push(("uploads", Json::num(*uploads as f64)));
                pairs.push(("upload_bytes", Json::num(*upload_bytes as f64)));
                pairs.push(("broadcast_bytes", Json::num(*broadcast_bytes as f64)));
                pairs.push(("stale_mean", Json::num(*stale_mean)));
                pairs.push(("stale_max", Json::num(*stale_max as f64)));
                if let Some(s) = stages {
                    pairs.push(("stages", s.to_json()));
                }
            }
            Event::Broadcast { time, step, absolute, codec, payload } => {
                pairs.push(("time", Json::num(*time)));
                pairs.push(("step", Json::num(*step as f64)));
                pairs.push(("absolute", Json::Bool(*absolute)));
                if *codec != 0 {
                    pairs.push(("codec", Json::num(*codec as f64)));
                }
                pairs.push(("payload", Json::str(hex_bytes(payload))));
            }
            Event::Rekey { time, step, worker, old, new, spec } => {
                pairs.push(("time", Json::num(*time)));
                pairs.push(("step", Json::num(*step as f64)));
                pairs.push(("worker", Json::num(*worker as f64)));
                pairs.push(("old", Json::num(*old as f64)));
                pairs.push(("new", Json::num(*new as f64)));
                pairs.push(("spec", Json::str(spec.clone())));
            }
            Event::Eval { time, step, uploads, val_loss, val_accuracy } => {
                pairs.push(("time", Json::num(*time)));
                pairs.push(("step", Json::num(*step as f64)));
                pairs.push(("uploads", Json::num(*uploads as f64)));
                pairs.push(("val_loss", Json::num(*val_loss)));
                pairs.push(("val_accuracy", Json::num(*val_accuracy)));
            }
            Event::Checkpoint { time, step, state } => {
                pairs.push(("time", Json::num(*time)));
                pairs.push(("step", Json::num(*step as f64)));
                pairs.push(("state", state.clone()));
            }
            Event::Final { step, uploads, upload_bytes, broadcasts, broadcast_bytes, model } => {
                pairs.push(("step", Json::num(*step as f64)));
                pairs.push(("uploads", Json::num(*uploads as f64)));
                pairs.push(("upload_bytes", Json::num(*upload_bytes as f64)));
                pairs.push(("broadcasts", Json::num(*broadcasts as f64)));
                pairs.push(("broadcast_bytes", Json::num(*broadcast_bytes as f64)));
                pairs.push(("model", Json::str(hex_f32s(model))));
            }
        }
        Json::obj(pairs)
    }

    /// Serialize to one JSONL line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }

    pub fn from_json(j: &Json) -> Result<Event> {
        let ev = text(j, "ev")?;
        Ok(match ev.as_str() {
            "meta" => Event::Meta {
                runtime: text(j, "runtime")?,
                algorithm: text(j, "algorithm")?,
                d: uint(j, "d")?,
                seed: parse_hex_u64(&text(j, "seed")?)?,
                fingerprint: text(j, "fingerprint")?,
                git: opt_text(j, "git")?,
                config: req(j, "config")?.clone(),
            },
            "codec" => Event::Codec {
                reg: text(j, "reg")?,
                id: uint(j, "id")?,
                spec: text(j, "spec")?,
            },
            "init" => Event::Init {
                x0: parse_hex_f32s(&text(j, "x0")?)?,
                server_seed: parse_hex_u64(&text(j, "server_seed")?)?,
            },
            "arrival" => Event::Arrival {
                time: num(j, "time")?,
                tier: text(j, "tier")?,
                user: uint(j, "user")?,
                trip: uint(j, "trip")?,
                t_start: uint(j, "t_start")?,
                dropped: boolean(j, "dropped")?,
                partial: match j.get("partial") {
                    Some(v) => Some(
                        v.as_f64()
                            .ok_or_else(|| anyhow!("event: 'partial' is not a number"))?,
                    ),
                    None => None,
                },
            },
            "ingest" => Event::Ingest {
                time: num(j, "time")?,
                step: uint(j, "step")?,
                worker: uint(j, "worker")?,
                codec: uint(j, "codec")?,
                staleness: uint(j, "staleness")?,
                payload: parse_hex_bytes(&text(j, "payload")?)?,
            },
            "ingest_partial" => Event::IngestPartial {
                time: num(j, "time")?,
                step: uint(j, "step")?,
                worker: uint(j, "worker")?,
                codec: uint(j, "codec")?,
                count: uint(j, "count")?,
                stale_counts: req(j, "stale_counts")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("event: 'stale_counts' is not an array"))?
                    .iter()
                    .map(|v| {
                        v.as_f64()
                            .map(|f| f as u64)
                            .ok_or_else(|| anyhow!("event: non-numeric stale count"))
                    })
                    .collect::<Result<Vec<u64>>>()?,
                stale_sum: uint(j, "stale_sum")?,
                stale_max: uint(j, "stale_max")?,
                stale_n: uint(j, "stale_n")?,
                payload: parse_hex_bytes(&text(j, "payload")?)?,
            },
            "step" => Event::Step {
                time: num(j, "time")?,
                step: uint(j, "step")?,
                k: uint(j, "k")?,
                uploads: uint(j, "uploads")?,
                upload_bytes: uint(j, "upload_bytes")?,
                broadcast_bytes: uint(j, "broadcast_bytes")?,
                stale_mean: num(j, "stale_mean")?,
                stale_max: uint(j, "stale_max")?,
                stages: match j.get("stages") {
                    Some(v) => Some(StageTimings::from_json(v)?),
                    None => None,
                },
            },
            "broadcast" => Event::Broadcast {
                time: num(j, "time")?,
                step: uint(j, "step")?,
                absolute: boolean(j, "absolute")?,
                codec: match j.get("codec") {
                    Some(v) => v
                        .as_f64()
                        .map(|f| f as u64)
                        .ok_or_else(|| anyhow!("event: 'codec' is not a number"))?,
                    None => 0,
                },
                payload: parse_hex_bytes(&text(j, "payload")?)?,
            },
            "rekey" => Event::Rekey {
                time: num(j, "time")?,
                step: uint(j, "step")?,
                worker: uint(j, "worker")?,
                old: uint(j, "old")?,
                new: uint(j, "new")?,
                spec: text(j, "spec")?,
            },
            "eval" => Event::Eval {
                time: num(j, "time")?,
                step: uint(j, "step")?,
                uploads: uint(j, "uploads")?,
                val_loss: num(j, "val_loss")?,
                val_accuracy: num(j, "val_accuracy")?,
            },
            "checkpoint" => Event::Checkpoint {
                time: num(j, "time")?,
                step: uint(j, "step")?,
                state: req(j, "state")?.clone(),
            },
            "final" => Event::Final {
                step: uint(j, "step")?,
                uploads: uint(j, "uploads")?,
                upload_bytes: uint(j, "upload_bytes")?,
                broadcasts: uint(j, "broadcasts")?,
                broadcast_bytes: uint(j, "broadcast_bytes")?,
                model: parse_hex_f32s(&text(j, "model")?)?,
            },
            other => bail!("journal: unknown event kind '{other}'"),
        })
    }

    /// Parse one JSONL line.
    pub fn from_line(line: &str) -> Result<Event> {
        let j = Json::parse(line).map_err(|e| anyhow!("journal: bad event line: {e}"))?;
        Event::from_json(&j)
    }
}

// ---- field accessors (loud on schema drift) -----------------------------

fn req<'a>(j: &'a Json, k: &str) -> Result<&'a Json> {
    j.get(k).ok_or_else(|| anyhow!("event: missing field '{k}'"))
}

fn num(j: &Json, k: &str) -> Result<f64> {
    req(j, k)?
        .as_f64()
        .ok_or_else(|| anyhow!("event: field '{k}' is not a number"))
}

fn uint(j: &Json, k: &str) -> Result<u64> {
    Ok(num(j, k)? as u64)
}

fn text(j: &Json, k: &str) -> Result<String> {
    Ok(req(j, k)?
        .as_str()
        .ok_or_else(|| anyhow!("event: field '{k}' is not a string"))?
        .to_string())
}

fn opt_text(j: &Json, k: &str) -> Result<Option<String>> {
    match j.get(k) {
        Some(v) => Ok(Some(
            v.as_str()
                .ok_or_else(|| anyhow!("event: field '{k}' is not a string"))?
                .to_string(),
        )),
        None => Ok(None),
    }
}

fn boolean(j: &Json, k: &str) -> Result<bool> {
    req(j, k)?
        .as_bool()
        .ok_or_else(|| anyhow!("event: field '{k}' is not a bool"))
}

// ---- hex codecs ----------------------------------------------------------

const HEX: &[u8; 16] = b"0123456789abcdef";

/// Lowercase hex of a byte string.
pub fn hex_bytes(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        s.push(HEX[(b >> 4) as usize] as char);
        s.push(HEX[(b & 15) as usize] as char);
    }
    s
}

pub fn parse_hex_bytes(s: &str) -> Result<Vec<u8>> {
    let b = s.as_bytes();
    if b.len() % 2 != 0 {
        bail!("hex string has odd length {}", b.len());
    }
    fn nib(c: u8) -> Result<u8> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            _ => bail!("invalid hex digit 0x{c:02x}"),
        }
    }
    b.chunks_exact(2)
        .map(|p| Ok((nib(p[0])? << 4) | nib(p[1])?))
        .collect()
}

/// Hex of the little-endian bytes of an f32 vector — exact (no decimal
/// round-trip), 8 chars per element.
pub fn hex_f32s(xs: &[f32]) -> String {
    let mut s = String::with_capacity(xs.len() * 8);
    for x in xs {
        for &b in &x.to_le_bytes() {
            s.push(HEX[(b >> 4) as usize] as char);
            s.push(HEX[(b & 15) as usize] as char);
        }
    }
    s
}

pub fn parse_hex_f32s(s: &str) -> Result<Vec<f32>> {
    let bytes = parse_hex_bytes(s)?;
    if bytes.len() % 4 != 0 {
        bail!("f32 hex string is {} bytes, not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// A u64 as a hex string (exact beyond 2^53, unlike a JSON number).
pub fn hex_u64(v: u64) -> String {
    format!("{v:x}")
}

pub fn parse_hex_u64(s: &str) -> Result<u64> {
    u64::from_str_radix(s, 16).map_err(|e| anyhow!("bad u64 hex '{s}': {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_variants() -> Vec<Event> {
        vec![
            Event::Meta {
                runtime: "sim".into(),
                algorithm: "qafel".into(),
                d: 128,
                seed: 0xDEAD_BEEF_CAFE_F00D, // > 2^53: needs the hex path
                fingerprint: "0123456789abcdef".into(),
                git: Some("c6cef03-dirty".into()),
                config: Json::obj(vec![("fl", Json::obj(vec![("shards", Json::num(4.0))]))]),
            },
            Event::Meta {
                runtime: "tcp".into(),
                algorithm: "fedbuff".into(),
                d: 64,
                seed: 7,
                fingerprint: "ffff0000ffff0000".into(),
                git: None,
                config: Json::obj(vec![]),
            },
            Event::Codec { reg: "client".into(), id: 1, spec: "top:0.1".into() },
            Event::Init { x0: vec![0.0, -1.5, f32::MIN_POSITIVE, 3.25e7], server_seed: u64::MAX },
            Event::Arrival {
                time: 0.125,
                tier: "phone".into(),
                user: 42,
                trip: 3,
                t_start: 17,
                dropped: true,
                partial: Some(0.4),
            },
            Event::Arrival {
                time: 1.5,
                tier: "default".into(),
                user: 0,
                trip: 0,
                t_start: 0,
                dropped: false,
                partial: None,
            },
            Event::Ingest {
                time: 2.25,
                step: 5,
                worker: 9,
                codec: 2,
                staleness: 11,
                payload: vec![0x00, 0xff, 0x7f, 0x80, 0x01],
            },
            Event::IngestPartial {
                time: 3.0,
                step: 6,
                worker: 1,
                codec: 0,
                count: 2,
                stale_counts: vec![1, 0, 1],
                stale_sum: 4,
                stale_max: 3,
                stale_n: 2,
                payload: vec![0xab, 0xcd],
            },
            Event::Step {
                time: 4.5,
                step: 7,
                k: 3,
                uploads: 21,
                upload_bytes: 5544,
                broadcast_bytes: 1848,
                stale_mean: 1.75,
                stale_max: 11,
                stages: Some(StageTimings {
                    steps: 7,
                    accumulate_ns: 100,
                    momentum_ns: 200,
                    diff_ns: 300,
                    encode_ns: 400,
                    advance_ns: 500,
                }),
            },
            Event::Step {
                time: 4.75,
                step: 8,
                k: 3,
                uploads: 24,
                upload_bytes: 6336,
                broadcast_bytes: 2112,
                stale_mean: 1.5,
                stale_max: 11,
                stages: None,
            },
            Event::Broadcast {
                time: 4.5,
                step: 7,
                absolute: false,
                codec: 0,
                payload: vec![1, 2, 3],
            },
            Event::Broadcast { time: 4.5, step: 7, absolute: true, codec: 2, payload: vec![4, 5] },
            Event::Rekey { time: 4.75, step: 8, worker: 3, old: 0, new: 2, spec: "qsgd:2".into() },
            Event::Eval { time: 5.0, step: 8, uploads: 24, val_loss: 0.3125, val_accuracy: 0.875 },
            Event::Checkpoint {
                time: 6.0,
                step: 10,
                state: Json::obj(vec![("rng", Json::arr(vec![Json::str("ff"), Json::str("1")]))]),
            },
            Event::Final {
                step: 30,
                uploads: 90,
                upload_bytes: 23760,
                broadcasts: 30,
                broadcast_bytes: 7920,
                model: vec![1.0, -2.5, 0.0],
            },
        ]
    }

    #[test]
    fn every_event_roundtrips_through_its_line() {
        for ev in all_variants() {
            let line = ev.to_line();
            assert!(!line.contains('\n'), "{}: line must be single-line", ev.kind());
            let back = Event::from_line(&line).unwrap_or_else(|e| {
                panic!("{}: failed to parse own line {line}: {e}", ev.kind())
            });
            assert_eq!(back, ev, "{} roundtrip", ev.kind());
        }
    }

    #[test]
    fn every_strict_prefix_fails_to_parse() {
        // the torn-tail guarantee: a journal line cut anywhere before its
        // final byte never parses as a valid event
        for ev in all_variants() {
            let line = ev.to_line();
            for cut in 0..line.len() {
                let prefix = &line[..cut];
                assert!(
                    Event::from_line(prefix).is_err(),
                    "{}: prefix of {} bytes parsed",
                    ev.kind(),
                    cut
                );
            }
        }
    }

    #[test]
    fn broadcast_codec_key_only_appears_for_non_default_families() {
        // byte-identity: family-0 broadcasts serialize exactly as the
        // pre-family format did, and old lines parse as family 0
        let b0 =
            Event::Broadcast { time: 1.0, step: 2, absolute: false, codec: 0, payload: vec![9] };
        assert!(!b0.to_line().contains("codec"));
        let old =
            "{\"ev\":\"broadcast\",\"time\":1,\"step\":2,\"absolute\":false,\"payload\":\"09\"}";
        assert_eq!(Event::from_line(old).unwrap(), b0);
        let b2 =
            Event::Broadcast { time: 1.0, step: 2, absolute: false, codec: 2, payload: vec![9] };
        assert!(b2.to_line().contains("\"codec\":2"));
    }

    #[test]
    fn garbage_and_unknown_kinds_are_rejected() {
        assert!(Event::from_line("").is_err());
        assert!(Event::from_line("not json").is_err());
        assert!(Event::from_line("[1,2]").is_err());
        assert!(Event::from_line("{\"no_ev\":1}").is_err());
        assert!(Event::from_line("{\"ev\":\"warp\"}").is_err());
        // right kind, missing field
        assert!(Event::from_line("{\"ev\":\"codec\",\"reg\":\"client\"}").is_err());
        // right kind, wrong type
        assert!(Event::from_line("{\"ev\":\"codec\",\"reg\":7,\"id\":0,\"spec\":\"x\"}").is_err());
        // rekey without its new codec id (or any other field) is rejected
        assert!(Event::from_line(
            "{\"ev\":\"rekey\",\"time\":1,\"step\":2,\"worker\":0,\"old\":0,\"spec\":\"qsgd:2\"}"
        )
        .is_err());
    }

    #[test]
    fn hex_codecs_roundtrip_and_reject_malformed() {
        let bytes: Vec<u8> = (0..=255).collect();
        assert_eq!(parse_hex_bytes(&hex_bytes(&bytes)).unwrap(), bytes);
        assert!(parse_hex_bytes("abc").is_err(), "odd length");
        assert!(parse_hex_bytes("zz").is_err(), "bad digit");
        assert!(parse_hex_bytes("AB").is_err(), "uppercase is not canonical");

        let xs = [0.0f32, -0.0, 1.5, f32::MAX, f32::MIN_POSITIVE];
        let rt = parse_hex_f32s(&hex_f32s(&xs)).unwrap();
        assert_eq!(rt.len(), xs.len());
        for (a, b) in xs.iter().zip(&rt) {
            assert_eq!(a.to_bits(), b.to_bits(), "exact bit roundtrip");
        }
        assert!(parse_hex_f32s("aabbcc").is_err(), "not a multiple of 4 bytes");

        for v in [0u64, 1, 0x7fff_ffff, u64::MAX, 1 << 53] {
            assert_eq!(parse_hex_u64(&hex_u64(v)).unwrap(), v);
        }
        assert!(parse_hex_u64("").is_err());
        assert!(parse_hex_u64("xyz").is_err());
    }
}
