//! Flight recorder: structured telemetry, the event-sourced run
//! journal, and checkpoint/resume (ARCHITECTURE.md §Telemetry).
//!
//! Dependency-free by construction (same offline discipline as
//! `vendor/anyhow`): events are hand-serialized JSONL via
//! [`crate::util::json::Json`], timings use `std::time::Instant`, and
//! the process-global sink is a single relaxed [`AtomicBool`].
//!
//! Three faces:
//!
//! * **Per-stage spans** — [`StageTimings`] accumulates wall time for
//!   the five server-step stages (accumulate, momentum + η_g apply,
//!   hidden-state diff, Q_s encode, x̂ advance). Capture is gated on
//!   [`enabled`]: when the sink is off, [`span_start`] returns `None`
//!   without ever calling `Instant::now()`, so the hot aggregation path
//!   pays one relaxed load + branch per stage — zero-cost in the
//!   `coordinator` bench's step sweep.
//! * **Run journal** — [`event::Event`] is the typed vocabulary shared
//!   by the simulator and the TCP runtime; [`journal::JournalWriter`]
//!   streams events as append-only JSONL. A journal replays
//!   bit-identically through [`replay::replay_events`] (the generalized
//!   form of the leader's old ad-hoc `record_trace`).
//! * **Checkpoint/resume** — [`event::Event::Checkpoint`] snapshots the
//!   full run state (model, hidden state, buffer, RNG streams) so a
//!   killed run continues from the last checkpoint to the same curve as
//!   an uninterrupted one (`qafel run --resume`, `--resume` on the
//!   leader).
//!
//! Every run is named by a **config fingerprint**
//! ([`config_fingerprint`] / [`run_fingerprint`]): an FNV-64 hash of
//! the resolved [`Config`] (via [`Config::to_json`]) plus the seed,
//! recorded in [`crate::metrics::RunResult`], every experiment CSV
//! header, and the journal's `Meta` event.

pub mod event;
pub mod journal;
pub mod replay;

pub use event::Event;
pub use journal::{progress_line, truncate_after_last_checkpoint, JournalReader, JournalWriter};
pub use replay::{replay_events, replay_file, ReplayReport};

use crate::config::Config;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// Process-global telemetry sink switch. Off by default; flipped on by
/// the CLI when `--journal` / `--progress` / `[telemetry]` ask for
/// timings, and by tests.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn span capture on or off process-wide.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Is span capture on? One relaxed load — safe to call per stage in the
/// hot path.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Begin a timing span: `Some(Instant)` when the sink is enabled,
/// `None` otherwise (no clock syscall on the disabled path).
#[inline]
pub fn span_start() -> Option<Instant> {
    if enabled() {
        Some(Instant::now())
    } else {
        None
    }
}

/// Close a span opened by [`span_start`], in nanoseconds (0 when the
/// sink was off at open time).
#[inline]
pub fn span_ns(start: Option<Instant>) -> u64 {
    start.map(|t| t.elapsed().as_nanos() as u64).unwrap_or(0)
}

/// Cumulative wall time per server-step stage (Algorithm 1's five
/// stages, DESIGN_SHARDING.md). `steps` counts every committed server
/// step unconditionally (a plain u64 add); the `*_ns` fields accumulate
/// only while [`enabled`] — a disabled run reports real step counts and
/// all-zero timings.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StageTimings {
    /// Server steps committed.
    pub steps: u64,
    /// Buffer accumulate (per-upload decode + weighted add), summed over
    /// every ingest.
    pub accumulate_ns: u64,
    /// Momentum update + η_g apply to x.
    pub momentum_ns: u64,
    /// Hidden-state diff x − x̂.
    pub diff_ns: u64,
    /// Q_s encode of the broadcast payload.
    pub encode_ns: u64,
    /// x̂ advance (apply q^t to the hidden state).
    pub advance_ns: u64,
}

impl StageTimings {
    /// Total time across all five stages.
    pub fn total_ns(&self) -> u64 {
        self.accumulate_ns
            + self.momentum_ns
            + self.diff_ns
            + self.encode_ns
            + self.advance_ns
    }

    /// Fold another accumulator into this one (merging shards/edges).
    pub fn merge(&mut self, other: &StageTimings) {
        self.steps += other.steps;
        self.accumulate_ns += other.accumulate_ns;
        self.momentum_ns += other.momentum_ns;
        self.diff_ns += other.diff_ns;
        self.encode_ns += other.encode_ns;
        self.advance_ns += other.advance_ns;
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("steps", Json::num(self.steps as f64)),
            ("accumulate_ns", Json::num(self.accumulate_ns as f64)),
            ("momentum_ns", Json::num(self.momentum_ns as f64)),
            ("diff_ns", Json::num(self.diff_ns as f64)),
            ("encode_ns", Json::num(self.encode_ns as f64)),
            ("advance_ns", Json::num(self.advance_ns as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<StageTimings> {
        let get = |k: &str| -> Result<u64> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .map(|f| f as u64)
                .ok_or_else(|| anyhow!("stage timings: missing numeric field '{k}'"))
        };
        Ok(StageTimings {
            steps: get("steps")?,
            accumulate_ns: get("accumulate_ns")?,
            momentum_ns: get("momentum_ns")?,
            diff_ns: get("diff_ns")?,
            encode_ns: get("encode_ns")?,
            advance_ns: get("advance_ns")?,
        })
    }
}

/// FNV-1a over a byte string (the same hash the codebase already uses
/// for stream labels — stable across platforms and builds).
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Stable fingerprint of a resolved [`Config`] (including its seed
/// list): 16 hex digits of FNV-64 over the canonical JSON form. Names
/// the configuration an experiment artifact came from.
pub fn config_fingerprint(cfg: &Config) -> String {
    format!("{:016x}", fnv64(cfg.to_json().to_string().as_bytes()))
}

/// Fingerprint of one run: the config fingerprint salted with the run's
/// seed. Two seeds of the same experiment get distinct names.
pub fn run_fingerprint(cfg: &Config, seed: u64) -> String {
    let text = format!("{}#seed={seed}", cfg.to_json());
    format!("{:016x}", fnv64(text.as_bytes()))
}

/// `git describe --always --dirty` of the working tree, if git and a
/// repository are available (best effort; journals record it so an
/// artifact names the code that produced it).
pub fn git_describe() -> Option<String> {
    let out = std::process::Command::new("git")
        .args(["describe", "--always", "--dirty"])
        .output()
        .ok()?;
    if !out.status.success() {
        return None;
    }
    let s = String::from_utf8_lossy(&out.stdout).trim().to_string();
    if s.is_empty() {
        None
    } else {
        Some(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_capture_follows_the_global_switch() {
        // journaled engine runs flip the global on from other test
        // threads, so the disabled state can't be asserted here — only
        // the enabled path and the None-span zero.
        set_enabled(true);
        assert!(enabled());
        let span = span_start();
        assert!(span.is_some());
        let _ = span_ns(span);
        assert_eq!(span_ns(None), 0);
    }

    #[test]
    fn stage_timings_roundtrip_and_merge() {
        let a = StageTimings {
            steps: 3,
            accumulate_ns: 10,
            momentum_ns: 20,
            diff_ns: 30,
            encode_ns: 40,
            advance_ns: 50,
        };
        assert_eq!(a.total_ns(), 150);
        let j = a.to_json();
        assert_eq!(StageTimings::from_json(&j).unwrap(), a);
        let mut b = a.clone();
        b.merge(&a);
        assert_eq!(b.steps, 6);
        assert_eq!(b.total_ns(), 300);
        // missing fields fail loudly
        assert!(StageTimings::from_json(&Json::obj(vec![])).is_err());
    }

    #[test]
    fn fingerprints_are_stable_and_seed_sensitive() {
        let cfg = Config::default();
        let f1 = config_fingerprint(&cfg);
        let f2 = config_fingerprint(&cfg);
        assert_eq!(f1, f2);
        assert_eq!(f1.len(), 16);
        let mut other = cfg.clone();
        other.fl.buffer_size += 1;
        assert_ne!(f1, config_fingerprint(&other));
        // the run fingerprint distinguishes seeds of one config
        assert_ne!(run_fingerprint(&cfg, 1), run_fingerprint(&cfg, 2));
        assert_eq!(run_fingerprint(&cfg, 1), run_fingerprint(&cfg, 1));
    }
}
