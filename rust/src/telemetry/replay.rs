//! Deterministic journal replay: rebuild the run from its `Meta` event
//! and drive a fresh [`Server`] with the journal's ingest stream,
//! asserting every recorded broadcast (and the final model) bit-exactly.
//!
//! This is the generalized form of the TCP leader's old ad-hoc
//! `record_trace`: because the journal captures what reached the server
//! (not when threads happened to run), replay is deterministic even for
//! journals recorded by the nondeterministic TCP runtime — it is the
//! proof that the recorded broadcasts follow from the recorded ingests
//! under Algorithm 1.

use super::event::Event;
use super::journal::JournalReader;
use crate::config::Config;
use crate::coordinator::{Broadcast, Server, ServerStep};
use crate::quant::QuantizedMsg;
use crate::scenario::StalenessHist;
use anyhow::{anyhow, bail, Result};

/// Summary of a successful replay.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ReplayReport {
    /// Server steps reproduced.
    pub steps: u64,
    /// Ingest events fed to the server (flat uploads + partials).
    pub uploads: u64,
    /// Broadcast payloads verified byte-for-byte.
    pub broadcasts_checked: u64,
    /// Checkpoint events encountered (not verified here; resume is).
    pub checkpoints: u64,
    /// True when the journal ended in a `Final` event whose totals and
    /// model were verified. A journal from a killed run has none — the
    /// prefix still replays, which is what makes resume trustworthy.
    pub finalized: bool,
}

/// Replay a journal read from `path`. See [`replay_events`].
pub fn replay_file(path: &str) -> Result<ReplayReport> {
    replay_events(&JournalReader::read(path)?)
}

/// Replay a journal: rebuild the config from `Meta`, the server from
/// `Init`, re-register codecs from `Codec` events, then feed every
/// `Ingest`/`IngestPartial` and check each produced broadcast against
/// the recorded `Broadcast` event (payload, step, absolute flag), each
/// `Step` event's cumulative totals, and the `Final` model bits.
pub fn replay_events(events: &[Event]) -> Result<ReplayReport> {
    let mut report = ReplayReport::default();
    let mut cfg: Option<Config> = None;
    let mut meta_d = 0usize;
    let mut server: Option<Server> = None;
    // broadcasts produced by an ingest-triggered step, awaiting their
    // journal events (one per downlink family, family 0 first)
    let mut produced: Vec<Broadcast> = Vec::new();
    // update slots since the last step (checked against Step.k)
    let mut slots: u64 = 0;

    for (i, ev) in events.iter().enumerate() {
        let at = |what: &str| anyhow!("journal event {i}: {what}");
        match ev {
            Event::Meta { algorithm, d, config, .. } => {
                if cfg.is_some() {
                    bail!(at("second meta event"));
                }
                let mut c = Config::default();
                c.apply(config)
                    .map_err(|e| anyhow!("journal event {i}: bad embedded config: {e}"))?;
                if c.fl.algorithm.name() != algorithm {
                    bail!(at(&format!(
                        "meta algorithm '{algorithm}' disagrees with embedded config '{}'",
                        c.fl.algorithm.name()
                    )));
                }
                meta_d = *d as usize;
                cfg = Some(c);
            }
            Event::Init { x0, server_seed } => {
                let c = cfg.as_ref().ok_or_else(|| at("init before meta"))?;
                if x0.len() != meta_d {
                    bail!(at(&format!(
                        "init model has d={} but meta declared d={meta_d}",
                        x0.len()
                    )));
                }
                if server.is_some() {
                    bail!(at("second init event"));
                }
                server = Some(Server::build(c, x0.clone(), *server_seed)?);
            }
            Event::Codec { reg, id, spec } => {
                let s = server.as_mut().ok_or_else(|| at("codec before init"))?;
                let got = match reg.as_str() {
                    "client" => s.register_client_codec(spec)?,
                    "server" => s.register_server_codec(spec)?,
                    "partial" => s.register_partial_codec(spec)?,
                    other => bail!(at(&format!("unknown codec registry '{other}'"))),
                } as u64;
                if got != *id {
                    bail!(at(&format!(
                        "codec '{spec}' registered as id {got}, journal says {id} — \
                         registration order diverged"
                    )));
                }
            }
            Event::Ingest { worker, codec, staleness, payload, .. } => {
                let s = server.as_mut().ok_or_else(|| at("ingest before init"))?;
                if !produced.is_empty() {
                    bail!(at("ingest while a produced broadcast is still unchecked"));
                }
                let msg = QuantizedMsg { payload: payload.clone(), d: s.d() };
                slots += 1;
                match s.ingest_from(&msg, *staleness, *codec as usize).map_err(|e| {
                    anyhow!("journal event {i}: ingest from worker {worker} failed: {e}")
                })? {
                    ServerStep::Buffered => {}
                    ServerStep::Stepped(b) => produced = b,
                }
                report.uploads += 1;
            }
            Event::IngestPartial {
                worker,
                codec,
                count,
                stale_counts,
                stale_sum,
                stale_max,
                stale_n,
                payload,
                ..
            } => {
                let s = server.as_mut().ok_or_else(|| at("ingest before init"))?;
                if !produced.is_empty() {
                    bail!(at("ingest while a produced broadcast is still unchecked"));
                }
                let msg = QuantizedMsg { payload: payload.clone(), d: s.d() };
                let hist = StalenessHist::from_parts(
                    stale_counts.clone(),
                    *stale_sum,
                    *stale_max,
                    *stale_n,
                );
                slots += count;
                match s
                    .ingest_partial(&msg, *count as u32, &hist, *codec as usize)
                    .map_err(|e| {
                        anyhow!("journal event {i}: partial from edge {worker} failed: {e}")
                    })? {
                    ServerStep::Buffered => {}
                    ServerStep::Stepped(b) => produced = b,
                }
                report.uploads += 1;
            }
            Event::Step { step, k, uploads, upload_bytes, broadcast_bytes, .. } => {
                let s = server.as_ref().ok_or_else(|| at("step before init"))?;
                if s.t() != *step {
                    bail!(at(&format!("server is at t={} but journal says {step}", s.t())));
                }
                if slots != *k {
                    bail!(at(&format!("step consumed {slots} slots, journal says {k}")));
                }
                if s.comm.uploads != *uploads
                    || s.comm.upload_bytes != *upload_bytes
                    || s.comm.broadcast_bytes != *broadcast_bytes
                {
                    bail!(at(&format!(
                        "comm totals diverged at step {step}: replay \
                         uploads={}/{}B broadcast={}B, journal \
                         uploads={uploads}/{upload_bytes}B broadcast={broadcast_bytes}B",
                        s.comm.uploads, s.comm.upload_bytes, s.comm.broadcast_bytes
                    )));
                }
                slots = 0;
                report.steps += 1;
            }
            Event::Broadcast { step, absolute, codec, payload, .. } => {
                if produced.is_empty() {
                    bail!(at("broadcast event without a produced broadcast"));
                }
                let b = produced.remove(0);
                if b.t != *step {
                    bail!(at(&format!("broadcast at t={} but journal says {step}", b.t)));
                }
                if b.absolute != *absolute {
                    bail!(at("broadcast absolute flag diverged"));
                }
                if b.codec as u64 != *codec {
                    bail!(at(&format!(
                        "broadcast family diverged at step {step}: replay \
                         produced family {}, journal says {codec}",
                        b.codec
                    )));
                }
                if &b.msg.payload != payload {
                    bail!(at(&format!(
                        "broadcast payload diverged at step {step} — \
                         replay produced different bits than the recorded run"
                    )));
                }
                report.broadcasts_checked += 1;
            }
            Event::Rekey { worker, old, new, spec, .. } => {
                // the switch itself moves no server state (ingests carry
                // their own codec ids), but the ids it names must exist —
                // a rekey to an unregistered codec means the journal lost
                // a Codec event
                let s = server.as_ref().ok_or_else(|| at("rekey before init"))?;
                let n = s.num_client_codecs() as u64;
                if *old >= n || *new >= n {
                    bail!(at(&format!(
                        "rekey of worker {worker} switches codec {old} -> {new}, but only \
                         {n} client codecs are registered at this point"
                    )));
                }
                if s.client_codec_name(*new as usize) != *spec {
                    bail!(at(&format!(
                        "rekey spec '{spec}' disagrees with registry entry '{}' at id {new}",
                        s.client_codec_name(*new as usize)
                    )));
                }
            }
            // informational for replay: arrivals/evals describe the
            // population and the curve, not the server's input stream
            Event::Arrival { .. } | Event::Eval { .. } => {}
            Event::Checkpoint { .. } => report.checkpoints += 1,
            Event::Final { step, uploads, upload_bytes, broadcasts, broadcast_bytes, model } => {
                let s = server.as_ref().ok_or_else(|| at("final before init"))?;
                if i + 1 != events.len() {
                    bail!(at("final event is not the last event"));
                }
                if !produced.is_empty() {
                    bail!(at(&format!(
                        "final event with {} unchecked broadcasts",
                        produced.len()
                    )));
                }
                if s.t() != *step {
                    bail!(at(&format!("final step {step} but replay reached t={}", s.t())));
                }
                if s.comm.uploads != *uploads
                    || s.comm.upload_bytes != *upload_bytes
                    || s.comm.broadcasts != *broadcasts
                    || s.comm.broadcast_bytes != *broadcast_bytes
                {
                    bail!(at("final comm totals diverged"));
                }
                if s.model().len() != model.len()
                    || s
                        .model()
                        .iter()
                        .zip(model.iter())
                        .any(|(a, b)| a.to_bits() != b.to_bits())
                {
                    bail!(at("final model diverged (bitwise)"));
                }
                report.finalized = true;
            }
        }
    }
    if server.is_none() {
        bail!("journal has no init event — nothing to replay");
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::quant::parse_spec;
    use crate::util::prng::Prng;

    /// Record a small qafel run (K=2, qsgd both ways) the way a runtime
    /// would, returning the event stream.
    fn record_run(tamper: bool) -> Vec<Event> {
        let mut cfg = Config::default();
        cfg.fl.buffer_size = 2;
        cfg.quant.client = "qsgd:8".into();
        cfg.quant.server = "qsgd:4".into();
        let d = 128 + 9;
        let seed = 11u64;
        let mut server = Server::build(&cfg, vec![0.0; d], seed).unwrap();

        let mut events = vec![
            Event::Meta {
                runtime: "sim".into(),
                algorithm: cfg.fl.algorithm.name().into(),
                d: d as u64,
                seed,
                fingerprint: crate::telemetry::run_fingerprint(&cfg, seed),
                git: None,
                config: cfg.to_json(),
            },
            Event::Init { x0: vec![0.0; d], server_seed: seed },
        ];
        let top = server.register_client_codec("top:0.25").unwrap();
        events.push(Event::Codec { reg: "client".into(), id: top as u64, spec: "top:0.25".into() });

        let qc = parse_spec("qsgd:8").unwrap();
        let qt = parse_spec("top:0.25").unwrap();
        let mut rng = Prng::new(3);
        for round in 0..8u64 {
            let delta: Vec<f32> =
                (0..d).map(|i| (i as f32 * 0.05 + round as f32).sin()).collect();
            let (codec, msg) = if round % 3 == 2 {
                (top as u64, qt.quantize(&delta, &mut rng))
            } else {
                (0u64, qc.quantize(&delta, &mut rng))
            };
            events.push(Event::Ingest {
                time: round as f64,
                step: server.t(),
                worker: round,
                codec,
                staleness: round % 2,
                payload: msg.payload.clone(),
            });
            if let ServerStep::Stepped(bs) =
                server.ingest_from(&msg, round % 2, codec as usize).unwrap()
            {
                events.push(Event::Step {
                    time: round as f64,
                    step: server.t(),
                    k: 2,
                    uploads: server.comm.uploads,
                    upload_bytes: server.comm.upload_bytes,
                    broadcast_bytes: server.comm.broadcast_bytes,
                    stale_mean: server.staleness_mean(),
                    stale_max: server.staleness_max,
                    stages: None,
                });
                for b in bs {
                    events.push(Event::Broadcast {
                        time: round as f64,
                        step: b.t,
                        absolute: b.absolute,
                        codec: b.codec as u64,
                        payload: b.msg.payload,
                    });
                }
            }
        }
        events.push(Event::Final {
            step: server.t(),
            uploads: server.comm.uploads,
            upload_bytes: server.comm.upload_bytes,
            broadcasts: server.comm.broadcasts,
            broadcast_bytes: server.comm.broadcast_bytes,
            model: server.model().to_vec(),
        });
        if tamper {
            // flip one bit of one broadcast payload
            for ev in events.iter_mut() {
                if let Event::Broadcast { payload, .. } = ev {
                    payload[0] ^= 1;
                    break;
                }
            }
        }
        events
    }

    #[test]
    fn recorded_run_replays_bit_identically() {
        let events = record_run(false);
        let report = replay_events(&events).unwrap();
        assert_eq!(report.steps, 4);
        assert_eq!(report.uploads, 8);
        assert_eq!(report.broadcasts_checked, 4);
        assert!(report.finalized);
        // the journal survives a serialization round trip and still
        // replays (what the JSONL file guarantees end to end)
        let lines: Vec<String> = events.iter().map(Event::to_line).collect();
        let back: Vec<Event> =
            lines.iter().map(|l| Event::from_line(l).unwrap()).collect();
        assert_eq!(replay_events(&back).unwrap(), report);
    }

    /// Record a run with a second downlink family (per-tier
    /// `quant_server` preset): every step emits one broadcast per
    /// family, journaled family 0 first with its family id.
    fn record_multi_family_run(tamper_family: bool) -> Vec<Event> {
        let mut cfg = Config::default();
        cfg.fl.buffer_size = 2;
        cfg.quant.client = "qsgd:8".into();
        cfg.quant.server = "qsgd:4".into();
        let d = 96 + 5;
        let seed = 13u64;
        let mut server = Server::build(&cfg, vec![0.0; d], seed).unwrap();
        let mut events = vec![
            Event::Meta {
                runtime: "sim".into(),
                algorithm: cfg.fl.algorithm.name().into(),
                d: d as u64,
                seed,
                fingerprint: crate::telemetry::run_fingerprint(&cfg, seed),
                git: None,
                config: cfg.to_json(),
            },
            Event::Init { x0: vec![0.0; d], server_seed: seed },
        ];
        let fam = server.register_server_codec("qsgd:2").unwrap();
        assert_eq!(fam, 1);
        events.push(Event::Codec { reg: "server".into(), id: fam as u64, spec: "qsgd:2".into() });

        let qc = parse_spec("qsgd:8").unwrap();
        let mut rng = Prng::new(5);
        for round in 0..6u64 {
            let delta: Vec<f32> =
                (0..d).map(|i| (i as f32 * 0.07 + round as f32).cos()).collect();
            let msg = qc.quantize(&delta, &mut rng);
            events.push(Event::Ingest {
                time: round as f64,
                step: server.t(),
                worker: round,
                codec: 0,
                staleness: 0,
                payload: msg.payload.clone(),
            });
            if let ServerStep::Stepped(bs) = server.ingest_from(&msg, 0, 0).unwrap() {
                assert_eq!(bs.len(), 2, "one broadcast per family");
                events.push(Event::Step {
                    time: round as f64,
                    step: server.t(),
                    k: 2,
                    uploads: server.comm.uploads,
                    upload_bytes: server.comm.upload_bytes,
                    broadcast_bytes: server.comm.broadcast_bytes,
                    stale_mean: server.staleness_mean(),
                    stale_max: server.staleness_max,
                    stages: None,
                });
                for b in bs {
                    events.push(Event::Broadcast {
                        time: round as f64,
                        step: b.t,
                        absolute: b.absolute,
                        codec: b.codec as u64,
                        payload: b.msg.payload,
                    });
                }
            }
        }
        events.push(Event::Final {
            step: server.t(),
            uploads: server.comm.uploads,
            upload_bytes: server.comm.upload_bytes,
            broadcasts: server.comm.broadcasts,
            broadcast_bytes: server.comm.broadcast_bytes,
            model: server.model().to_vec(),
        });
        if tamper_family {
            // swap a family-1 broadcast's recorded family id
            for ev in events.iter_mut() {
                if let Event::Broadcast { codec, .. } = ev {
                    if *codec == 1 {
                        *codec = 0;
                        break;
                    }
                }
            }
        }
        events
    }

    #[test]
    fn multi_family_run_replays_per_family_broadcasts() {
        let events = record_multi_family_run(false);
        let report = replay_events(&events).unwrap();
        assert_eq!(report.steps, 3);
        assert_eq!(report.broadcasts_checked, 6, "two families per step");
        assert!(report.finalized);
        // survives the JSONL round trip (including the codec field)
        let lines: Vec<String> = events.iter().map(Event::to_line).collect();
        let back: Vec<Event> =
            lines.iter().map(|l| Event::from_line(l).unwrap()).collect();
        assert_eq!(replay_events(&back).unwrap(), report);
    }

    /// Record a run whose single worker is rekeyed mid-run (qsgd:8 ->
    /// top:0.25 after the second step): the new codec's registration and
    /// the Rekey event land between two ingests, exactly as the adaptive
    /// controller journals them.
    fn record_rekey_run(lose_codec_event: bool) -> Vec<Event> {
        let mut cfg = Config::default();
        cfg.fl.buffer_size = 2;
        cfg.quant.client = "qsgd:8".into();
        cfg.quant.server = "qsgd:4".into();
        let d = 64 + 3;
        let seed = 17u64;
        let mut server = Server::build(&cfg, vec![0.0; d], seed).unwrap();
        let mut events = vec![
            Event::Meta {
                runtime: "tcp".into(),
                algorithm: cfg.fl.algorithm.name().into(),
                d: d as u64,
                seed,
                fingerprint: crate::telemetry::run_fingerprint(&cfg, seed),
                git: None,
                config: cfg.to_json(),
            },
            Event::Init { x0: vec![0.0; d], server_seed: seed },
        ];
        let qc = parse_spec("qsgd:8").unwrap();
        let qt = parse_spec("top:0.25").unwrap();
        let mut rng = Prng::new(9);
        let mut codec = 0u64;
        for round in 0..8u64 {
            if round == 4 {
                // the controller downshifts worker 0 at a step boundary
                let new = server.register_client_codec("top:0.25").unwrap();
                events.push(Event::Codec {
                    reg: "client".into(),
                    id: new as u64,
                    spec: "top:0.25".into(),
                });
                events.push(Event::Rekey {
                    time: round as f64,
                    step: server.t(),
                    worker: 0,
                    old: codec,
                    new: new as u64,
                    spec: server.client_codec_name(new),
                });
                codec = new as u64;
            }
            let delta: Vec<f32> =
                (0..d).map(|i| (i as f32 * 0.03 + round as f32).sin()).collect();
            let msg = if codec == 0 {
                qc.quantize(&delta, &mut rng)
            } else {
                qt.quantize(&delta, &mut rng)
            };
            events.push(Event::Ingest {
                time: round as f64,
                step: server.t(),
                worker: 0,
                codec,
                staleness: 0,
                payload: msg.payload.clone(),
            });
            if let ServerStep::Stepped(bs) = server.ingest_from(&msg, 0, codec as usize).unwrap()
            {
                events.push(Event::Step {
                    time: round as f64,
                    step: server.t(),
                    k: 2,
                    uploads: server.comm.uploads,
                    upload_bytes: server.comm.upload_bytes,
                    broadcast_bytes: server.comm.broadcast_bytes,
                    stale_mean: server.staleness_mean(),
                    stale_max: server.staleness_max,
                    stages: None,
                });
                for b in bs {
                    events.push(Event::Broadcast {
                        time: round as f64,
                        step: b.t,
                        absolute: b.absolute,
                        codec: b.codec as u64,
                        payload: b.msg.payload,
                    });
                }
            }
        }
        events.push(Event::Final {
            step: server.t(),
            uploads: server.comm.uploads,
            upload_bytes: server.comm.upload_bytes,
            broadcasts: server.comm.broadcasts,
            broadcast_bytes: server.comm.broadcast_bytes,
            model: server.model().to_vec(),
        });
        if lose_codec_event {
            events.retain(|ev| {
                !matches!(ev, Event::Codec { spec, .. } if spec == "top:0.25")
            });
        }
        events
    }

    #[test]
    fn rekeyed_run_replays_bit_identically() {
        let events = record_rekey_run(false);
        let report = replay_events(&events).unwrap();
        assert_eq!(report.steps, 4);
        assert_eq!(report.uploads, 8);
        assert!(report.finalized);
        // the rekey + mid-run codec events survive the JSONL round trip
        let lines: Vec<String> = events.iter().map(Event::to_line).collect();
        let back: Vec<Event> =
            lines.iter().map(|l| Event::from_line(l).unwrap()).collect();
        assert_eq!(replay_events(&back).unwrap(), report);
    }

    #[test]
    fn rekey_to_an_unregistered_codec_fails_the_replay() {
        // dropping the Codec event makes the Rekey point at an id the
        // registry does not have — replay must refuse, not guess
        let events = record_rekey_run(true);
        let err = replay_events(&events).unwrap_err().to_string();
        assert!(err.contains("rekey"), "{err}");
    }

    #[test]
    fn tampered_broadcast_family_fails_the_replay() {
        let events = record_multi_family_run(true);
        let err = replay_events(&events).unwrap_err().to_string();
        assert!(err.contains("family diverged"), "{err}");
    }

    #[test]
    fn tampered_broadcast_fails_the_replay() {
        let events = record_run(true);
        let err = replay_events(&events).unwrap_err().to_string();
        assert!(err.contains("diverged"), "{err}");
    }

    #[test]
    fn truncated_journal_replays_as_unfinalized_prefix() {
        let mut events = record_run(false);
        events.truncate(events.len() - 3); // drop final + last step pair
        let report = replay_events(&events).unwrap();
        assert!(!report.finalized);
        assert!(report.steps < 4);
    }

    #[test]
    fn structural_errors_are_loud() {
        // no meta/init
        assert!(replay_events(&[]).is_err());
        let events = record_run(false);
        // init before meta
        let mut reordered = events.clone();
        reordered.swap(0, 1);
        assert!(replay_events(&reordered).is_err());
        // codec id mismatch
        let mut bad = events.clone();
        for ev in bad.iter_mut() {
            if let Event::Codec { id, .. } = ev {
                *id += 7;
            }
        }
        let err = replay_events(&bad).unwrap_err().to_string();
        assert!(err.contains("registration order"), "{err}");
        // a journal whose broadcast payload length mismatches the codec
        // fails inside the server, with the event index attached
        let mut bad = events;
        for ev in bad.iter_mut() {
            if let Event::Ingest { payload, .. } = ev {
                payload.pop();
            }
        }
        let err = replay_events(&bad).unwrap_err().to_string();
        assert!(err.contains("journal event"), "{err}");
    }
}
