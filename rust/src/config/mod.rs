//! Typed experiment configuration with TOML file loading and CLI
//! overrides.
//!
//! Defaults reproduce the paper's Appendix D setup: K = 10, client lr
//! 4.7e-6, server lr 1000, server momentum 0.3, half-normal training
//! durations with sigma = 1, constant-rate arrivals, LEAF partition seed
//! 1549775860, target validation accuracy 90%.

pub mod toml;

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};

/// Which coordination algorithm to run (§ system inventory S1–S5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// The paper's contribution: bidirectional quantization + hidden state.
    Qafel,
    /// Nguyen et al. 2022: buffered aggregation, full-precision messages.
    FedBuff,
    /// Buffer size 1 (Xie et al. 2020 style), staleness-scaled.
    FedAsync,
    /// Ablation: quantize the server model directly (no hidden state) —
    /// demonstrates the error propagation QAFeL avoids.
    DirectQuant,
}

impl Algorithm {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "qafel" => Algorithm::Qafel,
            "fedbuff" => Algorithm::FedBuff,
            "fedasync" => Algorithm::FedAsync,
            "directquant" | "direct-quant" | "direct_quant" => Algorithm::DirectQuant,
            other => bail!("unknown algorithm '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Qafel => "qafel",
            Algorithm::FedBuff => "fedbuff",
            Algorithm::FedAsync => "fedasync",
            Algorithm::DirectQuant => "directquant",
        }
    }
}

/// Federated-optimization hyperparameters (paper Appendix D).
#[derive(Clone, Debug)]
pub struct FlConfig {
    pub algorithm: Algorithm,
    /// Buffer size K: client updates aggregated per server step.
    pub buffer_size: usize,
    /// Local (client) learning rate eta_l.
    pub client_lr: f32,
    /// Global (server) learning rate eta_g.
    pub server_lr: f32,
    /// Server Nesterov-free momentum beta (paper: 0.3; theory omits it).
    pub server_momentum: f32,
    /// Scale update weights by 1/sqrt(1 + staleness) (paper Fig. 3 runs).
    pub staleness_scaling: bool,
    /// Local SGD steps P per client round (must match the AOT artifact).
    pub local_steps: usize,
    /// Clip each client delta to this l2 norm before quantization
    /// (FLSim, the paper's implementation base, clips client updates);
    /// 0 disables clipping.
    pub clip_norm: f32,
    /// Server aggregation shards S: the server step (accumulate,
    /// momentum + eta_g apply, hidden-state diff, Q_s encode/apply) runs
    /// in parallel over S contiguous, bucket-aligned ranges of the model
    /// vector (DESIGN_SHARDING.md). 1 = sequential. Broadcast payloads
    /// are bit-identical for every S.
    pub shards: usize,
}

impl Default for FlConfig {
    fn default() -> Self {
        FlConfig {
            algorithm: Algorithm::Qafel,
            buffer_size: 10,
            // The paper's CelebA values are eta_l = 4.7e-6, eta_g = 1000;
            // re-tuned for the synthetic substitute (equivalent product,
            // stable with clipping): see EXPERIMENTS.md §Setup.
            client_lr: 1e-2,
            server_lr: 1.0,
            server_momentum: 0.3,
            staleness_scaling: false,
            local_steps: 1,
            clip_norm: 1.0,
            shards: 1,
        }
    }
}

/// Quantizer specs, parsed by `quant::parse_spec`:
/// `"qsgd:<bits>"`, `"top:<fraction>"`, `"rand:<fraction>"`, `"none"`.
#[derive(Clone, Debug)]
pub struct QuantConfig {
    pub client: String,
    pub server: String,
}

impl Default for QuantConfig {
    fn default() -> Self {
        // paper §4: 4-bit qsgd at both client and server
        QuantConfig { client: "qsgd:4".into(), server: "qsgd:4".into() }
    }
}

/// Simulator configuration (paper Appendix D timing model).
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Target expected number of clients training in parallel; determines
    /// the constant arrival rate via rate = concurrency / E[duration].
    pub concurrency: usize,
    /// Duration distribution: "halfnormal" | "lognormal" | "fixed".
    pub duration: String,
    pub duration_sigma: f64,
    /// Arrival process: "constant" | "poisson".
    pub arrival: String,
    /// Server steps between validation evaluations.
    pub eval_every: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            concurrency: 100,
            duration: "halfnormal".into(),
            duration_sigma: 1.0,
            arrival: "constant".into(),
            eval_every: 5,
        }
    }
}

/// Synthetic CelebA-LEAF dataset configuration (DESIGN.md §4).
#[derive(Clone, Debug)]
pub struct DataConfig {
    /// Total users before the 80/10/10 train/val/test user split.
    pub num_users: usize,
    /// LEAF partition seed (paper: 1549775860).
    pub seed: u64,
    /// Per-user sample count range (LEAF CelebA: 1..=32).
    pub min_samples: usize,
    pub max_samples: usize,
    /// Observation noise sigma added to each image.
    pub noise: f32,
    /// Strength of the per-user style offset (non-iid-ness).
    pub style: f32,
    /// Class-template signal strength.
    pub signal: f32,
    /// Max validation samples used per evaluation (subsampled).
    pub eval_samples: usize,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig {
            num_users: 1000,
            seed: 1_549_775_860,
            min_samples: 1,
            max_samples: 32,
            noise: 0.8,
            style: 1.0,
            signal: 1.0,
            eval_samples: 2048,
        }
    }
}

/// Stopping criteria for a run.
#[derive(Clone, Debug)]
pub struct StopConfig {
    /// Paper's metric: communication to reach this validation accuracy.
    pub target_accuracy: f64,
    /// Hard cap on client uploads (paper's 2-bit worst case ran 150k).
    pub max_uploads: u64,
    /// Hard cap on server steps.
    pub max_server_steps: u64,
}

impl Default for StopConfig {
    fn default() -> Self {
        StopConfig {
            target_accuracy: 0.90,
            max_uploads: 200_000,
            max_server_steps: 50_000,
        }
    }
}

/// Top-level configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub name: String,
    pub artifacts_dir: String,
    pub out_dir: String,
    /// Seeds for repeated runs; the paper reports mean ± std over 3.
    pub seeds: Vec<u64>,
    pub fl: FlConfig,
    pub quant: QuantConfig,
    pub sim: SimConfig,
    pub data: DataConfig,
    pub stop: StopConfig,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            name: "qafel".into(),
            artifacts_dir: "artifacts".into(),
            out_dir: "reports".into(),
            seeds: vec![1, 2, 3],
            fl: FlConfig::default(),
            quant: QuantConfig::default(),
            sim: SimConfig::default(),
            data: DataConfig::default(),
            stop: StopConfig::default(),
        }
    }
}

macro_rules! get_num {
    ($obj:expr, $path:expr, $dst:expr, $ty:ty) => {
        if let Some(v) = $obj.at($path) {
            $dst = v
                .as_f64()
                .ok_or_else(|| anyhow!("config {} must be a number", $path.join(".")))?
                as $ty;
        }
    };
}

macro_rules! get_bool {
    ($obj:expr, $path:expr, $dst:expr) => {
        if let Some(v) = $obj.at($path) {
            $dst = v
                .as_bool()
                .ok_or_else(|| anyhow!("config {} must be a bool", $path.join(".")))?;
        }
    };
}

macro_rules! get_str {
    ($obj:expr, $path:expr, $dst:expr) => {
        if let Some(v) = $obj.at($path) {
            $dst = v
                .as_str()
                .ok_or_else(|| anyhow!("config {} must be a string", $path.join(".")))?
                .to_string();
        }
    };
}

impl Config {
    /// Load from a TOML file, starting from defaults.
    pub fn from_file(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config file {path}"))?;
        let doc = toml::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        let mut cfg = Config::default();
        cfg.apply(&doc)?;
        Ok(cfg)
    }

    /// Overlay values from a parsed TOML/JSON document.
    pub fn apply(&mut self, doc: &Json) -> Result<()> {
        get_str!(doc, &["name"], self.name);
        get_str!(doc, &["artifacts_dir"], self.artifacts_dir);
        get_str!(doc, &["out_dir"], self.out_dir);
        if let Some(arr) = doc.at(&["seeds"]).and_then(|v| v.as_arr()) {
            self.seeds = arr
                .iter()
                .map(|v| v.as_f64().map(|f| f as u64).ok_or_else(|| anyhow!("bad seed")))
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(v) = doc.at(&["fl", "algorithm"]) {
            self.fl.algorithm =
                Algorithm::parse(v.as_str().ok_or_else(|| anyhow!("fl.algorithm must be str"))?)?;
        }
        get_num!(doc, &["fl", "buffer_size"], self.fl.buffer_size, usize);
        get_num!(doc, &["fl", "client_lr"], self.fl.client_lr, f32);
        get_num!(doc, &["fl", "server_lr"], self.fl.server_lr, f32);
        get_num!(doc, &["fl", "server_momentum"], self.fl.server_momentum, f32);
        get_bool!(doc, &["fl", "staleness_scaling"], self.fl.staleness_scaling);
        get_num!(doc, &["fl", "local_steps"], self.fl.local_steps, usize);
        get_num!(doc, &["fl", "clip_norm"], self.fl.clip_norm, f32);
        get_num!(doc, &["fl", "shards"], self.fl.shards, usize);

        get_str!(doc, &["quant", "client"], self.quant.client);
        get_str!(doc, &["quant", "server"], self.quant.server);

        get_num!(doc, &["sim", "concurrency"], self.sim.concurrency, usize);
        get_str!(doc, &["sim", "duration"], self.sim.duration);
        get_num!(doc, &["sim", "duration_sigma"], self.sim.duration_sigma, f64);
        get_str!(doc, &["sim", "arrival"], self.sim.arrival);
        get_num!(doc, &["sim", "eval_every"], self.sim.eval_every, usize);

        get_num!(doc, &["data", "num_users"], self.data.num_users, usize);
        get_num!(doc, &["data", "seed"], self.data.seed, u64);
        get_num!(doc, &["data", "min_samples"], self.data.min_samples, usize);
        get_num!(doc, &["data", "max_samples"], self.data.max_samples, usize);
        get_num!(doc, &["data", "noise"], self.data.noise, f32);
        get_num!(doc, &["data", "style"], self.data.style, f32);
        get_num!(doc, &["data", "signal"], self.data.signal, f32);
        get_num!(doc, &["data", "eval_samples"], self.data.eval_samples, usize);

        get_num!(doc, &["stop", "target_accuracy"], self.stop.target_accuracy, f64);
        get_num!(doc, &["stop", "max_uploads"], self.stop.max_uploads, u64);
        get_num!(doc, &["stop", "max_server_steps"], self.stop.max_server_steps, u64);
        self.validate()
    }

    /// Apply one `section.key=value` CLI override.
    pub fn set(&mut self, assignment: &str) -> Result<()> {
        let (path, value) = assignment
            .split_once('=')
            .ok_or_else(|| anyhow!("override must look like sim.concurrency=500"))?;
        // Reuse the TOML value grammar for the right-hand side.
        let parsed = toml::parse(&format!("__v = {}", value.trim()))
            .map_err(|e| anyhow!("bad override value '{value}': {e}"))?;
        let val = parsed.get("__v").unwrap().clone();
        // Build a nested single-entry doc and overlay it.
        let mut doc = val;
        for part in path.trim().split('.').rev() {
            let mut m = std::collections::BTreeMap::new();
            m.insert(part.to_string(), doc);
            doc = Json::Obj(m);
        }
        self.apply(&doc)
    }

    /// Consistency checks (fail fast, before any compute).
    pub fn validate(&self) -> Result<()> {
        if self.fl.buffer_size == 0 {
            bail!("fl.buffer_size (K) must be >= 1");
        }
        if self.fl.local_steps == 0 {
            bail!("fl.local_steps (P) must be >= 1");
        }
        if self.fl.shards == 0 {
            bail!("fl.shards (S) must be >= 1");
        }
        if self.fl.shards > 256 {
            bail!("fl.shards (S) must be <= 256 (one thread per shard)");
        }
        if self.seeds.is_empty() {
            bail!("need at least one seed");
        }
        if self.data.min_samples == 0 || self.data.min_samples > self.data.max_samples {
            bail!("data.min_samples must be in [1, max_samples]");
        }
        if !(0.0..=1.0).contains(&self.stop.target_accuracy) {
            bail!("stop.target_accuracy must be in [0,1]");
        }
        if self.sim.concurrency == 0 {
            bail!("sim.concurrency must be >= 1");
        }
        match self.sim.duration.as_str() {
            "halfnormal" | "lognormal" | "fixed" => {}
            other => bail!("unknown sim.duration '{other}'"),
        }
        match self.sim.arrival.as_str() {
            "constant" | "poisson" => {}
            other => bail!("unknown sim.arrival '{other}'"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_appendix_d() {
        let c = Config::default();
        assert_eq!(c.fl.buffer_size, 10);
        assert!((c.fl.client_lr - 1e-2).abs() < 1e-9); // re-tuned, see docs
        assert_eq!(c.fl.server_lr, 1.0);
        assert!((c.fl.server_momentum - 0.3).abs() < 1e-7);
        assert_eq!(c.quant.client, "qsgd:4");
        assert_eq!(c.quant.server, "qsgd:4");
        assert_eq!(c.data.seed, 1_549_775_860);
        assert_eq!(c.stop.target_accuracy, 0.90);
        assert_eq!(c.data.max_samples, 32);
        c.validate().unwrap();
    }

    #[test]
    fn toml_overlay() {
        let doc = toml::parse(
            "[fl]\nalgorithm = \"fedbuff\"\nbuffer_size = 5\n[sim]\nconcurrency = 500\n",
        )
        .unwrap();
        let mut c = Config::default();
        c.apply(&doc).unwrap();
        assert_eq!(c.fl.algorithm, Algorithm::FedBuff);
        assert_eq!(c.fl.buffer_size, 5);
        assert_eq!(c.sim.concurrency, 500);
        // untouched fields keep defaults
        assert_eq!(c.fl.server_lr, 1.0);
    }

    #[test]
    fn cli_set_overrides() {
        let mut c = Config::default();
        c.set("sim.concurrency=1000").unwrap();
        c.set("quant.client=\"qsgd:2\"").unwrap();
        c.set("fl.staleness_scaling=true").unwrap();
        assert_eq!(c.sim.concurrency, 1000);
        assert_eq!(c.quant.client, "qsgd:2");
        assert!(c.fl.staleness_scaling);
        assert!(c.set("nonsense").is_err());
    }

    #[test]
    fn shards_knob_round_trips() {
        let c = Config::default();
        assert_eq!(c.fl.shards, 1);
        let doc = toml::parse("[fl]\nshards = 4\n").unwrap();
        let mut c = Config::default();
        c.apply(&doc).unwrap();
        assert_eq!(c.fl.shards, 4);
        let mut c = Config::default();
        c.set("fl.shards=8").unwrap();
        assert_eq!(c.fl.shards, 8);
        c.fl.shards = 0;
        assert!(c.validate().is_err());
        c.fl.shards = 10_000;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut c = Config::default();
        c.fl.buffer_size = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.sim.duration = "uniform".into();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.stop.target_accuracy = 1.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn algorithm_parse() {
        assert_eq!(Algorithm::parse("QAFeL").unwrap(), Algorithm::Qafel);
        assert_eq!(Algorithm::parse("direct-quant").unwrap(), Algorithm::DirectQuant);
        assert!(Algorithm::parse("sgd").is_err());
    }
}
