//! Typed experiment configuration with TOML file loading and CLI
//! overrides.
//!
//! Defaults reproduce the paper's Appendix D setup: K = 10, client lr
//! 4.7e-6, server lr 1000, server momentum 0.3, half-normal training
//! durations with sigma = 1, constant-rate arrivals, LEAF partition seed
//! 1549775860, target validation accuracy 90%.

pub mod toml;

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};

/// Which coordination algorithm to run (§ system inventory S1–S5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// The paper's contribution: bidirectional quantization + hidden state.
    Qafel,
    /// Nguyen et al. 2022: buffered aggregation, full-precision messages.
    FedBuff,
    /// Buffer size 1 (Xie et al. 2020 style), staleness-scaled.
    FedAsync,
    /// Ablation: quantize the server model directly (no hidden state) —
    /// demonstrates the error propagation QAFeL avoids.
    DirectQuant,
}

impl Algorithm {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "qafel" => Algorithm::Qafel,
            "fedbuff" => Algorithm::FedBuff,
            "fedasync" => Algorithm::FedAsync,
            "directquant" | "direct-quant" | "direct_quant" => Algorithm::DirectQuant,
            other => bail!("unknown algorithm '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Qafel => "qafel",
            Algorithm::FedBuff => "fedbuff",
            Algorithm::FedAsync => "fedasync",
            Algorithm::DirectQuant => "directquant",
        }
    }
}

/// Server-side robust aggregation (`[fl.robust]`, ARCHITECTURE.md
/// §Robust aggregation). Per-update norm bounding before accumulate and
/// a coordinate-wise trimmed mean over the buffer, both running as
/// range-sharded stages on the shard pool. Disabled by default — an
/// absent table leaves every run byte-identical to the plain buffered
/// mean (and invisible in the config fingerprint).
#[derive(Clone, Debug)]
pub struct RobustConfig {
    /// Master switch. `false` (the default) means the plain mean runs
    /// and none of the knobs below are even validated.
    pub enabled: bool,
    /// Bound each decoded client update to this l2 norm *at the server*
    /// (scale = min(1, clip_norm / ||u||), folded into the staleness
    /// weight so sharded accumulate stays bit-identical). 0 = no
    /// clipping. Distinct from `fl.clip_norm`, which clips on the
    /// client before quantization — this one defends against updates
    /// the client lied about.
    pub clip_norm: f64,
    /// Rescale every update to *exactly* `clip_norm` instead of only
    /// shrinking oversized ones (norm-normalization; requires
    /// `clip_norm > 0`). Equalizes honest and hostile magnitudes.
    pub normalize: bool,
    /// Coordinate-wise trimmed mean over the K-update buffer: drop the
    /// `floor(trim_frac * K)` lowest and highest values per coordinate
    /// before averaging. 0 = plain mean; must stay < 0.5 (trimming
    /// everything leaves no mass).
    pub trim_frac: f64,
}

impl Default for RobustConfig {
    fn default() -> Self {
        RobustConfig { enabled: false, clip_norm: 0.0, normalize: false, trim_frac: 0.0 }
    }
}

impl RobustConfig {
    /// Is per-update norm bounding on?
    pub fn clip_enabled(&self) -> bool {
        self.enabled && self.clip_norm > 0.0
    }

    /// Is the coordinate-wise trimmed mean on?
    pub fn trim_enabled(&self) -> bool {
        self.enabled && self.trim_frac > 0.0
    }
}

/// Federated-optimization hyperparameters (paper Appendix D).
#[derive(Clone, Debug)]
pub struct FlConfig {
    pub algorithm: Algorithm,
    /// Buffer size K: client updates aggregated per server step.
    pub buffer_size: usize,
    /// Local (client) learning rate eta_l.
    pub client_lr: f32,
    /// Global (server) learning rate eta_g.
    pub server_lr: f32,
    /// Server Nesterov-free momentum beta (paper: 0.3; theory omits it).
    pub server_momentum: f32,
    /// Scale update weights by 1/sqrt(1 + staleness) (paper Fig. 3 runs).
    pub staleness_scaling: bool,
    /// Local SGD steps P per client round (must match the AOT artifact).
    pub local_steps: usize,
    /// Clip each client delta to this l2 norm before quantization
    /// (FLSim, the paper's implementation base, clips client updates);
    /// 0 disables clipping.
    pub clip_norm: f32,
    /// Server aggregation shards S: the server step (accumulate,
    /// momentum + eta_g apply, hidden-state diff, Q_s encode/apply) runs
    /// in parallel over S contiguous, bucket-aligned ranges of the model
    /// vector on a persistent worker pool (DESIGN_SHARDING.md). 1 =
    /// sequential (no-thread pool). Broadcast payloads are bit-identical
    /// for every S.
    pub shards: usize,
    /// Pool size for the simulator's eval path (validation reductions on
    /// the shard pool). 0 = inherit `shards` and reuse the server's
    /// pool; any other value sizes a dedicated eval pool. Eval results
    /// are bit-identical for every value (fixed-block reductions).
    pub eval_shards: usize,
    /// Robust aggregation (`[fl.robust]`): server-side norm bounding +
    /// trimmed mean. Off by default.
    pub robust: RobustConfig,
}

/// The `QAFEL_TEST_SHARDS` override (CI's shard matrix), if set and
/// valid (1..=256). Public so the shard-matrix tests read the exact
/// value `Config::default()` will use instead of re-parsing the env.
pub fn env_shards_override() -> Option<usize> {
    std::env::var("QAFEL_TEST_SHARDS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|s| (1..=256).contains(s))
}

/// Default for `fl.shards`. `QAFEL_TEST_SHARDS` overrides it so the
/// whole test suite runs under S > 1 without touching every config
/// literal — safe because the sharded pipeline's contract is
/// bit-identical output for every S.
fn default_shards() -> usize {
    env_shards_override().unwrap_or(1)
}

impl Default for FlConfig {
    fn default() -> Self {
        FlConfig {
            algorithm: Algorithm::Qafel,
            buffer_size: 10,
            // The paper's CelebA values are eta_l = 4.7e-6, eta_g = 1000;
            // re-tuned for the synthetic substitute (equivalent product,
            // stable with clipping): see EXPERIMENTS.md §Setup.
            client_lr: 1e-2,
            server_lr: 1.0,
            server_momentum: 0.3,
            staleness_scaling: false,
            local_steps: 1,
            clip_norm: 1.0,
            shards: default_shards(),
            eval_shards: 0,
            robust: RobustConfig::default(),
        }
    }
}

/// Quantizer specs, parsed by `quant::parse_spec`:
/// `"qsgd:<bits>"`, `"top:<fraction>"`, `"rand:<fraction>"`, `"none"`.
#[derive(Clone, Debug)]
pub struct QuantConfig {
    pub client: String,
    pub server: String,
}

impl Default for QuantConfig {
    fn default() -> Self {
        // paper §4: 4-bit qsgd at both client and server
        QuantConfig { client: "qsgd:4".into(), server: "qsgd:4".into() }
    }
}

/// Simulator configuration (paper Appendix D timing model).
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Target expected number of clients training in parallel; determines
    /// the constant arrival rate via `rate = concurrency / E[duration]`.
    pub concurrency: usize,
    /// Duration distribution: "halfnormal" | "lognormal" | "fixed".
    pub duration: String,
    pub duration_sigma: f64,
    /// Arrival process: "constant" | "poisson".
    pub arrival: String,
    /// Server steps between validation evaluations.
    pub eval_every: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            concurrency: 100,
            duration: "halfnormal".into(),
            duration_sigma: 1.0,
            arrival: "constant".into(),
            eval_every: 5,
        }
    }
}

/// One device tier of a heterogeneous client population
/// (`[scenario.tiers.<name>]`, DESIGN_SCENARIOS.md).
#[derive(Clone, Debug)]
pub struct TierConfig {
    /// Tier name (the TOML sub-table key).
    pub name: String,
    /// Relative share of arrivals routed to this tier (> 0).
    pub weight: f64,
    /// Duration distribution: "halfnormal" | "lognormal" | "fixed".
    pub duration: String,
    pub duration_sigma: f64,
    /// Uplink bandwidth in Mbit/s; 0 = unlimited (no transfer delay).
    pub upload_mbps: f64,
    /// Downlink bandwidth in Mbit/s; 0 = unlimited.
    pub download_mbps: f64,
    /// Probability a client trains but drops before uploading, in [0, 1).
    pub dropout: f64,
    /// Diurnal cycle length in virtual time; 0 = always available.
    pub day_period: f64,
    /// Fraction of each cycle the tier is available, in (0, 1].
    pub on_fraction: f64,
    /// Offset into the cycle (shifts tiers against each other).
    pub phase: f64,
    /// Per-tier client quantizer preset (`quant::parse_spec` grammar,
    /// e.g. `"top:0.05"`). `None` inherits `quant.client`. Full-precision
    /// baselines (FedBuff/FedAsync) ignore presets, exactly as they
    /// ignore `quant.client`.
    pub quant_client: Option<String>,
    /// Per-tier *downlink* (broadcast) quantizer preset. `None` inherits
    /// `quant.server`. Each distinct resolved server codec gets its own
    /// hidden-state family x̂ in the server (deduped like client
    /// presets), so a constrained tier can receive coarser broadcasts
    /// without perturbing anyone else's error feedback. Full-precision
    /// baselines (FedBuff/FedAsync) ignore presets, exactly as they
    /// ignore `quant.server`.
    pub quant_server: Option<String>,
    /// Probability that a *dropped* client submits the partial update
    /// from the local steps it did complete (scaled by m/P, FedBuff
    /// semantics) instead of discarding its work, in [0, 1]. Needs
    /// `fl.local_steps >= 2` to take effect (a 1-step round has no
    /// mid-round state to submit).
    pub partial_work: f64,
    /// Heavy-tailed gradient-noise injection applied to this tier's
    /// uploads before quantization (`scenario::GradNoise::parse`
    /// grammar: `"student_t:<dof>:<scale>"` or `"pareto:<alpha>:<scale>"`).
    /// Draws come from their own named PRNG stream, so `None` (the
    /// default) stays bit-identical to pre-robustness configs.
    pub grad_noise: Option<String>,
    /// Adversarial upload behavior for every client in this tier
    /// (`scenario::Adversary::parse` grammar: `"sign_flip"`,
    /// `"scale:<c>"` (scaled garbage), `"stale_replay"`). `None` = an
    /// honest tier.
    pub adversary: Option<String>,
}

impl TierConfig {
    /// A tier with the given name and neutral defaults: weight 1,
    /// half-normal(1) durations, unlimited bandwidth, no dropout,
    /// always available — i.e. exactly the paper's client model.
    pub fn named(name: &str) -> TierConfig {
        TierConfig {
            name: name.to_string(),
            weight: 1.0,
            duration: "halfnormal".into(),
            duration_sigma: 1.0,
            upload_mbps: 0.0,
            download_mbps: 0.0,
            dropout: 0.0,
            day_period: 0.0,
            on_fraction: 1.0,
            phase: 0.0,
            quant_client: None,
            quant_server: None,
            partial_work: 0.0,
            grad_noise: None,
            adversary: None,
        }
    }
}

/// The `[scenario.aggregators]` table: a tree-of-leaders layer between
/// the client population and the root server
/// (`crate::coordinator::aggregator`, ARCHITECTURE.md §Aggregator
/// tree). `edges = 0` (the default) is the flat single-server topology,
/// bit-identical to every pre-tree config.
#[derive(Clone, Debug)]
pub struct AggregatorsConfig {
    /// Number of edge aggregators K_e. Each edge owns a contiguous
    /// slice of the user population (`user * edges / num_users`) and
    /// forwards partial aggregates upstream on buffer-full. 0 = flat.
    pub edges: usize,
    /// Edge buffer size B: client updates folded per forwarded partial.
    /// 1 forwards every update immediately (with `partial_codec =
    /// "none"` this replays bit-identical to the flat server). For
    /// exact flat equivalence `fl.buffer_size` should be a multiple of
    /// B.
    pub buffer_size: usize,
    /// Partial-aggregate codec `Q_p` (`quant::parse_spec` grammar).
    /// `"none"` forwards the edge buffer at full precision.
    pub partial_codec: String,
}

impl Default for AggregatorsConfig {
    fn default() -> Self {
        AggregatorsConfig { edges: 0, buffer_size: 1, partial_codec: "none".into() }
    }
}

/// The adaptive-quantization control loop (`[net.adaptive]` on the TCP
/// leader, `[scenario.adaptive]` in the simulator; ARCHITECTURE.md
/// §Adaptive quantization control loop). Every `interval` server steps the
/// controller scores each worker (tier, in the simulator) by its
/// announced bandwidth hint or observed upload rate and walks the
/// slowest ones down the `levels` ladder until the projected uplink
/// traffic fits `budget_bytes_per_step`, switching codecs mid-run via
/// `Rekey` frames. Disabled by default — an absent table leaves every
/// run bit-identical to the static-codec engine.
#[derive(Clone, Debug)]
pub struct AdaptiveConfig {
    /// Master switch. `false` (the default) means no controller runs
    /// and no `Rekey` frame is ever sent.
    pub enabled: bool,
    /// Controller cadence: re-evaluate codec assignments every this
    /// many server steps (>= 1).
    pub interval: u64,
    /// Global uplink budget in bytes per server step. The controller
    /// downshifts workers until `sum(rate_w x bytes_w) <= budget`
    /// (projected over the next interval). Must be > 0 when enabled.
    pub budget_bytes_per_step: u64,
    /// Codec ladder as a comma-separated string of `quant::parse_spec`
    /// specs, e.g. `"qsgd:8,qsgd:4,qsgd:2,top:0.05"` (stored split).
    /// The controller sorts it by encoded size at runtime; order in
    /// the config is cosmetic. Must be non-empty when enabled.
    pub levels: Vec<String>,
    /// A worker (tier) is only eligible for a switch once it has at
    /// least this many uploads in the current observation window —
    /// protects cold workers from being downshifted on no data.
    pub min_uploads: u64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            enabled: false,
            interval: 10,
            budget_bytes_per_step: 0,
            levels: Vec::new(),
            min_uploads: 1,
        }
    }
}

/// The `[scenario]` table: client-population model for the simulator
/// (DESIGN_SCENARIOS.md). When `tiers` is empty the `sim.arrival` /
/// `sim.duration*` knobs desugar to a single-tier scenario, keeping old
/// configs bit-identical.
#[derive(Clone, Debug)]
pub struct ScenarioConfig {
    /// Arrival process override: "constant" | "poisson" | "bursty".
    /// `None` inherits `sim.arrival`.
    pub arrival: Option<String>,
    /// Tier-sampling policy for arriving clients:
    /// * `"weighted"` (default) — tiers are drawn by weight alone and an
    ///   arrival landing in a tier's off window is discarded (the
    ///   pre-v2 behavior, kept bit-identical);
    /// * `"availability"` — tiers are drawn proportional to
    ///   `weight x 1[tier is on at the current clock]`, so diurnal
    ///   windows shape *who* arrives instead of discarding arrivals
    ///   (an arrival is lost only when every tier is off).
    pub sampling: String,
    /// Bursty (MMPP) arrivals: rate multiplier while a burst is on.
    pub burst_factor: f64,
    /// Mean burst duration (virtual time).
    pub burst_on: f64,
    /// Mean quiet-period duration (virtual time).
    pub burst_off: f64,
    /// Device tiers, keyed by name in TOML; sorted by name here (the
    /// TOML table is alphabetical), which fixes the sampling order.
    pub tiers: Vec<TierConfig>,
    /// Correlate tier membership with data distribution: partition the
    /// user population into contiguous per-tier pools (by tier weight,
    /// in tier order) and draw an arriving client from its tier's pool
    /// instead of the whole population. Off by default — the shared
    /// draw keeps pre-existing scenarios bit-identical.
    pub tier_user_pools: bool,
    /// Optional tree-of-leaders layer (`[scenario.aggregators]`).
    pub aggregators: AggregatorsConfig,
    /// Optional adaptive-quantization controller
    /// (`[scenario.adaptive]`): per-tier mid-run codec switches under
    /// a global uplink budget, mirroring the TCP leader's
    /// `net.adaptive` policy.
    pub adaptive: AdaptiveConfig,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            arrival: None,
            sampling: "weighted".into(),
            burst_factor: 4.0,
            burst_on: 1.0,
            burst_off: 4.0,
            tiers: Vec::new(),
            tier_user_pools: false,
            aggregators: AggregatorsConfig::default(),
            adaptive: AdaptiveConfig::default(),
        }
    }
}

/// The `[net]` table: knobs for the real TCP runtime (`net/`,
/// ARCHITECTURE.md §Wire protocol). Shared by `qafel leader` and
/// `qafel worker`; the tier/codec keys only matter on the worker side
/// (they are sent in the v2 `Hello` handshake).
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Leader listen address / worker connect address
    /// (`host:port`; the `--addr` CLI flag overrides it).
    pub addr: String,
    /// Number of workers the leader waits for before starting
    /// (`--workers` overrides it).
    pub workers: usize,
    /// How long the leader waits for a v2 `Hello` after accepting a
    /// connection before classifying the peer as a silent v1 worker and
    /// serving it the legacy protocol, in milliseconds. v2 workers send
    /// `Hello` immediately on connect, so only genuine v1 workers ever
    /// pay this wait.
    pub v1_grace_ms: u64,
    /// Worker-side: device-tier name announced in `Hello`; the leader
    /// resolves it against its `scenario.tiers.<name>.quant_client`
    /// preset to pick this worker's upload codec (`--tier` overrides).
    pub tier: Option<String>,
    /// Worker-side: explicit upload-codec spec announced in `Hello`
    /// (`quant::parse_spec` grammar); wins over `net.tier`
    /// (`--quant-client` overrides). `None` inherits the leader's
    /// `quant.client` default.
    pub quant_client: Option<String>,
    /// Edge-leader mode: address of the upstream (root or higher-level)
    /// leader to forward partial aggregates to. `Some` turns `qafel
    /// leader` into an edge leader — a v2 worker upstream, a leader
    /// downstream (`--upstream` overrides). `None` = root leader.
    pub upstream: Option<String>,
    /// Edge-leader buffer size B: client updates folded per forwarded
    /// partial (1 = forward every update).
    pub edge_buffer: usize,
    /// Partial-aggregate codec `Q_p` used between an edge leader and
    /// its upstream. Must match on both ends of the link — it is the
    /// first (and only) spec both register, so registry id 0 is the
    /// wire contract.
    pub partial_codec: String,
    /// Leader-side: cap on the *resident* broadcast bytes queued per
    /// worker connection (0 = unlimited, the historical unbounded
    /// behavior). When a slow or stalled worker's writer queue exceeds
    /// the budget, the oldest queued delta frames are dropped and folded
    /// into a catch-up marker; once the worker drains again it receives
    /// the retained increments (or one bounded full-state sync) from the
    /// per-codec `UpdateLog` instead of every individual frame.
    pub broadcast_budget_bytes: u64,
    /// Leader-side adaptive-quantization controller (`[net.adaptive]`):
    /// mid-run per-worker codec switches via `Rekey` frames, driven by
    /// the per-worker byte accounting the leader already keeps.
    pub adaptive: AdaptiveConfig,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            addr: "127.0.0.1:7710".into(),
            workers: 4,
            v1_grace_ms: 500,
            tier: None,
            quant_client: None,
            upstream: None,
            edge_buffer: 1,
            partial_codec: "none".into(),
            broadcast_budget_bytes: 0,
            adaptive: AdaptiveConfig::default(),
        }
    }
}

/// Synthetic CelebA-LEAF dataset configuration (DESIGN.md §4).
#[derive(Clone, Debug)]
pub struct DataConfig {
    /// Total users before the 80/10/10 train/val/test user split.
    pub num_users: usize,
    /// LEAF partition seed (paper: 1549775860).
    pub seed: u64,
    /// Per-user sample count range (LEAF CelebA: 1..=32).
    pub min_samples: usize,
    pub max_samples: usize,
    /// Observation noise sigma added to each image.
    pub noise: f32,
    /// Strength of the per-user style offset (non-iid-ness).
    pub style: f32,
    /// Class-template signal strength.
    pub signal: f32,
    /// Max validation samples used per evaluation (subsampled).
    pub eval_samples: usize,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig {
            num_users: 1000,
            seed: 1_549_775_860,
            min_samples: 1,
            max_samples: 32,
            noise: 0.8,
            style: 1.0,
            signal: 1.0,
            eval_samples: 2048,
        }
    }
}

/// The `[telemetry]` table: the flight recorder
/// (`crate::telemetry`, ARCHITECTURE.md §Telemetry). Observer config —
/// none of these knobs can change a run's trajectory, so they are
/// excluded from [`Config::to_json`] and the config fingerprint.
#[derive(Clone, Debug, Default)]
pub struct TelemetryConfig {
    /// Journal path: append-only JSONL event stream of the run
    /// (`--journal` overrides). `None` = no journal.
    pub journal: Option<String>,
    /// Write a full-state `Checkpoint` event every N server steps so the
    /// run can resume after a kill (0 = never). Requires `journal`.
    pub checkpoint_every: u64,
    /// Print a live per-step progress line every N server steps
    /// (0 = off; `--progress` overrides).
    pub progress: u64,
}

/// Stopping criteria for a run.
#[derive(Clone, Debug)]
pub struct StopConfig {
    /// Paper's metric: communication to reach this validation accuracy.
    pub target_accuracy: f64,
    /// Hard cap on client uploads (paper's 2-bit worst case ran 150k).
    pub max_uploads: u64,
    /// Hard cap on server steps.
    pub max_server_steps: u64,
}

impl Default for StopConfig {
    fn default() -> Self {
        StopConfig {
            target_accuracy: 0.90,
            max_uploads: 200_000,
            max_server_steps: 50_000,
        }
    }
}

/// Top-level configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub name: String,
    pub artifacts_dir: String,
    pub out_dir: String,
    /// Seeds for repeated runs; the paper reports mean ± std over 3.
    pub seeds: Vec<u64>,
    pub fl: FlConfig,
    pub quant: QuantConfig,
    pub sim: SimConfig,
    pub scenario: ScenarioConfig,
    pub net: NetConfig,
    pub data: DataConfig,
    pub stop: StopConfig,
    pub telemetry: TelemetryConfig,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            name: "qafel".into(),
            artifacts_dir: "artifacts".into(),
            out_dir: "reports".into(),
            seeds: vec![1, 2, 3],
            fl: FlConfig::default(),
            quant: QuantConfig::default(),
            sim: SimConfig::default(),
            scenario: ScenarioConfig::default(),
            net: NetConfig::default(),
            data: DataConfig::default(),
            stop: StopConfig::default(),
            telemetry: TelemetryConfig::default(),
        }
    }
}

macro_rules! get_num {
    ($obj:expr, $path:expr, $dst:expr, $ty:ty) => {
        if let Some(v) = $obj.at($path) {
            $dst = v
                .as_f64()
                .ok_or_else(|| anyhow!("config {} must be a number", $path.join(".")))?
                as $ty;
        }
    };
}

macro_rules! get_bool {
    ($obj:expr, $path:expr, $dst:expr) => {
        if let Some(v) = $obj.at($path) {
            $dst = v
                .as_bool()
                .ok_or_else(|| anyhow!("config {} must be a bool", $path.join(".")))?;
        }
    };
}

macro_rules! get_str {
    ($obj:expr, $path:expr, $dst:expr) => {
        if let Some(v) = $obj.at($path) {
            $dst = v
                .as_str()
                .ok_or_else(|| anyhow!("config {} must be a string", $path.join(".")))?
                .to_string();
        }
    };
}

impl Config {
    /// Load from a TOML file, starting from defaults.
    pub fn from_file(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config file {path}"))?;
        let doc = toml::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        let mut cfg = Config::default();
        cfg.apply(&doc)?;
        Ok(cfg)
    }

    /// Overlay values from a parsed TOML/JSON document.
    pub fn apply(&mut self, doc: &Json) -> Result<()> {
        get_str!(doc, &["name"], self.name);
        get_str!(doc, &["artifacts_dir"], self.artifacts_dir);
        get_str!(doc, &["out_dir"], self.out_dir);
        if let Some(arr) = doc.at(&["seeds"]).and_then(|v| v.as_arr()) {
            self.seeds = arr
                .iter()
                .map(|v| v.as_f64().map(|f| f as u64).ok_or_else(|| anyhow!("bad seed")))
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(v) = doc.at(&["fl", "algorithm"]) {
            self.fl.algorithm =
                Algorithm::parse(v.as_str().ok_or_else(|| anyhow!("fl.algorithm must be str"))?)?;
        }
        get_num!(doc, &["fl", "buffer_size"], self.fl.buffer_size, usize);
        get_num!(doc, &["fl", "client_lr"], self.fl.client_lr, f32);
        get_num!(doc, &["fl", "server_lr"], self.fl.server_lr, f32);
        get_num!(doc, &["fl", "server_momentum"], self.fl.server_momentum, f32);
        get_bool!(doc, &["fl", "staleness_scaling"], self.fl.staleness_scaling);
        get_num!(doc, &["fl", "local_steps"], self.fl.local_steps, usize);
        get_num!(doc, &["fl", "clip_norm"], self.fl.clip_norm, f32);
        get_num!(doc, &["fl", "shards"], self.fl.shards, usize);
        get_num!(doc, &["fl", "eval_shards"], self.fl.eval_shards, usize);
        if let Some(r) = doc.at(&["fl", "robust"]) {
            apply_robust(&mut self.fl.robust, r)?;
        }

        get_str!(doc, &["quant", "client"], self.quant.client);
        get_str!(doc, &["quant", "server"], self.quant.server);

        get_num!(doc, &["sim", "concurrency"], self.sim.concurrency, usize);
        get_str!(doc, &["sim", "duration"], self.sim.duration);
        get_num!(doc, &["sim", "duration_sigma"], self.sim.duration_sigma, f64);
        get_str!(doc, &["sim", "arrival"], self.sim.arrival);
        get_num!(doc, &["sim", "eval_every"], self.sim.eval_every, usize);

        if let Some(sc) = doc.get("scenario") {
            self.apply_scenario(sc)?;
        }

        get_str!(doc, &["net", "addr"], self.net.addr);
        get_num!(doc, &["net", "workers"], self.net.workers, usize);
        get_num!(doc, &["net", "v1_grace_ms"], self.net.v1_grace_ms, u64);
        if let Some(v) = doc.at(&["net", "tier"]) {
            self.net.tier = Some(
                v.as_str().ok_or_else(|| anyhow!("config net.tier must be a string"))?.to_string(),
            );
        }
        if let Some(v) = doc.at(&["net", "quant_client"]) {
            self.net.quant_client = Some(
                v.as_str()
                    .ok_or_else(|| anyhow!("config net.quant_client must be a string"))?
                    .to_string(),
            );
        }
        if let Some(v) = doc.at(&["net", "upstream"]) {
            self.net.upstream = Some(
                v.as_str()
                    .ok_or_else(|| anyhow!("config net.upstream must be a string"))?
                    .to_string(),
            );
        }
        get_num!(doc, &["net", "edge_buffer"], self.net.edge_buffer, usize);
        get_str!(doc, &["net", "partial_codec"], self.net.partial_codec);
        get_num!(
            doc,
            &["net", "broadcast_budget_bytes"],
            self.net.broadcast_budget_bytes,
            u64
        );
        if let Some(a) = doc.at(&["net", "adaptive"]) {
            apply_adaptive(&mut self.net.adaptive, a, "net.adaptive")?;
        }

        get_num!(doc, &["data", "num_users"], self.data.num_users, usize);
        get_num!(doc, &["data", "seed"], self.data.seed, u64);
        get_num!(doc, &["data", "min_samples"], self.data.min_samples, usize);
        get_num!(doc, &["data", "max_samples"], self.data.max_samples, usize);
        get_num!(doc, &["data", "noise"], self.data.noise, f32);
        get_num!(doc, &["data", "style"], self.data.style, f32);
        get_num!(doc, &["data", "signal"], self.data.signal, f32);
        get_num!(doc, &["data", "eval_samples"], self.data.eval_samples, usize);

        get_num!(doc, &["stop", "target_accuracy"], self.stop.target_accuracy, f64);
        get_num!(doc, &["stop", "max_uploads"], self.stop.max_uploads, u64);
        get_num!(doc, &["stop", "max_server_steps"], self.stop.max_server_steps, u64);

        if let Some(v) = doc.at(&["telemetry", "journal"]) {
            self.telemetry.journal = Some(
                v.as_str()
                    .ok_or_else(|| anyhow!("config telemetry.journal must be a string"))?
                    .to_string(),
            );
        }
        get_num!(doc, &["telemetry", "checkpoint_every"], self.telemetry.checkpoint_every, u64);
        get_num!(doc, &["telemetry", "progress"], self.telemetry.progress, u64);
        self.validate()
    }

    /// Apply one `section.key=value` CLI override.
    pub fn set(&mut self, assignment: &str) -> Result<()> {
        let (path, value) = assignment
            .split_once('=')
            .ok_or_else(|| anyhow!("override must look like sim.concurrency=500"))?;
        // Reuse the TOML value grammar for the right-hand side.
        let parsed = toml::parse(&format!("__v = {}", value.trim()))
            .map_err(|e| anyhow!("bad override value '{value}': {e}"))?;
        let val = parsed.get("__v").unwrap().clone();
        // Build a nested single-entry doc and overlay it.
        let mut doc = val;
        for part in path.trim().split('.').rev() {
            let mut m = std::collections::BTreeMap::new();
            m.insert(part.to_string(), doc);
            doc = Json::Obj(m);
        }
        self.apply(&doc)
    }

    /// Overlay the `[scenario]` table. Unknown keys are rejected loudly
    /// (tier sub-tables are user-named, so a typo'd knob would otherwise
    /// vanish silently).
    fn apply_scenario(&mut self, doc: &Json) -> Result<()> {
        let obj = doc
            .as_obj()
            .ok_or_else(|| anyhow!("[scenario] must be a table"))?;
        for (key, val) in obj {
            match key.as_str() {
                "arrival" => {
                    self.scenario.arrival = Some(
                        val.as_str()
                            .ok_or_else(|| anyhow!("scenario.arrival must be a string"))?
                            .to_string(),
                    );
                }
                "sampling" => {
                    self.scenario.sampling = val
                        .as_str()
                        .ok_or_else(|| anyhow!("scenario.sampling must be a string"))?
                        .to_string();
                }
                "burst_factor" => self.scenario.burst_factor = scalar(val, "scenario.burst_factor")?,
                "burst_on" => self.scenario.burst_on = scalar(val, "scenario.burst_on")?,
                "burst_off" => self.scenario.burst_off = scalar(val, "scenario.burst_off")?,
                "tiers" => {
                    if let Some(list) = val.as_arr() {
                        // Array form: [{ name = "...", ... }, ...] in
                        // declaration order. This is what
                        // `Config::to_json` emits — a TOML table is
                        // alphabetical, but repeated `--set` overrides
                        // can build tiers in any order, and tier order
                        // is the codec-registry wire contract.
                        for tval in list {
                            let name = tval
                                .get("name")
                                .and_then(|v| v.as_str())
                                .ok_or_else(|| {
                                    anyhow!("each scenario.tiers entry needs a string 'name'")
                                })?
                                .to_string();
                            let mut body = tval
                                .as_obj()
                                .ok_or_else(|| anyhow!("scenario.tiers entries must be tables"))?
                                .clone();
                            body.remove("name");
                            self.apply_tier(&name, &Json::Obj(body))?;
                        }
                    } else {
                        let tiers = val.as_obj().ok_or_else(|| {
                            anyhow!(
                                "scenario.tiers must be a table of [scenario.tiers.<name>] \
                                 tables or an array of {{ name = ... }} tables"
                            )
                        })?;
                        for (name, tval) in tiers {
                            self.apply_tier(name, tval)?;
                        }
                    }
                }
                "tier_user_pools" => {
                    self.scenario.tier_user_pools = val
                        .as_bool()
                        .ok_or_else(|| anyhow!("scenario.tier_user_pools must be a bool"))?;
                }
                "aggregators" => self.apply_aggregators(val)?,
                "adaptive" => {
                    apply_adaptive(&mut self.scenario.adaptive, val, "scenario.adaptive")?;
                }
                other => bail!(
                    "unknown [scenario] key '{other}' \
                     (known: arrival, sampling, burst_factor, burst_on, burst_off, tiers, \
                      tier_user_pools, aggregators, adaptive)"
                ),
            }
        }
        Ok(())
    }

    /// Overlay the `[scenario.aggregators]` sub-table. Unknown keys are
    /// rejected loudly, like the parent table.
    fn apply_aggregators(&mut self, doc: &Json) -> Result<()> {
        let obj = doc
            .as_obj()
            .ok_or_else(|| anyhow!("[scenario.aggregators] must be a table"))?;
        for (key, val) in obj {
            let what = format!("scenario.aggregators.{key}");
            match key.as_str() {
                "edges" => self.scenario.aggregators.edges = scalar(val, &what)? as usize,
                "buffer_size" => {
                    self.scenario.aggregators.buffer_size = scalar(val, &what)? as usize;
                }
                "partial_codec" => {
                    self.scenario.aggregators.partial_codec = val
                        .as_str()
                        .ok_or_else(|| anyhow!("config {what} must be a string"))?
                        .to_string();
                }
                other => bail!(
                    "unknown [scenario.aggregators] key '{other}' \
                     (known: edges, buffer_size, partial_codec)"
                ),
            }
        }
        Ok(())
    }

    /// Overlay one `[scenario.tiers.<name>]` sub-table, merging into an
    /// existing tier of the same name (so `--set scenario.tiers.x.k=v`
    /// updates rather than resets).
    fn apply_tier(&mut self, name: &str, doc: &Json) -> Result<()> {
        let obj = doc
            .as_obj()
            .ok_or_else(|| anyhow!("scenario.tiers.{name} must be a table"))?;
        let idx = self.scenario.tiers.iter().position(|t| t.name == name);
        let mut tier = match idx {
            Some(i) => self.scenario.tiers[i].clone(),
            None => TierConfig::named(name),
        };
        for (key, val) in obj {
            let what = format!("scenario.tiers.{name}.{key}");
            match key.as_str() {
                "weight" => tier.weight = scalar(val, &what)?,
                "duration" => {
                    tier.duration = val
                        .as_str()
                        .ok_or_else(|| anyhow!("config {what} must be a string"))?
                        .to_string();
                }
                "duration_sigma" => tier.duration_sigma = scalar(val, &what)?,
                "upload_mbps" => tier.upload_mbps = scalar(val, &what)?,
                "download_mbps" => tier.download_mbps = scalar(val, &what)?,
                "dropout" => tier.dropout = scalar(val, &what)?,
                "day_period" => tier.day_period = scalar(val, &what)?,
                "on_fraction" => tier.on_fraction = scalar(val, &what)?,
                "phase" => tier.phase = scalar(val, &what)?,
                "quant_client" => {
                    tier.quant_client = Some(
                        val.as_str()
                            .ok_or_else(|| anyhow!("config {what} must be a string"))?
                            .to_string(),
                    );
                }
                "quant_server" => {
                    tier.quant_server = Some(
                        val.as_str()
                            .ok_or_else(|| anyhow!("config {what} must be a string"))?
                            .to_string(),
                    );
                }
                "partial_work" => tier.partial_work = scalar(val, &what)?,
                "grad_noise" => {
                    tier.grad_noise = Some(
                        val.as_str()
                            .ok_or_else(|| anyhow!("config {what} must be a string"))?
                            .to_string(),
                    );
                }
                "adversary" => {
                    tier.adversary = Some(
                        val.as_str()
                            .ok_or_else(|| anyhow!("config {what} must be a string"))?
                            .to_string(),
                    );
                }
                other => bail!(
                    "unknown tier key 'scenario.tiers.{name}.{other}' (known: weight, \
                     duration, duration_sigma, upload_mbps, download_mbps, dropout, \
                     day_period, on_fraction, phase, quant_client, quant_server, \
                     partial_work, grad_noise, adversary)"
                ),
            }
        }
        match idx {
            Some(i) => self.scenario.tiers[i] = tier,
            None => self.scenario.tiers.push(tier),
        }
        Ok(())
    }

    /// The effective tier list: explicit `[scenario.tiers.*]` tables, or
    /// the `sim.duration*` knobs desugared to a single always-available
    /// unlimited-bandwidth tier (the pre-scenario client model).
    pub fn resolved_tiers(&self) -> Vec<TierConfig> {
        if !self.scenario.tiers.is_empty() {
            return self.scenario.tiers.clone();
        }
        let mut t = TierConfig::named("default");
        t.duration = self.sim.duration.clone();
        t.duration_sigma = self.sim.duration_sigma;
        vec![t]
    }

    /// The effective arrival process: `scenario.arrival` when set,
    /// otherwise the `sim.arrival` back-compat alias.
    pub fn resolved_arrival(&self) -> &str {
        self.scenario.arrival.as_deref().unwrap_or(&self.sim.arrival)
    }

    /// The resolved config as a TOML-shaped JSON document — the exact
    /// form [`Config::apply`] overlays, so
    /// `Config::default().apply(&cfg.to_json())` reconstructs the
    /// config field-for-field (tiers keep their declaration order via
    /// the array form). This is what journals embed in their `Meta`
    /// event and what [`crate::telemetry::config_fingerprint`] hashes.
    ///
    /// `[telemetry]` is deliberately omitted: it is observer config
    /// (journal path, progress cadence) that cannot change the run's
    /// trajectory, so recording a run must not change its fingerprint.
    pub fn to_json(&self) -> Json {
        let num = Json::num;
        let mut fl = vec![
            ("algorithm", Json::str(self.fl.algorithm.name())),
            ("buffer_size", num(self.fl.buffer_size as f64)),
            ("client_lr", num(f64::from(self.fl.client_lr))),
            ("server_lr", num(f64::from(self.fl.server_lr))),
            ("server_momentum", num(f64::from(self.fl.server_momentum))),
            ("staleness_scaling", Json::Bool(self.fl.staleness_scaling)),
            ("local_steps", num(self.fl.local_steps as f64)),
            ("clip_norm", num(f64::from(self.fl.clip_norm))),
            ("shards", num(self.fl.shards as f64)),
            ("eval_shards", num(self.fl.eval_shards as f64)),
        ];
        if self.fl.robust.enabled {
            // Emitted only when enabled: a robust-off config keeps its
            // pre-robustness fingerprint byte-identical.
            fl.push(("robust", robust_to_json(&self.fl.robust)));
        }
        let fl = Json::obj(fl);
        let quant = Json::obj(vec![
            ("client", Json::str(&self.quant.client)),
            ("server", Json::str(&self.quant.server)),
        ]);
        let sim = Json::obj(vec![
            ("concurrency", num(self.sim.concurrency as f64)),
            ("duration", Json::str(&self.sim.duration)),
            ("duration_sigma", num(self.sim.duration_sigma)),
            ("arrival", Json::str(&self.sim.arrival)),
            ("eval_every", num(self.sim.eval_every as f64)),
        ]);
        let aggregators = Json::obj(vec![
            ("edges", num(self.scenario.aggregators.edges as f64)),
            ("buffer_size", num(self.scenario.aggregators.buffer_size as f64)),
            ("partial_codec", Json::str(&self.scenario.aggregators.partial_codec)),
        ]);
        let mut scenario = vec![
            ("sampling", Json::str(&self.scenario.sampling)),
            ("burst_factor", num(self.scenario.burst_factor)),
            ("burst_on", num(self.scenario.burst_on)),
            ("burst_off", num(self.scenario.burst_off)),
            ("tier_user_pools", Json::Bool(self.scenario.tier_user_pools)),
            ("aggregators", aggregators),
        ];
        if self.scenario.adaptive.enabled {
            // Emitted only when enabled: an adaptive-off config keeps
            // its pre-adaptive fingerprint byte-identical.
            scenario.push(("adaptive", adaptive_to_json(&self.scenario.adaptive)));
        }
        if let Some(a) = &self.scenario.arrival {
            scenario.push(("arrival", Json::str(a)));
        }
        if !self.scenario.tiers.is_empty() {
            let tiers: Vec<Json> = self
                .scenario
                .tiers
                .iter()
                .map(|t| {
                    let mut fields = vec![
                        ("name", Json::str(&t.name)),
                        ("weight", num(t.weight)),
                        ("duration", Json::str(&t.duration)),
                        ("duration_sigma", num(t.duration_sigma)),
                        ("upload_mbps", num(t.upload_mbps)),
                        ("download_mbps", num(t.download_mbps)),
                        ("dropout", num(t.dropout)),
                        ("day_period", num(t.day_period)),
                        ("on_fraction", num(t.on_fraction)),
                        ("phase", num(t.phase)),
                        ("partial_work", num(t.partial_work)),
                    ];
                    if let Some(q) = &t.quant_client {
                        fields.push(("quant_client", Json::str(q)));
                    }
                    if let Some(q) = &t.quant_server {
                        fields.push(("quant_server", Json::str(q)));
                    }
                    if let Some(g) = &t.grad_noise {
                        fields.push(("grad_noise", Json::str(g)));
                    }
                    if let Some(a) = &t.adversary {
                        fields.push(("adversary", Json::str(a)));
                    }
                    Json::obj(fields)
                })
                .collect();
            scenario.push(("tiers", Json::Arr(tiers)));
        }
        let mut net = vec![
            ("addr", Json::str(&self.net.addr)),
            ("workers", num(self.net.workers as f64)),
            ("v1_grace_ms", num(self.net.v1_grace_ms as f64)),
            ("edge_buffer", num(self.net.edge_buffer as f64)),
            ("partial_codec", Json::str(&self.net.partial_codec)),
            ("broadcast_budget_bytes", num(self.net.broadcast_budget_bytes as f64)),
        ];
        if let Some(t) = &self.net.tier {
            net.push(("tier", Json::str(t)));
        }
        if let Some(q) = &self.net.quant_client {
            net.push(("quant_client", Json::str(q)));
        }
        if let Some(u) = &self.net.upstream {
            net.push(("upstream", Json::str(u)));
        }
        if self.net.adaptive.enabled {
            net.push(("adaptive", adaptive_to_json(&self.net.adaptive)));
        }
        let data = Json::obj(vec![
            ("num_users", num(self.data.num_users as f64)),
            ("seed", num(self.data.seed as f64)),
            ("min_samples", num(self.data.min_samples as f64)),
            ("max_samples", num(self.data.max_samples as f64)),
            ("noise", num(f64::from(self.data.noise))),
            ("style", num(f64::from(self.data.style))),
            ("signal", num(f64::from(self.data.signal))),
            ("eval_samples", num(self.data.eval_samples as f64)),
        ]);
        let stop = Json::obj(vec![
            ("target_accuracy", num(self.stop.target_accuracy)),
            ("max_uploads", num(self.stop.max_uploads as f64)),
            ("max_server_steps", num(self.stop.max_server_steps as f64)),
        ]);
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("artifacts_dir", Json::str(&self.artifacts_dir)),
            ("out_dir", Json::str(&self.out_dir)),
            (
                "seeds",
                Json::Arr(self.seeds.iter().map(|&s| num(s as f64)).collect()),
            ),
            ("fl", fl),
            ("quant", quant),
            ("sim", sim),
            ("scenario", Json::obj(scenario)),
            ("net", Json::obj(net)),
            ("data", data),
            ("stop", stop),
        ])
    }

    /// Consistency checks (fail fast, before any compute).
    pub fn validate(&self) -> Result<()> {
        if self.fl.buffer_size == 0 {
            bail!("fl.buffer_size (K) must be >= 1");
        }
        if self.fl.local_steps == 0 {
            bail!("fl.local_steps (P) must be >= 1");
        }
        if self.fl.shards == 0 {
            bail!("fl.shards (S) must be >= 1");
        }
        if self.fl.shards > 256 {
            bail!("fl.shards (S) must be <= 256 (one thread per shard)");
        }
        if self.fl.eval_shards > 256 {
            bail!("fl.eval_shards must be <= 256 (0 = inherit fl.shards)");
        }
        validate_robust(&self.fl.robust)?;
        if self.seeds.is_empty() {
            bail!("need at least one seed");
        }
        if self.data.min_samples == 0 || self.data.min_samples > self.data.max_samples {
            bail!("data.min_samples must be in [1, max_samples]");
        }
        if !(0.0..=1.0).contains(&self.stop.target_accuracy) {
            bail!("stop.target_accuracy must be in [0,1]");
        }
        if self.sim.concurrency == 0 {
            bail!("sim.concurrency must be >= 1");
        }
        match self.sim.duration.as_str() {
            "halfnormal" | "lognormal" | "fixed" => {}
            other => bail!("unknown sim.duration '{other}'"),
        }
        match self.sim.arrival.as_str() {
            "constant" | "poisson" | "bursty" => {}
            other => bail!("unknown sim.arrival '{other}'"),
        }
        if self.net.addr.is_empty() {
            bail!("net.addr must not be empty");
        }
        if self.net.workers == 0 {
            bail!("net.workers must be >= 1");
        }
        if !(1..=600_000).contains(&self.net.v1_grace_ms) {
            bail!(
                "net.v1_grace_ms must be in [1, 600000], got {}",
                self.net.v1_grace_ms
            );
        }
        if let Some(spec) = &self.net.quant_client {
            crate::quant::parse_spec(spec)
                .map_err(|e| anyhow!("bad net.quant_client spec '{spec}': {e}"))?;
        }
        if let Some(up) = &self.net.upstream {
            if up.is_empty() {
                bail!("net.upstream must not be empty (omit it for a root leader)");
            }
        }
        if self.net.edge_buffer == 0 {
            bail!("net.edge_buffer (B) must be >= 1");
        }
        crate::quant::parse_spec(&self.net.partial_codec)
            .map_err(|e| anyhow!("bad net.partial_codec spec '{}': {e}", self.net.partial_codec))?;
        validate_adaptive(&self.net.adaptive, "net.adaptive")?;
        validate_adaptive(&self.scenario.adaptive, "scenario.adaptive")?;
        if self.telemetry.checkpoint_every > 0 && self.telemetry.journal.is_none() {
            bail!("telemetry.checkpoint_every needs telemetry.journal (checkpoints live in it)");
        }
        self.validate_scenario()
    }

    fn validate_scenario(&self) -> Result<()> {
        match self.resolved_arrival() {
            "constant" | "poisson" | "bursty" => {}
            other => bail!("unknown scenario.arrival '{other}'"),
        }
        // one source of truth for the mode names: the scenario engine's
        // own parser (config and engine can never drift apart)
        crate::scenario::Sampling::parse(&self.scenario.sampling)?;
        for (name, v) in [
            ("burst_factor", self.scenario.burst_factor),
            ("burst_on", self.scenario.burst_on),
            ("burst_off", self.scenario.burst_off),
        ] {
            if !(v.is_finite() && v > 0.0) {
                bail!("scenario.{name} must be > 0, got {v}");
            }
        }
        let tiers = self.resolved_tiers();
        let mut total_weight = 0.0;
        for t in &tiers {
            let name = &t.name;
            if !(t.weight.is_finite() && t.weight > 0.0) {
                bail!("scenario tier '{name}': weight must be positive, got {}", t.weight);
            }
            total_weight += t.weight;
            match t.duration.as_str() {
                "halfnormal" | "lognormal" | "fixed" => {}
                other => bail!("scenario tier '{name}': unknown duration dist '{other}'"),
            }
            if !(t.duration_sigma.is_finite() && t.duration_sigma > 0.0) {
                bail!(
                    "scenario tier '{name}': duration_sigma must be > 0, got {}",
                    t.duration_sigma
                );
            }
            for (knob, v) in [("upload_mbps", t.upload_mbps), ("download_mbps", t.download_mbps)] {
                if !(v.is_finite() && v >= 0.0) {
                    bail!("scenario tier '{name}': {knob} must be > 0 (or 0 = unlimited), got {v}");
                }
            }
            if !(0.0..1.0).contains(&t.dropout) {
                bail!("scenario tier '{name}': dropout must be in [0, 1), got {}", t.dropout);
            }
            if !(t.day_period.is_finite() && t.day_period >= 0.0) {
                bail!("scenario tier '{name}': day_period must be >= 0, got {}", t.day_period);
            }
            if t.day_period > 0.0 && !(t.on_fraction > 0.0 && t.on_fraction <= 1.0) {
                bail!(
                    "scenario tier '{name}': on_fraction must be in (0, 1], got {}",
                    t.on_fraction
                );
            }
            if !(t.phase.is_finite() && t.phase >= 0.0) {
                bail!("scenario tier '{name}': phase must be >= 0, got {}", t.phase);
            }
            if !(0.0..=1.0).contains(&t.partial_work) {
                bail!(
                    "scenario tier '{name}': partial_work must be in [0, 1], got {}",
                    t.partial_work
                );
            }
            if let Some(spec) = &t.quant_client {
                crate::quant::parse_spec(spec).map_err(|e| {
                    anyhow!("scenario tier '{name}': bad quant_client preset '{spec}': {e}")
                })?;
            }
            if let Some(spec) = &t.quant_server {
                crate::quant::parse_spec(spec).map_err(|e| {
                    anyhow!("scenario tier '{name}': bad quant_server preset '{spec}': {e}")
                })?;
            }
            // one source of truth for the spec grammars: the scenario
            // engine's own parsers (config and engine can never drift)
            if let Some(spec) = &t.grad_noise {
                crate::scenario::GradNoise::parse(spec).map_err(|e| {
                    anyhow!("scenario tier '{name}': bad grad_noise spec '{spec}': {e}")
                })?;
            }
            if let Some(spec) = &t.adversary {
                crate::scenario::Adversary::parse(spec).map_err(|e| {
                    anyhow!("scenario tier '{name}': bad adversary spec '{spec}': {e}")
                })?;
            }
        }
        if !(total_weight.is_finite() && total_weight > 0.0) {
            bail!("scenario tier weights must sum to a positive finite value");
        }
        let agg = &self.scenario.aggregators;
        if agg.edges > 0 {
            if agg.buffer_size == 0 {
                bail!("scenario.aggregators.buffer_size (B) must be >= 1");
            }
            if agg.edges > 4096 {
                bail!("scenario.aggregators.edges must be <= 4096, got {}", agg.edges);
            }
            if self.fl.robust.trim_enabled() {
                bail!(
                    "fl.robust.trim_frac needs individual client rows at the root, but \
                     scenario.aggregators.edges = {} forwards collapsed partial \
                     aggregates — use clip_norm at the edges instead, or set edges = 0",
                    agg.edges
                );
            }
        }
        crate::quant::parse_spec(&agg.partial_codec).map_err(|e| {
            anyhow!("bad scenario.aggregators.partial_codec spec '{}': {e}", agg.partial_codec)
        })?;
        Ok(())
    }
}

/// Numeric config cell with a path-qualified error.
fn scalar(v: &Json, what: &str) -> Result<f64> {
    v.as_f64().ok_or_else(|| anyhow!("config {what} must be a number"))
}

/// Overlay an `[net.adaptive]` / `[scenario.adaptive]` sub-table.
/// Unknown keys are rejected loudly, like the other strict sub-tables.
fn apply_adaptive(dst: &mut AdaptiveConfig, doc: &Json, what: &str) -> Result<()> {
    let obj = doc.as_obj().ok_or_else(|| anyhow!("[{what}] must be a table"))?;
    for (key, val) in obj {
        let path = format!("{what}.{key}");
        match key.as_str() {
            "enabled" => {
                dst.enabled =
                    val.as_bool().ok_or_else(|| anyhow!("config {path} must be a bool"))?;
            }
            "interval" => dst.interval = scalar(val, &path)? as u64,
            "budget_bytes_per_step" => {
                dst.budget_bytes_per_step = scalar(val, &path)? as u64;
            }
            "min_uploads" => dst.min_uploads = scalar(val, &path)? as u64,
            "levels" => {
                // Comma-separated codec ladder (the vendored TOML
                // parser keeps config values scalar-or-table).
                let s = val.as_str().ok_or_else(|| {
                    anyhow!("config {path} must be a comma-separated string of codec specs")
                })?;
                dst.levels = s
                    .split(',')
                    .map(|p| p.trim().to_string())
                    .filter(|p| !p.is_empty())
                    .collect();
            }
            other => bail!(
                "unknown [{what}] key '{other}' \
                 (known: enabled, interval, budget_bytes_per_step, levels, min_uploads)"
            ),
        }
    }
    Ok(())
}

/// Validate one adaptive-controller table (only when enabled — a
/// disabled controller may carry any half-edited knob values).
fn validate_adaptive(a: &AdaptiveConfig, what: &str) -> Result<()> {
    if !a.enabled {
        return Ok(());
    }
    if a.interval == 0 {
        bail!("{what}.interval must be >= 1 when the controller is enabled");
    }
    if a.budget_bytes_per_step == 0 {
        bail!("{what}.budget_bytes_per_step must be > 0 when the controller is enabled");
    }
    if a.levels.is_empty() {
        bail!("{what}.levels must list at least one codec spec when the controller is enabled");
    }
    for spec in &a.levels {
        crate::quant::parse_spec(spec)
            .map_err(|e| anyhow!("bad {what}.levels spec '{spec}': {e}"))?;
    }
    Ok(())
}

/// Overlay the `[fl.robust]` sub-table. Unknown keys are rejected
/// loudly, like the other strict sub-tables.
fn apply_robust(dst: &mut RobustConfig, doc: &Json) -> Result<()> {
    let obj = doc.as_obj().ok_or_else(|| anyhow!("[fl.robust] must be a table"))?;
    for (key, val) in obj {
        let path = format!("fl.robust.{key}");
        match key.as_str() {
            "enabled" => {
                dst.enabled =
                    val.as_bool().ok_or_else(|| anyhow!("config {path} must be a bool"))?;
            }
            "clip_norm" => dst.clip_norm = scalar(val, &path)?,
            "normalize" => {
                dst.normalize =
                    val.as_bool().ok_or_else(|| anyhow!("config {path} must be a bool"))?;
            }
            "trim_frac" => dst.trim_frac = scalar(val, &path)?,
            other => bail!(
                "unknown [fl.robust] key '{other}' \
                 (known: enabled, clip_norm, normalize, trim_frac)"
            ),
        }
    }
    Ok(())
}

/// Validate the robust-aggregation table (only when enabled — a
/// disabled table may carry any half-edited knob values, exactly like
/// the adaptive controller).
fn validate_robust(r: &RobustConfig) -> Result<()> {
    if !r.enabled {
        return Ok(());
    }
    if !(r.clip_norm.is_finite() && r.clip_norm >= 0.0) {
        bail!(
            "fl.robust.clip_norm must be a finite value >= 0 (0 = no clipping), got {}",
            r.clip_norm
        );
    }
    if !(r.trim_frac.is_finite() && (0.0..0.5).contains(&r.trim_frac)) {
        bail!(
            "fl.robust.trim_frac must be in [0, 0.5) — trimming half or more of the \
             buffer from each end leaves nothing to average — got {}",
            r.trim_frac
        );
    }
    if r.clip_norm == 0.0 && r.trim_frac == 0.0 {
        bail!(
            "fl.robust.enabled = true but clip_norm = 0 and trim_frac = 0: nothing to \
             do (set a positive clip_norm and/or trim_frac, or drop the table)"
        );
    }
    if r.normalize && r.clip_norm == 0.0 {
        bail!("fl.robust.normalize needs a positive fl.robust.clip_norm (the target norm)");
    }
    Ok(())
}

/// The robust table as a TOML-shaped JSON object.
fn robust_to_json(r: &RobustConfig) -> Json {
    Json::obj(vec![
        ("enabled", Json::Bool(r.enabled)),
        ("clip_norm", Json::num(r.clip_norm)),
        ("normalize", Json::Bool(r.normalize)),
        ("trim_frac", Json::num(r.trim_frac)),
    ])
}

/// The adaptive table as a TOML-shaped JSON object (levels re-joined
/// into the comma-separated form `apply_adaptive` parses).
fn adaptive_to_json(a: &AdaptiveConfig) -> Json {
    Json::obj(vec![
        ("enabled", Json::Bool(a.enabled)),
        ("interval", Json::num(a.interval as f64)),
        ("budget_bytes_per_step", Json::num(a.budget_bytes_per_step as f64)),
        ("levels", Json::str(&a.levels.join(","))),
        ("min_uploads", Json::num(a.min_uploads as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_appendix_d() {
        let c = Config::default();
        assert_eq!(c.fl.buffer_size, 10);
        assert!((c.fl.client_lr - 1e-2).abs() < 1e-9); // re-tuned, see docs
        assert_eq!(c.fl.server_lr, 1.0);
        assert!((c.fl.server_momentum - 0.3).abs() < 1e-7);
        assert_eq!(c.quant.client, "qsgd:4");
        assert_eq!(c.quant.server, "qsgd:4");
        assert_eq!(c.data.seed, 1_549_775_860);
        assert_eq!(c.stop.target_accuracy, 0.90);
        assert_eq!(c.data.max_samples, 32);
        c.validate().unwrap();
    }

    #[test]
    fn toml_overlay() {
        let doc = toml::parse(
            "[fl]\nalgorithm = \"fedbuff\"\nbuffer_size = 5\n[sim]\nconcurrency = 500\n",
        )
        .unwrap();
        let mut c = Config::default();
        c.apply(&doc).unwrap();
        assert_eq!(c.fl.algorithm, Algorithm::FedBuff);
        assert_eq!(c.fl.buffer_size, 5);
        assert_eq!(c.sim.concurrency, 500);
        // untouched fields keep defaults
        assert_eq!(c.fl.server_lr, 1.0);
    }

    #[test]
    fn cli_set_overrides() {
        let mut c = Config::default();
        c.set("sim.concurrency=1000").unwrap();
        c.set("quant.client=\"qsgd:2\"").unwrap();
        c.set("fl.staleness_scaling=true").unwrap();
        assert_eq!(c.sim.concurrency, 1000);
        assert_eq!(c.quant.client, "qsgd:2");
        assert!(c.fl.staleness_scaling);
        assert!(c.set("nonsense").is_err());
    }

    #[test]
    fn shards_knob_round_trips() {
        let c = Config::default();
        // the default is 1 unless the CI shard matrix overrides it
        assert_eq!(c.fl.shards, env_shards_override().unwrap_or(1));
        assert_eq!(c.fl.eval_shards, 0);
        let doc = toml::parse("[fl]\nshards = 4\neval_shards = 2\n").unwrap();
        let mut c = Config::default();
        c.apply(&doc).unwrap();
        assert_eq!(c.fl.shards, 4);
        assert_eq!(c.fl.eval_shards, 2);
        let mut c = Config::default();
        c.set("fl.shards=8").unwrap();
        c.set("fl.eval_shards=4").unwrap();
        assert_eq!(c.fl.shards, 8);
        assert_eq!(c.fl.eval_shards, 4);
        c.fl.shards = 0;
        assert!(c.validate().is_err());
        c.fl.shards = 10_000;
        assert!(c.validate().is_err());
        c.fl.shards = 1;
        c.fl.eval_shards = 10_000;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut c = Config::default();
        c.fl.buffer_size = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.sim.duration = "uniform".into();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.stop.target_accuracy = 1.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn scenario_toml_two_tiers() {
        let doc = toml::parse(
            "[scenario]\narrival = \"bursty\"\nburst_factor = 3.0\n\
             [scenario.tiers.fast]\nweight = 0.25\nduration_sigma = 0.5\nupload_mbps = 40.0\n\
             [scenario.tiers.slow]\nweight = 0.75\nduration = \"lognormal\"\ndropout = 0.2\n\
             day_period = 24.0\non_fraction = 0.5\n",
        )
        .unwrap();
        let mut c = Config::default();
        c.apply(&doc).unwrap();
        assert_eq!(c.resolved_arrival(), "bursty");
        assert_eq!(c.scenario.burst_factor, 3.0);
        // TOML tables are alphabetical: fast before slow
        assert_eq!(c.scenario.tiers.len(), 2);
        let fast = &c.scenario.tiers[0];
        assert_eq!(fast.name, "fast");
        assert_eq!(fast.weight, 0.25);
        assert_eq!(fast.duration_sigma, 0.5);
        assert_eq!(fast.upload_mbps, 40.0);
        assert_eq!(fast.dropout, 0.0); // default
        let slow = &c.scenario.tiers[1];
        assert_eq!(slow.duration, "lognormal");
        assert_eq!(slow.dropout, 0.2);
        assert_eq!(slow.day_period, 24.0);
        assert_eq!(slow.on_fraction, 0.5);
        // explicit tiers win over the sim.* aliases
        assert_eq!(c.resolved_tiers().len(), 2);
        c.validate().unwrap();
    }

    #[test]
    fn scenario_unknown_keys_rejected_loudly() {
        let mut c = Config::default();
        let doc = toml::parse("[scenario.tiers.slow]\nbandwidth = 3.0\n").unwrap();
        let err = c.apply(&doc).unwrap_err().to_string();
        assert!(err.contains("unknown tier key") && err.contains("bandwidth"), "{err}");
        let doc = toml::parse("[scenario]\narrivals = \"poisson\"\n").unwrap();
        let err = c.apply(&doc).unwrap_err().to_string();
        assert!(err.contains("unknown [scenario] key"), "{err}");
    }

    #[test]
    fn scenario_cli_set_overrides_merge() {
        let mut c = Config::default();
        c.set("scenario.tiers.slow.weight=2").unwrap();
        c.set("scenario.tiers.slow.dropout=0.1").unwrap();
        c.set("scenario.arrival=\"poisson\"").unwrap();
        assert_eq!(c.scenario.tiers.len(), 1);
        let slow = &c.scenario.tiers[0];
        assert_eq!(slow.name, "slow");
        assert_eq!(slow.weight, 2.0);
        assert_eq!(slow.dropout, 0.1, "second --set must merge, not reset");
        assert_eq!(c.resolved_arrival(), "poisson");
    }

    #[test]
    fn sim_knobs_desugar_to_single_default_tier() {
        let mut c = Config::default();
        c.sim.duration = "lognormal".into();
        c.sim.duration_sigma = 0.7;
        c.sim.arrival = "poisson".into();
        let tiers = c.resolved_tiers();
        assert_eq!(tiers.len(), 1);
        assert_eq!(tiers[0].name, "default");
        assert_eq!(tiers[0].duration, "lognormal");
        assert_eq!(tiers[0].duration_sigma, 0.7);
        assert_eq!(tiers[0].upload_mbps, 0.0);
        assert_eq!(tiers[0].dropout, 0.0);
        assert_eq!(c.resolved_arrival(), "poisson");
        c.validate().unwrap();
    }

    #[test]
    fn scenario_validation_catches_bad_tiers() {
        let bad = |f: &dyn Fn(&mut TierConfig)| {
            let mut c = Config::default();
            let mut t = TierConfig::named("x");
            f(&mut t);
            c.scenario.tiers = vec![t];
            c.validate()
        };
        assert!(bad(&|_| {}).is_ok());
        assert!(bad(&|t| t.weight = -1.0).is_err());
        assert!(bad(&|t| t.weight = 0.0).is_err());
        assert!(bad(&|t| t.weight = f64::NAN).is_err());
        assert!(bad(&|t| t.dropout = 1.0).is_err());
        assert!(bad(&|t| t.dropout = -0.1).is_err());
        assert!(bad(&|t| t.duration_sigma = 0.0).is_err());
        assert!(bad(&|t| t.duration = "uniform".into()).is_err());
        assert!(bad(&|t| t.upload_mbps = -2.0).is_err());
        assert!(bad(&|t| {
            t.day_period = 10.0;
            t.on_fraction = 0.0;
        })
        .is_err());
        assert!(bad(&|t| t.phase = -1.0).is_err());

        let mut c = Config::default();
        c.scenario.arrival = Some("flashmob".into());
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.scenario.burst_on = 0.0;
        assert!(c.validate().is_err());
        // sim.duration_sigma flows into the desugared tier's validation
        let mut c = Config::default();
        c.sim.duration_sigma = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn tier_codec_presets_and_partial_work_round_trip() {
        let doc = toml::parse(
            "[scenario]\nsampling = \"availability\"\n\
             [scenario.tiers.slow]\nquant_client = \"top:0.05\"\n\
             quant_server = \"qsgd:2\"\npartial_work = 0.4\n",
        )
        .unwrap();
        let mut c = Config::default();
        c.apply(&doc).unwrap();
        assert_eq!(c.scenario.sampling, "availability");
        let slow = &c.scenario.tiers[0];
        assert_eq!(slow.quant_client.as_deref(), Some("top:0.05"));
        assert_eq!(slow.quant_server.as_deref(), Some("qsgd:2"));
        assert_eq!(slow.partial_work, 0.4);
        c.validate().unwrap();
        // CLI --set reaches the same knobs and merges into the tier
        let mut c = Config::default();
        c.set("scenario.tiers.slow.quant_client=\"qsgd:2\"").unwrap();
        c.set("scenario.tiers.slow.quant_server=\"qsgd:8\"").unwrap();
        c.set("scenario.tiers.slow.partial_work=0.25").unwrap();
        c.set("scenario.sampling=\"availability\"").unwrap();
        assert_eq!(c.scenario.tiers.len(), 1);
        assert_eq!(c.scenario.tiers[0].quant_client.as_deref(), Some("qsgd:2"));
        assert_eq!(c.scenario.tiers[0].quant_server.as_deref(), Some("qsgd:8"));
        assert_eq!(c.scenario.tiers[0].partial_work, 0.25);
        // no preset: the default stays None (inherit quant.client/server)
        assert_eq!(TierConfig::named("x").quant_client, None);
        assert_eq!(TierConfig::named("x").quant_server, None);
        assert_eq!(TierConfig::named("x").partial_work, 0.0);
    }

    #[test]
    fn tier_codec_presets_and_partial_work_validated() {
        let bad = |f: &dyn Fn(&mut TierConfig)| {
            let mut c = Config::default();
            let mut t = TierConfig::named("x");
            f(&mut t);
            c.scenario.tiers = vec![t];
            c.validate()
        };
        // bad preset strings fail loudly, naming the tier and the spec
        let err = bad(&|t| t.quant_client = Some("huff:3".into())).unwrap_err().to_string();
        assert!(err.contains("quant_client") && err.contains("huff:3"), "{err}");
        assert!(bad(&|t| t.quant_client = Some("qsgd:x".into())).is_err());
        assert!(bad(&|t| t.quant_client = Some("top:0.1".into())).is_ok());
        assert!(bad(&|t| t.quant_client = Some("none".into())).is_ok());
        // the downlink preset goes through the same spec parser
        let err = bad(&|t| t.quant_server = Some("huff:3".into())).unwrap_err().to_string();
        assert!(err.contains("quant_server") && err.contains("huff:3"), "{err}");
        assert!(bad(&|t| t.quant_server = Some("qsgd:2".into())).is_ok());
        assert!(bad(&|t| t.quant_server = Some("none".into())).is_ok());
        // partial_work range
        assert!(bad(&|t| t.partial_work = -0.1).is_err());
        assert!(bad(&|t| t.partial_work = 1.5).is_err());
        assert!(bad(&|t| t.partial_work = f64::NAN).is_err());
        assert!(bad(&|t| t.partial_work = 1.0).is_ok());
        // sampling policy names
        let mut c = Config::default();
        c.scenario.sampling = "roundrobin".into();
        assert!(c.validate().is_err());
        c.scenario.sampling = "availability".into();
        c.validate().unwrap();
    }

    #[test]
    fn net_knobs_round_trip_and_validate() {
        let c = Config::default();
        assert_eq!(c.net.addr, "127.0.0.1:7710");
        assert_eq!(c.net.workers, 4);
        assert_eq!(c.net.v1_grace_ms, 500);
        assert_eq!(c.net.tier, None);
        assert_eq!(c.net.quant_client, None);
        c.validate().unwrap();

        let doc = toml::parse(
            "[net]\naddr = \"0.0.0.0:9000\"\nworkers = 8\nv1_grace_ms = 250\n\
             tier = \"phone\"\nquant_client = \"top:0.1\"\n",
        )
        .unwrap();
        let mut c = Config::default();
        c.apply(&doc).unwrap();
        assert_eq!(c.net.addr, "0.0.0.0:9000");
        assert_eq!(c.net.workers, 8);
        assert_eq!(c.net.v1_grace_ms, 250);
        assert_eq!(c.net.tier.as_deref(), Some("phone"));
        assert_eq!(c.net.quant_client.as_deref(), Some("top:0.1"));

        // CLI --set reaches the same knobs
        let mut c = Config::default();
        c.set("net.workers=3").unwrap();
        c.set("net.quant_client=\"qsgd:2\"").unwrap();
        c.set("net.broadcast_budget_bytes=65536").unwrap();
        assert_eq!(c.net.workers, 3);
        assert_eq!(c.net.quant_client.as_deref(), Some("qsgd:2"));
        assert_eq!(c.net.broadcast_budget_bytes, 65536);
        c.validate().unwrap();
        // default: unlimited (the historical unbounded fan-out)
        assert_eq!(Config::default().net.broadcast_budget_bytes, 0);

        // validation catches bad values loudly
        let mut c = Config::default();
        c.net.workers = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.net.addr = String::new();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.net.v1_grace_ms = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.net.quant_client = Some("huff:3".into());
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("net.quant_client") && err.contains("huff:3"), "{err}");
    }

    #[test]
    fn aggregator_tree_knobs_round_trip_and_validate() {
        // defaults: no tree, shared user draw, identity partial codec
        let c = Config::default();
        assert_eq!(c.scenario.aggregators.edges, 0);
        assert_eq!(c.scenario.aggregators.buffer_size, 1);
        assert_eq!(c.scenario.aggregators.partial_codec, "none");
        assert!(!c.scenario.tier_user_pools);
        assert_eq!(c.net.upstream, None);
        assert_eq!(c.net.edge_buffer, 1);
        assert_eq!(c.net.partial_codec, "none");
        c.validate().unwrap();

        let doc = toml::parse(
            "[scenario]\ntier_user_pools = true\n\
             [scenario.aggregators]\nedges = 8\nbuffer_size = 4\npartial_codec = \"qsgd:8\"\n\
             [net]\nupstream = \"127.0.0.1:7710\"\nedge_buffer = 2\npartial_codec = \"top:0.1\"\n",
        )
        .unwrap();
        let mut c = Config::default();
        c.apply(&doc).unwrap();
        assert!(c.scenario.tier_user_pools);
        assert_eq!(c.scenario.aggregators.edges, 8);
        assert_eq!(c.scenario.aggregators.buffer_size, 4);
        assert_eq!(c.scenario.aggregators.partial_codec, "qsgd:8");
        assert_eq!(c.net.upstream.as_deref(), Some("127.0.0.1:7710"));
        assert_eq!(c.net.edge_buffer, 2);
        assert_eq!(c.net.partial_codec, "top:0.1");
        c.validate().unwrap();

        // CLI --set reaches the same knobs
        let mut c = Config::default();
        c.set("scenario.aggregators.edges=4").unwrap();
        c.set("scenario.aggregators.buffer_size=2").unwrap();
        c.set("net.edge_buffer=3").unwrap();
        assert_eq!(c.scenario.aggregators.edges, 4);
        assert_eq!(c.scenario.aggregators.buffer_size, 2);
        assert_eq!(c.net.edge_buffer, 3);

        // unknown [scenario.aggregators] keys are rejected loudly
        let mut c = Config::default();
        let doc = toml::parse("[scenario.aggregators]\nfanout = 3\n").unwrap();
        let err = c.apply(&doc).unwrap_err().to_string();
        assert!(err.contains("aggregators") && err.contains("fanout"), "{err}");

        // validation catches bad values loudly
        let mut c = Config::default();
        c.scenario.aggregators.edges = 2;
        c.scenario.aggregators.buffer_size = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.scenario.aggregators.edges = 5000;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.scenario.aggregators.partial_codec = "huff:3".into();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("partial_codec") && err.contains("huff:3"), "{err}");
        let mut c = Config::default();
        c.net.upstream = Some(String::new());
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.net.edge_buffer = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.net.partial_codec = "qsgd:x".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn adaptive_knobs_round_trip_and_validate() {
        // defaults: both controllers off, invisible in the resolved doc
        let c = Config::default();
        assert!(!c.net.adaptive.enabled);
        assert!(!c.scenario.adaptive.enabled);
        assert_eq!(c.net.adaptive.interval, 10);
        assert_eq!(c.net.adaptive.min_uploads, 1);
        assert!(c.net.adaptive.levels.is_empty());
        assert!(
            !c.to_json().to_string().contains("adaptive"),
            "adaptive-off configs must keep their pre-adaptive fingerprint"
        );
        c.validate().unwrap();

        // TOML overlay reaches both tables; levels split on commas
        let doc = toml::parse(
            "[net.adaptive]\nenabled = true\ninterval = 5\n\
             budget_bytes_per_step = 4096\nlevels = \"qsgd:8, qsgd:4,qsgd:2\"\n\
             min_uploads = 2\n\
             [scenario.adaptive]\nenabled = true\ninterval = 20\n\
             budget_bytes_per_step = 65536\nlevels = \"qsgd:4,top:0.05\"\n",
        )
        .unwrap();
        let mut c = Config::default();
        c.apply(&doc).unwrap();
        assert!(c.net.adaptive.enabled);
        assert_eq!(c.net.adaptive.interval, 5);
        assert_eq!(c.net.adaptive.budget_bytes_per_step, 4096);
        assert_eq!(c.net.adaptive.levels, vec!["qsgd:8", "qsgd:4", "qsgd:2"]);
        assert_eq!(c.net.adaptive.min_uploads, 2);
        assert!(c.scenario.adaptive.enabled);
        assert_eq!(c.scenario.adaptive.interval, 20);
        assert_eq!(c.scenario.adaptive.levels, vec!["qsgd:4", "top:0.05"]);
        assert_eq!(c.scenario.adaptive.min_uploads, 1); // default kept
        c.validate().unwrap();

        // enabled controllers round-trip through to_json/apply exactly
        let doc = c.to_json();
        let mut back = Config::default();
        back.apply(&doc).unwrap();
        assert_eq!(back.to_json().to_string(), doc.to_string());
        assert_eq!(back.scenario.adaptive.levels, vec!["qsgd:4", "top:0.05"]);

        // CLI --set reaches the same knobs
        let mut c = Config::default();
        c.set("scenario.adaptive.enabled=true").unwrap();
        c.set("scenario.adaptive.budget_bytes_per_step=8192").unwrap();
        c.set("scenario.adaptive.levels=\"qsgd:8,qsgd:2\"").unwrap();
        assert!(c.scenario.adaptive.enabled);
        assert_eq!(c.scenario.adaptive.budget_bytes_per_step, 8192);
        assert_eq!(c.scenario.adaptive.levels, vec!["qsgd:8", "qsgd:2"]);
        c.validate().unwrap();

        // unknown keys rejected loudly, naming the table
        let mut c = Config::default();
        let doc = toml::parse("[net.adaptive]\nbudget = 3\n").unwrap();
        let err = c.apply(&doc).unwrap_err().to_string();
        assert!(err.contains("net.adaptive") && err.contains("budget"), "{err}");
        let doc = toml::parse("[scenario.adaptive]\ncadence = 3\n").unwrap();
        let err = c.apply(&doc).unwrap_err().to_string();
        assert!(err.contains("scenario.adaptive") && err.contains("cadence"), "{err}");

        // validation (enabled only): interval, budget, ladder specs
        let enabled = |f: &dyn Fn(&mut AdaptiveConfig)| {
            let mut c = Config::default();
            c.net.adaptive.enabled = true;
            c.net.adaptive.budget_bytes_per_step = 1024;
            c.net.adaptive.levels = vec!["qsgd:4".into()];
            f(&mut c.net.adaptive);
            c.validate()
        };
        assert!(enabled(&|_| {}).is_ok());
        assert!(enabled(&|a| a.interval = 0).is_err());
        assert!(enabled(&|a| a.budget_bytes_per_step = 0).is_err());
        assert!(enabled(&|a| a.levels.clear()).is_err());
        let err = enabled(&|a| a.levels = vec!["huff:3".into()]).unwrap_err().to_string();
        assert!(err.contains("net.adaptive.levels") && err.contains("huff:3"), "{err}");
        // a disabled controller never validates its knobs
        let mut c = Config::default();
        c.net.adaptive.budget_bytes_per_step = 0;
        c.scenario.adaptive.levels = vec!["huff:3".into()];
        c.validate().unwrap();
    }

    #[test]
    fn robust_knobs_round_trip_and_validate() {
        // defaults: off, invisible in the resolved doc (fingerprint
        // byte-identical to the pre-robustness engine)
        let c = Config::default();
        assert!(!c.fl.robust.enabled);
        assert!(!c.fl.robust.clip_enabled() && !c.fl.robust.trim_enabled());
        assert!(
            !c.to_json().to_string().contains("robust"),
            "robust-off configs must keep their pre-robustness fingerprint"
        );
        c.validate().unwrap();

        // TOML overlay
        let doc = toml::parse(
            "[fl.robust]\nenabled = true\nclip_norm = 2.5\nnormalize = true\n\
             trim_frac = 0.2\n",
        )
        .unwrap();
        let mut c = Config::default();
        c.apply(&doc).unwrap();
        assert!(c.fl.robust.enabled);
        assert_eq!(c.fl.robust.clip_norm, 2.5);
        assert!(c.fl.robust.normalize);
        assert_eq!(c.fl.robust.trim_frac, 0.2);
        assert!(c.fl.robust.clip_enabled() && c.fl.robust.trim_enabled());
        c.validate().unwrap();

        // enabled tables round-trip through to_json/apply exactly
        let doc = c.to_json();
        let mut back = Config::default();
        back.apply(&doc).unwrap();
        assert_eq!(back.to_json().to_string(), doc.to_string());
        assert_eq!(back.fl.robust.trim_frac, 0.2);

        // CLI --set reaches the same knobs
        let mut c = Config::default();
        c.set("fl.robust.enabled=true").unwrap();
        c.set("fl.robust.trim_frac=0.3").unwrap();
        assert!(c.fl.robust.trim_enabled());
        assert!(!c.fl.robust.clip_enabled());
        c.validate().unwrap();

        // unknown keys rejected loudly, naming the table
        let mut c = Config::default();
        let doc = toml::parse("[fl.robust]\nmedian = true\n").unwrap();
        let err = c.apply(&doc).unwrap_err().to_string();
        assert!(err.contains("fl.robust") && err.contains("median"), "{err}");

        // validation (enabled only): clip range, trim range, dead table
        let enabled = |f: &dyn Fn(&mut RobustConfig)| {
            let mut c = Config::default();
            c.fl.robust.enabled = true;
            c.fl.robust.clip_norm = 1.0;
            f(&mut c.fl.robust);
            c.validate()
        };
        assert!(enabled(&|_| {}).is_ok());
        assert!(enabled(&|r| r.clip_norm = -1.0).is_err());
        assert!(enabled(&|r| r.clip_norm = f64::NAN).is_err());
        assert!(enabled(&|r| r.trim_frac = 0.5).is_err());
        assert!(enabled(&|r| r.trim_frac = 0.7).is_err());
        assert!(enabled(&|r| r.trim_frac = -0.1).is_err());
        assert!(enabled(&|r| r.trim_frac = 0.49).is_ok());
        let err = enabled(&|r| r.clip_norm = 0.0).unwrap_err().to_string();
        assert!(err.contains("nothing to do"), "{err}");
        assert!(enabled(&|r| {
            r.clip_norm = 0.0;
            r.trim_frac = 0.2;
        })
        .is_ok());
        let err = enabled(&|r| {
            r.clip_norm = 0.0;
            r.trim_frac = 0.2;
            r.normalize = true;
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("normalize"), "{err}");
        // a disabled table never validates its knobs
        let mut c = Config::default();
        c.fl.robust.clip_norm = -3.0;
        c.fl.robust.trim_frac = 0.9;
        c.validate().unwrap();

        // trimming needs individual rows at the root: trim + edge
        // aggregators is rejected (clip + edges stays fine)
        let mut c = Config::default();
        c.fl.robust.enabled = true;
        c.fl.robust.trim_frac = 0.2;
        c.scenario.aggregators.edges = 2;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("trim_frac") && err.contains("edges"), "{err}");
        c.fl.robust.trim_frac = 0.0;
        c.fl.robust.clip_norm = 1.0;
        c.validate().unwrap();
    }

    #[test]
    fn tier_noise_and_adversary_round_trip_and_validate() {
        let doc = toml::parse(
            "[scenario.tiers.hostile]\nadversary = \"sign_flip\"\n\
             [scenario.tiers.noisy]\ngrad_noise = \"student_t:3:0.5\"\n",
        )
        .unwrap();
        let mut c = Config::default();
        c.apply(&doc).unwrap();
        assert_eq!(c.scenario.tiers[0].adversary.as_deref(), Some("sign_flip"));
        assert_eq!(c.scenario.tiers[1].grad_noise.as_deref(), Some("student_t:3:0.5"));
        c.validate().unwrap();
        // round trip through to_json (declaration order kept)
        let doc = c.to_json();
        let mut back = Config::default();
        back.apply(&doc).unwrap();
        assert_eq!(back.to_json().to_string(), doc.to_string());
        // knobs absent by default — and absent from the emitted doc
        assert_eq!(TierConfig::named("x").grad_noise, None);
        assert_eq!(TierConfig::named("x").adversary, None);
        assert!(!Config::default().to_json().to_string().contains("grad_noise"));

        // CLI --set reaches the same knobs and merges into the tier
        let mut c = Config::default();
        c.set("scenario.tiers.bad.adversary=\"scale:10\"").unwrap();
        c.set("scenario.tiers.bad.grad_noise=\"pareto:2:0.1\"").unwrap();
        assert_eq!(c.scenario.tiers.len(), 1);
        assert_eq!(c.scenario.tiers[0].adversary.as_deref(), Some("scale:10"));
        assert_eq!(c.scenario.tiers[0].grad_noise.as_deref(), Some("pareto:2:0.1"));
        c.validate().unwrap();

        // bad spec strings fail loudly, naming the tier and the spec
        let bad = |f: &dyn Fn(&mut TierConfig)| {
            let mut c = Config::default();
            let mut t = TierConfig::named("x");
            f(&mut t);
            c.scenario.tiers = vec![t];
            c.validate()
        };
        let err = bad(&|t| t.grad_noise = Some("cauchy:1".into())).unwrap_err().to_string();
        assert!(err.contains("grad_noise") && err.contains("cauchy:1"), "{err}");
        assert!(bad(&|t| t.grad_noise = Some("student_t:0:1".into())).is_err());
        assert!(bad(&|t| t.grad_noise = Some("pareto:2:-1".into())).is_err());
        assert!(bad(&|t| t.grad_noise = Some("pareto:1.5:0.1".into())).is_ok());
        let err = bad(&|t| t.adversary = Some("byzantine".into())).unwrap_err().to_string();
        assert!(err.contains("adversary") && err.contains("byzantine"), "{err}");
        assert!(bad(&|t| t.adversary = Some("scale:0".into())).is_err());
        assert!(bad(&|t| t.adversary = Some("stale_replay".into())).is_ok());
        assert!(bad(&|t| t.adversary = Some("sign_flip".into())).is_ok());
    }

    #[test]
    fn telemetry_knobs_round_trip_and_validate() {
        let c = Config::default();
        assert_eq!(c.telemetry.journal, None);
        assert_eq!(c.telemetry.checkpoint_every, 0);
        assert_eq!(c.telemetry.progress, 0);

        let doc = toml::parse(
            "[telemetry]\njournal = \"run.jsonl\"\ncheckpoint_every = 100\nprogress = 10\n",
        )
        .unwrap();
        let mut c = Config::default();
        c.apply(&doc).unwrap();
        assert_eq!(c.telemetry.journal.as_deref(), Some("run.jsonl"));
        assert_eq!(c.telemetry.checkpoint_every, 100);
        assert_eq!(c.telemetry.progress, 10);

        // CLI --set reaches the same knobs
        let mut c = Config::default();
        c.set("telemetry.journal=\"j.jsonl\"").unwrap();
        c.set("telemetry.progress=5").unwrap();
        assert_eq!(c.telemetry.journal.as_deref(), Some("j.jsonl"));
        assert_eq!(c.telemetry.progress, 5);

        // checkpoints need a journal to live in
        let mut c = Config::default();
        c.telemetry.checkpoint_every = 50;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("telemetry.journal"), "{err}");
        c.telemetry.journal = Some("run.jsonl".into());
        c.validate().unwrap();
    }

    #[test]
    fn to_json_round_trips_through_apply() {
        let mut c = Config::default();
        c.name = "roundtrip".into();
        c.seeds = vec![7, 9];
        c.fl.algorithm = Algorithm::FedBuff;
        c.fl.clip_norm = 0.5;
        c.quant.server = "qsgd:2".into();
        c.sim.duration = "lognormal".into();
        c.scenario.arrival = Some("bursty".into());
        c.scenario.tier_user_pools = true;
        c.scenario.aggregators.edges = 2;
        c.scenario.aggregators.buffer_size = 2;
        // out-of-alphabetical tier order, as repeated --set can produce
        c.set("scenario.tiers.phone.quant_client=\"top:0.1\"").unwrap();
        c.set("scenario.tiers.apad.weight=2").unwrap();
        c.net.tier = Some("phone".into());
        c.net.upstream = Some("127.0.0.1:7711".into());
        c.telemetry.journal = Some("run.jsonl".into());
        c.telemetry.progress = 5;

        let doc = c.to_json();
        let mut back = Config::default();
        back.apply(&doc).unwrap();
        // field-for-field round trip, including tier declaration order
        assert_eq!(back.to_json().to_string(), doc.to_string());
        assert_eq!(back.scenario.tiers.len(), 2);
        assert_eq!(back.scenario.tiers[0].name, "phone");
        assert_eq!(back.scenario.tiers[1].name, "apad");
        assert_eq!(back.scenario.tiers[1].weight, 2.0);
        assert_eq!(back.fl.algorithm, Algorithm::FedBuff);
        assert_eq!(back.net.upstream.as_deref(), Some("127.0.0.1:7711"));
        // telemetry is observer config: absent from the doc, so the
        // fingerprint of a run does not depend on whether it was recorded
        assert!(doc.get("telemetry").is_none());
        assert_eq!(back.telemetry.journal, None);

        // defaults round-trip too
        let c = Config::default();
        let mut back = Config::default();
        back.apply(&c.to_json()).unwrap();
        assert_eq!(back.to_json().to_string(), c.to_json().to_string());
    }

    #[test]
    fn tiers_array_form_keeps_order_and_rejects_anonymous_entries() {
        // the array form is how to_json() docs express tier order (the
        // vendored TOML parser has no [[array-of-tables]], so this path
        // is JSON-doc-only)
        let tiers = |list: Vec<Json>| {
            Json::obj(vec![("scenario", Json::obj(vec![("tiers", Json::Arr(list))]))])
        };
        let mut c = Config::default();
        c.apply(&tiers(vec![
            Json::obj(vec![("name", Json::str("zeta")), ("weight", Json::num(3.0))]),
            Json::obj(vec![("name", Json::str("alpha")), ("dropout", Json::num(0.1))]),
        ]))
        .unwrap();
        assert_eq!(c.scenario.tiers.len(), 2);
        assert_eq!(c.scenario.tiers[0].name, "zeta");
        assert_eq!(c.scenario.tiers[0].weight, 3.0);
        assert_eq!(c.scenario.tiers[1].name, "alpha");
        assert_eq!(c.scenario.tiers[1].dropout, 0.1);

        let mut c = Config::default();
        let err = c
            .apply(&tiers(vec![Json::obj(vec![("weight", Json::num(1.0))])]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("name"), "{err}");
    }

    #[test]
    fn algorithm_parse() {
        assert_eq!(Algorithm::parse("QAFeL").unwrap(), Algorithm::Qafel);
        assert_eq!(Algorithm::parse("direct-quant").unwrap(), Algorithm::DirectQuant);
        assert!(Algorithm::parse("sgd").is_err());
    }
}
