//! Minimal TOML parser (offline environment: no `toml` crate).
//!
//! Supports the subset used by qafel config files: comments, `[section]`
//! and `[dotted.section]` headers, bare/quoted keys, strings, integers,
//! floats (incl. scientific notation), booleans, and homogeneous arrays.
//! Parsed documents are represented as [`Json`] objects so the config
//! layer has a single value type.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Parse error with line number.
#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse TOML text into a nested [`Json::Obj`].
pub fn parse(text: &str) -> Result<Json, TomlError> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    let mut section: Vec<String> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError { line: lineno + 1, msg: msg.to_string() };

        if let Some(rest) = line.strip_prefix('[') {
            let inner = rest.strip_suffix(']').ok_or_else(|| err("unterminated section header"))?;
            if inner.is_empty() || inner.starts_with('[') {
                return Err(err("array-of-tables not supported"));
            }
            section = inner.split('.').map(|s| s.trim().to_string()).collect();
            if section.iter().any(|s| s.is_empty()) {
                return Err(err("empty section component"));
            }
            // materialize the section so empty sections exist
            ensure_path(&mut root, &section).map_err(|m| err(&m))?;
            continue;
        }

        let eq = line.find('=').ok_or_else(|| err("expected key = value"))?;
        let key = line[..eq].trim().trim_matches('"').to_string();
        if key.is_empty() {
            return Err(err("empty key"));
        }
        let vtext = line[eq + 1..].trim();
        let value = parse_value(vtext).map_err(|m| err(&m))?;

        let obj = ensure_path(&mut root, &section).map_err(|m| err(&m))?;
        if obj.insert(key.clone(), value).is_some() {
            return Err(err(&format!("duplicate key '{key}'")));
        }
    }
    Ok(Json::Obj(root))
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn ensure_path<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[String],
) -> Result<&'a mut BTreeMap<String, Json>, String> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        match entry {
            Json::Obj(m) => cur = m,
            _ => return Err(format!("'{part}' is not a table")),
        }
    }
    Ok(cur)
}

fn parse_value(text: &str) -> Result<Json, String> {
    let t = text.trim();
    if t.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = t.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Json::Str(unescape(inner)?));
    }
    if t == "true" {
        return Ok(Json::Bool(true));
    }
    if t == "false" {
        return Ok(Json::Bool(false));
    }
    if let Some(rest) = t.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for piece in split_top_level(inner) {
            let piece = piece.trim();
            if piece.is_empty() {
                continue;
            }
            items.push(parse_value(piece)?);
        }
        return Ok(Json::Arr(items));
    }
    // numbers: TOML allows underscores as separators
    let cleaned: String = t.chars().filter(|&c| c != '_').collect();
    cleaned
        .parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("cannot parse value '{t}'"))
}

fn unescape(s: &str) -> Result<String, String> {
    let mut out = String::new();
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('r') => out.push('\r'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            other => return Err(format!("bad escape: \\{other:?}")),
        }
    }
    Ok(out)
}

/// Split an array body on commas that are not nested in strings/brackets.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let text = r#"
# qafel experiment config
name = "table1"

[fl]
buffer_size = 10
client_lr = 4.7e-6
server_lr = 1_000.0
staleness_scaling = false

[quant]
client = "qsgd:4"
server = "qsgd:4"

[sim]
seeds = [1, 2, 3]
concurrency = 100     # clients in parallel
"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("table1"));
        assert_eq!(v.at(&["fl", "buffer_size"]).unwrap().as_usize(), Some(10));
        assert!((v.at(&["fl", "client_lr"]).unwrap().as_f64().unwrap() - 4.7e-6).abs() < 1e-12);
        assert_eq!(v.at(&["fl", "server_lr"]).unwrap().as_f64(), Some(1000.0));
        assert_eq!(v.at(&["fl", "staleness_scaling"]).unwrap().as_bool(), Some(false));
        assert_eq!(v.at(&["quant", "client"]).unwrap().as_str(), Some("qsgd:4"));
        let seeds = v.at(&["sim", "seeds"]).unwrap().as_arr().unwrap();
        assert_eq!(seeds.len(), 3);
        assert_eq!(v.at(&["sim", "concurrency"]).unwrap().as_usize(), Some(100));
    }

    #[test]
    fn dotted_sections_nest() {
        let v = parse("[a.b]\nx = 1\n[a.c]\ny = 2\n").unwrap();
        assert_eq!(v.at(&["a", "b", "x"]).unwrap().as_usize(), Some(1));
        assert_eq!(v.at(&["a", "c", "y"]).unwrap().as_usize(), Some(2));
    }

    #[test]
    fn strings_with_hash_and_escapes() {
        let v = parse(r#"s = "a # not comment \n done""#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("a # not comment \n done"));
    }

    #[test]
    fn nested_arrays() {
        let v = parse("m = [[1, 2], [3, 4]]").unwrap();
        let rows = v.get("m").unwrap().as_arr().unwrap();
        assert_eq!(rows[1].as_arr().unwrap()[0].as_f64(), Some(3.0));
    }

    #[test]
    fn errors_have_line_numbers() {
        let e = parse("ok = 1\nbroken line\n").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(parse("[unclosed\n").is_err());
        assert!(parse("dup = 1\ndup = 2\n").is_err());
    }
}
