//! # QAFeL — Quantized Asynchronous Federated Learning
//!
//! Production-quality reproduction of *"Asynchronous Federated Learning
//! with Bidirectional Quantized Communications and Buffered Aggregation"*
//! (Ortega & Jafarkhani, 2023) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: an
//!   asynchronous federated-learning server with buffered aggregation
//!   (FedBuff), bidirectional quantized communication and a shared hidden
//!   state ([`coordinator`]), plus the event-driven simulator ([`sim`]),
//!   a real threaded/TCP runtime ([`net`]), quantizers with exact wire
//!   codecs ([`quant`]), the heterogeneous-population scenario engine
//!   ([`scenario`], DESIGN_SCENARIOS.md: device tiers with per-tier
//!   quantizer presets and partial-work dropout, pluggable arrival
//!   processes with availability-weighted tier sampling, trace-driven
//!   calibration, versioned snapshot store for million-client streams),
//!   and the experiment harness ([`experiments`]).
//!   ARCHITECTURE.md maps the paper's Algorithms 1–3 to these modules
//!   line by line; CONFIG.md is the complete configuration reference.
//!   The server step runs as a **sharded aggregation pipeline**
//!   (`cfg.fl.shards`, DESIGN_SHARDING.md): accumulate / momentum /
//!   diff / `Q_s` encode execute shard-parallel over bucket-aligned
//!   ranges on a persistent worker pool ([`util::pool::ShardPool`] —
//!   zero thread spawns per step) with bit-identical broadcasts for
//!   every shard count, for every codec (qsgd/identity stitch, top_k
//!   candidate-merge, rand_k per-bucket index streams).
//! * **L2** — the LEAF-CelebA CNN fwd/bwd in JAX (`python/compile/model.py`),
//!   AOT-lowered once to HLO text and executed from Rust via PJRT
//!   ([`runtime`]). Python never runs on the request path.
//! * **L1** — Pallas kernels (`python/compile/kernels/`): tiled matmul and
//!   the qsgd stochastic-quantization kernel, lowered into the same HLO.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured results of every table and figure.

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod net;
pub mod quant;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod telemetry;
pub mod testing;
pub mod util;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
