//! Scenario engine: heterogeneous client populations at million-client
//! scale (DESIGN_SCENARIOS.md).
//!
//! The simulator used to model clients with two global knobs
//! (`sim.arrival`, `sim.duration`) and one shared delay distribution.
//! This subsystem owns the population model instead:
//!
//! * [`population::Scenario`] — a weighted mix of **device tiers**
//!   ([`crate::config::TierConfig`]), each with its own duration
//!   distribution, upload/download bandwidth (fed into per-trip transfer
//!   delays and byte accounting), dropout probability, and diurnal
//!   availability window;
//! * [`arrival`] — pluggable **arrival processes** behind a trait:
//!   constant (paper), Poisson, and a bursty 2-state MMPP, all
//!   calibrated to the same long-run rate `concurrency / E[duration]`;
//! * [`snapshots::SnapshotStore`] — **versioned hidden-state snapshots**
//!   keyed by server step `t`: every client arriving between two server
//!   steps shares one `Arc`, so memory is O(distinct model versions),
//!   not O(in-flight clients) — the property that makes `concurrency`
//!   in the 10⁵–10⁶ range feasible;
//! * [`metrics::ScenarioMetrics`] — per-tier staleness histograms,
//!   dropout counts and byte totals, threaded into
//!   [`crate::metrics::RunResult`].
//!
//! Since v2 the population model also covers (DESIGN_SCENARIOS.md):
//!
//! * **per-tier quantizer presets** — `scenario.tiers.<name>.quant_client`
//!   gives a tier its own upload codec (slow tiers compress harder);
//!   the server ingests the resulting heterogeneous wire formats per
//!   message ([`crate::coordinator::Server::ingest_from`]);
//! * **mid-round partial-work dropout** — `partial_work` lets a dropped
//!   client submit the `m/P`-step prefix it completed (FedBuff partial
//!   work) instead of discarding it;
//! * **availability-weighted sampling** — `scenario.sampling =
//!   "availability"` draws arriving tiers proportional to
//!   `weight x 1[on]`, so diurnal windows shape *who* arrives;
//! * **trace-driven calibration** — [`calibrate`] fits tier weights and
//!   duration distributions from an observed client-trace CSV
//!   (`qafel scenario calibrate <trace.csv>`).
//!
//! **Back-compat contract**: a config without a `[scenario]` table
//! desugars to a single always-available tier built from the `sim.*`
//! knobs, and the engine's randomness streams are arranged so that this
//! default reproduces the pre-scenario simulator **bit-identically**
//! (golden-tested in `tests/scenario.rs`). The same contract extends to
//! v2: tiers without presets (and `partial_work = 0`, `sampling =
//! "weighted"`) replay the v1 engine bit-for-bit.

pub mod arrival;
pub mod calibrate;
pub mod metrics;
pub mod population;
pub mod robust;
pub mod snapshots;

pub use arrival::{build_arrival, ArrivalProcess};
pub use calibrate::{fit_trace, FittedTier};
pub use metrics::{ScenarioMetrics, StalenessHist, TierMetrics};
pub use population::{duration_dist, Sampling, Scenario, Tier};
pub use robust::{Adversary, GradNoise};
pub use snapshots::SnapshotStore;
