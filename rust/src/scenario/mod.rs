//! Scenario engine: heterogeneous client populations at million-client
//! scale (DESIGN_SCENARIOS.md).
//!
//! The simulator used to model clients with two global knobs
//! (`sim.arrival`, `sim.duration`) and one shared delay distribution.
//! This subsystem owns the population model instead:
//!
//! * [`population::Scenario`] — a weighted mix of **device tiers**
//!   ([`crate::config::TierConfig`]), each with its own duration
//!   distribution, upload/download bandwidth (fed into per-trip transfer
//!   delays and byte accounting), dropout probability, and diurnal
//!   availability window;
//! * [`arrival`] — pluggable **arrival processes** behind a trait:
//!   constant (paper), Poisson, and a bursty 2-state MMPP, all
//!   calibrated to the same long-run rate `concurrency / E[duration]`;
//! * [`snapshots::SnapshotStore`] — **versioned hidden-state snapshots**
//!   keyed by server step `t`: every client arriving between two server
//!   steps shares one `Arc`, so memory is O(distinct model versions),
//!   not O(in-flight clients) — the property that makes `concurrency`
//!   in the 10⁵–10⁶ range feasible;
//! * [`metrics::ScenarioMetrics`] — per-tier staleness histograms,
//!   dropout counts and byte totals, threaded into
//!   [`crate::metrics::RunResult`].
//!
//! **Back-compat contract**: a config without a `[scenario]` table
//! desugars to a single always-available tier built from the `sim.*`
//! knobs, and the engine's randomness streams are arranged so that this
//! default reproduces the pre-scenario simulator **bit-identically**
//! (golden-tested in `tests/scenario.rs`).

pub mod arrival;
pub mod metrics;
pub mod population;
pub mod snapshots;

pub use arrival::{build_arrival, ArrivalProcess};
pub use metrics::{ScenarioMetrics, StalenessHist, TierMetrics};
pub use population::{duration_dist, Scenario, Tier};
pub use snapshots::SnapshotStore;
