//! Per-tier and per-run scenario metrics (staleness histograms, dropout
//! counts, byte accounting by device tier, concurrency tracking).
//!
//! Everything here is plain counting — no randomness is drawn — so
//! recording metrics can never perturb a run's trajectory. The counters
//! are threaded into [`crate::metrics::RunResult`] by the simulator and
//! flattened to CSV by the heterogeneity experiment.

use crate::util::json::Json;

/// Power-of-two bucketed histogram of observed staleness values
/// (`tau_n(t)` in the paper). Bucket 0 holds exact zeros; bucket `i >= 1`
/// holds `[2^(i-1), 2^i)`, so the whole `u64` range fits in 65 buckets
/// while the small staleness values the theory cares about stay
/// individually resolved.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StalenessHist {
    /// Bucket counts, grown on demand (index = [`StalenessHist::bucket`]).
    pub counts: Vec<u64>,
    /// Sum of all recorded values (for the exact mean).
    pub sum: u64,
    /// Largest recorded value.
    pub max: u64,
    /// Number of recorded values.
    pub n: u64,
}

impl StalenessHist {
    /// Bucket index for a staleness value.
    pub fn bucket(s: u64) -> usize {
        if s == 0 {
            0
        } else {
            64 - s.leading_zeros() as usize
        }
    }

    /// Inclusive value range `(lo, hi)` covered by bucket `i`.
    pub fn bucket_range(i: usize) -> (u64, u64) {
        if i == 0 {
            (0, 0)
        } else if i >= 64 {
            // top bucket saturates (1 << 64 would overflow the shift)
            (1u64 << 63, u64::MAX)
        } else {
            (1u64 << (i - 1), (1u64 << i) - 1)
        }
    }

    pub fn record(&mut self, s: u64) {
        let b = Self::bucket(s);
        if self.counts.len() <= b {
            self.counts.resize(b + 1, 0);
        }
        self.counts[b] += 1;
        self.sum += s;
        self.max = self.max.max(s);
        self.n += 1;
    }

    /// Rebuild a histogram from its serialized parts (the wire form of
    /// a partial-aggregate frame carries exactly these four fields).
    pub fn from_parts(counts: Vec<u64>, sum: u64, max: u64, n: u64) -> StalenessHist {
        StalenessHist { counts, sum, max, n }
    }

    /// Fold another histogram into this one — how per-edge staleness
    /// summaries merge up an aggregation tree. Exact: bucket counts,
    /// sum, max and n all add, so the merged mean equals the mean over
    /// the union of the recorded values.
    pub fn merge(&mut self, other: &StalenessHist) {
        if self.counts.len() < other.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (i, &c) in other.counts.iter().enumerate() {
            self.counts[i] += c;
        }
        self.sum += other.sum;
        self.max = self.max.max(other.max);
        self.n += other.n;
    }

    /// Exact mean of the recorded values.
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum as f64 / self.n as f64
        }
    }

    /// Approximate q-quantile (q in [0, 1]): the upper bound of the
    /// first bucket whose cumulative count reaches `ceil(q * n)`,
    /// clamped to the exact observed max. Buckets 0 and 1 are exact, so
    /// small staleness quantiles (the common case) are exact too.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.n == 0 {
            return 0;
        }
        let target = ((q * self.n as f64).ceil() as u64).clamp(1, self.n);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                let (_, hi) = Self::bucket_range(i);
                return hi.min(self.max);
            }
        }
        self.max
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "counts",
                Json::arr(self.counts.iter().map(|&c| Json::num(c as f64)).collect()),
            ),
            ("sum", Json::num(self.sum as f64)),
            ("max", Json::num(self.max as f64)),
            ("n", Json::num(self.n as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<StalenessHist> {
        let get = |k: &str| -> anyhow::Result<u64> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .map(|f| f as u64)
                .ok_or_else(|| anyhow::anyhow!("staleness hist: missing numeric field '{k}'"))
        };
        let counts = j
            .get("counts")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("staleness hist: missing 'counts' array"))?
            .iter()
            .map(|v| {
                v.as_f64()
                    .map(|f| f as u64)
                    .ok_or_else(|| anyhow::anyhow!("staleness hist: non-numeric count"))
            })
            .collect::<anyhow::Result<Vec<u64>>>()?;
        Ok(StalenessHist::from_parts(counts, get("sum")?, get("max")?, get("n")?))
    }

    /// Compact text form for CSV cells: `"0:12|1:30|2-3:7"` (empty
    /// buckets omitted).
    pub fn spec_string(&self) -> String {
        let mut parts = Vec::new();
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let (lo, hi) = Self::bucket_range(i);
            if lo == hi {
                parts.push(format!("{lo}:{c}"));
            } else {
                parts.push(format!("{lo}-{hi}:{c}"));
            }
        }
        parts.join("|")
    }
}

/// Counters for one device tier.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TierMetrics {
    pub name: String,
    /// Client-upload codec this tier encodes with (`quant.client` or
    /// the tier's `quant_client` preset, resolved per algorithm). Set by
    /// the engine once codecs are registered.
    pub codec: String,
    /// Broadcast (downlink) codec this tier decodes with — set by the
    /// engine only when the tier's `quant_server` preset resolved to a
    /// non-default downlink family; empty means the default `Q_s`.
    /// Serialized conditionally so no-preset checkpoints stay
    /// byte-identical to the pre-family engine.
    pub download_codec: String,
    /// Clients of this tier that arrived while the tier was available.
    pub arrivals: u64,
    /// Arrivals skipped because the tier was in its off window.
    pub unavailable: u64,
    /// Clients that trained but dropped before uploading anything.
    pub dropouts: u64,
    /// Updates this tier delivered to the server (full + partial).
    pub uploads: u64,
    /// Uploads that carried mid-round partial work (a dropped client
    /// submitting the `m/P` prefix it completed) — a subset of
    /// `uploads`.
    pub partial_uploads: u64,
    /// Wire bytes uploaded by this tier.
    pub upload_bytes: u64,
    /// Wire bytes downloaded by this tier (one hidden-state increment
    /// per trip in broadcast mode).
    pub download_bytes: u64,
    /// Downlink bytes spent on clients that contributed nothing (full
    /// dropouts): the communication the population wasted.
    pub wasted_download_bytes: u64,
    /// Mid-run upload-codec switches the adaptive controller
    /// (`[scenario.adaptive]`) applied to this tier. Serialized
    /// conditionally so adaptive-off checkpoints stay byte-identical to
    /// the pre-adaptive engine.
    pub codec_switches: u64,
    /// Gradient-noise spec this tier injects (`grad_noise` preset);
    /// empty for honest tiers. Tag only — drawn counters live on the
    /// robust side. Serialized conditionally, like `download_codec`.
    pub grad_noise: String,
    /// Adversary behavior this tier runs (`adversary` preset); empty
    /// for honest tiers. Serialized conditionally.
    pub adversary: String,
    /// Uploads from this tier the robust server shrank with the norm
    /// clip ([fl.robust] clip_norm). Serialized conditionally so
    /// robust-off checkpoints stay byte-identical.
    pub clipped_updates: u64,
    /// Uploads from this tier the trimmed mean excluded at a majority
    /// of coordinates. Serialized conditionally.
    pub trimmed_updates: u64,
    pub staleness: StalenessHist,
}

/// Counters for one edge aggregator of the tree (empty on flat runs).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EdgeMetrics {
    pub edge_id: usize,
    /// Client updates this edge ingested.
    pub updates: u64,
    /// Wire bytes of those uploads as received at the edge.
    pub update_bytes: u64,
    /// Partial aggregates this edge forwarded upstream.
    pub partials: u64,
    /// Wire bytes of the forwarded partials.
    pub partial_bytes: u64,
    /// Staleness over every update ingested at this edge.
    pub staleness: StalenessHist,
}

/// All scenario-level metrics for one run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ScenarioMetrics {
    /// One entry per tier, in the scenario's tier order.
    pub tiers: Vec<TierMetrics>,
    /// One entry per edge aggregator, in edge order — empty unless the
    /// run used a `[scenario.aggregators]` tree.
    pub edges: Vec<EdgeMetrics>,
    /// Staleness over every upload regardless of tier.
    pub staleness: StalenessHist,
    /// Arrivals lost because *every* tier was in its off window
    /// (availability-weighted sampling only; weighted sampling attributes
    /// off-window skips to the drawn tier's `unavailable` instead).
    pub arrivals_all_off: u64,
    /// Time-averaged number of in-flight clients (Little's-law check
    /// against `sim.concurrency`).
    pub mean_concurrency: f64,
    /// Peak number of simultaneously in-flight clients.
    pub max_in_flight: usize,
    /// Peak number of live model versions in the snapshot store — the
    /// memory story: O(distinct versions), not O(in-flight clients).
    pub max_live_snapshots: usize,
}

impl ScenarioMetrics {
    pub fn with_tiers<I: IntoIterator<Item = String>>(names: I) -> ScenarioMetrics {
        ScenarioMetrics {
            tiers: names
                .into_iter()
                .map(|name| TierMetrics { name, ..Default::default() })
                .collect(),
            ..Default::default()
        }
    }

    pub fn record_arrival(&mut self, tier: usize) {
        self.tiers[tier].arrivals += 1;
    }

    pub fn record_unavailable(&mut self, tier: usize) {
        self.tiers[tier].unavailable += 1;
    }

    /// An arrival under availability-weighted sampling that found every
    /// tier in its off window.
    pub fn record_all_off(&mut self) {
        self.arrivals_all_off += 1;
    }

    pub fn record_dropout(&mut self, tier: usize, download_bytes: usize) {
        let t = &mut self.tiers[tier];
        t.dropouts += 1;
        t.download_bytes += download_bytes as u64;
        // a full dropout contributes nothing: its downlink was wasted
        t.wasted_download_bytes += download_bytes as u64;
    }

    pub fn record_upload(
        &mut self,
        tier: usize,
        staleness: u64,
        upload_bytes: usize,
        download_bytes: usize,
    ) {
        let t = &mut self.tiers[tier];
        t.uploads += 1;
        t.upload_bytes += upload_bytes as u64;
        t.download_bytes += download_bytes as u64;
        t.staleness.record(staleness);
        self.staleness.record(staleness);
    }

    /// Like [`ScenarioMetrics::record_upload`] for a mid-round partial
    /// submission (a dropped client salvaging the prefix it completed).
    pub fn record_partial_upload(
        &mut self,
        tier: usize,
        staleness: u64,
        upload_bytes: usize,
        download_bytes: usize,
    ) {
        self.record_upload(tier, staleness, upload_bytes, download_bytes);
        self.tiers[tier].partial_uploads += 1;
    }

    /// The robust server shrank one of this tier's uploads to the clip
    /// norm.
    pub fn record_clipped(&mut self, tier: usize) {
        self.tiers[tier].clipped_updates += 1;
    }

    /// The trimmed mean excluded one of this tier's uploads at a
    /// majority of its coordinates.
    pub fn record_trimmed(&mut self, tier: usize) {
        self.tiers[tier].trimmed_updates += 1;
    }

    /// Serialize every counter — the checkpoint form. Exact: counters
    /// are u64 (< 2^53 in practice) and histograms carry their parts.
    pub fn to_json(&self) -> Json {
        let tier = |t: &TierMetrics| {
            let mut fields = vec![
                ("name", Json::str(t.name.clone())),
                ("codec", Json::str(t.codec.clone())),
            ];
            if !t.download_codec.is_empty() {
                fields.push(("download_codec", Json::str(t.download_codec.clone())));
            }
            fields.extend([
                ("arrivals", Json::num(t.arrivals as f64)),
                ("unavailable", Json::num(t.unavailable as f64)),
                ("dropouts", Json::num(t.dropouts as f64)),
                ("uploads", Json::num(t.uploads as f64)),
                ("partial_uploads", Json::num(t.partial_uploads as f64)),
                ("upload_bytes", Json::num(t.upload_bytes as f64)),
                ("download_bytes", Json::num(t.download_bytes as f64)),
                (
                    "wasted_download_bytes",
                    Json::num(t.wasted_download_bytes as f64),
                ),
            ]);
            if t.codec_switches != 0 {
                fields.push(("codec_switches", Json::num(t.codec_switches as f64)));
            }
            // hostile-tier tags and robust counters: conditional so
            // honest/robust-off checkpoints keep their pre-robustness
            // byte layout
            if !t.grad_noise.is_empty() {
                fields.push(("grad_noise", Json::str(t.grad_noise.clone())));
            }
            if !t.adversary.is_empty() {
                fields.push(("adversary", Json::str(t.adversary.clone())));
            }
            if t.clipped_updates != 0 {
                fields.push(("clipped_updates", Json::num(t.clipped_updates as f64)));
            }
            if t.trimmed_updates != 0 {
                fields.push(("trimmed_updates", Json::num(t.trimmed_updates as f64)));
            }
            fields.push(("staleness", t.staleness.to_json()));
            Json::obj(fields)
        };
        Json::obj(vec![
            ("tiers", Json::arr(self.tiers.iter().map(tier).collect())),
            ("staleness", self.staleness.to_json()),
            ("arrivals_all_off", Json::num(self.arrivals_all_off as f64)),
        ])
    }

    /// Rebuild tier counters from [`ScenarioMetrics::to_json`] output.
    /// Concurrency/snapshot gauges and edge counters are *not* restored
    /// here — the engine recomputes or restores those itself.
    pub fn from_json(j: &Json) -> anyhow::Result<ScenarioMetrics> {
        use anyhow::anyhow;
        let num = |o: &Json, k: &str| -> anyhow::Result<u64> {
            o.get(k)
                .and_then(|v| v.as_f64())
                .map(|f| f as u64)
                .ok_or_else(|| anyhow!("scenario metrics: missing numeric field '{k}'"))
        };
        let text = |o: &Json, k: &str| -> anyhow::Result<String> {
            Ok(o.get(k)
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("scenario metrics: missing string field '{k}'"))?
                .to_string())
        };
        let tiers = j
            .get("tiers")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("scenario metrics: missing 'tiers' array"))?
            .iter()
            .map(|t| {
                Ok(TierMetrics {
                    name: text(t, "name")?,
                    codec: text(t, "codec")?,
                    // optional: absent on no-preset (and pre-family) runs
                    download_codec: t
                        .get("download_codec")
                        .and_then(|v| v.as_str())
                        .unwrap_or("")
                        .to_string(),
                    arrivals: num(t, "arrivals")?,
                    unavailable: num(t, "unavailable")?,
                    dropouts: num(t, "dropouts")?,
                    uploads: num(t, "uploads")?,
                    partial_uploads: num(t, "partial_uploads")?,
                    upload_bytes: num(t, "upload_bytes")?,
                    download_bytes: num(t, "download_bytes")?,
                    wasted_download_bytes: num(t, "wasted_download_bytes")?,
                    // optional: absent on adaptive-off (and pre-adaptive) runs
                    codec_switches: t
                        .get("codec_switches")
                        .and_then(|v| v.as_f64())
                        .map(|f| f as u64)
                        .unwrap_or(0),
                    // optional: absent on honest / robust-off runs
                    grad_noise: t
                        .get("grad_noise")
                        .and_then(|v| v.as_str())
                        .unwrap_or("")
                        .to_string(),
                    adversary: t
                        .get("adversary")
                        .and_then(|v| v.as_str())
                        .unwrap_or("")
                        .to_string(),
                    clipped_updates: t
                        .get("clipped_updates")
                        .and_then(|v| v.as_f64())
                        .map(|f| f as u64)
                        .unwrap_or(0),
                    trimmed_updates: t
                        .get("trimmed_updates")
                        .and_then(|v| v.as_f64())
                        .map(|f| f as u64)
                        .unwrap_or(0),
                    staleness: StalenessHist::from_json(
                        t.get("staleness")
                            .ok_or_else(|| anyhow!("scenario metrics: tier missing 'staleness'"))?,
                    )?,
                })
            })
            .collect::<anyhow::Result<Vec<TierMetrics>>>()?;
        Ok(ScenarioMetrics {
            tiers,
            staleness: StalenessHist::from_json(
                j.get("staleness")
                    .ok_or_else(|| anyhow!("scenario metrics: missing 'staleness'"))?,
            )?,
            arrivals_all_off: num(j, "arrivals_all_off")?,
            ..Default::default()
        })
    }

    /// Human-readable per-tier table (printed by `qafel run` for
    /// multi-tier scenarios).
    pub fn table(&self) -> String {
        let mut out = String::new();
        out.push_str(
            "  tier         codec        arrivals  unavail  dropped  uploads  partial  clipped  trimmed      MB-up    MB-down  MB-wasted  stale-mean  stale-max\n",
        );
        for t in &self.tiers {
            out.push_str(&format!(
                "  {:<12} {:<12} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8} {:>10.3} {:>10.3} {:>10.3} {:>11.2} {:>10}\n",
                t.name,
                t.codec,
                t.arrivals,
                t.unavailable,
                t.dropouts,
                t.uploads,
                t.partial_uploads,
                t.clipped_updates,
                t.trimmed_updates,
                t.upload_bytes as f64 / 1e6,
                t.download_bytes as f64 / 1e6,
                t.wasted_download_bytes as f64 / 1e6,
                t.staleness.mean(),
                t.staleness.max,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_powers_of_two() {
        assert_eq!(StalenessHist::bucket(0), 0);
        assert_eq!(StalenessHist::bucket(1), 1);
        assert_eq!(StalenessHist::bucket(2), 2);
        assert_eq!(StalenessHist::bucket(3), 2);
        assert_eq!(StalenessHist::bucket(4), 3);
        assert_eq!(StalenessHist::bucket(7), 3);
        assert_eq!(StalenessHist::bucket(8), 4);
        assert_eq!(StalenessHist::bucket(u64::MAX), 64);
        assert_eq!(StalenessHist::bucket_range(0), (0, 0));
        assert_eq!(StalenessHist::bucket_range(1), (1, 1));
        assert_eq!(StalenessHist::bucket_range(3), (4, 7));
        assert_eq!(StalenessHist::bucket_range(64), (1u64 << 63, u64::MAX));
    }

    #[test]
    fn histogram_records_and_summarizes() {
        let mut h = StalenessHist::default();
        for s in [0u64, 0, 1, 2, 3, 6, 6] {
            h.record(s);
        }
        assert_eq!(h.n, 7);
        assert_eq!(h.max, 6);
        assert!((h.mean() - 18.0 / 7.0).abs() < 1e-12);
        assert_eq!(h.counts, vec![2, 1, 2, 2]);
        assert_eq!(h.spec_string(), "0:2|1:1|2-3:2|4-7:2");
    }

    #[test]
    fn histogram_merge_is_exact_union() {
        let mut a = StalenessHist::default();
        let mut b = StalenessHist::default();
        let mut all = StalenessHist::default();
        for s in [0u64, 1, 5] {
            a.record(s);
            all.record(s);
        }
        for s in [2u64, 9, 9, 130] {
            b.record(s);
            all.record(s);
        }
        a.merge(&b);
        assert_eq!(a, all);
        assert_eq!(a.mean(), all.mean());
        // merging an empty histogram is a no-op
        a.merge(&StalenessHist::default());
        assert_eq!(a, all);
        // round-trips through its serialized parts
        let rebuilt = StalenessHist::from_parts(all.counts.clone(), all.sum, all.max, all.n);
        assert_eq!(rebuilt, all);
    }

    #[test]
    fn quantiles_track_the_distribution() {
        let mut h = StalenessHist::default();
        assert_eq!(h.quantile(0.5), 0, "empty histogram");
        for s in [0u64, 0, 0, 0, 0, 1, 1, 2, 3, 9] {
            h.record(s);
        }
        // buckets 0 and 1 are exact
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.quantile(0.7), 1);
        // p100 clamps to the observed max, not the bucket bound (15)
        assert_eq!(h.quantile(1.0), 9);
        assert_eq!(h.quantile(0.0), 0, "q=0 means the first value");
    }

    #[test]
    fn histogram_and_metrics_json_roundtrip() {
        let mut m = ScenarioMetrics::with_tiers(["fast".to_string(), "slow".to_string()]);
        m.tiers[0].codec = "qsgd:4".into();
        m.tiers[1].codec = "top:0.1".into();
        m.tiers[1].download_codec = "qsgd:2".into();
        m.tiers[1].codec_switches = 2;
        m.tiers[1].grad_noise = "student_t:3:0.5".into();
        m.tiers[1].adversary = "sign_flip".into();
        m.record_clipped(1);
        m.record_trimmed(1);
        m.record_arrival(0);
        m.record_upload(0, 2, 100, 50);
        m.record_dropout(1, 50);
        m.record_partial_upload(1, 7, 60, 50);
        m.record_unavailable(1);
        m.record_all_off();
        let j = m.to_json();
        let back = ScenarioMetrics::from_json(&j).unwrap();
        assert_eq!(back.tiers, m.tiers);
        assert_eq!(back.staleness, m.staleness);
        assert_eq!(back.arrivals_all_off, m.arrivals_all_off);
        // the downlink-codec key only appears when a tier has a
        // non-default downlink family (byte-identity for no-preset runs)
        let text = j.to_string();
        assert_eq!(text.matches("download_codec").count(), 1);
        // likewise codec_switches: only the rekeyed tier carries the key
        assert_eq!(text.matches("codec_switches").count(), 1);
        // hostile tags and robust counters: only the hostile tier
        // carries the keys (honest/robust-off layout is unchanged)
        assert_eq!(text.matches("grad_noise").count(), 1);
        assert_eq!(text.matches("adversary").count(), 1);
        assert_eq!(text.matches("clipped_updates").count(), 1);
        assert_eq!(text.matches("trimmed_updates").count(), 1);
        // the parse is strict about schema
        assert!(ScenarioMetrics::from_json(&Json::obj(vec![])).is_err());
        assert!(StalenessHist::from_json(&Json::obj(vec![])).is_err());
    }

    #[test]
    fn tier_recording_accumulates() {
        let mut m =
            ScenarioMetrics::with_tiers(["fast".to_string(), "slow".to_string()]);
        m.record_arrival(0);
        m.record_arrival(1);
        m.record_arrival(1);
        m.record_unavailable(1);
        m.record_upload(0, 2, 100, 50);
        m.record_upload(1, 5, 200, 50);
        m.record_dropout(1, 50);
        m.record_partial_upload(1, 1, 200, 50);
        m.record_all_off();
        assert_eq!(m.tiers[0].uploads, 1);
        assert_eq!(m.tiers[1].dropouts, 1);
        assert_eq!(m.tiers[1].arrivals, 2);
        assert_eq!(m.tiers[1].unavailable, 1);
        assert_eq!(m.tiers[0].upload_bytes, 100);
        assert_eq!(m.tiers[1].download_bytes, 150);
        // partial uploads count as uploads AND as partials
        assert_eq!(m.tiers[1].uploads, 2);
        assert_eq!(m.tiers[1].partial_uploads, 1);
        assert_eq!(m.tiers[0].partial_uploads, 0);
        // only the full dropout wasted its downlink
        assert_eq!(m.tiers[1].wasted_download_bytes, 50);
        assert_eq!(m.tiers[0].wasted_download_bytes, 0);
        assert_eq!(m.arrivals_all_off, 1);
        assert_eq!(m.staleness.n, 3);
        assert_eq!(m.staleness.max, 5);
        let table = m.table();
        assert!(table.contains("fast") && table.contains("slow"));
    }
}
