//! Hostile-population primitives: heavy-tailed gradient noise and
//! adversarial upload behaviors (`scenario.tiers.<name>.grad_noise` /
//! `.adversary`, ARCHITECTURE.md §Robust aggregation).
//!
//! Both transforms mutate the client's delta **at upload time** — after
//! local training and any client-side clipping, immediately before
//! quantization — in the simulator ([`crate::sim::SimEngine`]) and on a
//! real TCP worker (`qafel worker --adversary`). Noise draws come from
//! their own named PRNG streams ("scenario-noise" /
//! "scenario-adversary" in the simulator), so configs without these
//! knobs draw nothing and replay bit-identically to the pre-robustness
//! engine.
//!
//! The config layer validates specs through [`GradNoise::parse`] and
//! [`Adversary::parse`] — one source of truth for the grammars, so
//! config and engine can never drift apart (the `Sampling::parse`
//! idiom).

use crate::util::dist::Normal;
use crate::util::prng::Prng;
use anyhow::{anyhow, bail, Result};

/// Heavy-tailed additive gradient noise
/// (`"student_t:<dof>:<scale>"` | `"pareto:<alpha>:<scale>"`).
///
/// Models the unbounded-gradient regime of Toghani & Uribe (PAPERS.md):
/// every coordinate of the delta gets an independent heavy-tailed draw
/// added to it. Student-t with small `dof` has polynomial tails (no
/// variance for `dof <= 2`); the symmetric Pareto (Lomax magnitude with
/// a random sign) has tail index `alpha` (no mean for `alpha <= 1`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GradNoise {
    /// Scaled Student-t: `scale * t(dof)` per coordinate.
    StudentT { dof: f64, scale: f64 },
    /// Symmetric Pareto (Lomax): `±scale * (U^{-1/alpha} - 1)`.
    Pareto { alpha: f64, scale: f64 },
}

impl GradNoise {
    pub fn parse(s: &str) -> Result<GradNoise> {
        let parts: Vec<&str> = s.split(':').collect();
        let num = |p: &str, what: &str| -> Result<f64> {
            let v: f64 = p
                .parse()
                .map_err(|_| anyhow!("grad_noise '{s}': {what} '{p}' is not a number"))?;
            if !(v.is_finite() && v > 0.0) {
                bail!("grad_noise '{s}': {what} must be > 0, got {p}");
            }
            Ok(v)
        };
        Ok(match parts.as_slice() {
            ["student_t", dof, scale] => GradNoise::StudentT {
                dof: num(dof, "dof")?,
                scale: num(scale, "scale")?,
            },
            ["pareto", alpha, scale] => GradNoise::Pareto {
                alpha: num(alpha, "alpha")?,
                scale: num(scale, "scale")?,
            },
            _ => bail!(
                "unknown grad_noise spec '{s}' \
                 (student_t:<dof>:<scale> | pareto:<alpha>:<scale>)"
            ),
        })
    }

    /// Canonical spec string (round-trips through [`GradNoise::parse`]).
    pub fn name(&self) -> String {
        match self {
            GradNoise::StudentT { dof, scale } => format!("student_t:{dof}:{scale}"),
            GradNoise::Pareto { alpha, scale } => format!("pareto:{alpha}:{scale}"),
        }
    }

    /// Add one heavy-tailed draw to every coordinate of `delta`.
    pub fn apply(&self, delta: &mut [f32], rng: &mut Prng) {
        match *self {
            GradNoise::StudentT { dof, scale } => {
                for x in delta.iter_mut() {
                    *x += (scale * sample_student_t(dof, rng)) as f32;
                }
            }
            GradNoise::Pareto { alpha, scale } => {
                for x in delta.iter_mut() {
                    // Lomax magnitude: U in (0, 1] avoids the pole at 0.
                    let u = 1.0 - rng.f64();
                    let mag = scale * (u.powf(-1.0 / alpha) - 1.0);
                    *x += if rng.bool(0.5) { -mag } else { mag } as f32;
                }
            }
        }
    }
}

/// One Student-t(dof) sample via Bailey's polar method (exact for any
/// dof > 0, no chi-square intermediate): accept (u, v) uniform in the
/// unit disc, return `u * sqrt(dof * (w^{-2/dof} - 1) / w)`.
fn sample_student_t(dof: f64, rng: &mut Prng) -> f64 {
    loop {
        let u = 2.0 * rng.f64() - 1.0;
        let v = 2.0 * rng.f64() - 1.0;
        let w = u * u + v * v;
        if w > 0.0 && w < 1.0 {
            return u * (dof * (w.powf(-2.0 / dof) - 1.0) / w).sqrt();
        }
    }
}

/// Adversarial upload behavior
/// (`"sign_flip"` | `"scale:<c>"` | `"stale_replay"`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Adversary {
    /// Upload `-delta`: honest magnitude (norm clipping is blind to it),
    /// maximally wrong direction — the case that forces the
    /// coordinate-wise trimmed mean.
    SignFlip,
    /// Scaled garbage: replace the delta with iid `N(0, c^2)` draws —
    /// the classic Gaussian-noise Byzantine attack; caught by norm
    /// bounding when `c` is large.
    ScaledGarbage(f64),
    /// Replay the client's *first* honest delta forever: the first
    /// upload passes through (and is cached); every later upload sends
    /// that same stale update again. Draws nothing.
    StaleReplay,
}

impl Adversary {
    pub fn parse(s: &str) -> Result<Adversary> {
        if let Some(c) = s.strip_prefix("scale:") {
            let c: f64 = c
                .parse()
                .map_err(|_| anyhow!("adversary '{s}': scale '{c}' is not a number"))?;
            if !(c.is_finite() && c > 0.0) {
                bail!("adversary '{s}': scale must be > 0");
            }
            return Ok(Adversary::ScaledGarbage(c));
        }
        Ok(match s {
            "sign_flip" | "sign-flip" => Adversary::SignFlip,
            "stale_replay" | "stale-replay" => Adversary::StaleReplay,
            other => bail!(
                "unknown adversary '{other}' (sign_flip | scale:<c> | stale_replay)"
            ),
        })
    }

    /// Canonical spec string (round-trips through [`Adversary::parse`]).
    pub fn name(&self) -> String {
        match self {
            Adversary::SignFlip => "sign_flip".into(),
            Adversary::ScaledGarbage(c) => format!("scale:{c}"),
            Adversary::StaleReplay => "stale_replay".into(),
        }
    }

    /// Apply the behavior to the outgoing delta. `cache` is the replay
    /// slot for [`Adversary::StaleReplay`] (per tier in the simulator,
    /// per worker on TCP); the other behaviors never touch it. Only
    /// [`Adversary::ScaledGarbage`] draws from `rng`.
    pub fn apply(&self, delta: &mut [f32], cache: &mut Option<Vec<f32>>, rng: &mut Prng) {
        match *self {
            Adversary::SignFlip => {
                for x in delta.iter_mut() {
                    *x = -*x;
                }
            }
            Adversary::ScaledGarbage(c) => {
                let mut normal = Normal::new();
                for x in delta.iter_mut() {
                    *x = (c * normal.sample(rng)) as f32;
                }
            }
            Adversary::StaleReplay => match cache {
                Some(old) if old.len() == delta.len() => delta.copy_from_slice(old),
                _ => *cache = Some(delta.to_vec()),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_noise_parse_round_trips_and_rejects() {
        let g = GradNoise::parse("student_t:3:0.5").unwrap();
        assert_eq!(g, GradNoise::StudentT { dof: 3.0, scale: 0.5 });
        assert_eq!(GradNoise::parse(&g.name()).unwrap(), g);
        let p = GradNoise::parse("pareto:1.5:0.1").unwrap();
        assert_eq!(p, GradNoise::Pareto { alpha: 1.5, scale: 0.1 });
        assert_eq!(GradNoise::parse(&p.name()).unwrap(), p);
        for bad in [
            "cauchy:1", "student_t:3", "student_t:0:1", "student_t:-2:1",
            "student_t:3:0", "pareto:2:-1", "pareto:x:1", "pareto:2:0.1:9", "",
        ] {
            assert!(GradNoise::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn adversary_parse_round_trips_and_rejects() {
        assert_eq!(Adversary::parse("sign_flip").unwrap(), Adversary::SignFlip);
        assert_eq!(Adversary::parse("sign-flip").unwrap(), Adversary::SignFlip);
        assert_eq!(Adversary::parse("scale:10").unwrap(), Adversary::ScaledGarbage(10.0));
        assert_eq!(Adversary::parse("stale_replay").unwrap(), Adversary::StaleReplay);
        for a in ["sign_flip", "scale:2.5", "stale_replay"] {
            let parsed = Adversary::parse(a).unwrap();
            assert_eq!(Adversary::parse(&parsed.name()).unwrap(), parsed);
        }
        for bad in ["byzantine", "scale:0", "scale:-2", "scale:x", "scale:", ""] {
            assert!(Adversary::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn student_t_moments_and_tails() {
        // dof = 30 is close to N(0,1): mean ~ 0, var ~ dof/(dof-2).
        let mut rng = Prng::new(11);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = sample_student_t(30.0, &mut rng);
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 30.0 / 28.0).abs() < 0.05, "var {var}");
        // dof = 2 has heavier tails than any normal: big excursions
        let mut big = 0usize;
        for _ in 0..n {
            if sample_student_t(2.0, &mut rng).abs() > 6.0 {
                big += 1;
            }
        }
        // P(|t_2| > 6) ~ 2.6%; P(|N(0,1)| > 6) ~ 2e-9
        assert!(big > n / 200, "only {big} of {n} beyond 6 sigma");
    }

    #[test]
    fn noise_apply_perturbs_every_coordinate() {
        let mut rng = Prng::new(3);
        let mut delta = vec![1.0f32; 64];
        GradNoise::parse("student_t:3:0.5").unwrap().apply(&mut delta, &mut rng);
        assert!(delta.iter().filter(|&&x| x != 1.0).count() > 60);
        let mut delta = vec![0.0f32; 64];
        GradNoise::parse("pareto:2:0.1").unwrap().apply(&mut delta, &mut rng);
        assert!(delta.iter().filter(|&&x| x != 0.0).count() > 60);
        // pareto noise is two-sided
        assert!(delta.iter().any(|&x| x > 0.0) && delta.iter().any(|&x| x < 0.0));
    }

    #[test]
    fn sign_flip_negates_and_draws_nothing() {
        let mut rng = Prng::new(5);
        let before = rng.clone().next_u64();
        let mut delta = vec![1.0f32, -2.0, 0.5];
        let mut cache = None;
        Adversary::SignFlip.apply(&mut delta, &mut cache, &mut rng);
        assert_eq!(delta, vec![-1.0, 2.0, -0.5]);
        assert!(cache.is_none());
        assert_eq!(rng.next_u64(), before, "sign_flip must not draw");
    }

    #[test]
    fn scaled_garbage_replaces_with_noise_of_the_right_scale() {
        let mut rng = Prng::new(6);
        let mut delta = vec![0.001f32; 4096];
        let mut cache = None;
        Adversary::ScaledGarbage(10.0).apply(&mut delta, &mut cache, &mut rng);
        let var: f64 =
            delta.iter().map(|&x| f64::from(x) * f64::from(x)).sum::<f64>() / 4096.0;
        assert!((var - 100.0).abs() < 10.0, "sample var {var}");
    }

    #[test]
    fn stale_replay_caches_first_and_replays_forever() {
        let mut rng = Prng::new(7);
        let before = rng.clone().next_u64();
        let mut cache = None;
        let mut first = vec![1.0f32, 2.0];
        Adversary::StaleReplay.apply(&mut first, &mut cache, &mut rng);
        // first upload is honest and cached
        assert_eq!(first, vec![1.0, 2.0]);
        assert_eq!(cache.as_deref(), Some(&[1.0f32, 2.0][..]));
        // later uploads replay the cached delta
        let mut second = vec![9.0f32, 9.0];
        Adversary::StaleReplay.apply(&mut second, &mut cache, &mut rng);
        assert_eq!(second, vec![1.0, 2.0]);
        assert_eq!(rng.next_u64(), before, "stale_replay must not draw");
    }
}
