//! Versioned hidden-state snapshot store keyed by server step `t`.
//!
//! The paper's client (Algorithm 2 line 1) copies the hidden state at the
//! *start* of local training. In the virtual-time simulator every client
//! arriving between two server steps sees the **same** hidden state, so
//! there is no reason for each in-flight client to carry its own handle:
//! the store keeps exactly one `Arc<Vec<f32>>` per *distinct* published
//! model version that still has a reader, and in-flight clients carry
//! only the `u64` version key.
//!
//! Memory math: with `C` in-flight clients whose staleness spans `V`
//! server steps, the store holds `V + 1 <= staleness_max + 2` vectors of
//! `d` floats — O(V·d), not O(C·d). `V` is bounded by the staleness the
//! algorithm itself tolerates (a handful of steps at the paper's
//! operating points), so concurrency in the 10⁵–10⁶ range costs 10⁵–10⁶
//! *event records* (a few dozen bytes each) plus a handful of model
//! vectors — which is what makes million-client arrival streams feasible.
//!
//! Versions are reference-counted explicitly (not via `Arc` strong
//! counts) so eviction is deterministic and observable: a version is
//! dropped the moment its last reader releases it, unless it is still
//! the current version (the next arrival may acquire it).

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

struct Entry {
    snap: Arc<Vec<f32>>,
    refs: usize,
}

/// The store. One per simulation run.
pub struct SnapshotStore {
    versions: BTreeMap<u64, Entry>,
    current: u64,
    max_live: usize,
}

impl SnapshotStore {
    /// Start the store at version `t0` (server step 0) with the initial
    /// hidden state.
    pub fn new(t0: u64, snap: Arc<Vec<f32>>) -> SnapshotStore {
        let mut versions = BTreeMap::new();
        versions.insert(t0, Entry { snap, refs: 0 });
        SnapshotStore { versions, current: t0, max_live: 1 }
    }

    /// Publish the hidden state after a server step. The previous
    /// current version is evicted immediately if no in-flight client
    /// holds it.
    pub fn publish(&mut self, t: u64, snap: Arc<Vec<f32>>) {
        debug_assert!(t > self.current, "snapshot versions must advance");
        if let Some(prev) = self.versions.get(&self.current) {
            if prev.refs == 0 {
                self.versions.remove(&self.current);
            }
        }
        self.current = t;
        self.versions.insert(t, Entry { snap, refs: 0 });
        self.max_live = self.max_live.max(self.versions.len());
    }

    /// A client starts training now: record a reference to the current
    /// version and return its key (the client's `t_start`).
    pub fn acquire(&mut self) -> u64 {
        let e = self
            .versions
            .get_mut(&self.current)
            .expect("current snapshot version is always live");
        e.refs += 1;
        self.current
    }

    /// The model vector for a version previously acquired.
    pub fn get(&self, t: u64) -> Result<&Arc<Vec<f32>>> {
        self.versions
            .get(&t)
            .map(|e| &e.snap)
            .ok_or_else(|| anyhow!("snapshot store: version {t} is not live"))
    }

    /// A client finished (or dropped): release its version, evicting it
    /// if it was the last reader of a superseded version.
    pub fn release(&mut self, t: u64) {
        let evict = match self.versions.get_mut(&t) {
            Some(e) => {
                debug_assert!(e.refs > 0, "release without acquire for version {t}");
                e.refs = e.refs.saturating_sub(1);
                e.refs == 0 && t != self.current
            }
            None => {
                debug_assert!(false, "release of unknown version {t}");
                false
            }
        };
        if evict {
            self.versions.remove(&t);
        }
    }

    /// Dump the live versions for a checkpoint: `(current, max_live,
    /// versions)` where each version carries its key, reader count and
    /// model bits. Exact inverse of [`SnapshotStore::from_parts`].
    pub fn parts(&self) -> (u64, usize, Vec<(u64, usize, Arc<Vec<f32>>)>) {
        (
            self.current,
            self.max_live,
            self.versions.iter().map(|(&t, e)| (t, e.refs, e.snap.clone())).collect(),
        )
    }

    /// Rebuild a store from a [`SnapshotStore::parts`] dump (the resume
    /// path). The current version must be among the dumped versions.
    pub fn from_parts(
        current: u64,
        max_live: usize,
        versions: Vec<(u64, usize, Vec<f32>)>,
    ) -> Result<SnapshotStore> {
        let mut map = BTreeMap::new();
        for (t, refs, snap) in versions {
            map.insert(t, Entry { snap: Arc::new(snap), refs });
        }
        if !map.contains_key(&current) {
            return Err(anyhow!("snapshot store: current version {current} not in dump"));
        }
        let max_live = max_live.max(map.len());
        Ok(SnapshotStore { versions: map, current, max_live })
    }

    /// Number of model versions currently held.
    pub fn live_versions(&self) -> usize {
        self.versions.len()
    }

    /// Peak number of simultaneously live versions over the store's
    /// lifetime.
    pub fn max_live(&self) -> usize {
        self.max_live
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(v: f32) -> Arc<Vec<f32>> {
        Arc::new(vec![v; 4])
    }

    #[test]
    fn acquire_get_release_roundtrip() {
        let mut s = SnapshotStore::new(0, snap(0.0));
        let t = s.acquire();
        assert_eq!(t, 0);
        assert_eq!(s.get(t).unwrap()[0], 0.0);
        s.release(t);
        // current version is never evicted, even at zero refs
        assert_eq!(s.live_versions(), 1);
        assert!(s.get(0).is_ok());
    }

    #[test]
    fn superseded_version_evicted_on_last_release() {
        let mut s = SnapshotStore::new(0, snap(0.0));
        let a = s.acquire();
        let b = s.acquire();
        s.publish(1, snap(1.0));
        assert_eq!(s.live_versions(), 2);
        s.release(a);
        assert_eq!(s.live_versions(), 2, "still one reader on v0");
        s.release(b);
        assert_eq!(s.live_versions(), 1, "v0 evicted with its last reader");
        assert!(s.get(0).is_err());
        assert_eq!(s.get(1).unwrap()[0], 1.0);
    }

    #[test]
    fn unread_versions_evicted_at_publish() {
        let mut s = SnapshotStore::new(0, snap(0.0));
        for t in 1..=100u64 {
            s.publish(t, snap(t as f32));
            assert_eq!(s.live_versions(), 1, "no readers => one live version");
        }
        assert_eq!(s.max_live(), 1);
    }

    #[test]
    fn parts_roundtrip_preserves_refs_and_current() {
        let mut s = SnapshotStore::new(0, snap(0.0));
        let a = s.acquire();
        s.publish(1, snap(1.0));
        let b = s.acquire();
        let (cur, max_live, parts) = s.parts();
        assert_eq!(cur, 1);
        let dump: Vec<(u64, usize, Vec<f32>)> =
            parts.iter().map(|(t, r, v)| (*t, *r, v.as_ref().clone())).collect();
        let mut back = SnapshotStore::from_parts(cur, max_live, dump).unwrap();
        assert_eq!(back.live_versions(), 2);
        assert_eq!(back.max_live(), 2);
        assert_eq!(back.get(a).unwrap()[0], 0.0);
        // restored refcounts behave: releasing v0's only reader evicts it
        back.release(a);
        assert_eq!(back.live_versions(), 1);
        back.release(b);
        assert!(back.get(1).is_ok());
        // the current version must be in the dump
        assert!(SnapshotStore::from_parts(5, 1, vec![(0, 0, vec![0.0])]).is_err());
    }

    #[test]
    fn live_versions_track_reader_span_not_reader_count() {
        // 10_000 "clients" acquire across 3 versions: memory is 3
        // versions, not 10_000 snapshots.
        let mut s = SnapshotStore::new(0, snap(0.0));
        let mut held = Vec::new();
        for step in 0..3u64 {
            for _ in 0..10_000 {
                held.push(s.acquire());
            }
            s.publish(step + 1, snap(step as f32 + 1.0));
        }
        assert_eq!(s.live_versions(), 4);
        assert_eq!(s.max_live(), 4);
        for t in held {
            s.release(t);
        }
        assert_eq!(s.live_versions(), 1);
    }
}
