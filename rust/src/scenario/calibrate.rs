//! Trace-driven tier calibration (`qafel scenario calibrate`).
//!
//! Fits a `[scenario]` tier table from a client-trace CSV of observed
//! sessions — the FedScale-style workflow: export `(tier label, session
//! duration)` rows from production logs, fit weights and duration
//! distributions here, and drop the emitted TOML into an experiment
//! config.
//!
//! ## Trace format
//!
//! A CSV with a header row naming at least `tier` and `duration`
//! (any column order; extra columns are ignored):
//!
//! ```csv
//! tier,duration
//! phone,2.31
//! phone,1.07
//! tablet,0.52
//! ```
//!
//! One row per observed client session; `duration` is the session's
//! training time in the trace's (consistent) time unit and must be a
//! positive finite number.
//!
//! ## Fitting
//!
//! * **weight** — the tier's share of sessions, `n_i / n` (relative
//!   weights are all the scenario engine uses).
//! * **duration / duration_sigma** — method of moments within each of
//!   the engine's one-parameter families, then the family whose implied
//!   coefficient of variation (std/mean) is closest to the empirical
//!   one:
//!   * `fixed`: `sigma = mean`, CV 0;
//!   * `halfnormal`: `E = sigma * sqrt(2/pi)` so `sigma = mean *
//!     sqrt(pi/2)`, CV `sqrt(pi/2 - 1)` (~0.756);
//!   * `lognormal(0, s)`: `E = exp(s^2/2)` so `s = sqrt(2 ln mean)`
//!     (only admissible when `mean > 1`), CV `sqrt(mean^2 - 1)`.
//!
//! The output is a ready-to-paste TOML snippet (validated to
//! round-trip through [`crate::config::Config`] in this module's
//! tests); bandwidths, dropout and diurnal windows are not observable
//! from a duration trace and keep their defaults.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// One fitted tier.
#[derive(Clone, Debug, PartialEq)]
pub struct FittedTier {
    pub name: String,
    /// Share of trace sessions, in (0, 1].
    pub weight: f64,
    /// Chosen duration family: "fixed" | "halfnormal" | "lognormal".
    pub duration: String,
    /// The family's sigma parameter, fitted to the tier's mean.
    pub duration_sigma: f64,
    /// Empirical session-duration mean.
    pub mean: f64,
    /// Empirical coefficient of variation (std/mean).
    pub cv: f64,
    /// Number of trace sessions.
    pub n: usize,
}

/// Parse a trace CSV and fit one tier per distinct label, sorted by
/// name (the scenario engine's tier order is alphabetical, matching the
/// TOML table order).
pub fn fit_trace(text: &str) -> Result<Vec<FittedTier>> {
    let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());
    let (_, header) = lines.next().context("trace is empty (need a header row)")?;
    let cols: Vec<&str> = header.split(',').map(|c| c.trim()).collect();
    let tier_col = cols
        .iter()
        .position(|c| *c == "tier")
        .context("trace header has no 'tier' column")?;
    let dur_col = cols
        .iter()
        .position(|c| *c == "duration")
        .context("trace header has no 'duration' column")?;

    let mut groups: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for (lineno, line) in lines {
        let fields: Vec<&str> = line.split(',').map(|c| c.trim()).collect();
        if fields.len() != cols.len() {
            bail!(
                "trace line {}: {} fields, header has {}",
                lineno + 1,
                fields.len(),
                cols.len()
            );
        }
        let tier = fields[tier_col];
        if tier.is_empty() {
            bail!("trace line {}: empty tier label", lineno + 1);
        }
        if !tier.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-') {
            bail!(
                "trace line {}: tier label '{tier}' is not a TOML bare key \
                 (use [A-Za-z0-9_-])",
                lineno + 1
            );
        }
        let dur: f64 = fields[dur_col].parse().with_context(|| {
            format!("trace line {}: bad duration '{}'", lineno + 1, fields[dur_col])
        })?;
        if !(dur.is_finite() && dur > 0.0) {
            bail!("trace line {}: duration must be positive and finite, got {dur}", lineno + 1);
        }
        groups.entry(tier.to_string()).or_default().push(dur);
    }
    if groups.is_empty() {
        bail!("trace has a header but no data rows");
    }

    let total: usize = groups.values().map(|v| v.len()).sum();
    let mut out = Vec::with_capacity(groups.len());
    for (name, durs) in groups {
        out.push(fit_tier(name, &durs, total));
    }
    Ok(out)
}

/// Fit one tier from its observed durations.
fn fit_tier(name: String, durs: &[f64], total: usize) -> FittedTier {
    let n = durs.len();
    let mean = durs.iter().sum::<f64>() / n as f64;
    let var = durs.iter().map(|&d| (d - mean) * (d - mean)).sum::<f64>() / n as f64;
    let cv = var.sqrt() / mean;

    // candidates: (family, sigma, implied CV)
    let mut candidates = vec![
        ("fixed", mean, 0.0),
        (
            "halfnormal",
            mean * (std::f64::consts::PI / 2.0).sqrt(),
            (std::f64::consts::PI / 2.0 - 1.0).sqrt(),
        ),
    ];
    if mean > 1.0 {
        // lognormal(0, s): E = exp(s^2/2) => s = sqrt(2 ln mean)
        let s = (2.0 * mean.ln()).sqrt();
        let implied_cv = (mean * mean - 1.0).sqrt();
        candidates.push(("lognormal", s, implied_cv));
    }
    let (family, sigma, _) = candidates
        .into_iter()
        .min_by(|a, b| (a.2 - cv).abs().total_cmp(&(b.2 - cv).abs()))
        .expect("candidate list is never empty");

    FittedTier {
        name,
        weight: n as f64 / total as f64,
        duration: family.to_string(),
        duration_sigma: sigma,
        mean,
        cv,
        n,
    }
}

/// Render fitted tiers as a `[scenario]` TOML snippet, with the
/// empirical statistics as comments.
pub fn to_toml(tiers: &[FittedTier]) -> String {
    let mut out = String::new();
    out.push_str("# fitted by `qafel scenario calibrate` from an observed client trace\n");
    for t in tiers {
        out.push_str(&format!(
            "\n[scenario.tiers.{}]\n# {} sessions, mean duration {:.4}, cv {:.3}\nweight = {:.6}\nduration = \"{}\"\nduration_sigma = {:.6}\n",
            t.name, t.n, t.mean, t.cv, t.weight, t.duration, t.duration_sigma
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::config::toml;
    use crate::util::dist::{DurationDist, HalfNormal, LogNormal};
    use crate::util::prng::Prng;

    fn trace_from(dists: &[(&str, DurationDist, usize)], seed: u64) -> String {
        let mut rng = Prng::new(seed);
        let mut out = String::from("tier,duration\n");
        for (name, dist, n) in dists {
            let mut d = dist.clone();
            for _ in 0..*n {
                out.push_str(&format!("{name},{}\n", d.sample(&mut rng).max(1e-6)));
            }
        }
        out
    }

    #[test]
    fn recovers_weights_and_families_from_synthetic_traces() {
        let text = trace_from(
            &[
                ("phone", DurationDist::LogNormal(LogNormal::new(0.0, 1.0)), 7500),
                ("tablet", DurationDist::HalfNormal(HalfNormal::new(2.0)), 2000),
                ("kiosk", DurationDist::Fixed(3.0), 500),
            ],
            1,
        );
        let fitted = fit_trace(&text).unwrap();
        assert_eq!(fitted.len(), 3);
        // BTreeMap order: alphabetical
        let kiosk = &fitted[0];
        assert_eq!(kiosk.name, "kiosk");
        assert_eq!(kiosk.duration, "fixed");
        assert!((kiosk.duration_sigma - 3.0).abs() < 1e-9, "{kiosk:?}");
        assert!((kiosk.weight - 0.05).abs() < 1e-9);
        let phone = &fitted[1];
        assert_eq!(phone.name, "phone");
        assert_eq!(phone.duration, "lognormal", "{phone:?}");
        // E[lognormal(0,1)] = e^0.5 ~ 1.6487 => s ~ 1
        assert!((phone.duration_sigma - 1.0).abs() < 0.1, "{phone:?}");
        assert!((phone.weight - 0.75).abs() < 1e-9);
        let tablet = &fitted[2];
        assert_eq!(tablet.duration, "halfnormal", "{tablet:?}");
        // E[halfnormal(2)] = 2*sqrt(2/pi) ~ 1.596 => sigma ~ 2
        assert!((tablet.duration_sigma - 2.0).abs() < 0.15, "{tablet:?}");
    }

    #[test]
    fn emitted_toml_round_trips_through_config() {
        let text = trace_from(
            &[
                ("fast", DurationDist::Fixed(0.5), 100),
                ("slow", DurationDist::HalfNormal(HalfNormal::new(2.0)), 300),
            ],
            2,
        );
        let fitted = fit_trace(&text).unwrap();
        let snippet = to_toml(&fitted);
        let doc = toml::parse(&snippet).unwrap();
        let mut cfg = Config::default();
        cfg.apply(&doc).unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.scenario.tiers.len(), 2);
        assert_eq!(cfg.scenario.tiers[0].name, "fast");
        assert!((cfg.scenario.tiers[0].weight - 0.25).abs() < 1e-6);
        assert!((cfg.scenario.tiers[1].weight - 0.75).abs() < 1e-6);
    }

    #[test]
    fn extra_columns_and_orders_are_tolerated() {
        let text = "client_id,duration,tier\n1,2.0,a\n2,3.0,b\n3,4.0,a\n";
        let fitted = fit_trace(text).unwrap();
        assert_eq!(fitted.len(), 2);
        assert_eq!(fitted[0].name, "a");
        assert_eq!(fitted[0].n, 2);
        assert!((fitted[0].mean - 3.0).abs() < 1e-12);
    }

    #[test]
    fn malformed_traces_fail_loudly() {
        assert!(fit_trace("").is_err());
        assert!(fit_trace("tier,duration\n").is_err(), "no data rows");
        assert!(fit_trace("duration\n1.0\n").is_err(), "no tier column");
        assert!(fit_trace("tier\nphone\n").is_err(), "no duration column");
        assert!(fit_trace("tier,duration\nphone\n").is_err(), "ragged row");
        assert!(fit_trace("tier,duration\nphone,zero\n").is_err(), "non-numeric");
        assert!(fit_trace("tier,duration\nphone,-1.0\n").is_err(), "negative");
        assert!(fit_trace("tier,duration\n,1.0\n").is_err(), "empty label");
    }
}
