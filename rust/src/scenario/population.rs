//! The resolved client population: a weighted mix of device tiers, each
//! with its own duration distribution, link bandwidths, dropout
//! probability and diurnal availability window.

use crate::config::{Config, TierConfig};
use crate::scenario::metrics::ScenarioMetrics;
use crate::scenario::robust::{Adversary, GradNoise};
use crate::util::dist::{DurationDist, HalfNormal, LogNormal};
use crate::util::prng::Prng;
use anyhow::{bail, Result};

use super::arrival::{build_arrival, ArrivalProcess};

/// Build a duration distribution from its config spec (the same mapping
/// the pre-scenario engine used for `sim.duration`).
pub fn duration_dist(kind: &str, sigma: f64) -> Result<DurationDist> {
    Ok(match kind {
        "halfnormal" => DurationDist::HalfNormal(HalfNormal::new(sigma)),
        "lognormal" => DurationDist::LogNormal(LogNormal::new(0.0, sigma)),
        "fixed" => DurationDist::Fixed(sigma),
        other => bail!("unknown duration dist '{other}'"),
    })
}

/// One device tier at runtime: its config plus a stateful sampler (the
/// half-normal keeps a Box–Muller spare, so the sampler must persist
/// across draws exactly like the pre-scenario engine's single
/// `DurationDist`).
pub struct Tier {
    pub cfg: TierConfig,
    dist: DurationDist,
    /// Parsed `grad_noise` spec (parsed once at build — the hot loop
    /// never re-parses strings).
    grad_noise: Option<GradNoise>,
    /// Parsed `adversary` spec.
    adversary: Option<Adversary>,
}

/// How arriving clients are matched to tiers (`scenario.sampling`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sampling {
    /// Draw by weight alone; an arrival landing in a tier's off window
    /// is discarded. The pre-v2 behavior — bit-identical default.
    Weighted,
    /// Draw proportional to `weight x 1[tier available at the clock]`:
    /// diurnal windows shape *who* arrives. An arrival is lost only
    /// when every tier is off.
    Availability,
}

impl Sampling {
    pub fn parse(s: &str) -> Result<Sampling> {
        Ok(match s {
            "weighted" => Sampling::Weighted,
            "availability" => Sampling::Availability,
            other => bail!("unknown scenario.sampling '{other}' (weighted | availability)"),
        })
    }
}

/// The resolved scenario: tier mix, calibrated arrival rate, and the
/// run's scenario metrics.
pub struct Scenario {
    tiers: Vec<Tier>,
    /// Cumulative tier weights for mixture sampling.
    cum: Vec<f64>,
    total_weight: f64,
    /// Target expected in-flight clients (`sim.concurrency`).
    concurrency: usize,
    /// Long-run client arrivals per unit virtual time. Calibrated via
    /// Little's law as `concurrency / (availability-weighted expected
    /// residency of the tier mix)` — from the *configured* duration
    /// distributions, not a hard-coded half-normal (the pre-scenario
    /// engine miscalibrated lognormal/fixed durations), compensating
    /// for arrivals lost to diurnal off-windows, and (after
    /// [`Scenario::recalibrate`]) for per-tier transfer delays.
    rate: f64,
    arrival_kind: String,
    burst: (f64, f64, f64),
    sampling: Sampling,
    /// `fl.local_steps` (P): the granularity of partial-work fractions.
    /// Partial submissions need P >= 2 — a 1-step round has no mid-round
    /// prefix to submit.
    local_steps: usize,
    pub metrics: ScenarioMetrics,
}

/// Wire-transfer delay in virtual time; 0 Mbps = unlimited (no delay).
fn bytes_delay(bytes: usize, mbps: f64) -> f64 {
    if mbps > 0.0 {
        bytes as f64 * 8.0 / (mbps * 1e6)
    } else {
        0.0
    }
}

impl Scenario {
    /// Resolve `cfg` into a runnable scenario. `cfg.scenario.tiers`
    /// when present; otherwise the `sim.*` knobs desugared to a single
    /// always-available unlimited-bandwidth tier (bit-identical to the
    /// pre-scenario engine).
    pub fn build(cfg: &Config) -> Result<Scenario> {
        let tier_cfgs = cfg.resolved_tiers();
        let mut tiers = Vec::with_capacity(tier_cfgs.len());
        for tc in tier_cfgs {
            tiers.push(Tier {
                dist: duration_dist(&tc.duration, tc.duration_sigma)?,
                grad_noise: tc.grad_noise.as_deref().map(GradNoise::parse).transpose()?,
                adversary: tc.adversary.as_deref().map(Adversary::parse).transpose()?,
                cfg: tc,
            });
        }
        let mut cum = Vec::with_capacity(tiers.len());
        let mut total_weight = 0.0;
        for t in &tiers {
            if !(t.cfg.weight.is_finite() && t.cfg.weight > 0.0) {
                bail!("scenario tier '{}': weight must be positive", t.cfg.name);
            }
            total_weight += t.cfg.weight;
            cum.push(total_weight);
        }
        let metrics =
            ScenarioMetrics::with_tiers(tiers.iter().map(|t| t.cfg.name.clone()));
        let mut scenario = Scenario {
            cum,
            total_weight,
            concurrency: cfg.sim.concurrency,
            rate: 0.0,
            arrival_kind: cfg.resolved_arrival().to_string(),
            burst: (
                cfg.scenario.burst_factor,
                cfg.scenario.burst_on,
                cfg.scenario.burst_off,
            ),
            sampling: Sampling::parse(&cfg.scenario.sampling)?,
            local_steps: cfg.fl.local_steps,
            metrics,
            tiers,
        };
        // Provisional calibration with zero wire sizes; the engine calls
        // `recalibrate` once the codec byte sizes (which depend on the
        // model dimension) are known.
        scenario.recalibrate(0, 0);
        if !(scenario.rate.is_finite() && scenario.rate > 0.0) {
            bail!(
                "scenario: availability-weighted mean residency must be positive \
                 (arrival rate came out as {})",
                scenario.rate
            );
        }
        Ok(scenario)
    }

    /// (Re)calibrate the arrival rate from Little's law with one upload
    /// and one download wire size shared by every tier (no per-tier
    /// codec presets): shorthand for [`Scenario::recalibrate_per_tier`]
    /// with uniform byte vectors.
    pub fn recalibrate(&mut self, upload_bytes: usize, download_bytes: usize) {
        let up = vec![upload_bytes; self.tiers.len()];
        let down = vec![download_bytes; self.tiers.len()];
        self.recalibrate_per_tier(&up, &down);
    }

    /// (Re)calibrate the arrival rate from Little's law:
    ///
    /// ```text
    /// concurrency = rate * sum_i (w_i/W) * a_i * R_i
    /// R_i = E[D_i]*df_i + download_delay_i + uf_i * upload_delay_i
    /// df_i = 1 - dropout_i * q_i / 2          (partial droppers stop early)
    /// uf_i = 1 - dropout_i * (1 - q_i)        (partial droppers still upload)
    /// ```
    ///
    /// where `a_i` is tier i's long-run availability (arrivals land
    /// uniformly over the diurnal cycle, so `a_i = on_fraction`), `R_i`
    /// is the expected in-flight **residency** of a started client —
    /// training plus its deterministic transfer time on that tier's own
    /// upload and download codecs (`upload_bytes[i]`,
    /// `download_bytes[i]`; per-tier `quant_server` presets shrink a
    /// tier's broadcast payload) — and `q_i` is the tier's
    /// effective `partial_work`: a mid-round dropper trains a uniform
    /// `m/P` prefix (mean exactly 1/2) and pays the upload delay, while
    /// a full dropper trains the whole round and never uploads. Without
    /// this weighting, a sleeping tier would undershoot the target
    /// concurrency by its off fraction and a bandwidth-limited tier
    /// would overshoot it by its transfer time — by different factors
    /// per algorithm (payload sizes differ), confounding
    /// cross-algorithm comparisons.
    ///
    /// Under [`Sampling::Availability`] the per-arrival tier shares are
    /// clock-dependent (`w_i x 1[on]` renormalized), so the expected
    /// residency per arrival is averaged numerically over the diurnal
    /// cycle instead of closed-form.
    pub fn recalibrate_per_tier(&mut self, upload_bytes: &[usize], download_bytes: &[usize]) {
        assert_eq!(upload_bytes.len(), self.tiers.len(), "one upload size per tier");
        assert_eq!(download_bytes.len(), self.tiers.len(), "one download size per tier");
        let residency: Vec<f64> = self
            .tiers
            .iter()
            .zip(upload_bytes.iter().zip(download_bytes))
            .map(|(t, (&up, &down))| {
                let c = &t.cfg;
                let q = if self.local_steps >= 2 { c.partial_work } else { 0.0 };
                let df = 1.0 - c.dropout * q * 0.5;
                let uf = 1.0 - c.dropout * (1.0 - q);
                t.dist.mean() * df
                    + bytes_delay(down, c.download_mbps)
                    + uf * bytes_delay(up, c.upload_mbps)
            })
            .collect();
        let mean_residency = match self.sampling {
            Sampling::Weighted => {
                let weighted: f64 = self
                    .tiers
                    .iter()
                    .zip(&residency)
                    .map(|(t, &r)| {
                        let c = &t.cfg;
                        let avail = if c.day_period > 0.0 { c.on_fraction } else { 1.0 };
                        c.weight * avail * r
                    })
                    .sum();
                weighted / self.total_weight
            }
            Sampling::Availability => self.availability_mean_residency(&residency),
        };
        self.rate = self.concurrency as f64 / mean_residency;
    }

    /// Expected residency added per arrival *event* under
    /// availability-weighted sampling, time-averaged over the diurnal
    /// cycle: at clock τ the arriving client lands on tier i with
    /// probability `w_i·1[on_i(τ)] / Σ_j w_j·1[on_j(τ)]` (and the
    /// arrival is lost when every tier is off). Evaluated on a uniform
    /// grid over the longest configured period — exact for populations
    /// sharing one period (the common case), a close approximation for
    /// incommensurate ones.
    fn availability_mean_residency(&self, residency: &[f64]) -> f64 {
        let p_max = self
            .tiers
            .iter()
            .map(|t| t.cfg.day_period)
            .fold(0.0f64, f64::max);
        if p_max <= 0.0 {
            // no windows: every tier always on, plain weighted mixture
            let weighted: f64 = self
                .tiers
                .iter()
                .zip(residency)
                .map(|(t, &r)| t.cfg.weight * r)
                .sum();
            return weighted / self.total_weight;
        }
        const GRID: usize = 2048;
        let mut sum = 0.0f64;
        for j in 0..GRID {
            let clock = (j as f64 + 0.5) / GRID as f64 * p_max;
            let mut mass = 0.0f64;
            let mut mass_r = 0.0f64;
            for (i, t) in self.tiers.iter().enumerate() {
                if self.available(i, clock) {
                    mass += t.cfg.weight;
                    mass_r += t.cfg.weight * residency[i];
                }
            }
            if mass > 0.0 {
                sum += mass_r / mass;
            }
        }
        sum / GRID as f64
    }

    /// Calibrated long-run arrival rate.
    pub fn rate(&self) -> f64 {
        self.rate
    }

    pub fn num_tiers(&self) -> usize {
        self.tiers.len()
    }

    pub fn tier_name(&self, tier: usize) -> &str {
        &self.tiers[tier].cfg.name
    }

    /// The arrival process for this scenario (constructed separately so
    /// it can own its regime state while the scenario stays borrowable).
    pub fn arrival_process(&self) -> Result<Box<dyn ArrivalProcess>> {
        build_arrival(&self.arrival_kind, self.rate, self.burst.0, self.burst.1, self.burst.2)
    }

    /// Sample the tier of the arriving client. Single-tier populations
    /// draw nothing (the desugared default consumes zero randomness
    /// here).
    pub fn sample_tier(&self, rng: &mut Prng) -> usize {
        if self.tiers.len() == 1 {
            return 0;
        }
        let x = rng.f64() * self.total_weight;
        self.cum.iter().position(|&c| x < c).unwrap_or(self.tiers.len() - 1)
    }

    /// Sample a training duration for a client of `tier`.
    pub fn sample_duration(&mut self, tier: usize, rng: &mut Prng) -> f64 {
        self.tiers[tier].dist.sample(rng)
    }

    /// Whether the client drops before uploading. Zero-dropout tiers
    /// draw nothing.
    pub fn sample_dropout(&self, tier: usize, rng: &mut Prng) -> bool {
        let p = self.tiers[tier].cfg.dropout;
        p > 0.0 && rng.bool(p)
    }

    /// The configured tier-sampling policy.
    pub fn sampling(&self) -> Sampling {
        self.sampling
    }

    /// The tier's client-codec preset spec, if it has one.
    pub fn tier_quant_client(&self, tier: usize) -> Option<&str> {
        self.tiers[tier].cfg.quant_client.as_deref()
    }

    /// The tier's server-codec (downlink) preset spec, if it has one.
    pub fn tier_quant_server(&self, tier: usize) -> Option<&str> {
        self.tiers[tier].cfg.quant_server.as_deref()
    }

    /// The tier's heavy-tailed gradient-noise model, if it has one.
    pub fn tier_grad_noise(&self, tier: usize) -> Option<GradNoise> {
        self.tiers[tier].grad_noise
    }

    /// The tier's adversarial upload behavior, if it has one.
    pub fn tier_adversary(&self, tier: usize) -> Option<Adversary> {
        self.tiers[tier].adversary
    }

    /// Does any tier inject noise or act adversarially? (The engine
    /// skips the whole upload-transform path — and its streams stay
    /// untouched — when this is false.)
    pub fn any_hostile(&self) -> bool {
        self.tiers.iter().any(|t| t.grad_noise.is_some() || t.adversary.is_some())
    }

    /// For a client that just *dropped*: does it submit the partial
    /// update from the `m` local steps it completed instead of
    /// discarding its work (FedBuff partial-work semantics)? Returns the
    /// completed fraction `m/P` with `m` uniform on `{1, .., P-1}`, or
    /// `None` for a full dropout. Tiers with `partial_work = 0` (and
    /// runs with `P < 2`, where no mid-round prefix exists) draw
    /// nothing — the stream stays untouched and pre-v2 runs replay
    /// bit-identically.
    pub fn sample_partial(&self, tier: usize, rng: &mut Prng) -> Option<f32> {
        let q = self.tiers[tier].cfg.partial_work;
        let p = self.local_steps;
        if q <= 0.0 || p < 2 {
            return None;
        }
        if !rng.bool(q) {
            return None;
        }
        let m = 1 + rng.below(p as u64 - 1);
        Some(m as f32 / p as f32)
    }

    /// Availability-weighted tier draw ([`Sampling::Availability`]): the
    /// arriving client lands on a tier with probability proportional to
    /// `weight x 1[tier on at clock]`. Returns `None` (drawing nothing)
    /// when every tier is off — the only case this mode loses an
    /// arrival.
    pub fn sample_available_tier(&self, clock: f64, rng: &mut Prng) -> Option<usize> {
        let mut mass = 0.0f64;
        let mut last = None;
        for (i, t) in self.tiers.iter().enumerate() {
            if self.available(i, clock) {
                mass += t.cfg.weight;
                last = Some(i);
            }
        }
        if mass <= 0.0 {
            return None;
        }
        let x = rng.f64() * mass;
        let mut acc = 0.0f64;
        for (i, t) in self.tiers.iter().enumerate() {
            if self.available(i, clock) {
                acc += t.cfg.weight;
                if x < acc {
                    return Some(i);
                }
            }
        }
        last // x landed on the top edge from rounding; take the last on-tier
    }

    /// Diurnal availability: a tier with `day_period > 0` is on for the
    /// first `on_fraction` of each period (shifted by `phase`).
    /// Deterministic in the clock — no randomness.
    pub fn available(&self, tier: usize, clock: f64) -> bool {
        let t = &self.tiers[tier].cfg;
        if t.day_period <= 0.0 {
            return true;
        }
        let pos = ((clock + t.phase) % t.day_period) / t.day_period;
        pos < t.on_fraction
    }

    /// Download delay (virtual time) for fetching the start-of-round
    /// increment on `tier`'s downlink. 0 Mbps = unlimited — the
    /// desugared default adds exactly 0.0 and stays bit-identical.
    pub fn download_delay(&self, tier: usize, bytes: usize) -> f64 {
        bytes_delay(bytes, self.tiers[tier].cfg.download_mbps)
    }

    /// Upload delay (virtual time) for the finished delta on `tier`'s
    /// uplink. Dropped clients never pay this (they never upload).
    pub fn upload_delay(&self, tier: usize, bytes: usize) -> f64 {
        bytes_delay(bytes, self.tiers[tier].cfg.upload_mbps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn two_tier_cfg() -> Config {
        let mut c = Config::default();
        let mut fast = TierConfig::named("fast");
        fast.weight = 1.0;
        fast.duration = "fixed".into();
        fast.duration_sigma = 1.0;
        let mut slow = TierConfig::named("slow");
        slow.weight = 3.0;
        slow.duration = "fixed".into();
        slow.duration_sigma = 3.0;
        slow.dropout = 0.5;
        slow.day_period = 10.0;
        slow.on_fraction = 0.5;
        slow.upload_mbps = 1.0;
        slow.download_mbps = 2.0;
        c.scenario.tiers = vec![fast, slow];
        c
    }

    #[test]
    fn default_config_desugars_to_single_tier() {
        let c = Config::default();
        let s = Scenario::build(&c).unwrap();
        assert_eq!(s.num_tiers(), 1);
        assert_eq!(s.tier_name(0), "default");
        // rate identical to the half-normal calibration the paper uses
        let expect = HalfNormal::new(1.0).rate_for_concurrency(c.sim.concurrency as f64);
        assert_eq!(s.rate(), expect);
        // single tier: no randomness drawn for tier choice
        let mut rng = Prng::new(1);
        let before = rng.clone().next_u64();
        assert_eq!(s.sample_tier(&mut rng), 0);
        assert_eq!(rng.next_u64(), before);
        assert!(s.available(0, 123.456));
        assert_eq!(s.download_delay(0, 10_000), 0.0);
        assert_eq!(s.upload_delay(0, 10_000), 0.0);
        // unlimited bandwidth: recalibrating with real wire sizes is a
        // no-op for the default tier
        let mut s = s;
        let before = s.rate();
        s.recalibrate(117_896, 14_738);
        assert_eq!(s.rate(), before);
    }

    #[test]
    fn mixture_rate_uses_configured_distributions_and_availability() {
        // regression for the rate miscalibration: fixed durations of 1
        // and 3 at weights 1:3 give E[D] = 2.5 — but the slow tier is
        // only available half the time (on_fraction 0.5), so its
        // contribution halves: E = (1*1*1 + 3*0.5*3) / 4 = 1.375, and
        // rate = c / 1.375 — not the half-normal formula, and not the
        // window-blind mixture.
        let c = two_tier_cfg();
        let s = Scenario::build(&c).unwrap();
        let expect = c.sim.concurrency as f64 / 1.375;
        assert!((s.rate() - expect).abs() < 1e-12, "{} vs {expect}", s.rate());
        // a window-free variant falls back to the plain mixture
        let mut c2 = c.clone();
        c2.scenario.tiers[1].day_period = 0.0;
        let s2 = Scenario::build(&c2).unwrap();
        let expect2 = c2.sim.concurrency as f64 / 2.5;
        assert!((s2.rate() - expect2).abs() < 1e-12, "{} vs {expect2}", s2.rate());
    }

    #[test]
    fn recalibration_folds_transfer_residency_into_the_rate() {
        // slow tier: 1 Mbps up / 2 Mbps down, dropout 0.5, avail 0.5,
        // fixed 3.0 durations; fast tier: unlimited links, fixed 1.0.
        // 1 MB each way: slow download delay = 8e6/2e6 = 4.0, upload
        // delay = 8e6/1e6 = 8.0 paid by half the clients => residency
        // R_slow = 3 + 4 + 0.5*8 = 11, R_fast = 1. Weighted mean:
        // (1*1*1 + 3*0.5*11) / 4 = 4.375.
        let c = two_tier_cfg();
        let mut s = Scenario::build(&c).unwrap();
        let r0 = s.rate();
        s.recalibrate(1_000_000, 1_000_000);
        assert!(s.rate() < r0, "bigger payloads must lower the arrival rate");
        let expect = c.sim.concurrency as f64 / 4.375;
        assert!((s.rate() - expect).abs() < 1e-9, "{} vs {expect}", s.rate());
        // per-direction delays match the residency math
        assert!((s.download_delay(1, 1_000_000) - 4.0).abs() < 1e-12);
        assert!((s.upload_delay(1, 1_000_000) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn tier_sampling_follows_weights() {
        let c = two_tier_cfg();
        let s = Scenario::build(&c).unwrap();
        let mut rng = Prng::new(5);
        let n = 100_000;
        let slow = (0..n).filter(|_| s.sample_tier(&mut rng) == 1).count();
        let frac = slow as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.01, "slow fraction {frac}");
    }

    #[test]
    fn availability_window_is_diurnal() {
        let c = two_tier_cfg();
        let s = Scenario::build(&c).unwrap();
        // slow tier: period 10, on for the first half
        assert!(s.available(1, 0.0));
        assert!(s.available(1, 4.9));
        assert!(!s.available(1, 5.1));
        assert!(!s.available(1, 9.9));
        assert!(s.available(1, 10.1));
        // fast tier: always on
        assert!(s.available(0, 7.0));
    }

    #[test]
    fn dropout_and_transfer_delay_scale() {
        let c = two_tier_cfg();
        let s = Scenario::build(&c).unwrap();
        let mut rng = Prng::new(9);
        let drops = (0..10_000).filter(|_| s.sample_dropout(1, &mut rng)).count();
        assert!((drops as f64 / 10_000.0 - 0.5).abs() < 0.02);
        // fast tier never draws or drops
        let before = rng.clone().next_u64();
        assert!(!s.sample_dropout(0, &mut rng));
        assert_eq!(rng.next_u64(), before);
        // slow tier: 1 Mbps up, 2 Mbps down; 1000 bytes each way
        let d = s.upload_delay(1, 1000) + s.download_delay(1, 1000);
        assert!((d - (8000.0 / 1e6 + 8000.0 / 2e6)).abs() < 1e-12);
    }

    #[test]
    fn hostile_tier_knobs_resolve_and_default_off() {
        let mut c = two_tier_cfg();
        let s = Scenario::build(&c).unwrap();
        assert!(!s.any_hostile());
        assert_eq!(s.tier_grad_noise(0), None);
        assert_eq!(s.tier_adversary(1), None);
        c.scenario.tiers[0].grad_noise = Some("student_t:3:0.5".into());
        c.scenario.tiers[1].adversary = Some("sign_flip".into());
        let s = Scenario::build(&c).unwrap();
        assert!(s.any_hostile());
        assert_eq!(
            s.tier_grad_noise(0),
            Some(GradNoise::StudentT { dof: 3.0, scale: 0.5 })
        );
        assert_eq!(s.tier_adversary(1), Some(Adversary::SignFlip));
        // bad specs fail at build, not mid-run
        c.scenario.tiers[0].grad_noise = Some("bogus".into());
        assert!(Scenario::build(&c).is_err());
    }

    #[test]
    fn zero_weight_rejected() {
        let mut c = two_tier_cfg();
        c.scenario.tiers[0].weight = 0.0;
        assert!(Scenario::build(&c).is_err());
    }

    #[test]
    fn partial_work_draws_nothing_unless_enabled() {
        // partial_work = 0 (and P < 2): the stream is untouched
        let c = two_tier_cfg();
        let s = Scenario::build(&c).unwrap();
        let mut rng = Prng::new(4);
        let before = rng.clone().next_u64();
        assert_eq!(s.sample_partial(1, &mut rng), None);
        assert_eq!(rng.next_u64(), before);
        // partial_work set but P = 1: still no mid-round prefix
        let mut c1 = two_tier_cfg();
        c1.scenario.tiers[1].partial_work = 0.8;
        c1.fl.local_steps = 1;
        let s1 = Scenario::build(&c1).unwrap();
        let mut rng = Prng::new(4);
        let before = rng.clone().next_u64();
        assert_eq!(s1.sample_partial(1, &mut rng), None);
        assert_eq!(rng.next_u64(), before);
    }

    #[test]
    fn partial_fractions_are_step_aligned_with_mean_half() {
        let mut c = two_tier_cfg();
        c.scenario.tiers[1].partial_work = 0.5;
        c.fl.local_steps = 4;
        let s = Scenario::build(&c).unwrap();
        let mut rng = Prng::new(7);
        let (mut some, mut sum) = (0usize, 0.0f64);
        let n = 40_000;
        for _ in 0..n {
            if let Some(f) = s.sample_partial(1, &mut rng) {
                // fractions are m/P for m in {1, 2, 3}
                assert!(
                    [0.25f32, 0.5, 0.75].contains(&f),
                    "unexpected fraction {f}"
                );
                some += 1;
                sum += f as f64;
            }
        }
        let frac = some as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.02, "partial probability {frac}");
        let mean = sum / some as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean completed fraction {mean}");
    }

    #[test]
    fn per_tier_upload_bytes_shift_the_rate() {
        // slow tier (1 Mbps up) compresses 10x harder than fast: its
        // upload delay shrinks accordingly, and recalibrate_per_tier
        // with a uniform vector matches plain recalibrate bit-for-bit.
        let c = two_tier_cfg();
        let mut uniform = Scenario::build(&c).unwrap();
        let mut per_tier = Scenario::build(&c).unwrap();
        uniform.recalibrate(1_000_000, 0);
        per_tier.recalibrate_per_tier(&[1_000_000, 1_000_000], &[0, 0]);
        assert_eq!(uniform.rate(), per_tier.rate());
        // shrinking only the slow tier's payload raises the rate
        per_tier.recalibrate_per_tier(&[1_000_000, 100_000], &[0, 0]);
        assert!(per_tier.rate() > uniform.rate());
        // R_slow = 3 + 0.5 * 0.8 = 3.4, R_fast = 1 (unlimited links);
        // weighted: (1*1*1 + 3*0.5*3.4)/4 = 1.525
        let expect = c.sim.concurrency as f64 / 1.525;
        assert!((per_tier.rate() - expect).abs() < 1e-9, "{} vs {expect}", per_tier.rate());
        // per-tier downloads enter the residency too: a 1 MB broadcast
        // on the slow downlink (2 Mbps) adds 4.0 of delay...
        per_tier.recalibrate_per_tier(&[1_000_000, 100_000], &[0, 1_000_000]);
        // R_slow = 3 + 4.0 + 0.4 = 7.4; weighted (1 + 1.5*7.4)/4 = 3.025
        let expect = c.sim.concurrency as f64 / 3.025;
        assert!((per_tier.rate() - expect).abs() < 1e-9, "{} vs {expect}", per_tier.rate());
        // ...while a 100 kB per-tier `quant_server` broadcast adds 0.4
        per_tier.recalibrate_per_tier(&[1_000_000, 100_000], &[0, 100_000]);
        // R_slow = 3 + 0.4 + 0.4 = 3.8; weighted (1 + 1.5*3.8)/4 = 1.675
        let expect = c.sim.concurrency as f64 / 1.675;
        assert!((per_tier.rate() - expect).abs() < 1e-9, "{} vs {expect}", per_tier.rate());
    }

    #[test]
    fn partial_work_enters_the_residency_math() {
        // slow tier: dropout 0.5, partial_work 1.0, P >= 2 => every
        // dropper submits partial work: trains E[f] = 1/2 of its round
        // and always pays the upload delay.
        let mut c = two_tier_cfg();
        c.scenario.tiers[1].partial_work = 1.0;
        c.fl.local_steps = 2;
        let mut s = Scenario::build(&c).unwrap();
        s.recalibrate_per_tier(&[1_000_000, 1_000_000], &[0, 0]);
        // df = 1 - 0.5*1*0.5 = 0.75 => training residency 3*0.75 = 2.25;
        // uf = 1 - 0.5*(1-1) = 1 => upload delay 8.0 always paid.
        // weighted: (1*1*1 + 3*0.5*(2.25 + 8.0))/4 = 4.09375
        let expect = c.sim.concurrency as f64 / 4.09375;
        assert!((s.rate() - expect).abs() < 1e-9, "{} vs {expect}", s.rate());
    }

    #[test]
    fn availability_sampling_draws_only_on_tiers() {
        let mut c = two_tier_cfg();
        c.scenario.sampling = "availability".into();
        let s = Scenario::build(&c).unwrap();
        assert_eq!(s.sampling(), Sampling::Availability);
        let mut rng = Prng::new(8);
        // slow tier (weight 3) is off in the second half of its period:
        // there, every arrival lands on fast
        for _ in 0..200 {
            assert_eq!(s.sample_available_tier(7.0, &mut rng), Some(0));
        }
        // first half: both on, slow drawn ~3/4 of the time
        let n = 40_000;
        let slow = (0..n)
            .filter(|_| s.sample_available_tier(2.0, &mut rng) == Some(1))
            .count();
        let frac = slow as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.02, "slow fraction {frac}");
        // a tier mix that is entirely off loses the arrival (and draws
        // nothing)
        let mut c2 = two_tier_cfg();
        c2.scenario.sampling = "availability".into();
        for t in &mut c2.scenario.tiers {
            t.day_period = 10.0;
            t.on_fraction = 0.5;
            t.phase = 0.0;
        }
        let s2 = Scenario::build(&c2).unwrap();
        let mut rng = Prng::new(9);
        let before = rng.clone().next_u64();
        assert_eq!(s2.sample_available_tier(7.0, &mut rng), None);
        assert_eq!(rng.next_u64(), before);
    }

    #[test]
    fn availability_sampling_recalibrates_over_the_cycle() {
        // Both tiers fixed-duration; slow (weight 3, E[D]=3) is on only
        // half its period. While slow is on the expected residency per
        // arrival is (1*1 + 3*3)/4 = 2.5; while it is off every arrival
        // is fast with residency 1. Time average: (2.5 + 1)/2 = 1.75.
        let mut c = two_tier_cfg();
        c.scenario.sampling = "availability".into();
        c.scenario.tiers[1].dropout = 0.0;
        let s = Scenario::build(&c).unwrap();
        let expect = c.sim.concurrency as f64 / 1.75;
        assert!(
            (s.rate() - expect).abs() / expect < 1e-3,
            "{} vs {expect}",
            s.rate()
        );
        // without any windows the mode degenerates to the plain mixture
        let mut c2 = two_tier_cfg();
        c2.scenario.sampling = "availability".into();
        c2.scenario.tiers[1].day_period = 0.0;
        c2.scenario.tiers[1].dropout = 0.0;
        let s2 = Scenario::build(&c2).unwrap();
        let expect2 = c2.sim.concurrency as f64 / 2.5;
        assert!((s2.rate() - expect2).abs() < 1e-12, "{} vs {expect2}", s2.rate());
    }
}
