//! The resolved client population: a weighted mix of device tiers, each
//! with its own duration distribution, link bandwidths, dropout
//! probability and diurnal availability window.

use crate::config::{Config, TierConfig};
use crate::scenario::metrics::ScenarioMetrics;
use crate::util::dist::{DurationDist, HalfNormal, LogNormal};
use crate::util::prng::Prng;
use anyhow::{bail, Result};

use super::arrival::{build_arrival, ArrivalProcess};

/// Build a duration distribution from its config spec (the same mapping
/// the pre-scenario engine used for `sim.duration`).
pub fn duration_dist(kind: &str, sigma: f64) -> Result<DurationDist> {
    Ok(match kind {
        "halfnormal" => DurationDist::HalfNormal(HalfNormal::new(sigma)),
        "lognormal" => DurationDist::LogNormal(LogNormal::new(0.0, sigma)),
        "fixed" => DurationDist::Fixed(sigma),
        other => bail!("unknown duration dist '{other}'"),
    })
}

/// One device tier at runtime: its config plus a stateful sampler (the
/// half-normal keeps a Box–Muller spare, so the sampler must persist
/// across draws exactly like the pre-scenario engine's single
/// `DurationDist`).
pub struct Tier {
    pub cfg: TierConfig,
    dist: DurationDist,
}

/// The resolved scenario: tier mix, calibrated arrival rate, and the
/// run's scenario metrics.
pub struct Scenario {
    tiers: Vec<Tier>,
    /// Cumulative tier weights for mixture sampling.
    cum: Vec<f64>,
    total_weight: f64,
    /// Target expected in-flight clients (`sim.concurrency`).
    concurrency: usize,
    /// Long-run client arrivals per unit virtual time. Calibrated via
    /// Little's law as `concurrency / (availability-weighted expected
    /// residency of the tier mix)` — from the *configured* duration
    /// distributions, not a hard-coded half-normal (the pre-scenario
    /// engine miscalibrated lognormal/fixed durations), compensating
    /// for arrivals lost to diurnal off-windows, and (after
    /// [`Scenario::recalibrate`]) for per-tier transfer delays.
    rate: f64,
    arrival_kind: String,
    burst: (f64, f64, f64),
    pub metrics: ScenarioMetrics,
}

/// Wire-transfer delay in virtual time; 0 Mbps = unlimited (no delay).
fn bytes_delay(bytes: usize, mbps: f64) -> f64 {
    if mbps > 0.0 {
        bytes as f64 * 8.0 / (mbps * 1e6)
    } else {
        0.0
    }
}

impl Scenario {
    /// Resolve `cfg` into a runnable scenario. `cfg.scenario.tiers`
    /// when present; otherwise the `sim.*` knobs desugared to a single
    /// always-available unlimited-bandwidth tier (bit-identical to the
    /// pre-scenario engine).
    pub fn build(cfg: &Config) -> Result<Scenario> {
        let tier_cfgs = cfg.resolved_tiers();
        let mut tiers = Vec::with_capacity(tier_cfgs.len());
        for tc in tier_cfgs {
            tiers.push(Tier { dist: duration_dist(&tc.duration, tc.duration_sigma)?, cfg: tc });
        }
        let mut cum = Vec::with_capacity(tiers.len());
        let mut total_weight = 0.0;
        for t in &tiers {
            if !(t.cfg.weight.is_finite() && t.cfg.weight > 0.0) {
                bail!("scenario tier '{}': weight must be positive", t.cfg.name);
            }
            total_weight += t.cfg.weight;
            cum.push(total_weight);
        }
        let metrics =
            ScenarioMetrics::with_tiers(tiers.iter().map(|t| t.cfg.name.clone()));
        let mut scenario = Scenario {
            cum,
            total_weight,
            concurrency: cfg.sim.concurrency,
            rate: 0.0,
            arrival_kind: cfg.resolved_arrival().to_string(),
            burst: (
                cfg.scenario.burst_factor,
                cfg.scenario.burst_on,
                cfg.scenario.burst_off,
            ),
            metrics,
            tiers,
        };
        // Provisional calibration with zero wire sizes; the engine calls
        // `recalibrate` once the codec byte sizes (which depend on the
        // model dimension) are known.
        scenario.recalibrate(0, 0);
        if !(scenario.rate.is_finite() && scenario.rate > 0.0) {
            bail!(
                "scenario: availability-weighted mean residency must be positive \
                 (arrival rate came out as {})",
                scenario.rate
            );
        }
        Ok(scenario)
    }

    /// (Re)calibrate the arrival rate from Little's law:
    ///
    /// ```text
    /// concurrency = rate * sum_i (w_i/W) * a_i * R_i
    /// R_i = E[D_i] + download_delay_i + (1 - dropout_i) * upload_delay_i
    /// ```
    ///
    /// where `a_i` is tier i's long-run availability (arrivals land
    /// uniformly over the diurnal cycle, so `a_i = on_fraction`) and
    /// `R_i` is the expected in-flight **residency** of a started
    /// client: training plus its deterministic transfer time (dropped
    /// clients download but never upload). Without this weighting, a
    /// sleeping tier would undershoot the target concurrency by its off
    /// fraction and a bandwidth-limited tier would overshoot it by its
    /// transfer time — by different factors per algorithm (payload
    /// sizes differ), confounding cross-algorithm comparisons.
    pub fn recalibrate(&mut self, upload_bytes: usize, download_bytes: usize) {
        let weighted: f64 = self
            .tiers
            .iter()
            .map(|t| {
                let c = &t.cfg;
                let avail = if c.day_period > 0.0 { c.on_fraction } else { 1.0 };
                let residency = t.dist.mean()
                    + bytes_delay(download_bytes, c.download_mbps)
                    + (1.0 - c.dropout) * bytes_delay(upload_bytes, c.upload_mbps);
                c.weight * avail * residency
            })
            .sum();
        self.rate = self.concurrency as f64 / (weighted / self.total_weight);
    }

    /// Calibrated long-run arrival rate.
    pub fn rate(&self) -> f64 {
        self.rate
    }

    pub fn num_tiers(&self) -> usize {
        self.tiers.len()
    }

    pub fn tier_name(&self, tier: usize) -> &str {
        &self.tiers[tier].cfg.name
    }

    /// The arrival process for this scenario (constructed separately so
    /// it can own its regime state while the scenario stays borrowable).
    pub fn arrival_process(&self) -> Result<Box<dyn ArrivalProcess>> {
        build_arrival(&self.arrival_kind, self.rate, self.burst.0, self.burst.1, self.burst.2)
    }

    /// Sample the tier of the arriving client. Single-tier populations
    /// draw nothing (the desugared default consumes zero randomness
    /// here).
    pub fn sample_tier(&self, rng: &mut Prng) -> usize {
        if self.tiers.len() == 1 {
            return 0;
        }
        let x = rng.f64() * self.total_weight;
        self.cum.iter().position(|&c| x < c).unwrap_or(self.tiers.len() - 1)
    }

    /// Sample a training duration for a client of `tier`.
    pub fn sample_duration(&mut self, tier: usize, rng: &mut Prng) -> f64 {
        self.tiers[tier].dist.sample(rng)
    }

    /// Whether the client drops before uploading. Zero-dropout tiers
    /// draw nothing.
    pub fn sample_dropout(&self, tier: usize, rng: &mut Prng) -> bool {
        let p = self.tiers[tier].cfg.dropout;
        p > 0.0 && rng.bool(p)
    }

    /// Diurnal availability: a tier with `day_period > 0` is on for the
    /// first `on_fraction` of each period (shifted by `phase`).
    /// Deterministic in the clock — no randomness.
    pub fn available(&self, tier: usize, clock: f64) -> bool {
        let t = &self.tiers[tier].cfg;
        if t.day_period <= 0.0 {
            return true;
        }
        let pos = ((clock + t.phase) % t.day_period) / t.day_period;
        pos < t.on_fraction
    }

    /// Download delay (virtual time) for fetching the start-of-round
    /// increment on `tier`'s downlink. 0 Mbps = unlimited — the
    /// desugared default adds exactly 0.0 and stays bit-identical.
    pub fn download_delay(&self, tier: usize, bytes: usize) -> f64 {
        bytes_delay(bytes, self.tiers[tier].cfg.download_mbps)
    }

    /// Upload delay (virtual time) for the finished delta on `tier`'s
    /// uplink. Dropped clients never pay this (they never upload).
    pub fn upload_delay(&self, tier: usize, bytes: usize) -> f64 {
        bytes_delay(bytes, self.tiers[tier].cfg.upload_mbps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn two_tier_cfg() -> Config {
        let mut c = Config::default();
        let mut fast = TierConfig::named("fast");
        fast.weight = 1.0;
        fast.duration = "fixed".into();
        fast.duration_sigma = 1.0;
        let mut slow = TierConfig::named("slow");
        slow.weight = 3.0;
        slow.duration = "fixed".into();
        slow.duration_sigma = 3.0;
        slow.dropout = 0.5;
        slow.day_period = 10.0;
        slow.on_fraction = 0.5;
        slow.upload_mbps = 1.0;
        slow.download_mbps = 2.0;
        c.scenario.tiers = vec![fast, slow];
        c
    }

    #[test]
    fn default_config_desugars_to_single_tier() {
        let c = Config::default();
        let s = Scenario::build(&c).unwrap();
        assert_eq!(s.num_tiers(), 1);
        assert_eq!(s.tier_name(0), "default");
        // rate identical to the half-normal calibration the paper uses
        let expect = HalfNormal::new(1.0).rate_for_concurrency(c.sim.concurrency as f64);
        assert_eq!(s.rate(), expect);
        // single tier: no randomness drawn for tier choice
        let mut rng = Prng::new(1);
        let before = rng.clone().next_u64();
        assert_eq!(s.sample_tier(&mut rng), 0);
        assert_eq!(rng.next_u64(), before);
        assert!(s.available(0, 123.456));
        assert_eq!(s.download_delay(0, 10_000), 0.0);
        assert_eq!(s.upload_delay(0, 10_000), 0.0);
        // unlimited bandwidth: recalibrating with real wire sizes is a
        // no-op for the default tier
        let mut s = s;
        let before = s.rate();
        s.recalibrate(117_896, 14_738);
        assert_eq!(s.rate(), before);
    }

    #[test]
    fn mixture_rate_uses_configured_distributions_and_availability() {
        // regression for the rate miscalibration: fixed durations of 1
        // and 3 at weights 1:3 give E[D] = 2.5 — but the slow tier is
        // only available half the time (on_fraction 0.5), so its
        // contribution halves: E = (1*1*1 + 3*0.5*3) / 4 = 1.375, and
        // rate = c / 1.375 — not the half-normal formula, and not the
        // window-blind mixture.
        let c = two_tier_cfg();
        let s = Scenario::build(&c).unwrap();
        let expect = c.sim.concurrency as f64 / 1.375;
        assert!((s.rate() - expect).abs() < 1e-12, "{} vs {expect}", s.rate());
        // a window-free variant falls back to the plain mixture
        let mut c2 = c.clone();
        c2.scenario.tiers[1].day_period = 0.0;
        let s2 = Scenario::build(&c2).unwrap();
        let expect2 = c2.sim.concurrency as f64 / 2.5;
        assert!((s2.rate() - expect2).abs() < 1e-12, "{} vs {expect2}", s2.rate());
    }

    #[test]
    fn recalibration_folds_transfer_residency_into_the_rate() {
        // slow tier: 1 Mbps up / 2 Mbps down, dropout 0.5, avail 0.5,
        // fixed 3.0 durations; fast tier: unlimited links, fixed 1.0.
        // 1 MB each way: slow download delay = 8e6/2e6 = 4.0, upload
        // delay = 8e6/1e6 = 8.0 paid by half the clients => residency
        // R_slow = 3 + 4 + 0.5*8 = 11, R_fast = 1. Weighted mean:
        // (1*1*1 + 3*0.5*11) / 4 = 4.375.
        let c = two_tier_cfg();
        let mut s = Scenario::build(&c).unwrap();
        let r0 = s.rate();
        s.recalibrate(1_000_000, 1_000_000);
        assert!(s.rate() < r0, "bigger payloads must lower the arrival rate");
        let expect = c.sim.concurrency as f64 / 4.375;
        assert!((s.rate() - expect).abs() < 1e-9, "{} vs {expect}", s.rate());
        // per-direction delays match the residency math
        assert!((s.download_delay(1, 1_000_000) - 4.0).abs() < 1e-12);
        assert!((s.upload_delay(1, 1_000_000) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn tier_sampling_follows_weights() {
        let c = two_tier_cfg();
        let s = Scenario::build(&c).unwrap();
        let mut rng = Prng::new(5);
        let n = 100_000;
        let slow = (0..n).filter(|_| s.sample_tier(&mut rng) == 1).count();
        let frac = slow as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.01, "slow fraction {frac}");
    }

    #[test]
    fn availability_window_is_diurnal() {
        let c = two_tier_cfg();
        let s = Scenario::build(&c).unwrap();
        // slow tier: period 10, on for the first half
        assert!(s.available(1, 0.0));
        assert!(s.available(1, 4.9));
        assert!(!s.available(1, 5.1));
        assert!(!s.available(1, 9.9));
        assert!(s.available(1, 10.1));
        // fast tier: always on
        assert!(s.available(0, 7.0));
    }

    #[test]
    fn dropout_and_transfer_delay_scale() {
        let c = two_tier_cfg();
        let s = Scenario::build(&c).unwrap();
        let mut rng = Prng::new(9);
        let drops = (0..10_000).filter(|_| s.sample_dropout(1, &mut rng)).count();
        assert!((drops as f64 / 10_000.0 - 0.5).abs() < 0.02);
        // fast tier never draws or drops
        let before = rng.clone().next_u64();
        assert!(!s.sample_dropout(0, &mut rng));
        assert_eq!(rng.next_u64(), before);
        // slow tier: 1 Mbps up, 2 Mbps down; 1000 bytes each way
        let d = s.upload_delay(1, 1000) + s.download_delay(1, 1000);
        assert!((d - (8000.0 / 1e6 + 8000.0 / 2e6)).abs() < 1e-12);
    }

    #[test]
    fn zero_weight_rejected() {
        let mut c = two_tier_cfg();
        c.scenario.tiers[0].weight = 0.0;
        assert!(Scenario::build(&c).is_err());
    }
}
