//! Pluggable client arrival processes.
//!
//! The paper models arrivals at a constant rate (Appendix D); Poisson
//! arrivals are the classical ablation; the bursty process is a 2-state
//! Markov-modulated Poisson process (MMPP) standing in for flash crowds
//! and regional wake-ups. All three are normalized to the same
//! **long-run** arrival rate, which [`super::population::Scenario`]
//! calibrates as `concurrency / (availability-weighted E[duration])` —
//! the target concurrency is sustained on average regardless of the
//! process chosen.
//!
//! Determinism contract: `next_gap` draws only from the `Prng` it is
//! handed (the simulator's "arrivals" stream), and the constant process
//! draws nothing — exactly the draw pattern of the pre-scenario engine,
//! which keeps the desugared default bit-identical.

use crate::util::dist::Exponential;
use crate::util::prng::Prng;
use anyhow::{bail, Result};

/// A point process generating client arrivals in virtual time.
pub trait ArrivalProcess {
    fn name(&self) -> &'static str;

    /// Virtual-time gap from the arrival just emitted to the next one.
    fn next_gap(&mut self, rng: &mut Prng) -> f64;

    /// Internal state beyond the engine-owned `Prng` (for checkpoints).
    /// Stateless processes return an empty vec; a stateful process must
    /// round-trip bit-exactly through [`ArrivalProcess::restore`].
    fn state(&self) -> Vec<f64> {
        Vec::new()
    }

    /// Restore a [`ArrivalProcess::state`] dump into a freshly built
    /// process (the resume path).
    fn restore(&mut self, state: &[f64]) -> Result<()> {
        if state.is_empty() {
            Ok(())
        } else {
            bail!("arrival process '{}' carries no state to restore", self.name())
        }
    }
}

/// Evenly spaced arrivals (the paper's model). Draws no randomness.
pub struct ConstantArrival {
    gap: f64,
}

impl ArrivalProcess for ConstantArrival {
    fn name(&self) -> &'static str {
        "constant"
    }

    fn next_gap(&mut self, _rng: &mut Prng) -> f64 {
        self.gap
    }
}

/// Poisson arrivals: iid exponential gaps (one draw per arrival).
pub struct PoissonArrival {
    exp: Exponential,
}

impl ArrivalProcess for PoissonArrival {
    fn name(&self) -> &'static str {
        "poisson"
    }

    fn next_gap(&mut self, rng: &mut Prng) -> f64 {
        self.exp.sample(rng)
    }
}

/// 2-state MMPP: a background ("off") Poisson regime interrupted by
/// bursts ("on") running at `burst_factor` times the off rate. Regime
/// sojourns are exponential with means `mean_on` / `mean_off`, so the
/// long-run rate is `rate` by construction:
///
/// ```text
/// p_on    = mean_on / (mean_on + mean_off)
/// rate_off = rate / (1 - p_on + factor * p_on),   rate_on = factor * rate_off
/// ```
///
/// Because the exponential is memoryless, drawing a fresh gap after each
/// regime switch reproduces the MMPP exactly (no thinning needed).
pub struct BurstyArrival {
    rate_on: f64,
    rate_off: f64,
    mean_on: f64,
    mean_off: f64,
    on: bool,
    /// Virtual time left in the current regime; lazily initialized on
    /// the first draw so construction consumes no randomness.
    remaining: f64,
    started: bool,
}

impl ArrivalProcess for BurstyArrival {
    fn name(&self) -> &'static str {
        "bursty"
    }

    fn next_gap(&mut self, rng: &mut Prng) -> f64 {
        if !self.started {
            self.remaining = Exponential::new(1.0 / self.mean_off).sample(rng);
            self.started = true;
        }
        let mut gap = 0.0;
        loop {
            let rate = if self.on { self.rate_on } else { self.rate_off };
            let draw = Exponential::new(rate).sample(rng);
            if draw < self.remaining {
                self.remaining -= draw;
                return gap + draw;
            }
            gap += self.remaining;
            self.on = !self.on;
            let mean = if self.on { self.mean_on } else { self.mean_off };
            self.remaining = Exponential::new(1.0 / mean).sample(rng);
        }
    }

    fn state(&self) -> Vec<f64> {
        vec![
            f64::from(u8::from(self.on)),
            self.remaining,
            f64::from(u8::from(self.started)),
        ]
    }

    fn restore(&mut self, state: &[f64]) -> Result<()> {
        let &[on, remaining, started] = state else {
            bail!("bursty arrival: expected 3 state values, got {}", state.len());
        };
        self.on = on != 0.0;
        self.remaining = remaining;
        self.started = started != 0.0;
        Ok(())
    }
}

/// Build an arrival process by name. `rate` is the long-run arrivals per
/// unit virtual time; the bursty parameters come from the `[scenario]`
/// table.
pub fn build_arrival(
    kind: &str,
    rate: f64,
    burst_factor: f64,
    burst_on: f64,
    burst_off: f64,
) -> Result<Box<dyn ArrivalProcess>> {
    if !(rate.is_finite() && rate > 0.0) {
        bail!("arrival rate must be positive and finite, got {rate}");
    }
    Ok(match kind {
        "constant" => Box::new(ConstantArrival { gap: 1.0 / rate }),
        "poisson" => Box::new(PoissonArrival { exp: Exponential::new(rate) }),
        "bursty" => {
            if !(burst_factor.is_finite() && burst_factor > 0.0) {
                bail!("scenario.burst_factor must be > 0, got {burst_factor}");
            }
            if !(burst_on > 0.0 && burst_off > 0.0) {
                bail!("scenario.burst_on/burst_off must be > 0");
            }
            let p_on = burst_on / (burst_on + burst_off);
            let rate_off = rate / ((1.0 - p_on) + burst_factor * p_on);
            Box::new(BurstyArrival {
                rate_on: burst_factor * rate_off,
                rate_off,
                mean_on: burst_on,
                mean_off: burst_off,
                on: false,
                remaining: 0.0,
                started: false,
            })
        }
        other => bail!("unknown arrival process '{other}' (constant | poisson | bursty)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_rate(p: &mut dyn ArrivalProcess, n: usize, seed: u64) -> f64 {
        let mut rng = Prng::new(seed);
        let mut total = 0.0;
        for _ in 0..n {
            total += p.next_gap(&mut rng);
        }
        n as f64 / total
    }

    #[test]
    fn constant_is_exact_and_draws_nothing() {
        let mut p = build_arrival("constant", 8.0, 4.0, 1.0, 4.0).unwrap();
        let mut rng = Prng::new(1);
        let before = rng.clone().next_u64();
        for _ in 0..10 {
            assert_eq!(p.next_gap(&mut rng), 0.125);
        }
        assert_eq!(rng.next_u64(), before, "constant arrivals must not draw randomness");
    }

    #[test]
    fn poisson_long_run_rate_matches() {
        let mut p = build_arrival("poisson", 5.0, 4.0, 1.0, 4.0).unwrap();
        let r = mean_rate(p.as_mut(), 200_000, 2);
        assert!((r - 5.0).abs() / 5.0 < 0.02, "poisson rate {r}");
    }

    #[test]
    fn bursty_long_run_rate_matches_but_is_overdispersed() {
        let mut p = build_arrival("bursty", 5.0, 6.0, 1.0, 4.0).unwrap();
        let r = mean_rate(p.as_mut(), 400_000, 3);
        assert!((r - 5.0).abs() / 5.0 < 0.05, "bursty long-run rate {r}");

        // count arrivals per unit-time window: MMPP variance-to-mean
        // ratio exceeds the Poisson value of 1
        let dispersion = |p: &mut dyn ArrivalProcess, seed: u64| {
            let mut rng = Prng::new(seed);
            let (mut t, mut window, mut count) = (0.0f64, 0usize, 0u64);
            let mut counts = vec![0u64; 2000];
            while window < counts.len() {
                t += p.next_gap(&mut rng);
                while window < counts.len() && t > (window + 1) as f64 {
                    counts[window] = count;
                    count = 0;
                    window += 1;
                }
                count += 1;
            }
            let n = counts.len() as f64;
            let mean = counts.iter().sum::<u64>() as f64 / n;
            let var = counts.iter().map(|&c| (c as f64 - mean).powi(2)).sum::<f64>() / n;
            var / mean
        };
        let mut bursty = build_arrival("bursty", 5.0, 6.0, 1.0, 4.0).unwrap();
        let mut poisson = build_arrival("poisson", 5.0, 4.0, 1.0, 4.0).unwrap();
        let db = dispersion(bursty.as_mut(), 7);
        let dp = dispersion(poisson.as_mut(), 7);
        assert!(db > 1.5 * dp, "bursty dispersion {db} vs poisson {dp}");
    }

    #[test]
    fn state_roundtrip_resumes_bit_identically() {
        // stateless processes checkpoint as empty and reject junk
        let mut c = build_arrival("constant", 2.0, 4.0, 1.0, 4.0).unwrap();
        assert!(c.state().is_empty());
        assert!(c.restore(&[]).is_ok());
        assert!(c.restore(&[1.0]).is_err());
        // bursty: run a prefix, checkpoint, then both copies must emit
        // the same gaps from the same rng state
        let mut a = build_arrival("bursty", 5.0, 6.0, 1.0, 4.0).unwrap();
        let mut rng = Prng::new(9);
        for _ in 0..137 {
            a.next_gap(&mut rng);
        }
        let saved = a.state();
        let rng_saved = rng.state();
        let tail: Vec<f64> = (0..50).map(|_| a.next_gap(&mut rng)).collect();
        let mut b = build_arrival("bursty", 5.0, 6.0, 1.0, 4.0).unwrap();
        b.restore(&saved).unwrap();
        let mut rng2 = Prng::from_state(rng_saved);
        let tail2: Vec<f64> = (0..50).map(|_| b.next_gap(&mut rng2)).collect();
        assert!(tail.iter().zip(&tail2).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert!(b.restore(&[1.0]).is_err());
    }

    #[test]
    fn bad_specs_rejected() {
        assert!(build_arrival("weibull", 1.0, 4.0, 1.0, 4.0).is_err());
        assert!(build_arrival("constant", 0.0, 4.0, 1.0, 4.0).is_err());
        assert!(build_arrival("constant", f64::NAN, 4.0, 1.0, 4.0).is_err());
        assert!(build_arrival("bursty", 1.0, 0.0, 1.0, 4.0).is_err());
        assert!(build_arrival("bursty", 1.0, 4.0, 0.0, 4.0).is_err());
    }
}
