//! The leader process: accepts workers, runs Algorithm 1 over TCP.
//!
//! **Protocol negotiation** (wire v2, see `net/message.rs`): v2 workers
//! speak first with `Hello`; a v1 worker connects silently and waits
//! for `Join`, so the leader classifies a connection that stays silent
//! for `net.v1_grace_ms` as v1 and serves it the legacy frames
//! bit-identically. Each connection is handshaked on its own thread —
//! one peer that connects and stalls mid-`Hello` burns only its own
//! grace deadline instead of serializing every later worker's join
//! behind it. Each v2 worker's upload codec is resolved from its
//! `Hello` (explicit `quant_client` override, else its tier's
//! `scenario.tiers.<name>.quant_client` preset, else the default) and
//! registered in the server's codec registry; every `UpdateV2` is then
//! routed by its `codec_id` through [`Server::ingest_from`] — no
//! payload-size guessing, no ambiguous-size failure mode.
//!
//! **Per-tier downlink** (ISSUE 8): the worker's tier also resolves its
//! *downlink* codec via `scenario.tiers.<name>.quant_server` — the
//! leader registers the tier presets as hidden-state families in the
//! [`Server`] (dedup by resolved codec name; tiers without a preset
//! share family 0) and tells the worker its family's codec in
//! `JoinV2.server_quant` / `server_codec_id`. Every server step emits
//! one broadcast per family; each writer queue receives only its own
//! family's frames, encoded once per family and shared as `Arc<[u8]>`.
//!
//! **Budgeted fan-out**: with `net.broadcast_budget_bytes > 0` each v2
//! writer queue is a bounded [`FrameQueue`] — when a slow worker falls
//! behind, superseded frames are evicted (newest kept) and the writer
//! folds the gap into a catch-up from its family's
//! [`UpdateLog`] (Appendix B.1): the missed increments replayed
//! bit-identically, or one full-state `Sync` frame when the log has
//! evicted them. Leader memory stays bounded per connection and the
//! step loop never stalls. v1 connections predate the `Sync` frame and
//! keep the unbudgeted queue. At the default budget 0 the fold
//! machinery is not even constructed and the fan-out behaves exactly
//! as before.
//!
//! **Flight recorder** (ARCHITECTURE.md §Telemetry): with
//! `telemetry.journal` set the leader streams the same typed
//! [`Event`] vocabulary the simulator writes — `Meta`/`Init`/`Codec`,
//! one `Ingest`/`IngestPartial` per upload that reached the server,
//! `Step` + one `Broadcast` per downlink family per committed step,
//! `Checkpoint` every `telemetry.checkpoint_every` steps, and a
//! closing `Final`. Because the journal records what *reached the
//! server* in arrival order, [`crate::telemetry::replay_events`]
//! reproduces the run's broadcasts bit-exactly even though TCP
//! delivery itself is nondeterministic. [`Leader::resume`] restores
//! the server from the journal's last checkpoint and appends;
//! rejoining workers receive the checkpointed hidden state as their
//! x^0 and pick up the broadcast stream at the resumed step (their
//! uploads are staleness-floored at the join step).

use super::message::{Message, PROTOCOL_VERSION};
use super::queue::{FrameQueue, QueuedFrame};
use super::transport::{frame_bytes, read_msg, read_msg_classified, write_msg, ReadOutcome};
use crate::config::Config;
use crate::coordinator::{CatchUp, Server, ServerStep, UpdateLog};
use crate::metrics::CommMetrics;
use crate::quant::{parse_spec, QuantizedMsg, Quantizer};
use crate::scenario::StalenessHist;
use crate::telemetry::event::{hex_u64, parse_hex_u64};
use crate::telemetry::{
    self, progress_line, truncate_after_last_checkpoint, Event, JournalWriter, StageTimings,
};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// One negotiated-codec epoch of a worker's upload accounting: the
/// codec the connection used from its join (or a `Rekey`) until the
/// next `Rekey`. In-flight old-codec uploads accepted during a
/// transition window attribute to *their* epoch, so
/// `upload_bytes == uploads x expected_bytes(d)` holds exactly per
/// epoch even across a switch. Partial aggregates from edge leaders
/// travel in the separate partial-codec registry and are not
/// attributed to epochs.
#[derive(Clone, Debug)]
pub struct CodecEpoch {
    /// Registry id of this epoch's upload codec.
    pub codec_id: usize,
    /// Resolved spec name of that codec (e.g. `"qsgd:4"`).
    pub codec: String,
    /// Uploads ingested under this epoch's codec.
    pub uploads: u64,
    /// Wire payload bytes of those uploads.
    pub upload_bytes: u64,
}

/// Per-worker accounting, mirroring the simulator's per-tier
/// [`crate::scenario::TierMetrics`]: what each connection uploaded,
/// what was actually written to it, and the staleness it produced.
#[derive(Clone, Debug)]
pub struct WorkerStats {
    pub worker_id: u32,
    /// Peer address the worker connected from.
    pub peer: String,
    /// Negotiated protocol version (1 = legacy silent join, 2 = Hello
    /// handshake with per-worker codec).
    pub protocol: u8,
    /// The worker's *current* upload codec in the server registry
    /// (0 = default); updated by `Rekey` switches.
    pub codec_id: usize,
    /// Resolved spec name of that codec (e.g. `"top:0.1"`).
    pub codec: String,
    /// Uplink bandwidth hint the worker announced in `Hello`
    /// (Mbit/s), if any — the adaptive controller's preferred score.
    pub bandwidth_hint: Option<f32>,
    /// Mid-run codec switches applied to this worker (`Rekey` frames
    /// sent by the adaptive controller).
    pub rekeys: u64,
    /// Per-epoch upload accounting, one entry per negotiated codec in
    /// order (the join codec first, then one per `Rekey`).
    pub epochs: Vec<CodecEpoch>,
    /// The worker's downlink family in the server's hidden-state
    /// registry (0 = default `quant.server`), resolved from its tier's
    /// `quant_server` preset.
    pub server_codec_id: usize,
    /// Resolved spec name of that downlink codec.
    pub server_codec: String,
    /// Ingested uploads from this worker (late post-shutdown uploads are
    /// dropped and not counted, matching the server's totals).
    pub uploads: u64,
    /// Sum of the ingested upload payload bytes, as counted off the
    /// wire frames (not derived from the codec formula).
    pub upload_bytes: u64,
    /// Of `uploads`, how many were `UpdatePartial` frames from a
    /// downstream edge leader (0 for plain workers). When non-zero,
    /// `codec` is the partial codec `Q_p` the frames were decoded with.
    pub partials: u64,
    /// Frames this worker's writer thread actually wrote (broadcasts +
    /// catch-up/Sync frames + the shutdown frame; the join frame is
    /// written before the writer thread starts).
    pub broadcast_frames: u64,
    /// Bytes this worker's writer thread actually wrote.
    pub broadcast_bytes: u64,
    /// Broadcast frames evicted from this worker's bounded queue under
    /// `net.broadcast_budget_bytes` pressure (0 at the default budget);
    /// each run of skips is folded into the catch-up below.
    pub skipped_broadcasts: u64,
    /// Of `broadcast_frames`, how many were catch-up frames (replayed
    /// increments or `Sync`) covering skipped broadcasts.
    pub catch_up_frames: u64,
    /// How many catch-ups had to ship the full hidden state (`Sync`)
    /// because the family's [`UpdateLog`] had evicted the increments.
    pub full_syncs: u64,
    /// Wall time spent decoding + aggregating this worker's uploads
    /// (the leader-side recv cost). Captured only while telemetry spans
    /// are on ([`telemetry::set_enabled`]); zero otherwise.
    pub ingest_ns: u64,
    /// Wall time this worker's writer thread spent in socket writes
    /// (the leader-side send cost). Span-gated like `ingest_ns`.
    pub send_ns: u64,
    /// Staleness histogram over this worker's ingested uploads.
    pub staleness: StalenessHist,
    /// Uploads from this worker the robust server shrank to the clip
    /// norm (`[fl.robust]` clip_norm; 0 with robust aggregation off).
    pub clipped_updates: u64,
    /// Uploads from this worker the trimmed mean excluded at a majority
    /// of coordinates (`[fl.robust]` trim_frac; 0 with trimming off).
    pub trimmed_updates: u64,
}

/// Final report of a leader run.
#[derive(Clone, Debug)]
pub struct LeaderReport {
    pub comm: CommMetrics,
    pub server_steps: u64,
    pub staleness_max: u64,
    pub staleness_mean: f64,
    /// Final server model x^T.
    pub model: Vec<f32>,
    pub workers: usize,
    /// Per-worker byte/staleness accounting, indexed by worker id.
    pub worker_stats: Vec<WorkerStats>,
    /// Cumulative per-stage server-step timings (span-gated; `steps`
    /// always counts).
    pub stage_timings: StageTimings,
    /// [`telemetry::run_fingerprint`] of (resolved config, seed).
    pub fingerprint: String,
    /// The run's full event stream, present when
    /// [`Leader::record_events`] was set: the same typed events a
    /// journal file would hold, replayable via
    /// [`crate::telemetry::replay_events`].
    pub events: Option<Vec<Event>>,
}

/// Leader configuration + run loop.
pub struct Leader {
    cfg: Config,
    x0: Vec<f32>,
    seed: u64,
    /// Collect the run's journal events in memory into
    /// [`LeaderReport::events`] (tests: replay without a journal file).
    /// Fresh runs only — a resumed run's prefix lives in the file, so
    /// the in-memory slice alone would not replay. Default off.
    pub record_events: bool,
    /// Resume from `telemetry.journal`: truncate it to its last
    /// `Checkpoint`, restore the server state saved there, and append.
    /// Default off.
    pub resume: bool,
}

/// Fan-in sink for journal events: a file writer (the `--journal`
/// path), an in-memory buffer ([`Leader::record_events`]), or both.
struct Recorder {
    writer: Option<JournalWriter>,
    mem: Option<Vec<Event>>,
}

impl Recorder {
    fn on(&self) -> bool {
        self.writer.is_some() || self.mem.is_some()
    }

    fn emit(&mut self, ev: Event) -> Result<()> {
        if let Some(w) = self.writer.as_mut() {
            w.write(&ev)?;
        }
        if let Some(v) = self.mem.as_mut() {
            v.push(ev);
        }
        Ok(())
    }
}

/// What a handshake thread hands back to the accept loop: a classified
/// connection, ready for codec resolution (which needs the server) and
/// the join frame.
struct Handshake {
    worker_id: u32,
    peer: String,
    reader: TcpStream,
    writer: TcpStream,
    /// `None` = silent v1 peer; `Some` = the v2 `Hello` fields
    /// (version, tier, quant_client, bandwidth_hint).
    hello: Option<(u8, Option<String>, Option<String>, Option<f32>)>,
}

/// Classify one fresh connection as v1/v2 and read its `Hello` if any,
/// all under the `grace` deadline. Runs on its own thread so a stalled
/// peer cannot block other workers' handshakes (it fails alone when its
/// deadline expires).
fn handshake(
    stream: TcpStream,
    worker_id: u32,
    peer: String,
    grace: Duration,
) -> Result<Handshake> {
    // v2 workers send Hello immediately on connect; a v1 worker waits
    // silently for Join. Peek (never consume) with a bounded timeout to
    // classify the peer without corrupting the stream.
    stream
        .set_read_timeout(Some(grace))
        .with_context(|| format!("worker {worker_id} ({peer}): handshake timeout"))?;
    let mut probe = [0u8; 1];
    let spoke = match stream.peek(&mut probe) {
        Ok(n) => n > 0,
        Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => false,
        Err(e) => {
            return Err(e).with_context(|| format!("probing worker {worker_id} ({peer})"));
        }
    };
    // the read timeout stays armed through the Hello read: a peer that
    // sends a partial frame and stalls fails its own handshake loudly
    let mut reader = stream.try_clone().context("cloning tcp stream")?;
    let writer = stream;
    let hello = if spoke {
        let msg = read_msg(&mut reader)
            .with_context(|| {
                format!(
                    "reading Hello from worker {worker_id} ({peer}) \
                     within the {}ms handshake deadline",
                    grace.as_millis()
                )
            })?
            .ok_or_else(|| anyhow!("worker {worker_id} ({peer}) disconnected during handshake"))?;
        match msg {
            Message::Hello { version, tier, quant_client, bandwidth_hint } => {
                Some((version, tier, quant_client, bandwidth_hint))
            }
            other => bail!("worker {worker_id} ({peer}): expected Hello, got {other:?}"),
        }
    } else {
        None
    };
    // handshake over: the steady-state reader blocks as long as it
    // likes (clears the deadline on the shared socket)
    reader
        .set_read_timeout(None)
        .with_context(|| format!("worker {worker_id} ({peer}): clearing deadline"))?;
    Ok(Handshake { worker_id, peer, reader, writer, hello })
}

/// What a writer thread reports when joined.
#[derive(Default)]
struct WriterTotals {
    frames: u64,
    bytes: u64,
    send_ns: u64,
    catch_up_frames: u64,
    full_syncs: u64,
}

/// Turn a budgeted writer's skip-gap into wire frames: the family
/// log's increments from `from_t + 1` (bit-identical to the originally
/// skipped broadcasts) or one full-state [`Message::Sync`] when the
/// log has evicted them. Returns the step the frames catch up to.
fn materialize_catch_up(log: &Mutex<UpdateLog>, from_t: u64) -> Result<(u64, Vec<Vec<u8>>, bool)> {
    let mut log = log.lock().unwrap();
    let to_t = log.t();
    Ok(match log.catch_up(from_t)? {
        CatchUp::Increments(incs) => {
            let frames = incs
                .into_iter()
                .map(|b| {
                    frame_bytes(&Message::Broadcast {
                        t: b.t,
                        absolute: b.absolute,
                        payload: b.msg.payload,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            (to_t, frames, false)
        }
        CatchUp::FullState { t, x_hat, .. } => {
            (t, vec![frame_bytes(&Message::Sync { t, x: x_hat })?], true)
        }
    })
}

impl Leader {
    pub fn new(cfg: Config, x0: Vec<f32>, seed: u64) -> Leader {
        Leader { cfg, x0, seed, record_events: false, resume: false }
    }

    /// Serve on `addr` (e.g. "127.0.0.1:7710"), wait for exactly
    /// `n_workers` workers, coordinate until a stop cap is hit, shut the
    /// workers down, and report.
    pub fn run(&self, addr: &str, n_workers: usize) -> Result<LeaderReport> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        self.run_on(listener, n_workers)
    }

    /// Like [`Leader::run`] with a pre-bound listener (lets tests use an
    /// ephemeral port).
    pub fn run_on(&self, listener: TcpListener, n_workers: usize) -> Result<LeaderReport> {
        let tel = &self.cfg.telemetry;
        if self.resume && tel.journal.is_none() {
            bail!("leader: resume needs telemetry.journal (the journal to resume from)");
        }
        // Spans cost one clock read per stage — turn them on whenever
        // the run is being observed (same policy as the simulator).
        if tel.journal.is_some() || tel.progress > 0 {
            telemetry::set_enabled(true);
        }
        // cfg.fl.shards > 1 turns on the shard-parallel aggregation
        // pipeline inside the server; the wire protocol is unchanged
        // (broadcast bytes are bit-identical for every shard count).
        let mut server = Server::build(&self.cfg, self.x0.clone(), self.seed)?;
        let d = server.d();
        if server.shards() > 1 {
            tracing_log(&format!("leader: sharded aggregation, S={}", server.shards()));
        }
        // Tier presets are registered up front in tier order, exactly as
        // the scenario engine does, so codec ids agree with a simulator
        // run of the same config. The downlink (`quant_server`) presets
        // become hidden-state families; both registries are fixed before
        // any state is restored or ingested.
        let tiers = self.cfg.resolved_tiers();
        let tier_codecs = server.register_tier_presets(&self.cfg)?;
        let tier_server_codecs = server.register_tier_server_presets(&self.cfg)?;
        // Partial-aggregate codec (leader-to-leader v2 frames): registered
        // up front from config so edges and root agree on registry id 0 —
        // registration order is the wire contract, as for client codecs.
        server.register_partial_codec(&self.cfg.net.partial_codec)?;
        // Adaptive controller (`net.adaptive`): the codec ladder is
        // registered up front — before any Hello negotiation or resume
        // replay — so every level's registry entry is in the journal
        // header and a mid-run Rekey never races a Codec event. The
        // registry dedups by resolved name, so ladder levels shared
        // with tier presets (or with each other) cost nothing. Sorted
        // by encoded size ascending: "one level down" = the next
        // cheaper entry.
        let adaptive = self.cfg.net.adaptive.clone();
        let mut ladder: Vec<(usize, String, u64)> = Vec::new(); // (id, name, bytes/upload)
        if adaptive.enabled {
            for spec in &adaptive.levels {
                let id = server.register_client_codec(spec)?;
                if !ladder.iter().any(|&(lid, ..)| lid == id) {
                    let name = server.client_codec_name(id);
                    let bytes = parse_spec(&name)?.expected_bytes(d) as u64;
                    ladder.push((id, name, bytes));
                }
            }
            ladder.sort_by_key(|&(_, _, b)| b);
        }
        let grace = Duration::from_millis(self.cfg.net.v1_grace_ms.max(1));

        // --- resume: cut the journal back to its last checkpoint and
        // restore the server saved there. The journal's surviving prefix
        // is real history, so the whole file (prefix + what this session
        // appends) still replays end-to-end through `replay_events`.
        let mut t_base = 0.0f64;
        if self.resume {
            let path = tel.journal.as_deref().unwrap();
            let prefix = truncate_after_last_checkpoint(path)?;
            let Some(Event::Meta { runtime, fingerprint, .. }) = prefix.first() else {
                bail!("journal '{path}' does not start with a meta event");
            };
            if runtime != "tcp" {
                bail!("journal '{path}' was recorded by runtime '{runtime}', not the TCP leader");
            }
            let want = telemetry::run_fingerprint(&self.cfg, self.seed);
            if *fingerprint != want {
                bail!(
                    "journal '{path}' was recorded under fingerprint {fingerprint}, but \
                     this config/seed fingerprints as {want} — resume with the original config"
                );
            }
            // Rebuild the codec registries exactly as replay does: the
            // config-derived registrations above dedup to their original
            // ids, dynamically negotiated ones re-register in journal
            // order.
            for ev in &prefix {
                if let Event::Codec { reg, id, spec } = ev {
                    let got = match reg.as_str() {
                        "client" => server.register_client_codec(spec)?,
                        "server" => server.register_server_codec(spec)?,
                        "partial" => server.register_partial_codec(spec)?,
                        other => bail!("journal '{path}': unknown codec registry '{other}'"),
                    } as u64;
                    if got != *id {
                        bail!(
                            "journal '{path}': codec '{spec}' registered as id {got}, journal \
                             says {id} — registration order diverged"
                        );
                    }
                }
            }
            let Some(Event::Checkpoint { state, .. }) = prefix.last() else {
                bail!("journal '{path}' has no checkpoint to resume from");
            };
            let server_state = state
                .get("server")
                .ok_or_else(|| anyhow!("journal '{path}': checkpoint lacks 'server' state"))?;
            server.restore_state(server_state)?;
            let wall = state
                .get("wall")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("journal '{path}': checkpoint lacks 'wall' time"))?;
            t_base = f64::from_bits(parse_hex_u64(wall)?);
            tracing_log(&format!(
                "leader: resumed from '{path}' at step {} (t={t_base:.3})",
                server.t()
            ));
        }
        // Codec ids at/above these are not yet in the journal (id 0 is
        // the implicit default in each registry; a resumed prefix covers
        // its own).
        let journaled_client = if self.resume { server.num_client_codecs() } else { 1 };
        let journaled_server = if self.resume { server.num_server_codecs() } else { 1 };
        let mut recorder = Recorder {
            writer: match (tel.journal.as_deref(), self.resume) {
                (Some(path), true) => Some(JournalWriter::append(path)?),
                (Some(path), false) => Some(JournalWriter::create(path)?),
                (None, _) => None,
            },
            mem: self.record_events.then(Vec::new),
        };
        let run_start = Instant::now();
        // What a joining worker copies as x^0: the shared hidden state —
        // bit-identical to the run's x^0 on a fresh start (x̂^0 = x^0),
        // the checkpointed snapshot after a resume, so a rejoining
        // replica tracks the broadcast stream from the resumed step.
        let x_join: Vec<f32> = server.client_snapshot().as_ref().clone();
        let join_step = server.t();

        // Budgeted fan-out state (`net.broadcast_budget_bytes > 0`):
        // one Appendix-B.1 UpdateLog per downlink family, seeded from
        // that family's hidden state at the join step and advanced by
        // the exact broadcast payloads (before they reach any queue, so
        // a writer's fold always covers every step it skipped). At the
        // default budget 0 none of this exists.
        let budget = self.cfg.net.broadcast_budget_bytes;
        let fold_logs: Option<Vec<Arc<Mutex<UpdateLog>>>> = if budget > 0 {
            Some(
                (0..server.num_server_codecs())
                    .map(|f| {
                        Arc::new(Mutex::new(UpdateLog::new_at(
                            server.family_snapshot(f).as_ref().clone(),
                            server.server_codec_bytes(f),
                            join_step,
                        )))
                    })
                    .collect(),
            )
        } else {
            None
        };
        let fold_codecs: Vec<Box<dyn Quantizer>> = if budget > 0 {
            (0..server.num_server_codecs())
                .map(|f| parse_spec(&server.server_codec_name(f)))
                .collect::<Result<_>>()?
        } else {
            Vec::new()
        };
        let fold_pool = server.pool().clone();

        // accept all workers, handshake each on its own thread, then
        // resolve codecs + send the join frame as each handshake lands
        let (tx, rx) = mpsc::channel::<(u32, Result<Option<Message>>)>();
        let (htx, hrx) = mpsc::channel::<Result<Handshake>>();
        let mut handshake_handles = Vec::new();
        for worker_id in 0..n_workers as u32 {
            let (stream, peer) = listener.accept().context("accepting worker")?;
            stream.set_nodelay(true).ok();
            let peer = peer.to_string();
            let htx = htx.clone();
            handshake_handles.push(std::thread::spawn(move || {
                let _ = htx.send(handshake(stream, worker_id, peer, grace));
            }));
        }
        drop(htx);
        // per-worker slots, indexed by worker id (handshakes complete
        // in any order)
        let mut queues: Vec<Option<(Arc<FrameQueue>, usize)>> = vec![None; n_workers];
        let mut writer_handles: Vec<Option<std::thread::JoinHandle<WriterTotals>>> =
            (0..n_workers).map(|_| None).collect();
        let mut reader_handles = Vec::new();
        let mut stats_slots: Vec<Option<WorkerStats>> = vec![None; n_workers];
        for _ in 0..n_workers {
            let hs = hrx.recv().map_err(|_| anyhow!("handshake threads gone"))??;
            let Handshake { worker_id, peer, mut reader, mut writer, hello } = hs;
            let wid = worker_id as usize;

            let mut bandwidth_hint: Option<f32> = None;
            let (protocol, codec_id, server_codec_id) = if let Some(h) = hello {
                let (version, tier, quant_client, hint) = h;
                bandwidth_hint = hint;
                // both ends run at the minimum version (decode already
                // guarantees version >= 2)
                let version = version.min(PROTOCOL_VERSION);
                // the tier resolves both directions: upload codec
                // (explicit override > tier preset > default) and the
                // downlink family (tier preset > default)
                let tier_idx = match tier {
                    Some(name) => match tiers.iter().position(|t| t.name == name) {
                        Some(i) => Some(i),
                        None => bail!(
                            "worker {worker_id} ({peer}): unknown tier '{name}' (known: {})",
                            tiers
                                .iter()
                                .map(|t| t.name.as_str())
                                .collect::<Vec<_>>()
                                .join(", ")
                        ),
                    },
                    None => None,
                };
                let codec_id = if let Some(spec) = quant_client {
                    server.register_client_codec(&spec).with_context(|| {
                        format!("worker {worker_id} ({peer}): bad quant_client '{spec}'")
                    })?
                } else if let Some(i) = tier_idx {
                    tier_codecs[i]
                } else {
                    0
                };
                let server_codec_id = tier_idx.map_or(0, |i| tier_server_codecs[i]);
                // family 0 keeps the raw config spec (what v2 always
                // sent); a preset family sends its resolved codec name
                let server_quant = if server_codec_id == 0 {
                    self.cfg.quant.server.clone()
                } else {
                    server.server_codec_name(server_codec_id)
                };
                write_msg(
                    &mut writer,
                    &Message::JoinV2 {
                        version,
                        worker_id,
                        d: d as u32,
                        x0: x_join.clone(),
                        client_quant: server.client_codec_name(codec_id),
                        server_quant,
                        client_lr: self.cfg.fl.client_lr,
                        codec_id: codec_id as u32,
                        server_codec_id: server_codec_id as u32,
                    },
                )
                .with_context(|| format!("sending JoinV2 to worker {worker_id} ({peer})"))?;
                (version, codec_id, server_codec_id)
            } else {
                // v1 worker: the legacy Join, bit-identical to the
                // pre-v2 protocol (pinned by a golden test)
                write_msg(
                    &mut writer,
                    &Message::Join {
                        worker_id,
                        d: d as u32,
                        x0: x_join.clone(),
                        client_quant: self.cfg.quant.client.clone(),
                        server_quant: self.cfg.quant.server.clone(),
                        client_lr: self.cfg.fl.client_lr,
                    },
                )
                .with_context(|| format!("sending Join to worker {worker_id} ({peer})"))?;
                (1u8, 0usize, 0usize)
            };

            // reader thread: a worker dying (EOF, reset) is a tolerable
            // disconnect, exactly as before v2; only *protocol*
            // violations — corrupt or oversized frames — are forwarded
            // as errors and abort the run with this worker's context
            let txc = tx.clone();
            reader_handles.push(std::thread::spawn(move || {
                loop {
                    match read_msg_classified(&mut reader) {
                        ReadOutcome::Msg(msg) => {
                            if txc.send((worker_id, Ok(Some(msg)))).is_err() {
                                break;
                            }
                        }
                        ReadOutcome::Disconnected(_) => {
                            let _ = txc.send((worker_id, Ok(None)));
                            break;
                        }
                        ReadOutcome::BadFrame(e) => {
                            let _ = txc.send((worker_id, Err(e)));
                            break;
                        }
                    }
                }
            }));

            // persistent writer thread: its own bounded outbound queue,
            // frames pre-encoded and shared; returns what it actually
            // wrote (and the span-gated wall time spent writing it).
            // v1 peers predate the Sync frame, so they keep an
            // unbudgeted queue (budget 0) and never see a fold.
            let queue = FrameQueue::new(if protocol >= 2 { budget } else { 0 });
            let fold_log = if protocol >= 2 {
                fold_logs.as_ref().map(|logs| logs[server_codec_id].clone())
            } else {
                None
            };
            let q = Arc::clone(&queue);
            writer_handles[wid] = Some(std::thread::spawn(move || {
                let mut tot = WriterTotals::default();
                // last step this connection was brought up to (join
                // frames carry the hidden state at join_step)
                let mut last_sent = join_step;
                'writer: while let Some(item) = q.pop() {
                    let frame: Arc<[u8]> = match item {
                        QueuedFrame::Control(frame) => frame,
                        QueuedFrame::Step { t, frame } => {
                            if let Some(log) = &fold_log {
                                if t <= last_sent {
                                    continue; // covered by an earlier fold
                                }
                                if t > last_sent + 1 {
                                    // the queue evicted frames: fold the
                                    // gap (this popped frame included —
                                    // the log holds its exact payload)
                                    let Ok((to_t, frames, full)) =
                                        materialize_catch_up(log, last_sent)
                                    else {
                                        break 'writer;
                                    };
                                    for f in &frames {
                                        let timer = telemetry::span_start();
                                        if writer.write_all(f).is_err() {
                                            break 'writer;
                                        }
                                        tot.send_ns += telemetry::span_ns(timer);
                                        tot.frames += 1;
                                        tot.bytes += f.len() as u64;
                                        tot.catch_up_frames += 1;
                                    }
                                    if full {
                                        tot.full_syncs += 1;
                                    }
                                    last_sent = to_t;
                                    continue;
                                }
                                last_sent = t;
                            }
                            frame
                        }
                    };
                    let timer = telemetry::span_start();
                    if writer.write_all(&frame).is_err() {
                        break; // dead worker: its reader thread reports it
                    }
                    tot.send_ns += telemetry::span_ns(timer);
                    tot.frames += 1;
                    tot.bytes += frame.len() as u64;
                }
                tot
            }));
            queues[wid] = Some((queue, server_codec_id));

            tracing_log(&format!(
                "leader: worker {worker_id} joined from {peer} (protocol v{protocol}, \
                 codec '{}', downlink '{}')",
                server.client_codec_name(codec_id),
                server.server_codec_name(server_codec_id)
            ));
            stats_slots[wid] = Some(WorkerStats {
                worker_id,
                peer,
                protocol,
                codec_id,
                codec: server.client_codec_name(codec_id),
                bandwidth_hint,
                rekeys: 0,
                epochs: vec![CodecEpoch {
                    codec_id,
                    codec: server.client_codec_name(codec_id),
                    uploads: 0,
                    upload_bytes: 0,
                }],
                server_codec_id,
                server_codec: server.server_codec_name(server_codec_id),
                uploads: 0,
                upload_bytes: 0,
                partials: 0,
                broadcast_frames: 0,
                broadcast_bytes: 0,
                skipped_broadcasts: 0,
                catch_up_frames: 0,
                full_syncs: 0,
                ingest_ns: 0,
                send_ns: 0,
                staleness: StalenessHist::default(),
                clipped_updates: 0,
                trimmed_updates: 0,
            });
        }
        drop(tx);
        for h in handshake_handles {
            let _ = h.join();
        }
        let mut stats: Vec<WorkerStats> =
            stats_slots.into_iter().map(|s| s.expect("all worker slots filled")).collect();
        let queues: Vec<(Arc<FrameQueue>, usize)> =
            queues.into_iter().map(|q| q.expect("all worker slots filled")).collect();

        // every codec is registered once the accept loop is done, so the
        // journal header (meta, init, codec registry) goes out before
        // the first ingest — the order replay demands
        if recorder.on() {
            if !self.resume {
                recorder.emit(Event::Meta {
                    runtime: "tcp".into(),
                    algorithm: self.cfg.fl.algorithm.name().to_string(),
                    d: d as u64,
                    seed: self.seed,
                    fingerprint: telemetry::run_fingerprint(&self.cfg, self.seed),
                    git: telemetry::git_describe(),
                    config: self.cfg.to_json(),
                })?;
                recorder.emit(Event::Init { x0: self.x0.clone(), server_seed: self.seed })?;
            }
            for id in journaled_client..server.num_client_codecs() {
                recorder.emit(Event::Codec {
                    reg: "client".into(),
                    id: id as u64,
                    spec: server.client_codec_name(id),
                })?;
            }
            for id in journaled_server..server.num_server_codecs() {
                recorder.emit(Event::Codec {
                    reg: "server".into(),
                    id: id as u64,
                    spec: server.server_codec_name(id),
                })?;
            }
            if !self.resume {
                recorder.emit(Event::Codec {
                    reg: "partial".into(),
                    id: 0,
                    spec: server.partial_codec_name(0),
                })?;
            }
        }

        // main coordination loop
        let mut live = n_workers;
        let mut byes = 0usize;
        let mut shutdown_sent = false;
        // Adaptive-controller state: per-worker transition windows (old
        // codec ids whose in-flight uploads are still accepted after a
        // Rekey, cleared on the first upload tagged with the current
        // id — frames are ordered per connection, so once the new tag
        // arrives no older-tagged frame can follow) and the per-window
        // upload/byte counters the policy scores and projects from.
        let mut transition: Vec<Vec<usize>> = vec![Vec::new(); n_workers];
        let mut win_uploads: Vec<u64> = vec![0; n_workers];
        let mut win_bytes: Vec<u64> = vec![0; n_workers];
        // journal step/progress state: slots since the last step (the
        // Step event's k), the run-wide staleness histogram quantiles on
        // the progress line draw from, the previous Step event (deltas)
        let mut slots_since_step: u64 = 0;
        let mut hist_all = StalenessHist::default();
        let mut prev_step_ev: Option<Event> = None;
        // robust-aggregation attribution: which worker fed each live
        // buffer row, zipped against the server's per-row trim flags
        // when a step fires. Flat uploads only — `ingest_partial`
        // rejects trimming, so with trim on every row is an Update.
        let trim_on = self.cfg.fl.robust.trim_enabled();
        let mut buffer_workers: Vec<usize> = Vec::new();
        while live > 0 {
            let (worker_id, incoming) = rx.recv().map_err(|_| anyhow!("all workers gone"))?;
            let wid = worker_id as usize;
            let msg = match incoming {
                Ok(Some(m)) => m,
                Ok(None) => {
                    live -= 1;
                    continue;
                }
                Err(e) => {
                    // only reachable for protocol violations (corrupt
                    // frames); transport-level disconnects arrive as
                    // Ok(None) and are tolerated above
                    if shutdown_sent {
                        live -= 1;
                        continue;
                    }
                    return Err(e.context(format!(
                        "reading from worker {worker_id} ({})",
                        stats[wid].peer
                    )));
                }
            };
            // normalize v1/v2 uploads and edge partials into one
            // registry-routed ingest
            enum Inbound {
                Update { t_start: u64, codec_id: usize, payload: Vec<u8> },
                Partial { codec_id: usize, count: u32, hist: StalenessHist, payload: Vec<u8> },
            }
            let inbound = match msg {
                Message::Update { t_start, payload, .. } => {
                    Inbound::Update { t_start, codec_id: 0, payload }
                }
                Message::UpdateV2 { t_start, codec_id, payload, .. } => {
                    Inbound::Update { t_start, codec_id: codec_id as usize, payload }
                }
                Message::UpdatePartial {
                    codec_id,
                    count,
                    stale_counts,
                    stale_sum,
                    stale_max,
                    stale_n,
                    payload,
                    ..
                } => Inbound::Partial {
                    codec_id: codec_id as usize,
                    count,
                    hist: StalenessHist::from_parts(stale_counts, stale_sum, stale_max, stale_n),
                    payload,
                },
                Message::Bye { worker_id: wid2, uploads } => {
                    byes += 1;
                    tracing_log(&format!("leader: worker {wid2} done ({uploads} uploads)"));
                    continue;
                }
                other => {
                    tracing_log(&format!(
                        "leader: unexpected message from worker {worker_id}: {other:?}"
                    ));
                    continue;
                }
            };
            if shutdown_sent {
                continue; // late update after shutdown: drop
            }
            let now = t_base + run_start.elapsed().as_secs_f64();
            let step = match inbound {
                Inbound::Update { t_start, codec_id, payload } => {
                    // the tag must be the codec this connection negotiated
                    // (at join or via the latest Rekey): two registered
                    // codecs can share a wire size at some d, so accepting
                    // a mismatched (even registered) id could silently
                    // mis-decode into the aggregation buffer — and
                    // per-worker accounting is keyed by the negotiated
                    // codec. During a Rekey transition window, uploads
                    // still tagged with a superseded id are in flight
                    // from before the worker saw the frame and stay
                    // accepted until the first current-id upload cuts
                    // the window over.
                    if codec_id != stats[wid].codec_id {
                        if !transition[wid].contains(&codec_id) {
                            bail!(
                                "worker {worker_id} ({}): upload tagged codec id {codec_id}, but \
                                 this connection negotiated codec id {} ('{}')",
                                stats[wid].peer,
                                stats[wid].codec_id,
                                stats[wid].codec
                            );
                        }
                    } else if !transition[wid].is_empty() {
                        // cutover: the worker switched — per-connection
                        // frame order guarantees no older-tagged upload
                        // can still arrive
                        transition[wid].clear();
                    }
                    let qmsg = QuantizedMsg { payload, d };
                    let wire = qmsg.wire_bytes();
                    // a worker's snapshot can never predate its join-time
                    // model (the checkpointed x̂ after a resume), so its
                    // t_start is floored there — a no-op on fresh runs
                    // where join_step is 0
                    let staleness = server.t().saturating_sub(t_start.max(join_step));
                    if recorder.on() {
                        recorder.emit(Event::Ingest {
                            time: now,
                            step: server.t(),
                            worker: worker_id as u64,
                            codec: codec_id as u64,
                            staleness,
                            payload: qmsg.payload.clone(),
                        })?;
                    }
                    if trim_on {
                        buffer_workers.push(wid);
                    }
                    let timer = telemetry::span_start();
                    let step =
                        server.ingest_from(&qmsg, staleness, codec_id).with_context(|| {
                            format!(
                                "ingesting upload from worker {worker_id} ({}, codec '{}')",
                                stats[wid].peer,
                                server.client_codec_name(codec_id)
                            )
                        })?;
                    stats[wid].ingest_ns += telemetry::span_ns(timer);
                    if server.last_ingest_clipped() {
                        stats[wid].clipped_updates += 1;
                    }
                    stats[wid].uploads += 1;
                    stats[wid].upload_bytes += wire as u64;
                    // per-epoch attribution: the current epoch, or —
                    // for an in-flight old-codec upload — the most
                    // recent earlier epoch that used this codec
                    let ep = if codec_id == stats[wid].codec_id {
                        stats[wid].epochs.len() - 1
                    } else {
                        stats[wid]
                            .epochs
                            .iter()
                            .rposition(|e| e.codec_id == codec_id)
                            .expect("transition window ids always have an epoch")
                    };
                    stats[wid].epochs[ep].uploads += 1;
                    stats[wid].epochs[ep].upload_bytes += wire as u64;
                    win_uploads[wid] += 1;
                    win_bytes[wid] += wire as u64;
                    stats[wid].staleness.record(staleness);
                    hist_all.record(staleness);
                    slots_since_step += 1;
                    step
                }
                Inbound::Partial { codec_id, count, hist, payload } => {
                    // an edge leader forwarding its buffer: staleness was
                    // weighted downstream, the histogram travels for
                    // accounting and is merged here
                    let qmsg = QuantizedMsg { payload, d };
                    let wire = qmsg.wire_bytes();
                    if recorder.on() {
                        recorder.emit(Event::IngestPartial {
                            time: now,
                            step: server.t(),
                            worker: worker_id as u64,
                            codec: codec_id as u64,
                            count: u64::from(count),
                            stale_counts: hist.counts.clone(),
                            stale_sum: hist.sum,
                            stale_max: hist.max,
                            stale_n: hist.n,
                            payload: qmsg.payload.clone(),
                        })?;
                    }
                    let timer = telemetry::span_start();
                    let step = server
                        .ingest_partial(&qmsg, count, &hist, codec_id)
                        .with_context(|| {
                            format!(
                                "ingesting partial aggregate from edge {worker_id} ({})",
                                stats[wid].peer
                            )
                        })?;
                    stats[wid].ingest_ns += telemetry::span_ns(timer);
                    stats[wid].uploads += 1;
                    stats[wid].upload_bytes += wire as u64;
                    stats[wid].partials += 1;
                    stats[wid].codec = server.partial_codec_name(codec_id);
                    stats[wid].staleness.merge(&hist);
                    hist_all.merge(&hist);
                    slots_since_step += u64::from(count);
                    step
                }
            };

            if let ServerStep::Stepped(broadcasts) = step {
                for (&w, &flagged) in buffer_workers.iter().zip(server.last_trim_flags()) {
                    if flagged {
                        stats[w].trimmed_updates += 1;
                    }
                }
                buffer_workers.clear();
                if recorder.on() || tel.progress > 0 {
                    let step_ev = Event::Step {
                        time: now,
                        step: server.t(),
                        k: slots_since_step,
                        uploads: server.comm.uploads,
                        upload_bytes: server.comm.upload_bytes,
                        broadcast_bytes: server.comm.broadcast_bytes,
                        stale_mean: server.staleness_mean(),
                        stale_max: server.staleness_max,
                        stages: telemetry::enabled().then(|| server.stage_timings().clone()),
                    };
                    if recorder.on() {
                        recorder.emit(step_ev.clone())?;
                        for b in &broadcasts {
                            recorder.emit(Event::Broadcast {
                                time: now,
                                step: b.t,
                                absolute: b.absolute,
                                codec: b.codec as u64,
                                payload: b.msg.payload.clone(),
                            })?;
                        }
                    }
                    if tel.progress > 0 && server.t() % tel.progress == 0 {
                        if let Some(line) =
                            progress_line(&step_ev, prev_step_ev.as_ref(), &hist_all)
                        {
                            eprintln!("[qafel] {line}");
                        }
                    }
                    prev_step_ev = Some(step_ev);
                }
                slots_since_step = 0;
                if tel.checkpoint_every > 0 && server.t() % tel.checkpoint_every == 0 {
                    let state = Json::obj(vec![
                        ("wall", Json::str(hex_u64(now.to_bits()))),
                        ("server", server.state_json()),
                    ]);
                    recorder.emit(Event::Checkpoint {
                        time: now,
                        step: server.t(),
                        state,
                    })?;
                }
                // one frame per downlink family, encoded once and shared
                // with every writer queue of that family. Budgeted runs
                // push into the family's UpdateLog FIRST: a writer that
                // later finds a gap is guaranteed the log covers every
                // step up to (at least) the frame it popped.
                for b in broadcasts {
                    let (t, absolute, fam) = (b.t, b.absolute, b.codec);
                    let frame: Arc<[u8]> = if let Some(logs) = &fold_logs {
                        let frame = frame_bytes(&Message::Broadcast {
                            t,
                            absolute,
                            payload: b.msg.payload.clone(),
                        })?;
                        logs[fam]
                            .lock()
                            .unwrap()
                            .push_quantized(b, fold_codecs[fam].as_ref(), &fold_pool)
                            .context("advancing the downlink catch-up log")?;
                        frame.into()
                    } else {
                        frame_bytes(&Message::Broadcast { t, absolute, payload: b.msg.payload })?
                            .into()
                    };
                    for (q, q_fam) in &queues {
                        if *q_fam == fam {
                            q.push_step(t, frame.clone());
                        }
                    }
                }

                // Adaptive-quantization controller: every `interval`
                // steps, project the next window's uplink traffic from
                // the window just observed and walk the slowest
                // workers down the ladder until it fits the budget.
                if adaptive.enabled
                    && !ladder.is_empty()
                    && server.t() % adaptive.interval == 0
                {
                    let interval = adaptive.interval as f64;
                    // Eligible for a switch: plain v2 workers (edges
                    // forward partials and never rekey; v1 peers
                    // predate the frame) with enough window uploads to
                    // score and no transition still in flight. Score:
                    // the announced bandwidth hint when given, else
                    // the observed window upload rate — lower score =
                    // first to downshift.
                    let mut eligible: Vec<(usize, f64)> = Vec::new();
                    for (w, s) in stats.iter().enumerate() {
                        if s.protocol < 2 || s.partials > 0 || !transition[w].is_empty() {
                            continue;
                        }
                        if win_uploads[w] < adaptive.min_uploads.max(1) {
                            continue;
                        }
                        let score = match s.bandwidth_hint {
                            Some(h) => f64::from(h),
                            None => win_uploads[w] as f64 / interval,
                        };
                        eligible.push((w, score));
                    }
                    // Projected bytes/step if nothing changes: what
                    // each worker actually shipped over the window.
                    // Every worker counts toward the projection (the
                    // budget is global), movable or not.
                    let mut rate: Vec<f64> = vec![0.0; n_workers];
                    let mut bytes_now: Vec<u64> = vec![0; n_workers];
                    let mut projected = 0.0f64;
                    for w in 0..n_workers {
                        rate[w] = win_uploads[w] as f64 / interval;
                        bytes_now[w] = if win_uploads[w] > 0 {
                            win_bytes[w] / win_uploads[w]
                        } else {
                            0
                        };
                        projected += win_bytes[w] as f64 / interval;
                    }
                    // Greedy: move the lowest-scored movable worker one
                    // ladder level down (the largest entry strictly
                    // cheaper than its current codec), cycling until
                    // the projection fits or everyone is at the bottom.
                    let mut switches: Vec<(usize, usize)> = Vec::new(); // (wid, ladder idx)
                    let budget = adaptive.budget_bytes_per_step as f64;
                    while projected > budget {
                        let mut pick: Option<(usize, f64, usize)> = None; // (wid, score, idx)
                        for &(w, score) in &eligible {
                            let cur = switches
                                .iter()
                                .rev()
                                .find(|&&(sw, _)| sw == w)
                                .map(|&(_, idx)| ladder[idx].2)
                                .unwrap_or(bytes_now[w]);
                            let Some(down) =
                                ladder.iter().rposition(|&(_, _, b)| b < cur)
                            else {
                                continue; // already at (or below) the bottom
                            };
                            if pick.map_or(true, |(_, best, _)| score < best) {
                                pick = Some((w, score, down));
                            }
                        }
                        let Some((w, _, idx)) = pick else { break };
                        let cur = switches
                            .iter()
                            .rev()
                            .find(|&&(sw, _)| sw == w)
                            .map(|&(_, i)| ladder[i].2)
                            .unwrap_or(bytes_now[w]);
                        projected -= rate[w] * (cur - ladder[idx].2) as f64;
                        switches.retain(|&(sw, _)| sw != w);
                        switches.push((w, idx));
                    }
                    for (w, idx) in switches {
                        let (new_id, ref name, _) = ladder[idx];
                        let old_id = stats[w].codec_id;
                        if new_id == old_id {
                            continue;
                        }
                        if recorder.on() {
                            recorder.emit(Event::Rekey {
                                time: now,
                                step: server.t(),
                                worker: w as u64,
                                old: old_id as u64,
                                new: new_id as u64,
                                spec: name.clone(),
                            })?;
                        }
                        let frame: Arc<[u8]> = frame_bytes(&Message::Rekey {
                            worker_id: w as u32,
                            codec_id: new_id as u32,
                            spec: name.clone(),
                            t: server.t(),
                        })?
                        .into();
                        queues[w].0.push_control(frame);
                        transition[w].push(old_id);
                        stats[w].codec_id = new_id;
                        stats[w].codec = name.clone();
                        stats[w].rekeys += 1;
                        stats[w].epochs.push(CodecEpoch {
                            codec_id: new_id,
                            codec: name.clone(),
                            uploads: 0,
                            upload_bytes: 0,
                        });
                        tracing_log(&format!(
                            "leader: rekeyed worker {w} to '{name}' (codec id {new_id}) at \
                             step {}",
                            server.t()
                        ));
                    }
                    // fresh observation window
                    win_uploads.iter_mut().for_each(|v| *v = 0);
                    win_bytes.iter_mut().for_each(|v| *v = 0);
                }
            }
            if server.t() >= self.cfg.stop.max_server_steps
                || server.comm.uploads >= self.cfg.stop.max_uploads
            {
                let frame: Arc<[u8]> = frame_bytes(&Message::Shutdown)?.into();
                for (q, _) in &queues {
                    q.push_control(frame.clone());
                }
                shutdown_sent = true;
            }
        }
        // shutdown: close the outbound queues, join the writer threads
        // (collecting what each actually wrote), then the readers
        for (q, _) in &queues {
            q.close();
        }
        for (i, h) in writer_handles.into_iter().enumerate() {
            if let Ok(tot) = h.expect("all worker slots filled").join() {
                stats[i].broadcast_frames = tot.frames;
                stats[i].broadcast_bytes = tot.bytes;
                stats[i].send_ns = tot.send_ns;
                stats[i].catch_up_frames = tot.catch_up_frames;
                stats[i].full_syncs = tot.full_syncs;
            }
            stats[i].skipped_broadcasts = queues[i].0.skipped();
            if stats[i].skipped_broadcasts > 0 {
                tracing_log(&format!(
                    "leader: worker {i} fell behind — {} broadcasts folded into {} catch-up \
                     frames ({} full syncs)",
                    stats[i].skipped_broadcasts, stats[i].catch_up_frames, stats[i].full_syncs
                ));
            }
        }
        for h in reader_handles {
            let _ = h.join();
        }
        let _ = byes;

        if recorder.on() {
            recorder.emit(Event::Final {
                step: server.t(),
                uploads: server.comm.uploads,
                upload_bytes: server.comm.upload_bytes,
                broadcasts: server.comm.broadcasts,
                broadcast_bytes: server.comm.broadcast_bytes,
                model: server.model().to_vec(),
            })?;
        }

        Ok(LeaderReport {
            comm: server.comm.clone(),
            server_steps: server.t(),
            staleness_max: server.staleness_max,
            staleness_mean: server.staleness_mean(),
            model: server.model().to_vec(),
            workers: n_workers,
            worker_stats: stats,
            stage_timings: server.stage_timings().clone(),
            fingerprint: telemetry::run_fingerprint(&self.cfg, self.seed),
            events: recorder.mem,
        })
    }
}

fn tracing_log(msg: &str) {
    if std::env::var("QAFEL_NET_LOG").is_ok() {
        eprintln!("{msg}");
    }
}
