//! The leader process: accepts workers, runs Algorithm 1 over TCP.
//!
//! **Protocol negotiation** (wire v2, see `net/message.rs`): v2 workers
//! speak first with `Hello`; a v1 worker connects silently and waits
//! for `Join`, so the leader classifies a connection that stays silent
//! for `net.v1_grace_ms` as v1 and serves it the legacy frames
//! bit-identically. Each v2 worker's upload codec is resolved from its
//! `Hello` (explicit `quant_client` override, else its tier's
//! `scenario.tiers.<name>.quant_client` preset, else the default) and
//! registered in the server's codec registry; every `UpdateV2` is then
//! routed by its `codec_id` through [`Server::ingest_from`] — no
//! payload-size guessing, no ambiguous-size failure mode.
//!
//! **Broadcast fan-out**: one persistent writer thread per worker with
//! its own outbound queue. Each broadcast frame is encoded exactly once
//! and shared as `Arc<[u8]>`, so a slow or dead worker can never stall
//! the step loop; writers are joined on shutdown (like `ShardPool`
//! workers) and report the bytes they actually put on the wire, which
//! feeds the per-worker accounting in [`LeaderReport`].
//!
//! **Flight recorder** (ARCHITECTURE.md §Telemetry): with
//! `telemetry.journal` set the leader streams the same typed
//! [`Event`] vocabulary the simulator writes — `Meta`/`Init`/`Codec`,
//! one `Ingest`/`IngestPartial` per upload that reached the server,
//! `Step` + `Broadcast` per committed step, `Checkpoint` every
//! `telemetry.checkpoint_every` steps, and a closing `Final`. Because
//! the journal records what *reached the server* in arrival order,
//! [`crate::telemetry::replay_events`] reproduces the run's broadcasts
//! bit-exactly even though TCP delivery itself is nondeterministic.
//! [`Leader::resume`] restores the server from the journal's last
//! checkpoint and appends; rejoining workers receive the checkpointed
//! hidden state as their x^0 and pick up the broadcast stream at the
//! resumed step (their uploads are staleness-floored at the join step).

use super::message::{Message, PROTOCOL_VERSION};
use super::transport::{frame_bytes, read_msg, read_msg_classified, write_msg, ReadOutcome};
use crate::config::Config;
use crate::coordinator::{Server, ServerStep};
use crate::metrics::CommMetrics;
use crate::quant::QuantizedMsg;
use crate::scenario::StalenessHist;
use crate::telemetry::event::{hex_u64, parse_hex_u64};
use crate::telemetry::{
    self, progress_line, truncate_after_last_checkpoint, Event, JournalWriter, StageTimings,
};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{ErrorKind, Write};
use std::net::TcpListener;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-worker accounting, mirroring the simulator's per-tier
/// [`crate::scenario::TierMetrics`]: what each connection uploaded,
/// what was actually written to it, and the staleness it produced.
#[derive(Clone, Debug)]
pub struct WorkerStats {
    pub worker_id: u32,
    /// Peer address the worker connected from.
    pub peer: String,
    /// Negotiated protocol version (1 = legacy silent join, 2 = Hello
    /// handshake with per-worker codec).
    pub protocol: u8,
    /// The worker's upload codec in the server registry (0 = default).
    pub codec_id: usize,
    /// Resolved spec name of that codec (e.g. `"top:0.1"`).
    pub codec: String,
    /// Ingested uploads from this worker (late post-shutdown uploads are
    /// dropped and not counted, matching the server's totals).
    pub uploads: u64,
    /// Sum of the ingested upload payload bytes, as counted off the
    /// wire frames (not derived from the codec formula).
    pub upload_bytes: u64,
    /// Of `uploads`, how many were `UpdatePartial` frames from a
    /// downstream edge leader (0 for plain workers). When non-zero,
    /// `codec` is the partial codec `Q_p` the frames were decoded with.
    pub partials: u64,
    /// Frames this worker's writer thread actually wrote (broadcasts +
    /// the shutdown frame; the join frame is written before the writer
    /// thread starts).
    pub broadcast_frames: u64,
    /// Bytes this worker's writer thread actually wrote.
    pub broadcast_bytes: u64,
    /// Wall time spent decoding + aggregating this worker's uploads
    /// (the leader-side recv cost). Captured only while telemetry spans
    /// are on ([`telemetry::set_enabled`]); zero otherwise.
    pub ingest_ns: u64,
    /// Wall time this worker's writer thread spent in socket writes
    /// (the leader-side send cost). Span-gated like `ingest_ns`.
    pub send_ns: u64,
    /// Staleness histogram over this worker's ingested uploads.
    pub staleness: StalenessHist,
}

/// Final report of a leader run.
#[derive(Clone, Debug)]
pub struct LeaderReport {
    pub comm: CommMetrics,
    pub server_steps: u64,
    pub staleness_max: u64,
    pub staleness_mean: f64,
    /// Final server model x^T.
    pub model: Vec<f32>,
    pub workers: usize,
    /// Per-worker byte/staleness accounting, indexed by worker id.
    pub worker_stats: Vec<WorkerStats>,
    /// Cumulative per-stage server-step timings (span-gated; `steps`
    /// always counts).
    pub stage_timings: StageTimings,
    /// [`telemetry::run_fingerprint`] of (resolved config, seed).
    pub fingerprint: String,
    /// The run's full event stream, present when
    /// [`Leader::record_events`] was set: the same typed events a
    /// journal file would hold, replayable via
    /// [`crate::telemetry::replay_events`].
    pub events: Option<Vec<Event>>,
}

/// Leader configuration + run loop.
pub struct Leader {
    cfg: Config,
    x0: Vec<f32>,
    seed: u64,
    /// Collect the run's journal events in memory into
    /// [`LeaderReport::events`] (tests: replay without a journal file).
    /// Fresh runs only — a resumed run's prefix lives in the file, so
    /// the in-memory slice alone would not replay. Default off.
    pub record_events: bool,
    /// Resume from `telemetry.journal`: truncate it to its last
    /// `Checkpoint`, restore the server state saved there, and append.
    /// Default off.
    pub resume: bool,
}

/// Fan-in sink for journal events: a file writer (the `--journal`
/// path), an in-memory buffer ([`Leader::record_events`]), or both.
struct Recorder {
    writer: Option<JournalWriter>,
    mem: Option<Vec<Event>>,
}

impl Recorder {
    fn on(&self) -> bool {
        self.writer.is_some() || self.mem.is_some()
    }

    fn emit(&mut self, ev: Event) -> Result<()> {
        if let Some(w) = self.writer.as_mut() {
            w.write(&ev)?;
        }
        if let Some(v) = self.mem.as_mut() {
            v.push(ev);
        }
        Ok(())
    }
}

impl Leader {
    pub fn new(cfg: Config, x0: Vec<f32>, seed: u64) -> Leader {
        Leader { cfg, x0, seed, record_events: false, resume: false }
    }

    /// Serve on `addr` (e.g. "127.0.0.1:7710"), wait for exactly
    /// `n_workers` workers, coordinate until a stop cap is hit, shut the
    /// workers down, and report.
    pub fn run(&self, addr: &str, n_workers: usize) -> Result<LeaderReport> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        self.run_on(listener, n_workers)
    }

    /// Like [`Leader::run`] with a pre-bound listener (lets tests use an
    /// ephemeral port).
    pub fn run_on(&self, listener: TcpListener, n_workers: usize) -> Result<LeaderReport> {
        let tel = &self.cfg.telemetry;
        if self.resume && tel.journal.is_none() {
            bail!("leader: resume needs telemetry.journal (the journal to resume from)");
        }
        // Spans cost one clock read per stage — turn them on whenever
        // the run is being observed (same policy as the simulator).
        if tel.journal.is_some() || tel.progress > 0 {
            telemetry::set_enabled(true);
        }
        // cfg.fl.shards > 1 turns on the shard-parallel aggregation
        // pipeline inside the server; the wire protocol is unchanged
        // (broadcast bytes are bit-identical for every shard count).
        let mut server = Server::build(&self.cfg, self.x0.clone(), self.seed)?;
        let d = server.d();
        if server.shards() > 1 {
            tracing_log(&format!("leader: sharded aggregation, S={}", server.shards()));
        }
        // Tier presets are registered up front in tier order, exactly as
        // the scenario engine does, so codec ids agree with a simulator
        // run of the same config.
        let tiers = self.cfg.resolved_tiers();
        let tier_codecs = server.register_tier_presets(&self.cfg)?;
        // Partial-aggregate codec (leader-to-leader v2 frames): registered
        // up front from config so edges and root agree on registry id 0 —
        // registration order is the wire contract, as for client codecs.
        server.register_partial_codec(&self.cfg.net.partial_codec)?;
        let grace = Duration::from_millis(self.cfg.net.v1_grace_ms.max(1));

        // --- resume: cut the journal back to its last checkpoint and
        // restore the server saved there. The journal's surviving prefix
        // is real history, so the whole file (prefix + what this session
        // appends) still replays end-to-end through `replay_events`.
        let mut t_base = 0.0f64;
        if self.resume {
            let path = tel.journal.as_deref().unwrap();
            let prefix = truncate_after_last_checkpoint(path)?;
            let Some(Event::Meta { runtime, fingerprint, .. }) = prefix.first() else {
                bail!("journal '{path}' does not start with a meta event");
            };
            if runtime != "tcp" {
                bail!("journal '{path}' was recorded by runtime '{runtime}', not the TCP leader");
            }
            let want = telemetry::run_fingerprint(&self.cfg, self.seed);
            if *fingerprint != want {
                bail!(
                    "journal '{path}' was recorded under fingerprint {fingerprint}, but \
                     this config/seed fingerprints as {want} — resume with the original config"
                );
            }
            // Rebuild the codec registries exactly as replay does: the
            // config-derived registrations above dedup to their original
            // ids, dynamically negotiated ones re-register in journal
            // order.
            for ev in &prefix {
                if let Event::Codec { reg, id, spec } = ev {
                    let got = match reg.as_str() {
                        "client" => server.register_client_codec(spec)?,
                        "partial" => server.register_partial_codec(spec)?,
                        other => bail!("journal '{path}': unknown codec registry '{other}'"),
                    } as u64;
                    if got != *id {
                        bail!(
                            "journal '{path}': codec '{spec}' registered as id {got}, journal \
                             says {id} — registration order diverged"
                        );
                    }
                }
            }
            let Some(Event::Checkpoint { state, .. }) = prefix.last() else {
                bail!("journal '{path}' has no checkpoint to resume from");
            };
            let server_state = state
                .get("server")
                .ok_or_else(|| anyhow!("journal '{path}': checkpoint lacks 'server' state"))?;
            server.restore_state(server_state)?;
            let wall = state
                .get("wall")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("journal '{path}': checkpoint lacks 'wall' time"))?;
            t_base = f64::from_bits(parse_hex_u64(wall)?);
            tracing_log(&format!(
                "leader: resumed from '{path}' at step {} (t={t_base:.3})",
                server.t()
            ));
        }
        // Client-codec ids at/above this are not yet in the journal (id 0
        // is the implicit default; a resumed prefix covers its own).
        let journaled_client = if self.resume { server.num_client_codecs() } else { 1 };
        let mut recorder = Recorder {
            writer: match (tel.journal.as_deref(), self.resume) {
                (Some(path), true) => Some(JournalWriter::append(path)?),
                (Some(path), false) => Some(JournalWriter::create(path)?),
                (None, _) => None,
            },
            mem: self.record_events.then(Vec::new),
        };
        let run_start = Instant::now();
        // What a joining worker copies as x^0: the shared hidden state —
        // bit-identical to the run's x^0 on a fresh start (x̂^0 = x^0),
        // the checkpointed snapshot after a resume, so a rejoining
        // replica tracks the broadcast stream from the resumed step.
        let x_join: Vec<f32> = server.client_snapshot().as_ref().clone();
        let join_step = server.t();

        // accept all workers: negotiate the protocol, send the join
        // frame, then spawn one reader and one writer thread each
        let (tx, rx) = mpsc::channel::<(u32, Result<Option<Message>>)>();
        let mut writers: Vec<mpsc::Sender<Arc<[u8]>>> = Vec::new();
        let mut writer_handles = Vec::new();
        let mut reader_handles = Vec::new();
        let mut stats: Vec<WorkerStats> = Vec::new();
        for worker_id in 0..n_workers as u32 {
            let (stream, peer) = listener.accept().context("accepting worker")?;
            stream.set_nodelay(true).ok();
            let peer = peer.to_string();

            // v2 workers send Hello immediately on connect; a v1 worker
            // waits silently for Join. Peek (never consume) with a
            // bounded timeout to classify the peer without corrupting
            // the stream.
            stream
                .set_read_timeout(Some(grace))
                .with_context(|| format!("worker {worker_id} ({peer}): handshake timeout"))?;
            let mut probe = [0u8; 1];
            let spoke = match stream.peek(&mut probe) {
                Ok(n) => n > 0,
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => false,
                Err(e) => {
                    return Err(e)
                        .with_context(|| format!("probing worker {worker_id} ({peer})"));
                }
            };
            // the read timeout stays armed through the Hello read: a
            // peer that sends a partial frame and stalls fails the
            // handshake loudly instead of wedging the serial accept
            // loop; it is cleared below before the reader thread (which
            // must block indefinitely) takes over
            let mut reader = stream.try_clone().context("cloning tcp stream")?;
            let mut writer = stream;

            let (protocol, codec_id) = if spoke {
                let hello = read_msg(&mut reader)
                    .with_context(|| {
                        format!(
                            "reading Hello from worker {worker_id} ({peer}) \
                             within the {}ms handshake deadline",
                            grace.as_millis()
                        )
                    })?
                    .ok_or_else(|| {
                        anyhow!("worker {worker_id} ({peer}) disconnected during handshake")
                    })?;
                let (version, tier, quant_client) = match hello {
                    Message::Hello { version, tier, quant_client } => {
                        (version, tier, quant_client)
                    }
                    other => bail!("worker {worker_id} ({peer}): expected Hello, got {other:?}"),
                };
                // both ends run at the minimum version (decode already
                // guarantees version >= 2)
                let version = version.min(PROTOCOL_VERSION);
                // per-worker codec: explicit override > tier preset > default
                let codec_id = if let Some(spec) = quant_client {
                    server.register_client_codec(&spec).with_context(|| {
                        format!("worker {worker_id} ({peer}): bad quant_client '{spec}'")
                    })?
                } else if let Some(name) = tier {
                    match tiers.iter().position(|t| t.name == name) {
                        Some(i) => tier_codecs[i],
                        None => bail!(
                            "worker {worker_id} ({peer}): unknown tier '{name}' (known: {})",
                            tiers
                                .iter()
                                .map(|t| t.name.as_str())
                                .collect::<Vec<_>>()
                                .join(", ")
                        ),
                    }
                } else {
                    0
                };
                write_msg(
                    &mut writer,
                    &Message::JoinV2 {
                        version,
                        worker_id,
                        d: d as u32,
                        x0: x_join.clone(),
                        client_quant: server.client_codec_name(codec_id),
                        server_quant: self.cfg.quant.server.clone(),
                        client_lr: self.cfg.fl.client_lr,
                        codec_id: codec_id as u32,
                    },
                )
                .with_context(|| format!("sending JoinV2 to worker {worker_id} ({peer})"))?;
                (version, codec_id)
            } else {
                // v1 worker: the legacy Join, bit-identical to the
                // pre-v2 protocol (pinned by a golden test)
                write_msg(
                    &mut writer,
                    &Message::Join {
                        worker_id,
                        d: d as u32,
                        x0: x_join.clone(),
                        client_quant: self.cfg.quant.client.clone(),
                        server_quant: self.cfg.quant.server.clone(),
                        client_lr: self.cfg.fl.client_lr,
                    },
                )
                .with_context(|| format!("sending Join to worker {worker_id} ({peer})"))?;
                (1u8, 0usize)
            };
            // handshake over: the steady-state reader blocks as long as
            // it likes (clears the deadline on the shared socket)
            reader
                .set_read_timeout(None)
                .with_context(|| format!("worker {worker_id} ({peer}): clearing deadline"))?;

            // reader thread: a worker dying (EOF, reset) is a tolerable
            // disconnect, exactly as before v2; only *protocol*
            // violations — corrupt or oversized frames — are forwarded
            // as errors and abort the run with this worker's context
            let txc = tx.clone();
            reader_handles.push(std::thread::spawn(move || {
                loop {
                    match read_msg_classified(&mut reader) {
                        ReadOutcome::Msg(msg) => {
                            if txc.send((worker_id, Ok(Some(msg)))).is_err() {
                                break;
                            }
                        }
                        ReadOutcome::Disconnected(_) => {
                            let _ = txc.send((worker_id, Ok(None)));
                            break;
                        }
                        ReadOutcome::BadFrame(e) => {
                            let _ = txc.send((worker_id, Err(e)));
                            break;
                        }
                    }
                }
            }));

            // persistent writer thread: its own outbound queue, frames
            // pre-encoded and shared; returns what it actually wrote
            // (and the span-gated wall time spent writing it)
            let (wtx, wrx) = mpsc::channel::<Arc<[u8]>>();
            writer_handles.push(std::thread::spawn(move || {
                let mut frames = 0u64;
                let mut bytes = 0u64;
                let mut send_ns = 0u64;
                for frame in wrx {
                    let timer = telemetry::span_start();
                    if writer.write_all(&frame).is_err() {
                        break; // dead worker: its reader thread reports it
                    }
                    send_ns += telemetry::span_ns(timer);
                    frames += 1;
                    bytes += frame.len() as u64;
                }
                (frames, bytes, send_ns)
            }));
            writers.push(wtx);

            tracing_log(&format!(
                "leader: worker {worker_id} joined from {peer} (protocol v{protocol}, codec '{}')",
                server.client_codec_name(codec_id)
            ));
            stats.push(WorkerStats {
                worker_id,
                peer,
                protocol,
                codec_id,
                codec: server.client_codec_name(codec_id),
                uploads: 0,
                upload_bytes: 0,
                partials: 0,
                broadcast_frames: 0,
                broadcast_bytes: 0,
                ingest_ns: 0,
                send_ns: 0,
                staleness: StalenessHist::default(),
            });
        }
        drop(tx);

        // every codec is registered once the accept loop is done, so the
        // journal header (meta, init, codec registry) goes out before
        // the first ingest — the order replay demands
        if recorder.on() {
            if !self.resume {
                recorder.emit(Event::Meta {
                    runtime: "tcp".into(),
                    algorithm: self.cfg.fl.algorithm.name().to_string(),
                    d: d as u64,
                    seed: self.seed,
                    fingerprint: telemetry::run_fingerprint(&self.cfg, self.seed),
                    git: telemetry::git_describe(),
                    config: self.cfg.to_json(),
                })?;
                recorder.emit(Event::Init { x0: self.x0.clone(), server_seed: self.seed })?;
            }
            for id in journaled_client..server.num_client_codecs() {
                recorder.emit(Event::Codec {
                    reg: "client".into(),
                    id: id as u64,
                    spec: server.client_codec_name(id),
                })?;
            }
            if !self.resume {
                recorder.emit(Event::Codec {
                    reg: "partial".into(),
                    id: 0,
                    spec: server.partial_codec_name(0),
                })?;
            }
        }

        // main coordination loop
        let mut live = n_workers;
        let mut byes = 0usize;
        let mut shutdown_sent = false;
        // journal step/progress state: slots since the last step (the
        // Step event's k), the run-wide staleness histogram quantiles on
        // the progress line draw from, the previous Step event (deltas)
        let mut slots_since_step: u64 = 0;
        let mut hist_all = StalenessHist::default();
        let mut prev_step_ev: Option<Event> = None;
        while live > 0 {
            let (worker_id, incoming) = rx.recv().map_err(|_| anyhow!("all workers gone"))?;
            let wid = worker_id as usize;
            let msg = match incoming {
                Ok(Some(m)) => m,
                Ok(None) => {
                    live -= 1;
                    continue;
                }
                Err(e) => {
                    // only reachable for protocol violations (corrupt
                    // frames); transport-level disconnects arrive as
                    // Ok(None) and are tolerated above
                    if shutdown_sent {
                        live -= 1;
                        continue;
                    }
                    return Err(e.context(format!(
                        "reading from worker {worker_id} ({})",
                        stats[wid].peer
                    )));
                }
            };
            // normalize v1/v2 uploads and edge partials into one
            // registry-routed ingest
            enum Inbound {
                Update { t_start: u64, codec_id: usize, payload: Vec<u8> },
                Partial { codec_id: usize, count: u32, hist: StalenessHist, payload: Vec<u8> },
            }
            let inbound = match msg {
                Message::Update { t_start, payload, .. } => {
                    Inbound::Update { t_start, codec_id: 0, payload }
                }
                Message::UpdateV2 { t_start, codec_id, payload, .. } => {
                    Inbound::Update { t_start, codec_id: codec_id as usize, payload }
                }
                Message::UpdatePartial {
                    codec_id,
                    count,
                    stale_counts,
                    stale_sum,
                    stale_max,
                    stale_n,
                    payload,
                    ..
                } => Inbound::Partial {
                    codec_id: codec_id as usize,
                    count,
                    hist: StalenessHist::from_parts(stale_counts, stale_sum, stale_max, stale_n),
                    payload,
                },
                Message::Bye { worker_id: wid2, uploads } => {
                    byes += 1;
                    tracing_log(&format!("leader: worker {wid2} done ({uploads} uploads)"));
                    continue;
                }
                other => {
                    tracing_log(&format!(
                        "leader: unexpected message from {worker_id}: {other:?}"
                    ));
                    continue;
                }
            };
            if shutdown_sent {
                continue; // late update after shutdown: drop
            }
            let now = t_base + run_start.elapsed().as_secs_f64();
            let step = match inbound {
                Inbound::Update { t_start, codec_id, payload } => {
                    // the tag must be the codec this connection negotiated
                    // at join: two registered codecs can share a wire size
                    // at some d, so accepting a mismatched (even
                    // registered) id could silently mis-decode into the
                    // aggregation buffer — and per-worker accounting is
                    // keyed by the negotiated codec
                    if codec_id != stats[wid].codec_id {
                        bail!(
                            "worker {worker_id} ({}): upload tagged codec id {codec_id}, but \
                             this connection negotiated codec id {} ('{}')",
                            stats[wid].peer,
                            stats[wid].codec_id,
                            stats[wid].codec
                        );
                    }
                    let qmsg = QuantizedMsg { payload, d };
                    let wire = qmsg.wire_bytes();
                    // a worker's snapshot can never predate its join-time
                    // model (the checkpointed x̂ after a resume), so its
                    // t_start is floored there — a no-op on fresh runs
                    // where join_step is 0
                    let staleness = server.t().saturating_sub(t_start.max(join_step));
                    if recorder.on() {
                        recorder.emit(Event::Ingest {
                            time: now,
                            step: server.t(),
                            worker: worker_id as u64,
                            codec: codec_id as u64,
                            staleness,
                            payload: qmsg.payload.clone(),
                        })?;
                    }
                    let timer = telemetry::span_start();
                    let step =
                        server.ingest_from(&qmsg, staleness, codec_id).with_context(|| {
                            format!(
                                "ingesting upload from worker {worker_id} ({}, codec '{}')",
                                stats[wid].peer,
                                server.client_codec_name(codec_id)
                            )
                        })?;
                    stats[wid].ingest_ns += telemetry::span_ns(timer);
                    stats[wid].uploads += 1;
                    stats[wid].upload_bytes += wire as u64;
                    stats[wid].staleness.record(staleness);
                    hist_all.record(staleness);
                    slots_since_step += 1;
                    step
                }
                Inbound::Partial { codec_id, count, hist, payload } => {
                    // an edge leader forwarding its buffer: staleness was
                    // weighted downstream, the histogram travels for
                    // accounting and is merged here
                    let qmsg = QuantizedMsg { payload, d };
                    let wire = qmsg.wire_bytes();
                    if recorder.on() {
                        recorder.emit(Event::IngestPartial {
                            time: now,
                            step: server.t(),
                            worker: worker_id as u64,
                            codec: codec_id as u64,
                            count: u64::from(count),
                            stale_counts: hist.counts.clone(),
                            stale_sum: hist.sum,
                            stale_max: hist.max,
                            stale_n: hist.n,
                            payload: qmsg.payload.clone(),
                        })?;
                    }
                    let timer = telemetry::span_start();
                    let step = server
                        .ingest_partial(&qmsg, count, &hist, codec_id)
                        .with_context(|| {
                            format!(
                                "ingesting partial aggregate from edge {worker_id} ({})",
                                stats[wid].peer
                            )
                        })?;
                    stats[wid].ingest_ns += telemetry::span_ns(timer);
                    stats[wid].uploads += 1;
                    stats[wid].upload_bytes += wire as u64;
                    stats[wid].partials += 1;
                    stats[wid].codec = server.partial_codec_name(codec_id);
                    stats[wid].staleness.merge(&hist);
                    hist_all.merge(&hist);
                    slots_since_step += u64::from(count);
                    step
                }
            };

            if let ServerStep::Stepped(b) = step {
                if recorder.on() || tel.progress > 0 {
                    let step_ev = Event::Step {
                        time: now,
                        step: server.t(),
                        k: slots_since_step,
                        uploads: server.comm.uploads,
                        upload_bytes: server.comm.upload_bytes,
                        broadcast_bytes: server.comm.broadcast_bytes,
                        stale_mean: server.staleness_mean(),
                        stale_max: server.staleness_max,
                        stages: telemetry::enabled().then(|| server.stage_timings().clone()),
                    };
                    if recorder.on() {
                        recorder.emit(step_ev.clone())?;
                        recorder.emit(Event::Broadcast {
                            time: now,
                            step: b.t,
                            absolute: b.absolute,
                            payload: b.msg.payload.clone(),
                        })?;
                    }
                    if tel.progress > 0 && server.t() % tel.progress == 0 {
                        if let Some(line) =
                            progress_line(&step_ev, prev_step_ev.as_ref(), &hist_all)
                        {
                            eprintln!("[qafel] {line}");
                        }
                    }
                    prev_step_ev = Some(step_ev);
                }
                slots_since_step = 0;
                if tel.checkpoint_every > 0 && server.t() % tel.checkpoint_every == 0 {
                    let state = Json::obj(vec![
                        ("wall", Json::str(hex_u64(now.to_bits()))),
                        ("server", server.state_json()),
                    ]);
                    recorder.emit(Event::Checkpoint {
                        time: now,
                        step: server.t(),
                        state,
                    })?;
                }
                // encode once, share with every writer queue
                let frame: Arc<[u8]> = frame_bytes(&Message::Broadcast {
                    t: b.t,
                    absolute: b.absolute,
                    payload: b.msg.payload,
                })?
                .into();
                for w in &writers {
                    let _ = w.send(frame.clone());
                }
            }
            if server.t() >= self.cfg.stop.max_server_steps
                || server.comm.uploads >= self.cfg.stop.max_uploads
            {
                let frame: Arc<[u8]> = frame_bytes(&Message::Shutdown)?.into();
                for w in &writers {
                    let _ = w.send(frame.clone());
                }
                shutdown_sent = true;
            }
        }
        // shutdown: close the outbound queues, join the writer threads
        // (collecting what each actually wrote), then the readers
        drop(writers);
        for (i, h) in writer_handles.into_iter().enumerate() {
            if let Ok((frames, bytes, send_ns)) = h.join() {
                stats[i].broadcast_frames = frames;
                stats[i].broadcast_bytes = bytes;
                stats[i].send_ns = send_ns;
            }
        }
        for h in reader_handles {
            let _ = h.join();
        }
        let _ = byes;

        if recorder.on() {
            recorder.emit(Event::Final {
                step: server.t(),
                uploads: server.comm.uploads,
                upload_bytes: server.comm.upload_bytes,
                broadcasts: server.comm.broadcasts,
                broadcast_bytes: server.comm.broadcast_bytes,
                model: server.model().to_vec(),
            })?;
        }

        Ok(LeaderReport {
            comm: server.comm.clone(),
            server_steps: server.t(),
            staleness_max: server.staleness_max,
            staleness_mean: server.staleness_mean(),
            model: server.model().to_vec(),
            workers: n_workers,
            worker_stats: stats,
            stage_timings: server.stage_timings().clone(),
            fingerprint: telemetry::run_fingerprint(&self.cfg, self.seed),
            events: recorder.mem,
        })
    }
}

fn tracing_log(msg: &str) {
    if std::env::var("QAFEL_NET_LOG").is_ok() {
        eprintln!("{msg}");
    }
}
