//! The leader process: accepts workers, runs Algorithm 1 over TCP.

use super::message::Message;
use super::transport::{write_msg, Conn};
use crate::config::Config;
use crate::coordinator::{Server, ServerStep};
use crate::metrics::CommMetrics;
use crate::quant::QuantizedMsg;
use anyhow::{anyhow, Context, Result};
use std::net::TcpListener;
use std::sync::mpsc;

/// Final report of a leader run.
#[derive(Clone, Debug)]
pub struct LeaderReport {
    pub comm: CommMetrics,
    pub server_steps: u64,
    pub staleness_max: u64,
    pub staleness_mean: f64,
    /// Final server model x^T.
    pub model: Vec<f32>,
    pub workers: usize,
}

/// Leader configuration + run loop.
pub struct Leader {
    cfg: Config,
    x0: Vec<f32>,
    seed: u64,
}

impl Leader {
    pub fn new(cfg: Config, x0: Vec<f32>, seed: u64) -> Leader {
        Leader { cfg, x0, seed }
    }

    /// Serve on `addr` (e.g. "127.0.0.1:7710"), wait for exactly
    /// `n_workers` workers, coordinate until a stop cap is hit, shut the
    /// workers down, and report.
    pub fn run(&self, addr: &str, n_workers: usize) -> Result<LeaderReport> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        self.run_on(listener, n_workers)
    }

    /// Like [`Leader::run`] with a pre-bound listener (lets tests use an
    /// ephemeral port).
    pub fn run_on(&self, listener: TcpListener, n_workers: usize) -> Result<LeaderReport> {
        // cfg.fl.shards > 1 turns on the shard-parallel aggregation
        // pipeline inside the server; the wire protocol is unchanged
        // (broadcast bytes are bit-identical for every shard count).
        let mut server = Server::build(&self.cfg, self.x0.clone(), self.seed)?;
        let d = server.d();
        if server.shards() > 1 {
            tracing_log(&format!("leader: sharded aggregation, S={}", server.shards()));
        }

        // accept all workers, send Join, spawn reader threads
        let (tx, rx) = mpsc::channel::<(u32, Option<Message>)>();
        let mut writers = Vec::new();
        let mut reader_handles = Vec::new();
        for worker_id in 0..n_workers as u32 {
            let (stream, peer) = listener.accept().context("accepting worker")?;
            let mut conn = Conn::from_stream(stream)?;
            conn.send(&Message::Join {
                worker_id,
                d: d as u32,
                x0: self.x0.clone(),
                client_quant: self.cfg.quant.client.clone(),
                server_quant: self.cfg.quant.server.clone(),
                client_lr: self.cfg.fl.client_lr,
            })?;
            let tx = tx.clone();
            let mut reader = conn.reader.try_clone().context("cloning reader")?;
            reader_handles.push(std::thread::spawn(move || {
                loop {
                    match super::transport::read_msg(&mut reader) {
                        Ok(Some(msg)) => {
                            if tx.send((worker_id, Some(msg))).is_err() {
                                break;
                            }
                        }
                        Ok(None) | Err(_) => {
                            let _ = tx.send((worker_id, None));
                            break;
                        }
                    }
                }
            }));
            tracing_log(&format!("leader: worker {worker_id} joined from {peer}"));
            writers.push(conn.writer);
        }
        drop(tx);

        // main coordination loop
        let mut live = n_workers;
        let mut byes = 0usize;
        let mut shutdown_sent = false;
        while live > 0 {
            let (worker_id, msg) = rx.recv().map_err(|_| anyhow!("all workers gone"))?;
            let msg = match msg {
                Some(m) => m,
                None => {
                    live -= 1;
                    continue;
                }
            };
            match msg {
                Message::Update { t_start, trip: _, train_loss: _, payload, .. } => {
                    if shutdown_sent {
                        continue; // late update after shutdown: drop
                    }
                    let qmsg = QuantizedMsg { payload, d };
                    let staleness = server.t().saturating_sub(t_start);
                    if let ServerStep::Stepped(b) = server.ingest(&qmsg, staleness)? {
                        let bmsg = Message::Broadcast {
                            t: b.t,
                            absolute: b.absolute,
                            payload: b.msg.payload,
                        };
                        for w in &mut writers {
                            // a dead worker surfaces via its reader thread
                            let _ = write_msg(w, &bmsg);
                        }
                    }
                    if server.t() >= self.cfg.stop.max_server_steps
                        || server.comm.uploads >= self.cfg.stop.max_uploads
                    {
                        for w in &mut writers {
                            let _ = write_msg(w, &Message::Shutdown);
                        }
                        shutdown_sent = true;
                    }
                }
                Message::Bye { worker_id: wid, uploads } => {
                    byes += 1;
                    tracing_log(&format!("leader: worker {wid} done ({uploads} uploads)"));
                }
                other => {
                    tracing_log(&format!("leader: unexpected message from {worker_id}: {other:?}"));
                }
            }
        }
        for h in reader_handles {
            let _ = h.join();
        }
        let _ = byes;

        Ok(LeaderReport {
            comm: server.comm.clone(),
            server_steps: server.t(),
            staleness_max: server.staleness_max,
            staleness_mean: server.staleness_mean(),
            model: server.model().to_vec(),
            workers: n_workers,
        })
    }
}

fn tracing_log(msg: &str) {
    if std::env::var("QAFEL_NET_LOG").is_ok() {
        eprintln!("{msg}");
    }
}
