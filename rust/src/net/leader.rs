//! The leader process: accepts workers, runs Algorithm 1 over TCP.
//!
//! **Protocol negotiation** (wire v2, see `net/message.rs`): v2 workers
//! speak first with `Hello`; a v1 worker connects silently and waits
//! for `Join`, so the leader classifies a connection that stays silent
//! for `net.v1_grace_ms` as v1 and serves it the legacy frames
//! bit-identically. Each v2 worker's upload codec is resolved from its
//! `Hello` (explicit `quant_client` override, else its tier's
//! `scenario.tiers.<name>.quant_client` preset, else the default) and
//! registered in the server's codec registry; every `UpdateV2` is then
//! routed by its `codec_id` through [`Server::ingest_from`] — no
//! payload-size guessing, no ambiguous-size failure mode.
//!
//! **Broadcast fan-out**: one persistent writer thread per worker with
//! its own outbound queue. Each broadcast frame is encoded exactly once
//! and shared as `Arc<[u8]>`, so a slow or dead worker can never stall
//! the step loop; writers are joined on shutdown (like `ShardPool`
//! workers) and report the bytes they actually put on the wire, which
//! feeds the per-worker accounting in [`LeaderReport`].

use super::message::{Message, PROTOCOL_VERSION};
use super::transport::{frame_bytes, read_msg, read_msg_classified, write_msg, ReadOutcome};
use crate::config::Config;
use crate::coordinator::{Server, ServerStep};
use crate::metrics::CommMetrics;
use crate::quant::QuantizedMsg;
use crate::scenario::StalenessHist;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{ErrorKind, Write};
use std::net::TcpListener;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

/// Per-worker accounting, mirroring the simulator's per-tier
/// [`crate::scenario::TierMetrics`]: what each connection uploaded,
/// what was actually written to it, and the staleness it produced.
#[derive(Clone, Debug)]
pub struct WorkerStats {
    pub worker_id: u32,
    /// Peer address the worker connected from.
    pub peer: String,
    /// Negotiated protocol version (1 = legacy silent join, 2 = Hello
    /// handshake with per-worker codec).
    pub protocol: u8,
    /// The worker's upload codec in the server registry (0 = default).
    pub codec_id: usize,
    /// Resolved spec name of that codec (e.g. `"top:0.1"`).
    pub codec: String,
    /// Ingested uploads from this worker (late post-shutdown uploads are
    /// dropped and not counted, matching the server's totals).
    pub uploads: u64,
    /// Sum of the ingested upload payload bytes, as counted off the
    /// wire frames (not derived from the codec formula).
    pub upload_bytes: u64,
    /// Of `uploads`, how many were `UpdatePartial` frames from a
    /// downstream edge leader (0 for plain workers). When non-zero,
    /// `codec` is the partial codec `Q_p` the frames were decoded with.
    pub partials: u64,
    /// Frames this worker's writer thread actually wrote (broadcasts +
    /// the shutdown frame; the join frame is written before the writer
    /// thread starts).
    pub broadcast_frames: u64,
    /// Bytes this worker's writer thread actually wrote.
    pub broadcast_bytes: u64,
    /// Staleness histogram over this worker's ingested uploads.
    pub staleness: StalenessHist,
}

/// One ingested upload in a recorded trace (see [`LeaderTrace`]).
#[derive(Clone, Debug)]
pub struct TraceUpdate {
    pub worker_id: u32,
    /// Codec registry id the payload was decoded with.
    pub codec: usize,
    /// Staleness the leader observed for this upload.
    pub staleness: u64,
    /// The exact wire payload.
    pub payload: Vec<u8>,
}

/// A full record of the server-relevant event order of a run — enough
/// to replay the leader's trajectory through the simulator's
/// [`Server::ingest_from`] path and compare bit-for-bit. Recorded only
/// when [`Leader::record_trace`] is set (tests); off by default.
#[derive(Clone, Debug, Default)]
pub struct LeaderTrace {
    /// Spec names of the registered client codecs, in registry-id order
    /// (replays must rebuild the registry in this order).
    pub codecs: Vec<String>,
    /// Every ingested upload, in ingest order.
    pub updates: Vec<TraceUpdate>,
    /// Every broadcast payload, in step order.
    pub broadcasts: Vec<Vec<u8>>,
}

/// Final report of a leader run.
#[derive(Clone, Debug)]
pub struct LeaderReport {
    pub comm: CommMetrics,
    pub server_steps: u64,
    pub staleness_max: u64,
    pub staleness_mean: f64,
    /// Final server model x^T.
    pub model: Vec<f32>,
    pub workers: usize,
    /// Per-worker byte/staleness accounting, indexed by worker id.
    pub worker_stats: Vec<WorkerStats>,
    /// Present when [`Leader::record_trace`] was set.
    pub trace: Option<LeaderTrace>,
}

/// Leader configuration + run loop.
pub struct Leader {
    cfg: Config,
    x0: Vec<f32>,
    seed: u64,
    /// Record the full update/broadcast trace into the report (tests:
    /// replay against the simulator's ingest path). Default off.
    pub record_trace: bool,
}

impl Leader {
    pub fn new(cfg: Config, x0: Vec<f32>, seed: u64) -> Leader {
        Leader { cfg, x0, seed, record_trace: false }
    }

    /// Serve on `addr` (e.g. "127.0.0.1:7710"), wait for exactly
    /// `n_workers` workers, coordinate until a stop cap is hit, shut the
    /// workers down, and report.
    pub fn run(&self, addr: &str, n_workers: usize) -> Result<LeaderReport> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        self.run_on(listener, n_workers)
    }

    /// Like [`Leader::run`] with a pre-bound listener (lets tests use an
    /// ephemeral port).
    pub fn run_on(&self, listener: TcpListener, n_workers: usize) -> Result<LeaderReport> {
        // cfg.fl.shards > 1 turns on the shard-parallel aggregation
        // pipeline inside the server; the wire protocol is unchanged
        // (broadcast bytes are bit-identical for every shard count).
        let mut server = Server::build(&self.cfg, self.x0.clone(), self.seed)?;
        let d = server.d();
        if server.shards() > 1 {
            tracing_log(&format!("leader: sharded aggregation, S={}", server.shards()));
        }
        // Tier presets are registered up front in tier order, exactly as
        // the scenario engine does, so codec ids agree with a simulator
        // run of the same config.
        let tiers = self.cfg.resolved_tiers();
        let tier_codecs = server.register_tier_presets(&self.cfg)?;
        // Partial-aggregate codec (leader-to-leader v2 frames): registered
        // up front from config so edges and root agree on registry id 0 —
        // registration order is the wire contract, as for client codecs.
        server.register_partial_codec(&self.cfg.net.partial_codec)?;
        let grace = Duration::from_millis(self.cfg.net.v1_grace_ms.max(1));

        // accept all workers: negotiate the protocol, send the join
        // frame, then spawn one reader and one writer thread each
        let (tx, rx) = mpsc::channel::<(u32, Result<Option<Message>>)>();
        let mut writers: Vec<mpsc::Sender<Arc<[u8]>>> = Vec::new();
        let mut writer_handles = Vec::new();
        let mut reader_handles = Vec::new();
        let mut stats: Vec<WorkerStats> = Vec::new();
        for worker_id in 0..n_workers as u32 {
            let (stream, peer) = listener.accept().context("accepting worker")?;
            stream.set_nodelay(true).ok();
            let peer = peer.to_string();

            // v2 workers send Hello immediately on connect; a v1 worker
            // waits silently for Join. Peek (never consume) with a
            // bounded timeout to classify the peer without corrupting
            // the stream.
            stream
                .set_read_timeout(Some(grace))
                .with_context(|| format!("worker {worker_id} ({peer}): handshake timeout"))?;
            let mut probe = [0u8; 1];
            let spoke = match stream.peek(&mut probe) {
                Ok(n) => n > 0,
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => false,
                Err(e) => {
                    return Err(e)
                        .with_context(|| format!("probing worker {worker_id} ({peer})"));
                }
            };
            // the read timeout stays armed through the Hello read: a
            // peer that sends a partial frame and stalls fails the
            // handshake loudly instead of wedging the serial accept
            // loop; it is cleared below before the reader thread (which
            // must block indefinitely) takes over
            let mut reader = stream.try_clone().context("cloning tcp stream")?;
            let mut writer = stream;

            let (protocol, codec_id) = if spoke {
                let hello = read_msg(&mut reader)
                    .with_context(|| {
                        format!(
                            "reading Hello from worker {worker_id} ({peer}) \
                             within the {}ms handshake deadline",
                            grace.as_millis()
                        )
                    })?
                    .ok_or_else(|| {
                        anyhow!("worker {worker_id} ({peer}) disconnected during handshake")
                    })?;
                let (version, tier, quant_client) = match hello {
                    Message::Hello { version, tier, quant_client } => {
                        (version, tier, quant_client)
                    }
                    other => bail!("worker {worker_id} ({peer}): expected Hello, got {other:?}"),
                };
                // both ends run at the minimum version (decode already
                // guarantees version >= 2)
                let version = version.min(PROTOCOL_VERSION);
                // per-worker codec: explicit override > tier preset > default
                let codec_id = if let Some(spec) = quant_client {
                    server.register_client_codec(&spec).with_context(|| {
                        format!("worker {worker_id} ({peer}): bad quant_client '{spec}'")
                    })?
                } else if let Some(name) = tier {
                    match tiers.iter().position(|t| t.name == name) {
                        Some(i) => tier_codecs[i],
                        None => bail!(
                            "worker {worker_id} ({peer}): unknown tier '{name}' (known: {})",
                            tiers
                                .iter()
                                .map(|t| t.name.as_str())
                                .collect::<Vec<_>>()
                                .join(", ")
                        ),
                    }
                } else {
                    0
                };
                write_msg(
                    &mut writer,
                    &Message::JoinV2 {
                        version,
                        worker_id,
                        d: d as u32,
                        x0: self.x0.clone(),
                        client_quant: server.client_codec_name(codec_id),
                        server_quant: self.cfg.quant.server.clone(),
                        client_lr: self.cfg.fl.client_lr,
                        codec_id: codec_id as u32,
                    },
                )
                .with_context(|| format!("sending JoinV2 to worker {worker_id} ({peer})"))?;
                (version, codec_id)
            } else {
                // v1 worker: the legacy Join, bit-identical to the
                // pre-v2 protocol (pinned by a golden test)
                write_msg(
                    &mut writer,
                    &Message::Join {
                        worker_id,
                        d: d as u32,
                        x0: self.x0.clone(),
                        client_quant: self.cfg.quant.client.clone(),
                        server_quant: self.cfg.quant.server.clone(),
                        client_lr: self.cfg.fl.client_lr,
                    },
                )
                .with_context(|| format!("sending Join to worker {worker_id} ({peer})"))?;
                (1u8, 0usize)
            };
            // handshake over: the steady-state reader blocks as long as
            // it likes (clears the deadline on the shared socket)
            reader
                .set_read_timeout(None)
                .with_context(|| format!("worker {worker_id} ({peer}): clearing deadline"))?;

            // reader thread: a worker dying (EOF, reset) is a tolerable
            // disconnect, exactly as before v2; only *protocol*
            // violations — corrupt or oversized frames — are forwarded
            // as errors and abort the run with this worker's context
            let txc = tx.clone();
            reader_handles.push(std::thread::spawn(move || {
                loop {
                    match read_msg_classified(&mut reader) {
                        ReadOutcome::Msg(msg) => {
                            if txc.send((worker_id, Ok(Some(msg)))).is_err() {
                                break;
                            }
                        }
                        ReadOutcome::Disconnected(_) => {
                            let _ = txc.send((worker_id, Ok(None)));
                            break;
                        }
                        ReadOutcome::BadFrame(e) => {
                            let _ = txc.send((worker_id, Err(e)));
                            break;
                        }
                    }
                }
            }));

            // persistent writer thread: its own outbound queue, frames
            // pre-encoded and shared; returns what it actually wrote
            let (wtx, wrx) = mpsc::channel::<Arc<[u8]>>();
            writer_handles.push(std::thread::spawn(move || {
                let mut frames = 0u64;
                let mut bytes = 0u64;
                for frame in wrx {
                    if writer.write_all(&frame).is_err() {
                        break; // dead worker: its reader thread reports it
                    }
                    frames += 1;
                    bytes += frame.len() as u64;
                }
                (frames, bytes)
            }));
            writers.push(wtx);

            tracing_log(&format!(
                "leader: worker {worker_id} joined from {peer} (protocol v{protocol}, codec '{}')",
                server.client_codec_name(codec_id)
            ));
            stats.push(WorkerStats {
                worker_id,
                peer,
                protocol,
                codec_id,
                codec: server.client_codec_name(codec_id),
                uploads: 0,
                upload_bytes: 0,
                partials: 0,
                broadcast_frames: 0,
                broadcast_bytes: 0,
                staleness: StalenessHist::default(),
            });
        }
        drop(tx);

        // main coordination loop
        let mut trace = self.record_trace.then(LeaderTrace::default);
        let mut live = n_workers;
        let mut byes = 0usize;
        let mut shutdown_sent = false;
        while live > 0 {
            let (worker_id, incoming) = rx.recv().map_err(|_| anyhow!("all workers gone"))?;
            let wid = worker_id as usize;
            let msg = match incoming {
                Ok(Some(m)) => m,
                Ok(None) => {
                    live -= 1;
                    continue;
                }
                Err(e) => {
                    // only reachable for protocol violations (corrupt
                    // frames); transport-level disconnects arrive as
                    // Ok(None) and are tolerated above
                    if shutdown_sent {
                        live -= 1;
                        continue;
                    }
                    return Err(e.context(format!(
                        "reading from worker {worker_id} ({})",
                        stats[wid].peer
                    )));
                }
            };
            // normalize v1/v2 uploads and edge partials into one
            // registry-routed ingest
            enum Inbound {
                Update { t_start: u64, codec_id: usize, payload: Vec<u8> },
                Partial { codec_id: usize, count: u32, hist: StalenessHist, payload: Vec<u8> },
            }
            let inbound = match msg {
                Message::Update { t_start, payload, .. } => {
                    Inbound::Update { t_start, codec_id: 0, payload }
                }
                Message::UpdateV2 { t_start, codec_id, payload, .. } => {
                    Inbound::Update { t_start, codec_id: codec_id as usize, payload }
                }
                Message::UpdatePartial {
                    codec_id,
                    count,
                    stale_counts,
                    stale_sum,
                    stale_max,
                    stale_n,
                    payload,
                    ..
                } => Inbound::Partial {
                    codec_id: codec_id as usize,
                    count,
                    hist: StalenessHist::from_parts(stale_counts, stale_sum, stale_max, stale_n),
                    payload,
                },
                Message::Bye { worker_id: wid2, uploads } => {
                    byes += 1;
                    tracing_log(&format!("leader: worker {wid2} done ({uploads} uploads)"));
                    continue;
                }
                other => {
                    tracing_log(&format!(
                        "leader: unexpected message from {worker_id}: {other:?}"
                    ));
                    continue;
                }
            };
            if shutdown_sent {
                continue; // late update after shutdown: drop
            }
            let step = match inbound {
                Inbound::Update { t_start, codec_id, payload } => {
                    // the tag must be the codec this connection negotiated
                    // at join: two registered codecs can share a wire size
                    // at some d, so accepting a mismatched (even
                    // registered) id could silently mis-decode into the
                    // aggregation buffer — and per-worker accounting is
                    // keyed by the negotiated codec
                    if codec_id != stats[wid].codec_id {
                        bail!(
                            "worker {worker_id} ({}): upload tagged codec id {codec_id}, but \
                             this connection negotiated codec id {} ('{}')",
                            stats[wid].peer,
                            stats[wid].codec_id,
                            stats[wid].codec
                        );
                    }
                    let qmsg = QuantizedMsg { payload, d };
                    let wire = qmsg.wire_bytes();
                    let staleness = server.t().saturating_sub(t_start);
                    if let Some(tr) = trace.as_mut() {
                        tr.updates.push(TraceUpdate {
                            worker_id,
                            codec: codec_id,
                            staleness,
                            payload: qmsg.payload.clone(),
                        });
                    }
                    let step =
                        server.ingest_from(&qmsg, staleness, codec_id).with_context(|| {
                            format!(
                                "ingesting upload from worker {worker_id} ({}, codec '{}')",
                                stats[wid].peer,
                                server.client_codec_name(codec_id)
                            )
                        })?;
                    stats[wid].uploads += 1;
                    stats[wid].upload_bytes += wire as u64;
                    stats[wid].staleness.record(staleness);
                    step
                }
                Inbound::Partial { codec_id, count, hist, payload } => {
                    // an edge leader forwarding its buffer: staleness was
                    // weighted downstream, the histogram travels for
                    // accounting and is merged here (not recorded in the
                    // per-update trace — partials replay through
                    // `ingest_partial`, not `ingest_from`)
                    let qmsg = QuantizedMsg { payload, d };
                    let wire = qmsg.wire_bytes();
                    let step = server
                        .ingest_partial(&qmsg, count, &hist, codec_id)
                        .with_context(|| {
                            format!(
                                "ingesting partial aggregate from edge {worker_id} ({})",
                                stats[wid].peer
                            )
                        })?;
                    stats[wid].uploads += 1;
                    stats[wid].upload_bytes += wire as u64;
                    stats[wid].partials += 1;
                    stats[wid].codec = server.partial_codec_name(codec_id);
                    stats[wid].staleness.merge(&hist);
                    step
                }
            };

            if let ServerStep::Stepped(b) = step {
                if let Some(tr) = trace.as_mut() {
                    tr.broadcasts.push(b.msg.payload.clone());
                }
                // encode once, share with every writer queue
                let frame: Arc<[u8]> = frame_bytes(&Message::Broadcast {
                    t: b.t,
                    absolute: b.absolute,
                    payload: b.msg.payload,
                })?
                .into();
                for w in &writers {
                    let _ = w.send(frame.clone());
                }
            }
            if server.t() >= self.cfg.stop.max_server_steps
                || server.comm.uploads >= self.cfg.stop.max_uploads
            {
                let frame: Arc<[u8]> = frame_bytes(&Message::Shutdown)?.into();
                for w in &writers {
                    let _ = w.send(frame.clone());
                }
                shutdown_sent = true;
            }
        }
        // shutdown: close the outbound queues, join the writer threads
        // (collecting what each actually wrote), then the readers
        drop(writers);
        for (i, h) in writer_handles.into_iter().enumerate() {
            if let Ok((frames, bytes)) = h.join() {
                stats[i].broadcast_frames = frames;
                stats[i].broadcast_bytes = bytes;
            }
        }
        for h in reader_handles {
            let _ = h.join();
        }
        let _ = byes;

        if let Some(tr) = trace.as_mut() {
            tr.codecs = (0..server.num_client_codecs())
                .map(|i| server.client_codec_name(i))
                .collect();
        }

        Ok(LeaderReport {
            comm: server.comm.clone(),
            server_steps: server.t(),
            staleness_max: server.staleness_max,
            staleness_mean: server.staleness_mean(),
            model: server.model().to_vec(),
            workers: n_workers,
            worker_stats: stats,
            trace,
        })
    }
}

fn tracing_log(msg: &str) {
    if std::env::var("QAFEL_NET_LOG").is_ok() {
        eprintln!("{msg}");
    }
}
