//! Real distributed runtime: the same coordinator (Algorithms 1–3)
//! running over actual TCP connections between a **leader** process and
//! **worker** processes/threads (DESIGN.md S8).
//!
//! The virtual-time simulator answers the paper's questions; this module
//! proves the coordinator is a deployable system, not only a model:
//! the leader owns the [`crate::coordinator::Server`] state machine, each
//! worker owns a [`crate::coordinator::client::HiddenReplica`] (Algorithm
//! 3 as a real background thread) and a compute backend, and every
//! payload on the wire is the same packed bytes the codecs produce.
//!
//! No `tokio` offline: blocking I/O with one reader thread per
//! connection + an mpsc fan-in to the leader loop — the standard
//! thread-per-connection design, adequate for the tens of workers a
//! single-host deployment runs.

pub mod leader;
pub mod message;
pub mod transport;
pub mod worker;

pub use leader::{Leader, LeaderReport};
pub use message::Message;
pub use worker::Worker;
