//! Real distributed runtime: the same coordinator (Algorithms 1–3)
//! running over actual TCP connections between a **leader** process and
//! **worker** processes/threads (DESIGN.md S8).
//!
//! The virtual-time simulator answers the paper's questions; this module
//! proves the coordinator is a deployable system, not only a model:
//! the leader owns the [`crate::coordinator::Server`] state machine, each
//! worker owns a [`crate::coordinator::client::HiddenReplica`] (Algorithm
//! 3 as a real background thread) and a compute backend, and every
//! payload on the wire is the same packed bytes the codecs produce.
//!
//! Wire protocol **v2** (ARCHITECTURE.md §Wire protocol) negotiates a
//! per-worker upload codec at join time — the same heterogeneous-codec
//! model the scenario engine simulates with per-tier presets — and
//! tags every upload with its codec registry id, so the leader decodes
//! mixed wire formats through [`crate::coordinator::Server::ingest_from`]
//! exactly like the simulator. v1 workers (silent join, untagged
//! uploads) are detected by their initial silence and served the legacy
//! frames bit-identically.
//!
//! **Aggregation trees** (ISSUE 6): an [`edge::EdgeLeader`] is a v2
//! worker upstream and a leader downstream — it buffers its workers'
//! uploads in an [`crate::coordinator::EdgeAggregator`] and forwards
//! count-weighted quantized partials as `UpdatePartial` frames (tag 9),
//! which the root decodes through its partial-codec registry and folds
//! in via [`crate::coordinator::Server::ingest_partial`]. Broadcasts
//! are relayed down the tree byte-identically; a trivial tree (one
//! edge, `net.edge_buffer = 1`, identity `net.partial_codec`) replays
//! bit-identical to the flat topology.
//!
//! No `tokio` offline: blocking I/O with one reader thread and one
//! writer thread per connection + an mpsc fan-in to the leader loop —
//! the standard thread-per-connection design, adequate for the tens of
//! workers a single-host deployment runs. Broadcasts are encoded once
//! *per downlink family* (ISSUE 8: `scenario.tiers.<name>.quant_server`
//! resolves each tier to its own `Q_s`, negotiated in `JoinV2`) and
//! fanned out through per-worker [`queue::FrameQueue`]s, so one slow
//! worker cannot stall the step loop. With `net.broadcast_budget_bytes`
//! set, a backlogged worker's queue stays bounded: superseded frames are
//! evicted and the writer folds the gap into an incremental catch-up
//! from the server's [`crate::coordinator::UpdateLog`] — or one
//! `Sync` frame when the log has evicted the increments (Appendix B.1).
//!
//! **Adaptive quantization** (ISSUE 9): with `net.adaptive` enabled the
//! leader re-scores every plain v2 worker each `interval` steps — by
//! the bandwidth hint its `Hello` announced, else its observed upload
//! rate — and walks the slowest workers down a codec ladder until the
//! projected uplink traffic fits `budget_bytes_per_step`, switching a
//! worker's upload codec mid-run with a `Rekey` frame (tag 11). The
//! switch lands at a step boundary: uploads still in flight under the
//! old codec id stay accepted until the first new-tagged upload cuts
//! the transition window over, and per-epoch accounting in
//! [`leader::CodecEpoch`] keeps `upload_bytes == uploads x
//! expected_bytes` exact on both sides of the switch. v1 peers and
//! edge leaders never see the frame.

pub mod edge;
pub mod leader;
pub mod message;
pub mod queue;
pub mod transport;
pub mod worker;

pub use edge::{EdgeLeader, EdgeReport};
pub use leader::{CodecEpoch, Leader, LeaderReport, WorkerStats};
pub use message::{Message, PROTOCOL_VERSION};
pub use worker::{Worker, WorkerReport};
